//! Compression lab: grounds the paper's §3.2 what-if in *working codecs*.
//!
//! 1. Runs every codec over a synthetic gradient (achieved ratio +
//!    reconstruction error).
//! 2. Plugs each codec's achieved ratio into the what-if simulator at 10
//!    and 100 Gbps (Fig 8's question: how much ratio do you really need?).
//! 3. Demonstrates the convergence cost the paper warns about: SGD on a
//!    quadratic with compressed gradients, with and without error
//!    feedback.
//!
//! ```text
//! cargo run --release --example compression_lab
//! ```

use netbn::compress::{codecs, CodecKind, ErrorFeedback};
use netbn::models::timing::backward_trace;
use netbn::models::ModelId;
use netbn::report::Table;
use netbn::sim::{simulate, SimParams};
use netbn::util::Rng;

fn l2(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>().sqrt()
}

fn norm(a: &[f32]) -> f64 {
    a.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt().max(1e-12)
}

fn main() -> netbn::Result<()> {
    let kinds = [
        CodecKind::Fp16,
        CodecKind::Int8,
        CodecKind::OneBit,
        CodecKind::TopK { k_fraction: 0.1 },
        CodecKind::TopK { k_fraction: 0.01 },
        CodecKind::RandomK { k_fraction: 0.1 },
    ];

    // ---- 1. codec quality on a gradient-shaped vector ----
    let n = 1 << 20;
    let mut rng = Rng::new(0xc0dec);
    let mut grad = vec![0.0f32; n];
    // Heavy-tailed, like real gradients: normal + sparse spikes.
    for g in grad.iter_mut() {
        *g = rng.normal() as f32 * 0.01;
    }
    for _ in 0..n / 100 {
        let i = rng.next_below(n as u64) as usize;
        grad[i] = rng.normal() as f32 * 0.5;
    }
    let mut t = Table::new(
        "codec quality on a 4 MB heavy-tailed gradient",
        &["codec", "nominal ratio", "achieved ratio", "rel L2 error"],
    );
    let mut achieved = Vec::new();
    for kind in kinds {
        let enc = codecs::encode(kind, &grad, 7);
        let dec = codecs::decode(kind, &enc, 7)?;
        let err = l2(&grad, &dec) / norm(&grad);
        t.row(vec![
            kind.name(),
            format!("{:.1}x", kind.nominal_ratio()),
            format!("{:.1}x", enc.achieved_ratio()),
            format!("{err:.4}"),
        ]);
        achieved.push((kind, enc.achieved_ratio()));
    }
    println!("{}", t.render());

    // ---- 2. what each ratio buys at 10 vs 100 Gbps (VGG16, 64 GPUs) ----
    let trace = backward_trace(&ModelId::Vgg16.profile());
    let mut t = Table::new(
        "what-if scaling factor with each codec's achieved ratio (VGG16, 64 GPUs)",
        &["codec", "ratio", "sf @10 Gbps", "sf @100 Gbps"],
    );
    let sf = |bw: f64, ratio: f64| {
        let mut p = SimParams::whatif(trace.clone(), 8, 8, bw);
        p.compression_ratio = ratio;
        simulate(&p).scaling_factor
    };
    t.row(vec![
        "none".into(),
        "1.0x".into(),
        format!("{:.1}%", sf(10.0, 1.0) * 100.0),
        format!("{:.1}%", sf(100.0, 1.0) * 100.0),
    ]);
    for (kind, ratio) in &achieved {
        t.row(vec![
            kind.name(),
            format!("{ratio:.1}x"),
            format!("{:.1}%", sf(10.0, *ratio) * 100.0),
            format!("{:.1}%", sf(100.0, *ratio) * 100.0),
        ]);
    }
    println!("{}", t.render());
    println!(
        "Note the paper's point: at 100 Gbps every row is already ≈100% —\n\
         compression buys nothing; at 10 Gbps modest ratios (2–10x) do the job\n\
         and 50x+ is wasted.\n"
    );

    // ---- 3. convergence cost: SGD on a quadratic ----
    // minimize ||x - x*||^2 with gradient 2(x - x*), compressing gradients.
    let dim = 512;
    let mut target = vec![0.0f32; dim];
    Rng::new(5).fill_f32(&mut target, 1.0);
    let run = |kind: Option<CodecKind>, ef_on: bool| -> Vec<f64> {
        let mut x = vec![0.0f32; dim];
        let mut ef = kind.map(|k| ErrorFeedback::new(k, dim));
        let mut dists = Vec::new();
        for step in 0..200u64 {
            let g: Vec<f32> =
                x.iter().zip(&target).map(|(xi, ti)| 2.0 * (xi - ti)).collect();
            let applied: Vec<f32> = match (kind, ef_on) {
                (None, _) => g,
                (Some(k), true) => {
                    let enc = ef.as_mut().unwrap().compress(&g, step).unwrap();
                    codecs::decode(k, &enc, step).unwrap()
                }
                (Some(k), false) => {
                    let enc = codecs::encode(k, &g, step);
                    codecs::decode(k, &enc, step).unwrap()
                }
            };
            for (xi, gi) in x.iter_mut().zip(&applied) {
                *xi -= 0.05 * gi;
            }
            dists.push(l2(&x, &target));
        }
        dists
    };
    let mut t = Table::new(
        "distance to optimum after 200 SGD steps (convergence cost of lossy codecs)",
        &["gradient", "dist @50", "dist @200"],
    );
    let mut row = |name: &str, d: &[f64]| {
        t.row(vec![name.into(), format!("{:.4}", d[49]), format!("{:.4}", d[199])]);
    };
    let exact = run(None, false);
    row("exact", &exact);
    let k = CodecKind::TopK { k_fraction: 0.05 };
    row("topk 5% (no error feedback)", &run(Some(k), false));
    row("topk 5% + error feedback", &run(Some(k), true));
    row("onebit + error feedback", &run(Some(CodecKind::OneBit), true));
    println!("{}", t.render());
    println!(
        "Lossy codecs converge slower than exact gradients (the trade-off the\n\
         paper highlights); error feedback contains but does not erase it —\n\
         network-level optimization costs none of this."
    );
    Ok(())
}
