//! Quickstart: the paper's question in 30 seconds.
//!
//! Runs the what-if simulator for the three models at 10 and 100 Gbps
//! under both transports and prints the headline comparison: the network
//! *speed* is not the bottleneck — the transport software is.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use netbn::models::timing::backward_trace;
use netbn::models::ModelId;
use netbn::report::Table;
use netbn::sim::{simulate, SimParams};

fn main() -> netbn::Result<()> {
    // 8 servers × 8 GPUs (p3dn.24xlarge)
    let mut table = Table::new(
        "scaling factor: Horovod-like transport vs fully-utilized network (64 GPUs)",
        &["model", "bw Gbps", "measured-mode", "what-if (full util)", "gap"],
    );
    for id in ModelId::paper_models() {
        let trace = backward_trace(&id.profile());
        for bw in [10.0, 100.0] {
            let meas =
                simulate(&SimParams::horovod_like(trace.clone(), 8, 8, bw)).scaling_factor;
            let ideal = simulate(&SimParams::whatif(trace.clone(), 8, 8, bw)).scaling_factor;
            table.row(vec![
                id.name().into(),
                format!("{bw}"),
                format!("{:.1}%", meas * 100.0),
                format!("{:.1}%", ideal * 100.0),
                format!("{:+.1} pts", (ideal - meas) * 100.0),
            ]);
        }
    }
    println!("{}", table.render());
    println!(
        "Takeaway (the paper's): at 100 Gbps the fully-utilized network reaches\n\
         ~100% scaling for every model — the 25–40 point gap is transport\n\
         software, not link speed. At 10 Gbps the two agree: there the wire\n\
         really is the limit, and only there does gradient compression help."
    );
    Ok(())
}
