//! End-to-end driver: **real training through all three layers.**
//!
//! Loads the AOT transformer artifacts (L2 JAX model + L1 Pallas kernels,
//! built once by `make artifacts`), then:
//!
//! 1. trains a single-worker baseline;
//! 2. trains the same model data-parallel over N workers connected by
//!    real TCP sockets, gradients averaged with fusion-bucketed ring
//!    all-reduce (L3);
//! 3. verifies the replicas stayed bit-consistent, logs both loss curves
//!    to `out/e2e_loss.csv`, and reports throughput and step breakdown.
//!
//! ```text
//! cargo run --release --example train_e2e [workers] [steps]
//! ```
//!
//! Defaults: 4 workers × 120 steps (≈ tens of minutes on the 1-core CI
//! box — compute serializes through the PJRT device service; see
//! EXPERIMENTS.md §E2E for the recorded run).

use netbn::config::FusionConfig;
use netbn::net::shaper::Shaper;
use netbn::net::tcp::TcpFabric;
use netbn::runtime::{artifacts_dir, DeviceService};
use netbn::topology::Topology;
use netbn::trainer::xla::{load_init_params, ModelMeta, XlaTrainer};
use netbn::Result;
use std::io::Write;
use std::sync::Arc;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let workers: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let steps: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(120);
    let baseline_steps = steps.min(40);
    let lr = 0.25f32;

    let dir = artifacts_dir();
    let meta = ModelMeta::load(&dir)?;
    let init = load_init_params(&dir, meta.param_count)?;
    println!(
        "e2e transformer: {:.2}M params / {} tensors, vocab {}, seq {}, batch {} per worker",
        meta.param_count as f64 / 1e6,
        meta.layers.len(),
        meta.vocab,
        meta.seq,
        meta.batch
    );
    let svc = DeviceService::start(dir);
    let trainer = XlaTrainer::new(svc.handle(), meta.clone());
    trainer.handle.warm("train_fwd_bwd")?;
    trainer.handle.warm("apply_sgd")?;

    // ---- single-worker baseline ----
    println!("\n[1/2] single-worker baseline ({baseline_steps} steps)...");
    let t0 = std::time::Instant::now();
    let single = trainer.train_single(init.clone(), baseline_steps, meta.batch, lr, 0xbade)?;
    let single_wall = t0.elapsed().as_secs_f64();
    let single_step = single_wall / baseline_steps as f64;
    println!(
        "  loss {:.4} -> {:.4}; {:.2} s/step; {:.2} samples/s",
        single.loss_curve[0],
        single.loss_curve.last().unwrap(),
        single_step,
        meta.batch as f64 / single_step
    );

    // ---- distributed over real TCP ----
    println!("\n[2/2] {workers}-worker data-parallel over TCP ({steps} steps)...");
    // A light NIC model on the fabric: 10 Gbps-class per-server egress so
    // the communication phase is visible but not dominant.
    let topo = Topology::new(workers, 1);
    let shaper = Arc::new(Shaper::new(topo, netbn::gbps_to_bytes_per_sec(10.0), 20e-6));
    let fabric = TcpFabric::new(workers, Some(shaper))?;
    let t0 = std::time::Instant::now();
    let dist = trainer.train_distributed(
        &fabric,
        init,
        steps,
        meta.batch,
        lr,
        0xe2e,
        FusionConfig::default(),
    )?;
    let dist_wall = t0.elapsed().as_secs_f64();
    let dist_step = dist_wall / steps as f64;
    println!(
        "  loss {:.4} -> {:.4}; {:.2} s/step; {:.2} samples/s aggregate",
        dist.loss_curve[0],
        dist.loss_curve.last().unwrap(),
        dist_step,
        (workers * meta.batch) as f64 / dist_step
    );
    println!(
        "  note: this box has 1 CPU core — compute for all {workers} workers\n\
         serializes through the device service, so wall-clock scaling is\n\
         bounded by 1/{workers}; the scaling-factor experiments live in the\n\
         modeled emulator (`netbn emulate`) where compute genuinely overlaps."
    );

    // ---- persist loss curves ----
    std::fs::create_dir_all("out")?;
    let mut f = std::fs::File::create("out/e2e_loss.csv")?;
    writeln!(f, "step,single_loss,distributed_loss")?;
    for i in 0..steps {
        let s = single.loss_curve.get(i).map(|v| v.to_string()).unwrap_or_default();
        let d = dist.loss_curve.get(i).map(|v| v.to_string()).unwrap_or_default();
        writeln!(f, "{i},{s},{d}")?;
    }
    println!("\nloss curves -> out/e2e_loss.csv");

    // ---- verdicts ----
    let single_drop = single.loss_curve[0] - single.loss_curve.last().unwrap();
    let dist_drop = dist.loss_curve[0] - dist.loss_curve.last().unwrap();
    let ok = single_drop > 0.3 && dist_drop > 0.3;
    println!(
        "training verdict: single Δloss={single_drop:.3}, distributed Δloss={dist_drop:.3} -> {}",
        if ok { "LEARNING" } else { "NOT LEARNING" }
    );
    let stats = trainer.handle.stats()?;
    println!(
        "device service: {} exec calls, {:.1} s exec, {} compiles ({:.1} s)",
        stats.calls, stats.exec_seconds, stats.compiles, stats.compile_seconds
    );
    std::process::exit(if ok { 0 } else { 1 });
}
