//! Regenerate every figure of the paper's evaluation (Fig 1–8), write the
//! CSVs to `out/`, and verify the paper-shape checks. Exits non-zero if
//! any shape check fails — usable as a reproduction gate in CI.
//!
//! ```text
//! cargo run --release --example whatif_sweep [out_dir]
//! ```

use std::path::PathBuf;

fn main() {
    let out = std::env::args().nth(1).map(PathBuf::from).unwrap_or_else(|| PathBuf::from("out"));
    let mut all_ok = true;
    let mut total_checks = 0;
    for id in netbn::figures::FIGURE_IDS {
        let run = match netbn::figures::run_figure(id) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("figure {id} failed: {e:#}");
                std::process::exit(2);
            }
        };
        match run.emit(&out) {
            Ok(ok) => {
                all_ok &= ok;
                total_checks += run.checks.len();
            }
            Err(e) => {
                eprintln!("figure {id} emit failed: {e:#}");
                std::process::exit(2);
            }
        }
    }
    println!(
        "\n{} shape checks across 8 figures: {}",
        total_checks,
        if all_ok { "ALL PASS" } else { "FAILURES" }
    );
    std::process::exit(if all_ok { 0 } else { 1 });
}
