//! Regenerate every figure of the paper's evaluation (Fig 1–8) through
//! the scenario engine, write the CSVs to `out/`, and verify the
//! paper-shape checks. Exits non-zero if any shape check fails — usable
//! as a reproduction gate in CI.
//!
//! This is the engine's idiom for "run every figure": enumerate the
//! registry instead of hard-wiring a figure list — a scenario registered
//! tomorrow with mode "figure" is picked up automatically.
//!
//! ```text
//! cargo run --release --example whatif_sweep [out_dir]
//! ```

use netbn::engine::ScenarioRegistry;
use std::path::PathBuf;

fn main() {
    let out = std::env::args().nth(1).map(PathBuf::from).unwrap_or_else(|| PathBuf::from("out"));
    let registry = ScenarioRegistry::builtin();
    let mut all_ok = true;
    let mut total_checks = 0;
    let mut figures = 0;
    for scenario in registry.iter().filter(|s| s.mode() == "figure") {
        let outcome = match scenario.run(&[]) {
            Ok(o) => o,
            Err(e) => {
                eprintln!("scenario {} failed: {e:#}", scenario.name());
                std::process::exit(2);
            }
        };
        match outcome.emit(Some(out.as_path())) {
            Ok(ok) => {
                all_ok &= ok;
                total_checks += outcome.checks.len();
                figures += 1;
            }
            Err(e) => {
                eprintln!("scenario {} emit failed: {e:#}", scenario.name());
                std::process::exit(2);
            }
        }
    }
    println!(
        "\n{} shape checks across {} figure scenarios: {}",
        total_checks,
        figures,
        if all_ok { "ALL PASS" } else { "FAILURES" }
    );
    std::process::exit(if all_ok { 0 } else { 1 });
}
