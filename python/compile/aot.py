"""AOT lowering: jax functions -> HLO *text* artifacts for the rust runtime.

HLO text (NOT ``lowered.compile()`` / serialized protos): jax >= 0.5 emits
64-bit instruction ids that the image's xla_extension 0.5.1 rejects; the
text parser reassigns ids (see /opt/xla-example/README.md).

Artifacts written to ``--out-dir`` (default ``../artifacts``):

* ``train_fwd_bwd.hlo.txt``  — transformer loss+grads (L2+L1 fused)
* ``apply_sgd.hlo.txt``      — SGD parameter update
* ``vecadd_1m.hlo.txt``      — Pallas vector-add over 262144 f32 (1 MiB)
* ``vecavg_1m.hlo.txt``      — fused (a+b)/2 over the same shape
* ``quant_int8_1m.hlo.txt``  — Pallas int8 quantize (scale + i32 codes)
* ``dequant_int8_1m.hlo.txt``— Pallas int8 dequantize
* ``topk_mask_1m.hlo.txt``   — Pallas magnitude-threshold mask
* ``model_meta.txt``         — flat-parameter layout for the rust trainer
* ``init_params.bin``        — initial parameters (little-endian f32)

Run via ``make artifacts``; idempotent and build-time only.
"""

import argparse
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import dequant_int8, mask_by_threshold, quant_int8, vecadd, vecavg

KERNEL_N = 262144  # 1 MiB of f32 per kernel artifact


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, args, path: pathlib.Path) -> int:
    text = to_hlo_text(jax.jit(fn).lower(*args))
    path.write_text(text)
    return len(text)


def build_all(out_dir: pathlib.Path, cfg=None, seed: int = 0) -> dict:
    cfg = cfg or model.TINY
    out_dir.mkdir(parents=True, exist_ok=True)
    written = {}

    flat, _unravel, train_fwd_bwd, apply_sgd, spans = model.make_flat_fns(cfg, seed)
    p = flat.size
    b, s = cfg["batch"], cfg["seq"]

    params_spec = jax.ShapeDtypeStruct((p,), jnp.float32)
    tokens_spec = jax.ShapeDtypeStruct((b, s + 1), jnp.int32)
    lr_spec = jax.ShapeDtypeStruct((), jnp.float32)
    written["train_fwd_bwd"] = lower_to_file(
        train_fwd_bwd, (params_spec, tokens_spec), out_dir / "train_fwd_bwd.hlo.txt"
    )
    written["apply_sgd"] = lower_to_file(
        apply_sgd, (params_spec, params_spec, lr_spec), out_dir / "apply_sgd.hlo.txt"
    )

    vec_spec = jax.ShapeDtypeStruct((KERNEL_N,), jnp.float32)
    scale_spec = jax.ShapeDtypeStruct((1,), jnp.float32)
    int_spec = jax.ShapeDtypeStruct((KERNEL_N,), jnp.int32)
    written["vecadd_1m"] = lower_to_file(
        lambda a, b: (vecadd(a, b),), (vec_spec, vec_spec), out_dir / "vecadd_1m.hlo.txt"
    )
    written["vecavg_1m"] = lower_to_file(
        lambda a, b: (vecavg(a, b),), (vec_spec, vec_spec), out_dir / "vecavg_1m.hlo.txt"
    )
    written["quant_int8_1m"] = lower_to_file(
        quant_int8, (vec_spec,), out_dir / "quant_int8_1m.hlo.txt"
    )
    written["dequant_int8_1m"] = lower_to_file(
        lambda s_, q: (dequant_int8(s_, q),),
        (scale_spec, int_spec),
        out_dir / "dequant_int8_1m.hlo.txt",
    )
    written["topk_mask_1m"] = lower_to_file(
        lambda x, t: (mask_by_threshold(x, t),),
        (vec_spec, scale_spec),
        out_dir / "topk_mask_1m.hlo.txt",
    )

    # Metadata + initial parameters for the rust trainer.
    meta_lines = [
        f"param_count {p}",
        f"vocab {cfg['vocab']}",
        f"seq {cfg['seq']}",
        f"batch {cfg['batch']}",
    ]
    meta_lines += [f"layer {name} {off} {n}" for name, off, n in spans]
    (out_dir / "model_meta.txt").write_text("\n".join(meta_lines) + "\n")
    np.asarray(flat, dtype="<f4").tofile(out_dir / "init_params.bin")
    written["model_meta"] = p
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    written = build_all(out_dir, seed=args.seed)
    for name, size in sorted(written.items()):
        print(f"  {name}: {size}")
    print(f"artifacts -> {out_dir.resolve()}")


if __name__ == "__main__":
    main()
