"""L1 Pallas kernels (build-time only; lowered into HLO artifacts)."""

from .matmul import matmul
from .quantize import dequant_int8, mask_by_threshold, quant_int8, topk_mask
from .vecadd import vecadd, vecavg

__all__ = [
    "matmul",
    "vecadd",
    "vecavg",
    "quant_int8",
    "dequant_int8",
    "topk_mask",
    "mask_by_threshold",
]
