"""L1 Pallas kernel: tiled matmul with a custom VJP.

The transformer's projections (QKV, output, MLP, LM head) all route
through this kernel, so it lowers into the train-step artifact for both
the forward and backward passes (backward is two more matmuls).

TPU mapping (DESIGN.md §Hardware-Adaptation): blocks are MXU-oriented —
(bm, K) x (K, bn) tiles with bm = bn = 64 for the tiny e2e model (the
paper-scale config would use 128x128 bf16 tiles). The K dimension stays
resident in VMEM because every K in the model is small (<= 1024);
paper-scale shapes would add a K-loop accumulator.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 64
BN = 64


def _mm_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)


def _pallas_mm(a, b):
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"matmul inner dims {k} vs {k2}"
    bm = BM if m % BM == 0 else _divisor(m, BM)
    bn = BN if n % BN == 0 else _divisor(n, BN)
    return pl.pallas_call(
        _mm_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,
    )(a, b)


def _divisor(n: int, cap: int) -> int:
    b = min(n, cap)
    while n % b:
        b -= 1
    return b


@jax.custom_vjp
def matmul(a, b):
    """a @ b through the Pallas kernel, differentiable."""
    return _pallas_mm(a, b)


def _fwd(a, b):
    return _pallas_mm(a, b), (a, b)


def _bwd(res, g):
    a, b = res
    # dA = g @ B^T, dB = A^T @ g — the backward matmuls also hit the MXU
    # kernel, mirroring how cuDNN/cuBLAS serve both passes on GPU.
    return _pallas_mm(g, b.T), _pallas_mm(a.T, g)


matmul.defvjp(_fwd, _bwd)
