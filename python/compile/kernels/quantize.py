"""L1 Pallas kernels: int8 linear quantization (encode/decode) and the
magnitude-threshold mask used by top-k sparsification.

These are the gradient-compression hot spots of §3.2.  The scale (a global
max-reduction) is computed by XLA outside the kernel; the element-wise
quantize/dequantize/mask streams through VMEM-sized blocks like vecadd.
The rust trainer runs the same codecs natively; the AOT artifacts built
from these kernels let the runtime cross-check both implementations.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 65536


def _block(n: int) -> int:
    b = min(n, BLOCK)
    while n % b:
        b -= 1
    return b


def _quant_kernel(x_ref, scale_ref, o_ref):
    inv = 1.0 / scale_ref[0]
    q = jnp.clip(jnp.round(x_ref[...] * inv), -127.0, 127.0)
    o_ref[...] = q.astype(jnp.int32)


def _dequant_kernel(q_ref, scale_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * scale_ref[0]


def _mask_kernel(x_ref, thr_ref, o_ref):
    x = x_ref[...]
    o_ref[...] = jnp.where(jnp.abs(x) >= thr_ref[0], x, 0.0)


def quant_int8(x):
    """x f32[n] -> (scale f32[1], q i32[n]) with scale = max|x|/127."""
    n = x.shape[0]
    blk = _block(n)
    scale = (jnp.max(jnp.abs(x)) / 127.0 + 1e-30).reshape(1)
    q = pl.pallas_call(
        _quant_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        interpret=True,
    )(x, scale)
    return scale, q


def dequant_int8(scale, q):
    """(scale f32[1], q i32[n]) -> f32[n]."""
    n = q.shape[0]
    blk = _block(n)
    return pl.pallas_call(
        _dequant_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        interpret=True,
    )(q, scale)


def topk_mask(x, k_fraction: float):
    """Zero all but (approximately) the top k_fraction of |x|.

    The threshold is the (1-k)-quantile of |x| computed by XLA; the mask
    itself is the Pallas kernel.
    """
    thr = jnp.quantile(jnp.abs(x), 1.0 - k_fraction).reshape(1)
    return mask_by_threshold(x, thr)


def mask_by_threshold(x, thr):
    """x f32[n], thr f32[1] -> x masked where |x| < thr."""
    n = x.shape[0]
    blk = _block(n)
    return pl.pallas_call(
        _mask_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        interpret=True,
    )(x, thr)
