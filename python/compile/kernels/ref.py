"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
pytest compares against — no pallas imports here on purpose)."""

import jax.numpy as jnp


def ref_vecadd(a, b):
    return a + b


def ref_vecavg(a, b):
    return (a + b) * a.dtype.type(0.5)


def ref_matmul(a, b):
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def ref_quant_int8(x):
    scale = (jnp.max(jnp.abs(x)) / 127.0 + 1e-30).reshape(1)
    q = jnp.clip(jnp.round(x / scale[0]), -127.0, 127.0).astype(jnp.int32)
    return scale, q


def ref_dequant_int8(scale, q):
    return q.astype(jnp.float32) * scale[0]


def ref_mask_by_threshold(x, thr):
    return jnp.where(jnp.abs(x) >= thr[0], x, 0.0)


def ref_topk_mask(x, k_fraction: float):
    thr = jnp.quantile(jnp.abs(x), 1.0 - k_fraction).reshape(1)
    return ref_mask_by_threshold(x, thr)
