"""L1 Pallas kernel: fused gradient vector add / average.

This is the all-reduce reduction hot spot the paper models as
``(N-1) * AddEst(S/N)``.  On TPU the op is HBM-bound, so the kernel's job
is purely a well-shaped HBM<->VMEM schedule: 1-D grid over contiguous
blocks sized to stream through VMEM (see DESIGN.md §Hardware-Adaptation).

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers to plain HLO so the same artifact runs
through the rust runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block size in elements. 64K f32 = 256 KB per operand -> 3 operands fit
# comfortably in a 16 MB VMEM with room for double buffering.
BLOCK = 65536


def _add_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


def _scale_add_kernel(a_ref, b_ref, o_ref, *, scale):
    o_ref[...] = (a_ref[...] + b_ref[...]) * scale


def _block(n: int) -> int:
    """Largest block that divides n, capped at BLOCK."""
    b = min(n, BLOCK)
    while n % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=())
def vecadd(a, b):
    """Element-wise a + b via the Pallas kernel (1-D inputs)."""
    n = a.shape[0]
    blk = _block(n)
    return pl.pallas_call(
        _add_kernel,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        interpret=True,
    )(a, b)


def vecavg(a, b):
    """(a + b) / 2 fused in one pass (the 2-worker gradient average)."""
    n = a.shape[0]
    blk = _block(n)
    kernel = functools.partial(_scale_add_kernel, scale=a.dtype.type(0.5))
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(a.shape, a.dtype),
        grid=(n // blk,),
        in_specs=[
            pl.BlockSpec((blk,), lambda i: (i,)),
            pl.BlockSpec((blk,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        interpret=True,
    )(a, b)
