"""L2: the transformer LM train step in JAX, calling the L1 Pallas kernels.

Everything here runs ONCE at build time (`make artifacts`): the functions
are lowered to HLO text by `aot.py` and executed from rust afterwards.

The model is a small decoder-only transformer (pre-LN, tied embeddings)
whose dimensions mirror `rust/src/models/transformer.rs::tiny_transformer_dims`
— keep TINY in sync with that function.

Artifact contract (consumed by `rust/src/trainer/xla.rs`):

* ``train_fwd_bwd(params_flat f32[P], tokens i32[B, S+1]) -> (loss f32[],
  grads_flat f32[P])``
* ``apply_sgd(params f32[P], grads f32[P], lr f32[]) -> (params f32[P],)``
"""

from typing import Dict

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .kernels import matmul

# Must mirror rust tiny_transformer_dims(): (vocab, d_model, n_layers,
# n_heads, seq).
TINY = dict(vocab=512, d_model=256, n_layers=4, n_heads=8, seq=64, batch=4)


def init_params(key, cfg: Dict) -> Dict:
    """Initialize the parameter pytree (dict keys define the flat order)."""
    d = cfg["d_model"]
    vocab = cfg["vocab"]
    seq = cfg["seq"]
    n_layers = cfg["n_layers"]
    keys = jax.random.split(key, 2 + 4 * n_layers)
    params = {
        "embed": jax.random.normal(keys[0], (vocab, d), jnp.float32) * 0.02,
        "pos": jax.random.normal(keys[1], (seq, d), jnp.float32) * 0.01,
        "final_ln_scale": jnp.ones((d,), jnp.float32),
        "final_ln_bias": jnp.zeros((d,), jnp.float32),
    }
    for layer in range(n_layers):
        k = keys[2 + 4 * layer : 6 + 4 * layer]
        prefix = f"layer{layer:02d}"
        params[f"{prefix}.qkv"] = jax.random.normal(k[0], (d, 3 * d), jnp.float32) * (
            1.0 / jnp.sqrt(d)
        )
        params[f"{prefix}.proj"] = jax.random.normal(k[1], (d, d), jnp.float32) * (
            1.0 / jnp.sqrt(d)
        )
        params[f"{prefix}.mlp_up"] = jax.random.normal(k[2], (d, 4 * d), jnp.float32) * (
            1.0 / jnp.sqrt(d)
        )
        params[f"{prefix}.mlp_down"] = jax.random.normal(
            k[3], (4 * d, d), jnp.float32
        ) * (1.0 / jnp.sqrt(4 * d))
        params[f"{prefix}.ln1_scale"] = jnp.ones((d,), jnp.float32)
        params[f"{prefix}.ln1_bias"] = jnp.zeros((d,), jnp.float32)
        params[f"{prefix}.ln2_scale"] = jnp.ones((d,), jnp.float32)
        params[f"{prefix}.ln2_bias"] = jnp.zeros((d,), jnp.float32)
    return params


def _layer_norm(x, scale, bias, eps=1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * scale + bias


def _block(params, prefix: str, x, cfg: Dict):
    """One pre-LN decoder block; projections via the Pallas matmul."""
    n_heads = cfg["n_heads"]
    seq = cfg["seq"]
    bs, d = x.shape  # x is [B*S, d]
    h = _layer_norm(x, params[f"{prefix}.ln1_scale"], params[f"{prefix}.ln1_bias"])
    qkv = matmul(h, params[f"{prefix}.qkv"])  # [B*S, 3d]
    b = bs // seq
    d_head = d // n_heads
    qkv = qkv.reshape(b, seq, 3, n_heads, d_head)
    q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]  # [b, s, h, dh]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(float(d_head))
    causal = jnp.tril(jnp.ones((seq, seq), jnp.float32))
    scores = jnp.where(causal[None, None, :, :] > 0, scores, -1e30)
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", attn, v).reshape(bs, d)
    x = x + matmul(ctx, params[f"{prefix}.proj"])
    h = _layer_norm(x, params[f"{prefix}.ln2_scale"], params[f"{prefix}.ln2_bias"])
    up = jax.nn.gelu(matmul(h, params[f"{prefix}.mlp_up"]))
    return x + matmul(up, params[f"{prefix}.mlp_down"])


def forward(params: Dict, tokens_in, cfg: Dict):
    """tokens_in i32[B, S] -> logits f32[B*S, vocab]."""
    b, s = tokens_in.shape
    x = params["embed"][tokens_in] + params["pos"][None, :s, :]
    x = x.reshape(b * s, cfg["d_model"])
    for layer in range(cfg["n_layers"]):
        x = _block(params, f"layer{layer:02d}", x, cfg)
    x = _layer_norm(x, params["final_ln_scale"], params["final_ln_bias"])
    # Tied LM head: the big [B*S, d] @ [d, vocab] matmul on the MXU kernel.
    return matmul(x, params["embed"].T)


def loss_fn(params: Dict, tokens, cfg: Dict):
    """tokens i32[B, S+1] -> mean cross-entropy of next-token prediction."""
    inputs = tokens[:, :-1]
    targets = tokens[:, 1:].reshape(-1)
    logits = forward(params, inputs, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[:, None], axis=-1)
    return jnp.mean(nll)


def make_flat_fns(cfg: Dict, seed: int = 0):
    """Build the flat-parameter functions the artifacts are lowered from.

    Returns ``(init_flat, unravel, train_fwd_bwd, apply_sgd, spans)`` where
    ``spans`` is ``[(name, offset, elems)]`` describing the flat layout.
    """
    params = init_params(jax.random.PRNGKey(seed), cfg)
    flat, unravel = ravel_pytree(params)

    # Span table: ravel_pytree flattens in tree_flatten order (sorted keys).
    leaves_with_path = jax.tree_util.tree_flatten_with_path(params)[0]
    spans = []
    offset = 0
    for path, leaf in leaves_with_path:
        name = path[0].key if hasattr(path[0], "key") else str(path[0])
        spans.append((name, offset, int(leaf.size)))
        offset += int(leaf.size)
    assert offset == flat.size

    def train_fwd_bwd(params_flat, tokens):
        loss, grads = jax.value_and_grad(lambda p: loss_fn(unravel(p), tokens, cfg))(
            params_flat
        )
        return loss, grads

    def apply_sgd(params_flat, grads_flat, lr):
        return (params_flat - lr * grads_flat,)

    return flat, unravel, train_fwd_bwd, apply_sgd, spans
