"""Make the build-time `compile` package importable whether pytest runs
from the repo root (`pytest python/tests/`) or from `python/`."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1]))
