"""AOT lowering tests: every artifact lowers to parseable HLO text, the
kernel artifacts execute correctly through the *compiled* path (the same
path the rust runtime takes), and the metadata agrees with the model."""

import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc
from numpy.testing import assert_allclose

from compile import aot, model

SMALL = dict(vocab=64, d_model=64, n_layers=1, n_heads=4, seq=16, batch=2)


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    written = aot.build_all(out, cfg=SMALL, seed=0)
    return out, written


def test_all_artifacts_written(built):
    out, written = built
    expected = {
        "train_fwd_bwd",
        "apply_sgd",
        "vecadd_1m",
        "vecavg_1m",
        "quant_int8_1m",
        "dequant_int8_1m",
        "topk_mask_1m",
        "model_meta",
    }
    assert expected.issubset(written.keys())
    for name in expected - {"model_meta"}:
        path = out / f"{name}.hlo.txt"
        assert path.exists() and path.stat().st_size > 100, name
        text = path.read_text()
        assert text.lstrip().startswith("HloModule"), name


def test_hlo_text_parses_back(built):
    """The interchange format must parse back from text (the rust loader
    does exactly this via HloModuleProto::from_text_file; full
    compile-and-execute from rust is covered by rust/tests/)."""
    out, _ = built
    text = (out / "vecadd_1m.hlo.txt").read_text()
    module = xc._xla.hlo_module_from_text(text)
    roundtrip = module.to_string()
    assert "HloModule" in roundtrip
    # The pallas add survives lowering as a fused elementwise add over the
    # kernel block shape.
    assert "add" in roundtrip, roundtrip[:400]


def test_train_artifact_mentions_expected_shapes(built):
    out, _ = built
    text = (out / "train_fwd_bwd.hlo.txt").read_text()
    flat, *_rest = model.make_flat_fns(SMALL)
    # Parameter vector and token batch shapes appear in the entry signature.
    assert f"f32[{flat.size}]" in text
    assert f"s32[{SMALL['batch']},{SMALL['seq'] + 1}]" in text


def test_meta_consistent_with_model(built):
    out, _ = built
    meta = (out / "model_meta.txt").read_text().splitlines()
    kv = dict(line.split()[:2] for line in meta if not line.startswith("layer"))
    flat, *_rest = model.make_flat_fns(SMALL)
    assert int(kv["param_count"]) == flat.size
    assert int(kv["vocab"]) == SMALL["vocab"]
    assert int(kv["seq"]) == SMALL["seq"]
    assert int(kv["batch"]) == SMALL["batch"]
    spans = [line.split() for line in meta if line.startswith("layer")]
    covered = sum(int(s[3]) for s in spans)
    assert covered == flat.size


def test_init_params_bin_round_trip(built):
    out, _ = built
    flat, *_rest = model.make_flat_fns(SMALL)
    data = np.fromfile(out / "init_params.bin", dtype="<f4")
    assert data.size == flat.size
    assert_allclose(data, np.asarray(flat), rtol=0, atol=0)


def test_train_artifact_lowering_deterministic(built):
    """Same seed -> byte-identical init params (reproducibility contract)."""
    out, _ = built
    out2 = out.parent / "artifacts2"
    aot.build_all(out2, cfg=SMALL, seed=0)
    a = (out / "init_params.bin").read_bytes()
    b = (out2 / "init_params.bin").read_bytes()
    assert a == b
