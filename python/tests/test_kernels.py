"""Pallas kernels vs pure-jnp oracles, with hypothesis shape/value sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile.kernels import (
    dequant_int8,
    mask_by_threshold,
    matmul,
    quant_int8,
    topk_mask,
    vecadd,
    vecavg,
)
from compile.kernels import ref

SETTINGS = dict(max_examples=20, deadline=None)


def rand(key, shape, dtype=jnp.float32, scale=3.0):
    return (jax.random.normal(jax.random.PRNGKey(key), shape) * scale).astype(dtype)


# ----------------------------------------------------------------- vecadd


@settings(**SETTINGS)
@given(n=st.integers(min_value=1, max_value=70000), seed=st.integers(0, 2**16))
def test_vecadd_matches_ref_any_length(n, seed):
    a = rand(seed, (n,))
    b = rand(seed + 1, (n,))
    assert_allclose(np.asarray(vecadd(a, b)), np.asarray(ref.ref_vecadd(a, b)), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_vecadd_dtypes(dtype):
    a = rand(0, (4096,), dtype)
    b = rand(1, (4096,), dtype)
    got = vecadd(a, b)
    assert got.dtype == dtype
    assert_allclose(
        np.asarray(got, dtype=np.float32),
        np.asarray(ref.ref_vecadd(a, b), dtype=np.float32),
        rtol=1e-2,
    )


def test_vecadd_block_boundary_sizes():
    from compile.kernels.vecadd import BLOCK

    for n in [BLOCK - 1, BLOCK, BLOCK + 1, 2 * BLOCK, 3]:
        a = rand(2, (n,))
        b = rand(3, (n,))
        assert_allclose(np.asarray(vecadd(a, b)), np.asarray(a + b), rtol=1e-6)


@settings(**SETTINGS)
@given(n=st.integers(min_value=2, max_value=8192), seed=st.integers(0, 2**16))
def test_vecavg_matches_ref(n, seed):
    a = rand(seed, (n,))
    b = rand(seed + 9, (n,))
    assert_allclose(np.asarray(vecavg(a, b)), np.asarray((a + b) * 0.5), rtol=1e-6)


# ----------------------------------------------------------------- matmul


@settings(**SETTINGS)
@given(
    m=st.sampled_from([1, 7, 64, 128, 192]),
    k=st.sampled_from([1, 32, 256]),
    n=st.sampled_from([1, 64, 512]),
    seed=st.integers(0, 2**16),
)
def test_matmul_matches_ref(m, k, n, seed):
    a = rand(seed, (m, k), scale=1.0)
    b = rand(seed + 1, (k, n), scale=1.0)
    assert_allclose(
        np.asarray(matmul(a, b)), np.asarray(ref.ref_matmul(a, b)), rtol=2e-5, atol=2e-5
    )


def test_matmul_gradients_match_jnp():
    a = rand(5, (64, 32), scale=1.0)
    b = rand(6, (32, 64), scale=1.0)

    def f_pallas(a, b):
        return jnp.sum(matmul(a, b) ** 2)

    def f_ref(a, b):
        return jnp.sum(ref.ref_matmul(a, b) ** 2)

    ga_p, gb_p = jax.grad(f_pallas, argnums=(0, 1))(a, b)
    ga_r, gb_r = jax.grad(f_ref, argnums=(0, 1))(a, b)
    assert_allclose(np.asarray(ga_p), np.asarray(ga_r), rtol=1e-4, atol=1e-4)
    assert_allclose(np.asarray(gb_p), np.asarray(gb_r), rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------- quantize


@settings(**SETTINGS)
@given(n=st.integers(min_value=1, max_value=50000), seed=st.integers(0, 2**16))
def test_quant_dequant_round_trip_error_bounded(n, seed):
    x = rand(seed, (n,), scale=10.0)
    scale, q = quant_int8(x)
    back = dequant_int8(scale, q)
    # |err| <= scale/2 per element (linear quantization bound).
    bound = float(scale[0]) * 0.5 + 1e-6
    assert np.max(np.abs(np.asarray(back) - np.asarray(x))) <= bound


def test_quant_matches_ref_exactly():
    x = rand(7, (8192,), scale=5.0)
    s_p, q_p = quant_int8(x)
    s_r, q_r = ref.ref_quant_int8(x)
    assert_allclose(np.asarray(s_p), np.asarray(s_r), rtol=1e-7)
    assert np.array_equal(np.asarray(q_p), np.asarray(q_r))
    assert_allclose(
        np.asarray(dequant_int8(s_p, q_p)),
        np.asarray(ref.ref_dequant_int8(s_r, q_r)),
        rtol=1e-7,
    )


def test_quant_codes_in_range():
    x = rand(8, (4096,), scale=100.0)
    _, q = quant_int8(x)
    qn = np.asarray(q)
    assert qn.min() >= -127 and qn.max() <= 127


def test_quant_zero_vector():
    x = jnp.zeros((1024,), jnp.float32)
    scale, q = quant_int8(x)
    assert np.all(np.asarray(q) == 0)
    assert np.all(np.isfinite(np.asarray(scale)))


# ----------------------------------------------------------------- topk


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**16), k=st.sampled_from([0.01, 0.1, 0.5]))
def test_topk_mask_matches_ref(seed, k):
    x = rand(seed, (10000,))
    got = topk_mask(x, k)
    want = ref.ref_topk_mask(x, k)
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_topk_keeps_about_k_fraction():
    x = rand(11, (100000,))
    got = np.asarray(topk_mask(x, 0.1))
    frac = np.count_nonzero(got) / got.size
    assert 0.05 < frac < 0.15, frac


def test_mask_threshold_semantics():
    x = jnp.array([-3.0, -1.0, 0.5, 2.0], jnp.float32)
    thr = jnp.array([1.5], jnp.float32)
    got = np.asarray(mask_by_threshold(x, thr))
    assert np.array_equal(got, np.array([-3.0, 0.0, 0.0, 2.0], np.float32))
