"""L2 model tests: shapes, differentiability, span table, optimizer step,
and the core sanity check that training reduces the loss."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

SMALL = dict(vocab=64, d_model=64, n_layers=2, n_heads=4, seq=16, batch=2)


def make_tokens(key, cfg, batch=None):
    b = batch or cfg["batch"]
    return jax.random.randint(jax.random.PRNGKey(key), (b, cfg["seq"] + 1), 0, cfg["vocab"])


def test_forward_shapes():
    params = model.init_params(jax.random.PRNGKey(0), SMALL)
    tokens = make_tokens(1, SMALL)
    logits = model.forward(params, tokens[:, :-1], SMALL)
    assert logits.shape == (SMALL["batch"] * SMALL["seq"], SMALL["vocab"])


def test_initial_loss_near_uniform():
    params = model.init_params(jax.random.PRNGKey(0), SMALL)
    tokens = make_tokens(2, SMALL)
    loss = float(model.loss_fn(params, tokens, SMALL))
    assert abs(loss - np.log(SMALL["vocab"])) < 0.8, loss


def test_grads_finite_and_full_coverage():
    flat, _unravel, train_fwd_bwd, _apply, spans = model.make_flat_fns(SMALL)
    tokens = make_tokens(3, SMALL)
    loss, grads = train_fwd_bwd(flat, tokens)
    assert np.isfinite(float(loss))
    g = np.asarray(grads)
    assert g.shape == (flat.size,)
    assert np.all(np.isfinite(g))
    # Most parameters receive gradient signal.
    nz = np.count_nonzero(g) / g.size
    assert nz > 0.5, nz


def test_span_table_covers_flat_vector():
    flat, _u, _t, _a, spans = model.make_flat_fns(SMALL)
    total = sum(n for _, _, n in spans)
    assert total == flat.size
    # Spans are contiguous and ordered.
    offset = 0
    for name, off, n in spans:
        assert off == offset, name
        offset += n
    names = [s[0] for s in spans]
    assert "embed" in names and "pos" in names


def test_apply_sgd_is_descent_step():
    flat, _u, train_fwd_bwd, apply_sgd, _s = model.make_flat_fns(SMALL)
    tokens = make_tokens(4, SMALL)
    _, grads = train_fwd_bwd(flat, tokens)
    (updated,) = apply_sgd(flat, grads, jnp.float32(0.1))
    assert np.allclose(np.asarray(updated), np.asarray(flat) - 0.1 * np.asarray(grads))


def test_loss_decreases_over_training():
    """The headline sanity check: a few SGD steps reduce loss on data with
    learnable structure (same generator family as the rust DataGen)."""
    cfg = SMALL
    flat, _u, train_fwd_bwd, apply_sgd, _s = model.make_flat_fns(cfg)
    step_fn = jax.jit(train_fwd_bwd)
    apply_fn = jax.jit(apply_sgd)

    def gen_batch(key):
        # tok[t+1] = (3*tok[t] + 7) % vocab, deterministic (fully learnable).
        start = jax.random.randint(key, (cfg["batch"], 1), 0, cfg["vocab"])
        toks = [start]
        for _ in range(cfg["seq"]):
            toks.append((toks[-1] * 3 + 7) % cfg["vocab"])
        return jnp.concatenate(toks, axis=1)

    params = flat
    losses = []
    for i in range(70):
        tokens = gen_batch(jax.random.PRNGKey(i))
        loss, grads = step_fn(params, tokens)
        (params,) = apply_fn(params, grads, jnp.float32(0.3))
        losses.append(float(loss))
    # lr=0.3 drives the deterministic sequence below half the initial loss
    # within 70 steps (empirically ~0.3–0.5 by step 70).
    assert losses[-1] < losses[0] * 0.5, losses[:3] + losses[-3:]


def test_tiny_matches_rust_dims():
    # rust/src/models/transformer.rs::tiny_transformer_dims()
    assert (
        model.TINY["vocab"],
        model.TINY["d_model"],
        model.TINY["n_layers"],
        model.TINY["n_heads"],
        model.TINY["seq"],
    ) == (512, 256, 4, 8, 64)


def test_param_count_matches_rust_formula():
    """rust tiny_transformer_params() must agree with the real pytree."""
    flat, *_ = model.make_flat_fns(model.TINY)
    vocab, d, n_layers, seq = 512, 256, 4, 64
    block = 4 * d * d + 4 * d + 8 * d * d + 5 * d + 4 * d
    expected = vocab * d + seq * d + n_layers * block + 2 * d
    # The python model has no linear biases; block formula counts them.
    # Recompute exactly: qkv d*3d, proj d*d, mlp d*4d + 4d*d, 4 ln vectors.
    block_actual = d * 3 * d + d * d + d * 4 * d + 4 * d * d + 4 * d
    expected_actual = vocab * d + seq * d + n_layers * block_actual + 2 * d
    assert flat.size == expected_actual, (flat.size, expected_actual, expected)
