//! Collective benches: ring vs tree vs parameter-server over the in-proc
//! fabric, plus the raw reduction kernel. Backs the §Perf targets for the
//! L3 hot path (reduction within 2× of memcpy roofline; ring beats PS at
//! scale, as the paper's ring cost model presumes).

use netbn::collectives::reduce::add_assign;
use netbn::collectives::{ps::ps_allreduce, ring::ring_allreduce, tree::tree_allreduce};
use netbn::net::{inproc::InProcFabric, Endpoint, Fabric};
use netbn::topology::{Ring, Topology};
use netbn::util::bench::{black_box, Bench, BenchConfig};
use std::time::Duration;

type Collective = fn(&dyn Endpoint, &Ring, u32, u32, &mut [f32]) -> netbn::Result<()>;

fn run_collective(n: usize, elems: usize, step: u32, f: Collective) {
    let topo = Topology::new(n, 1);
    let ring = topo.flat_ring();
    let fabric = InProcFabric::new(n);
    let eps = fabric.endpoints();
    let mut handles = Vec::new();
    for ep in eps {
        let ring = ring.clone();
        handles.push(std::thread::spawn(move || {
            let mut data = vec![1.0f32; elems];
            f(ep.as_ref(), &ring, step, 0, &mut data).unwrap();
            black_box(&data);
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
}

fn main() {
    let cfg = BenchConfig {
        warmup_iters: 2,
        min_iters: 5,
        max_iters: 200,
        min_time: Duration::from_millis(300),
        max_time: Duration::from_secs(4),
    };

    // Raw reduction kernel (the AddEst subject).
    let mut b = Bench::with_config("reduce", cfg);
    for elems in [1usize << 14, 1 << 18, 1 << 22] {
        let mut dst = vec![1.0f32; elems];
        let src = vec![2.0f32; elems];
        b.bench_bytes(
            &format!("add_assign/{}KiB", elems * 4 / 1024),
            Some((elems * 12) as f64),
            || {
                add_assign(&mut dst, &src);
                black_box(&dst);
            },
        );
    }
    // memcpy roofline reference for the same footprint.
    {
        let elems = 1usize << 22;
        let src = vec![2.0f32; elems];
        let mut dst = vec![0.0f32; elems];
        b.bench_bytes(
            &format!("memcpy/{}KiB", elems * 4 / 1024),
            Some((elems * 8) as f64),
            || {
                dst.copy_from_slice(&src);
                black_box(&dst);
            },
        );
    }
    // §Perf before/after: the old allocating serialization vs the
    // zero-copy view the collectives now use on the send path.
    {
        let elems = 1usize << 20;
        let data = vec![1.5f32; elems];
        b.bench_bytes("serialize/alloc-per-call (before)", Some((elems * 4) as f64), || {
            black_box(netbn::collectives::f32s_to_bytes(&data));
        });
        b.bench_bytes("serialize/zero-copy view (after)", Some((elems * 4) as f64), || {
            black_box(netbn::collectives::f32s_as_bytes(&data));
        });
    }
    b.report();

    // Collectives at 4 MB across 4 workers.
    let mut step = 0u32;
    let mut b = Bench::with_config("allreduce-4w-4MB", cfg);
    let elems = 1 << 20;
    let bytes = Some((elems * 4) as f64);
    b.bench_bytes("ring", bytes, || {
        run_collective(4, elems, step, ring_allreduce);
        step += 1;
    });
    b.bench_bytes("tree", bytes, || {
        run_collective(4, elems, step, tree_allreduce);
        step += 1;
    });
    b.bench_bytes("parameter-server", bytes, || {
        run_collective(4, elems, step, ps_allreduce);
        step += 1;
    });
    b.report();

    // Ring scaling in worker count (fixed 1 MB).
    let mut b = Bench::with_config("ring-scaling-1MB", cfg);
    for n in [2usize, 4, 8] {
        b.bench(&format!("{n}w"), || {
            run_collective(n, 1 << 18, step, ring_allreduce);
            step += 1;
        });
    }
    b.report();
}
