//! Codec benches: encode/decode throughput per codec. These bound how
//! much compression can help in practice — a codec slower than the wire
//! saves nothing (the systems caveat behind the paper's §3.2).

use netbn::compress::{codecs, CodecKind};
use netbn::util::bench::{black_box, Bench, BenchConfig};
use netbn::util::Rng;
use std::time::Duration;

fn main() {
    let cfg = BenchConfig {
        warmup_iters: 2,
        min_iters: 5,
        max_iters: 500,
        min_time: Duration::from_millis(300),
        max_time: Duration::from_secs(3),
    };
    let n = 1 << 20; // 4 MB of gradients
    let mut rng = Rng::new(1);
    let mut grad = vec![0.0f32; n];
    rng.fill_f32(&mut grad, 0.5);
    let bytes = Some((n * 4) as f64);

    let kinds = [
        CodecKind::Fp16,
        CodecKind::Int8,
        CodecKind::OneBit,
        CodecKind::TopK { k_fraction: 0.01 },
        CodecKind::RandomK { k_fraction: 0.01 },
    ];

    let mut b = Bench::with_config("encode-4MB", cfg);
    for kind in kinds {
        b.bench_bytes(&kind.name(), bytes, || {
            black_box(codecs::encode(kind, &grad, 3));
        });
    }
    b.report();

    let mut b = Bench::with_config("decode-4MB", cfg);
    for kind in kinds {
        let enc = codecs::encode(kind, &grad, 3);
        b.bench_bytes(&kind.name(), bytes, || {
            black_box(codecs::decode(kind, &enc, 3).unwrap());
        });
    }
    b.report();

    // Wire-time budget comparison at 10 Gbps: encoding must beat the
    // bytes it saves.
    println!("\nwire-time context: 4 MB at 10 Gbps = {:.2} ms on the wire", 4e6 / 1.25e9 * 1e3);
}
