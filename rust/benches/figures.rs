//! Figure regeneration benches: one bench per paper figure. Each bench
//! re-generates the figure's full data series (so `cargo bench` both
//! times the harness and reprints the reproduction numbers), then the
//! series themselves are printed once at the end.

use netbn::util::bench::{Bench, BenchConfig};
use std::time::Duration;

fn main() {
    let cfg = BenchConfig {
        warmup_iters: 1,
        min_iters: 3,
        max_iters: 50,
        min_time: Duration::from_millis(200),
        max_time: Duration::from_secs(3),
    };
    let mut b = Bench::with_config("figures", cfg);
    for id in netbn::figures::FIGURE_IDS {
        b.bench(&format!("fig{id}/regenerate"), || {
            let run = netbn::figures::run_figure(id).expect("figure runs");
            std::hint::black_box(&run.figures);
        });
    }
    b.report();

    // Print the actual reproduction series once (the paper's rows).
    println!("\n==== regenerated figure data ====");
    for id in netbn::figures::FIGURE_IDS {
        let run = netbn::figures::run_figure(id).unwrap();
        for f in &run.figures {
            println!("{}", f.render());
        }
        let (text, ok) = netbn::report::render_checks(&run.checks);
        println!("{text}  => fig{id} shape {}", if ok { "OK" } else { "MISMATCH" });
    }
}
