//! Runtime benches: PJRT artifact execution latency/throughput — the L1
//! kernel artifacts and the full L2 train step through the same device
//! service the e2e trainer uses. Skips cleanly if `make artifacts` has
//! not run.

use netbn::runtime::{artifacts_dir, DeviceService, HostTensor};
use netbn::util::bench::{black_box, Bench, BenchConfig};
use netbn::util::Rng;
use std::time::Duration;

fn main() {
    let dir = artifacts_dir();
    if !dir.join("vecadd_1m.hlo.txt").exists() {
        println!("runtime bench: artifacts missing at {dir:?}; run `make artifacts` first — skipping");
        return;
    }
    let svc = DeviceService::start(dir.clone());
    let h = svc.handle();
    let cfg = BenchConfig {
        warmup_iters: 2,
        min_iters: 5,
        max_iters: 200,
        min_time: Duration::from_millis(300),
        max_time: Duration::from_secs(5),
    };

    const N: usize = 262144;
    let mut rng = Rng::new(2);
    let mut a = vec![0.0f32; N];
    let mut bb = vec![0.0f32; N];
    rng.fill_f32(&mut a, 1.0);
    rng.fill_f32(&mut bb, 1.0);

    let mut b = Bench::with_config("kernel-artifacts", cfg);
    b.bench_bytes("vecadd_1m", Some((N * 12) as f64), || {
        let out = h
            .exec(
                "vecadd_1m",
                vec![
                    HostTensor::f32(&[N as i64], a.clone()),
                    HostTensor::f32(&[N as i64], bb.clone()),
                ],
            )
            .unwrap();
        black_box(out);
    });
    b.bench_bytes("quant_int8_1m", Some((N * 4) as f64), || {
        let out = h.exec("quant_int8_1m", vec![HostTensor::f32(&[N as i64], a.clone())]).unwrap();
        black_box(out);
    });
    b.report();

    // Full train step (the e2e compute phase).
    match netbn::trainer::xla::ModelMeta::load(&dir) {
        Ok(meta) => {
            let init = netbn::trainer::xla::load_init_params(&dir, meta.param_count).unwrap();
            let trainer = netbn::trainer::xla::XlaTrainer::new(h.clone(), meta.clone());
            let mut gen = netbn::trainer::xla::DataGen::new(1, meta.vocab, 0.1);
            let tokens = gen.batch(meta.batch, meta.seq);
            let slow = BenchConfig {
                warmup_iters: 1,
                min_iters: 3,
                max_iters: 20,
                min_time: Duration::from_millis(200),
                max_time: Duration::from_secs(30),
            };
            let mut b = Bench::with_config("train-step", slow);
            b.bench(&format!("grad_step/{:.1}M-params", meta.param_count as f64 / 1e6), || {
                black_box(trainer.grad_step(&init, &tokens).unwrap());
            });
            let grads = trainer.grad_step(&init, &tokens).unwrap().1;
            b.bench("apply_sgd", || {
                black_box(trainer.apply(&init, &grads, 0.1).unwrap());
            });
            b.report();
            let stats = h.stats().unwrap();
            println!(
                "\ndevice service: {} calls, mean exec {:.2} ms, {} compiles ({:.1}s)",
                stats.calls,
                stats.exec_seconds / stats.calls.max(1) as f64 * 1e3,
                stats.compiles,
                stats.compile_seconds
            );
        }
        Err(e) => println!("train-step bench skipped: {e}"),
    }
}
