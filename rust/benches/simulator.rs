//! Simulator benches: full what-if step simulations per second (the §Perf
//! target is ≥10⁴ sims/s so whole-figure sweeps stay interactive), fusion
//! buffer throughput, and trace generation cost.

use netbn::collectives::fusion::{FusionBuffer, GradTensor};
use netbn::config::FusionConfig;
use netbn::models::timing::backward_trace;
use netbn::models::ModelId;
use netbn::sim::{simulate, SimParams};
use netbn::util::bench::{black_box, Bench, BenchConfig};
use std::time::Duration;

fn main() {
    let cfg = BenchConfig {
        warmup_iters: 3,
        min_iters: 20,
        max_iters: 100_000,
        min_time: Duration::from_millis(400),
        max_time: Duration::from_secs(3),
    };

    let mut b = Bench::with_config("simulate", cfg);
    for id in ModelId::paper_models() {
        let trace = backward_trace(&id.profile());
        let p = SimParams::whatif(trace, 8, 8, 100.0);
        b.bench(&format!("whatif/{}", id.name()), || {
            black_box(simulate(&p));
        });
    }
    {
        let p = SimParams::horovod_like(backward_trace(&ModelId::Vgg16.profile()), 8, 8, 100.0);
        b.bench("horovod-like/VGG16", || {
            black_box(simulate(&p));
        });
    }
    b.report();

    let mut b = Bench::with_config("fusion-buffer", cfg);
    b.bench("push/160-layer-model", || {
        let mut f = FusionBuffer::new(FusionConfig::default());
        let mut emitted = 0usize;
        for layer in 0..160 {
            let now = layer as f64 * 4e-4;
            emitted += f.push(GradTensor::sized(layer, 600_000), now).len();
        }
        emitted += usize::from(f.flush().is_some());
        black_box(emitted);
    });
    b.report();

    let mut b = Bench::with_config("trace-gen", cfg);
    for id in ModelId::paper_models() {
        let profile = id.profile();
        b.bench(&format!("backward_trace/{}", id.name()), || {
            black_box(backward_trace(&profile));
        });
    }
    b.report();
}
