//! Transport benches: in-proc fabric message rate, TCP loopback round
//! trips and bulk throughput, token-bucket shaper accuracy, and the
//! kernel-TCP model evaluation cost.

use netbn::net::kernel_tcp::KernelTcpModel;
use netbn::net::shaper::Shaper;
use netbn::net::{inproc::InProcFabric, tcp::TcpFabric, Fabric};
use netbn::topology::{Topology, WorkerId};
use netbn::util::bench::{black_box, Bench, BenchConfig};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    let cfg = BenchConfig {
        warmup_iters: 2,
        min_iters: 10,
        max_iters: 5_000,
        min_time: Duration::from_millis(300),
        max_time: Duration::from_secs(3),
    };

    // In-proc fabric: small-message rate + bulk throughput.
    let mut b = Bench::with_config("inproc", cfg);
    {
        let fab = InProcFabric::new(2);
        let eps = fab.endpoints();
        let (a, bb) = (Arc::clone(&eps[0]), Arc::clone(&eps[1]));
        let mut tag = 0u64;
        b.bench("send+recv/64B", || {
            a.send(WorkerId(1), tag, &[0u8; 64]).unwrap();
            black_box(bb.recv(WorkerId(0), tag).unwrap());
            tag += 1;
        });
        let payload = vec![7u8; 1 << 20];
        b.bench_bytes("send+recv/1MiB", Some((1 << 20) as f64), || {
            a.send(WorkerId(1), tag, &payload).unwrap();
            black_box(bb.recv(WorkerId(0), tag).unwrap());
            tag += 1;
        });
    }
    b.report();

    // TCP loopback: the e2e fabric.
    let mut b = Bench::with_config("tcp-loopback", cfg);
    {
        let fab = TcpFabric::new(2, None).unwrap();
        let eps = fab.endpoints();
        let (a, bb) = (Arc::clone(&eps[0]), Arc::clone(&eps[1]));
        let echo_a = Arc::clone(&bb);
        let t = std::thread::spawn(move || {
            let mut n = 0u64;
            loop {
                let m = echo_a.recv(WorkerId(0), n).unwrap();
                if m.is_empty() {
                    return;
                }
                echo_a.send(WorkerId(0), n | (1 << 60), &m).unwrap();
                n += 1;
            }
        });
        let mut tag = 0u64;
        b.bench("round-trip/64B", || {
            a.send(WorkerId(1), tag, &[1u8; 64]).unwrap();
            black_box(a.recv(WorkerId(1), tag | (1 << 60)).unwrap());
            tag += 1;
        });
        let payload = vec![7u8; 1 << 20];
        b.bench_bytes("round-trip/1MiB", Some((2 << 20) as f64), || {
            a.send(WorkerId(1), tag, &payload).unwrap();
            black_box(a.recv(WorkerId(1), tag | (1 << 60)).unwrap());
            tag += 1;
        });
        a.send(WorkerId(1), tag, &[]).unwrap(); // stop echo
        t.join().unwrap();
    }
    b.report();

    // Shaper: admission cost and pacing accuracy.
    let mut b = Bench::with_config("shaper", cfg);
    {
        let topo = Topology::new(2, 1);
        let fast = Shaper::new(topo, 1e12, 0.0); // effectively unthrottled
        b.bench("admit/unthrottled", || {
            black_box(fast.admit(WorkerId(0), WorkerId(1), 4096));
        });
    }
    b.report();

    // Pacing accuracy check (printed, not timed): 10 MB at 100 MB/s ≈ 100 ms.
    {
        let topo = Topology::new(2, 1);
        let s = Shaper::new(topo, 100e6, 0.0);
        let t0 = Instant::now();
        for _ in 0..10 {
            s.admit(WorkerId(0), WorkerId(1), 1_000_000);
        }
        let took = t0.elapsed().as_secs_f64();
        println!(
            "\nshaper pacing: 10 MB at 100 MB/s took {took:.3}s (target 0.100s, error {:+.1}%)",
            (took / 0.100 - 1.0) * 100.0
        );
    }

    // Kernel-TCP model: effectively free to evaluate.
    let mut b = Bench::with_config("kernel-tcp-model", cfg);
    let m = KernelTcpModel::default();
    let mut x = 1.0;
    b.bench("effective_gbps", || {
        x = black_box(m.effective_gbps(x % 100.0 + 1.0));
    });
    b.report();
}
