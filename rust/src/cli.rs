//! Command-line parsing substrate (clap stand-in): subcommands, `--flag`,
//! `--key value` / `--key=value` options, positional args, and generated
//! help text.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Declarative option spec used for help text and validation.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// `true` if the option takes a value; `false` for boolean flags.
    pub takes_value: bool,
    pub default: Option<&'static str>,
    /// `true` if the option may be given multiple times (`--param a=1
    /// --param b=2`); values accumulate in [`Args::multi`].
    pub multi: bool,
}

impl OptSpec {
    /// A value-taking option with a default.
    pub fn value(name: &'static str, help: &'static str, default: &'static str) -> OptSpec {
        OptSpec { name, help, takes_value: true, default: Some(default), multi: false }
    }

    /// A value-taking option without a default.
    pub fn optional(name: &'static str, help: &'static str) -> OptSpec {
        OptSpec { name, help, takes_value: true, default: None, multi: false }
    }

    /// A boolean flag.
    pub fn flag(name: &'static str, help: &'static str) -> OptSpec {
        OptSpec { name, help, takes_value: false, default: None, multi: false }
    }

    /// A repeatable value-taking option.
    pub fn repeated(name: &'static str, help: &'static str) -> OptSpec {
        OptSpec { name, help, takes_value: true, default: None, multi: true }
    }
}

/// A subcommand spec.
#[derive(Clone, Debug)]
pub struct CmdSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
    pub positional: Vec<(&'static str, &'static str)>,
}

/// Parsed arguments for a matched subcommand.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub options: BTreeMap<String, String>,
    /// Accumulated values of repeatable options, in argv order.
    pub multi: BTreeMap<String, Vec<String>>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Get an option value, falling back to the spec default.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| anyhow!("--{name} expects an integer, got {s:?}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| anyhow!("--{name} expects a number, got {s:?}")),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// All values of a repeatable option, in argv order.
    pub fn get_multi(&self, name: &str) -> &[String] {
        self.multi.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    /// Values of a repeatable `key=value` option, split at the first `=`.
    pub fn get_kv_multi(&self, name: &str) -> Result<Vec<(String, String)>> {
        self.get_multi(name)
            .iter()
            .map(|pair| {
                pair.split_once('=')
                    .map(|(k, v)| (k.trim().to_string(), v.trim().to_string()))
                    .ok_or_else(|| anyhow!("--{name} expects key=value, got {pair:?}"))
            })
            .collect()
    }

    /// Comma-separated list option → Vec<f64>.
    pub fn get_f64_list(&self, name: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse::<f64>()
                        .map_err(|_| anyhow!("--{name}: bad list element {p:?}"))
                })
                .collect(),
        }
    }
}

/// A CLI application: name + subcommands.
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<CmdSpec>,
}

/// Result of parsing argv.
#[derive(Debug)]
pub enum Parsed {
    /// (subcommand name, its args)
    Command(String, Args),
    /// Help was requested; the rendered text is returned.
    Help(String),
}

impl App {
    /// Parse an argv (excluding the program name).
    pub fn parse(&self, argv: &[String]) -> Result<Parsed> {
        if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" || argv[0] == "help" {
            return Ok(Parsed::Help(self.render_help()));
        }
        let cmd_name = &argv[0];
        let spec = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| anyhow!("unknown command {cmd_name:?}; try --help"))?;
        let mut args = Args::default();
        // Seed defaults.
        for o in &spec.opts {
            if let Some(d) = o.default {
                args.options.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 1;
        while i < argv.len() {
            let tok = &argv[i];
            if tok == "--help" || tok == "-h" {
                return Ok(Parsed::Help(self.render_cmd_help(spec)));
            }
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let ospec = spec
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| anyhow!("unknown option --{name} for {cmd_name}"))?;
                if ospec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .ok_or_else(|| anyhow!("--{name} expects a value"))?
                                .clone()
                        }
                    };
                    if ospec.multi {
                        args.multi.entry(name.to_string()).or_default().push(val);
                    } else {
                        args.options.insert(name.to_string(), val);
                    }
                } else {
                    if inline_val.is_some() {
                        bail!("flag --{name} does not take a value");
                    }
                    args.flags.push(name.to_string());
                }
            } else {
                args.positional.push(tok.clone());
            }
            i += 1;
        }
        if args.positional.len() > spec.positional.len() {
            bail!(
                "{cmd_name} takes at most {} positional argument(s), got {}",
                spec.positional.len(),
                args.positional.len()
            );
        }
        Ok(Parsed::Command(cmd_name.clone(), args))
    }

    /// Top-level help text.
    pub fn render_help(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <command> [options]\n\nCOMMANDS:\n", self.name, self.about, self.name);
        for c in &self.commands {
            s.push_str(&format!("  {:<16} {}\n", c.name, c.about));
        }
        s.push_str("\nRun `netbn <command> --help` for command options.\n");
        s
    }

    /// Per-command help text.
    pub fn render_cmd_help(&self, spec: &CmdSpec) -> String {
        let mut s = format!("{} {} — {}\n\nOPTIONS:\n", self.name, spec.name, spec.about);
        for o in &spec.opts {
            let val = if o.takes_value { " <value>" } else { "" };
            let def = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            let rep = if o.multi { " (repeatable)" } else { "" };
            s.push_str(&format!("  --{}{:<24} {}{}{}\n", o.name, val, o.help, def, rep));
        }
        for (p, h) in &spec.positional {
            s.push_str(&format!("  <{p}>  {h}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn app() -> App {
        App {
            name: "netbn",
            about: "test",
            commands: vec![CmdSpec {
                name: "fig",
                about: "regenerate a figure",
                opts: vec![
                    OptSpec::value("servers", "server count", "2"),
                    OptSpec::flag("fast", "quick mode"),
                    OptSpec::repeated("param", "k=v override"),
                ],
                positional: vec![("n", "figure number")],
            }],
        }
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_command_with_positional_and_options() {
        match app().parse(&argv(&["fig", "3", "--servers", "8", "--fast"])).unwrap() {
            Parsed::Command(name, args) => {
                assert_eq!(name, "fig");
                assert_eq!(args.positional, vec!["3"]);
                assert_eq!(args.get("servers"), Some("8"));
                assert!(args.has_flag("fast"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn equals_syntax() {
        match app().parse(&argv(&["fig", "--servers=4"])).unwrap() {
            Parsed::Command(_, args) => assert_eq!(args.get_usize("servers", 0).unwrap(), 4),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn defaults_applied() {
        match app().parse(&argv(&["fig"])).unwrap() {
            Parsed::Command(_, args) => assert_eq!(args.get("servers"), Some("2")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(app().parse(&argv(&["fig", "--bogus", "1"])).is_err());
    }

    #[test]
    fn unknown_command_rejected() {
        assert!(app().parse(&argv(&["nope"])).is_err());
    }

    #[test]
    fn help_paths() {
        assert!(matches!(app().parse(&argv(&["--help"])).unwrap(), Parsed::Help(_)));
        assert!(matches!(app().parse(&argv(&["fig", "--help"])).unwrap(), Parsed::Help(_)));
    }

    #[test]
    fn repeatable_options_accumulate() {
        match app()
            .parse(&argv(&["fig", "--param", "a=1", "--param=b = 2", "--servers", "4"]))
            .unwrap()
        {
            Parsed::Command(_, args) => {
                assert_eq!(args.get_multi("param"), &["a=1".to_string(), "b = 2".to_string()]);
                assert_eq!(
                    args.get_kv_multi("param").unwrap(),
                    vec![("a".to_string(), "1".to_string()), ("b".to_string(), "2".to_string())]
                );
                assert_eq!(args.get("servers"), Some("4"));
                assert!(args.get_multi("absent").is_empty());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn malformed_kv_pair_rejected() {
        match app().parse(&argv(&["fig", "--param", "novalue"])).unwrap() {
            Parsed::Command(_, args) => {
                let err = args.get_kv_multi("param").unwrap_err().to_string();
                assert!(err.contains("key=value"), "{err}");
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn f64_list_parsing() {
        match app().parse(&argv(&["fig", "--servers", "1,2.5,100"])) {
            Ok(Parsed::Command(_, args)) => {
                assert_eq!(args.get_f64_list("servers", &[]).unwrap(), vec![1.0, 2.5, 100.0]);
            }
            other => panic!("{other:?}"),
        }
    }
}
