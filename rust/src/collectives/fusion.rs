//! Horovod-style gradient **fusion buffer** ("tensor fusion").
//!
//! Paper §3.1: *"the backward process has a timeout window of 5 ms and a
//! gradients buffer size of 64 MB for batching gradients for the
//! all-reduce operations. Once the timeout criterion or buffer size limit
//! is satisfied, it notifies the all-reduce process."*
//!
//! Implemented as a pure state machine over an abstract clock (seconds as
//! `f64`), so the *same* logic drives both the real-time emulator/trainer
//! and the virtual-time what-if simulator — a core design invariant of
//! this reproduction (see DESIGN.md).

use crate::config::FusionConfig;

/// One gradient tensor handed to the buffer by the backward pass.
#[derive(Clone, Debug, PartialEq)]
pub struct GradTensor {
    /// Layer index (front of the model = 0).
    pub layer: usize,
    /// Size on the wire in bytes (f32 elements × 4 unless compressed).
    pub bytes: usize,
    /// Actual values; `None` in simulation (timing only).
    pub data: Option<Vec<f32>>,
}

impl GradTensor {
    pub fn sized(layer: usize, bytes: usize) -> GradTensor {
        GradTensor { layer, bytes, data: None }
    }

    pub fn with_data(layer: usize, data: Vec<f32>) -> GradTensor {
        GradTensor { layer, bytes: data.len() * 4, data: Some(data) }
    }
}

/// A batch of fused tensors ready for one all-reduce.
#[derive(Clone, Debug, PartialEq)]
pub struct Bucket {
    pub seq: u32,
    pub tensors: Vec<GradTensor>,
    pub bytes: usize,
    /// Why the bucket was emitted (observability + tests).
    pub trigger: Trigger,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Trigger {
    /// Size limit reached.
    Size,
    /// Timeout window expired.
    Timeout,
    /// Backward finished; explicit flush.
    Flush,
}

/// The fusion state machine. Call [`push`](FusionBuffer::push) as layers
/// finish, [`poll`](FusionBuffer::poll) when the deadline passes, and
/// [`flush`](FusionBuffer::flush) at end of backward.
#[derive(Debug)]
pub struct FusionBuffer {
    cfg: FusionConfig,
    pending: Vec<GradTensor>,
    pending_bytes: usize,
    window_start: Option<f64>,
    next_seq: u32,
    emitted_bytes_total: u64,
    emitted_buckets: u32,
}

impl FusionBuffer {
    pub fn new(cfg: FusionConfig) -> FusionBuffer {
        FusionBuffer {
            cfg,
            pending: Vec::new(),
            pending_bytes: 0,
            window_start: None,
            next_seq: 0,
            emitted_bytes_total: 0,
            emitted_buckets: 0,
        }
    }

    /// Absolute deadline (same clock as `now` passed to `push`) by which
    /// the pending window times out, if a window is open.
    pub fn deadline(&self) -> Option<f64> {
        self.window_start.map(|t| t + self.cfg.timeout_s)
    }

    pub fn pending_bytes(&self) -> usize {
        self.pending_bytes
    }

    /// Lifetime counters `(buckets, bytes)` — conservation checks.
    pub fn emitted(&self) -> (u32, u64) {
        (self.emitted_buckets, self.emitted_bytes_total)
    }

    /// Offer a tensor at time `now`. Returns any bucket(s) this emission
    /// triggers (at most 2: a size-triggered flush of the previous window
    /// plus an oversized tensor's own bucket).
    pub fn push(&mut self, t: GradTensor, now: f64) -> Vec<Bucket> {
        let mut out = Vec::new();
        // Timeout may already have expired before this push.
        if let Some(b) = self.poll(now) {
            out.push(b);
        }
        if t.bytes >= self.cfg.buffer_bytes {
            // Oversized tensor (e.g. VGG16's ~400 MB fc layer): flush what
            // we have, then the tensor ships as its own bucket.
            if let Some(b) = self.emit(Trigger::Size) {
                out.push(b);
            }
            self.pending.push(t);
            self.pending_bytes = self.pending.last().unwrap().bytes;
            out.push(self.emit(Trigger::Size).unwrap());
            return out;
        }
        if self.pending_bytes + t.bytes > self.cfg.buffer_bytes {
            if let Some(b) = self.emit(Trigger::Size) {
                out.push(b);
            }
        }
        if self.pending.is_empty() {
            self.window_start = Some(now);
        }
        self.pending_bytes += t.bytes;
        self.pending.push(t);
        if self.pending_bytes >= self.cfg.buffer_bytes {
            out.push(self.emit(Trigger::Size).unwrap());
        }
        out
    }

    /// Emit the pending bucket if its timeout window has expired at `now`.
    pub fn poll(&mut self, now: f64) -> Option<Bucket> {
        match self.deadline() {
            Some(d) if now >= d && !self.pending.is_empty() => self.emit(Trigger::Timeout),
            _ => None,
        }
    }

    /// Unconditionally emit whatever is pending (end of backward pass).
    pub fn flush(&mut self) -> Option<Bucket> {
        self.emit(Trigger::Flush)
    }

    fn emit(&mut self, trigger: Trigger) -> Option<Bucket> {
        if self.pending.is_empty() {
            return None;
        }
        let tensors = std::mem::take(&mut self.pending);
        let bytes = self.pending_bytes;
        self.pending_bytes = 0;
        self.window_start = None;
        let seq = self.next_seq;
        self.next_seq += 1;
        self.emitted_buckets += 1;
        self.emitted_bytes_total += bytes as u64;
        Some(Bucket { seq, tensors, bytes, trigger })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn cfg(buffer: usize, timeout: f64) -> FusionConfig {
        FusionConfig { buffer_bytes: buffer, timeout_s: timeout }
    }

    #[test]
    fn size_trigger_at_limit() {
        let mut f = FusionBuffer::new(cfg(100, 1.0));
        assert!(f.push(GradTensor::sized(0, 40), 0.0).is_empty());
        assert!(f.push(GradTensor::sized(1, 40), 0.001).is_empty());
        let out = f.push(GradTensor::sized(2, 40), 0.002);
        // 40+40 = 80, adding 40 would exceed 100 → emit {0,1}, keep {2}.
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].trigger, Trigger::Size);
        assert_eq!(out[0].tensors.len(), 2);
        assert_eq!(out[0].bytes, 80);
        assert_eq!(f.pending_bytes(), 40);
    }

    #[test]
    fn exact_fill_emits() {
        let mut f = FusionBuffer::new(cfg(80, 1.0));
        assert!(f.push(GradTensor::sized(0, 40), 0.0).is_empty());
        let out = f.push(GradTensor::sized(1, 40), 0.0);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].bytes, 80);
        assert_eq!(f.pending_bytes(), 0);
    }

    #[test]
    fn timeout_trigger() {
        let mut f = FusionBuffer::new(cfg(1000, 0.005));
        f.push(GradTensor::sized(0, 10), 0.000);
        assert_eq!(f.deadline(), Some(0.005));
        assert!(f.poll(0.004).is_none());
        let b = f.poll(0.005).unwrap();
        assert_eq!(b.trigger, Trigger::Timeout);
        assert_eq!(b.bytes, 10);
        assert!(f.poll(0.006).is_none(), "empty buffer never times out");
    }

    #[test]
    fn window_starts_at_first_tensor() {
        let approx = |a: Option<f64>, b: f64| (a.unwrap() - b).abs() < 1e-12;
        let mut f = FusionBuffer::new(cfg(1000, 0.005));
        f.push(GradTensor::sized(0, 10), 0.100);
        assert!(approx(f.deadline(), 0.105));
        // Second tensor does NOT extend the window (Horovod semantics).
        f.push(GradTensor::sized(1, 10), 0.104);
        assert!(approx(f.deadline(), 0.105));
    }

    #[test]
    fn push_after_expiry_emits_old_window_first() {
        let mut f = FusionBuffer::new(cfg(1000, 0.005));
        f.push(GradTensor::sized(0, 10), 0.0);
        let out = f.push(GradTensor::sized(1, 20), 0.010);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].trigger, Trigger::Timeout);
        assert_eq!(out[0].tensors[0].layer, 0);
        assert_eq!(f.pending_bytes(), 20);
    }

    #[test]
    fn oversized_tensor_ships_alone() {
        // VGG16's 400 MB fc layer against a 64 MB buffer.
        let mut f = FusionBuffer::new(cfg(64 << 20, 5e-3));
        f.push(GradTensor::sized(0, 1 << 20), 0.0);
        let out = f.push(GradTensor::sized(1, 400 << 20), 0.001);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].bytes, 1 << 20);
        assert_eq!(out[1].bytes, 400 << 20);
        assert_eq!(out[1].tensors.len(), 1);
        assert_eq!(f.pending_bytes(), 0);
    }

    #[test]
    fn flush_emits_remainder() {
        let mut f = FusionBuffer::new(cfg(100, 1.0));
        f.push(GradTensor::sized(0, 30), 0.0);
        let b = f.flush().unwrap();
        assert_eq!(b.trigger, Trigger::Flush);
        assert_eq!(b.bytes, 30);
        assert!(f.flush().is_none());
    }

    #[test]
    fn property_conservation_and_order() {
        // Every pushed byte comes out exactly once, in layer order.
        prop::forall("fusion conserves bytes/order", 100, |rng| {
            let buffer = prop::usize_in(rng, 50..=5000);
            let timeout = rng.range_f64(0.001, 0.01);
            let mut f = FusionBuffer::new(cfg(buffer, timeout));
            let n = prop::usize_in(rng, 1..=60);
            let mut now = 0.0;
            let mut pushed_bytes = 0u64;
            let mut emitted: Vec<usize> = Vec::new();
            let mut emitted_bytes = 0u64;
            for layer in 0..n {
                let sz = prop::usize_in(rng, 1..=2000);
                pushed_bytes += sz as u64;
                now += rng.range_f64(0.0, 0.004);
                for b in f.push(GradTensor::sized(layer, sz), now) {
                    emitted_bytes += b.bytes as u64;
                    emitted.extend(b.tensors.iter().map(|t| t.layer));
                }
            }
            if let Some(b) = f.flush() {
                emitted_bytes += b.bytes as u64;
                emitted.extend(b.tensors.iter().map(|t| t.layer));
            }
            if emitted_bytes != pushed_bytes {
                return Err(format!("bytes {emitted_bytes} != {pushed_bytes}"));
            }
            let want: Vec<usize> = (0..n).collect();
            if emitted != want {
                return Err(format!("order {emitted:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn buckets_never_exceed_limit_unless_single_tensor() {
        prop::forall("fusion size bound", 100, |rng| {
            let buffer = prop::usize_in(rng, 100..=1000);
            let mut f = FusionBuffer::new(cfg(buffer, 0.005));
            let mut now = 0.0;
            for layer in 0..40 {
                let sz = prop::usize_in(rng, 1..=buffer * 2);
                now += 0.001;
                for b in f.push(GradTensor::sized(layer, sz), now) {
                    if b.bytes > buffer && b.tensors.len() != 1 {
                        return Err(format!(
                            "multi-tensor bucket of {} > limit {}",
                            b.bytes, buffer
                        ));
                    }
                }
            }
            Ok(())
        });
    }
}
