//! Hierarchical **leader-ring** all-reduce — the mechanism behind Sun et
//! al.'s "ImageNet/AlexNet in 1.5 Minutes" and the constructive answer to
//! the paper's question at datacenter scale: full utilization of *every*
//! tier, not compression, is what recovers near-linear scale-out.
//!
//! A flat ring drags the whole `2·S·(N−1)/N` wire volume across the
//! slowest link — on an oversubscribed aggregation tier, that tier. The
//! hierarchical scheme splits the work per [`crate::topology::Cluster`]
//! tier:
//!
//! 1. **Intra-group ring all-reduce** (reduce-scatter + all-gather over
//!    the group ring): every member ends with the exact group sum. Runs
//!    on the fast intra tier (NVLink / intra-rack).
//! 2. **Inter-group ring among leaders**: one rank per group carries the
//!    group sum through a ring all-reduce across groups — only
//!    `2·S·(G−1)/G` crosses the oversubscribed tier, and striped lanes
//!    ([`crate::net::striped`]) keep those uplinks saturated.
//! 3. **Intra-group broadcast**: each leader fans the global sum back to
//!    its members.
//!
//! Determinism: every phase reduces in a fixed order, so all ranks end
//! with **bit-identical** tensors (the leaders exchange fully-reduced
//! chunk *bytes* in phase 2 and forward them verbatim in phase 3). The
//! summation *order* differs from a flat ring's, so equality with the
//! flat result is exact-arithmetic equality: bit-identical whenever the
//! sums are exact (integer-valued f32s — see the cross-check suite),
//! within float tolerance otherwise.
//!
//! The collective runs over any [`Endpoint`] — both fabrics (in-proc,
//! TCP) and both transports (single-stream, striped) — and is selected
//! with `--collective hier:<group_size>` wherever a collective knob
//! exists ([`crate::config::CollectiveKind::Hierarchical`]).

use super::{f32s_as_bytes, f32s_as_bytes_mut, ring::ring_allreduce};
use crate::net::{tag, tags, Endpoint};
use crate::topology::Cluster;
use crate::Result;

/// In-place hierarchical all-reduce of `data` across `cluster`. `step`
/// and `bucket` disambiguate concurrent collectives exactly as in
/// [`ring_allreduce`]. Blocking; must be called by *every* rank in the
/// cluster with identically-sized `data`.
pub fn hier_allreduce(
    ep: &dyn Endpoint,
    cluster: &Cluster,
    step: u32,
    bucket: u32,
    data: &mut [f32],
) -> Result<()> {
    cluster.validate()?;
    anyhow::ensure!(
        cluster.workers == ep.world(),
        "cluster of {} workers over a fabric of {}",
        cluster.workers,
        ep.world()
    );
    let me = ep.me();
    let g = cluster.group_of(me);

    // Phase 1 — intra-group ring all-reduce: every member of the group
    // ends with the (bit-identical) group sum. A single-member group is a
    // no-op inside `ring_allreduce`.
    ring_allreduce(ep, &cluster.group_ring(g), step, bucket, data)?;

    // One group means phase 1 already produced the global sum.
    if cluster.n_groups() == 1 {
        return Ok(());
    }

    // Phase 2 — inter-group ring among the leaders. Tag space: the same
    // (step, bucket) is safe because phase-1 peers (same group) and
    // phase-2 peers (leaders of *other* groups) are disjoint senders, and
    // mailboxes match on (from, tag).
    let bcast = tag(tags::HIER_BCAST, step, bucket << 16);
    if cluster.is_leader(me) {
        ring_allreduce(ep, &cluster.leader_ring(), step, bucket, data)?;
        // Phase 3 — broadcast the global sum to the group (verbatim
        // bytes, so members land bit-identical to the leader).
        for member in cluster.members_of(g) {
            if member != me {
                ep.send(member, bcast, f32s_as_bytes(data))?;
            }
        }
    } else {
        // The global sum lands straight in the gradient buffer.
        let got = ep.recv_into(cluster.group_leader(g), bcast, f32s_as_bytes_mut(data))?;
        anyhow::ensure!(got == data.len() * 4, "hier bcast size mismatch");
    }
    Ok(())
}

/// Wire bytes a *leader* moves through the inter-group tier for one
/// hierarchical all-reduce of `s_bytes` across `n_groups` — the ring
/// formula over groups instead of ranks: `2·S·(G−1)/G`.
pub fn inter_wire_bytes_per_leader(s_bytes: f64, n_groups: usize) -> f64 {
    super::ring::wire_bytes_per_worker(s_bytes, n_groups)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::reduce::serial_sum;
    use crate::net::{inproc::InProcFabric, Fabric};
    use crate::util::{prop, Rng};

    /// Run a hierarchical all-reduce across threads and return each
    /// rank's result.
    fn run_hier(inputs: Vec<Vec<f32>>, group_size: usize) -> Vec<Vec<f32>> {
        let n = inputs.len();
        let cluster = Cluster::new(n, group_size);
        let fab = InProcFabric::new(n);
        let eps = fab.endpoints();
        let mut handles = Vec::new();
        for (ep, mut data) in eps.into_iter().zip(inputs) {
            handles.push(std::thread::spawn(move || {
                hier_allreduce(ep.as_ref(), &cluster, 0, 0, &mut data).unwrap();
                data
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    fn close(a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn four_workers_two_groups_sum() {
        let inputs: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32, 10.0 * i as f32]).collect();
        let want = serial_sum(&inputs);
        for r in run_hier(inputs, 2) {
            assert_eq!(r, want); // small integers: sums are exact
        }
    }

    #[test]
    fn ragged_groups_and_uneven_length() {
        // 5 workers in groups of 2 -> {0,1} {2,3} {4}; 103 elements do not
        // divide either ring evenly.
        let mut rng = Rng::new(7);
        let inputs: Vec<Vec<f32>> = (0..5)
            .map(|_| {
                let mut v = vec![0.0f32; 103];
                rng.fill_f32(&mut v, 1.0);
                v
            })
            .collect();
        let want = serial_sum(&inputs);
        for r in run_hier(inputs, 2) {
            close(&r, &want);
        }
    }

    #[test]
    fn single_group_is_flat_ring() {
        // group_size >= workers: phase 1 covers everyone, phases 2-3 are
        // skipped — exactly a flat ring, bit for bit.
        let mut rng = Rng::new(9);
        let inputs: Vec<Vec<f32>> = (0..3)
            .map(|_| {
                let mut v = vec![0.0f32; 64];
                rng.fill_f32(&mut v, 1.0);
                v
            })
            .collect();
        let flat = {
            let n = inputs.len();
            let ring = crate::topology::Topology::new(n, 1).flat_ring();
            let fab = InProcFabric::new(n);
            let mut handles = Vec::new();
            for (ep, mut data) in fab.endpoints().into_iter().zip(inputs.clone()) {
                let ring = ring.clone();
                handles.push(std::thread::spawn(move || {
                    ring_allreduce(ep.as_ref(), &ring, 0, 0, &mut data).unwrap();
                    data
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<_>>()
        };
        let hier = run_hier(inputs, 8);
        for (h, f) in hier.iter().zip(&flat) {
            let hb: Vec<u32> = h.iter().map(|x| x.to_bits()).collect();
            let fb: Vec<u32> = f.iter().map(|x| x.to_bits()).collect();
            assert_eq!(hb, fb);
        }
    }

    #[test]
    fn group_size_one_is_leader_ring_only() {
        // Everyone is a leader: phase 1 is a no-op, phase 2 is a flat ring
        // over all ranks, phase 3 has no followers.
        let inputs: Vec<Vec<f32>> = (0..3).map(|i| vec![(i + 1) as f32; 10]).collect();
        for r in run_hier(inputs, 1) {
            assert_eq!(r, vec![6.0; 10]);
        }
    }

    #[test]
    fn all_ranks_bit_identical() {
        let mut rng = Rng::new(0xbeef);
        let inputs: Vec<Vec<f32>> = (0..6)
            .map(|_| {
                let mut v = vec![0.0f32; 257];
                rng.fill_f32(&mut v, 3.0);
                v
            })
            .collect();
        let results = run_hier(inputs, 2);
        let first: Vec<u32> = results[0].iter().map(|x| x.to_bits()).collect();
        for (w, r) in results.iter().enumerate() {
            let bits: Vec<u32> = r.iter().map(|x| x.to_bits()).collect();
            assert_eq!(bits, first, "rank {w} disagrees bitwise");
        }
    }

    #[test]
    fn len_smaller_than_rings() {
        // 2 elements across 6 workers in groups of 3: both rings see empty
        // chunks.
        let inputs: Vec<Vec<f32>> = (0..6).map(|i| vec![i as f32, 1.0]).collect();
        let want = serial_sum(&inputs);
        for r in run_hier(inputs, 3) {
            assert_eq!(r, want);
        }
    }

    #[test]
    fn property_matches_serial_over_odd_group_sizes() {
        prop::forall("hier == serial over ragged groups", 12, |rng| {
            let n = prop::usize_in(rng, 2..=6);
            let g = prop::usize_in(rng, 1..=n + 1);
            let len = prop::usize_in(rng, 1..=129);
            let inputs: Vec<Vec<f32>> =
                (0..n).map(|_| prop::vec_f32(rng, len..=len, 4.0)).collect();
            let want = serial_sum(&inputs);
            let results = run_hier(inputs, g);
            for r in &results {
                if r.len() != want.len() {
                    return Err("length changed".into());
                }
                for i in 0..want.len() {
                    if (r[i] - want[i]).abs() > 1e-3 {
                        return Err(format!(
                            "n={n} g={g} elem {i}: {} vs {}",
                            r[i], want[i]
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn concurrent_buckets_do_not_cross() {
        let n = 4;
        let cluster = Cluster::new(n, 2);
        let fab = InProcFabric::new(n);
        let mut handles = Vec::new();
        for (i, ep) in fab.endpoints().into_iter().enumerate() {
            handles.push(std::thread::spawn(move || {
                let mut a = vec![i as f32; 9];
                let mut b = vec![(i * 10) as f32; 5];
                hier_allreduce(ep.as_ref(), &cluster, 3, 0, &mut a).unwrap();
                hier_allreduce(ep.as_ref(), &cluster, 3, 1, &mut b).unwrap();
                (a, b)
            }));
        }
        for h in handles {
            let (a, b) = h.join().unwrap();
            assert_eq!(a, vec![6.0; 9]); // 0+1+2+3
            assert_eq!(b, vec![60.0; 5]);
        }
    }

    #[test]
    fn world_mismatch_rejected() {
        let fab = InProcFabric::new(2);
        let eps = fab.endpoints();
        let cluster = Cluster::new(3, 2);
        let mut data = vec![0.0f32; 4];
        assert!(hier_allreduce(eps[0].as_ref(), &cluster, 0, 0, &mut data).is_err());
    }
}
