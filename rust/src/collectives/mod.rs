//! Collective operations over a [`crate::net::Endpoint`] fabric, plus the
//! Horovod-style gradient **fusion buffer**.
//!
//! The all-reduce algorithms here are the real thing — they move real
//! bytes and produce numerically correct sums — and are shared by the
//! integration tests, the emulated trainer, and the e2e example. The
//! what-if simulator ([`crate::sim`]) instead uses the paper's analytic
//! cost model of the *same* ring algorithm, which is why the two can be
//! compared apples-to-apples.

pub mod fusion;
pub mod hierarchical;
pub mod ps;
pub mod reduce;
pub mod ring;
pub mod tree;

use crate::config::CollectiveKind;
use crate::net::{tag, tags, Endpoint};
use crate::topology::{Cluster, Ring};
use crate::Result;
use anyhow::Context as _;

/// Dispatch one all-reduce through the configured algorithm. `ring`,
/// `tree` and `ps` run over the flat rank ring; `hier:<g>` runs the
/// two-phase leader-ring scheme over a [`Cluster`] grouping of the
/// fabric's world. This is the single knob behind `--collective`.
/// Builds the topology per call — hot paths that run many buckets use
/// [`allreduce_prepared`] instead.
pub fn allreduce(
    kind: CollectiveKind,
    ep: &dyn Endpoint,
    step: u32,
    bucket: u32,
    data: &mut [f32],
) -> Result<()> {
    let flat = crate::topology::Topology::new(ep.world(), 1).flat_ring();
    let cluster = match kind {
        CollectiveKind::Hierarchical { group_size } => {
            Some(Cluster::new(ep.world(), group_size))
        }
        _ => None,
    };
    allreduce_prepared(kind, ep, &flat, cluster.as_ref(), step, bucket, data)
}

/// [`allreduce`] with caller-prebuilt topology, so a per-bucket comm path
/// (the async collective engine runs one of these per bucket) allocates
/// nothing. `cluster` is required for — and only read by — the
/// hierarchical kind.
pub fn allreduce_prepared(
    kind: CollectiveKind,
    ep: &dyn Endpoint,
    flat: &Ring,
    cluster: Option<&Cluster>,
    step: u32,
    bucket: u32,
    data: &mut [f32],
) -> Result<()> {
    match kind {
        CollectiveKind::Ring => ring::ring_allreduce(ep, flat, step, bucket, data),
        CollectiveKind::Tree => tree::tree_allreduce(ep, flat, step, bucket, data),
        CollectiveKind::ParameterServer => ps::ps_allreduce(ep, flat, step, bucket, data),
        CollectiveKind::Hierarchical { .. } => {
            let cluster =
                cluster.context("hierarchical all-reduce needs a prebuilt Cluster")?;
            hierarchical::hier_allreduce(ep, cluster, step, bucket, data)
        }
    }
}

/// Serialize an f32 slice to little-endian bytes (allocating copy; kept
/// as the readable reference — the hot path uses [`f32s_as_bytes`]).
pub fn f32s_to_bytes(xs: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Deserialize little-endian bytes to f32s (allocating; hot path uses
/// [`bytes_to_f32s_into`]).
pub fn bytes_to_f32s(bytes: &[u8]) -> Result<Vec<f32>> {
    anyhow::ensure!(bytes.len() % 4 == 0, "payload length {} not a multiple of 4", bytes.len());
    Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
}

/// Zero-copy view of an f32 slice as wire bytes. Valid because every f32
/// bit pattern is a valid byte sequence; the wire format is little-endian,
/// which is asserted at compile time (the supported targets are LE).
#[inline]
pub fn f32s_as_bytes(xs: &[f32]) -> &[u8] {
    const _: () = assert!(cfg!(target_endian = "little"), "wire format is little-endian");
    // SAFETY: f32 and u8 have no invalid bit patterns; alignment of u8 is 1;
    // the length is exactly the byte size of the slice.
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const u8, xs.len() * 4) }
}

/// Mutable zero-copy view of an f32 slice as wire bytes — the receive
/// side of [`f32s_as_bytes`]: collectives hand it to
/// [`crate::net::Endpoint::recv_into`] so incoming chunks land in the
/// gradient buffer with no intermediate copy. Sound for the same reasons
/// (every byte pattern is a valid f32, u8 alignment is 1, LE wire
/// format), plus the exclusive borrow rules out aliasing.
#[inline]
pub fn f32s_as_bytes_mut(xs: &mut [f32]) -> &mut [u8] {
    const _: () = assert!(cfg!(target_endian = "little"), "wire format is little-endian");
    // SAFETY: see f32s_as_bytes; the &mut borrow is exclusive.
    unsafe { std::slice::from_raw_parts_mut(xs.as_mut_ptr() as *mut u8, xs.len() * 4) }
}

/// Decode little-endian bytes into an existing f32 buffer (no allocation).
#[inline]
pub fn bytes_to_f32s_into(bytes: &[u8], dst: &mut [f32]) -> Result<()> {
    anyhow::ensure!(
        bytes.len() == dst.len() * 4,
        "payload {} bytes, expected {}",
        bytes.len(),
        dst.len() * 4
    );
    for (d, c) in dst.iter_mut().zip(bytes.chunks_exact(4)) {
        *d = f32::from_le_bytes(c.try_into().unwrap());
    }
    Ok(())
}

/// Chunk boundaries that split `len` elements into `parts` nearly-equal
/// contiguous ranges (first `len % parts` ranges get one extra element).
pub fn split_points(len: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    assert!(parts >= 1);
    let base = len / parts;
    let extra = len % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for i in 0..parts {
        let sz = base + usize::from(i < extra);
        out.push(start..start + sz);
        start += sz;
    }
    debug_assert_eq!(start, len);
    out
}

/// Rendezvous barrier over the fabric: everyone sends a token to rank 0,
/// rank 0 replies. Used to align step boundaries in the emulator.
pub fn barrier(ep: &dyn Endpoint, step: u32) -> Result<()> {
    let world = ep.world();
    let root = crate::topology::WorkerId(0);
    let t_up = tag(tags::BARRIER, step, 0);
    let t_down = tag(tags::BARRIER, step, 1);
    if ep.me() == root {
        for w in 1..world {
            ep.recv(crate::topology::WorkerId(w), t_up)?;
        }
        for w in 1..world {
            ep.send(crate::topology::WorkerId(w), t_down, &[])?;
        }
    } else {
        ep.send(root, t_up, &[])?;
        ep.recv(root, t_down)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_round_trip() {
        let xs = vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE];
        assert_eq!(bytes_to_f32s(&f32s_to_bytes(&xs)).unwrap(), xs);
    }

    #[test]
    fn bad_length_rejected() {
        assert!(bytes_to_f32s(&[1, 2, 3]).is_err());
    }

    #[test]
    fn split_points_cover_exactly() {
        for (len, parts) in [(10, 3), (7, 7), (5, 8), (0, 2), (64, 4)] {
            let ranges = split_points(len, parts);
            assert_eq!(ranges.len(), parts);
            let mut covered = 0;
            let mut expected_start = 0;
            for r in &ranges {
                assert_eq!(r.start, expected_start);
                expected_start = r.end;
                covered += r.len();
            }
            assert_eq!(covered, len);
            // Near-equal: sizes differ by at most 1.
            let sizes: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
            let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(mx - mn <= 1);
        }
    }

    #[test]
    fn allreduce_dispatch_covers_every_kind() {
        use crate::config::CollectiveKind;
        use crate::net::{inproc::InProcFabric, Fabric};
        for kind in [
            CollectiveKind::Ring,
            CollectiveKind::Tree,
            CollectiveKind::ParameterServer,
            CollectiveKind::Hierarchical { group_size: 2 },
        ] {
            let fab = InProcFabric::new(4);
            let mut handles = Vec::new();
            for (i, ep) in fab.endpoints().into_iter().enumerate() {
                handles.push(std::thread::spawn(move || {
                    let mut data = vec![i as f32; 11];
                    allreduce(kind, ep.as_ref(), 0, 0, &mut data).unwrap();
                    data
                }));
            }
            for h in handles {
                assert_eq!(h.join().unwrap(), vec![6.0; 11], "{kind:?}");
            }
        }
    }

    #[test]
    fn barrier_releases_everyone() {
        use crate::net::{inproc::InProcFabric, Fabric};
        let fab = InProcFabric::new(4);
        let eps = fab.endpoints();
        let mut hs = Vec::new();
        for ep in eps {
            hs.push(std::thread::spawn(move || {
                barrier(ep.as_ref(), 0).unwrap();
                barrier(ep.as_ref(), 1).unwrap();
            }));
        }
        for h in hs {
            h.join().unwrap();
        }
    }
}
