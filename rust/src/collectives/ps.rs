//! Parameter-server baseline: every worker pushes its gradient to a single
//! server (ring member 0), which sums and sends the result back. Wire cost
//! at the server scales with `N·S` — the centralization bottleneck the
//! all-reduce strategy avoids. The paper lists PS as a future-work
//! comparison; we include it so the benches can show the contrast.

use super::{f32s_as_bytes, f32s_as_bytes_mut, reduce::add_bytes_assign};
use crate::net::{tag, tags, Endpoint};
use crate::topology::Ring;
use crate::Result;

/// In-place parameter-server all-reduce (sum) over `ring`'s members, with
/// member 0 acting as the server. Must be called by every member.
pub fn ps_allreduce(
    ep: &dyn Endpoint,
    ring: &Ring,
    step: u32,
    bucket: u32,
    data: &mut [f32],
) -> Result<()> {
    let n = ring.len();
    if n == 1 {
        return Ok(());
    }
    let me = ep.me();
    let rank = ring
        .position(me)
        .ok_or_else(|| anyhow::anyhow!("worker {me} not in the PS group"))?;
    let server = ring.members()[0];
    let t_push = tag(tags::PS_PUSH, step, bucket);
    let t_pull = tag(tags::PS_PULL, step, bucket);
    if rank == 0 {
        for &w in &ring.members()[1..] {
            // Pooled frame, decode-added in place (size-checked inside).
            let inb = ep.recv_buf(w, t_push)?;
            add_bytes_assign(data, &inb)?;
        }
        for &w in &ring.members()[1..] {
            ep.send(w, t_pull, f32s_as_bytes(data))?;
        }
    } else {
        ep.send(server, t_push, f32s_as_bytes(data))?;
        // The reduced vector lands straight in the gradient buffer.
        let got = ep.recv_into(server, t_pull, f32s_as_bytes_mut(data))?;
        anyhow::ensure!(got == data.len() * 4, "ps pull size mismatch");
    }
    Ok(())
}

/// Wire bytes through the *server's* NIC for one PS round of `s_bytes`
/// across `n` members: `(n-1)·S` in plus `(n-1)·S` out.
pub fn server_wire_bytes(s_bytes: f64, n: usize) -> f64 {
    if n <= 1 {
        0.0
    } else {
        2.0 * s_bytes * (n as f64 - 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::reduce::serial_sum;
    use crate::net::{inproc::InProcFabric, Fabric};
    use crate::topology::Topology;

    fn run_ps(inputs: Vec<Vec<f32>>) -> Vec<Vec<f32>> {
        let n = inputs.len();
        let topo = Topology::new(n, 1);
        let ring = topo.flat_ring();
        let fab = InProcFabric::new(n);
        let eps = fab.endpoints();
        let mut handles = Vec::new();
        for (ep, mut data) in eps.into_iter().zip(inputs) {
            let ring = ring.clone();
            handles.push(std::thread::spawn(move || {
                ps_allreduce(ep.as_ref(), &ring, 0, 0, &mut data).unwrap();
                data
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn sums_across_members() {
        let inputs: Vec<Vec<f32>> = (0..5).map(|i| vec![i as f32; 4]).collect();
        let want = serial_sum(&inputs);
        for r in run_ps(inputs) {
            assert_eq!(r, want);
        }
    }

    #[test]
    fn single_member_identity() {
        assert_eq!(run_ps(vec![vec![1.0, 2.0]])[0], vec![1.0, 2.0]);
    }

    #[test]
    fn server_traffic_scales_linearly() {
        assert_eq!(server_wire_bytes(10.0, 1), 0.0);
        assert_eq!(server_wire_bytes(10.0, 3), 40.0);
        // vs ring at the same size: constant ~2S per worker.
        assert!(server_wire_bytes(10.0, 64) > super::super::ring::wire_bytes_per_worker(10.0, 64) * 30.0);
    }
}
