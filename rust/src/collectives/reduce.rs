//! The reduction hot path: element-wise vector add (and average). This is
//! the CPU cost the paper models as `(N−1)·AddEst(S/N)` — in our stack it
//! exists twice: here (rust, used on the emulator's hot path) and as the
//! Pallas `vecadd` kernel (used inside the AOT'd train step). The two are
//! cross-checked in `rust/tests/`.

/// `dst[i] += src[i]`. The loop is written so LLVM auto-vectorizes it
/// (no bounds checks in the body; exact-length zip).
#[inline]
pub fn add_assign(dst: &mut [f32], src: &[f32]) {
    assert_eq!(dst.len(), src.len(), "add_assign length mismatch");
    // Chunked to give the optimizer a straight-line inner body.
    const LANES: usize = 8;
    let n = dst.len();
    let main = n - n % LANES;
    let (dm, dt) = dst.split_at_mut(main);
    let (sm, st) = src.split_at(main);
    for (d8, s8) in dm.chunks_exact_mut(LANES).zip(sm.chunks_exact(LANES)) {
        for i in 0..LANES {
            d8[i] += s8[i];
        }
    }
    for (d, s) in dt.iter_mut().zip(st) {
        *d += *s;
    }
}

/// `dst[i] += f32_le(bytes[4i..4i+4])` — the wire-facing reduce: an
/// incoming chunk is decoded and accumulated in one pass, straight off
/// the receive buffer (no intermediate `Vec<f32>`). Chunked like
/// [`add_assign`] so LLVM vectorizes the fused decode+add body; on LE
/// targets the decode is a plain load, so this runs at [`add_assign`]
/// speed. Bench-tracked as `reduce.reduce_bw_gbps` via
/// [`measure_reduce_bw_gbps`].
#[inline]
pub fn add_bytes_assign(dst: &mut [f32], bytes: &[u8]) -> crate::Result<()> {
    anyhow::ensure!(
        bytes.len() == dst.len() * 4,
        "reduce chunk size mismatch: got {} bytes, want {}",
        bytes.len(),
        dst.len() * 4
    );
    const LANES: usize = 8;
    let n = dst.len();
    let main = n - n % LANES;
    let (dm, dt) = dst.split_at_mut(main);
    let (bm, bt) = bytes.split_at(main * 4);
    for (d8, b32) in dm.chunks_exact_mut(LANES).zip(bm.chunks_exact(LANES * 4)) {
        for i in 0..LANES {
            d8[i] += f32::from_le_bytes(b32[i * 4..i * 4 + 4].try_into().unwrap());
        }
    }
    for (d, c) in dt.iter_mut().zip(bt.chunks_exact(4)) {
        *d += f32::from_le_bytes(c.try_into().unwrap());
    }
    Ok(())
}

/// `dst[i] *= k` — used to turn the all-reduce sum into an average.
#[inline]
pub fn scale(dst: &mut [f32], k: f32) {
    for d in dst.iter_mut() {
        *d *= k;
    }
}

/// Serial reference all-reduce: sum the per-worker vectors. Ground truth
/// for every collective's correctness tests.
pub fn serial_sum(inputs: &[Vec<f32>]) -> Vec<f32> {
    assert!(!inputs.is_empty());
    let mut acc = inputs[0].clone();
    for x in &inputs[1..] {
        add_assign(&mut acc, x);
    }
    acc
}

/// Measure the wall time of `add_assign` over vectors of `elems` f32s —
/// the empirical basis for the simulator's `AddEst` table (§3.1: "we first
/// empirically evaluate time cost of vector-add with various vector sizes
/// ... then use linear interpolation").
pub fn measure_add_seconds(elems: usize, reps: usize) -> f64 {
    let mut a = vec![1.0f32; elems.max(1)];
    let b = vec![1.000001f32; elems.max(1)];
    // Warmup.
    add_assign(&mut a, &b);
    let t0 = std::time::Instant::now();
    for _ in 0..reps.max(1) {
        add_assign(&mut a, &b);
    }
    let dt = t0.elapsed().as_secs_f64() / reps.max(1) as f64;
    std::hint::black_box(&a);
    dt
}

/// Sustained [`add_bytes_assign`] throughput in Gbps of wire bytes
/// reduced — the receive-side CPU ceiling of every collective's hot
/// path. Reported (and regression-gated) by `netbn bench` as
/// `reduce.reduce_bw_gbps`.
pub fn measure_reduce_bw_gbps(elems: usize, reps: usize) -> f64 {
    let mut dst = vec![1.0f32; elems.max(1)];
    let bytes = super::f32s_to_bytes(&vec![1.000001f32; elems.max(1)]);
    // Warmup.
    add_bytes_assign(&mut dst, &bytes).expect("sized to match");
    let t0 = std::time::Instant::now();
    for _ in 0..reps.max(1) {
        add_bytes_assign(&mut dst, &bytes).expect("sized to match");
    }
    let dt = t0.elapsed().as_secs_f64() / reps.max(1) as f64;
    std::hint::black_box(&dst);
    crate::bytes_per_sec_to_gbps(bytes.len() as f64 / dt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn add_assign_matches_scalar_loop() {
        prop::forall("add_assign == scalar", 100, |rng| {
            let a = prop::vec_f32(rng, 1..=4099, 10.0);
            let b_full = prop::vec_f32(rng, a.len()..=a.len(), 10.0);
            let mut got = a.clone();
            add_assign(&mut got, &b_full);
            for i in 0..a.len() {
                let want = a[i] + b_full[i];
                if got[i] != want {
                    return Err(format!("idx {i}: {} != {}", got[i], want));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn add_assign_tail_handling() {
        // Lengths around the LANES boundary.
        for n in [1usize, 7, 8, 9, 15, 16, 17] {
            let mut d = vec![1.0f32; n];
            let s = vec![2.0f32; n];
            add_assign(&mut d, &s);
            assert!(d.iter().all(|x| *x == 3.0), "n={n}");
        }
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn add_assign_rejects_mismatch() {
        add_assign(&mut [1.0], &[1.0, 2.0]);
    }

    #[test]
    fn add_bytes_assign_matches_add_assign() {
        prop::forall("add_bytes_assign == add_assign", 50, |rng| {
            let a = prop::vec_f32(rng, 1..=1025, 10.0);
            let b = prop::vec_f32(rng, a.len()..=a.len(), 10.0);
            let mut want = a.clone();
            add_assign(&mut want, &b);
            let mut got = a.clone();
            add_bytes_assign(&mut got, &crate::collectives::f32s_to_bytes(&b)).unwrap();
            for i in 0..want.len() {
                if got[i].to_bits() != want[i].to_bits() {
                    return Err(format!("idx {i}: {} != {}", got[i], want[i]));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn add_bytes_assign_rejects_size_mismatch() {
        let mut d = vec![0.0f32; 2];
        assert!(add_bytes_assign(&mut d, &[0u8; 7]).is_err());
        assert!(add_bytes_assign(&mut d, &[0u8; 12]).is_err());
        assert!(add_bytes_assign(&mut d, &[0u8; 8]).is_ok());
    }

    #[test]
    fn reduce_bw_is_positive() {
        assert!(measure_reduce_bw_gbps(1 << 14, 4) > 0.0);
    }

    #[test]
    fn scale_averages() {
        let mut v = vec![4.0f32, 8.0];
        scale(&mut v, 0.25);
        assert_eq!(v, vec![1.0, 2.0]);
    }

    #[test]
    fn serial_sum_is_columnwise() {
        let s = serial_sum(&[vec![1.0, 2.0], vec![10.0, 20.0], vec![100.0, 200.0]]);
        assert_eq!(s, vec![111.0, 222.0]);
    }

    #[test]
    fn measure_add_is_positive_and_scales() {
        let t_small = measure_add_seconds(1 << 10, 10);
        let t_big = measure_add_seconds(1 << 20, 10);
        assert!(t_small > 0.0);
        assert!(t_big > t_small, "big {t_big} <= small {t_small}");
    }
}
