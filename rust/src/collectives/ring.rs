//! Ring all-reduce: reduce-scatter followed by all-gather — the algorithm
//! Horovod/NCCL run and the one the paper's cost model describes
//! (per-worker wire traffic `2·S·(N−1)/N`).
//!
//! Every worker calls [`ring_allreduce`] with its local gradient vector;
//! on return the vector holds the element-wise **sum** across the ring.

use super::reduce::add_bytes_assign;
use super::{f32s_as_bytes, f32s_as_bytes_mut, split_points};
use crate::net::{tag, tags, Endpoint};
use crate::topology::Ring;
use crate::Result;

/// In-place ring all-reduce of `data` across `ring`. `step` and `bucket`
/// disambiguate concurrent collectives (tag space). Blocking; must be
/// called by *every* ring member with identically-sized `data`.
pub fn ring_allreduce(
    ep: &dyn Endpoint,
    ring: &Ring,
    step: u32,
    bucket: u32,
    data: &mut [f32],
) -> Result<()> {
    let n = ring.len();
    if n == 1 {
        return Ok(());
    }
    let me = ep.me();
    let pos = ring
        .position(me)
        .ok_or_else(|| anyhow::anyhow!("worker {me} not a member of the ring"))?;
    let next = ring.next(me);
    let prev = ring.prev(me);
    let chunks = split_points(data.len(), n);
    // Tag sub-field: bucket in the high 16 bits, round in the low 16.
    let sub = |round: usize| ((bucket as u32) << 16) | round as u32;

    // Phase 1 — reduce-scatter. After round r, worker at position p holds
    // the partial sum of chunk (p - r) over r+1 contributors; after n-1
    // rounds, chunk (p+1) mod n is fully reduced at position p.
    for round in 0..n - 1 {
        let send_idx = (pos + n - round) % n;
        let recv_idx = (pos + n - round - 1) % n;
        // Zero-copy send view; the incoming chunk is borrowed from the
        // fabric's pool and decode-added in place — no Vec on either side.
        ep.send(
            next,
            tag(tags::REDUCE_SCATTER, step, sub(round)),
            f32s_as_bytes(&data[chunks[send_idx].clone()]),
        )?;
        let inb = ep.recv_buf(prev, tag(tags::REDUCE_SCATTER, step, sub(round)))?;
        let _sp = crate::span!("reduce.add", me.0, step, inb.len());
        add_bytes_assign(&mut data[chunks[recv_idx].clone()], &inb)?;
    }

    // Phase 2 — all-gather. Each worker circulates its fully-reduced
    // chunk; the incoming chunk lands straight in the gradient buffer.
    for round in 0..n - 1 {
        let send_idx = (pos + 1 + n - round) % n;
        let recv_idx = (pos + n - round) % n;
        ep.send(
            next,
            tag(tags::ALL_GATHER, step, sub(round)),
            f32s_as_bytes(&data[chunks[send_idx].clone()]),
        )?;
        let dst = f32s_as_bytes_mut(&mut data[chunks[recv_idx].clone()]);
        let got = ep.recv_into(prev, tag(tags::ALL_GATHER, step, sub(round)), dst)?;
        anyhow::ensure!(
            got == dst.len(),
            "all-gather chunk size mismatch: got {got} bytes, want {}",
            dst.len()
        );
    }
    Ok(())
}

/// Wire bytes each worker sends for one ring all-reduce of `s_bytes` —
/// the paper's `2·S·(N−1)/N`.
pub fn wire_bytes_per_worker(s_bytes: f64, n: usize) -> f64 {
    if n <= 1 {
        0.0
    } else {
        2.0 * s_bytes * (n as f64 - 1.0) / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::reduce::serial_sum;
    use crate::net::{inproc::InProcFabric, Fabric};
    use crate::topology::Topology;
    use crate::util::{prop, Rng};
    use std::sync::Arc;

    /// Run a full ring all-reduce across `n` threads and return each
    /// worker's result.
    fn run_ring(inputs: Vec<Vec<f32>>) -> Vec<Vec<f32>> {
        let n = inputs.len();
        let topo = Topology::new(n, 1);
        let ring = topo.flat_ring();
        let fab = InProcFabric::new(n);
        let eps = fab.endpoints();
        let mut handles = Vec::new();
        for (ep, mut data) in eps.into_iter().zip(inputs) {
            let ring = ring.clone();
            handles.push(std::thread::spawn(move || {
                ring_allreduce(ep.as_ref(), &ring, 0, 0, &mut data).unwrap();
                data
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn two_workers_sum() {
        let results = run_ring(vec![vec![1.0, 2.0, 3.0], vec![10.0, 20.0, 30.0]]);
        for r in results {
            assert_eq!(r, vec![11.0, 22.0, 33.0]);
        }
    }

    #[test]
    fn four_workers_arbitrary_len() {
        // Length not divisible by ring size exercises uneven chunks.
        let mut rng = Rng::new(42);
        let inputs: Vec<Vec<f32>> = (0..4)
            .map(|_| {
                let mut v = vec![0.0f32; 101];
                rng.fill_f32(&mut v, 1.0);
                v
            })
            .collect();
        let want = serial_sum(&inputs);
        for r in run_ring(inputs) {
            for (a, b) in r.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn single_worker_identity() {
        let results = run_ring(vec![vec![5.0, 6.0]]);
        assert_eq!(results[0], vec![5.0, 6.0]);
    }

    #[test]
    fn len_smaller_than_ring() {
        // 4 workers, 2 elements → some chunks are empty.
        let inputs: Vec<Vec<f32>> = (0..4).map(|i| vec![i as f32, 1.0]).collect();
        let want = serial_sum(&inputs);
        for r in run_ring(inputs) {
            assert_eq!(r, want);
        }
    }

    #[test]
    fn property_all_ranks_agree_and_match_serial() {
        prop::forall("ring == serial", 15, |rng| {
            let n = prop::usize_in(rng, 2..=5);
            let len = prop::usize_in(rng, 1..=257);
            let inputs: Vec<Vec<f32>> =
                (0..n).map(|_| prop::vec_f32(rng, len..=len, 5.0)).collect();
            let want = serial_sum(&inputs);
            let results = run_ring(inputs);
            for r in &results {
                if r.len() != want.len() {
                    return Err("length changed".into());
                }
                for i in 0..want.len() {
                    if (r[i] - want[i]).abs() > 1e-3 {
                        return Err(format!("elem {i}: {} vs {}", r[i], want[i]));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn concurrent_buckets_do_not_cross() {
        // Two all-reduces in flight under different bucket ids.
        let n = 3;
        let topo = Topology::new(n, 1);
        let ring = topo.flat_ring();
        let fab = InProcFabric::new(n);
        let eps = fab.endpoints();
        let mut handles = Vec::new();
        for (i, ep) in eps.into_iter().enumerate() {
            let ring = ring.clone();
            let ep: Arc<dyn crate::net::Endpoint> = ep;
            handles.push(std::thread::spawn(move || {
                let mut a = vec![i as f32; 10];
                let mut b = vec![(i * 100) as f32; 7];
                ring_allreduce(ep.as_ref(), &ring, 5, 0, &mut a).unwrap();
                ring_allreduce(ep.as_ref(), &ring, 5, 1, &mut b).unwrap();
                (a, b)
            }));
        }
        for h in handles {
            let (a, b) = h.join().unwrap();
            assert_eq!(a, vec![3.0; 10]); // 0+1+2
            assert_eq!(b, vec![300.0; 7]); // 0+100+200
        }
    }

    #[test]
    fn wire_bytes_formula() {
        assert_eq!(wire_bytes_per_worker(100.0, 1), 0.0);
        assert_eq!(wire_bytes_per_worker(100.0, 2), 100.0);
        assert!((wire_bytes_per_worker(527e6, 64) - 2.0 * 527e6 * 63.0 / 64.0).abs() < 1.0);
    }
}
