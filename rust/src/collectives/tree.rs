//! Binary-tree all-reduce baseline: reduce up a binomial tree to the root,
//! then broadcast down. Wire cost per step is `O(S·log N)` on the critical
//! path vs the ring's `2S(N−1)/N` — included so benches can contrast the
//! algorithms the way the paper's §3.1 model assumes ring.

use super::{f32s_as_bytes, f32s_as_bytes_mut, reduce::add_bytes_assign};
use crate::net::{tag, tags, Endpoint};
use crate::topology::{Ring, WorkerId};
use crate::Result;

/// In-place binomial-tree all-reduce (sum) over the members of `ring`
/// (the ring order provides a stable rank assignment; no ring links are
/// implied). Must be called by every member.
pub fn tree_allreduce(
    ep: &dyn Endpoint,
    ring: &Ring,
    step: u32,
    bucket: u32,
    data: &mut [f32],
) -> Result<()> {
    let n = ring.len();
    if n == 1 {
        return Ok(());
    }
    let me = ep.me();
    let rank = ring
        .position(me)
        .ok_or_else(|| anyhow::anyhow!("worker {me} not a member of the tree group"))?;
    let member = |r: usize| -> WorkerId { ring.members()[r] };
    let sub = |round: usize| ((bucket as u32) << 16) | round as u32;

    // Reduce phase: in round k, ranks with the (1<<k) bit set send to
    // rank - (1<<k) and drop out; receivers accumulate.
    let mut k = 0usize;
    loop {
        let bit = 1usize << k;
        if bit >= n {
            break;
        }
        if rank & (bit - 1) != 0 {
            // Already sent in an earlier round.
            k += 1;
            continue;
        }
        if rank & bit != 0 {
            let dst = rank - bit;
            ep.send(member(dst), tag(tags::TREE_UP, step, sub(k)), f32s_as_bytes(data))?;
            break; // sender's reduce role is done
        } else if rank + bit < n {
            let src = rank + bit;
            // Pooled frame, decode-added in place (size-checked inside).
            let inb = ep.recv_buf(member(src), tag(tags::TREE_UP, step, sub(k)))?;
            add_bytes_assign(data, &inb)?;
        }
        k += 1;
    }

    // Broadcast phase: mirror image — root sends down the same tree.
    let rounds = (0..).take_while(|k| (1usize << k) < n).count();
    for k in (0..rounds).rev() {
        let bit = 1usize << k;
        if rank & (bit - 1) != 0 {
            continue;
        }
        if rank & bit != 0 {
            let src = rank - bit;
            // The broadcast lands straight in the gradient buffer.
            let got = ep.recv_into(
                member(src),
                tag(tags::TREE_DOWN, step, sub(k)),
                f32s_as_bytes_mut(data),
            )?;
            anyhow::ensure!(got == data.len() * 4, "tree bcast size mismatch");
        } else if rank + bit < n {
            let dst = rank + bit;
            ep.send(member(dst), tag(tags::TREE_DOWN, step, sub(k)), f32s_as_bytes(data))?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::reduce::serial_sum;
    use crate::net::{inproc::InProcFabric, Fabric};
    use crate::topology::Topology;
    use crate::util::prop;

    fn run_tree(inputs: Vec<Vec<f32>>) -> Vec<Vec<f32>> {
        let n = inputs.len();
        let topo = Topology::new(n, 1);
        let ring = topo.flat_ring();
        let fab = InProcFabric::new(n);
        let eps = fab.endpoints();
        let mut handles = Vec::new();
        for (ep, mut data) in eps.into_iter().zip(inputs) {
            let ring = ring.clone();
            handles.push(std::thread::spawn(move || {
                tree_allreduce(ep.as_ref(), &ring, 0, 0, &mut data).unwrap();
                data
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn powers_of_two_and_odd_sizes() {
        for n in [2usize, 3, 4, 5, 7, 8] {
            let inputs: Vec<Vec<f32>> = (0..n).map(|i| vec![i as f32, 1.0, -(i as f32)]).collect();
            let want = serial_sum(&inputs);
            for r in run_tree(inputs) {
                assert_eq!(r, want, "n={n}");
            }
        }
    }

    #[test]
    fn single_member_identity() {
        let r = run_tree(vec![vec![9.0]]);
        assert_eq!(r[0], vec![9.0]);
    }

    #[test]
    fn property_matches_serial() {
        prop::forall("tree == serial", 12, |rng| {
            let n = prop::usize_in(rng, 2..=6);
            let len = prop::usize_in(rng, 1..=64);
            let inputs: Vec<Vec<f32>> =
                (0..n).map(|_| prop::vec_f32(rng, len..=len, 3.0)).collect();
            let want = serial_sum(&inputs);
            for r in run_tree(inputs) {
                for i in 0..want.len() {
                    if (r[i] - want[i]).abs() > 1e-3 {
                        return Err(format!("elem {i}: {} vs {}", r[i], want[i]));
                    }
                }
            }
            Ok(())
        });
    }
}
