//! Codec implementations. All stateless; the error-feedback residual for
//! lossy codecs lives in [`super::error_feedback`].

use super::CodecKind;
use crate::util::Rng;
use crate::Result;
use anyhow::ensure;

/// Compressed payload + metadata needed to reconstruct.
#[derive(Clone, Debug)]
pub struct Encoded {
    pub kind_name: String,
    /// Original element count.
    pub len: usize,
    /// Wire bytes.
    pub bytes: Vec<u8>,
}

impl Encoded {
    /// Achieved compression ratio for this payload.
    pub fn achieved_ratio(&self) -> f64 {
        (self.len * 4) as f64 / self.bytes.len().max(1) as f64
    }
}

/// Compress `data` with `kind`. `seed` feeds RandomK (both encode and
/// decode must agree on the seed; the trainer derives it from the step).
pub fn encode(kind: CodecKind, data: &[f32], seed: u64) -> Encoded {
    let bytes = match kind {
        CodecKind::Fp16 => fp16_encode(data),
        CodecKind::Int8 => int8_encode(data),
        CodecKind::TopK { k_fraction } => topk_encode(data, k_fraction),
        CodecKind::RandomK { k_fraction } => randk_encode(data, k_fraction, seed),
        CodecKind::OneBit => onebit_encode(data),
    };
    Encoded { kind_name: kind.name(), len: data.len(), bytes }
}

/// Decompress. Sparse codecs return dense vectors with zeros at dropped
/// coordinates.
pub fn decode(kind: CodecKind, enc: &Encoded, seed: u64) -> Result<Vec<f32>> {
    match kind {
        CodecKind::Fp16 => fp16_decode(&enc.bytes, enc.len),
        CodecKind::Int8 => int8_decode(&enc.bytes, enc.len),
        CodecKind::TopK { .. } => topk_decode(&enc.bytes, enc.len),
        CodecKind::RandomK { k_fraction } => randk_decode(&enc.bytes, enc.len, k_fraction, seed),
        CodecKind::OneBit => onebit_decode(&enc.bytes, enc.len),
    }
}

// ------------------------------------------------------------------- fp16

/// f32 → IEEE 754 half, round-to-nearest-even, with overflow → ±inf.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x7f_ffff;
    if exp == 0xff {
        // Inf/NaN.
        return sign | 0x7c00 | if mant != 0 { 0x200 } else { 0 };
    }
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7c00; // overflow → inf
    }
    if unbiased >= -14 {
        // Normal half.
        let half_exp = (unbiased + 15) as u32;
        let half_mant = mant >> 13;
        // Round to nearest even on the dropped 13 bits.
        let round_bits = mant & 0x1fff;
        let mut h = ((half_exp << 10) | half_mant) as u16;
        if round_bits > 0x1000 || (round_bits == 0x1000 && (half_mant & 1) == 1) {
            h += 1; // may carry into exponent — that's correct rounding
        }
        return sign | h;
    }
    if unbiased >= -24 {
        // Subnormal half.
        let shift = (-unbiased - 14) as u32 + 13;
        let full_mant = mant | 0x80_0000;
        let half_mant = full_mant >> (shift + 1);
        let round = (full_mant >> shift) & 1;
        return sign | (half_mant as u16 + round as u16);
    }
    sign // underflow → ±0
}

/// IEEE half bits → f32.
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = if exp == 0x1f {
        sign | 0x7f80_0000 | (mant << 13)
    } else if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // Subnormal: normalize.
            let mut e = 127 - 15 + 1;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | ((e as u32) << 23) | ((m & 0x3ff) << 13)
        }
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

fn fp16_encode(data: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() * 2);
    for x in data {
        out.extend_from_slice(&f32_to_f16_bits(*x).to_le_bytes());
    }
    out
}

fn fp16_decode(bytes: &[u8], len: usize) -> Result<Vec<f32>> {
    ensure!(bytes.len() == len * 2, "fp16 payload size");
    Ok(bytes
        .chunks_exact(2)
        .map(|c| f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])))
        .collect())
}

// ------------------------------------------------------------------- int8

/// Per-buffer linear quantization: scale = max|x| / 127.
fn int8_encode(data: &[f32]) -> Vec<u8> {
    let max_abs = data.iter().fold(0.0f32, |m, x| m.max(x.abs()));
    let scale = if max_abs > 0.0 { max_abs / 127.0 } else { 1.0 };
    let mut out = Vec::with_capacity(4 + data.len());
    out.extend_from_slice(&scale.to_le_bytes());
    for x in data {
        let q = (x / scale).round().clamp(-127.0, 127.0) as i8;
        out.push(q as u8);
    }
    out
}

fn int8_decode(bytes: &[u8], len: usize) -> Result<Vec<f32>> {
    ensure!(bytes.len() == 4 + len, "int8 payload size");
    let scale = f32::from_le_bytes(bytes[0..4].try_into().unwrap());
    Ok(bytes[4..].iter().map(|b| (*b as i8) as f32 * scale).collect())
}

// ------------------------------------------------------------------- topk

fn kept_count(len: usize, k_fraction: f64) -> usize {
    ((len as f64 * k_fraction).ceil() as usize).clamp(1, len.max(1))
}

/// Keep the `k_fraction` largest-magnitude coordinates:
/// wire = [u32 count][u32 idx]*k [f32 val]*k.
fn topk_encode(data: &[f32], k_fraction: f64) -> Vec<u8> {
    let k = kept_count(data.len(), k_fraction);
    let mut idx: Vec<u32> = (0..data.len() as u32).collect();
    // Partial selection by magnitude (descending).
    let nth = k.saturating_sub(1).min(idx.len() - 1);
    idx.select_nth_unstable_by(nth, |a, b| {
        data[*b as usize]
            .abs()
            .partial_cmp(&data[*a as usize].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    idx.truncate(k);
    idx.sort_unstable();
    let mut out = Vec::with_capacity(4 + k * 8);
    out.extend_from_slice(&(k as u32).to_le_bytes());
    for i in &idx {
        out.extend_from_slice(&i.to_le_bytes());
    }
    for i in &idx {
        out.extend_from_slice(&data[*i as usize].to_le_bytes());
    }
    out
}

fn topk_decode(bytes: &[u8], len: usize) -> Result<Vec<f32>> {
    ensure!(bytes.len() >= 4, "topk payload too short");
    let k = u32::from_le_bytes(bytes[0..4].try_into().unwrap()) as usize;
    ensure!(bytes.len() == 4 + k * 8, "topk payload size");
    let mut out = vec![0.0f32; len];
    let idx_bytes = &bytes[4..4 + k * 4];
    let val_bytes = &bytes[4 + k * 4..];
    for (ib, vb) in idx_bytes.chunks_exact(4).zip(val_bytes.chunks_exact(4)) {
        let i = u32::from_le_bytes(ib.try_into().unwrap()) as usize;
        ensure!(i < len, "topk index {i} out of range {len}");
        out[i] = f32::from_le_bytes(vb.try_into().unwrap());
    }
    Ok(out)
}

// ----------------------------------------------------------------- randomk

/// Random-k: indices are *not* sent — both sides regenerate them from the
/// shared seed. Values are scaled by 1/k so the estimate is unbiased.
fn randk_indices(len: usize, k: usize, seed: u64) -> Vec<usize> {
    let mut rng = Rng::new(seed ^ 0x9E3779B97F4A7C15);
    let mut idx: Vec<usize> = (0..len).collect();
    rng.shuffle(&mut idx);
    idx.truncate(k);
    idx.sort_unstable();
    idx
}

fn randk_encode(data: &[f32], k_fraction: f64, seed: u64) -> Vec<u8> {
    let k = kept_count(data.len(), k_fraction);
    let idx = randk_indices(data.len(), k, seed);
    let inv_k = 1.0 / k_fraction.min(1.0) as f32;
    let mut out = Vec::with_capacity(idx.len() * 4);
    for i in idx {
        out.extend_from_slice(&(data[i] * inv_k).to_le_bytes());
    }
    out
}

fn randk_decode(bytes: &[u8], len: usize, k_fraction: f64, seed: u64) -> Result<Vec<f32>> {
    let k = kept_count(len, k_fraction);
    ensure!(bytes.len() == k * 4, "randk payload size");
    let idx = randk_indices(len, k, seed);
    let mut out = vec![0.0f32; len];
    for (i, vb) in idx.into_iter().zip(bytes.chunks_exact(4)) {
        out[i] = f32::from_le_bytes(vb.try_into().unwrap());
    }
    Ok(out)
}

// ------------------------------------------------------------------ 1-bit

/// 1-bit SGD: sign bitmap + one mean magnitude for positives and one for
/// negatives (per buffer).
fn onebit_encode(data: &[f32]) -> Vec<u8> {
    let (mut pos_sum, mut pos_n, mut neg_sum, mut neg_n) = (0.0f64, 0usize, 0.0f64, 0usize);
    for x in data {
        if *x >= 0.0 {
            pos_sum += *x as f64;
            pos_n += 1;
        } else {
            neg_sum += *x as f64;
            neg_n += 1;
        }
    }
    let pos_mean = if pos_n > 0 { (pos_sum / pos_n as f64) as f32 } else { 0.0 };
    let neg_mean = if neg_n > 0 { (neg_sum / neg_n as f64) as f32 } else { 0.0 };
    let mut out = Vec::with_capacity(8 + data.len().div_ceil(8));
    out.extend_from_slice(&pos_mean.to_le_bytes());
    out.extend_from_slice(&neg_mean.to_le_bytes());
    let mut byte = 0u8;
    for (i, x) in data.iter().enumerate() {
        if *x >= 0.0 {
            byte |= 1 << (i % 8);
        }
        if i % 8 == 7 {
            out.push(byte);
            byte = 0;
        }
    }
    if data.len() % 8 != 0 {
        out.push(byte);
    }
    out
}

fn onebit_decode(bytes: &[u8], len: usize) -> Result<Vec<f32>> {
    ensure!(bytes.len() == 8 + len.div_ceil(8), "onebit payload size");
    let pos = f32::from_le_bytes(bytes[0..4].try_into().unwrap());
    let neg = f32::from_le_bytes(bytes[4..8].try_into().unwrap());
    let bitmap = &bytes[8..];
    Ok((0..len)
        .map(|i| if bitmap[i / 8] >> (i % 8) & 1 == 1 { pos } else { neg })
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn l2(a: &[f32], b: &[f32]) -> f64 {
        a.iter().zip(b).map(|(x, y)| ((x - y) as f64).powi(2)).sum::<f64>().sqrt()
    }

    fn norm(a: &[f32]) -> f64 {
        a.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt().max(1e-12)
    }

    #[test]
    fn fp16_known_values() {
        for (x, h) in [(0.0f32, 0u16), (1.0, 0x3c00), (-2.0, 0xc000), (65504.0, 0x7bff)] {
            assert_eq!(f32_to_f16_bits(x), h, "{x}");
            assert_eq!(f16_bits_to_f32(h), x, "{h:#x}");
        }
        assert_eq!(f32_to_f16_bits(1e30), 0x7c00); // overflow → inf
        assert!(f16_bits_to_f32(0x7c00).is_infinite());
    }

    #[test]
    fn fp16_round_trip_precision() {
        prop::forall("fp16 relative error < 0.1%", 100, |rng| {
            let xs = prop::vec_f32(rng, 1..=300, 10.0);
            let enc = encode(CodecKind::Fp16, &xs, 0);
            let dec = decode(CodecKind::Fp16, &enc, 0).unwrap();
            for (a, b) in xs.iter().zip(&dec) {
                let rel = (a - b).abs() / a.abs().max(1e-3);
                if rel > 1e-3 {
                    return Err(format!("{a} -> {b}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn int8_error_bounded_by_scale() {
        prop::forall("int8 |err| <= scale/2", 100, |rng| {
            let xs = prop::vec_f32(rng, 1..=500, 50.0);
            let enc = encode(CodecKind::Int8, &xs, 0);
            let dec = decode(CodecKind::Int8, &enc, 0).unwrap();
            let max_abs = xs.iter().fold(0.0f32, |m, x| m.max(x.abs()));
            let scale = max_abs / 127.0;
            for (a, b) in xs.iter().zip(&dec) {
                if (a - b).abs() > scale * 0.5 + 1e-7 {
                    return Err(format!("{a} -> {b}, scale {scale}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn topk_keeps_largest() {
        let xs = vec![0.1f32, -5.0, 0.2, 3.0, -0.05];
        let enc = encode(CodecKind::TopK { k_fraction: 0.4 }, &xs, 0);
        let dec = decode(CodecKind::TopK { k_fraction: 0.4 }, &enc, 0).unwrap();
        assert_eq!(dec, vec![0.0, -5.0, 0.0, 3.0, 0.0]);
    }

    #[test]
    fn topk_reduces_error_vs_zero() {
        prop::forall("topk beats dropping everything", 50, |rng| {
            let xs = prop::vec_f32(rng, 10..=500, 1.0);
            let kind = CodecKind::TopK { k_fraction: 0.25 };
            let dec = decode(kind, &encode(kind, &xs, 0), 0).unwrap();
            let zero = vec![0.0f32; xs.len()];
            if l2(&xs, &dec) <= l2(&xs, &zero) {
                Ok(())
            } else {
                Err("topk worse than zeros".into())
            }
        });
    }

    #[test]
    fn randk_same_seed_reconstructs_unbiased_scale() {
        // Values start at 1 so "kept" is detectable as nonzero.
        let xs: Vec<f32> = (1..=100).map(|i| i as f32).collect();
        let kind = CodecKind::RandomK { k_fraction: 0.5 };
        let dec = decode(kind, &encode(kind, &xs, 42), 42).unwrap();
        // Kept coordinates are scaled by 1/k = 2.
        let kept: Vec<(usize, f32)> =
            dec.iter().cloned().enumerate().filter(|(_, v)| *v != 0.0).collect();
        assert_eq!(kept.len(), 50);
        for (i, v) in kept {
            assert_eq!(v, xs[i] * 2.0);
        }
    }

    #[test]
    fn randk_different_seed_fails_cleanly() {
        // Different seeds → different index sets; decode still succeeds
        // structurally (payload size is seed-independent).
        let xs: Vec<f32> = (1..=16).map(|i| i as f32).collect();
        let kind = CodecKind::RandomK { k_fraction: 0.25 };
        let enc = encode(kind, &xs, 1);
        let dec = decode(kind, &enc, 2).unwrap();
        assert_eq!(dec.len(), xs.len());
    }

    #[test]
    fn onebit_preserves_signs_and_mean() {
        let xs = vec![1.0f32, 2.0, 3.0, -1.0, -3.0];
        let enc = encode(CodecKind::OneBit, &xs, 0);
        let dec = decode(CodecKind::OneBit, &enc, 0).unwrap();
        assert_eq!(dec, vec![2.0, 2.0, 2.0, -2.0, -2.0]);
    }

    #[test]
    fn achieved_ratios_near_nominal_for_large_buffers() {
        let xs: Vec<f32> = (0..100_000).map(|i| (i as f32).sin()).collect();
        for kind in [CodecKind::Fp16, CodecKind::Int8, CodecKind::OneBit] {
            let enc = encode(kind, &xs, 0);
            let nominal = kind.nominal_ratio();
            let achieved = enc.achieved_ratio();
            assert!(
                (achieved - nominal).abs() / nominal < 0.05,
                "{kind:?}: {achieved} vs {nominal}"
            );
        }
        let kind = CodecKind::TopK { k_fraction: 0.01 };
        let enc = encode(kind, &xs, 0);
        assert!((enc.achieved_ratio() - 50.0).abs() < 5.0, "{}", enc.achieved_ratio());
    }

    #[test]
    fn all_codecs_handle_edge_vectors() {
        prop::forall("codecs round-trip structurally on edgy data", 60, |rng| {
            let xs = prop::vec_f32_edgy(rng, 1..=64);
            for kind in [
                CodecKind::Fp16,
                CodecKind::Int8,
                CodecKind::TopK { k_fraction: 0.3 },
                CodecKind::RandomK { k_fraction: 0.3 },
                CodecKind::OneBit,
            ] {
                let enc = encode(kind, &xs, 7);
                let dec = decode(kind, &enc, 7)
                    .map_err(|e| format!("{kind:?}: {e}"))?;
                if dec.len() != xs.len() {
                    return Err(format!("{kind:?}: length {}", dec.len()));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn relative_error_ordering_matches_lossiness() {
        // fp16 < int8 < onebit in reconstruction error, on generic data.
        let mut rng = crate::util::Rng::new(3);
        let mut xs = vec![0.0f32; 10_000];
        rng.fill_f32(&mut xs, 1.0);
        let err = |kind| {
            let enc = encode(kind, &xs, 0);
            let dec = decode(kind, &enc, 0).unwrap();
            l2(&xs, &dec) / norm(&xs)
        };
        let e16 = err(CodecKind::Fp16);
        let e8 = err(CodecKind::Int8);
        let e1 = err(CodecKind::OneBit);
        assert!(e16 < e8 && e8 < e1, "{e16} {e8} {e1}");
    }
}
