//! Error-feedback (memory) for lossy codecs: the quantization/sparsification
//! residual is carried into the next step's gradient (Seide et al. 2014,
//! Lin et al. DGC). This is the mechanism that keeps convergence from
//! collapsing under aggressive compression — and the reason the paper can
//! say compression "can prolong the convergence time": without residuals
//! the bias is unbounded; with them it is contained but still costs steps.

use super::{codecs, CodecKind};
use crate::Result;

/// Per-bucket residual state.
#[derive(Clone, Debug)]
pub struct ErrorFeedback {
    kind: CodecKind,
    residual: Vec<f32>,
}

impl ErrorFeedback {
    pub fn new(kind: CodecKind, len: usize) -> ErrorFeedback {
        ErrorFeedback { kind, residual: vec![0.0; len] }
    }

    /// Compress `grad + residual`, retaining the new residual locally.
    /// Returns the encoded payload to ship.
    pub fn compress(&mut self, grad: &[f32], seed: u64) -> Result<codecs::Encoded> {
        anyhow::ensure!(grad.len() == self.residual.len(), "error-feedback length mismatch");
        let corrected: Vec<f32> =
            grad.iter().zip(&self.residual).map(|(g, r)| g + r).collect();
        let enc = codecs::encode(self.kind, &corrected, seed);
        let dec = codecs::decode(self.kind, &enc, seed)?;
        for ((r, c), d) in self.residual.iter_mut().zip(&corrected).zip(&dec) {
            *r = c - d;
        }
        Ok(enc)
    }

    pub fn residual_norm(&self) -> f64 {
        self.residual.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt()
    }

    pub fn kind(&self) -> CodecKind {
        self.kind
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn residual_carries_dropped_mass() {
        // With top-k keeping 1 of 4 coords, the other 3 must persist in
        // the residual and eventually ship.
        let kind = CodecKind::TopK { k_fraction: 0.25 };
        let mut ef = ErrorFeedback::new(kind, 4);
        let grad = vec![1.0f32, 0.9, 0.8, 0.7];
        let enc1 = ef.compress(&grad, 0).unwrap();
        let dec1 = codecs::decode(kind, &enc1, 0).unwrap();
        assert_eq!(dec1, vec![1.0, 0.0, 0.0, 0.0]);
        // Next step, zero fresh gradient: the residual's largest (0.9)
        // ships now.
        let enc2 = ef.compress(&[0.0; 4], 1).unwrap();
        let dec2 = codecs::decode(kind, &enc2, 1).unwrap();
        assert_eq!(dec2, vec![0.0, 0.9, 0.0, 0.0]);
    }

    #[test]
    fn cumulative_transmission_approaches_cumulative_gradient() {
        // Σ decoded ≈ Σ grads + residual ⇒ ‖Σ grads − Σ decoded‖ = ‖residual‖.
        let kind = CodecKind::Int8;
        let n = 256;
        let mut ef = ErrorFeedback::new(kind, n);
        let mut rng = Rng::new(9);
        let mut sum_grad = vec![0.0f64; n];
        let mut sum_dec = vec![0.0f64; n];
        for step in 0..50 {
            let mut g = vec![0.0f32; n];
            rng.fill_f32(&mut g, 0.1);
            for (s, v) in sum_grad.iter_mut().zip(&g) {
                *s += *v as f64;
            }
            let enc = ef.compress(&g, step).unwrap();
            let dec = codecs::decode(kind, &enc, step).unwrap();
            for (s, v) in sum_dec.iter_mut().zip(&dec) {
                *s += *v as f64;
            }
        }
        let drift: f64 = sum_grad
            .iter()
            .zip(&sum_dec)
            .map(|(a, b)| (a - b).powi(2))
            .sum::<f64>()
            .sqrt();
        assert!((drift - ef.residual_norm()).abs() < 1e-3, "drift {drift} vs residual {}", ef.residual_norm());
    }

    #[test]
    fn rejects_length_mismatch() {
        let mut ef = ErrorFeedback::new(CodecKind::Fp16, 4);
        assert!(ef.compress(&[1.0; 5], 0).is_err());
    }
}
