//! Real gradient compression codecs (§3.2's subject). The what-if
//! simulator only needs a *ratio*; these implementations exist so the
//! trainer can run compression for real, so the ratio numbers used in
//! Fig 8 are grounded in working codecs, and so the accuracy cost the
//! paper warns about ("lossy compression ... can prolong the convergence
//! time") is measurable (see `examples/compression_lab.rs`).
//!
//! Codecs: fp16 (2×), int8 linear quantization (4×), top-k magnitude
//! sparsification (~`1/k`×), random-k sparsification, and 1-bit SGD
//! (Seide et al.) with the customary error-feedback residual.

pub mod codecs;
pub mod error_feedback;

pub use codecs::{decode, encode, Encoded};
pub use error_feedback::ErrorFeedback;

/// The codec selector (config-file facing).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CodecKind {
    /// IEEE half precision: 2× smaller, low loss.
    Fp16,
    /// Per-chunk linear int8 quantization: 4× smaller.
    Int8,
    /// Keep the top `k` fraction of coordinates by magnitude
    /// (values + u32 indices on the wire).
    TopK { k_fraction: f64 },
    /// Keep a uniformly random `k` fraction (cheap, unbiased w/ scaling).
    RandomK { k_fraction: f64 },
    /// Sign + per-chunk mean magnitude: ~32× smaller.
    OneBit,
}

impl CodecKind {
    pub fn parse(s: &str) -> Option<CodecKind> {
        let lower = s.to_ascii_lowercase();
        match lower.as_str() {
            "fp16" | "half" => return Some(CodecKind::Fp16),
            "int8" | "q8" => return Some(CodecKind::Int8),
            "onebit" | "1bit" | "sign" => return Some(CodecKind::OneBit),
            _ => {}
        }
        // topk:0.01 / randk:0.05
        if let Some(rest) = lower.strip_prefix("topk:") {
            return parse_k_fraction(rest).map(|k_fraction| CodecKind::TopK { k_fraction });
        }
        if let Some(rest) = lower.strip_prefix("randk:") {
            return parse_k_fraction(rest).map(|k_fraction| CodecKind::RandomK { k_fraction });
        }
        None
    }

    /// Nominal wire-size ratio (uncompressed / compressed) — what the
    /// paper's §3.2 model divides the transit time by. [`CodecKind::parse`]
    /// guarantees `0 < k <= 1`, so the division is well-defined (no silent
    /// clamping); directly-constructed codecs must uphold the same bound.
    pub fn nominal_ratio(&self) -> f64 {
        match self {
            CodecKind::Fp16 => 2.0,
            CodecKind::Int8 => 4.0,
            // topk sends (f32 value + u32 index) per kept coordinate.
            CodecKind::TopK { k_fraction } => {
                debug_assert!(*k_fraction > 0.0 && *k_fraction <= 1.0);
                1.0 / (k_fraction * 2.0)
            }
            // randk regenerates indices from the shared seed: values only.
            CodecKind::RandomK { k_fraction } => {
                debug_assert!(*k_fraction > 0.0 && *k_fraction <= 1.0);
                1.0 / k_fraction
            }
            CodecKind::OneBit => 32.0,
        }
    }

    pub fn name(&self) -> String {
        match self {
            CodecKind::Fp16 => "fp16".into(),
            CodecKind::Int8 => "int8".into(),
            CodecKind::TopK { k_fraction } => format!("topk:{k_fraction}"),
            CodecKind::RandomK { k_fraction } => format!("randk:{k_fraction}"),
            CodecKind::OneBit => "onebit".into(),
        }
    }
}

/// A sparsification `k` must be a real fraction: finite, `> 0` (k = 0
/// keeps nothing and would divide `nominal_ratio` by zero) and `<= 1`.
fn parse_k_fraction(s: &str) -> Option<f64> {
    let k: f64 = s.parse().ok()?;
    (k.is_finite() && k > 0.0 && k <= 1.0).then_some(k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trip() {
        for k in [
            CodecKind::Fp16,
            CodecKind::Int8,
            CodecKind::TopK { k_fraction: 0.01 },
            CodecKind::RandomK { k_fraction: 0.05 },
            CodecKind::OneBit,
        ] {
            assert_eq!(CodecKind::parse(&k.name()), Some(k));
        }
        assert_eq!(CodecKind::parse("nope"), None);
    }

    #[test]
    fn nominal_ratios() {
        assert_eq!(CodecKind::Fp16.nominal_ratio(), 2.0);
        assert_eq!(CodecKind::Int8.nominal_ratio(), 4.0);
        assert_eq!(CodecKind::OneBit.nominal_ratio(), 32.0);
        // topk 1% → 50× (value+index doubles the per-coordinate cost).
        assert!((CodecKind::TopK { k_fraction: 0.01 }.nominal_ratio() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn parse_rejects_degenerate_k_fractions() {
        for bad in [
            "topk:0", "randk:0", "topk:0.0", "topk:-0.1", "randk:-1", "topk:1.5", "randk:2",
            "topk:nan", "randk:nan", "topk:inf", "randk:-inf", "topk:", "randk:x",
        ] {
            assert_eq!(CodecKind::parse(bad), None, "{bad:?} should be rejected");
        }
    }

    #[test]
    fn parse_accepts_boundary_k_fractions() {
        assert_eq!(CodecKind::parse("randk:1"), Some(CodecKind::RandomK { k_fraction: 1.0 }));
        assert_eq!(CodecKind::parse("topk:0.5"), Some(CodecKind::TopK { k_fraction: 0.5 }));
        // k = 1e-9 is tiny but legal; the ratio stays finite.
        let k = CodecKind::parse("randk:0.000000001").unwrap();
        assert!(k.nominal_ratio().is_finite());
    }
}
