//! Experiment configuration: typed configs, paper presets, and a small
//! TOML-subset parser (`[section]`, `key = value`) so experiment files can
//! be versioned without a serde dependency.

pub mod parser;
pub mod spec;

pub use spec::FromSpec;

use crate::models::ModelId;
use std::fmt;

/// Which transport the communication phase runs over — the pivot of the
/// whole paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// Idealized transport that achieves 100% of provisioned bandwidth
    /// (the what-if §3.1 assumption).
    FullUtilization,
    /// Mechanistic kernel-TCP model calibrated to the paper's Fig 4
    /// utilization measurements — reproduces Horovod's "measured" series.
    /// `single` is an accepted alias: this *is* the single-stream path.
    KernelTcp,
    /// Real TCP sockets between local worker threads, shaped by a token
    /// bucket to the provisioned rate (the emulation path).
    Tcp,
    /// Multi-stream striped transport: kernel-TCP-class software
    /// pipelines × `streams` parallel connections (the §2.4 repair; see
    /// [`crate::net::striped`]).
    Striped { streams: usize },
}

impl TransportKind {
    /// Accepted spellings: `full`/`ideal`, `kernel-tcp`/`horovod`/
    /// `single`, `tcp`, `striped` (8 streams) or `striped:<n>`.
    ///
    /// Thin alias over [`FromSpec::match_spec`]; use
    /// [`FromSpec::from_spec`] when an actionable error is wanted instead
    /// of `None`.
    pub fn parse(s: &str) -> Option<TransportKind> {
        Self::match_spec(s).and_then(|r| r.ok())
    }
}

impl fmt::Display for TransportKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportKind::FullUtilization => f.write_str("full-utilization"),
            TransportKind::KernelTcp => f.write_str("kernel-tcp"),
            TransportKind::Tcp => f.write_str("tcp"),
            TransportKind::Striped { streams } => write!(f, "striped:{streams}"),
        }
    }
}

/// All-reduce algorithm.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CollectiveKind {
    /// Ring all-reduce: reduce-scatter + all-gather, `2S(N-1)/N` on the wire
    /// per worker — the paper's §3.1 model and Horovod/NCCL's algorithm.
    Ring,
    /// Binary-tree reduce + broadcast baseline (`2S·log2(N)`-ish critical path).
    Tree,
    /// Central parameter-server baseline (paper §4 "future work" strategy).
    ParameterServer,
    /// Two-phase leader-ring all-reduce (Sun et al., "ImageNet/AlexNet in
    /// 1.5 Minutes"): intra-group ring, inter-group ring among the group
    /// leaders, intra-group broadcast — the mechanism that keeps every
    /// tier of an oversubscribed network busy. See
    /// [`crate::collectives::hierarchical`].
    Hierarchical {
        /// Ranks per group (the last group may be smaller).
        group_size: usize,
    },
}

impl CollectiveKind {
    /// Accepted spellings: `ring`, `tree`, `ps`/`parameter-server`,
    /// `hier` (groups of 8) or `hier:<group_size>` /
    /// `hierarchical:<group_size>`.
    ///
    /// Thin alias over [`FromSpec::match_spec`]; use
    /// [`FromSpec::from_spec`] when an actionable error is wanted instead
    /// of `None`.
    pub fn parse(s: &str) -> Option<CollectiveKind> {
        Self::match_spec(s).and_then(|r| r.ok())
    }
}

impl fmt::Display for CollectiveKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CollectiveKind::Ring => f.write_str("ring"),
            CollectiveKind::Tree => f.write_str("tree"),
            CollectiveKind::ParameterServer => f.write_str("parameter-server"),
            CollectiveKind::Hierarchical { group_size } => write!(f, "hier:{group_size}"),
        }
    }
}

/// Whether gradient aggregation overlaps backward compute — the knob the
/// overlap scheduler ([`crate::sched`]) adds next to `--transport` and
/// `--collective`. `Off` is the serialized compute-then-all-reduce
/// baseline the paper measures against; `Buckets` flushes size-threshold
/// buckets into the async collective engine as backward layers complete.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum OverlapMode {
    /// Blocking: every bucket's all-reduce starts only after backward
    /// finishes. Same bucket decomposition and collective order as
    /// `Buckets`, so the two modes are bit-identical — only *when* the
    /// communication runs differs.
    Off,
    /// Overlapped: buckets are submitted to the background collective
    /// engine the moment their last layer's gradient is ready.
    #[default]
    Buckets,
}

impl OverlapMode {
    /// Accepted spellings: `off`/`blocking`/`none`, `buckets`/`on`.
    ///
    /// Thin alias over [`FromSpec::match_spec`].
    pub fn parse(s: &str) -> Option<OverlapMode> {
        Self::match_spec(s).and_then(|r| r.ok())
    }
}

impl fmt::Display for OverlapMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OverlapMode::Off => f.write_str("off"),
            OverlapMode::Buckets => f.write_str("buckets"),
        }
    }
}

/// Horovod-style gradient fusion ("tensor fusion") parameters. Paper §3.1:
/// "a timeout window of 5 ms and a gradients buffer size of 64 MB".
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FusionConfig {
    pub buffer_bytes: usize,
    pub timeout_s: f64,
}

impl Default for FusionConfig {
    fn default() -> Self {
        FusionConfig { buffer_bytes: 64 << 20, timeout_s: 5e-3 }
    }
}

/// Gradient compression applied before the wire (what-if §3.2 divides the
/// transit time by `ratio`; the real codecs live in [`crate::compress`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Compression {
    None,
    /// Pure what-if ratio (paper's simplification).
    Ratio(f64),
    /// Real codec identified by name; its measured ratio is used.
    Codec(crate::compress::CodecKind),
}

impl Compression {
    /// Effective wire-size divisor.
    pub fn ratio(&self) -> f64 {
        match self {
            Compression::None => 1.0,
            Compression::Ratio(r) => *r,
            Compression::Codec(c) => c.nominal_ratio(),
        }
    }

    /// Parse a compression spec: a plain wire-size ratio (`"1"`, `"4"`,
    /// `"2.5"`), a named codec accepted by [`crate::compress::CodecKind::parse`]
    /// (`"fp16"`, `"int8"`, `"onebit"`, `"topk:0.01"`, `"randk:0.05"`), or
    /// `"none"`. This is the one entry point every ratio-accepting flag
    /// and parameter goes through, so named codecs work anywhere a ratio
    /// does; the derived wire ratio must be >= 1.
    ///
    /// # Examples
    ///
    /// ```
    /// use netbn::config::Compression;
    ///
    /// // A plain ratio divides wire bytes directly.
    /// assert_eq!(Compression::parse("4").unwrap().ratio(), 4.0);
    /// // Named codecs resolve through their nominal wire ratio.
    /// assert_eq!(Compression::parse("fp16").unwrap().ratio(), 2.0);
    /// // top-k ships (value, index) pairs: keeping 1% costs ~1/50th.
    /// let topk = Compression::parse("topk:0.01").unwrap();
    /// assert!((topk.ratio() - 50.0).abs() < 1e-9);
    /// // Degenerate specs are rejected at parse time, never clamped.
    /// assert!(Compression::parse("topk:0").is_err());
    /// assert!(Compression::parse("0.5").is_err());
    /// ```
    ///
    /// Thin alias over [`FromSpec::from_spec`] (this type's only
    /// `Result`-returning entry point already carried the actionable
    /// error, so the alias preserves the `Result` shape).
    pub fn parse(s: &str) -> crate::Result<Compression> {
        Self::from_spec(s)
    }
}

impl fmt::Display for Compression {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Compression::None => f.write_str("none"),
            Compression::Ratio(r) => write!(f, "{r}x"),
            Compression::Codec(c) => f.write_str(&c.name()),
        }
    }
}

/// Online autotuning on the emulated trainer (see [`crate::tune`]): when
/// enabled, worker 0 runs the warmup→probe→exploit controller over the
/// axes the emulator can reconfigure per step — bucket threshold and
/// compression — and every worker applies the shared knob decision at the
/// next step boundary. The other three knob axes (stripes, chunk,
/// collective) are frozen at the config's values: the emulated fabric and
/// collective engine are built once per run.
#[derive(Clone, Debug, PartialEq)]
pub struct AutotuneConfig {
    pub enabled: bool,
    /// Candidate bucket thresholds, MB (all > 0; the trainer additionally
    /// keeps the config's own `bucket_mb` — including `0`, the
    /// fusion-buffer timeline — as a candidate, so the configured
    /// operating point is always exactly representable).
    pub bucket_mbs: Vec<f64>,
    /// Candidate compression settings.
    pub compressions: Vec<Compression>,
}

impl Default for AutotuneConfig {
    fn default() -> Self {
        AutotuneConfig {
            enabled: false,
            bucket_mbs: vec![1.0, 4.0, 16.0, 64.0],
            compressions: vec![Compression::None, Compression::Ratio(4.0)],
        }
    }
}

impl AutotuneConfig {
    /// Invariants checked by [`ExperimentConfig::validate`] when enabled.
    fn errors(&self) -> Vec<String> {
        let mut errs = Vec::new();
        if self.bucket_mbs.is_empty() {
            errs.push("autotune.bucket_mbs must be non-empty".into());
        }
        for &mb in &self.bucket_mbs {
            if !(mb.is_finite() && mb > 0.0) {
                errs.push(format!("autotune bucket_mb {mb} must be > 0 and finite"));
            }
        }
        if self.compressions.is_empty() {
            errs.push("autotune.compressions must be non-empty".into());
        }
        for c in &self.compressions {
            let r = c.ratio();
            if !(r.is_finite() && r >= 1.0) {
                errs.push(format!("autotune compression ratio {r} must be >= 1"));
            }
        }
        errs
    }
}

/// One experiment: a (model, cluster, network, algorithm) point.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub model: ModelId,
    /// Number of servers; each has `gpus_per_server` workers.
    pub servers: usize,
    /// GPUs per server (p3dn.24xlarge → 8).
    pub gpus_per_server: usize,
    /// Per-worker batch size (paper fixes 32).
    pub batch_per_worker: usize,
    /// Provisioned inter-server bandwidth in Gbps.
    pub bandwidth_gbps: f64,
    pub transport: TransportKind,
    pub collective: CollectiveKind,
    /// Compute/communication overlap policy (see [`crate::sched`]).
    pub overlap: OverlapMode,
    /// Bucketizer size threshold in MB. `<= 0` keeps the paper's fusion
    /// buffer (64 MB / 5 ms) as the bucket source; `> 0` switches to the
    /// DDP-style reverse-order size-threshold bucketizer
    /// ([`crate::sched::bucket`]).
    pub bucket_mb: f64,
    pub fusion: FusionConfig,
    pub compression: Compression,
    /// Online autotuning of the per-step knobs (emulated trainer).
    pub autotune: AutotuneConfig,
    /// Measured steps (after warmup).
    pub steps: usize,
    pub warmup_steps: usize,
    pub seed: u64,
    /// Bound on the launch paths' worker rendezvous, seconds (`netbn
    /// launch --rendezvous-timeout`; also each elastic membership-epoch
    /// formation).
    pub rendezvous_timeout_s: f64,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            model: ModelId::ResNet50,
            servers: 2,
            gpus_per_server: 8,
            batch_per_worker: 32,
            bandwidth_gbps: 100.0,
            transport: TransportKind::KernelTcp,
            collective: CollectiveKind::Ring,
            overlap: OverlapMode::Buckets,
            bucket_mb: 0.0,
            fusion: FusionConfig::default(),
            compression: Compression::None,
            autotune: AutotuneConfig::default(),
            steps: 30,
            warmup_steps: 5,
            seed: 0x5eed,
            rendezvous_timeout_s: 60.0,
        }
    }
}

impl ExperimentConfig {
    /// Total workers in the cluster.
    pub fn workers(&self) -> usize {
        self.servers * self.gpus_per_server
    }

    /// The paper's hardware preset: p3dn.24xlarge (8×V100, 100 Gbps).
    pub fn p3dn(model: ModelId, servers: usize) -> ExperimentConfig {
        ExperimentConfig { model, servers, ..Default::default() }
    }

    /// Validate invariants; returns a human-readable error list.
    pub fn validate(&self) -> Result<(), Vec<String>> {
        let mut errs = Vec::new();
        if self.servers == 0 {
            errs.push("servers must be >= 1".into());
        }
        if self.gpus_per_server == 0 {
            errs.push("gpus_per_server must be >= 1".into());
        }
        if self.bandwidth_gbps <= 0.0 {
            errs.push("bandwidth_gbps must be > 0".into());
        }
        if self.fusion.buffer_bytes == 0 {
            errs.push("fusion.buffer_bytes must be > 0".into());
        }
        if self.fusion.timeout_s < 0.0 {
            errs.push("fusion.timeout_s must be >= 0".into());
        }
        if let TransportKind::Striped { streams } = self.transport {
            if !(1..=256).contains(&streams) {
                errs.push("striped transport streams must be in 1..=256".into());
            }
        }
        if let CollectiveKind::Hierarchical { group_size } = self.collective {
            if group_size == 0 {
                errs.push("hierarchical collective group_size must be >= 1".into());
            }
        }
        if !self.bucket_mb.is_finite() {
            errs.push("bucket_mb must be finite (0 = fusion-buffer bucketing)".into());
        }
        let ratio = self.compression.ratio();
        if !ratio.is_finite() || ratio < 1.0 {
            errs.push("compression ratio must be finite and >= 1".into());
        }
        if self.autotune.enabled {
            errs.extend(self.autotune.errors());
        }
        if self.steps == 0 {
            errs.push("steps must be >= 1".into());
        }
        if !(self.rendezvous_timeout_s.is_finite() && self.rendezvous_timeout_s > 0.0) {
            errs.push("rendezvous_timeout_s must be finite and > 0".into());
        }
        if errs.is_empty() {
            Ok(())
        } else {
            Err(errs)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid_and_paper_shaped() {
        let c = ExperimentConfig::default();
        c.validate().unwrap();
        assert_eq!(c.gpus_per_server, 8);
        assert_eq!(c.batch_per_worker, 32);
        assert_eq!(c.fusion.buffer_bytes, 64 << 20);
        assert!((c.fusion.timeout_s - 5e-3).abs() < 1e-12);
    }

    #[test]
    fn workers_product() {
        let c = ExperimentConfig::p3dn(ModelId::Vgg16, 8);
        assert_eq!(c.workers(), 64);
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut c = ExperimentConfig::default();
        c.servers = 0;
        c.bandwidth_gbps = -1.0;
        let errs = c.validate().unwrap_err();
        assert_eq!(errs.len(), 2);
    }

    #[test]
    fn transport_parse() {
        assert_eq!(TransportKind::parse("ideal"), Some(TransportKind::FullUtilization));
        assert_eq!(TransportKind::parse("horovod"), Some(TransportKind::KernelTcp));
        assert_eq!(TransportKind::parse("nope"), None);
    }

    #[test]
    fn transport_parse_striped_and_single() {
        // `single` is the kernel-TCP path by another name; `striped:N`
        // is the repaired multi-connection transport.
        assert_eq!(TransportKind::parse("single"), Some(TransportKind::KernelTcp));
        assert_eq!(TransportKind::parse("striped"), Some(TransportKind::Striped { streams: 8 }));
        assert_eq!(
            TransportKind::parse("striped:16"),
            Some(TransportKind::Striped { streams: 16 })
        );
        assert_eq!(TransportKind::parse("striped:0"), None);
        assert_eq!(TransportKind::parse("striped:1000"), None);
        assert_eq!(TransportKind::parse("striped:x"), None);
        assert_eq!(TransportKind::Striped { streams: 4 }.to_string(), "striped:4");
    }

    #[test]
    fn collective_parse_hierarchical() {
        assert_eq!(CollectiveKind::parse("ring"), Some(CollectiveKind::Ring));
        assert_eq!(
            CollectiveKind::parse("hier"),
            Some(CollectiveKind::Hierarchical { group_size: 8 })
        );
        assert_eq!(
            CollectiveKind::parse("hier:4"),
            Some(CollectiveKind::Hierarchical { group_size: 4 })
        );
        assert_eq!(
            CollectiveKind::parse("hierarchical:2"),
            Some(CollectiveKind::Hierarchical { group_size: 2 })
        );
        assert_eq!(CollectiveKind::parse("hier:0"), None);
        assert_eq!(CollectiveKind::parse("hier:x"), None);
        assert_eq!(
            CollectiveKind::Hierarchical { group_size: 4 }.to_string(),
            "hier:4"
        );
    }

    #[test]
    fn overlap_parse_and_display() {
        assert_eq!(OverlapMode::parse("off"), Some(OverlapMode::Off));
        assert_eq!(OverlapMode::parse("blocking"), Some(OverlapMode::Off));
        assert_eq!(OverlapMode::parse("Buckets"), Some(OverlapMode::Buckets));
        assert_eq!(OverlapMode::parse("on"), Some(OverlapMode::Buckets));
        assert_eq!(OverlapMode::parse("pipelined"), None);
        assert_eq!(OverlapMode::Off.to_string(), "off");
        assert_eq!(OverlapMode::Buckets.to_string(), "buckets");
        assert_eq!(OverlapMode::default(), OverlapMode::Buckets);
    }

    #[test]
    fn validation_rejects_non_finite_bucket_mb() {
        let mut c = ExperimentConfig::default();
        c.bucket_mb = f64::NAN;
        assert!(c.validate().is_err());
        c.bucket_mb = 0.0;
        c.validate().unwrap();
        c.bucket_mb = 25.0;
        c.validate().unwrap();
    }

    #[test]
    fn autotune_defaults_off_and_validates_when_on() {
        let mut c = ExperimentConfig::default();
        assert!(!c.autotune.enabled);
        c.validate().unwrap();
        c.autotune.enabled = true;
        c.validate().unwrap();
        c.autotune.bucket_mbs = vec![0.0];
        assert!(c.validate().is_err(), "zero bucket candidates must be rejected");
        c.autotune.bucket_mbs = vec![4.0];
        c.autotune.compressions = vec![];
        assert!(c.validate().is_err());
        // Disabled autotune never blocks validation, whatever it holds.
        c.autotune.enabled = false;
        c.validate().unwrap();
    }

    #[test]
    fn validation_rejects_bad_rendezvous_timeout() {
        let mut c = ExperimentConfig::default();
        assert_eq!(c.rendezvous_timeout_s, 60.0);
        c.rendezvous_timeout_s = 0.0;
        assert!(c.validate().is_err());
        c.rendezvous_timeout_s = f64::NAN;
        assert!(c.validate().is_err());
        c.rendezvous_timeout_s = 0.5;
        c.validate().unwrap();
    }

    #[test]
    fn compression_ratio() {
        assert_eq!(Compression::None.ratio(), 1.0);
        assert_eq!(Compression::Ratio(5.0).ratio(), 5.0);
    }

    #[test]
    fn compression_parse_accepts_ratios_and_codecs() {
        assert_eq!(Compression::parse("none").unwrap(), Compression::None);
        assert_eq!(Compression::parse("1").unwrap(), Compression::None);
        assert_eq!(Compression::parse("4").unwrap(), Compression::Ratio(4.0));
        assert_eq!(Compression::parse(" 2.5 ").unwrap(), Compression::Ratio(2.5));
        assert_eq!(
            Compression::parse("fp16").unwrap(),
            Compression::Codec(crate::compress::CodecKind::Fp16)
        );
        assert!((Compression::parse("topk:0.01").unwrap().ratio() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn compression_parse_rejects_bad_specs() {
        for bad in ["0", "0.5", "-3", "nan", "inf", "topk:0", "randk:2", "bogus", "topk:0.9"] {
            assert!(Compression::parse(bad).is_err(), "{bad:?} should be rejected");
        }
    }
}
