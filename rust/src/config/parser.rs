//! TOML-subset parser for experiment files. Supports:
//!
//! * `[section]` headers (one level),
//! * `key = value` with string (`"..."`), bool, integer, float values,
//! * `#` comments and blank lines.
//!
//! This deliberately covers only what our config files need — it is a
//! substrate standing in for `toml`+`serde` in the offline build.

use super::{Compression, ExperimentConfig, FusionConfig, OverlapMode, TransportKind};
use crate::config::CollectiveKind;
use crate::models::ModelId;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parsed document: `section.key -> value`; top-level keys use section `""`.
pub type Doc = BTreeMap<String, Value>;

/// Parse TOML-subset text into a flat `section.key` map.
pub fn parse(text: &str) -> Result<Doc> {
    let mut doc = Doc::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| anyhow!("line {}: unterminated section header", lineno + 1))?
                .trim();
            if name.is_empty() {
                bail!("line {}: empty section name", lineno + 1);
            }
            section = name.to_string();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| anyhow!("line {}: expected `key = value`", lineno + 1))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            bail!("line {}: empty key", lineno + 1);
        }
        let val = parse_value(line[eq + 1..].trim())
            .with_context(|| format!("line {}: bad value", lineno + 1))?;
        let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        doc.insert(full, val);
    }
    Ok(doc)
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a string literal.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or_else(|| anyhow!("unterminated string"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    // Integers may use `_` separators like TOML.
    let cleaned: String = s.chars().filter(|c| *c != '_').collect();
    if let Ok(i) = cleaned.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = cleaned.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value: {s:?}")
}

/// Build an [`ExperimentConfig`] from a parsed doc, starting from defaults.
/// Recognized keys (all optional):
///
/// ```toml
/// model = "vgg16"            # resnet50 | resnet101 | vgg16 | transformer
/// servers = 4
/// gpus_per_server = 8
/// batch_per_worker = 32
/// bandwidth_gbps = 100.0
/// transport = "kernel-tcp"   # full | kernel-tcp | tcp | single | striped:N
/// collective = "ring"        # ring | tree | ps
/// overlap = "buckets"        # off | buckets
/// bucket_mb = 25.0           # 0 = fusion-buffer bucketing
/// steps = 30
/// warmup_steps = 5
/// seed = 1234
/// rendezvous_timeout_s = 60.0  # launch worker-registration deadline
/// autotune = true             # or [autotune] enabled = true
/// [autotune]
/// enabled = true
/// bucket_mbs = "1,4,16,64"
/// compressions = "none,fp16,4"  # any ratio-or-codec spec
/// [fusion]
/// buffer_mb = 64
/// timeout_ms = 5.0
/// [compression]
/// ratio = 4.0                # or codec = "int8"
/// ```
pub fn experiment_from_doc(doc: &Doc) -> Result<ExperimentConfig> {
    let mut c = ExperimentConfig::default();
    for (key, val) in doc {
        match key.as_str() {
            "model" => {
                let s = val.as_str().ok_or_else(|| anyhow!("model must be a string"))?;
                c.model = ModelId::parse(s).ok_or_else(|| anyhow!("unknown model {s:?}"))?;
            }
            "servers" => c.servers = get_usize(val, key)?,
            "gpus_per_server" => c.gpus_per_server = get_usize(val, key)?,
            "batch_per_worker" => c.batch_per_worker = get_usize(val, key)?,
            "bandwidth_gbps" => {
                c.bandwidth_gbps = val.as_f64().ok_or_else(|| anyhow!("{key} must be numeric"))?
            }
            "transport" => {
                let s = val.as_str().ok_or_else(|| anyhow!("transport must be a string"))?;
                c.transport =
                    TransportKind::parse(s).ok_or_else(|| anyhow!("unknown transport {s:?}"))?;
            }
            "collective" => {
                let s = val.as_str().ok_or_else(|| anyhow!("collective must be a string"))?;
                c.collective =
                    CollectiveKind::parse(s).ok_or_else(|| anyhow!("unknown collective {s:?}"))?;
            }
            "overlap" => {
                let s = val.as_str().ok_or_else(|| anyhow!("overlap must be a string"))?;
                c.overlap =
                    OverlapMode::parse(s).ok_or_else(|| anyhow!("unknown overlap mode {s:?}"))?;
            }
            "bucket_mb" => c.bucket_mb = get_f64(val, key)?,
            "autotune" | "autotune.enabled" => {
                c.autotune.enabled =
                    val.as_bool().ok_or_else(|| anyhow!("{key} must be a bool"))?
            }
            "autotune.bucket_mbs" => {
                let s = val.as_str().ok_or_else(|| {
                    anyhow!("{key} must be a string of comma-separated MB values")
                })?;
                c.autotune.bucket_mbs = s
                    .split(',')
                    .map(|p| {
                        p.trim()
                            .parse::<f64>()
                            .map_err(|_| anyhow!("{key}: bad MB value {p:?}"))
                    })
                    .collect::<Result<_>>()?;
            }
            "autotune.compressions" => {
                // Reuses the one ratio-or-codec entry point, so every
                // codec spelling works here too.
                let s = val.as_str().ok_or_else(|| {
                    anyhow!("{key} must be a string of comma-separated compression specs")
                })?;
                c.autotune.compressions = s
                    .split(',')
                    .map(|p| Compression::parse(p.trim()))
                    .collect::<Result<_>>()?;
            }
            "steps" => c.steps = get_usize(val, key)?,
            "warmup_steps" => c.warmup_steps = get_usize(val, key)?,
            "seed" => c.seed = get_usize(val, key)? as u64,
            "rendezvous_timeout_s" => c.rendezvous_timeout_s = get_f64(val, key)?,
            "fusion.buffer_mb" => {
                c.fusion = FusionConfig {
                    buffer_bytes: (get_f64(val, key)? * 1e6) as usize,
                    ..c.fusion
                }
            }
            "fusion.timeout_ms" => {
                c.fusion = FusionConfig { timeout_s: get_f64(val, key)? * 1e-3, ..c.fusion }
            }
            "compression.ratio" => c.compression = Compression::Ratio(get_f64(val, key)?),
            "compression.codec" => {
                let s = val.as_str().ok_or_else(|| anyhow!("codec must be a string"))?;
                let kind = crate::compress::CodecKind::parse(s)
                    .ok_or_else(|| anyhow!("unknown codec {s:?}"))?;
                c.compression = Compression::Codec(kind);
            }
            other => bail!("unknown config key {other:?}"),
        }
    }
    c.validate().map_err(|errs| anyhow!("invalid config: {}", errs.join("; ")))?;
    Ok(c)
}

/// Parse an experiment config straight from TOML-subset text.
pub fn experiment_from_str(text: &str) -> Result<ExperimentConfig> {
    experiment_from_doc(&parse(text)?)
}

fn get_usize(v: &Value, key: &str) -> Result<usize> {
    let i = v.as_i64().ok_or_else(|| anyhow!("{key} must be an integer"))?;
    if i < 0 {
        bail!("{key} must be non-negative");
    }
    Ok(i as usize)
}

fn get_f64(v: &Value, key: &str) -> Result<f64> {
    v.as_f64().ok_or_else(|| anyhow!("{key} must be numeric"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_sections_comments() {
        let doc = parse(
            r#"
# top comment
model = "vgg16"   # trailing
servers = 4
bandwidth_gbps = 25.0
flag = true
[fusion]
buffer_mb = 32
timeout_ms = 2.5
"#,
        )
        .unwrap();
        assert_eq!(doc["model"], Value::Str("vgg16".into()));
        assert_eq!(doc["servers"], Value::Int(4));
        assert_eq!(doc["bandwidth_gbps"], Value::Float(25.0));
        assert_eq!(doc["flag"], Value::Bool(true));
        assert_eq!(doc["fusion.buffer_mb"], Value::Int(32));
        assert_eq!(doc["fusion.timeout_ms"], Value::Float(2.5));
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = parse(r##"name = "a#b""##).unwrap();
        assert_eq!(doc["name"], Value::Str("a#b".into()));
    }

    #[test]
    fn full_experiment_round_trip() {
        let c = experiment_from_str(
            r#"
model = "resnet101"
servers = 8
bandwidth_gbps = 10
transport = "full"
collective = "tree"
overlap = "off"
bucket_mb = 25.0
[fusion]
buffer_mb = 64
timeout_ms = 5.0
[compression]
ratio = 4.0
"#,
        )
        .unwrap();
        assert_eq!(c.model, ModelId::ResNet101);
        assert_eq!(c.servers, 8);
        assert_eq!(c.bandwidth_gbps, 10.0);
        assert_eq!(c.transport, TransportKind::FullUtilization);
        assert_eq!(c.collective, CollectiveKind::Tree);
        assert_eq!(c.overlap, OverlapMode::Off);
        assert_eq!(c.bucket_mb, 25.0);
        assert_eq!(c.compression.ratio(), 4.0);
    }

    #[test]
    fn unknown_key_is_an_error() {
        assert!(experiment_from_str("bogus = 1").is_err());
    }

    #[test]
    fn autotune_keys_parse() {
        let c = experiment_from_str(
            r#"
autotune = true
[autotune]
bucket_mbs = "2,8,32"
compressions = "none,fp16,4"
"#,
        )
        .unwrap();
        assert!(c.autotune.enabled);
        assert_eq!(c.autotune.bucket_mbs, vec![2.0, 8.0, 32.0]);
        assert_eq!(c.autotune.compressions.len(), 3);
        assert_eq!(c.autotune.compressions[1].ratio(), 2.0); // fp16 via CodecKind
        assert_eq!(c.autotune.compressions[2].ratio(), 4.0);

        // The section spelling alone also enables it.
        let c = experiment_from_str("[autotune]\nenabled = true").unwrap();
        assert!(c.autotune.enabled);

        // Bad values fail through the shared parsers, with validation on
        // top (a 0 MB candidate passes parsing but fails validate()).
        assert!(experiment_from_str("[autotune]\ncompressions = \"topk:0\"").is_err());
        assert!(experiment_from_str("autotune = 1").is_err());
        assert!(
            experiment_from_str("[autotune]\nenabled = true\nbucket_mbs = \"0\"").is_err()
        );
    }

    #[test]
    fn rendezvous_timeout_parses_and_validates() {
        let c = experiment_from_str("rendezvous_timeout_s = 7.5").unwrap();
        assert_eq!(c.rendezvous_timeout_s, 7.5);
        // Integers coerce like every other float key.
        let c = experiment_from_str("rendezvous_timeout_s = 10").unwrap();
        assert_eq!(c.rendezvous_timeout_s, 10.0);
        // Zero and strings are rejected (validation and type check).
        assert!(experiment_from_str("rendezvous_timeout_s = 0").is_err());
        assert!(experiment_from_str("rendezvous_timeout_s = \"fast\"").is_err());
    }

    #[test]
    fn bad_section_reports_line() {
        let err = parse("[oops").unwrap_err().to_string();
        assert!(err.contains("line 1"), "{err}");
    }

    #[test]
    fn invalid_config_rejected() {
        assert!(experiment_from_str("servers = 0").is_err());
    }

    #[test]
    fn underscore_separators() {
        let doc = parse("n = 1_000_000").unwrap();
        assert_eq!(doc["n"], Value::Int(1_000_000));
    }
}
