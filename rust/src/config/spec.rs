//! [`FromSpec`] — the one trait behind every textual spec parser.
//!
//! The CLI, the TOML subset, and knob strings all accept short textual
//! specs: `--transport striped:8`, `collective = "hier:4"`,
//! `compression=topk:0.01`. Each spec-accepting type used to carry an
//! ad-hoc `parse` with its own error wording; they now all implement
//! [`FromSpec`], so the recognizer logic lives in exactly one place per
//! type and the unknown-value error has the same shape everywhere:
//!
//! ```text
//! unknown <kind> "<spec>"; valid values: <list>
//! ```
//!
//! The old entry points ([`TransportKind::parse`],
//! [`CollectiveKind::parse`], [`OverlapMode::parse`],
//! [`Compression::parse`], [`crate::tune::KnobPoint::parse_spec`]) remain
//! as thin aliases over the trait, so every CLI flag, TOML key, and knob
//! string accepts and rejects exactly the specs it did before.

use super::{CollectiveKind, Compression, OverlapMode, TransportKind};
use crate::Result;

/// A type constructible from a short textual spec (a CLI flag value, a
/// TOML string, or a knob value).
///
/// Implementors provide [`FromSpec::match_spec`], which distinguishes
/// *unrecognized* spellings (`None` — [`FromSpec::from_spec`] turns that
/// into the shared `unknown ...; valid values: ...` error) from
/// *recognized but invalid* ones (`Some(Err(..))` — a specific error says
/// which constraint failed, e.g. `striped:0`'s stream range).
pub trait FromSpec: Sized {
    /// Human name of the kind, used in the shared unknown-value error.
    const KIND: &'static str;
    /// The valid spellings, listed verbatim in the shared error.
    const VALID: &'static str;

    /// Recognize and parse `s`.
    fn match_spec(s: &str) -> Option<Result<Self>>;

    /// Parse `s`, failing with the shared error format when the spelling
    /// is not recognized: `unknown <KIND> "<s>"; valid values: <VALID>`.
    fn from_spec(s: &str) -> Result<Self> {
        Self::match_spec(s).unwrap_or_else(|| {
            Err(anyhow::anyhow!(
                "unknown {} {s:?}; valid values: {}",
                Self::KIND,
                Self::VALID
            ))
        })
    }
}

impl FromSpec for TransportKind {
    const KIND: &'static str = "transport";
    const VALID: &'static str = "full | full-utilization | ideal | kernel-tcp | horovod | single \
                                 | tcp | emulated | striped | striped:<1..=256>";

    fn match_spec(s: &str) -> Option<Result<TransportKind>> {
        let lower = s.to_ascii_lowercase();
        match lower.as_str() {
            "full" | "full-utilization" | "ideal" => {
                return Some(Ok(TransportKind::FullUtilization))
            }
            "kernel-tcp" | "kernel_tcp" | "horovod" | "single" => {
                return Some(Ok(TransportKind::KernelTcp))
            }
            "tcp" | "emulated" => return Some(Ok(TransportKind::Tcp)),
            "striped" => return Some(Ok(TransportKind::Striped { streams: 8 })),
            _ => {}
        }
        let rest = lower.strip_prefix("striped:")?;
        Some(match rest.parse::<usize>() {
            Ok(n) if (1..=256).contains(&n) => Ok(TransportKind::Striped { streams: n }),
            Ok(n) => Err(anyhow::anyhow!("striped transport streams must be in 1..=256, got {n}")),
            Err(_) => Err(anyhow::anyhow!(
                "striped transport stream count must be an integer, got {rest:?}"
            )),
        })
    }
}

impl FromSpec for CollectiveKind {
    const KIND: &'static str = "collective";
    const VALID: &'static str = "ring | tree | ps | parameter-server | hier | hierarchical \
                                 | hier:<1..=4096> | hierarchical:<1..=4096>";

    fn match_spec(s: &str) -> Option<Result<CollectiveKind>> {
        let lower = s.to_ascii_lowercase();
        match lower.as_str() {
            "ring" => return Some(Ok(CollectiveKind::Ring)),
            "tree" => return Some(Ok(CollectiveKind::Tree)),
            "ps" | "parameter-server" => return Some(Ok(CollectiveKind::ParameterServer)),
            "hier" | "hierarchical" => {
                return Some(Ok(CollectiveKind::Hierarchical { group_size: 8 }))
            }
            _ => {}
        }
        let rest = lower.strip_prefix("hier:").or_else(|| lower.strip_prefix("hierarchical:"))?;
        Some(match rest.parse::<usize>() {
            Ok(g) if (1..=4096).contains(&g) => Ok(CollectiveKind::Hierarchical { group_size: g }),
            Ok(g) => Err(anyhow::anyhow!(
                "hierarchical collective group size must be in 1..=4096, got {g}"
            )),
            Err(_) => Err(anyhow::anyhow!(
                "hierarchical collective group size must be an integer, got {rest:?}"
            )),
        })
    }
}

impl FromSpec for OverlapMode {
    const KIND: &'static str = "overlap mode";
    const VALID: &'static str = "off | blocking | none | buckets | on | bucketized";

    fn match_spec(s: &str) -> Option<Result<OverlapMode>> {
        match s.to_ascii_lowercase().as_str() {
            "off" | "blocking" | "none" => Some(Ok(OverlapMode::Off)),
            "buckets" | "on" | "bucketized" => Some(Ok(OverlapMode::Buckets)),
            _ => None,
        }
    }
}

impl FromSpec for Compression {
    const KIND: &'static str = "compression";
    const VALID: &'static str =
        "a ratio >= 1, \"none\", or a codec (fp16 | int8 | onebit | topk:<k> | randk:<k>)";

    fn match_spec(s: &str) -> Option<Result<Compression>> {
        let t = s.trim();
        if t.is_empty() || t.eq_ignore_ascii_case("none") {
            return Some(Ok(Compression::None));
        }
        if let Ok(r) = t.parse::<f64>() {
            return Some(if r.is_finite() && r >= 1.0 {
                Ok(if r == 1.0 { Compression::None } else { Compression::Ratio(r) })
            } else {
                Err(anyhow::anyhow!("compression ratio must be finite and >= 1, got {t:?}"))
            });
        }
        let kind = crate::compress::CodecKind::parse(t)?;
        let c = Compression::Codec(kind);
        Some(if c.ratio() >= 1.0 {
            Ok(c)
        } else {
            Err(anyhow::anyhow!(
                "codec {t:?} has wire ratio {:.3} < 1 (value+index doubling would inflate \
                 traffic); pick topk k <= 0.5",
                c.ratio()
            ))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_specs_share_one_error_shape() {
        let e = TransportKind::from_spec("warp").unwrap_err().to_string();
        assert!(e.contains("unknown transport \"warp\""), "{e}");
        assert!(e.contains("valid values:") && e.contains("striped"), "{e}");
        let e = CollectiveKind::from_spec("butterfly").unwrap_err().to_string();
        assert!(e.contains("unknown collective") && e.contains("ring"), "{e}");
        let e = OverlapMode::from_spec("pipelined").unwrap_err().to_string();
        assert!(e.contains("unknown overlap mode") && e.contains("buckets"), "{e}");
        let e = Compression::from_spec("bogus").unwrap_err().to_string();
        assert!(e.contains("unknown compression") && e.contains("fp16"), "{e}");
    }

    #[test]
    fn recognized_but_invalid_specs_get_specific_errors() {
        let e = TransportKind::from_spec("striped:0").unwrap_err().to_string();
        assert!(e.contains("1..=256"), "{e}");
        let e = TransportKind::from_spec("striped:x").unwrap_err().to_string();
        assert!(e.contains("integer"), "{e}");
        let e = CollectiveKind::from_spec("hier:5000").unwrap_err().to_string();
        assert!(e.contains("1..=4096"), "{e}");
        let e = Compression::from_spec("0.5").unwrap_err().to_string();
        assert!(e.contains(">= 1"), "{e}");
        let e = Compression::from_spec("topk:0.9").unwrap_err().to_string();
        assert!(e.contains("wire ratio"), "{e}");
    }

    #[test]
    fn from_spec_agrees_with_legacy_parse() {
        // The `parse` aliases must accept/reject exactly what the trait
        // does — they are the compatibility contract for every CLI flag
        // and TOML key.
        for s in ["ideal", "single", "tcp", "striped", "striped:16", "striped:0", "nope", ""] {
            assert_eq!(TransportKind::parse(s), TransportKind::from_spec(s).ok(), "{s:?}");
        }
        for s in ["ring", "tree", "ps", "hier", "hier:4", "hierarchical:2", "hier:0", "nope"] {
            assert_eq!(CollectiveKind::parse(s), CollectiveKind::from_spec(s).ok(), "{s:?}");
        }
        for s in ["off", "blocking", "none", "buckets", "on", "bucketized", "nope"] {
            assert_eq!(OverlapMode::parse(s), OverlapMode::from_spec(s).ok(), "{s:?}");
        }
        for s in ["none", "1", "4", "fp16", "topk:0.01", "topk:0", "0.5", "bogus"] {
            let a = Compression::parse(s).ok();
            let b = Compression::from_spec(s).ok();
            assert_eq!(a, b, "{s:?}");
        }
    }
}
