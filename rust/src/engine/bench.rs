//! The perf-regression **bench gate**.
//!
//! `netbn bench` runs the throughput-bearing scenarios
//! (`transport_ablation`, `hier_vs_flat`), extracts their
//! effective-throughput metrics into a flat `name -> value` report, and
//! compares it against a committed baseline (`bench/baseline.json`) with
//! a fractional tolerance: any gated metric falling more than
//! `tolerance` below its baseline fails the gate, with a delta table
//! naming the regressed metrics. CI runs exactly this
//! (`netbn bench --json BENCH_ci.json --compare bench/baseline.json`),
//! and the same command reproduces the check locally.
//!
//! The baseline format is deliberately minimal — one flat JSON object of
//! `"metric": number` pairs, written by [`BenchReport::to_json`] and
//! parsed back by [`parse_flat_json`] (no serde in the offline build).
//! Metrics *above* baseline don't fail the gate; a sustained improvement
//! shows up in the delta table as a reminder to re-baseline.
//!
//! The CLI path ([`collect_with_e2e`]) additionally reports
//! `e2e.busbw_gbps` from a short real `netbn launch` run as an
//! **informational** metric: it rides in the JSON report so its run-to-run
//! variance can be characterized, but it is not in `GATED` or the
//! baseline, so it can never fail the gate.

use super::registry::ScenarioRegistry;
use crate::report::{json_str, Table};
use crate::Result;
use std::fmt::Write as _;

/// Which metrics of which scenario the gate tracks. Effective-throughput
/// fields only: these move when a transport or collective change alters
/// delivered bandwidth, and stay put under refactors.
const GATED: &[(&str, &[&str])] = &[
    ("transport_ablation", &["single_effective_gbps", "effective_gbps@8", "speedup@8"]),
    ("hier_vs_flat", &["flat_bus_gbps", "hier_bus_gbps", "hier_speedup"]),
];

/// A collected benchmark run: flat `scenario.metric -> value`.
#[derive(Clone, Debug)]
pub struct BenchReport {
    pub metrics: Vec<(String, f64)>,
}

impl BenchReport {
    /// Render as a human table.
    pub fn render(&self) -> String {
        let mut t = Table::new("bench metrics", &["metric", "value"]);
        for (k, v) in &self.metrics {
            t.row(vec![k.clone(), format!("{v:.4}")]);
        }
        t.render()
    }

    /// Flat JSON object, keys in collection order.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n");
        for (i, (k, v)) in self.metrics.iter().enumerate() {
            let _ = write!(s, "  {}: {v}", json_str(k));
            if i + 1 < self.metrics.len() {
                s.push(',');
            }
            s.push('\n');
        }
        s.push_str("}\n");
        s
    }
}

/// Run the gated scenarios (with default parameters — the baseline's
/// contract) and collect their throughput metrics.
pub fn collect(registry: &ScenarioRegistry) -> Result<BenchReport> {
    let mut metrics = Vec::new();
    for &(scenario, keys) in GATED {
        let out = registry.get(scenario)?.run(&[])?;
        anyhow::ensure!(
            out.passed(),
            "bench scenario {scenario} failed its own shape checks"
        );
        for &key in keys {
            let v = out.metric_value(key).ok_or_else(|| {
                anyhow::anyhow!("bench scenario {scenario} no longer emits metric {key:?}")
            })?;
            metrics.push((format!("{scenario}.{key}"), v));
        }
    }
    Ok(BenchReport { metrics })
}

/// [`collect`], plus `e2e.busbw_gbps` from one default run of the
/// registered `e2e_tcp_smoke` scenario (thread-spawned workers, striped
/// lanes, hier collective over real loopback TCP — exactly the smoke
/// CI already exercises, so there is a single definition of "the short
/// e2e run"). **Informational, never gated**: the metric is deliberately
/// absent from `GATED` and from `bench/baseline.json`, so [`compare`]
/// lists it under "not in baseline" — the point is to accumulate
/// variance data across CI runs before any gate is attached (PR 3
/// follow-up).
pub fn collect_with_e2e(registry: &ScenarioRegistry) -> Result<BenchReport> {
    let mut report = collect(registry)?;
    // Informational means informational: a flaky loopback launch must
    // degrade to a missing ride-along metric, never fail the gate.
    match e2e_busbw_gbps(registry) {
        Ok(v) => report.metrics.push(("e2e.busbw_gbps".to_string(), v)),
        Err(e) => eprintln!("note: skipping informational e2e.busbw_gbps ({e:#})"),
    }
    Ok(report)
}

/// The `e2e_tcp_smoke` scenario (defaults) reduced to its effective bus
/// bandwidth.
fn e2e_busbw_gbps(registry: &ScenarioRegistry) -> Result<f64> {
    use anyhow::Context as _;
    let out = registry.get("e2e_tcp_smoke")?.run(&[])?;
    anyhow::ensure!(out.passed(), "bench e2e smoke failed its checks");
    out.metric_value("effective_bus_gbps")
        .context("e2e_tcp_smoke no longer emits effective_bus_gbps")
}

/// Parse a flat `{"key": number, ...}` JSON object — the only shape the
/// bench baseline uses. Whitespace/newlines anywhere; no nesting, no
/// arrays, no escapes beyond `\"` and `\\` in keys.
pub fn parse_flat_json(s: &str) -> Result<Vec<(String, f64)>> {
    fn skip_ws(chars: &mut std::iter::Peekable<std::str::Chars<'_>>) {
        while chars.peek().is_some_and(|c| c.is_whitespace()) {
            chars.next();
        }
    }
    let mut out = Vec::new();
    let mut chars = s.chars().peekable();
    skip_ws(&mut chars);
    anyhow::ensure!(chars.next() == Some('{'), "baseline must be a JSON object");
    skip_ws(&mut chars);
    if chars.peek() == Some(&'}') {
        return Ok(out);
    }
    loop {
        skip_ws(&mut chars);
        anyhow::ensure!(chars.next() == Some('"'), "expected a quoted key");
        let mut key = String::new();
        loop {
            match chars.next() {
                Some('\\') => match chars.next() {
                    Some(c @ ('"' | '\\')) => key.push(c),
                    other => anyhow::bail!("unsupported escape {other:?} in key"),
                },
                Some('"') => break,
                Some(c) => key.push(c),
                None => anyhow::bail!("unterminated key"),
            }
        }
        skip_ws(&mut chars);
        anyhow::ensure!(chars.next() == Some(':'), "expected ':' after key {key:?}");
        skip_ws(&mut chars);
        let mut num = String::new();
        while chars
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, '-' | '+' | '.' | 'e' | 'E'))
        {
            num.push(chars.next().expect("peeked"));
        }
        let v: f64 =
            num.parse().map_err(|_| anyhow::anyhow!("bad number {num:?} for key {key:?}"))?;
        out.push((key, v));
        skip_ws(&mut chars);
        match chars.next() {
            Some(',') => continue,
            Some('}') => break,
            other => anyhow::bail!("expected ',' or '}}', got {other:?}"),
        }
    }
    Ok(out)
}

/// One gated metric's baseline-vs-current delta.
#[derive(Clone, Debug)]
pub struct Delta {
    pub key: String,
    pub baseline: f64,
    pub current: Option<f64>,
    /// `current / baseline - 1`; `None` when the metric disappeared.
    pub rel: Option<f64>,
    pub regressed: bool,
}

/// The full comparison: per-metric deltas plus metrics the baseline has
/// never seen (informational, never failing).
#[derive(Clone, Debug)]
pub struct Comparison {
    pub deltas: Vec<Delta>,
    pub new_metrics: Vec<String>,
    pub tolerance: f64,
}

impl Comparison {
    /// The gate verdict: every baselined metric present and within
    /// tolerance of (or above) its baseline.
    pub fn ok(&self) -> bool {
        self.deltas.iter().all(|d| !d.regressed)
    }

    /// Human delta table (printed by `netbn bench --compare`).
    pub fn render(&self, baseline_path: &str, tolerance: f64) -> String {
        let mut t = Table::new(
            format!("bench gate vs {baseline_path} (tolerance -{:.0}%)", tolerance * 100.0),
            &["metric", "baseline", "current", "delta", "status"],
        );
        for d in &self.deltas {
            let (current, delta) = match (d.current, d.rel) {
                (Some(c), Some(r)) => (format!("{c:.4}"), format!("{:+.1}%", r * 100.0)),
                _ => ("MISSING".into(), "-".into()),
            };
            let status = if d.regressed {
                "REGRESSED"
            } else if d.rel.is_some_and(|r| r > tolerance) {
                "improved (re-baseline?)"
            } else {
                "ok"
            };
            t.row(vec![
                d.key.clone(),
                format!("{:.4}", d.baseline),
                current,
                delta,
                status.into(),
            ]);
        }
        let mut s = t.render();
        if !self.new_metrics.is_empty() {
            s.push_str(&format!(
                "\nnot in baseline (informational): {}\n",
                self.new_metrics.join(", ")
            ));
        }
        s.push_str(if self.ok() {
            "\nbench gate: PASS\n"
        } else {
            "\nbench gate: FAIL (throughput regression beyond tolerance)\n"
        });
        s
    }
}

/// Compare a collected report against a baseline. A metric regresses when
/// `current < baseline * (1 - tolerance)` or when it vanished from the
/// current run; extra current-only metrics are reported but never fail.
pub fn compare(
    current: &[(String, f64)],
    baseline: &[(String, f64)],
    tolerance: f64,
) -> Comparison {
    assert!((0.0..1.0).contains(&tolerance), "tolerance in [0, 1)");
    let mut deltas = Vec::new();
    for (key, base) in baseline {
        let cur = current.iter().find(|(k, _)| k == key).map(|(_, v)| *v);
        let rel = cur.map(|c| if *base != 0.0 { c / base - 1.0 } else { 0.0 });
        let regressed = match cur {
            None => true,
            Some(c) => c < base * (1.0 - tolerance),
        };
        deltas.push(Delta { key: key.clone(), baseline: *base, current: cur, rel, regressed });
    }
    let new_metrics = current
        .iter()
        .filter(|(k, _)| !baseline.iter().any(|(b, _)| b == k))
        .map(|(k, _)| k.clone())
        .collect();
    Comparison { deltas, new_metrics, tolerance }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kv(pairs: &[(&str, f64)]) -> Vec<(String, f64)> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn collect_produces_every_gated_metric() {
        let report = collect(&ScenarioRegistry::builtin()).unwrap();
        assert_eq!(
            report.metrics.len(),
            GATED.iter().map(|(_, ks)| ks.len()).sum::<usize>()
        );
        for (k, v) in &report.metrics {
            assert!(v.is_finite() && *v > 0.0, "{k} = {v}");
        }
        assert!(report
            .metrics
            .iter()
            .any(|(k, _)| k == "transport_ablation.effective_gbps@8"));
        assert!(report.metrics.iter().any(|(k, _)| k == "hier_vs_flat.hier_bus_gbps"));
    }

    #[test]
    fn e2e_busbw_ride_along_is_informational() {
        // The ride-along metric itself (without re-running the gated
        // suite): a real short smoke run over loopback TCP.
        let busbw = e2e_busbw_gbps(&ScenarioRegistry::builtin()).unwrap();
        assert!(busbw > 0.0, "{busbw}");
        // Never gated: absent from GATED and from the committed baseline,
        // so compare() can only ever list it as informational.
        assert!(GATED.iter().all(|(s, _)| *s != "e2e_tcp_smoke"));
        let committed = parse_flat_json(include_str!("../../../bench/baseline.json")).unwrap();
        assert!(committed.iter().all(|(k, _)| k != "e2e.busbw_gbps"));
        let mut current = committed.clone();
        current.push(("e2e.busbw_gbps".to_string(), busbw));
        let cmp = compare(&current, &committed, 0.2);
        assert!(cmp.ok(), "{cmp:?}");
        assert!(cmp.new_metrics.iter().any(|k| k == "e2e.busbw_gbps"), "{:?}", cmp.new_metrics);
    }

    #[test]
    fn json_round_trips() {
        let report = BenchReport { metrics: kv(&[("a.x", 1.5), ("b.y@8", 30.25)]) };
        let parsed = parse_flat_json(&report.to_json()).unwrap();
        assert_eq!(parsed, report.metrics);
        assert_eq!(parse_flat_json("{}").unwrap(), vec![]);
        assert_eq!(
            parse_flat_json(" { \"k\" : -2.5e-1 } ").unwrap(),
            vec![("k".to_string(), -0.25)]
        );
        assert!(parse_flat_json("[1,2]").is_err());
        assert!(parse_flat_json("{\"k\": }").is_err());
        assert!(parse_flat_json("{\"k\": 1").is_err());
    }

    #[test]
    fn compare_passes_within_tolerance() {
        let base = kv(&[("m.a", 100.0), ("m.b", 2.0)]);
        let cur = kv(&[("m.a", 85.0), ("m.b", 2.3), ("m.new", 1.0)]);
        let cmp = compare(&cur, &base, 0.2);
        assert!(cmp.ok(), "{cmp:?}");
        assert_eq!(cmp.new_metrics, vec!["m.new".to_string()]);
        let rendered = cmp.render("bench/baseline.json", 0.2);
        assert!(rendered.contains("PASS"), "{rendered}");
    }

    #[test]
    fn compare_fails_on_injected_regression() {
        // The CI acceptance test, inverted locally: inflate the baseline
        // >= 25% above current and the +/-20% gate must fail.
        let cur = kv(&[("m.a", 100.0)]);
        let base = kv(&[("m.a", 130.0)]);
        let cmp = compare(&cur, &base, 0.2);
        assert!(!cmp.ok());
        let rendered = cmp.render("baseline", 0.2);
        assert!(rendered.contains("REGRESSED"), "{rendered}");
        assert!(rendered.contains("FAIL"), "{rendered}");
    }

    #[test]
    fn compare_fails_on_vanished_metric() {
        let cur = kv(&[("m.a", 100.0)]);
        let base = kv(&[("m.a", 100.0), ("m.gone", 5.0)]);
        let cmp = compare(&cur, &base, 0.2);
        assert!(!cmp.ok());
        assert!(cmp.render("b", 0.2).contains("MISSING"));
    }

    #[test]
    fn committed_baseline_matches_current_build() {
        // bench/baseline.json is the CI gate's contract: the numbers this
        // build produces must sit within the gate's own tolerance of it.
        // (Analytic scenarios are deterministic, so in practice they match
        // near-exactly; the tolerance absorbs model recalibrations small
        // enough not to matter.)
        let committed = parse_flat_json(include_str!("../../../bench/baseline.json")).unwrap();
        let current = collect(&ScenarioRegistry::builtin()).unwrap();
        let cmp = compare(&current.metrics, &committed, 0.2);
        assert!(
            cmp.ok(),
            "committed bench/baseline.json regressed vs this build:\n{}",
            cmp.render("bench/baseline.json", 0.2)
        );
        // And the reverse direction: the current build must not sit far
        // ABOVE the baseline either, or the baseline is stale enough to
        // hide future regressions.
        let reverse = compare(&committed, &current.metrics, 0.2);
        assert!(
            reverse.ok(),
            "bench/baseline.json is stale (current far above it):\n{}",
            reverse.render("current build", 0.2)
        );
    }

    #[test]
    fn injected_regression_fails_against_committed_baseline() {
        // End-to-end version of the CI criterion: take the committed
        // baseline, simulate a 25% throughput loss, and the gate fails.
        let committed = parse_flat_json(include_str!("../../../bench/baseline.json")).unwrap();
        let regressed: Vec<(String, f64)> =
            committed.iter().map(|(k, v)| (k.clone(), v * 0.75)).collect();
        let cmp = compare(&regressed, &committed, 0.2);
        assert!(!cmp.ok(), "a 25% regression must trip the 20% gate");
    }
}
