//! The perf-regression **bench gate**.
//!
//! `netbn bench` runs the throughput-bearing scenarios
//! (`transport_ablation`, `hier_vs_flat`), extracts their
//! effective-throughput metrics into a flat `name -> value` report, and
//! compares it against a committed baseline (`bench/baseline.json`) with
//! a fractional tolerance: any gated metric falling more than
//! `tolerance` below its baseline fails the gate, with a delta table
//! naming the regressed metrics. CI runs exactly this
//! (`netbn bench --json BENCH_ci.json --compare bench/baseline.json`),
//! and the same command reproduces the check locally.
//!
//! The baseline format is deliberately minimal — one flat JSON object of
//! `"metric": number` pairs, written by [`BenchReport::to_json`] and
//! parsed back by [`parse_flat_json`] (no serde in the offline build).
//! Metrics *above* baseline don't fail the gate; a sustained improvement
//! shows up in the delta table as a reminder to re-baseline.
//!
//! The CLI path ([`collect_with_e2e`]) additionally runs the real
//! `e2e_tcp_smoke` launch probe **N times** — once uninstrumented and
//! once with the span tracer on (`obs=on`), gating the instrumentation
//! overhead in-process (see [`OBS_OVERHEAD_TOL`]) — and reports
//! `e2e.busbw_gbps` (mean) plus `e2e.busbw_gbps.stddev` — and, unlike
//! the analytic metrics, this pair is gated **variance-aware**: a mean
//! metric whose baseline carries a `.stddev` companion regresses only
//! when it falls below `baseline·(1−tolerance) − 3·σ_baseline`
//! (the committed dispersion; never the current run's own, which a
//! regression could inflate), clamped below by the collapse floor
//! ([`COLLAPSE_FLOOR_FRAC`] of the baseline). Loopback launch timings
//! are machine- and load-dependent; the 3σ slack keeps an honest noisy
//! run green while a genuine throughput collapse still fails — the
//! floor guarantees the gate can never go vacuous however generous the
//! dispersion. `.stddev` keys themselves are dispersion companions,
//! never gated. The committed baseline starts deliberately conservative
//! (low mean, generous σ) — tighten it as CI accumulates variance data.

use super::registry::ScenarioRegistry;
use crate::report::{json_str, Table};
use crate::Result;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// Which metrics of which scenario the gate tracks. Effective-throughput
/// fields only: these move when a transport or collective change alters
/// delivered bandwidth, and stay put under refactors.
const GATED: &[(&str, &[&str])] = &[
    ("transport_ablation", &["single_effective_gbps", "effective_gbps@8", "speedup@8"]),
    ("hier_vs_flat", &["flat_bus_gbps", "hier_bus_gbps", "hier_speedup"]),
];

/// A collected benchmark run: flat `scenario.metric -> value`.
#[derive(Clone, Debug)]
pub struct BenchReport {
    pub metrics: Vec<(String, f64)>,
}

impl BenchReport {
    /// Render as a human table.
    pub fn render(&self) -> String {
        let mut t = Table::new("bench metrics", &["metric", "value"]);
        for (k, v) in &self.metrics {
            t.row(vec![k.clone(), format!("{v:.4}")]);
        }
        t.render()
    }

    /// Flat JSON object, keys in collection order.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{\n");
        for (i, (k, v)) in self.metrics.iter().enumerate() {
            let _ = write!(s, "  {}: {v}", json_str(k));
            if i + 1 < self.metrics.len() {
                s.push(',');
            }
            s.push('\n');
        }
        s.push_str("}\n");
        s
    }
}

/// One bench run as a single JSONL record: the flat metric object of
/// [`BenchReport::to_json`] collapsed to one line, stamped with
/// `unix_ts` so a history file orders itself. Parseable back with
/// [`parse_flat_json`].
pub fn history_line(report: &BenchReport, unix_ts: u64) -> String {
    let mut s = format!("{{\"unix_ts\":{unix_ts}");
    for (k, v) in &report.metrics {
        let _ = write!(s, ",{}:{v}", json_str(k));
    }
    s.push('}');
    s
}

/// Append this run to `<store_dir>/bench_history.jsonl` (creating the
/// directory and file as needed) — `netbn bench --store <dir>` writes
/// the same store a `netbn serve` daemon uses, so one directory carries
/// both job history and the perf trend line. Returns the file path.
pub fn append_history(report: &BenchReport, store_dir: &Path) -> Result<PathBuf> {
    use std::io::Write as _;
    std::fs::create_dir_all(store_dir)
        .map_err(|e| anyhow::anyhow!("create store dir {}: {e}", store_dir.display()))?;
    let path = store_dir.join("bench_history.jsonl");
    let ts = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(&path)?;
    writeln!(f, "{}", history_line(report, ts))?;
    Ok(path)
}

/// Run the gated scenarios (with default parameters — the baseline's
/// contract) and collect their throughput metrics.
pub fn collect(registry: &ScenarioRegistry) -> Result<BenchReport> {
    let mut metrics = Vec::new();
    for &(scenario, keys) in GATED {
        let out = registry.get(scenario)?.run(&[])?;
        anyhow::ensure!(
            out.passed(),
            "bench scenario {scenario} failed its own shape checks"
        );
        for &key in keys {
            let v = out.metric_value(key).ok_or_else(|| {
                anyhow::anyhow!("bench scenario {scenario} no longer emits metric {key:?}")
            })?;
            metrics.push((format!("{scenario}.{key}"), v));
        }
    }
    Ok(BenchReport { metrics })
}

/// [`collect`], plus the gated machine-dependent pairs:
///
/// * `e2e.busbw_gbps` (+ `.stddev`) — the registered `e2e_tcp_smoke`
///   scenario (thread-spawned workers, striped lanes, hier collective
///   over real loopback TCP — exactly the smoke CI already exercises,
///   so there is a single definition of "the launch probe"), run `runs`
///   times. PR 4 shipped the mean as informational-only; with the
///   dispersion measured per run, the metric is **gated** —
///   variance-aware, see [`compare`].
/// * `e2e.busbw_gbps.obs` (+ `.stddev`) — the same probe with the span
///   tracer and per-step breakdown shipping on. Besides being gated
///   against the committed baseline like its uninstrumented twin, the
///   pair is gated **in-process** against the off leg collected moments
///   earlier on the same machine: instrumentation may cost at most
///   [`OBS_OVERHEAD_TOL`] of the uninstrumented bandwidth (3σ
///   variance-aware, so loopback noise doesn't flake the gate). The off
///   leg always runs first — enabling the tracer is sticky for the
///   process, so the ordering is load-bearing.
/// * `reduce.reduce_bw_gbps` (+ `.stddev`) — the sustained decode+add
///   bandwidth of [`crate::collectives::reduce::add_bytes_assign`], the
///   receive-side CPU ceiling of every collective. Gated the same
///   variance-aware way against a deliberately conservative baseline,
///   so a de-vectorizing regression of the reduce loop fails CI without
///   the gate tripping on CPU-speed differences between machines.
pub fn collect_with_e2e(registry: &ScenarioRegistry, runs: usize) -> Result<BenchReport> {
    anyhow::ensure!(runs >= 1, "e2e bench needs >= 1 run");
    let mut report = collect(registry)?;
    // Uninstrumented leg FIRST: enabling the tracer is sticky for the
    // process, so an obs-first ordering would contaminate these samples.
    let samples = e2e_busbw_samples(registry, runs)?;
    let s = crate::util::stats::Summary::of(&samples);
    report.metrics.push(("e2e.busbw_gbps".to_string(), s.mean));
    report.metrics.push(("e2e.busbw_gbps.stddev".to_string(), s.std));
    let obs_samples = e2e_busbw_samples_with(registry, runs, &[("obs", "on")])?;
    let os = crate::util::stats::Summary::of(&obs_samples);
    report.metrics.push(("e2e.busbw_gbps.obs".to_string(), os.mean));
    report.metrics.push(("e2e.busbw_gbps.obs.stddev".to_string(), os.std));
    let gate = obs_overhead_gate(s.mean, s.std, os.mean);
    anyhow::ensure!(
        gate.ok(),
        "span instrumentation overhead beyond {:.0}% of the uninstrumented leg:\n{}",
        OBS_OVERHEAD_TOL * 100.0,
        gate.render("uninstrumented leg (same process)", OBS_OVERHEAD_TOL)
    );
    let r = reduce_bw_samples(runs.max(3));
    let rs = crate::util::stats::Summary::of(&r);
    report.metrics.push(("reduce.reduce_bw_gbps".to_string(), rs.mean));
    report.metrics.push(("reduce.reduce_bw_gbps.stddev".to_string(), rs.std));
    // Per-lane wire histograms the striped lane senders recorded during
    // the probes above: mean send time per lane, so lane skew shows up
    // in `bench --json` (informational — not in the baseline, so the
    // gate treats them as new metrics and never fails on them).
    for s in crate::obs::metrics::global().sample() {
        if s.name != "wire.lane.send_us" {
            continue;
        }
        if let crate::obs::metrics::SampleValue::Histo { count, sum } = s.value {
            if count == 0 {
                continue;
            }
            let lane = s
                .labels
                .iter()
                .find(|(k, _)| k == "lane")
                .map(|(_, v)| v.clone())
                .unwrap_or_default();
            report
                .metrics
                .push((format!("wire.lane{lane}.send_us.mean"), sum as f64 / count as f64));
        }
    }
    Ok(report)
}

/// `runs` samples of the reduce hot path's wire-bytes-reduced bandwidth
/// (1M f32 elements per rep — streaming from memory, the regime the
/// collectives' chunks run in).
fn reduce_bw_samples(runs: usize) -> Vec<f64> {
    (0..runs).map(|_| crate::collectives::reduce::measure_reduce_bw_gbps(1 << 20, 4)).collect()
}

/// `runs` samples of the launch probe's effective bus bandwidth.
fn e2e_busbw_samples(registry: &ScenarioRegistry, runs: usize) -> Result<Vec<f64>> {
    e2e_busbw_samples_with(registry, runs, &[])
}

/// [`e2e_busbw_samples`] with parameter overrides — the obs leg passes
/// `obs=on` to run the identical probe with the tracer live.
fn e2e_busbw_samples_with(
    registry: &ScenarioRegistry,
    runs: usize,
    overrides: &[(&str, &str)],
) -> Result<Vec<f64>> {
    use anyhow::Context as _;
    let scenario = registry.get("e2e_tcp_smoke")?;
    let ov: Vec<(String, String)> =
        overrides.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
    let mut samples = Vec::with_capacity(runs);
    for i in 0..runs {
        let out = scenario.run(&ov)?;
        anyhow::ensure!(out.passed(), "bench e2e probe run {i} failed its checks");
        samples.push(
            out.metric_value("effective_bus_gbps")
                .context("e2e_tcp_smoke no longer emits effective_bus_gbps")?,
        );
    }
    Ok(samples)
}

/// The in-process instrumentation-overhead tolerance: the obs leg's mean
/// bus bandwidth may sit at most this fraction below the uninstrumented
/// leg measured moments earlier in the same process.
pub const OBS_OVERHEAD_TOL: f64 = 0.03;

/// Gate the instrumented leg against the uninstrumented one through the
/// same variance-aware [`compare`] machinery: the off leg's measured
/// dispersion earns the 3σ slack (clamped by the collapse floor), so a
/// noisy loopback run stays green while a real tracer slowdown fails.
fn obs_overhead_gate(off_mean: f64, off_std: f64, obs_mean: f64) -> Comparison {
    let base = vec![
        ("e2e.busbw_gbps.obs".to_string(), off_mean),
        ("e2e.busbw_gbps.obs.stddev".to_string(), off_std),
    ];
    let cur = vec![("e2e.busbw_gbps.obs".to_string(), obs_mean)];
    compare(&cur, &base, OBS_OVERHEAD_TOL)
}

/// Default history window for `netbn bench --trend`.
pub const TREND_WINDOW: usize = 12;

/// The trend gate's verdict over the tail of `bench_history.jsonl`.
#[derive(Clone, Debug)]
pub struct TrendReport {
    /// History entries actually evaluated (after the window cut).
    pub evaluated: usize,
    /// Throughput series examined.
    pub series: usize,
    pub detections: Vec<crate::obs::Detection>,
}

impl TrendReport {
    pub fn ok(&self) -> bool {
        self.detections.is_empty()
    }

    pub fn render(&self, window: usize) -> String {
        let mut t = Table::new(
            format!(
                "bench trend over last {} of {window} history entries, {} series",
                self.evaluated, self.series
            ),
            &["series", "entry", "value", "baseline", "z"],
        );
        for d in &self.detections {
            t.row(vec![
                d.series.clone(),
                d.at.to_string(),
                format!("{:.4}", d.value),
                format!("{:.4}", d.baseline),
                format!("{:+.1}", d.z),
            ]);
        }
        let mut s = t.render();
        s.push_str(if self.ok() {
            "\nbench trend: PASS (no sustained regression)\n"
        } else {
            "\nbench trend: FAIL (sustained throughput regression)\n"
        });
        s
    }
}

/// `netbn bench --trend`: replay the last `window` entries of
/// `<store_dir>/bench_history.jsonl` through the same online detector
/// the serve daemon runs ([`crate::obs::detect`], throughput config).
/// Only sustained drops fire — a single noisy CI run never fails the
/// trend gate (that's the point-in-time [`compare`] gate's job), and a
/// history shorter than the detector's warmup+sustain passes trivially.
/// Throughput series are the `gbps`-named keys; `.stddev` companions
/// and timestamps are skipped.
pub fn evaluate_trend(store_dir: &Path, window: usize) -> Result<TrendReport> {
    anyhow::ensure!(window >= 1, "trend window must be >= 1");
    let path = store_dir.join("bench_history.jsonl");
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
    let mut entries: Vec<Vec<(String, f64)>> = Vec::new();
    for (i, line) in text.lines().filter(|l| !l.trim().is_empty()).enumerate() {
        entries.push(
            parse_flat_json(line)
                .map_err(|e| anyhow::anyhow!("bench history line {}: {e:#}", i + 1))?,
        );
    }
    if entries.len() > window {
        entries.drain(..entries.len() - window);
    }
    let lookup = |e: &[(String, f64)], key: &str| {
        e.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
    };
    // Keys in first-seen order, deduped across entries (history may gain
    // metrics over time).
    let mut keys: Vec<String> = Vec::new();
    for e in &entries {
        for (k, _) in e {
            if k.contains("gbps") && !k.ends_with(".stddev") && !keys.contains(k) {
                keys.push(k.clone());
            }
        }
    }
    let mut detections = Vec::new();
    for key in &keys {
        let series: Vec<(u64, f64)> = entries
            .iter()
            .enumerate()
            .filter_map(|(i, e)| lookup(e, key).map(|v| (i as u64, v)))
            .collect();
        detections.extend(crate::obs::detect::scan(
            crate::obs::detect::DetectorConfig::throughput(),
            crate::obs::detect::DetectionKind::ThroughputRegression,
            key,
            &series,
        ));
    }
    Ok(TrendReport { evaluated: entries.len(), series: keys.len(), detections })
}

/// Parse a flat `{"key": number, ...}` JSON object — the only shape the
/// bench baseline uses. Whitespace/newlines anywhere; no nesting, no
/// arrays, no escapes beyond `\"` and `\\` in keys.
pub fn parse_flat_json(s: &str) -> Result<Vec<(String, f64)>> {
    fn skip_ws(chars: &mut std::iter::Peekable<std::str::Chars<'_>>) {
        while chars.peek().is_some_and(|c| c.is_whitespace()) {
            chars.next();
        }
    }
    let mut out = Vec::new();
    let mut chars = s.chars().peekable();
    skip_ws(&mut chars);
    anyhow::ensure!(chars.next() == Some('{'), "baseline must be a JSON object");
    skip_ws(&mut chars);
    if chars.peek() == Some(&'}') {
        return Ok(out);
    }
    loop {
        skip_ws(&mut chars);
        anyhow::ensure!(chars.next() == Some('"'), "expected a quoted key");
        let mut key = String::new();
        loop {
            match chars.next() {
                Some('\\') => match chars.next() {
                    Some(c @ ('"' | '\\')) => key.push(c),
                    other => anyhow::bail!("unsupported escape {other:?} in key"),
                },
                Some('"') => break,
                Some(c) => key.push(c),
                None => anyhow::bail!("unterminated key"),
            }
        }
        skip_ws(&mut chars);
        anyhow::ensure!(chars.next() == Some(':'), "expected ':' after key {key:?}");
        skip_ws(&mut chars);
        let mut num = String::new();
        while chars
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, '-' | '+' | '.' | 'e' | 'E'))
        {
            num.push(chars.next().expect("peeked"));
        }
        let v: f64 =
            num.parse().map_err(|_| anyhow::anyhow!("bad number {num:?} for key {key:?}"))?;
        out.push((key, v));
        skip_ws(&mut chars);
        match chars.next() {
            Some(',') => continue,
            Some('}') => break,
            other => anyhow::bail!("expected ',' or '}}', got {other:?}"),
        }
    }
    Ok(out)
}

/// One gated metric's baseline-vs-current delta.
#[derive(Clone, Debug)]
pub struct Delta {
    pub key: String,
    pub baseline: f64,
    pub current: Option<f64>,
    /// `current / baseline - 1`; `None` when the metric disappeared.
    pub rel: Option<f64>,
    /// Absolute 3σ allowance below the tolerance floor (non-zero only for
    /// metrics whose *baseline* carries a `.stddev` companion — the
    /// variance-aware gate).
    pub slack: f64,
    pub regressed: bool,
}

/// The full comparison: per-metric deltas plus metrics the baseline has
/// never seen (informational, never failing).
#[derive(Clone, Debug)]
pub struct Comparison {
    pub deltas: Vec<Delta>,
    pub new_metrics: Vec<String>,
    pub tolerance: f64,
}

impl Comparison {
    /// The gate verdict: every baselined metric present and within
    /// tolerance of (or above) its baseline.
    pub fn ok(&self) -> bool {
        self.deltas.iter().all(|d| !d.regressed)
    }

    /// Human delta table (printed by `netbn bench --compare`).
    pub fn render(&self, baseline_path: &str, tolerance: f64) -> String {
        let mut t = Table::new(
            format!("bench gate vs {baseline_path} (tolerance -{:.0}%)", tolerance * 100.0),
            &["metric", "baseline", "current", "delta", "status"],
        );
        for d in &self.deltas {
            let (current, delta) = match (d.current, d.rel) {
                (Some(c), Some(r)) => (format!("{c:.4}"), format!("{:+.1}%", r * 100.0)),
                _ => ("MISSING".into(), "-".into()),
            };
            let status = if d.regressed {
                "REGRESSED"
            } else if d.rel.is_some_and(|r| r > tolerance) {
                "improved (re-baseline?)"
            } else if d.slack > 0.0 && d.rel.is_some_and(|r| r < -tolerance) {
                "ok (within 3σ)"
            } else {
                "ok"
            };
            t.row(vec![
                d.key.clone(),
                format!("{:.4}", d.baseline),
                current,
                delta,
                status.into(),
            ]);
        }
        let mut s = t.render();
        if !self.new_metrics.is_empty() {
            s.push_str(&format!(
                "\nnot in baseline (informational): {}\n",
                self.new_metrics.join(", ")
            ));
        }
        s.push_str(if self.ok() {
            "\nbench gate: PASS\n"
        } else {
            "\nbench gate: FAIL (throughput regression beyond tolerance)\n"
        });
        s
    }
}

/// The 3σ slack may widen a variance-aware gate, but never below this
/// fraction of the baseline: a collapse past 10× always fails, however
/// noisy the runs claim to be. Without this floor, a conservative
/// baseline (small mean, generous σ) would make the gate vacuous — the
/// tolerance floor would go negative and any positive value would pass.
pub const COLLAPSE_FLOOR_FRAC: f64 = 0.1;

/// Compare a collected report against a baseline. A sharp metric (no
/// dispersion companion) regresses when
/// `current < baseline * (1 - tolerance)`; a variance-aware one when it
/// falls below that minus the 3σ slack, clamped by the collapse floor.
/// A metric that vanished from the current run always regresses; extra
/// current-only metrics are reported but never fail.
///
/// **Variance awareness**: a metric `K` whose *baseline* carries a
/// `K.stddev` companion earns a `slack` of `3 · σ_baseline` — the
/// committed, trusted dispersion widens the gate instead of tripping it,
/// down to (never past) `baseline ·` [`COLLAPSE_FLOOR_FRAC`]. The
/// current run's self-reported stddev deliberately earns nothing: a
/// change that makes the path slow AND erratic must not widen the very
/// gate meant to catch it. `.stddev` keys are dispersion companions, not
/// throughput metrics: they are skipped as gate rows (shrinking
/// dispersion must never "regress").
pub fn compare(
    current: &[(String, f64)],
    baseline: &[(String, f64)],
    tolerance: f64,
) -> Comparison {
    assert!((0.0..1.0).contains(&tolerance), "tolerance in [0, 1)");
    let lookup = |set: &[(String, f64)], key: &str| {
        set.iter().find(|(k, _)| k == key).map(|(_, v)| *v)
    };
    let mut deltas = Vec::new();
    for (key, base) in baseline {
        if key.ends_with(".stddev") {
            continue;
        }
        let stddev_key = format!("{key}.stddev");
        let sigma = lookup(baseline, &stddev_key).unwrap_or(0.0);
        let slack = 3.0 * sigma;
        // Sharp metrics keep the plain fractional gate; only a
        // variance-aware metric earns the slack — and with it the
        // collapse floor that stops the slack going vacuous.
        let floor = if sigma > 0.0 {
            (base * (1.0 - tolerance) - slack).max(base * COLLAPSE_FLOOR_FRAC)
        } else {
            base * (1.0 - tolerance)
        };
        let cur = lookup(current, key);
        let rel = cur.map(|c| if *base != 0.0 { c / base - 1.0 } else { 0.0 });
        let regressed = match cur {
            None => true,
            Some(c) => c < floor,
        };
        deltas.push(Delta {
            key: key.clone(),
            baseline: *base,
            current: cur,
            rel,
            slack,
            regressed,
        });
    }
    let new_metrics = current
        .iter()
        .filter(|(k, _)| !baseline.iter().any(|(b, _)| b == k))
        .map(|(k, _)| k.clone())
        .collect();
    Comparison { deltas, new_metrics, tolerance }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kv(pairs: &[(&str, f64)]) -> Vec<(String, f64)> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn collect_produces_every_gated_metric() {
        let report = collect(&ScenarioRegistry::builtin()).unwrap();
        assert_eq!(
            report.metrics.len(),
            GATED.iter().map(|(_, ks)| ks.len()).sum::<usize>()
        );
        for (k, v) in &report.metrics {
            assert!(v.is_finite() && *v > 0.0, "{k} = {v}");
        }
        assert!(report
            .metrics
            .iter()
            .any(|(k, _)| k == "transport_ablation.effective_gbps@8"));
        assert!(report.metrics.iter().any(|(k, _)| k == "hier_vs_flat.hier_bus_gbps"));
    }

    #[test]
    fn e2e_busbw_is_gated_with_measured_dispersion() {
        // Two real probe runs over loopback TCP: positive samples, a
        // finite stddev, and the pair is present in the committed
        // baseline — the PR 4 open item ("gate e2e busbw") closed.
        let samples = e2e_busbw_samples(&ScenarioRegistry::builtin(), 2).unwrap();
        assert_eq!(samples.len(), 2);
        for s in &samples {
            assert!(s.is_finite() && *s > 0.0, "{samples:?}");
        }
        let committed = parse_flat_json(include_str!("../../../bench/baseline.json")).unwrap();
        assert!(committed.iter().any(|(k, _)| k == "e2e.busbw_gbps"));
        assert!(committed.iter().any(|(k, _)| k == "e2e.busbw_gbps.stddev"));
        assert!(committed.iter().any(|(k, _)| k == "e2e.busbw_gbps.obs"));
        assert!(committed.iter().any(|(k, _)| k == "e2e.busbw_gbps.obs.stddev"));
    }

    #[test]
    fn reduce_bw_is_gated_with_measured_dispersion() {
        // The reduce hot path's CPU ceiling is a first-class gated metric:
        // samples are positive and the variance-aware pair is committed in
        // the baseline, conservatively enough that the floor (10% of 20
        // Gbps after 3σ slack) only trips on a genuine de-vectorization.
        let samples = reduce_bw_samples(3);
        assert_eq!(samples.len(), 3);
        for s in &samples {
            assert!(s.is_finite() && *s > 0.0, "{samples:?}");
        }
        let committed = parse_flat_json(include_str!("../../../bench/baseline.json")).unwrap();
        assert!(committed.iter().any(|(k, _)| k == "reduce.reduce_bw_gbps"));
        assert!(committed.iter().any(|(k, _)| k == "reduce.reduce_bw_gbps.stddev"));
    }

    #[test]
    fn obs_overhead_gate_is_variance_aware() {
        // A quiet off leg makes the gate sharp: 3% under fails, 2% passes.
        assert!(obs_overhead_gate(1.0, 0.0, 0.98).ok());
        assert!(!obs_overhead_gate(1.0, 0.0, 0.96).ok());
        // A noisy off leg earns 3σ slack — 0.5 sits inside
        // 1.0·0.97 − 3·0.2 = 0.37 — but the collapse floor still catches
        // a tracer that destroys throughput outright.
        assert!(obs_overhead_gate(1.0, 0.2, 0.5).ok());
        assert!(!obs_overhead_gate(1.0, 0.2, 0.05).ok());
    }

    #[test]
    fn variance_aware_gate_widens_by_three_sigma() {
        let base = kv(&[("e2e.busbw_gbps", 10.0), ("e2e.busbw_gbps.stddev", 1.0)]);
        // 7.5 is below the 20% floor (8.0) but inside 8.0 − 3σ = 5.0.
        let cur = kv(&[("e2e.busbw_gbps", 7.5), ("e2e.busbw_gbps.stddev", 0.5)]);
        let cmp = compare(&cur, &base, 0.2);
        assert!(cmp.ok(), "{cmp:?}");
        assert!(cmp.render("b", 0.2).contains("within 3σ"));
        // Below the widened floor still fails.
        let cur = kv(&[("e2e.busbw_gbps", 4.0), ("e2e.busbw_gbps.stddev", 0.5)]);
        assert!(!compare(&cur, &base, 0.2).ok());
        // Only the COMMITTED dispersion earns slack: a run that got slow
        // and erratic cannot widen its own gate with a noisy stddev.
        let base_quiet = kv(&[("e2e.busbw_gbps", 10.0), ("e2e.busbw_gbps.stddev", 0.1)]);
        let cur_noisy = kv(&[("e2e.busbw_gbps", 6.0), ("e2e.busbw_gbps.stddev", 1.5)]);
        assert!(!compare(&cur_noisy, &base_quiet, 0.2).ok(), "self-reported noise must not save a 40% regression");
    }

    #[test]
    fn sigma_slack_never_makes_the_gate_vacuous() {
        // The committed conservative baseline (mean 1.0, σ 0.5) pushes the
        // tolerance floor negative; the collapse floor must still catch a
        // genuine throughput collapse while tolerating honest noise.
        let base = kv(&[("e2e.busbw_gbps", 1.0), ("e2e.busbw_gbps.stddev", 0.5)]);
        let collapsed = kv(&[("e2e.busbw_gbps", 0.01), ("e2e.busbw_gbps.stddev", 0.01)]);
        assert!(!compare(&collapsed, &base, 0.2).ok(), "a 100x collapse must fail");
        let noisy_but_alive = kv(&[("e2e.busbw_gbps", 0.3), ("e2e.busbw_gbps.stddev", 0.2)]);
        assert!(compare(&noisy_but_alive, &base, 0.2).ok());
        // Sharp metrics (no stddev) are unaffected by the floor: 0.8x of
        // baseline still passes at 20% tolerance, 0.79x still fails.
        assert!(compare(&kv(&[("m.a", 8.0)]), &kv(&[("m.a", 10.0)]), 0.2).ok());
        assert!(!compare(&kv(&[("m.a", 7.9)]), &kv(&[("m.a", 10.0)]), 0.2).ok());
    }

    #[test]
    fn stddev_companions_are_never_gate_rows() {
        // Dispersion shrinking (or vanishing) must not read as a
        // regression, and it produces no delta row at all.
        let base = kv(&[("m.a", 10.0), ("m.a.stddev", 2.0)]);
        let cur = kv(&[("m.a", 10.0)]);
        let cmp = compare(&cur, &base, 0.2);
        assert!(cmp.ok(), "{cmp:?}");
        assert_eq!(cmp.deltas.len(), 1);
        assert_eq!(cmp.deltas[0].key, "m.a");
        assert!(cmp.deltas[0].slack > 0.0);
        // Metrics without a companion keep the plain sharp gate.
        let sharp = compare(&kv(&[("m.a", 7.9)]), &kv(&[("m.a", 10.0)]), 0.2);
        assert!(!sharp.ok());
    }

    #[test]
    fn bench_history_appends_one_parseable_line_per_run() {
        let dir = std::env::temp_dir().join(format!("netbn_bench_hist_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let report = BenchReport { metrics: kv(&[("a.x", 1.5), ("b.y@8", 30.25)]) };
        let p1 = append_history(&report, &dir).unwrap();
        let p2 = append_history(&report, &dir).unwrap();
        assert_eq!(p1, p2);
        let text = std::fs::read_to_string(&p1).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2, "one line per run:\n{text}");
        for line in lines {
            let parsed = parse_flat_json(line).unwrap();
            assert!(parsed.iter().any(|(k, _)| k == "unix_ts"), "{line}");
            assert!(parsed.iter().any(|(k, v)| k == "a.x" && *v == 1.5), "{line}");
            assert!(parsed.iter().any(|(k, v)| k == "b.y@8" && *v == 30.25), "{line}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn trend_gate_fails_only_on_sustained_regression() {
        let dir = std::env::temp_dir()
            .join(format!("netbn_bench_trend_{}_{}", std::process::id(), line!()));
        let _ = std::fs::remove_dir_all(&dir);
        let entry = |bw: f64| BenchReport {
            metrics: kv(&[("e2e.busbw_gbps", bw), ("e2e.busbw_gbps.stddev", bw * 0.02)]),
        };
        // Steady prefix with a single-sample dip: the dip must NOT fail.
        for bw in [10.0, 10.2, 9.9, 10.1, 4.0, 10.0, 10.1, 9.95] {
            append_history(&entry(bw), &dir).unwrap();
        }
        let t = evaluate_trend(&dir, TREND_WINDOW).unwrap();
        assert!(t.ok(), "single dip flagged: {:?}", t.detections);
        assert_eq!(t.evaluated, 8);
        assert_eq!(t.series, 1, ".stddev and unix_ts must not become series");
        // Now a sustained collapse: the tail fails, naming the series.
        for _ in 0..3 {
            append_history(&entry(1.0), &dir).unwrap();
        }
        let t = evaluate_trend(&dir, TREND_WINDOW).unwrap();
        assert!(!t.ok(), "sustained regression missed");
        assert_eq!(t.detections[0].series, "e2e.busbw_gbps");
        assert!(t.render(TREND_WINDOW).contains("FAIL"), "{}", t.render(TREND_WINDOW));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn trend_gate_passes_trivially_on_short_history() {
        let dir = std::env::temp_dir()
            .join(format!("netbn_bench_trend_{}_{}", std::process::id(), line!()));
        let _ = std::fs::remove_dir_all(&dir);
        assert!(evaluate_trend(&dir, TREND_WINDOW).is_err(), "missing history is an error");
        let report = BenchReport { metrics: kv(&[("e2e.busbw_gbps", 10.0)]) };
        append_history(&report, &dir).unwrap();
        append_history(&report, &dir).unwrap();
        let t = evaluate_trend(&dir, TREND_WINDOW).unwrap();
        assert!(t.ok(), "{t:?}");
        assert_eq!(t.evaluated, 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn trend_window_cuts_old_history() {
        let dir = std::env::temp_dir()
            .join(format!("netbn_bench_trend_{}_{}", std::process::id(), line!()));
        let _ = std::fs::remove_dir_all(&dir);
        // An ancient collapse outside the window must not fail today's gate.
        let entry = |bw: f64| BenchReport { metrics: kv(&[("e2e.busbw_gbps", bw)]) };
        for bw in [10.0, 10.0, 10.0, 10.0, 1.0, 1.0, 1.0] {
            append_history(&entry(bw), &dir).unwrap();
        }
        for _ in 0..12 {
            append_history(&entry(1.0), &dir).unwrap();
        }
        let t = evaluate_trend(&dir, 12).unwrap();
        assert_eq!(t.evaluated, 12);
        assert!(t.ok(), "flat (if low) window must pass: {:?}", t.detections);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn json_round_trips() {
        let report = BenchReport { metrics: kv(&[("a.x", 1.5), ("b.y@8", 30.25)]) };
        let parsed = parse_flat_json(&report.to_json()).unwrap();
        assert_eq!(parsed, report.metrics);
        assert_eq!(parse_flat_json("{}").unwrap(), vec![]);
        assert_eq!(
            parse_flat_json(" { \"k\" : -2.5e-1 } ").unwrap(),
            vec![("k".to_string(), -0.25)]
        );
        assert!(parse_flat_json("[1,2]").is_err());
        assert!(parse_flat_json("{\"k\": }").is_err());
        assert!(parse_flat_json("{\"k\": 1").is_err());
    }

    #[test]
    fn compare_passes_within_tolerance() {
        let base = kv(&[("m.a", 100.0), ("m.b", 2.0)]);
        let cur = kv(&[("m.a", 85.0), ("m.b", 2.3), ("m.new", 1.0)]);
        let cmp = compare(&cur, &base, 0.2);
        assert!(cmp.ok(), "{cmp:?}");
        assert_eq!(cmp.new_metrics, vec!["m.new".to_string()]);
        let rendered = cmp.render("bench/baseline.json", 0.2);
        assert!(rendered.contains("PASS"), "{rendered}");
    }

    #[test]
    fn compare_fails_on_injected_regression() {
        // The CI acceptance test, inverted locally: inflate the baseline
        // >= 25% above current and the +/-20% gate must fail.
        let cur = kv(&[("m.a", 100.0)]);
        let base = kv(&[("m.a", 130.0)]);
        let cmp = compare(&cur, &base, 0.2);
        assert!(!cmp.ok());
        let rendered = cmp.render("baseline", 0.2);
        assert!(rendered.contains("REGRESSED"), "{rendered}");
        assert!(rendered.contains("FAIL"), "{rendered}");
    }

    #[test]
    fn compare_fails_on_vanished_metric() {
        let cur = kv(&[("m.a", 100.0)]);
        let base = kv(&[("m.a", 100.0), ("m.gone", 5.0)]);
        let cmp = compare(&cur, &base, 0.2);
        assert!(!cmp.ok());
        assert!(cmp.render("b", 0.2).contains("MISSING"));
    }

    #[test]
    fn committed_baseline_matches_current_build() {
        // bench/baseline.json is the CI gate's contract: the numbers this
        // build produces must sit within the gate's own tolerance of it.
        // (Analytic scenarios are deterministic, so in practice they match
        // near-exactly; the tolerance absorbs model recalibrations small
        // enough not to matter.) The e2e and reduce pairs are
        // machine-dependent by nature — `collect()` deliberately excludes
        // them, so strip them from the committed set here; their gating is
        // covered by the variance-aware tests above and exercised for real
        // by CI's `netbn bench --compare`.
        let committed: Vec<(String, f64)> =
            parse_flat_json(include_str!("../../../bench/baseline.json"))
                .unwrap()
                .into_iter()
                .filter(|(k, _)| !k.starts_with("e2e.") && !k.starts_with("reduce."))
                .collect();
        let current = collect(&ScenarioRegistry::builtin()).unwrap();
        let cmp = compare(&current.metrics, &committed, 0.2);
        assert!(
            cmp.ok(),
            "committed bench/baseline.json regressed vs this build:\n{}",
            cmp.render("bench/baseline.json", 0.2)
        );
        // And the reverse direction: the current build must not sit far
        // ABOVE the baseline either, or the baseline is stale enough to
        // hide future regressions.
        let reverse = compare(&committed, &current.metrics, 0.2);
        assert!(
            reverse.ok(),
            "bench/baseline.json is stale (current far above it):\n{}",
            reverse.render("current build", 0.2)
        );
    }

    #[test]
    fn injected_regression_fails_against_committed_baseline() {
        // End-to-end version of the CI criterion: take the committed
        // baseline, simulate a 25% throughput loss, and the gate fails.
        let committed = parse_flat_json(include_str!("../../../bench/baseline.json")).unwrap();
        let regressed: Vec<(String, f64)> =
            committed.iter().map(|(k, v)| (k.clone(), v * 0.75)).collect();
        let cmp = compare(&regressed, &committed, 0.2);
        assert!(!cmp.ok(), "a 25% regression must trip the 20% gate");
    }
}
