//! Job-queue adapter: the registry as a *backend* for queued execution.
//!
//! ROADMAP item 4 asks for the engine to serve as a job-queue backend —
//! this module is the seam. A [`JobRequest`] is a wire-friendly
//! submission (scenario name + string overrides + priority) that any
//! frontend can produce: `netbn serve`'s HTTP `POST /jobs` bodies decode
//! straight into it, and the `serve_throughput` scenario drives the same
//! type in-process. [`validate`] rejects bad submissions *at admission*
//! (unknown scenario, overrides the schema refuses) so queues never hold
//! doomed work, and [`execute`] is the single choke point where a queued
//! request becomes a [`ScenarioRegistry`] run. Warm starts are plain
//! parameter injection ([`warm_start_overrides`]): a persisted
//! [`TunerCheckpoint`] turns into `bucket-mb`/`compression` overrides on
//! scenarios that declare both knobs, never overriding what the
//! submitter pinned.

use super::outcome::Outcome;
use super::params::ParamSchema;
use super::registry::ScenarioRegistry;
use crate::config::Compression;
use crate::report::json_str;
use crate::tune::TunerCheckpoint;
use crate::util::json;
use crate::Result;
use anyhow::{ensure, Context};

/// One queued unit of work, as submitted by a frontend.
#[derive(Clone, Debug, PartialEq)]
pub struct JobRequest {
    /// Registered scenario name.
    pub scenario: String,
    /// `(name, value)` parameter overrides, exactly as `netbn run
    /// --param` would pass them.
    pub params: Vec<(String, String)>,
    /// Scheduling priority, 0–9 (higher drains first).
    pub priority: u8,
}

impl JobRequest {
    /// Decode a submission body:
    /// `{"scenario": "...", "params": {"k": "v", ...}, "priority": 5}`
    /// (`params` and `priority` optional; priority defaults to 5).
    pub fn from_json(body: &str) -> Result<JobRequest> {
        let fields = json::object_fields(body).context("malformed job submission")?;
        let scenario = json::parse_string(json::require(&fields, "scenario")?)?;
        let params = match json::get(&fields, "params") {
            Some(raw) => json::parse_str_map(raw).context("malformed params object")?,
            None => Vec::new(),
        };
        let priority = match json::get(&fields, "priority") {
            Some(raw) => {
                let p = json::parse_u64(raw).context("priority must be an integer")?;
                ensure!(p <= 9, "priority must be 0..=9, got {p}");
                p as u8
            }
            None => 5,
        };
        Ok(JobRequest { scenario, params, priority })
    }

    pub fn to_json(&self) -> String {
        let mut s = format!(
            "{{\"scenario\":{},\"priority\":{},\"params\":{{",
            json_str(&self.scenario),
            self.priority
        );
        for (i, (k, v)) in self.params.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("{}:{}", json_str(k), json_str(v)));
        }
        s.push_str("}}");
        s
    }

    /// Is `key` explicitly pinned by the submitter?
    pub fn has_param(&self, key: &str) -> bool {
        self.params.iter().any(|(k, _)| k == key)
    }
}

/// Admission-time validation: the scenario must exist and the overrides
/// must resolve against its schema. Queues reject here, not at run time.
pub fn validate(registry: &ScenarioRegistry, req: &JobRequest) -> Result<()> {
    let scenario = registry.get(&req.scenario)?;
    scenario.schema().resolve(&req.params)?;
    Ok(())
}

/// Run a queued request to its [`Outcome`] — the one place queue
/// frontends hand work to the engine.
pub fn execute(registry: &ScenarioRegistry, req: &JobRequest) -> Result<Outcome> {
    registry.get(&req.scenario)?.run(&req.params)
}

/// The warm-start overrides a persisted checkpoint implies for `schema`:
/// `bucket-mb`/`compression` from the checkpoint's chosen point, only
/// when the schema declares *both* knobs (the emulated-trainer contract)
/// and the submitter pinned neither. Empty means "no warm start".
pub fn warm_start_overrides(
    schema: &ParamSchema,
    req: &JobRequest,
    ck: &TunerCheckpoint,
) -> Vec<(String, String)> {
    let declares = |key: &str| schema.specs().iter().any(|p| p.name == key);
    if !declares("bucket-mb") || !declares("compression") {
        return Vec::new();
    }
    if req.has_param("bucket-mb") || req.has_param("compression") {
        return Vec::new();
    }
    vec![
        ("bucket-mb".to_string(), format!("{}", ck.chosen.bucket_mb)),
        ("compression".to_string(), compression_value(ck)),
    ]
}

/// The `compression` parameter value for a checkpoint ("1" — the
/// identity ratio — for none, since the param parser has no "none").
fn compression_value(ck: &TunerCheckpoint) -> String {
    match &ck.chosen.compression {
        Compression::None => "1".to_string(),
        Compression::Ratio(r) => format!("{r}"),
        Compression::Codec(k) => k.name(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tune::KnobPoint;

    #[test]
    fn submission_body_round_trips() {
        let req = JobRequest::from_json(
            r#"{"scenario":"simulate","params":{"workers":"8","model":"vgg16"},"priority":7}"#,
        )
        .unwrap();
        assert_eq!(req.scenario, "simulate");
        assert_eq!(req.priority, 7);
        assert!(req.has_param("workers") && !req.has_param("bandwidth"));
        let back = JobRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(back, req);
    }

    #[test]
    fn submission_defaults_and_rejections() {
        let req = JobRequest::from_json(r#"{"scenario":"fig1"}"#).unwrap();
        assert_eq!(req.priority, 5);
        assert!(req.params.is_empty());
        assert!(JobRequest::from_json(r#"{"params":{}}"#).is_err(), "scenario is required");
        assert!(JobRequest::from_json(r#"{"scenario":"x","priority":12}"#).is_err());
        assert!(JobRequest::from_json("not json").is_err());
    }

    #[test]
    fn validate_rejects_at_admission() {
        let r = ScenarioRegistry::builtin();
        let ok = JobRequest {
            scenario: "simulate".into(),
            params: vec![("workers".into(), "8".into())],
            priority: 5,
        };
        validate(&r, &ok).unwrap();
        let unknown = JobRequest { scenario: "nope".into(), params: vec![], priority: 5 };
        assert!(validate(&r, &unknown).is_err());
        let bad = JobRequest {
            scenario: "simulate".into(),
            params: vec![("bandwidth".into(), "-1".into())],
            priority: 5,
        };
        assert!(validate(&r, &bad).is_err());
    }

    #[test]
    fn execute_matches_a_direct_registry_run() {
        let r = ScenarioRegistry::builtin();
        let req = JobRequest {
            scenario: "simulate".into(),
            params: vec![("workers".into(), "8".into())],
            priority: 5,
        };
        let via_queue = execute(&r, &req).unwrap();
        let direct = r
            .get("simulate")
            .unwrap()
            .run(&[("workers".to_string(), "8".to_string())])
            .unwrap();
        // Identical up to the run's own wall clock.
        assert_eq!(via_queue.scenario, direct.scenario);
        assert_eq!(via_queue.params, direct.params);
        assert_eq!(via_queue.metrics, direct.metrics);
    }

    #[test]
    fn warm_start_injects_only_unpinned_declared_knobs() {
        let r = ScenarioRegistry::builtin();
        let emulate = r.get("emulate").unwrap();
        let ck = TunerCheckpoint::from_point(KnobPoint {
            bucket_mb: 4.0,
            ..KnobPoint::default_static()
        });
        let free = JobRequest { scenario: "emulate".into(), params: vec![], priority: 5 };
        let inj = warm_start_overrides(emulate.schema(), &free, &ck);
        assert!(
            inj.iter().any(|(k, v)| k == "bucket-mb" && v == "4"),
            "expected bucket-mb=4 in {inj:?}"
        );
        assert!(inj.iter().any(|(k, _)| k == "compression"));
        // Pinning either knob suppresses injection entirely.
        let pinned = JobRequest {
            scenario: "emulate".into(),
            params: vec![("bucket-mb".into(), "16".into())],
            priority: 5,
        };
        assert!(warm_start_overrides(emulate.schema(), &pinned, &ck).is_empty());
        // A schema without the knobs never warm-starts.
        let sim = r.get("simulate").unwrap();
        assert!(warm_start_overrides(sim.schema(), &free, &ck).is_empty());
        // The injected overrides must actually resolve.
        let mut warmed = free.clone();
        warmed.params.extend(warm_start_overrides(emulate.schema(), &free, &ck));
        validate(&r, &warmed).unwrap();
    }
}
