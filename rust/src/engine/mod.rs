//! The experiment engine: every way of running an experiment behind one
//! API.
//!
//! The paper's method is running the *same* experiment point through
//! multiple execution modes — what-if simulation, real-time emulation,
//! figure regeneration — and comparing apples to apples. This module
//! makes that a first-class, enumerable capability:
//!
//! * [`Scenario`] — a named, self-describing experiment spec: description,
//!   typed [`ParamSchema`], and the [`Runner`] that executes it;
//! * [`Runner`] — the execution-mode trait; built-in implementations wrap
//!   [`crate::figures`], [`crate::sim`], [`crate::trainer`] and
//!   [`crate::sim::ablation`];
//! * [`Outcome`] — the uniform result record (series, tables, checks,
//!   metrics, timing), renderable to the terminal, CSV (byte-identical to
//!   the pre-engine paths) and JSON;
//! * [`ScenarioRegistry`] — the catalogue behind `netbn list` / `netbn
//!   run <scenario>`; [`ScenarioRegistry::builtin`] registers all 8 paper
//!   figures, simulate, emulate, validate, the four ablation sweeps,
//!   the four transport scenarios (`transport_ablation`,
//!   `chunk_size_sweep`, `fig4_recovered`, `utilization_frontier`),
//!   the three hierarchical scenarios (`hier_vs_flat`, `oversub_sweep`,
//!   `e2e_tcp_smoke`), the three overlap scenarios
//!   (`overlap_ablation`, `bucket_size_sweep`,
//!   `scaling_factor_recovered`), the three autotune scenarios
//!   (`autotune_convergence`, `autotune_vs_static`, `autotune_adapt`),
//!   the two service scenarios (`multi_tenant_contention`,
//!   `serve_throughput`) and the span-measured observability scenario
//!   (`utilization_timeline`); `netbn list --markdown` renders it as
//!   `docs/SCENARIOS.md`;
//! * [`jobqueue`] — the registry as a job-queue backend: wire-friendly
//!   [`jobqueue::JobRequest`] submissions, admission-time validation,
//!   and tuner-checkpoint warm starts (`netbn serve` drives this);
//! * [`bench`] — the perf-regression gate: collect throughput metrics
//!   from the gated scenarios and compare against `bench/baseline.json`
//!   (`netbn bench --compare`);
//! * [`SweepBuilder`] — cartesian grids over any scenario's parameters,
//!   executed serially or on a thread pool (`netbn sweep ... --parallel N`).
//!
//! Registering a new workload is additive: implement [`Runner`] (or use
//! [`Scenario::from_fn`]), describe the parameters, and register — no
//! dispatch code changes anywhere. See `ENGINE.md` for a worked example.

pub mod bench;
pub mod jobqueue;
pub mod outcome;
pub mod params;
pub mod registry;
pub mod runner;
pub(crate) mod scenarios_chaos;
pub(crate) mod scenarios_hier;
pub(crate) mod scenarios_obs;
pub(crate) mod scenarios_overlap;
pub(crate) mod scenarios_serve;
pub(crate) mod scenarios_transport;
pub(crate) mod scenarios_tune;
pub mod sweep;

pub use outcome::Outcome;
pub use params::{ParamKind, ParamSchema, ParamSpec, ParamValues};
pub use registry::{Scenario, ScenarioRegistry};
pub use runner::Runner;
pub use sweep::{SweepBuilder, SweepPoint};
