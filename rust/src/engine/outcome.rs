//! The uniform result record every scenario produces.
//!
//! An [`Outcome`] carries whatever a run generated — figure series,
//! tables, paper-shape checks, scalar metrics — plus identity (scenario
//! name, resolved parameters, execution mode) and timing metadata. One
//! record type means one emission path: the same `Outcome` renders to the
//! terminal, writes the CSVs the pre-engine commands wrote (byte-identical
//! — `Figure::write_csv` is unchanged), and serializes to JSON for
//! machine consumers (`netbn run <scenario> --json -`).

use crate::report::{json_str, render_checks, Check, Figure, Table};
use crate::Result;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// Uniform result of one scenario execution.
#[derive(Clone, Debug, Default)]
pub struct Outcome {
    /// Registered scenario name (filled by [`crate::engine::Scenario::run`]).
    pub scenario: String,
    /// Execution mode ("figure", "simulate", "emulate", "validate", "ablate", ...).
    pub mode: String,
    /// Resolved `(name, value)` parameters the run executed with.
    pub params: Vec<(String, String)>,
    /// Regenerated figure data series.
    pub figures: Vec<Figure>,
    /// Human-readable summary tables.
    pub tables: Vec<Table>,
    /// Paper-shape checks evaluated against the data.
    pub checks: Vec<Check>,
    /// Scalar results, e.g. `("scaling_factor", 0.71)`.
    pub metrics: Vec<(String, f64)>,
    /// Wall-clock seconds the runner took (filled by `Scenario::run`).
    pub wall_s: f64,
    /// The autotuner's chosen operating point in [`crate::tune::KnobPoint::spec`]
    /// form, when the run tuned one. Consumers that persist tuner state
    /// (`netbn serve`'s results store) read it back via
    /// `KnobPoint::parse_spec` — unlike the lossy `final_*` metrics, the
    /// spec round-trips every axis.
    pub tuned_knobs: Option<String>,
}

impl Outcome {
    pub fn new() -> Outcome {
        Outcome::default()
    }

    /// An outcome holding figures + their shape checks (the old
    /// `figures::FigureRun` payload).
    pub fn from_figures(figures: Vec<Figure>, checks: Vec<Check>) -> Outcome {
        Outcome { figures, checks, ..Outcome::default() }
    }

    /// Append a scalar metric.
    pub fn metric(&mut self, name: impl Into<String>, value: f64) {
        self.metrics.push((name.into(), value));
    }

    /// Look up a scalar metric.
    pub fn metric_value(&self, name: &str) -> Option<f64> {
        self.metrics.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
    }

    /// `true` when every check passed (vacuously true without checks).
    pub fn passed(&self) -> bool {
        self.checks.iter().all(|c| c.pass)
    }

    /// Write one CSV per figure into `out_dir`; returns the paths.
    pub fn write_csvs(&self, out_dir: &Path) -> Result<Vec<PathBuf>> {
        let mut paths = Vec::with_capacity(self.figures.len());
        for f in &self.figures {
            paths.push(f.write_csv(out_dir)?);
        }
        Ok(paths)
    }

    /// Render everything to stdout; persist CSVs when `out_dir` is given.
    /// Returns whether all checks passed. This reproduces the exact
    /// emission sequence of the pre-engine `fig` command (figure render,
    /// `  -> path` line per CSV, then the check block).
    pub fn emit(&self, out_dir: Option<&Path>) -> Result<bool> {
        for f in &self.figures {
            println!("{}", f.render());
            if let Some(dir) = out_dir {
                let path = f.write_csv(dir)?;
                println!("  -> {}", path.display());
            }
        }
        for t in &self.tables {
            println!("{}", t.render());
        }
        let mut ok = true;
        if !self.checks.is_empty() {
            let (text, all) = render_checks(&self.checks);
            println!("paper-shape checks:\n{text}");
            ok = all;
        }
        Ok(ok)
    }

    /// Hand-rolled JSON encoding (the offline build has no serde; same
    /// approach as [`Figure::to_json`]).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        let _ = write!(
            s,
            "{{\"scenario\":{},\"mode\":{},\"passed\":{},\"wall_s\":{}",
            json_str(&self.scenario),
            json_str(&self.mode),
            self.passed(),
            json_num(self.wall_s)
        );
        if let Some(spec) = &self.tuned_knobs {
            let _ = write!(s, ",\"tuned_knobs\":{}", json_str(spec));
        }
        s.push_str(",\"params\":{");
        for (i, (k, v)) in self.params.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(s, "{}:{}", json_str(k), json_str(v));
        }
        s.push_str("},\"metrics\":{");
        for (i, (k, v)) in self.metrics.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(s, "{}:{}", json_str(k), json_num(*v));
        }
        s.push_str("},\"checks\":[");
        for (i, c) in self.checks.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(
                s,
                "{{\"desc\":{},\"pass\":{},\"detail\":{}}}",
                json_str(&c.desc),
                c.pass,
                json_str(&c.detail)
            );
        }
        s.push_str("],\"figures\":[");
        for (i, f) in self.figures.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&f.to_json());
        }
        s.push_str("],\"tables\":[");
        for (i, t) in self.tables.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&t.to_json());
        }
        s.push_str("]}");
        s
    }
}

/// JSON-safe number: finite floats print as-is, anything else as null.
fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::report::Series;

    fn sample() -> Outcome {
        let mut fig = Figure::new("figX", "t", "x", "y");
        fig.series.push(Series { name: "s".into(), points: vec![(1.0, 2.0)] });
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into()]);
        Outcome {
            scenario: "demo".into(),
            mode: "figure".into(),
            params: vec![("k".into(), "v".into())],
            figures: vec![fig],
            tables: vec![t],
            checks: vec![Check::assert("c", true, "d")],
            metrics: vec![("scaling_factor".into(), 0.5), ("bad".into(), f64::NAN)],
            wall_s: 0.25,
            tuned_knobs: None,
        }
    }

    #[test]
    fn json_has_all_sections() {
        let j = sample().to_json();
        for needle in [
            "\"scenario\":\"demo\"",
            "\"mode\":\"figure\"",
            "\"passed\":true",
            "\"wall_s\":0.25",
            "\"params\":{\"k\":\"v\"}",
            "\"scaling_factor\":0.5",
            "\"bad\":null",
            "\"checks\":[{\"desc\":\"c\",\"pass\":true,\"detail\":\"d\"}]",
            "\"points\":[[1,2]]",
            "\"rows\":[[\"1\"]]",
        ] {
            assert!(j.contains(needle), "missing {needle} in {j}");
        }
    }

    #[test]
    fn tuned_knobs_serialize_only_when_present() {
        let mut o = sample();
        assert!(!o.to_json().contains("tuned_knobs"));
        o.tuned_knobs = Some("bucket_mb=4;stripes=1".into());
        assert!(o.to_json().contains("\"tuned_knobs\":\"bucket_mb=4;stripes=1\""));
    }

    #[test]
    fn passed_tracks_checks() {
        let mut o = sample();
        assert!(o.passed());
        o.checks.push(Check::assert("f", false, ""));
        assert!(!o.passed());
        assert!(Outcome::new().passed());
    }

    #[test]
    fn csvs_written_per_figure() {
        let dir = std::env::temp_dir().join("netbn_outcome_csv_test");
        let paths = sample().write_csvs(&dir).unwrap();
        assert_eq!(paths.len(), 1);
        assert!(paths[0].ends_with("figX.csv"));
        assert!(paths[0].exists());
    }

    #[test]
    fn metric_lookup() {
        let o = sample();
        assert_eq!(o.metric_value("scaling_factor"), Some(0.5));
        assert_eq!(o.metric_value("nope"), None);
    }
}
