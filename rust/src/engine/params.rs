//! Typed parameter schemas for scenarios.
//!
//! Every [`crate::engine::Scenario`] declares its parameters as a
//! [`ParamSchema`]: name, help, kind and default. CLI overrides
//! (`--param k=v`) are validated against the schema *before* the runner
//! executes, so runners only ever see well-formed values and `netbn run`
//! can reject typos with an error that lists the legal parameters.
//!
//! ```
//! use netbn::engine::{ParamKind, ParamSchema, ParamSpec};
//!
//! let schema = ParamSchema::new(vec![
//!     ParamSpec::new("bandwidth", "provisioned Gbps", ParamKind::PositiveFloat, "25"),
//! ]);
//! // Defaults merge with overrides into a fully validated set.
//! let vals = schema
//!     .resolve(&[("bandwidth".to_string(), "100".to_string())])
//!     .unwrap();
//! assert_eq!(vals.get_f64("bandwidth").unwrap(), 100.0);
//! // Typos and ill-typed values are rejected before any runner executes.
//! assert!(schema.resolve(&[("bandwdith".to_string(), "1".to_string())]).is_err());
//! assert!(schema.resolve(&[("bandwidth".to_string(), "-5".to_string())]).is_err());
//! ```

use crate::config::{CollectiveKind, Compression, TransportKind};
use crate::models::ModelId;
use crate::Result;
use anyhow::{anyhow, ensure};
use std::collections::BTreeMap;

/// What a parameter value must parse as.
#[derive(Clone, Debug)]
pub enum ParamKind {
    /// Non-negative integer (`usize`).
    Int,
    /// Finite float.
    Float,
    /// Finite float strictly greater than zero.
    PositiveFloat,
    /// Free-form string.
    Str,
    /// A [`ModelId`] name (`resnet50 | resnet101 | vgg16 | transformer`).
    Model,
    /// A [`TransportKind`] name (`full | kernel-tcp | tcp | single | striped:N`).
    Transport,
    /// A [`CollectiveKind`] name (`ring | tree | ps | hier:<group_size>`).
    Collective,
    /// A [`Compression`] spec: ratio >= 1 or codec name.
    Compression,
    /// Comma-separated list of positive floats.
    FloatList,
    /// One of a fixed set of strings.
    Choice(&'static [&'static str]),
}

impl ParamKind {
    /// Short human/markdown label for the catalog (`netbn list
    /// --markdown`, docs/SCENARIOS.md).
    pub fn label(&self) -> String {
        match self {
            ParamKind::Int => "int".into(),
            ParamKind::Float => "float".into(),
            ParamKind::PositiveFloat => "float > 0".into(),
            ParamKind::Str => "string".into(),
            ParamKind::Model => "model".into(),
            ParamKind::Transport => "transport".into(),
            ParamKind::Collective => "collective".into(),
            ParamKind::Compression => "compression".into(),
            ParamKind::FloatList => "float list".into(),
            ParamKind::Choice(choices) => choices.join("\\|"),
        }
    }
}

/// One declared parameter.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub kind: ParamKind,
    pub default: &'static str,
}

impl ParamSpec {
    pub fn new(
        name: &'static str,
        help: &'static str,
        kind: ParamKind,
        default: &'static str,
    ) -> ParamSpec {
        ParamSpec { name, help, kind, default }
    }

    /// Validate one value against this spec's kind.
    fn check(&self, v: &str) -> Result<()> {
        let name = self.name;
        match &self.kind {
            ParamKind::Int => {
                v.parse::<usize>()
                    .map_err(|_| anyhow!("parameter {name}: expected an integer, got {v:?}"))?;
            }
            ParamKind::Float => {
                let f = v
                    .parse::<f64>()
                    .map_err(|_| anyhow!("parameter {name}: expected a number, got {v:?}"))?;
                ensure!(f.is_finite(), "parameter {name}: must be finite, got {v:?}");
            }
            ParamKind::PositiveFloat => {
                let f = v
                    .parse::<f64>()
                    .map_err(|_| anyhow!("parameter {name}: expected a number, got {v:?}"))?;
                ensure!(f.is_finite() && f > 0.0, "parameter {name}: must be > 0, got {v:?}");
            }
            ParamKind::Str => {}
            ParamKind::Model => {
                ModelId::parse(v).ok_or_else(|| {
                    anyhow!("parameter {name}: unknown model {v:?} (resnet50|resnet101|vgg16|transformer)")
                })?;
            }
            ParamKind::Transport => {
                TransportKind::parse(v).ok_or_else(|| {
                    anyhow!(
                        "parameter {name}: unknown transport {v:?} \
                         (full|kernel-tcp|tcp|single|striped:N)"
                    )
                })?;
            }
            ParamKind::Collective => {
                CollectiveKind::parse(v).ok_or_else(|| {
                    anyhow!(
                        "parameter {name}: unknown collective {v:?} \
                         (ring|tree|ps|hier:<group_size>)"
                    )
                })?;
            }
            ParamKind::Compression => {
                Compression::parse(v)
                    .map_err(|e| anyhow!("parameter {name}: {e:#}"))?;
            }
            ParamKind::FloatList => {
                for part in v.split(',') {
                    let f = part.trim().parse::<f64>().map_err(|_| {
                        anyhow!("parameter {name}: bad list element {part:?} in {v:?}")
                    })?;
                    ensure!(
                        f.is_finite() && f > 0.0,
                        "parameter {name}: list elements must be > 0, got {part:?}"
                    );
                }
            }
            ParamKind::Choice(choices) => {
                ensure!(
                    choices.contains(&v),
                    "parameter {name}: {v:?} is not one of {}",
                    choices.join("|")
                );
            }
        }
        Ok(())
    }
}

/// A scenario's declared parameter set.
#[derive(Clone, Debug, Default)]
pub struct ParamSchema {
    specs: Vec<ParamSpec>,
}

impl ParamSchema {
    /// A schema with no parameters (figure scenarios).
    pub fn empty() -> ParamSchema {
        ParamSchema { specs: Vec::new() }
    }

    pub fn new(specs: Vec<ParamSpec>) -> ParamSchema {
        ParamSchema { specs }
    }

    pub fn specs(&self) -> &[ParamSpec] {
        &self.specs
    }

    fn spec(&self, name: &str) -> Option<&ParamSpec> {
        self.specs.iter().find(|s| s.name == name)
    }

    /// Merge defaults with overrides and validate everything; the result
    /// is the complete, well-formed parameter set the runner executes
    /// with. Unknown parameter names are rejected with the legal list.
    pub fn resolve(&self, overrides: &[(String, String)]) -> Result<ParamValues> {
        let mut vals = BTreeMap::new();
        for s in &self.specs {
            vals.insert(s.name.to_string(), s.default.to_string());
        }
        for (k, v) in overrides {
            let spec = self.spec(k).ok_or_else(|| {
                let known: Vec<&str> = self.specs.iter().map(|s| s.name).collect();
                if known.is_empty() {
                    anyhow!("unknown parameter {k:?}: this scenario takes no parameters")
                } else {
                    anyhow!("unknown parameter {k:?}; legal parameters: {}", known.join(", "))
                }
            })?;
            spec.check(v)?;
            vals.insert(k.clone(), v.clone());
        }
        // Defaults are compile-time constants, but validate them too so a
        // mistyped default fails loudly at the first run, not in a runner.
        for s in &self.specs {
            s.check(&vals[s.name])?;
        }
        Ok(ParamValues { vals })
    }
}

/// A fully resolved, validated parameter set (defaults + overrides).
#[derive(Clone, Debug)]
pub struct ParamValues {
    vals: BTreeMap<String, String>,
}

impl ParamValues {
    /// All resolved `(name, value)` pairs, sorted by name.
    pub fn resolved(&self) -> Vec<(String, String)> {
        self.vals.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
    }

    pub fn get_str(&self, name: &str) -> Result<&str> {
        self.vals
            .get(name)
            .map(|s| s.as_str())
            .ok_or_else(|| anyhow!("runner asked for undeclared parameter {name:?}"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        let v = self.get_str(name)?;
        v.parse().map_err(|_| anyhow!("parameter {name}: expected an integer, got {v:?}"))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        let v = self.get_str(name)?;
        v.parse().map_err(|_| anyhow!("parameter {name}: expected a number, got {v:?}"))
    }

    pub fn get_f64_list(&self, name: &str) -> Result<Vec<f64>> {
        let v = self.get_str(name)?;
        v.split(',')
            .map(|p| {
                p.trim()
                    .parse::<f64>()
                    .map_err(|_| anyhow!("parameter {name}: bad list element {p:?}"))
            })
            .collect()
    }

    pub fn get_model(&self, name: &str) -> Result<ModelId> {
        let v = self.get_str(name)?;
        ModelId::parse(v).ok_or_else(|| anyhow!("parameter {name}: unknown model {v:?}"))
    }

    pub fn get_transport(&self, name: &str) -> Result<TransportKind> {
        let v = self.get_str(name)?;
        TransportKind::parse(v).ok_or_else(|| anyhow!("parameter {name}: unknown transport {v:?}"))
    }

    pub fn get_collective(&self, name: &str) -> Result<CollectiveKind> {
        let v = self.get_str(name)?;
        CollectiveKind::parse(v)
            .ok_or_else(|| anyhow!("parameter {name}: unknown collective {v:?}"))
    }

    pub fn get_compression(&self, name: &str) -> Result<Compression> {
        Compression::parse(self.get_str(name)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn schema() -> ParamSchema {
        ParamSchema::new(vec![
            ParamSpec::new("workers", "worker count", ParamKind::Int, "4"),
            ParamSpec::new("bandwidth", "Gbps", ParamKind::PositiveFloat, "25"),
            ParamSpec::new("model", "model id", ParamKind::Model, "resnet50"),
            ParamSpec::new("compression", "ratio or codec", ParamKind::Compression, "1"),
            ParamSpec::new("collective", "allreduce algorithm", ParamKind::Collective, "ring"),
            ParamSpec::new("mode", "choice", ParamKind::Choice(&["a", "b"]), "a"),
        ])
    }

    fn kv(pairs: &[(&str, &str)]) -> Vec<(String, String)> {
        pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
    }

    #[test]
    fn defaults_resolve() {
        let p = schema().resolve(&[]).unwrap();
        assert_eq!(p.get_usize("workers").unwrap(), 4);
        assert_eq!(p.get_f64("bandwidth").unwrap(), 25.0);
        assert_eq!(p.get_model("model").unwrap(), ModelId::ResNet50);
        assert_eq!(p.get_compression("compression").unwrap().ratio(), 1.0);
    }

    #[test]
    fn overrides_apply_and_validate() {
        let p = schema()
            .resolve(&kv(&[
                ("workers", "8"),
                ("model", "vgg16"),
                ("compression", "topk:0.01"),
                ("collective", "hier:4"),
            ]))
            .unwrap();
        assert_eq!(p.get_usize("workers").unwrap(), 8);
        assert_eq!(p.get_model("model").unwrap(), ModelId::Vgg16);
        assert!((p.get_compression("compression").unwrap().ratio() - 50.0).abs() < 1e-9);
        assert_eq!(
            p.get_collective("collective").unwrap(),
            CollectiveKind::Hierarchical { group_size: 4 }
        );
    }

    #[test]
    fn unknown_parameter_lists_legal_names() {
        let err = schema().resolve(&kv(&[("bogus", "1")])).unwrap_err().to_string();
        assert!(err.contains("bogus"), "{err}");
        assert!(err.contains("workers"), "{err}");
        assert!(err.contains("bandwidth"), "{err}");
    }

    #[test]
    fn bad_values_rejected() {
        for (k, v) in [
            ("workers", "four"),
            ("workers", "-1"),
            ("bandwidth", "0"),
            ("bandwidth", "nan"),
            ("model", "alexnet"),
            ("compression", "topk:0"),
            ("compression", "0.5"),
            ("collective", "butterfly"),
            ("collective", "hier:0"),
            ("mode", "c"),
        ] {
            assert!(schema().resolve(&kv(&[(k, v)])).is_err(), "{k}={v} should be rejected");
        }
    }

    #[test]
    fn float_list_parses() {
        let s = ParamSchema::new(vec![ParamSpec::new(
            "bandwidths",
            "Gbps list",
            ParamKind::FloatList,
            "5,25,100",
        )]);
        let p = s.resolve(&[]).unwrap();
        assert_eq!(p.get_f64_list("bandwidths").unwrap(), vec![5.0, 25.0, 100.0]);
        assert!(s.resolve(&kv(&[("bandwidths", "5,x")])).is_err());
        assert!(s.resolve(&kv(&[("bandwidths", "5,-1")])).is_err());
    }
}
