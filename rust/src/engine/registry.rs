//! Named, discoverable scenarios.
//!
//! A [`Scenario`] binds a name + description + [`ParamSchema`] to a
//! [`Runner`]. The [`ScenarioRegistry`] is the single catalogue every
//! entry point goes through: `netbn list` prints it, `netbn run` and
//! `netbn sweep` look names up in it, and the legacy subcommands (`fig`,
//! `simulate`, `emulate`, `validate`, `ablate`) are thin aliases over it.
//! Later PRs register new workloads here instead of growing `main.rs`.

use super::outcome::Outcome;
use super::params::{ParamKind, ParamSchema, ParamSpec, ParamValues};
use super::runner::{
    AblateKind, AblateRunner, EmulateRunner, FigureRunner, Runner, SimulateRunner, ValidateRunner,
};
use crate::Result;
use anyhow::{anyhow, bail};
use std::time::Instant;

/// A named, self-describing experiment spec.
pub struct Scenario {
    name: &'static str,
    about: &'static str,
    schema: ParamSchema,
    runner: Box<dyn Runner>,
}

impl Scenario {
    pub fn new(
        name: &'static str,
        about: &'static str,
        schema: ParamSchema,
        runner: Box<dyn Runner>,
    ) -> Scenario {
        Scenario { name, about, schema, runner }
    }

    /// Build a scenario from a plain function — the lightest way to
    /// register a custom experiment (see ENGINE.md for a worked example).
    pub fn from_fn<F>(
        name: &'static str,
        about: &'static str,
        schema: ParamSchema,
        mode: &'static str,
        f: F,
    ) -> Scenario
    where
        F: Fn(&ParamValues) -> Result<Outcome> + Send + Sync + 'static,
    {
        Scenario::new(name, about, schema, Box::new(FnRunner { mode, f: Box::new(f) }))
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn about(&self) -> &'static str {
        self.about
    }

    pub fn schema(&self) -> &ParamSchema {
        &self.schema
    }

    pub fn mode(&self) -> &'static str {
        self.runner.mode()
    }

    /// `true` when this scenario measures real wall-clock behavior — see
    /// [`Runner::realtime`]; concurrent points would distort its numbers.
    pub fn realtime(&self) -> bool {
        self.runner.realtime()
    }

    /// Validate `overrides` against the schema, execute the runner, and
    /// stamp identity + timing metadata onto the outcome.
    pub fn run(&self, overrides: &[(String, String)]) -> Result<Outcome> {
        let vals = self.schema.resolve(overrides)?;
        let t0 = Instant::now();
        let mut out = self.runner.run(&vals)?;
        out.scenario = self.name.to_string();
        out.mode = self.runner.mode().to_string();
        out.params = vals.resolved();
        out.wall_s = t0.elapsed().as_secs_f64();
        Ok(out)
    }
}

/// Adapter: a closure as a [`Runner`].
struct FnRunner {
    mode: &'static str,
    #[allow(clippy::type_complexity)]
    f: Box<dyn Fn(&ParamValues) -> Result<Outcome> + Send + Sync>,
}

impl Runner for FnRunner {
    fn mode(&self) -> &'static str {
        self.mode
    }

    fn run(&self, params: &ParamValues) -> Result<Outcome> {
        (self.f)(params)
    }
}

/// The scenario catalogue.
pub struct ScenarioRegistry {
    scenarios: Vec<Scenario>,
}

impl ScenarioRegistry {
    /// An empty registry (tests and embedders).
    pub fn new() -> ScenarioRegistry {
        ScenarioRegistry { scenarios: Vec::new() }
    }

    /// All built-in scenarios: the 8 paper figures, the three execution
    /// modes (simulate / emulate / validate), the four ablation sweeps,
    /// the four transport scenarios (`transport_ablation`,
    /// `chunk_size_sweep`, `fig4_recovered`, `utilization_frontier`),
    /// the three hierarchical scenarios (`hier_vs_flat`, `oversub_sweep`,
    /// `e2e_tcp_smoke`), the three overlap scenarios
    /// (`overlap_ablation`, `bucket_size_sweep`,
    /// `scaling_factor_recovered`), the three autotune scenarios
    /// (`autotune_convergence`, `autotune_vs_static`, `autotune_adapt`),
    /// the two service scenarios (`multi_tenant_contention`,
    /// `serve_throughput`), the three chaos scenarios
    /// (`elastic_scaleout`, `straggler_injection`,
    /// `worker_crash_recovery`) and the span-measured observability
    /// scenario (`utilization_timeline`).
    pub fn builtin() -> ScenarioRegistry {
        let mut r = ScenarioRegistry::new();
        let figures: [(&'static str, &'static str, &'static str); 8] = [
            ("fig1", "1", "paper Fig 1: scaling factor vs servers (measured-mode, 100 Gbps)"),
            ("fig2", "2", "paper Fig 2: computation time vs servers"),
            ("fig3", "3", "paper Fig 3: scaling factor vs bandwidth (ResNet50)"),
            ("fig4", "4", "paper Fig 4: network utilization vs provisioned bandwidth"),
            ("fig5", "5", "paper Fig 5: CPU utilization vs network speed"),
            ("fig6", "6", "paper Fig 6: simulated vs measured scaling factor per model"),
            ("fig7", "7", "paper Fig 7: simulated scaling under 100 Gbps vs workers"),
            ("fig8", "8", "paper Fig 8: scaling factor vs compression ratio"),
        ];
        for (name, fig_id, about) in figures {
            r.register(Scenario::new(
                name,
                about,
                ParamSchema::empty(),
                Box::new(FigureRunner { fig_id }),
            ))
            .expect("builtin registration");
        }
        r.register(Scenario::new(
            "simulate",
            "what-if simulator at one experiment point",
            ParamSchema::new(vec![
                ParamSpec::new("model", "resnet50|resnet101|vgg16|transformer", ParamKind::Model, "resnet50"),
                ParamSpec::new("workers", "GPUs in the all-reduce", ParamKind::Int, "64"),
                ParamSpec::new("bandwidth", "provisioned Gbps", ParamKind::PositiveFloat, "100"),
                ParamSpec::new("transport", "full|kernel-tcp|striped:N", ParamKind::Transport, "full"),
                ParamSpec::new("compression", "wire ratio or codec (fp16, topk:0.01, ...)", ParamKind::Compression, "1"),
            ]),
            Box::new(SimulateRunner),
        ))
        .expect("builtin registration");
        r.register(Scenario::new(
            "emulate",
            "real-time emulator (modeled compute, shaped fabric)",
            ParamSchema::new(vec![
                ParamSpec::new("model", "resnet50|resnet101|vgg16", ParamKind::Model, "resnet50"),
                ParamSpec::new("servers", "server count (1 worker each)", ParamKind::Int, "4"),
                ParamSpec::new("bandwidth", "provisioned Gbps", ParamKind::PositiveFloat, "25"),
                ParamSpec::new("transport", "full|kernel-tcp|striped:N", ParamKind::Transport, "full"),
                ParamSpec::new("collective", "ring|tree|ps|hier:<g>", ParamKind::Collective, "ring"),
                ParamSpec::new(
                    "overlap",
                    "submit buckets during backward (buckets) or after (off)",
                    ParamKind::Choice(&["off", "buckets"]),
                    "buckets",
                ),
                ParamSpec::new(
                    "bucket-mb",
                    "DDP-style bucket threshold MB (0 = fusion buffer)",
                    ParamKind::Float,
                    "0",
                ),
                ParamSpec::new(
                    "autotune",
                    "tune bucket-mb x compression online from step feedback",
                    ParamKind::Choice(&["off", "on"]),
                    "off",
                ),
                ParamSpec::new("steps", "measured steps", ParamKind::Int, "5"),
                ParamSpec::new("payload-scale", "byte/rate shrink factor", ParamKind::PositiveFloat, "256"),
                ParamSpec::new("compression", "wire ratio or codec", ParamKind::Compression, "1"),
            ]),
            Box::new(EmulateRunner),
        ))
        .expect("builtin registration");
        r.register(Scenario::new(
            "validate",
            "cross-validate emulator vs simulator (the paper's Fig 6 logic)",
            ParamSchema::new(vec![
                ParamSpec::new("workers", "worker count", ParamKind::Int, "4"),
                ParamSpec::new("bandwidths", "comma list of Gbps", ParamKind::FloatList, "5,25,100"),
                ParamSpec::new("payload-scale", "byte/rate shrink factor", ParamKind::PositiveFloat, "1024"),
            ]),
            Box::new(ValidateRunner),
        ))
        .expect("builtin registration");
        let model_param =
            || ParamSpec::new("model", "resnet50|resnet101|vgg16", ParamKind::Model, "vgg16");
        r.register(Scenario::new(
            "ablate-fusion-size",
            "scaling factor vs fusion buffer size (measured-mode, 100 Gbps)",
            ParamSchema::new(vec![model_param()]),
            Box::new(AblateRunner { kind: AblateKind::FusionSize }),
        ))
        .expect("builtin registration");
        r.register(Scenario::new(
            "ablate-fusion-timeout",
            "scaling factor vs fusion timeout (measured-mode, 100 Gbps)",
            ParamSchema::new(vec![model_param()]),
            Box::new(AblateRunner { kind: AblateKind::FusionTimeout }),
        ))
        .expect("builtin registration");
        r.register(Scenario::new(
            "ablate-collectives",
            "analytic wire time of ring vs tree vs parameter-server",
            ParamSchema::new(vec![
                model_param(),
                ParamSpec::new("bandwidth", "provisioned Gbps", ParamKind::PositiveFloat, "100"),
            ]),
            Box::new(AblateRunner { kind: AblateKind::Collectives }),
        ))
        .expect("builtin registration");
        r.register(Scenario::new(
            "ablate-bw-compression",
            "scaling factor across the bandwidth x compression grid",
            ParamSchema::new(vec![model_param()]),
            Box::new(AblateRunner { kind: AblateKind::BwCompression }),
        ))
        .expect("builtin registration");
        super::scenarios_transport::register(&mut r).expect("builtin registration");
        super::scenarios_hier::register(&mut r).expect("builtin registration");
        super::scenarios_overlap::register(&mut r).expect("builtin registration");
        super::scenarios_tune::register(&mut r).expect("builtin registration");
        super::scenarios_serve::register(&mut r).expect("builtin registration");
        super::scenarios_chaos::register(&mut r).expect("builtin registration");
        super::scenarios_obs::register(&mut r).expect("builtin registration");
        r
    }

    /// Register a scenario; duplicate names are rejected.
    pub fn register(&mut self, scenario: Scenario) -> Result<()> {
        if self.scenarios.iter().any(|s| s.name == scenario.name) {
            bail!("scenario {:?} is already registered", scenario.name);
        }
        self.scenarios.push(scenario);
        Ok(())
    }

    /// Look a scenario up by name; the error lists every registered name.
    pub fn get(&self, name: &str) -> Result<&Scenario> {
        self.scenarios.iter().find(|s| s.name == name).ok_or_else(|| {
            anyhow!(
                "unknown scenario {name:?}; registered scenarios: {}",
                self.names().join(", ")
            )
        })
    }

    /// Registered names, in registration order.
    pub fn names(&self) -> Vec<&'static str> {
        self.scenarios.iter().map(|s| s.name).collect()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Scenario> {
        self.scenarios.iter()
    }

    /// Render the catalogue as Markdown — the generator behind
    /// `netbn list --markdown` and `docs/SCENARIOS.md` (CI regenerates
    /// the file and fails on drift, so the catalog can never go stale).
    pub fn markdown(&self) -> String {
        let mut s = String::new();
        s.push_str("# Scenario catalog\n\n");
        s.push_str(
            "<!-- GENERATED FILE - do not edit by hand. Regenerate with:\n     \
             netbn list --markdown > docs/SCENARIOS.md -->\n\n",
        );
        s.push_str(&format!(
            "{} scenarios are registered. Run one with `netbn run <name> [--param k=v ...]`; \
             sweep a grid with `netbn sweep <name> --grid k=v1,v2,... [--parallel N]`. \
             See ENGINE.md for the engine API.\n\n",
            self.len()
        ));
        // Pipes inside help/default strings would split table cells.
        let esc = |s: &str| s.replace('|', "\\|");
        s.push_str("| scenario | mode | description |\n|---|---|---|\n");
        for sc in self.iter() {
            s.push_str(&format!(
                "| [`{}`](#{}) | {} | {} |\n",
                sc.name(),
                sc.name(),
                sc.mode(),
                esc(sc.about())
            ));
        }
        for sc in self.iter() {
            s.push_str(&format!("\n## {}\n\n{}\n\n", sc.name(), sc.about()));
            let specs = sc.schema().specs();
            if specs.is_empty() {
                s.push_str("No parameters.\n");
            } else {
                s.push_str("| parameter | type | default | description |\n|---|---|---|---|\n");
                for p in specs {
                    s.push_str(&format!(
                        "| `{}` | {} | `{}` | {} |\n",
                        p.name,
                        p.kind.label(),
                        esc(p.default),
                        esc(p.help)
                    ));
                }
            }
        }
        s
    }

    pub fn len(&self) -> usize {
        self.scenarios.len()
    }

    pub fn is_empty(&self) -> bool {
        self.scenarios.is_empty()
    }
}

impl Default for ScenarioRegistry {
    fn default() -> Self {
        ScenarioRegistry::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_covers_every_entry_point() {
        let r = ScenarioRegistry::builtin();
        assert!(r.len() >= 34, "only {} scenarios", r.len());
        for name in [
            "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "simulate",
            "emulate", "validate", "ablate-fusion-size", "ablate-fusion-timeout",
            "ablate-collectives", "ablate-bw-compression", "transport_ablation",
            "chunk_size_sweep", "fig4_recovered", "utilization_frontier", "hier_vs_flat",
            "oversub_sweep", "e2e_tcp_smoke", "overlap_ablation", "bucket_size_sweep",
            "scaling_factor_recovered", "autotune_convergence", "autotune_vs_static",
            "autotune_adapt", "multi_tenant_contention", "serve_throughput",
            "elastic_scaleout", "straggler_injection", "worker_crash_recovery",
            "utilization_timeline",
        ] {
            assert!(r.get(name).is_ok(), "missing {name}");
        }
    }

    #[test]
    fn markdown_catalog_is_complete() {
        let r = ScenarioRegistry::builtin();
        let md = r.markdown();
        assert!(md.starts_with("# Scenario catalog"));
        assert!(md.contains("GENERATED FILE"));
        for sc in r.iter() {
            assert!(md.contains(&format!("\n## {}\n", sc.name())), "missing section {}", sc.name());
            for p in sc.schema().specs() {
                assert!(
                    md.contains(&format!("| `{}` |", p.name)),
                    "{}: missing parameter row {}",
                    sc.name(),
                    p.name
                );
            }
        }
    }

    #[test]
    fn docs_scenarios_md_tracks_registry() {
        // docs/SCENARIOS.md is generated output; CI regenerates it via
        // `netbn list --markdown` and diffs byte-for-byte. This test is
        // the offline structural guard: registering a scenario (or a
        // parameter) without regenerating the doc fails here too.
        let on_disk = include_str!("../../../docs/SCENARIOS.md");
        assert!(on_disk.contains("GENERATED FILE"), "docs/SCENARIOS.md lost its generated header");
        let r = ScenarioRegistry::builtin();
        for sc in r.iter() {
            assert!(
                on_disk.contains(&format!("\n## {}\n", sc.name())),
                "docs/SCENARIOS.md is stale: missing {} (regenerate with `netbn list --markdown`)",
                sc.name()
            );
            for p in sc.schema().specs() {
                assert!(
                    on_disk.contains(&format!("| `{}` |", p.name)),
                    "docs/SCENARIOS.md is stale: {} lost parameter {} \
                     (regenerate with `netbn list --markdown`)",
                    sc.name(),
                    p.name
                );
            }
        }
    }

    #[test]
    fn unknown_name_error_lists_registered() {
        let err = ScenarioRegistry::builtin().get("fig9").unwrap_err().to_string();
        assert!(err.contains("fig9"), "{err}");
        assert!(err.contains("fig1"), "{err}");
        assert!(err.contains("simulate"), "{err}");
    }

    #[test]
    fn duplicate_registration_rejected() {
        let mut r = ScenarioRegistry::new();
        let mk = || {
            Scenario::from_fn("dup", "", ParamSchema::empty(), "test", |_| Ok(Outcome::new()))
        };
        r.register(mk()).unwrap();
        assert!(r.register(mk()).is_err());
    }

    #[test]
    fn run_stamps_identity_params_and_timing() {
        let r = ScenarioRegistry::builtin();
        let out = r
            .get("simulate")
            .unwrap()
            .run(&[("workers".to_string(), "8".to_string())])
            .unwrap();
        assert_eq!(out.scenario, "simulate");
        assert_eq!(out.mode, "simulate");
        assert!(out.wall_s >= 0.0);
        assert!(out.params.iter().any(|(k, v)| k == "workers" && v == "8"));
        // Defaults are present too.
        assert!(out.params.iter().any(|(k, v)| k == "transport" && v == "full"));
    }

    #[test]
    fn run_rejects_bad_overrides_before_executing() {
        let r = ScenarioRegistry::builtin();
        let err = r
            .get("simulate")
            .unwrap()
            .run(&[("bandwidth".to_string(), "-5".to_string())])
            .unwrap_err()
            .to_string();
        assert!(err.contains("bandwidth"), "{err}");
    }
}
