//! Execution modes behind scenarios.
//!
//! A [`Runner`] turns validated [`ParamValues`] into an [`Outcome`]. The
//! four built-in runners wrap the pre-existing subsystems — they own no
//! experiment logic of their own:
//!
//! * [`FigureRunner`] → [`crate::figures`] (paper figure regeneration),
//! * [`SimulateRunner`] → [`crate::sim`] (the §3 what-if simulator),
//! * [`EmulateRunner`] → [`crate::trainer::run_emulated`] (real-time emulator),
//! * [`ValidateRunner`] → emulator-vs-simulator cross-validation,
//! * [`AblateRunner`] → [`crate::sim::ablation`] sweeps.

use super::outcome::Outcome;
use super::params::ParamValues;
use crate::config::{ExperimentConfig, TransportKind};
use crate::models::timing::backward_trace;
use crate::models::ModelId;
use crate::report::Table;
use crate::sim::{ablation, simulate, SimParams};
use crate::trainer::{run_emulated, EmulatedRunConfig};
use crate::util::fmt;
use crate::Result;
use anyhow::ensure;

/// An execution mode: validated parameters in, uniform [`Outcome`] out.
///
/// Runners must be `Send + Sync` so sweeps can execute scenario points on
/// a thread pool.
pub trait Runner: Send + Sync {
    /// Mode label surfaced in `netbn list` and in `Outcome::mode`.
    fn mode(&self) -> &'static str;

    /// `true` when the runner measures real wall-clock behavior (emulation
    /// with real sleeps/threads). Running such points concurrently
    /// oversubscribes the host and distorts the measurements, so sweeps
    /// warn before parallelizing them; analytic runners stay `false`.
    fn realtime(&self) -> bool {
        false
    }

    /// Execute with a fully resolved parameter set.
    fn run(&self, params: &ParamValues) -> Result<Outcome>;
}

/// Wraps [`crate::figures::run_figure`]: regenerates one paper figure and
/// its paper-shape checks.
pub struct FigureRunner {
    /// The `figures` module id ("1".."8").
    pub fig_id: &'static str,
}

impl Runner for FigureRunner {
    fn mode(&self) -> &'static str {
        "figure"
    }

    fn run(&self, _params: &ParamValues) -> Result<Outcome> {
        Ok(crate::figures::run_figure(self.fig_id)?.into())
    }
}

/// Wraps the what-if simulator at one experiment point.
pub struct SimulateRunner;

impl Runner for SimulateRunner {
    fn mode(&self) -> &'static str {
        "simulate"
    }

    fn run(&self, p: &ParamValues) -> Result<Outcome> {
        let model = p.get_model("model")?;
        let workers = p.get_usize("workers")?;
        ensure!(workers >= 1, "parameter workers: must be >= 1");
        let bw = p.get_f64("bandwidth")?;
        let transport = p.get_transport("transport")?;
        let ratio = p.get_compression("compression")?.ratio();
        let trace = backward_trace(&model.profile());
        // Cluster shaping: up to 8 GPUs per server (p3dn), the rest as
        // extra servers. Counts that don't decompose exactly are rejected
        // rather than silently truncated — the Outcome stamps `workers`
        // into structured output, so every requested worker must exist.
        ensure!(
            workers <= 8 || workers % 8 == 0,
            "parameter workers: counts > 8 must be a multiple of 8 (8 GPUs per server), got {workers}"
        );
        let gpus = 8.min(workers);
        let servers = workers / gpus;
        let mut sp = match transport {
            TransportKind::KernelTcp => SimParams::horovod_like(trace, servers, gpus, bw),
            TransportKind::Striped { streams } => {
                SimParams::striped_like(trace, servers, gpus, bw, streams)
            }
            _ => SimParams::whatif(trace, servers, gpus, bw),
        };
        sp.compression_ratio = ratio;
        let r = simulate(&sp);

        let mut t = Table::new(
            format!("what-if: {model}, {workers} workers, {bw} Gbps, {transport}, {ratio}x"),
            &["metric", "value"],
        );
        t.row(vec!["t_batch".into(), fmt::secs(r.t_batch)]);
        t.row(vec!["t_back".into(), fmt::secs(r.t_back)]);
        t.row(vec!["t_sync".into(), fmt::secs(r.t_sync)]);
        t.row(vec!["t_overhead".into(), fmt::secs(r.t_overhead)]);
        t.row(vec!["scaling factor".into(), fmt::pct(r.scaling_factor)]);
        t.row(vec!["buckets".into(), r.buckets.to_string()]);
        t.row(vec!["wire bytes/worker".into(), fmt::bytes(r.wire_bytes_per_worker)]);
        t.row(vec!["achieved rate".into(), format!("{:.2} Gbps", r.achieved_gbps)]);

        let mut out = Outcome::new();
        out.tables.push(t);
        out.metric("t_batch_s", r.t_batch);
        out.metric("t_back_s", r.t_back);
        out.metric("t_sync_s", r.t_sync);
        out.metric("t_overhead_s", r.t_overhead);
        out.metric("scaling_factor", r.scaling_factor);
        out.metric("buckets", r.buckets as f64);
        out.metric("wire_bytes_per_worker", r.wire_bytes_per_worker);
        out.metric("achieved_gbps", r.achieved_gbps);
        Ok(out)
    }
}

/// Wraps the real-time emulator (modeled compute, shaped fabric, real
/// bytes).
pub struct EmulateRunner;

impl Runner for EmulateRunner {
    fn mode(&self) -> &'static str {
        "emulate"
    }

    fn realtime(&self) -> bool {
        true
    }

    fn run(&self, p: &ParamValues) -> Result<Outcome> {
        let model = p.get_model("model")?;
        let servers = p.get_usize("servers")?;
        ensure!(servers >= 1, "parameter servers: must be >= 1");
        let bw = p.get_f64("bandwidth")?;
        let steps = p.get_usize("steps")?;
        ensure!(steps >= 1, "parameter steps: must be >= 1");
        let payload_scale = p.get_f64("payload-scale")?;
        let transport = p.get_transport("transport")?;
        let collective = p.get_collective("collective")?;
        let compression = p.get_compression("compression")?;
        let overlap = crate::config::OverlapMode::parse(p.get_str("overlap")?)
            .expect("schema-validated choice");
        let bucket_mb = p.get_f64("bucket-mb")?;
        let mut exp = ExperimentConfig {
            model,
            servers,
            gpus_per_server: 1,
            bandwidth_gbps: bw,
            transport,
            collective,
            overlap,
            bucket_mb,
            compression,
            steps,
            warmup_steps: 1,
            ..Default::default()
        };
        exp.autotune.enabled = p.get_str("autotune")? == "on";
        let r = run_emulated(&EmulatedRunConfig { exp, payload_scale })?;

        let mut t = Table::new(
            format!("emulated: {model}, {servers} servers, {bw} Gbps, {transport}"),
            &["metric", "value"],
        );
        t.row(vec!["step time".into(), fmt::secs(r.step_time_s)]);
        t.row(vec!["throughput".into(), format!("{:.1} samples/s", r.throughput)]);
        t.row(vec!["scaling factor".into(), fmt::pct(r.scaling_factor)]);
        t.row(vec!["mean compute".into(), fmt::secs(r.mean_compute_s)]);
        t.row(vec!["mean comm wait".into(), fmt::secs(r.mean_comm_wait_s)]);
        t.row(vec!["network utilization".into(), fmt::pct(r.network_utilization)]);
        t.row(vec!["buckets/step".into(), format!("{:.1}", r.buckets_per_step)]);

        let mut out = Outcome::new();
        out.tables.push(t);
        out.metric("step_time_s", r.step_time_s);
        out.metric("throughput", r.throughput);
        out.metric("scaling_factor", r.scaling_factor);
        out.metric("mean_compute_s", r.mean_compute_s);
        out.metric("mean_comm_wait_s", r.mean_comm_wait_s);
        out.metric("network_utilization", r.network_utilization);
        out.metric("buckets_per_step", r.buckets_per_step);
        if let Some(summary) = &r.autotune {
            out.tuned_knobs = Some(summary.final_knobs.spec());
            out.metric("knob_changes", summary.changes as f64);
            out.metric("final_bucket_mb", summary.final_knobs.bucket_mb);
            out.metric("final_compression_ratio", summary.final_knobs.compression.ratio());
            let mut tt = Table::new(
                format!("autotune trajectory ({} applied points)", summary.trajectory.len()),
                &["from step", "knobs"],
            );
            for (step, point) in &summary.trajectory {
                tt.row(vec![step.to_string(), point.spec()]);
            }
            out.tables.push(tt);
        }
        Ok(out)
    }
}

/// Cross-validates emulator against simulator across a bandwidth list
/// (the paper's Fig 6 logic).
pub struct ValidateRunner;

impl Runner for ValidateRunner {
    fn mode(&self) -> &'static str {
        "validate"
    }

    fn realtime(&self) -> bool {
        true
    }

    fn run(&self, p: &ParamValues) -> Result<Outcome> {
        let workers = p.get_usize("workers")?;
        ensure!(workers >= 1, "parameter workers: must be >= 1");
        let bws = p.get_f64_list("bandwidths")?;
        ensure!(!bws.is_empty(), "parameter bandwidths: list is empty");
        let payload_scale = p.get_f64("payload-scale")?;
        let mut out = Outcome::new();
        let mut t = Table::new(
            "emulator vs simulator (full-utilization transport)",
            &["model", "Gbps", "emulated sf", "simulated sf"],
        );
        // Metric keys are by bandwidth; a repeated bandwidth gets a #n
        // suffix so the JSON metrics object never carries duplicate keys.
        let mut seen: std::collections::BTreeMap<String, usize> = std::collections::BTreeMap::new();
        for bw in bws {
            let (e, s, check) = crate::figures::validate_emulator_against_sim(
                ModelId::ResNet50,
                workers,
                bw,
                payload_scale,
            )?;
            t.row(vec!["ResNet50".into(), format!("{bw}"), format!("{e:.3}"), format!("{s:.3}")]);
            let n = seen.entry(format!("{bw}")).or_insert(0);
            *n += 1;
            let suffix = if *n > 1 { format!("#{n}") } else { String::new() };
            out.metric(format!("emulated_sf@{bw}g{suffix}"), e);
            out.metric(format!("simulated_sf@{bw}g{suffix}"), s);
            out.checks.push(check);
        }
        out.tables.push(t);
        Ok(out)
    }
}

/// Which ablation sweep to run.
#[derive(Clone, Copy, Debug)]
pub enum AblateKind {
    FusionSize,
    FusionTimeout,
    Collectives,
    BwCompression,
}

/// Wraps one [`crate::sim::ablation`] sweep.
pub struct AblateRunner {
    pub kind: AblateKind,
}

impl Runner for AblateRunner {
    fn mode(&self) -> &'static str {
        "ablate"
    }

    fn run(&self, p: &ParamValues) -> Result<Outcome> {
        let model = p.get_model("model")?;
        let fig = match self.kind {
            AblateKind::FusionSize => ablation::ablate_fusion_size(model),
            AblateKind::FusionTimeout => ablation::ablate_fusion_timeout(model),
            AblateKind::Collectives => {
                ablation::ablate_collective_cost(model, p.get_f64("bandwidth")?)
            }
            AblateKind::BwCompression => ablation::ablate_bw_compression_grid(model),
        };
        Ok(Outcome::from_figures(vec![fig], Vec::new()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::params::{ParamKind, ParamSchema, ParamSpec};

    fn simulate_schema() -> ParamSchema {
        ParamSchema::new(vec![
            ParamSpec::new("model", "", ParamKind::Model, "resnet50"),
            ParamSpec::new("workers", "", ParamKind::Int, "64"),
            ParamSpec::new("bandwidth", "", ParamKind::PositiveFloat, "100"),
            ParamSpec::new("transport", "", ParamKind::Transport, "full"),
            ParamSpec::new("compression", "", ParamKind::Compression, "1"),
        ])
    }

    #[test]
    fn simulate_runner_produces_metrics_and_table() {
        let p = simulate_schema().resolve(&[]).unwrap();
        let out = SimulateRunner.run(&p).unwrap();
        assert_eq!(out.tables.len(), 1);
        let sf = out.metric_value("scaling_factor").unwrap();
        assert!((0.0..=1.0).contains(&sf), "{sf}");
        assert!(out.metric_value("t_sync_s").unwrap() > 0.0);
    }

    #[test]
    fn simulate_runner_named_codec_equals_its_ratio() {
        // fp16 == ratio 2: the satellite unification in action.
        let run = |compression: &str| {
            let p = simulate_schema()
                .resolve(&[("compression".to_string(), compression.to_string())])
                .unwrap();
            SimulateRunner.run(&p).unwrap().metric_value("scaling_factor").unwrap()
        };
        assert_eq!(run("fp16"), run("2"));
    }

    #[test]
    fn simulate_runner_striped_beats_single_stream() {
        let run = |transport: &str| {
            let p = simulate_schema()
                .resolve(&[("transport".to_string(), transport.to_string())])
                .unwrap();
            SimulateRunner.run(&p).unwrap().metric_value("scaling_factor").unwrap()
        };
        // Same point, repaired transport: scaling factor climbs.
        assert!(run("striped:8") > run("kernel-tcp") + 0.05);
        // `single` is the kernel-TCP path by another name.
        assert_eq!(run("single"), run("kernel-tcp"));
    }

    #[test]
    fn figure_runner_wraps_figures() {
        let p = ParamSchema::empty().resolve(&[]).unwrap();
        let out = FigureRunner { fig_id: "1" }.run(&p).unwrap();
        assert!(!out.figures.is_empty());
        assert!(!out.checks.is_empty());
        assert!(out.passed(), "fig1 shape checks should pass");
    }

    #[test]
    fn ablate_runner_produces_figures() {
        let schema = ParamSchema::new(vec![
            ParamSpec::new("model", "", ParamKind::Model, "vgg16"),
            ParamSpec::new("bandwidth", "", ParamKind::PositiveFloat, "100"),
        ]);
        let p = schema.resolve(&[]).unwrap();
        for kind in [
            AblateKind::FusionSize,
            AblateKind::FusionTimeout,
            AblateKind::Collectives,
            AblateKind::BwCompression,
        ] {
            let out = AblateRunner { kind }.run(&p).unwrap();
            assert_eq!(out.figures.len(), 1);
            assert!(!out.figures[0].series.is_empty());
        }
    }
}
