//! Chaos scenarios: elastic membership, fault injection, crash recovery.
//!
//! The paper's measurements assume a *stable* cohort; these scenarios
//! probe the opposite regime — the launch path under membership churn
//! and worker death — and hold it to the same determinism bar as the
//! steady-state runs:
//!
//! * `elastic_scaleout` — workers join and leave at step boundaries;
//!   the final tensor must stay FNV-bit-identical to the fixed-
//!   membership oracle (re-sharding moved bytes, never arithmetic);
//! * `straggler_injection` — one worker gets per-step compute skew;
//!   the cohort-median compute score must flag exactly that worker
//!   (`harness=model` scores synthetic feedback rings in isolation,
//!   `harness=launch` drives a real cohort over loopback sockets);
//! * `worker_crash_recovery` — a worker dies mid-run (SIGKILL of the
//!   real OS process in `spawn=process`, an abrupt socket drop in
//!   `spawn=thread`); with recovery on the run must complete
//!   bit-identical to the oracle, with recovery off it must fail fast
//!   naming the dead worker instead of wedging.

use super::outcome::Outcome;
use super::params::{ParamKind, ParamSchema, ParamSpec, ParamValues};
use super::registry::{Scenario, ScenarioRegistry};
use crate::report::Check;
use crate::trainer::elastic::{
    elastic_launch, expected_checksum, ElasticConfig, ElasticParams, MembershipPlan,
};
use crate::trainer::launch::SpawnMode;
use crate::tune::{straggler_scores, FeedbackRing, StepFeedback};
use crate::Result;
use anyhow::ensure;
use std::time::Instant;

/// Register the three chaos scenarios (called from
/// [`ScenarioRegistry::builtin`]).
pub(crate) fn register(r: &mut ScenarioRegistry) -> Result<()> {
    r.register(Scenario::new(
        "elastic_scaleout",
        "elastic cohort: join/leave at step boundaries, bit-identical to the fixed-membership oracle",
        ParamSchema::new(vec![
            ParamSpec::new("workers", "initial cohort size", ParamKind::Int, "2"),
            ParamSpec::new("steps", "total training steps", ParamKind::Int, "6"),
            ParamSpec::new("join-step", "a new worker joins at this boundary (0 = never)", ParamKind::Int, "2"),
            ParamSpec::new("leave-step", "worker 1 departs at this boundary (0 = never)", ParamKind::Int, "4"),
            ParamSpec::new("shards", "fixed logical shard count", ParamKind::Int, "8"),
            ParamSpec::new("elems", "parameter tensor length (f32)", ParamKind::Int, "4096"),
            ParamSpec::new("seed", "gradient RNG seed", ParamKind::Int, "57765"),
        ]),
        Box::new(ElasticScaleoutRunner),
    ))?;
    r.register(Scenario::new(
        "straggler_injection",
        "inject per-step compute skew into one worker; median scoring must flag exactly it",
        ParamSchema::new(vec![
            ParamSpec::new(
                "harness",
                "model (synthetic feedback rings) or launch (real loopback cohort)",
                ParamKind::Choice(&["model", "launch"]),
                "model",
            ),
            ParamSpec::new("workers", "cohort size", ParamKind::Int, "3"),
            ParamSpec::new("steps", "scored steps", ParamKind::Int, "5"),
            ParamSpec::new("compute-us", "baseline modeled compute per step (us)", ParamKind::Int, "300"),
            ParamSpec::new("extra-us", "skew added to the straggler per step (us)", ParamKind::Int, "8000"),
            ParamSpec::new("window", "scoring window (newest steps)", ParamKind::Int, "8"),
            ParamSpec::new("threshold", "flag when compute exceeds threshold x cohort median", ParamKind::PositiveFloat, "3"),
        ]),
        Box::new(StragglerInjectionRunner),
    ))?;
    r.register(Scenario::new(
        "worker_crash_recovery",
        "kill a worker mid-run; recover bit-identical from checkpoint, or fail fast naming it",
        ParamSchema::new(vec![
            ParamSpec::new(
                "spawn",
                "process (real `netbn _eworker` processes, SIGKILL) or thread (socket drop)",
                ParamKind::Choice(&["process", "thread"]),
                "process",
            ),
            ParamSpec::new("workers", "cohort size", ParamKind::Int, "3"),
            ParamSpec::new("steps", "total training steps", ParamKind::Int, "6"),
            ParamSpec::new("die-step", "the victim dies once it reaches this step", ParamKind::Int, "2"),
            ParamSpec::new(
                "recovery",
                "replay the dead worker's shards from checkpoint (on) or require fail-fast (off)",
                ParamKind::Choice(&["on", "off"]),
                "on",
            ),
            ParamSpec::new("shards", "fixed logical shard count", ParamKind::Int, "8"),
            ParamSpec::new("elems", "parameter tensor length (f32)", ParamKind::Int, "4096"),
            ParamSpec::new("seed", "gradient RNG seed", ParamKind::Int, "57765"),
        ]),
        Box::new(CrashRecoveryRunner),
    ))?;
    Ok(())
}

/// Shared shape-parameter extraction for the elastic scenarios.
fn elastic_params(p: &ParamValues) -> Result<(usize, ElasticParams)> {
    let workers = p.get_usize("workers")?;
    ensure!((1..=8).contains(&workers), "parameter workers: must be in 1..=8, got {workers}");
    let steps = p.get_usize("steps")?;
    ensure!((2..=100).contains(&steps), "parameter steps: must be in 2..=100, got {steps}");
    let shards = p.get_usize("shards")?;
    let elems = p.get_usize("elems")?;
    ensure!(elems >= 1, "parameter elems: must be >= 1");
    let params = ElasticParams {
        shards,
        elems,
        steps,
        seed: p.get_usize("seed")? as u64,
        ..ElasticParams::default()
    };
    Ok((workers, params))
}

struct ElasticScaleoutRunner;

impl super::runner::Runner for ElasticScaleoutRunner {
    fn mode(&self) -> &'static str {
        "e2e"
    }

    fn realtime(&self) -> bool {
        true
    }

    fn run(&self, p: &ParamValues) -> Result<Outcome> {
        let (workers, params) = elastic_params(p)?;
        let join = p.get_usize("join-step")?;
        let leave = p.get_usize("leave-step")?;
        let mut plan = MembershipPlan {
            initial: (1..=workers as u64).collect(),
            ..MembershipPlan::default()
        };
        if join > 0 {
            plan.joins.push((workers as u64 + 1, join));
        }
        if leave > 0 {
            plan.leaves.push((1, leave));
        }
        // 1 epoch per distinct scheduled boundary inside the run.
        let boundaries: std::collections::BTreeSet<usize> = plan
            .joins
            .iter()
            .chain(plan.leaves.iter())
            .map(|(_, s)| *s)
            .collect();
        let expected_epochs = 1 + boundaries.len();
        let final_world = plan.active_at(params.steps).len();
        let oracle = expected_checksum(&params);
        let r = elastic_launch(&ElasticConfig::loopback(params, plan))?;

        let mut out = Outcome::new();
        out.metric("epochs", r.epochs as f64);
        out.metric("final_world", r.final_world as f64);
        out.checks.push(Check::assert(
            "elastic checksum bit-identical to the fixed-membership oracle",
            r.checksum == oracle,
            format!("{:x} vs oracle {oracle:x}", r.checksum),
        ));
        out.checks.push(Check::assert(
            "one membership epoch per scheduled boundary",
            r.epochs == expected_epochs,
            format!("{} epochs, {} boundaries", r.epochs, boundaries.len()),
        ));
        out.checks.push(Check::assert(
            "final cohort matches the schedule",
            r.final_world == final_world,
            format!("{} vs planned {final_world}", r.final_world),
        ));
        Ok(out)
    }
}

struct StragglerInjectionRunner;

impl super::runner::Runner for StragglerInjectionRunner {
    fn mode(&self) -> &'static str {
        "e2e"
    }

    fn realtime(&self) -> bool {
        true
    }

    fn run(&self, p: &ParamValues) -> Result<Outcome> {
        let workers = p.get_usize("workers")?;
        ensure!((2..=8).contains(&workers), "parameter workers: must be in 2..=8, got {workers}");
        let steps = p.get_usize("steps")?;
        ensure!((1..=100).contains(&steps), "parameter steps: must be in 1..=100, got {steps}");
        let compute_us = p.get_usize("compute-us")? as u64;
        ensure!(compute_us > 0, "parameter compute-us: must be > 0");
        let extra_us = p.get_usize("extra-us")? as u64;
        ensure!(extra_us > 0, "parameter extra-us: must be > 0");
        let window = p.get_usize("window")?;
        ensure!(window >= 1, "parameter window: must be >= 1");
        let threshold = p.get_f64("threshold")?;
        ensure!(threshold > 1.0, "parameter threshold: must be > 1, got {threshold}");
        let slow = workers as u64; // the last uid straggles

        let scores = match p.get_str("harness")? {
            "launch" => {
                let params = ElasticParams {
                    steps,
                    compute_us,
                    straggler_window: window,
                    straggler_threshold: threshold,
                    ..ElasticParams::default()
                };
                let plan = MembershipPlan {
                    initial: (1..=workers as u64).collect(),
                    ..MembershipPlan::default()
                };
                let mut cfg = ElasticConfig::loopback(params, plan);
                cfg.fault.straggle = vec![(slow, extra_us)];
                elastic_launch(&cfg)?.stragglers
            }
            _ => {
                // Synthetic rings: same scorer, no sockets — the cheap
                // harness CI can always afford.
                let mk = |per_step_us: u64| {
                    let mut r = FeedbackRing::new(window.max(steps));
                    for s in 0..steps {
                        let c = per_step_us as f64 * 1e-6;
                        r.push(StepFeedback {
                            step: s as u64,
                            // Synchronous loop: walls equalize at the
                            // slowest rank, so wall carries no signal.
                            wall_s: (compute_us + extra_us) as f64 * 1e-6,
                            compute_s: c,
                            comm_busy_s: 0.0,
                            busbw_gbps: 0.0,
                        });
                    }
                    r
                };
                let rings: Vec<(u64, FeedbackRing)> = (1..=workers as u64)
                    .map(|u| (u, mk(if u == slow { compute_us + extra_us } else { compute_us })))
                    .collect();
                let refs: Vec<(u64, &FeedbackRing)> =
                    rings.iter().map(|(u, r)| (*u, r)).collect();
                straggler_scores(&refs, window, threshold)
            }
        };

        let flagged: Vec<u64> =
            scores.iter().filter(|s| s.straggler).map(|s| s.id).collect();
        let slow_score =
            scores.iter().find(|s| s.id == slow).map_or(0.0, |s| s.score);
        let mut out = Outcome::new();
        out.metric("straggler_score", slow_score);
        out.metric("flagged", flagged.len() as f64);
        out.checks.push(Check::assert(
            "exactly the skewed worker is flagged",
            flagged == vec![slow],
            format!("flagged {flagged:?}, injected uid {slow} (score {slow_score:.2}x median)"),
        ));
        Ok(out)
    }
}

struct CrashRecoveryRunner;

impl super::runner::Runner for CrashRecoveryRunner {
    fn mode(&self) -> &'static str {
        "e2e"
    }

    fn realtime(&self) -> bool {
        true
    }

    fn run(&self, p: &ParamValues) -> Result<Outcome> {
        let (workers, params) = elastic_params(p)?;
        ensure!(workers >= 2, "parameter workers: crash recovery needs >= 2, got {workers}");
        let die = p.get_usize("die-step")?;
        ensure!(die < params.steps, "parameter die-step: must be inside the run");
        let spawn = match p.get_str("spawn")? {
            "thread" => SpawnMode::Thread,
            _ => SpawnMode::Process,
        };
        let recovery = p.get_str("recovery")? == "on";
        let victim = workers as u64; // the last uid dies
        let oracle = expected_checksum(&params);
        let timeout = params.rendezvous_timeout;
        let plan = MembershipPlan {
            initial: (1..=workers as u64).collect(),
            ..MembershipPlan::default()
        };
        let mut cfg = ElasticConfig::loopback(params, plan);
        cfg.spawn = spawn;
        cfg.fault.recovery = recovery;
        if spawn == SpawnMode::Process {
            // The real thing: the coordinator SIGKILLs the victim's OS
            // process once it reports reaching the step. No cleanup, no
            // goodbye — the surviving cohort must notice and re-form.
            cfg.fault.kill = Some((victim, die));
        } else {
            cfg.fault.die = Some((victim, die));
        }

        let t0 = Instant::now();
        let result = elastic_launch(&cfg);
        let elapsed = t0.elapsed();
        let mut out = Outcome::new();
        if recovery {
            let r = result?;
            out.metric("epochs", r.epochs as f64);
            out.metric("recoveries", r.recoveries as f64);
            out.metric("final_world", r.final_world as f64);
            out.checks.push(Check::assert(
                "post-recovery checksum bit-identical to the uninterrupted oracle",
                r.checksum == oracle,
                format!("{:x} vs oracle {oracle:x}", r.checksum),
            ));
            out.checks.push(Check::assert(
                "the death was survived via checkpoint replay",
                r.recoveries >= 1,
                format!("{} recoveries, {} epochs", r.recoveries, r.epochs),
            ));
            out.checks.push(Check::assert(
                "the cohort actually shrank",
                r.final_world == workers - 1,
                format!("final world {}", r.final_world),
            ));
        } else {
            match result {
                Ok(_) => out.checks.push(Check::assert(
                    "run without recovery fails instead of completing",
                    false,
                    "run completed despite a dead worker".to_string(),
                )),
                Err(e) => {
                    let msg = format!("{e:#}");
                    out.metric("fail_fast_s", elapsed.as_secs_f64());
                    out.checks.push(Check::assert(
                        "failure names the dead worker",
                        msg.contains(&format!("worker {victim}")),
                        msg.clone(),
                    ));
                    out.checks.push(Check::assert(
                        "failure arrives before the rendezvous deadline (no wedge)",
                        elapsed < timeout,
                        format!("{elapsed:?} vs deadline {timeout:?}"),
                    ));
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> ScenarioRegistry {
        ScenarioRegistry::builtin()
    }

    #[test]
    fn elastic_scaleout_meets_oracle() {
        let out = registry().get("elastic_scaleout").unwrap().run(&[]).unwrap();
        assert!(out.passed(), "checks failed: {:?}", out.checks);
        assert_eq!(out.metric_value("epochs").unwrap(), 3.0);
        assert_eq!(out.metric_value("final_world").unwrap(), 2.0);
    }

    #[test]
    fn elastic_scaleout_fixed_membership_degenerates() {
        let out = registry()
            .get("elastic_scaleout")
            .unwrap()
            .run(&[
                ("join-step".to_string(), "0".to_string()),
                ("leave-step".to_string(), "0".to_string()),
            ])
            .unwrap();
        assert!(out.passed(), "checks failed: {:?}", out.checks);
        assert_eq!(out.metric_value("epochs").unwrap(), 1.0);
    }

    #[test]
    fn straggler_injection_model_harness() {
        let out = registry().get("straggler_injection").unwrap().run(&[]).unwrap();
        assert!(out.passed(), "checks failed: {:?}", out.checks);
        assert!(out.metric_value("straggler_score").unwrap() > 3.0);
        assert_eq!(out.metric_value("flagged").unwrap(), 1.0);
    }

    #[test]
    fn straggler_injection_launch_harness() {
        let out = registry()
            .get("straggler_injection")
            .unwrap()
            .run(&[("harness".to_string(), "launch".to_string())])
            .unwrap();
        assert!(out.passed(), "checks failed: {:?}", out.checks);
        assert_eq!(out.metric_value("flagged").unwrap(), 1.0);
    }

    #[test]
    fn crash_recovery_thread_mode_is_bit_identical() {
        // Process mode (SIGKILL of a real `_eworker`) needs the netbn
        // binary on disk; the integration suite covers it. In-test we
        // exercise the same recovery machinery via the socket-drop crash.
        let out = registry()
            .get("worker_crash_recovery")
            .unwrap()
            .run(&[("spawn".to_string(), "thread".to_string())])
            .unwrap();
        assert!(out.passed(), "checks failed: {:?}", out.checks);
        assert!(out.metric_value("recoveries").unwrap() >= 1.0);
        assert!(out.metric_value("epochs").unwrap() >= 2.0);
    }

    #[test]
    fn crash_without_recovery_fails_fast_naming_the_worker() {
        let out = registry()
            .get("worker_crash_recovery")
            .unwrap()
            .run(&[
                ("spawn".to_string(), "thread".to_string()),
                ("recovery".to_string(), "off".to_string()),
            ])
            .unwrap();
        assert!(out.passed(), "checks failed: {:?}", out.checks);
        assert!(out.metric_value("fail_fast_s").unwrap() < 15.0);
    }
}
