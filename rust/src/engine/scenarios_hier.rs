//! Hierarchical-collective scenarios: the tentpole's measurable claims.
//!
//! * `hier_vs_flat` — leader-ring vs flat ring bus bandwidth across the
//!   provisioned-bandwidth sweep on an oversubscribed two-tier cluster
//!   (default: the acceptance topology — 4 groups × 4 ranks, 1:4
//!   oversubscription, striped:8 uplinks);
//! * `oversub_sweep` — the hierarchy's speedup as the aggregation tier's
//!   oversubscription grows 1 → 16: ≈`wire(N)/wire(G)` in the limit;
//! * `e2e_tcp_smoke` — the real thing, miniaturized: `netbn launch`'s
//!   worker loop over real loopback TCP sockets (threads by default so
//!   the scenario runs inside `cargo test`; `spawn=process` forks real
//!   worker processes when run from the `netbn` binary), asserting
//!   non-zero effective bandwidth and bit-identical final tensors.

use super::outcome::Outcome;
use super::params::{ParamKind, ParamSchema, ParamSpec, ParamValues};
use super::registry::{Scenario, ScenarioRegistry};
use crate::report::{Check, Figure, Series, Table};
use crate::sim::hier_model::HierModel;
use crate::topology::Cluster;
use crate::trainer::launch::{launch, LaunchConfig, SpawnMode, WorkerParams};
use crate::Result;
use anyhow::ensure;

/// Register the three hierarchical scenarios (called from
/// [`ScenarioRegistry::builtin`]).
pub(crate) fn register(r: &mut ScenarioRegistry) -> Result<()> {
    r.register(Scenario::from_fn(
        "hier_vs_flat",
        "leader-ring vs flat ring bus bandwidth on an oversubscribed two-tier cluster",
        ParamSchema::new(vec![
            ParamSpec::new("model", "resnet50|resnet101|vgg16", ParamKind::Model, "vgg16"),
            ParamSpec::new("groups", "group count", ParamKind::Int, "4"),
            ParamSpec::new("group-size", "ranks per group", ParamKind::Int, "4"),
            ParamSpec::new("oversub", "inter-tier oversubscription (1 = full bisection)", ParamKind::PositiveFloat, "4"),
            ParamSpec::new("streams", "striped streams on the inter tier", ParamKind::Int, "8"),
            ParamSpec::new("intra", "intra-group tier Gbps", ParamKind::PositiveFloat, "300"),
            ParamSpec::new("bandwidths", "comma list of uplink Gbps", ParamKind::FloatList, "1,5,10,25,50,100"),
        ]),
        "analytic",
        run_hier_vs_flat,
    ))?;
    r.register(Scenario::from_fn(
        "oversub_sweep",
        "hierarchical speedup vs inter-tier oversubscription",
        ParamSchema::new(vec![
            ParamSpec::new("model", "resnet50|resnet101|vgg16", ParamKind::Model, "vgg16"),
            ParamSpec::new("groups", "group count", ParamKind::Int, "4"),
            ParamSpec::new("group-size", "ranks per group", ParamKind::Int, "4"),
            ParamSpec::new("streams", "striped streams on the inter tier", ParamKind::Int, "8"),
            ParamSpec::new("intra", "intra-group tier Gbps", ParamKind::PositiveFloat, "300"),
            ParamSpec::new("bandwidth", "uplink Gbps", ParamKind::PositiveFloat, "100"),
            ParamSpec::new("oversubs", "comma list of oversubscription ratios", ParamKind::FloatList, "1,2,4,8,16"),
        ]),
        "analytic",
        run_oversub_sweep,
    ))?;
    r.register(Scenario::new(
        "e2e_tcp_smoke",
        "end-to-end launch smoke: real loopback TCP workers, striped transport, hier collective",
        ParamSchema::new(vec![
            ParamSpec::new("workers", "worker count", ParamKind::Int, "4"),
            ParamSpec::new("steps", "synchronous steps", ParamKind::Int, "2"),
            ParamSpec::new("elems", "gradient tensor length (f32)", ParamKind::Int, "65536"),
            ParamSpec::new("transport", "single|tcp|striped:N", ParamKind::Transport, "striped:4"),
            ParamSpec::new("collective", "ring|tree|ps|hier:<g>", ParamKind::Collective, "hier:2"),
            ParamSpec::new(
                "overlap",
                "submit buckets during backward (buckets) or after (off)",
                ParamKind::Choice(&["off", "buckets"]),
                "off",
            ),
            ParamSpec::new(
                "spawn",
                "thread (in-test) or process (real `netbn _worker` processes)",
                ParamKind::Choice(&["thread", "process"]),
                "thread",
            ),
            ParamSpec::new(
                "obs",
                "span tracing + per-step breakdown shipping (the bench gate's overhead leg)",
                ParamKind::Choice(&["off", "on"]),
                "off",
            ),
            ParamSpec::new("seed", "gradient RNG seed", ParamKind::Int, "3735928559"),
        ]),
        Box::new(E2eSmokeRunner),
    ))?;
    Ok(())
}

/// Build the model from the shared cluster parameters.
fn model_from(p: &ParamValues, oversub: f64, inter_gbps: f64) -> Result<HierModel> {
    let groups = p.get_usize("groups")?;
    let group_size = p.get_usize("group-size")?;
    ensure!((2..=1024).contains(&groups), "parameter groups: must be in 2..=1024, got {groups}");
    ensure!(
        (1..=1024).contains(&group_size),
        "parameter group-size: must be in 1..=1024, got {group_size}"
    );
    let streams = p.get_usize("streams")?;
    ensure!((1..=64).contains(&streams), "parameter streams: must be in 1..=64, got {streams}");
    let intra = p.get_f64("intra")?;
    let cluster =
        Cluster::with_tiers(groups * group_size, group_size, intra, inter_gbps, oversub);
    cluster.validate()?;
    Ok(HierModel::new(cluster, streams))
}

fn run_hier_vs_flat(p: &ParamValues) -> Result<Outcome> {
    let model_id = p.get_model("model")?;
    let s_bytes = model_id.profile().total_bytes() as f64;
    let oversub = p.get_f64("oversub")?;
    ensure!(oversub >= 1.0, "parameter oversub: must be >= 1, got {oversub}");
    let mut bws = p.get_f64_list("bandwidths")?;
    ensure!(!bws.is_empty(), "parameter bandwidths: list is empty");
    bws.sort_by(f64::total_cmp);

    let probe = model_from(p, oversub, bws[0])?;
    let (n, g) = (probe.cluster.workers, probe.cluster.n_groups());
    let mut fig = Figure::new(
        "hier_vs_flat",
        format!(
            "Leader-ring vs flat ring bus bandwidth ({model_id}, {g}x{} cluster, 1:{oversub:.0} oversubscribed, striped:{})",
            probe.cluster.group_size, probe.streams
        ),
        "uplink Gbps",
        "bus Gbps",
    );
    let mut s_hier = Series::new("hier (leader ring)");
    let mut s_flat = Series::new("flat ring");
    let mut t = Table::new(
        format!("hier vs flat: {n} ranks, oversub 1:{oversub:.0}"),
        &["uplink Gbps", "flat bus Gbps", "hier bus Gbps", "speedup"],
    );
    let mut dominates = true;
    let mut last = (0.0, 0.0, 0.0); // (flat, hier, speedup) at max bw
    for &bw in &bws {
        let m = model_from(p, oversub, bw)?;
        let flat = m.flat_bus_gbps(s_bytes);
        let hier = m.hier_bus_gbps(s_bytes);
        let speedup = m.speedup(s_bytes);
        s_hier.push(bw, hier);
        s_flat.push(bw, flat);
        t.row(vec![
            format!("{bw}"),
            format!("{flat:.2}"),
            format!("{hier:.2}"),
            format!("{speedup:.3}x"),
        ]);
        dominates &= hier + 1e-9 >= flat;
        last = (flat, hier, speedup);
    }
    fig.series.push(s_hier);
    fig.series.push(s_flat);

    let mut out = Outcome::new();
    out.metric("flat_bus_gbps", last.0);
    out.metric("hier_bus_gbps", last.1);
    out.metric("hier_speedup", last.2);
    if oversub >= 2.0 {
        // The acceptance claim: on an oversubscribed tier the leader ring
        // is never slower than the flat ring, at any provisioned rate.
        out.checks.push(Check::assert(
            "hier >= flat bus bandwidth at every swept rate (oversubscribed tier)",
            dominates,
            format!("{g} groups, 1:{oversub:.0} oversubscription"),
        ));
        out.checks.push(Check::assert(
            "hier beats flat at the peak rate",
            last.2 >= 1.0,
            format!("speedup {:.3}x at {} Gbps", last.2, bws.last().expect("non-empty")),
        ));
    }
    out.tables.push(t);
    out.figures.push(fig);
    Ok(out)
}

fn run_oversub_sweep(p: &ParamValues) -> Result<Outcome> {
    let model_id = p.get_model("model")?;
    let s_bytes = model_id.profile().total_bytes() as f64;
    let bw = p.get_f64("bandwidth")?;
    let mut oversubs = p.get_f64_list("oversubs")?;
    ensure!(!oversubs.is_empty(), "parameter oversubs: list is empty");
    for &o in &oversubs {
        ensure!(o >= 1.0, "parameter oversubs: ratios must be >= 1, got {o}");
    }
    oversubs.sort_by(f64::total_cmp);

    let probe = model_from(p, oversubs[0], bw)?;
    let (n, g) = (probe.cluster.workers, probe.cluster.n_groups());
    let bound = crate::collectives::ring::wire_bytes_per_worker(1.0, n)
        / crate::collectives::ring::wire_bytes_per_worker(1.0, g);
    let mut fig = Figure::new(
        "oversub_sweep",
        format!("Hierarchy speedup vs oversubscription ({model_id}, {n} ranks, {bw} Gbps uplinks)"),
        "oversubscription",
        "t_flat / t_hier",
    );
    let mut s = Series::new("speedup");
    let mut monotone = true;
    let mut prev = f64::NEG_INFINITY;
    for &o in &oversubs {
        let m = model_from(p, o, bw)?;
        let speedup = m.speedup(s_bytes);
        monotone &= speedup + 1e-9 >= prev;
        prev = speedup;
        s.push(o, speedup);
    }
    let first = s.points.first().expect("non-empty").1;
    let peak = s.points.last().expect("non-empty").1;
    fig.series.push(s);

    let mut out = Outcome::new();
    out.metric("speedup_at_min_oversub", first);
    out.metric("speedup_at_max_oversub", peak);
    out.metric("speedup_bound", bound);
    out.checks.push(Check::assert(
        "speedup is monotone in oversubscription",
        monotone,
        format!("{} points at {bw} Gbps", oversubs.len()),
    ));
    out.checks.push(Check::assert(
        "speedup stays below the wire-volume bound wire(N)/wire(G)",
        peak <= bound + 1e-9,
        format!("peak {peak:.3}x vs bound {bound:.3}x"),
    ));
    if oversubs.last().is_some_and(|o| *o >= 4.0) {
        out.checks.push(Check::assert(
            "hierarchy wins under >= 1:4 oversubscription",
            peak > 1.0,
            format!("peak speedup {peak:.3}x"),
        ));
    }
    out.figures.push(fig);
    Ok(out)
}

/// Runner for the e2e smoke: real wall-clock, real sockets.
struct E2eSmokeRunner;

impl super::runner::Runner for E2eSmokeRunner {
    fn mode(&self) -> &'static str {
        "e2e"
    }

    fn realtime(&self) -> bool {
        true
    }

    fn run(&self, p: &ParamValues) -> Result<Outcome> {
        let workers = p.get_usize("workers")?;
        ensure!((1..=16).contains(&workers), "parameter workers: must be in 1..=16, got {workers}");
        let steps = p.get_usize("steps")?;
        ensure!((1..=100).contains(&steps), "parameter steps: must be in 1..=100, got {steps}");
        let elems = p.get_usize("elems")?;
        ensure!(elems >= 1, "parameter elems: must be >= 1");
        let spawn = match p.get_str("spawn")? {
            "process" => SpawnMode::Process,
            _ => SpawnMode::Thread,
        };
        let overlap = crate::config::OverlapMode::parse(p.get_str("overlap")?)
            .expect("schema-validated choice");
        let cfg = LaunchConfig {
            params: WorkerParams {
                world: workers,
                steps,
                elems,
                transport: p.get_transport("transport")?,
                collective: p.get_collective("collective")?,
                overlap,
                bucket_mb: 0.0,
                layers: 1,
                compute_us: 0,
                autotune: false,
                chunk_kbs: Vec::new(),
                gate_gbps: 0.0,
                drop_at_step: 0,
                drop_gbps: 0.0,
                seed: p.get_usize("seed")? as u64,
                obs: p.get_str("obs")? == "on",
                trace_out: None,
            },
            spawn,
            feedback_out: None,
            rendezvous_timeout: std::time::Duration::from_secs(60),
            bind: "127.0.0.1:0".parse().unwrap(),
        };
        let r = launch(&cfg)?;
        let t = r.step_table();

        let mut out = Outcome::new();
        out.metric("effective_bus_gbps", r.effective_bus_gbps);
        out.metric(
            "mean_step_wall_s",
            r.step_wall_s.iter().sum::<f64>() / r.step_wall_s.len().max(1) as f64,
        );
        out.checks.push(Check::assert(
            "final tensors bit-identical across workers",
            r.identical,
            format!(
                "checksums {}",
                r.checksums.iter().map(|c| format!("{c:x}")).collect::<Vec<_>>().join(" ")
            ),
        ));
        if workers > 1 {
            out.checks.push(Check::assert(
                "non-zero effective bandwidth over real sockets",
                r.effective_bus_gbps > 0.0,
                format!("{:.3} Gbps bus bandwidth", r.effective_bus_gbps),
            ));
        }
        out.tables.push(t);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> ScenarioRegistry {
        ScenarioRegistry::builtin()
    }

    #[test]
    fn hier_vs_flat_meets_acceptance() {
        // Defaults ARE the ISSUE's acceptance topology — a MODELED 4x4
        // cluster, 1:4 oversubscribed, leader-ring striping vs flat
        // striped (this scenario is analytic; the mechanistic e2e path
        // is e2e_tcp_smoke / `netbn launch`).
        let out = registry().get("hier_vs_flat").unwrap().run(&[]).unwrap();
        assert!(out.passed(), "checks failed: {:?}", out.checks);
        let hier = out.metric_value("hier_bus_gbps").unwrap();
        let flat = out.metric_value("flat_bus_gbps").unwrap();
        assert!(hier >= flat, "{hier} vs {flat}");
        assert!(out.metric_value("hier_speedup").unwrap() >= 1.05);
    }

    #[test]
    fn hier_vs_flat_full_bisection_emits_no_dominance_check() {
        // At 1:1 the hierarchy legitimately loses a little; the dominance
        // check only applies to oversubscribed tiers.
        let out = registry()
            .get("hier_vs_flat")
            .unwrap()
            .run(&[("oversub".to_string(), "1".to_string())])
            .unwrap();
        assert!(out.passed());
        assert!(out.checks.is_empty());
        assert!(out.metric_value("hier_speedup").unwrap() < 1.0);
    }

    #[test]
    fn oversub_sweep_monotone_and_bounded() {
        let out = registry().get("oversub_sweep").unwrap().run(&[]).unwrap();
        assert!(out.passed(), "checks failed: {:?}", out.checks);
        let peak = out.metric_value("speedup_at_max_oversub").unwrap();
        let bound = out.metric_value("speedup_bound").unwrap();
        assert!(peak > 1.1 && peak <= bound, "peak {peak} bound {bound}");
    }

    #[test]
    fn e2e_tcp_smoke_runs_real_sockets() {
        // Thread spawn mode inside the test binary; rendezvous + data
        // still cross real loopback TCP.
        let out = registry()
            .get("e2e_tcp_smoke")
            .unwrap()
            .run(&[("workers".to_string(), "2".to_string()), ("elems".to_string(), "8192".to_string())])
            .unwrap();
        assert!(out.passed(), "checks failed: {:?}", out.checks);
        assert!(out.metric_value("effective_bus_gbps").unwrap() > 0.0);
    }

    #[test]
    fn scenarios_are_sweepable() {
        let reg = registry();
        let scenario = reg.get("hier_vs_flat").unwrap();
        let points = crate::engine::SweepBuilder::new(scenario)
            .axis_csv("oversub", "1,4")
            .run(1);
        assert_eq!(points.len(), 2);
        for p in &points {
            assert!(p.outcome.is_ok());
        }
    }
}
