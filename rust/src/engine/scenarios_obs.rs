//! Observability scenarios: the paper's utilization argument, measured
//! from spans instead of asserted from a model — and the detector that
//! watches for the finding live.
//!
//! `utilization_timeline` re-runs the paper's Fig-4 story end to end on
//! real loopback sockets with the span tracer on: a single gated stream
//! at a modeled 100 Gbps NIC leaves the wire mostly idle (the ~30 Gbps
//! single-stream TCP ceiling), and striping the same payload across 8
//! lanes recovers the provisioned rate. Both utilization numbers come
//! out of the cross-rank span aggregation (`wire.send` busy intervals →
//! [`crate::obs::breakdown::wire_mean_bps`]), and the per-step
//! compute/serialize/wire/reduce/barrier breakdown is checked to account
//! for the measured step wall — the tracer auditing itself.
//!
//! `anomaly_sentinel` turns the same finding into an alarm: a scripted
//! mid-run NIC drop must be flagged by the online detector
//! ([`crate::obs::detect`]) within 3 steps, with zero false positives on
//! the steady prefix and on a steady control run. `harness=model` scans
//! a deterministic synthetic series; `harness=launch` drives two real
//! gated loopback launches (dropped + steady control) and reads
//! [`LaunchReport::detections`].

use super::outcome::Outcome;
use super::params::{ParamKind, ParamSchema, ParamSpec, ParamValues};
use super::registry::{Scenario, ScenarioRegistry};
use crate::config::{CollectiveKind, OverlapMode, TransportKind};
use crate::obs::detect::{scan, Detection, DetectionKind, DetectorConfig};
use crate::report::{Check, Figure, Series, Table};
use crate::trainer::launch::{launch, LaunchConfig, LaunchReport, SpawnMode, WorkerParams};
use crate::util::Rng;
use crate::Result;
use anyhow::ensure;

/// Register the observability scenarios (called from
/// [`ScenarioRegistry::builtin`]).
pub(crate) fn register(r: &mut ScenarioRegistry) -> Result<()> {
    r.register(Scenario::new(
        "utilization_timeline",
        "span-measured wire utilization: single-stream ceiling vs striped recovery at 100 Gbps",
        ParamSchema::new(vec![
            ParamSpec::new("workers", "worker count", ParamKind::Int, "2"),
            ParamSpec::new("steps", "synchronous steps", ParamKind::Int, "4"),
            ParamSpec::new("elems", "gradient tensor length (f32)", ParamKind::Int, "1048576"),
            ParamSpec::new(
                "provisioned",
                "modeled NIC Gbps the utilization is judged against",
                ParamKind::PositiveFloat,
                "100",
            ),
            ParamSpec::new(
                "ceiling",
                "single-stream TCP software ceiling Gbps (the paper's ~30)",
                ParamKind::PositiveFloat,
                "30",
            ),
            ParamSpec::new("streams", "stripe width of the recovery run", ParamKind::Int, "8"),
            ParamSpec::new(
                "payload-scale",
                "byte/rate shrink factor so the run fits loopback",
                ParamKind::PositiveFloat,
                "64",
            ),
            ParamSpec::new(
                "spawn",
                "thread (in-test) or process (real `netbn _worker` processes)",
                ParamKind::Choice(&["thread", "process"]),
                "thread",
            ),
            ParamSpec::new("seed", "gradient RNG seed", ParamKind::Int, "77"),
        ]),
        Box::new(UtilizationTimelineRunner),
    ))?;
    r.register(Scenario::new(
        "anomaly_sentinel",
        "online detector flags a scripted mid-run NIC drop within 3 steps, zero false positives",
        ParamSchema::new(vec![
            ParamSpec::new(
                "harness",
                "model (synthetic busbw series) or launch (real gated loopback sockets)",
                ParamKind::Choice(&["model", "launch"]),
                "model",
            ),
            ParamSpec::new("workers", "worker count (launch harness)", ParamKind::Int, "2"),
            ParamSpec::new("steps", "synchronous steps", ParamKind::Int, "8"),
            ParamSpec::new(
                "drop-at",
                "step at which the per-stream gate collapses",
                ParamKind::Int,
                "4",
            ),
            ParamSpec::new(
                "gate-gbps",
                "steady per-stream gate Gbps before the drop",
                ParamKind::PositiveFloat,
                "0.5",
            ),
            ParamSpec::new(
                "drop-gbps",
                "per-stream gate Gbps after the drop",
                ParamKind::PositiveFloat,
                "0.05",
            ),
            ParamSpec::new(
                "jitter",
                "relative jitter on the synthetic steady level (model harness)",
                ParamKind::PositiveFloat,
                "0.02",
            ),
            ParamSpec::new(
                "elems",
                "gradient tensor length f32 (launch harness)",
                ParamKind::Int,
                "60000",
            ),
            ParamSpec::new("seed", "jitter / gradient RNG seed", ParamKind::Int, "7"),
        ]),
        Box::new(AnomalySentinelRunner),
    ))?;
    Ok(())
}

/// Per-stream gate of the single-stream leg: the software ceiling, or
/// the NIC if it is slower, shrunk by the payload scale.
fn single_gate_gbps(provisioned: f64, ceiling: f64, scale: f64) -> f64 {
    ceiling.min(provisioned) / scale
}

/// Per-stream gate of the striped leg: each lane gets an equal share of
/// the NIC, still capped by the per-stream software ceiling.
fn striped_gate_gbps(provisioned: f64, ceiling: f64, streams: usize, scale: f64) -> f64 {
    ceiling.min(provisioned / streams as f64) / scale
}

/// Runner: two real launches with the tracer on, judged from spans.
struct UtilizationTimelineRunner;

impl super::runner::Runner for UtilizationTimelineRunner {
    fn mode(&self) -> &'static str {
        "e2e"
    }

    fn realtime(&self) -> bool {
        true
    }

    fn run(&self, p: &ParamValues) -> Result<Outcome> {
        let workers = p.get_usize("workers")?;
        ensure!((2..=16).contains(&workers), "parameter workers: must be in 2..=16, got {workers}");
        let steps = p.get_usize("steps")?;
        ensure!((2..=100).contains(&steps), "parameter steps: must be in 2..=100, got {steps}");
        let elems = p.get_usize("elems")?;
        ensure!(elems >= 1024, "parameter elems: must be >= 1024, got {elems}");
        let provisioned = p.get_f64("provisioned")?;
        let ceiling = p.get_f64("ceiling")?;
        let streams = p.get_usize("streams")?;
        ensure!((2..=64).contains(&streams), "parameter streams: must be in 2..=64, got {streams}");
        let scale = p.get_f64("payload-scale")?;
        let spawn = match p.get_str("spawn")? {
            "process" => SpawnMode::Process,
            _ => SpawnMode::Thread,
        };
        let seed = p.get_usize("seed")? as u64;

        let leg = |lanes: usize, gate_gbps: f64| -> Result<LaunchReport> {
            launch(&LaunchConfig {
                params: WorkerParams {
                    world: workers,
                    steps,
                    elems,
                    transport: TransportKind::Striped { streams: lanes },
                    collective: CollectiveKind::Ring,
                    overlap: OverlapMode::Off,
                    bucket_mb: 0.0,
                    layers: 1,
                    compute_us: 0,
                    autotune: false,
                    chunk_kbs: Vec::new(),
                    gate_gbps,
                    drop_at_step: 0,
                    drop_gbps: 0.0,
                    seed,
                    obs: true,
                    trace_out: None,
                },
                spawn,
                feedback_out: None,
                rendezvous_timeout: std::time::Duration::from_secs(60),
                bind: "127.0.0.1:0".parse().unwrap(),
            })
        };
        let single = leg(1, single_gate_gbps(provisioned, ceiling, scale))?;
        let striped = leg(streams, striped_gate_gbps(provisioned, ceiling, streams, scale))?;
        ensure!(single.identical && striped.identical, "launch checksums diverged");

        // Utilization: span-measured delivered rate while the wire is
        // busy, against the (scaled) provisioned per-rank NIC rate.
        let capacity_bps = crate::gbps_to_bytes_per_sec(provisioned / scale);
        let single_util = single.wire_mean_bps / capacity_bps;
        let striped_util = striped.wire_mean_bps / capacity_bps;
        let ratio = if single_util > 0.0 { striped_util / single_util } else { 0.0 };

        // The tracer's self-audit: past the warmup step, the five span
        // components must account for the measured step wall.
        let mut gap_max = 0.0f64;
        let mut audited = 0usize;
        for b in single.breakdown.iter().chain(&striped.breakdown) {
            if b.step == 0 || b.total_s <= 0.0 {
                continue;
            }
            gap_max = gap_max.max((b.components_sum() - b.total_s).abs() / b.total_s);
            audited += 1;
        }

        let mut out = Outcome::new();
        out.metric("single_util", single_util);
        out.metric("striped_util", striped_util);
        out.metric("util_ratio", ratio);
        out.metric("single_wire_gbps", crate::bytes_per_sec_to_gbps(single.wire_mean_bps));
        out.metric("striped_wire_gbps", crate::bytes_per_sec_to_gbps(striped.wire_mean_bps));
        out.metric("breakdown_gap_max", gap_max);
        out.checks.push(Check::assert(
            "single gated stream leaves the provisioned NIC under-used",
            single_util > 0.0 && single_util < 0.6,
            format!("utilization {:.3} at {provisioned} Gbps (/{scale})", single_util),
        ));
        out.checks.push(Check::assert(
            "striping recovers utilization (>= 1.8x the single stream)",
            ratio >= 1.8,
            format!("striped:{streams} {:.3} vs single {:.3} ({ratio:.2}x)", striped_util, single_util),
        ));
        out.checks.push(Check::assert(
            "span breakdown accounts for the step wall within 5% (steps >= 1)",
            audited > 0 && gap_max <= 0.05,
            format!("max gap {:.2}% over {audited} rank-averaged steps", gap_max * 100.0),
        ));

        let mut fig = Figure::new(
            "utilization_timeline",
            format!(
                "Span-measured delivered wire rate over time ({workers} ranks, {provisioned} Gbps NIC /{scale})"
            ),
            "time s",
            "delivered Gbps per rank",
        );
        for (name, r) in [("striped:1".to_string(), &single), (format!("striped:{streams}"), &striped)] {
            let mut s = Series::new(name);
            for &(t, bps) in &r.util_timeline {
                s.push(t, crate::bytes_per_sec_to_gbps(bps));
            }
            fig.series.push(s);
        }
        out.figures.push(fig);

        let mut t = Table::new(
            format!("per-step breakdown, striped:{streams} leg (rank-averaged seconds)"),
            &["step", "barrier", "compute", "serialize", "wire", "reduce", "total", "sum/total"],
        );
        for b in &striped.breakdown {
            t.row(vec![
                format!("{}", b.step),
                format!("{:.6}", b.barrier_s),
                format!("{:.6}", b.compute_s),
                format!("{:.6}", b.serialize_s),
                format!("{:.6}", b.wire_s),
                format!("{:.6}", b.reduce_s),
                format!("{:.6}", b.total_s),
                if b.total_s > 0.0 {
                    format!("{:.1}%", b.components_sum() / b.total_s * 100.0)
                } else {
                    "-".to_string()
                },
            ]);
        }
        out.tables.push(t);
        Ok(out)
    }
}

/// Runner: the detector watching a run lose its NIC mid-flight. Both
/// harnesses produce a per-step busbw-like series plus its detections
/// and a steady control; the checks are harness-independent.
struct AnomalySentinelRunner;

/// Deterministic synthetic per-step series: `level` jittered by ±`jitter`.
fn synth_series(
    rng: &mut Rng,
    steps: usize,
    drop_at: usize,
    gate: f64,
    drop: f64,
    jitter: f64,
) -> Vec<(u64, f64)> {
    (0..steps)
        .map(|s| {
            let level = if s < drop_at { gate } else { drop };
            (s as u64, level * (1.0 + jitter * (rng.next_f64() * 2.0 - 1.0)))
        })
        .collect()
}

impl super::runner::Runner for AnomalySentinelRunner {
    fn mode(&self) -> &'static str {
        "e2e"
    }

    fn realtime(&self) -> bool {
        true
    }

    fn run(&self, p: &ParamValues) -> Result<Outcome> {
        let harness = p.get_str("harness")?;
        let workers = p.get_usize("workers")?;
        ensure!((2..=8).contains(&workers), "parameter workers: must be in 2..=8, got {workers}");
        let steps = p.get_usize("steps")?;
        let drop_at = p.get_usize("drop-at")?;
        let det_cfg = DetectorConfig::throughput();
        ensure!(
            drop_at > det_cfg.warmup,
            "parameter drop-at: the steady prefix must outlast the detector warmup ({}), got {drop_at}",
            det_cfg.warmup
        );
        ensure!(
            steps >= drop_at + 3,
            "parameter steps: need drop-at + 3 ({}) so the detection window fits, got {steps}",
            drop_at + 3
        );
        let gate = p.get_f64("gate-gbps")?;
        let drop = p.get_f64("drop-gbps")?;
        ensure!(
            drop <= gate * 0.5,
            "parameter drop-gbps: must be a real collapse (<= half of gate-gbps {gate}), got {drop}"
        );
        let jitter = p.get_f64("jitter")?;
        ensure!(
            jitter < det_cfg.min_rel_dev,
            "parameter jitter: must stay under the detector scale floor {}, got {jitter}",
            det_cfg.min_rel_dev
        );
        let elems = p.get_usize("elems")?;
        ensure!(elems >= 1024, "parameter elems: must be >= 1024, got {elems}");
        let seed = p.get_usize("seed")? as u64;

        // (series for the figure, its detections, steady-control detections)
        let (series, dets, control_dets, series_unit): (_, Vec<Detection>, Vec<Detection>, &str) =
            if harness == "launch" {
                let leg = |drop_at_step: usize| -> Result<LaunchReport> {
                    launch(&LaunchConfig {
                        params: WorkerParams {
                            world: workers,
                            steps,
                            elems,
                            transport: TransportKind::Striped { streams: 2 },
                            collective: CollectiveKind::Ring,
                            overlap: OverlapMode::Off,
                            bucket_mb: 0.0,
                            layers: 1,
                            compute_us: 0,
                            autotune: false,
                            chunk_kbs: Vec::new(),
                            gate_gbps: gate,
                            drop_at_step,
                            drop_gbps: if drop_at_step > 0 { drop } else { 0.0 },
                            seed,
                            obs: false,
                            trace_out: None,
                        },
                        spawn: SpawnMode::Thread,
                        feedback_out: None,
                        rendezvous_timeout: std::time::Duration::from_secs(60),
                        bind: "127.0.0.1:0".parse().unwrap(),
                    })
                };
                let dropped = leg(drop_at)?;
                let steady = leg(0)?;
                ensure!(
                    dropped.identical && steady.identical && dropped.passed() && steady.passed(),
                    "launch legs failed or diverged"
                );
                let walls: Vec<(u64, f64)> =
                    dropped.step_wall_s.iter().enumerate().map(|(s, w)| (s as u64, *w)).collect();
                (walls, dropped.detections, steady.detections, "step wall s")
            } else {
                // Two independent jitter streams so the control is not
                // just the dropped series with the drop erased.
                let mut rng = Rng::new(seed);
                let mut control_rng = rng.fork();
                let series = synth_series(&mut rng, steps, drop_at, gate, drop, jitter);
                let control = synth_series(&mut control_rng, steps, steps, gate, drop, jitter);
                let dets = scan(
                    det_cfg,
                    DetectionKind::ThroughputRegression,
                    "busbw_gbps",
                    &series,
                );
                let control_dets = scan(
                    det_cfg,
                    DetectionKind::ThroughputRegression,
                    "busbw_gbps",
                    &control,
                );
                (series, dets, control_dets, "busbw Gbps")
            };

        let first_at = dets.iter().map(|d| d.at).min();
        let latency = first_at.map(|at| at as f64 - drop_at as f64);
        let false_pos = dets.iter().filter(|d| d.at < drop_at as u64).count();

        let mut out = Outcome::new();
        out.metric("detections", dets.len() as f64);
        out.metric("false_positives", false_pos as f64);
        out.metric("control_detections", control_dets.len() as f64);
        out.metric("latency_steps", latency.unwrap_or(-1.0));
        out.checks.push(Check::assert(
            "scripted NIC drop is detected",
            !dets.is_empty(),
            format!("{} detection(s) on the dropped run", dets.len()),
        ));
        out.checks.push(Check::assert(
            "detected within 3 steps of the drop",
            matches!(latency, Some(l) if (0.0..3.0).contains(&l)),
            format!("drop at step {drop_at}, first detection {first_at:?}"),
        ));
        out.checks.push(Check::assert(
            "zero false positives on the steady prefix",
            false_pos == 0,
            format!("{false_pos} detection(s) before step {drop_at}"),
        ));
        out.checks.push(Check::assert(
            "steady control run yields no detections",
            control_dets.is_empty(),
            format!("{} detection(s) on the control", control_dets.len()),
        ));

        let mut fig = Figure::new(
            "anomaly_sentinel",
            format!("per-step series with a gate drop {gate}→{drop} Gbps at step {drop_at} ({harness} harness)"),
            "step",
            series_unit,
        );
        let mut s = Series::new("observed");
        for &(at, v) in &series {
            s.push(at as f64, v);
        }
        fig.series.push(s);
        out.figures.push(fig);

        let mut t = Table::new(
            "detections (throughput config: EWMA baseline + MAD z-score, sustain 2)".to_string(),
            &["kind", "at", "z", "baseline", "value"],
        );
        for d in &dets {
            t.row(vec![
                d.kind.as_str().to_string(),
                d.at.to_string(),
                format!("{:.2}", d.z),
                format!("{:.4}", d.baseline),
                format!("{:.4}", d.value),
            ]);
        }
        out.tables.push(t);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The scenario itself runs real launches with the global tracer
    // enabled, so (like the bench gate) it is exercised from the binary
    // — CI runs `netbn run utilization_timeline` in its own process.
    // In-crate we pin registration, schema, and the gate arithmetic.

    #[test]
    fn utilization_timeline_is_registered_with_schema() {
        let r = ScenarioRegistry::builtin();
        let sc = r.get("utilization_timeline").unwrap();
        assert_eq!(sc.mode(), "e2e");
        assert!(sc.realtime(), "two timed launches must not run concurrently with other points");
        let names: Vec<&str> = sc.schema().specs().iter().map(|p| p.name).collect();
        for n in
            ["workers", "steps", "elems", "provisioned", "ceiling", "streams", "payload-scale", "spawn", "seed"]
        {
            assert!(names.contains(&n), "missing param {n}");
        }
    }

    #[test]
    fn gate_math_matches_the_paper_setup() {
        // 30 Gbps software ceiling on the lone stream; striped:8 splits
        // the 100 Gbps NIC into 12.5 Gbps lanes under the same ceiling.
        assert!((single_gate_gbps(100.0, 30.0, 64.0) - 30.0 / 64.0).abs() < 1e-12);
        assert!((striped_gate_gbps(100.0, 30.0, 8, 64.0) - 12.5 / 64.0).abs() < 1e-12);
        // A slow NIC binds before the ceiling does.
        assert!((single_gate_gbps(10.0, 30.0, 1.0) - 10.0).abs() < 1e-12);
        assert!((striped_gate_gbps(10.0, 30.0, 2, 1.0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_params() {
        let r = ScenarioRegistry::builtin();
        let sc = r.get("utilization_timeline").unwrap();
        for (k, v) in [("workers", "1"), ("streams", "1"), ("steps", "1"), ("elems", "4")] {
            let err = sc.run(&[(k.to_string(), v.to_string())]).unwrap_err().to_string();
            assert!(err.contains(k), "{k}={v}: {err}");
        }
    }

    #[test]
    fn anomaly_sentinel_is_registered_with_schema() {
        let r = ScenarioRegistry::builtin();
        let sc = r.get("anomaly_sentinel").unwrap();
        assert_eq!(sc.mode(), "e2e");
        assert!(sc.realtime());
        let names: Vec<&str> = sc.schema().specs().iter().map(|p| p.name).collect();
        for n in
            ["harness", "workers", "steps", "drop-at", "gate-gbps", "drop-gbps", "jitter", "elems", "seed"]
        {
            assert!(names.contains(&n), "missing param {n}");
        }
    }

    #[test]
    fn anomaly_sentinel_model_harness() {
        let out = ScenarioRegistry::builtin().get("anomaly_sentinel").unwrap().run(&[]).unwrap();
        assert!(out.passed(), "checks failed: {:?}", out.checks);
        assert_eq!(out.metric_value("false_positives").unwrap(), 0.0);
        assert_eq!(out.metric_value("control_detections").unwrap(), 0.0);
        let latency = out.metric_value("latency_steps").unwrap();
        assert!((0.0..3.0).contains(&latency), "latency {latency}");
    }

    #[test]
    fn anomaly_sentinel_launch_harness() {
        // Real gated loopback sockets — the same mechanism launch.rs's
        // gated_launch_with_mid_run_drop_completes exercises, judged
        // through the scenario's checks.
        let out = ScenarioRegistry::builtin()
            .get("anomaly_sentinel")
            .unwrap()
            .run(&[("harness".to_string(), "launch".to_string())])
            .unwrap();
        assert!(out.passed(), "checks failed: {:?}", out.checks);
        assert_eq!(out.metric_value("false_positives").unwrap(), 0.0);
    }

    #[test]
    fn anomaly_sentinel_rejects_bad_params() {
        let r = ScenarioRegistry::builtin();
        let sc = r.get("anomaly_sentinel").unwrap();
        for (k, v) in [
            ("drop-at", "2"),     // steady prefix inside detector warmup
            ("steps", "5"),       // detection window does not fit
            ("drop-gbps", "0.4"), // not a real collapse vs gate 0.5
            ("jitter", "0.5"),    // above the detector scale floor
            ("workers", "1"),
        ] {
            let err = sc.run(&[(k.to_string(), v.to_string())]).unwrap_err().to_string();
            assert!(err.contains(k), "{k}={v}: {err}");
        }
    }
}
