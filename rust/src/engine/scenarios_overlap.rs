//! Overlap-scheduler scenarios: the tentpole's measurable claims.
//!
//! * `overlap_ablation` — the mechanistic ablation: two real `netbn
//!   launch` runs (thread-spawned workers over loopback TCP) differing
//!   only in `--overlap`, on a compute-heavy config. Overlapped mean step
//!   time must fall strictly below blocking, and the final parameter
//!   tensors must be bit-identical across the two runs (FNV checksums) —
//!   overlap changes *when* communication happens, never the arithmetic;
//! * `bucket_size_sweep` — the bucketizer's trade, on the analytic mirror
//!   ([`crate::sim::overlap_model`]): tiny buckets drown in per-bucket
//!   coordination, one huge bucket ships only when backward ends; the
//!   optimum is interior;
//! * `scaling_factor_recovered` — the paper's Fig 6 claim, constructively:
//!   overlap + striped transport pushes the modeled scaling factor to
//!   ≥ 0.9 of the analytic full-utilization bound at 100 Gbps, where the
//!   blocking single-stream baseline sits far below it.

use super::outcome::Outcome;
use super::params::{ParamKind, ParamSchema, ParamSpec, ParamValues};
use super::registry::{Scenario, ScenarioRegistry};
use crate::config::OverlapMode;
use crate::models::timing::backward_trace;
use crate::net::kernel_tcp::KernelTcpModel;
use crate::net::striped::StripedModel;
use crate::report::{Check, Figure, Series, Table};
use crate::sim::overlap_model::{overlap_step, OverlapModelParams};
use crate::trainer::launch::{launch, LaunchConfig, LaunchReport, SpawnMode, WorkerParams};
use crate::Result;
use anyhow::ensure;

/// Register the three overlap scenarios (called from
/// [`ScenarioRegistry::builtin`]).
pub(crate) fn register(r: &mut ScenarioRegistry) -> Result<()> {
    r.register(Scenario::new(
        "overlap_ablation",
        "overlapped vs blocking launch on a compute-heavy config: faster AND bit-identical",
        ParamSchema::new(vec![
            ParamSpec::new("workers", "worker count", ParamKind::Int, "4"),
            ParamSpec::new("steps", "synchronous steps", ParamKind::Int, "4"),
            ParamSpec::new("elems", "gradient tensor length (f32)", ParamKind::Int, "1048576"),
            ParamSpec::new("layers", "synthetic backward layers", ParamKind::Int, "8"),
            ParamSpec::new("compute-us", "modeled backward compute per step (us)", ParamKind::Int, "60000"),
            ParamSpec::new("bucket-mb", "bucketizer threshold MB", ParamKind::PositiveFloat, "1"),
            ParamSpec::new("transport", "single|tcp|striped:N", ParamKind::Transport, "tcp"),
            ParamSpec::new("collective", "ring|tree|ps|hier:<g>", ParamKind::Collective, "ring"),
            ParamSpec::new("seed", "gradient RNG seed", ParamKind::Int, "3735928559"),
        ]),
        Box::new(OverlapAblationRunner),
    ))?;
    r.register(Scenario::from_fn(
        "bucket_size_sweep",
        "modeled step time vs bucket threshold: too small and too large both lose",
        ParamSchema::new(vec![
            ParamSpec::new("model", "resnet50|resnet101|vgg16", ParamKind::Model, "vgg16"),
            ParamSpec::new("servers", "server count", ParamKind::Int, "8"),
            ParamSpec::new("gpus", "GPUs per server", ParamKind::Int, "8"),
            ParamSpec::new("bandwidth", "provisioned Gbps", ParamKind::PositiveFloat, "5"),
            ParamSpec::new("streams", "striped streams (1 = single kernel-TCP)", ParamKind::Int, "8"),
            ParamSpec::new("bucket-mbs", "comma list of bucket thresholds (MB)", ParamKind::FloatList, "0.25,1,4,16,64,600"),
        ]),
        "analytic",
        run_bucket_size_sweep,
    ))?;
    r.register(Scenario::from_fn(
        "scaling_factor_recovered",
        "overlap + striped transport vs the analytic full-utilization bound (paper Fig 6 recovered)",
        ParamSchema::new(vec![
            ParamSpec::new("model", "resnet50|resnet101|vgg16", ParamKind::Model, "resnet50"),
            ParamSpec::new("servers", "server count", ParamKind::Int, "8"),
            ParamSpec::new("gpus", "GPUs per server", ParamKind::Int, "8"),
            ParamSpec::new("streams", "striped streams", ParamKind::Int, "8"),
            ParamSpec::new("bucket-mb", "bucketizer threshold MB", ParamKind::PositiveFloat, "25"),
            ParamSpec::new("target", "required fraction of the bound at the peak rate", ParamKind::PositiveFloat, "0.9"),
            ParamSpec::new("bandwidths", "comma list of provisioned Gbps", ParamKind::FloatList, "1,5,10,25,50,100"),
        ]),
        "analytic",
        run_scaling_factor_recovered,
    ))?;
    Ok(())
}

/// Mean step wall time, skipping the first (warmup/connection-cache) step
/// when more than one was measured.
fn mean_steady_step(r: &LaunchReport) -> f64 {
    let steps = if r.step_wall_s.len() > 1 { &r.step_wall_s[1..] } else { &r.step_wall_s[..] };
    steps.iter().sum::<f64>() / steps.len().max(1) as f64
}

/// Runner for the mechanistic ablation: real wall-clock, real sockets.
struct OverlapAblationRunner;

impl super::runner::Runner for OverlapAblationRunner {
    fn mode(&self) -> &'static str {
        "e2e"
    }

    fn realtime(&self) -> bool {
        true
    }

    fn run(&self, p: &ParamValues) -> Result<Outcome> {
        let workers = p.get_usize("workers")?;
        ensure!((2..=16).contains(&workers), "parameter workers: must be in 2..=16, got {workers}");
        let steps = p.get_usize("steps")?;
        ensure!((1..=100).contains(&steps), "parameter steps: must be in 1..=100, got {steps}");
        let elems = p.get_usize("elems")?;
        ensure!(elems >= 1024, "parameter elems: must be >= 1024, got {elems}");
        let layers = p.get_usize("layers")?;
        ensure!((1..=4096).contains(&layers), "parameter layers: must be in 1..=4096, got {layers}");
        let params = WorkerParams {
            world: workers,
            steps,
            elems,
            transport: p.get_transport("transport")?,
            collective: p.get_collective("collective")?,
            overlap: OverlapMode::Off,
            bucket_mb: p.get_f64("bucket-mb")?,
            layers,
            compute_us: p.get_usize("compute-us")? as u64,
            autotune: false,
            chunk_kbs: Vec::new(),
            gate_gbps: 0.0,
            drop_at_step: 0,
            drop_gbps: 0.0,
            seed: p.get_usize("seed")? as u64,
            obs: false,
            trace_out: None,
        };
        let blocking = launch(&LaunchConfig {
            params: params.clone(),
            spawn: SpawnMode::Thread,
            feedback_out: None,
            rendezvous_timeout: std::time::Duration::from_secs(60),
            bind: "127.0.0.1:0".parse().unwrap(),
        })?;
        let overlapped = launch(&LaunchConfig {
            params: WorkerParams { overlap: OverlapMode::Buckets, ..params },
            spawn: SpawnMode::Thread,
            feedback_out: None,
            rendezvous_timeout: std::time::Duration::from_secs(60),
            bind: "127.0.0.1:0".parse().unwrap(),
        })?;

        let off_s = mean_steady_step(&blocking);
        let on_s = mean_steady_step(&overlapped);
        let speedup = if on_s > 0.0 { off_s / on_s } else { 0.0 };

        let mut t = Table::new(
            format!("overlap ablation: {workers} workers, {steps} steps over loopback TCP"),
            &["mode", "mean step (steady)", "collective busy (mean)", "checksum[0]"],
        );
        for (name, r, mean) in
            [("off (blocking)", &blocking, off_s), ("buckets (overlapped)", &overlapped, on_s)]
        {
            t.row(vec![
                name.into(),
                crate::util::fmt::secs(mean),
                crate::util::fmt::secs(
                    r.allreduce_s.iter().sum::<f64>() / r.allreduce_s.len().max(1) as f64,
                ),
                format!("{:x}", r.checksums.first().copied().unwrap_or(0)),
            ]);
        }

        let mut out = Outcome::new();
        out.metric("blocking_step_s", off_s);
        out.metric("overlapped_step_s", on_s);
        out.metric("overlap_speedup", speedup);
        out.metric("effective_bus_gbps", overlapped.effective_bus_gbps);
        out.checks.push(Check::assert(
            "final tensors bit-identical within each run",
            blocking.identical && overlapped.identical,
            format!(
                "blocking {:x?} overlapped {:x?}",
                blocking.checksums, overlapped.checksums
            ),
        ));
        out.checks.push(Check::assert(
            "overlapped run bit-identical to the blocking run (same arithmetic)",
            blocking.checksums == overlapped.checksums,
            format!("{:x?} vs {:x?}", blocking.checksums, overlapped.checksums),
        ));
        out.checks.push(Check::assert(
            "overlapped step time strictly below blocking",
            on_s < off_s,
            format!("{:.1} ms vs {:.1} ms ({speedup:.3}x)", on_s * 1e3, off_s * 1e3),
        ));
        out.tables.push(t);
        Ok(out)
    }
}

/// Shared cluster parsing for the two analytic scenarios.
fn cluster_from(p: &ParamValues) -> Result<(usize, usize, usize)> {
    let servers = p.get_usize("servers")?;
    ensure!((2..=1024).contains(&servers), "parameter servers: must be in 2..=1024, got {servers}");
    let gpus = p.get_usize("gpus")?;
    ensure!((1..=64).contains(&gpus), "parameter gpus: must be in 1..=64, got {gpus}");
    let streams = p.get_usize("streams")?;
    ensure!((1..=64).contains(&streams), "parameter streams: must be in 1..=64, got {streams}");
    Ok((servers, gpus, streams))
}

fn transport_for(streams: usize) -> KernelTcpModel {
    if streams > 1 {
        StripedModel::with_streams(streams).to_kernel_model()
    } else {
        KernelTcpModel::default()
    }
}

fn run_bucket_size_sweep(p: &ParamValues) -> Result<Outcome> {
    let model = p.get_model("model")?;
    let (servers, gpus, streams) = cluster_from(p)?;
    let bw = p.get_f64("bandwidth")?;
    let mut mbs = p.get_f64_list("bucket-mbs")?;
    ensure!(mbs.len() >= 3, "parameter bucket-mbs: need >= 3 sizes to locate an interior optimum");
    mbs.sort_by(f64::total_cmp);
    let trace = backward_trace(&model.profile());

    let mut fig = Figure::new(
        "bucket_size_sweep",
        format!("Step time vs bucket threshold ({model}, {servers}x{gpus}, {bw} Gbps, striped:{streams})"),
        "bucket MB",
        "step seconds",
    );
    let mut s = Series::new("overlapped step time");
    let mut t = Table::new(
        format!("bucket size sweep: {model} at {bw} Gbps"),
        &["bucket MB", "buckets", "step", "overhead", "comm (serial)"],
    );
    let mut times = Vec::with_capacity(mbs.len());
    for &mb in &mbs {
        let r = overlap_step(&OverlapModelParams::engine(
            trace.clone(),
            servers,
            gpus,
            bw,
            transport_for(streams),
            mb,
        ));
        s.push(mb, r.step_time_s);
        t.row(vec![
            format!("{mb}"),
            r.buckets.to_string(),
            crate::util::fmt::secs(r.step_time_s),
            crate::util::fmt::secs(r.t_overhead),
            crate::util::fmt::secs(r.t_comm_s),
        ]);
        times.push(r.step_time_s);
    }
    fig.series.push(s);

    let best = times
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .expect(">= 3 sizes");
    let mut out = Outcome::new();
    out.metric("best_bucket_mb", mbs[best]);
    out.metric("best_step_s", times[best]);
    out.metric("smallest_bucket_step_s", times[0]);
    out.metric("largest_bucket_step_s", *times.last().expect("non-empty"));
    out.checks.push(Check::assert(
        "optimal bucket size is interior (both extremes lose)",
        best != 0 && best != times.len() - 1,
        format!(
            "best {} MB at index {best} of {} (ends: {:.3}s / {:.3}s, best {:.3}s)",
            mbs[best],
            times.len(),
            times[0],
            times.last().expect("non-empty"),
            times[best]
        ),
    ));
    out.figures.push(fig);
    out.tables.push(t);
    Ok(out)
}

fn run_scaling_factor_recovered(p: &ParamValues) -> Result<Outcome> {
    let model = p.get_model("model")?;
    let (servers, gpus, streams) = cluster_from(p)?;
    let bucket_mb = p.get_f64("bucket-mb")?;
    let target = p.get_f64("target")?;
    ensure!(
        (0.0..=1.0).contains(&target),
        "parameter target: must be in (0, 1], got {target}"
    );
    let mut bws = p.get_f64_list("bandwidths")?;
    ensure!(!bws.is_empty(), "parameter bandwidths: list is empty");
    bws.sort_by(f64::total_cmp);
    let trace = backward_trace(&model.profile());

    let mut fig = Figure::new(
        "scaling_factor_recovered",
        format!("Scaling factor vs bandwidth ({model}, {servers}x{gpus}): bound vs recovered vs broken"),
        "Gbps",
        "scaling factor",
    );
    let mut s_bound = Series::new("full-utilization bound");
    let mut s_rec = Series::new(format!("overlap + striped:{streams}"));
    let mut s_broken = Series::new("blocking + single-stream");
    let mut dominates = true;
    let mut last = (0.0, 0.0, 0.0); // (bound, recovered, broken) at peak bw
    for &bw in &bws {
        let bound = overlap_step(&OverlapModelParams::ideal_bound(
            trace.clone(),
            servers,
            gpus,
            bw,
            bucket_mb,
        ));
        let recovered = overlap_step(&OverlapModelParams::engine(
            trace.clone(),
            servers,
            gpus,
            bw,
            transport_for(streams),
            bucket_mb,
        ));
        let broken = {
            // The paper's measured configuration: hook-driven inflation,
            // single kernel-TCP pipeline, aggregation after backward.
            let mut q = OverlapModelParams::engine(
                trace.clone(),
                servers,
                gpus,
                bw,
                KernelTcpModel::default(),
                bucket_mb,
            );
            q.mode = OverlapMode::Off;
            q.compute_inflation = 1.12;
            overlap_step(&q)
        };
        s_bound.push(bw, bound.scaling_factor);
        s_rec.push(bw, recovered.scaling_factor);
        s_broken.push(bw, broken.scaling_factor);
        dominates &= recovered.scaling_factor + 1e-9 >= broken.scaling_factor;
        last = (bound.scaling_factor, recovered.scaling_factor, broken.scaling_factor);
    }
    fig.series.push(s_bound);
    fig.series.push(s_rec);
    fig.series.push(s_broken);

    let peak_bw = *bws.last().expect("non-empty");
    let recovery = if last.0 > 0.0 { last.1 / last.0 } else { 0.0 };
    let mut out = Outcome::new();
    out.metric("sf_bound", last.0);
    out.metric("sf_overlap_striped", last.1);
    out.metric("sf_blocking_single", last.2);
    out.metric("recovery_frac", recovery);
    out.checks.push(Check::assert(
        "overlap + striped reaches the bound at the peak rate",
        recovery >= target,
        format!(
            "{:.3} vs bound {:.3} at {peak_bw} Gbps: {:.1}% recovered (target {:.0}%)",
            last.1,
            last.0,
            recovery * 100.0,
            target * 100.0
        ),
    ));
    out.checks.push(Check::assert(
        "overlap + striped never below the blocking single-stream baseline",
        dominates,
        format!("{} swept rates", bws.len()),
    ));
    out.checks.push(Check::assert(
        "the blocking baseline genuinely misses the bound at the peak rate",
        last.2 < target * last.0,
        format!("broken {:.3} vs target {:.3}", last.2, target * last.0),
    ));
    out.figures.push(fig);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> ScenarioRegistry {
        ScenarioRegistry::builtin()
    }

    #[test]
    fn overlap_ablation_meets_acceptance() {
        // Shrunk but still decisively compute-heavy: ~50 ms modeled
        // backward vs a few ms of comm; the overlapped run hides the comm
        // and both runs end bit-identical.
        let out = registry()
            .get("overlap_ablation")
            .unwrap()
            .run(&[
                ("workers".to_string(), "2".to_string()),
                ("steps".to_string(), "3".to_string()),
                ("elems".to_string(), "2097152".to_string()),
                ("compute-us".to_string(), "50000".to_string()),
                ("bucket-mb".to_string(), "1".to_string()),
            ])
            .unwrap();
        assert!(out.passed(), "checks failed: {:?}", out.checks);
        assert!(out.metric_value("overlap_speedup").unwrap() > 1.0);
    }

    #[test]
    fn bucket_size_sweep_has_interior_optimum() {
        let out = registry().get("bucket_size_sweep").unwrap().run(&[]).unwrap();
        assert!(out.passed(), "checks failed: {:?}", out.checks);
        let best = out.metric_value("best_bucket_mb").unwrap();
        assert!(best > 0.25 && best < 600.0, "{best}");
        assert!(
            out.metric_value("smallest_bucket_step_s").unwrap()
                > out.metric_value("best_step_s").unwrap()
        );
        assert!(
            out.metric_value("largest_bucket_step_s").unwrap()
                > out.metric_value("best_step_s").unwrap()
        );
    }

    #[test]
    fn scaling_factor_recovered_meets_acceptance() {
        // The ISSUE's acceptance criterion verbatim: >= 0.9 of the
        // analytic full-utilization bound at 100 Gbps.
        let out = registry().get("scaling_factor_recovered").unwrap().run(&[]).unwrap();
        assert!(out.passed(), "checks failed: {:?}", out.checks);
        let bound = out.metric_value("sf_bound").unwrap();
        let recovered = out.metric_value("sf_overlap_striped").unwrap();
        let broken = out.metric_value("sf_blocking_single").unwrap();
        assert!(recovered >= 0.9 * bound, "{recovered} vs bound {bound}");
        assert!(broken < recovered, "{broken} vs {recovered}");
        assert!(out.metric_value("recovery_frac").unwrap() >= 0.9);
    }

    #[test]
    fn recovery_holds_for_every_paper_model() {
        for model in ["resnet50", "resnet101", "vgg16"] {
            let out = registry()
                .get("scaling_factor_recovered")
                .unwrap()
                .run(&[("model".to_string(), model.to_string())])
                .unwrap();
            assert!(out.passed(), "{model}: {:?}", out.checks);
        }
    }

    #[test]
    fn overlap_scenarios_are_sweepable() {
        let reg = registry();
        let scenario = reg.get("bucket_size_sweep").unwrap();
        let points = crate::engine::SweepBuilder::new(scenario)
            .axis_csv("bandwidth", "10,100")
            .run(2);
        assert_eq!(points.len(), 2);
        for p in &points {
            assert!(p.outcome.is_ok());
        }
    }
}
