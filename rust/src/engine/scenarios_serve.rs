//! Service scenarios: multi-tenant contention and queue throughput.
//!
//! * `multi_tenant_contention` — N emulated jobs share one NIC
//!   ([`Shaper`]) under weighted fair sharing. The claim: priority
//!   weights protect the high-priority tenant (its step-time degradation
//!   vs running alone stays within a bound) *while* total NIC
//!   utilization stays at least the single-job level — contention packs
//!   the link instead of wasting it. `harness=model` is the analytic
//!   fluid model (fast, deterministic); `harness=emulate` runs real
//!   threads against a shared shaper and checks the same two properties
//!   on measured wall clock;
//! * `serve_throughput` — a burst of M jobs against W workers through
//!   the same [`crate::engine::jobqueue`] adapter + [`JobQueue`] the
//!   daemon uses: submission→completion latency percentiles, makespan,
//!   jobs/s, and the ordering claim that a single worker drains
//!   strictly by priority.

use super::outcome::Outcome;
use super::params::{ParamKind, ParamSchema, ParamSpec, ParamValues};
use super::registry::{Scenario, ScenarioRegistry};
use crate::engine::jobqueue::{self, JobRequest};
use crate::net::shaper::Shaper;
use crate::report::{Check, Table};
use crate::serve::queue::JobQueue;
use crate::topology::{Topology, WorkerId};
use crate::Result;
use anyhow::ensure;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Register the service scenarios (called from
/// [`ScenarioRegistry::builtin`]).
pub(crate) fn register(r: &mut ScenarioRegistry) -> Result<()> {
    r.register(Scenario::new(
        "multi_tenant_contention",
        "N tenants share one NIC: weighted fairness protects the hi-pri tenant without idling the link",
        ParamSchema::new(vec![
            ParamSpec::new("harness", "model (analytic fluid shares) or emulate (real threads on a shared Shaper)", ParamKind::Choice(&["model", "emulate"]), "model"),
            ParamSpec::new("tenants", "concurrent jobs sharing the NIC", ParamKind::Int, "3"),
            ParamSpec::new("steps", "training steps per tenant", ParamKind::Int, "6"),
            ParamSpec::new("weights", "per-tenant fair-share weights (first = hi-pri by convention)", ParamKind::FloatList, "4,1,1"),
            ParamSpec::new("rate-gbps", "shared NIC rate, Gbps", ParamKind::PositiveFloat, "1"),
            ParamSpec::new("payload-mb", "gradient payload per step, MB", ParamKind::PositiveFloat, "4"),
            ParamSpec::new("compute-ms", "compute phase per step, ms", ParamKind::PositiveFloat, "20"),
            ParamSpec::new("max-degradation", "hi-pri step-time bound, × its solo step time", ParamKind::PositiveFloat, "1.6"),
            ParamSpec::new("min-utilization-frac", "contended aggregate NIC utilization floor, × the solo level", ParamKind::PositiveFloat, "0.9"),
        ]),
        Box::new(ContentionRunner),
    ))?;
    r.register(Scenario::new(
        "serve_throughput",
        "burst of M jobs vs W workers through the job queue: latency percentiles, makespan, priority order",
        ParamSchema::new(vec![
            ParamSpec::new("jobs", "burst size M", ParamKind::Int, "8"),
            ParamSpec::new("workers", "worker threads W", ParamKind::Int, "2"),
            ParamSpec::new("queue-cap", "queue capacity (must admit the whole burst)", ParamKind::Int, "32"),
            ParamSpec::new("scenario", "inner scenario each job runs", ParamKind::Str, "simulate"),
        ]),
        Box::new(ThroughputRunner),
    ))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// multi_tenant_contention
// ---------------------------------------------------------------------------

struct ContentionRunner;

/// Shared, validated parameters for both harnesses.
struct Contention {
    tenants: usize,
    steps: usize,
    weights: Vec<f64>,
    /// Shared NIC rate, bytes/second.
    rate_bps: f64,
    payload_bytes: u64,
    compute_s: f64,
    max_degradation: f64,
    min_util_frac: f64,
    /// Index of the high-priority tenant (largest weight).
    hi: usize,
}

impl Contention {
    fn from(p: &ParamValues) -> Result<Contention> {
        let tenants = p.get_usize("tenants")?;
        ensure!((2..=16).contains(&tenants), "parameter tenants: must be in 2..=16, got {tenants}");
        let steps = p.get_usize("steps")?;
        ensure!(steps >= 2, "parameter steps: must be >= 2, got {steps}");
        let weights = p.get_f64_list("weights")?;
        ensure!(
            weights.len() == tenants,
            "parameter weights: {} values for {tenants} tenants",
            weights.len()
        );
        let hi = weights
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .expect("non-empty weights");
        Ok(Contention {
            tenants,
            steps,
            rate_bps: p.get_f64("rate-gbps")? * 1e9 / 8.0,
            payload_bytes: (p.get_f64("payload-mb")? * 1e6) as u64,
            compute_s: p.get_f64("compute-ms")? / 1e3,
            max_degradation: p.get_f64("max-degradation")?,
            min_util_frac: p.get_f64("min-utilization-frac")?,
            weights,
            hi,
        })
    }

    /// A tenant's solo step time: compute + full-rate serialization.
    fn solo_step_s(&self) -> f64 {
        self.compute_s + self.payload_bytes as f64 / self.rate_bps
    }
}

impl super::runner::Runner for ContentionRunner {
    fn mode(&self) -> &'static str {
        "serve"
    }

    fn realtime(&self) -> bool {
        // The emulate harness sleeps through real compute + wire time.
        true
    }

    fn run(&self, p: &ParamValues) -> Result<Outcome> {
        let c = Contention::from(p)?;
        match p.get_str("harness")? {
            "emulate" => run_contention_emulate(&c),
            _ => run_contention_model(&c),
        }
    }
}

/// Analytic fluid model: every tenant is always active, so tenant `i`
/// holds share `w_i / Σw` of the NIC for the whole run.
fn run_contention_model(c: &Contention) -> Result<Outcome> {
    let total_w: f64 = c.weights.iter().sum();
    let solo_step = c.solo_step_s();
    let solo_util = (c.payload_bytes as f64 / solo_step) / c.rate_bps;

    let mut t = Table::new(
        format!("{} tenants on one NIC (fluid shares)", c.tenants),
        &["tenant", "weight", "share", "step s", "degradation", "goodput MB/s"],
    );
    let mut agg_bps = 0.0;
    let mut steps_s = Vec::with_capacity(c.tenants);
    for (i, w) in c.weights.iter().enumerate() {
        let share = w / total_w;
        let step = c.compute_s + c.payload_bytes as f64 / (c.rate_bps * share);
        let goodput = c.payload_bytes as f64 / step;
        agg_bps += goodput;
        steps_s.push(step);
        t.row(vec![
            format!("{i}{}", if i == c.hi { " (hi)" } else { "" }),
            format!("{w}"),
            format!("{share:.3}"),
            crate::util::fmt::secs(step),
            format!("{:.2}x", step / solo_step),
            format!("{:.1}", goodput / 1e6),
        ]);
    }
    let degradation = steps_s[c.hi] / solo_step;
    let agg_util = (agg_bps / c.rate_bps).min(1.0);

    let mut out = Outcome::new();
    contention_outcome(&mut out, c, solo_step, solo_util, degradation, agg_util);
    out.tables.push(t);
    Ok(out)
}

/// Real-thread harness: a solo baseline run, then all tenants together
/// on one shared [`Shaper`] with per-flow weights, both measured on the
/// wall clock. Same two checks as the model, on measured numbers.
fn run_contention_emulate(c: &Contention) -> Result<Outcome> {
    const LATENCY_S: f64 = 50e-6;
    let topo = Topology::new(2, 1);

    // Solo baseline: one tenant, the whole NIC.
    let solo_shaper = Arc::new(Shaper::new(topo, c.rate_bps, LATENCY_S));
    let flow = solo_shaper.register_flow(c.weights[c.hi]);
    let t0 = Instant::now();
    for _ in 0..c.steps {
        spin_compute(c.compute_s);
        solo_shaper.admit_weighted(flow, WorkerId(0), WorkerId(1), c.payload_bytes);
    }
    let solo_elapsed = t0.elapsed().as_secs_f64();
    let solo_step = solo_elapsed / c.steps as f64;
    let solo_util =
        solo_shaper.counters().total_egress() as f64 / solo_elapsed / c.rate_bps;

    // Contended: every tenant on one fresh shaper, one flow each.
    let shaper = Arc::new(Shaper::new(topo, c.rate_bps, LATENCY_S));
    let flows: Vec<_> = c.weights.iter().map(|w| shaper.register_flow(*w)).collect();
    let t0 = Instant::now();
    let handles: Vec<_> = flows
        .into_iter()
        .map(|flow| {
            let shaper = Arc::clone(&shaper);
            let (steps, compute_s, payload) = (c.steps, c.compute_s, c.payload_bytes);
            std::thread::spawn(move || {
                let start = Instant::now();
                for _ in 0..steps {
                    spin_compute(compute_s);
                    shaper.admit_weighted(flow, WorkerId(0), WorkerId(1), payload);
                }
                start.elapsed().as_secs_f64()
            })
        })
        .collect();
    let elapsed: Vec<f64> =
        handles.into_iter().map(|h| h.join().expect("tenant thread")).collect();
    let makespan = t0.elapsed().as_secs_f64();
    let agg_util = shaper.counters().total_egress() as f64 / makespan / c.rate_bps;
    let degradation = (elapsed[c.hi] / c.steps as f64) / solo_step;

    let mut t = Table::new(
        format!("{} tenants on one emulated NIC (measured)", c.tenants),
        &["tenant", "weight", "steps", "elapsed", "step s", "degradation"],
    );
    for (i, e) in elapsed.iter().enumerate() {
        t.row(vec![
            format!("{i}{}", if i == c.hi { " (hi)" } else { "" }),
            format!("{}", c.weights[i]),
            c.steps.to_string(),
            crate::util::fmt::secs(*e),
            crate::util::fmt::secs(e / c.steps as f64),
            format!("{:.2}x", (e / c.steps as f64) / solo_step),
        ]);
    }

    let mut out = Outcome::new();
    contention_outcome(&mut out, c, solo_step, solo_util, degradation, agg_util);
    out.metric("makespan_s", makespan);
    out.tables.push(t);
    Ok(out)
}

/// Busy-wait compute stand-in. Sleeping would free the core, but the
/// emulate harness wants the compute phase on the wall clock regardless
/// of scheduler granularity; a spin keeps short phases honest.
fn spin_compute(seconds: f64) {
    if seconds <= 0.0 {
        return;
    }
    let deadline = Instant::now() + Duration::from_secs_f64(seconds);
    while Instant::now() < deadline {
        std::hint::spin_loop();
    }
}

/// The metrics + the two CHECKED claims, shared by both harnesses.
fn contention_outcome(
    out: &mut Outcome,
    c: &Contention,
    solo_step: f64,
    solo_util: f64,
    degradation: f64,
    agg_util: f64,
) {
    out.metric("solo_step_s", solo_step);
    out.metric("solo_utilization", solo_util);
    out.metric("hi_pri_degradation", degradation);
    out.metric("aggregate_utilization", agg_util);
    out.metric("tenants", c.tenants as f64);
    out.checks.push(Check::assert(
        "hi-pri tenant's step-time degradation stays within the bound",
        degradation <= c.max_degradation,
        format!(
            "hi-pri {:.2}x its solo step {} (bound {:.2}x; weights {:?})",
            degradation,
            crate::util::fmt::secs(solo_step),
            c.max_degradation,
            c.weights
        ),
    ));
    out.checks.push(Check::assert(
        "contended aggregate NIC utilization at least the single-job level",
        agg_util >= c.min_util_frac * solo_util,
        format!(
            "aggregate {:.1}% vs solo {:.1}% (floor {:.0}% of solo): sharing must pack the link, not idle it",
            agg_util * 100.0,
            solo_util * 100.0,
            c.min_util_frac * 100.0
        ),
    ));
}

// ---------------------------------------------------------------------------
// serve_throughput
// ---------------------------------------------------------------------------

struct ThroughputRunner;

impl super::runner::Runner for ThroughputRunner {
    fn mode(&self) -> &'static str {
        "serve"
    }

    fn realtime(&self) -> bool {
        // Latencies are wall-clock measurements over real threads.
        true
    }

    fn run(&self, p: &ParamValues) -> Result<Outcome> {
        let jobs = p.get_usize("jobs")?;
        ensure!((2..=256).contains(&jobs), "parameter jobs: must be in 2..=256, got {jobs}");
        let workers = p.get_usize("workers")?;
        ensure!((1..=32).contains(&workers), "parameter workers: must be in 1..=32, got {workers}");
        let cap = p.get_usize("queue-cap")?;
        ensure!(cap >= jobs, "parameter queue-cap: must admit the burst ({cap} < {jobs})");
        let inner = p.get_str("scenario")?.to_string();
        ensure!(
            inner != "serve_throughput" && inner != "multi_tenant_contention",
            "parameter scenario: {inner:?} would recurse into the service scenarios"
        );
        let registry = ScenarioRegistry::builtin();
        let request = |priority: u8| JobRequest {
            scenario: inner.clone(),
            params: Vec::new(),
            priority,
        };
        jobqueue::validate(&registry, &request(5))?;

        // Burst phase: M jobs land at t0, W workers drain them. Each
        // completion records (job id, submission→done latency).
        let queue = Arc::new(JobQueue::new(cap, workers));
        let done: Arc<Mutex<Vec<(u64, f64)>>> = Arc::new(Mutex::new(Vec::new()));
        let t0 = Instant::now();
        let pool: Vec<_> = (0..workers)
            .map(|_| {
                let queue = Arc::clone(&queue);
                let done = Arc::clone(&done);
                let inner = inner.clone();
                std::thread::spawn(move || {
                    let registry = ScenarioRegistry::builtin();
                    let req =
                        JobRequest { scenario: inner, params: Vec::new(), priority: 5 };
                    while let Some(id) = queue.pop() {
                        let outcome = jobqueue::execute(&registry, &req);
                        let ok = outcome.is_ok();
                        done.lock().unwrap().push((id, t0.elapsed().as_secs_f64()));
                        assert!(ok, "inner scenario failed mid-burst");
                    }
                })
            })
            .collect();
        for id in 0..jobs as u64 {
            queue
                .push(id, (id % 10) as u8)
                .map_err(|e| anyhow::anyhow!("burst admission failed: {e:?}"))?;
        }
        while done.lock().unwrap().len() < jobs {
            std::thread::sleep(Duration::from_millis(2));
        }
        queue.close();
        for h in pool {
            h.join().expect("worker thread");
        }
        let mut latencies: Vec<f64> =
            done.lock().unwrap().iter().map(|(_, l)| *l).collect();
        let completed = latencies.len();
        latencies.sort_by(f64::total_cmp);
        let makespan = latencies.last().copied().unwrap_or(0.0);
        let pct = |q: f64| latencies[((latencies.len() - 1) as f64 * q) as usize];

        // Ordering phase: pre-fill, then let ONE worker drain — the pops
        // must come out in strict priority order (FIFO within a level).
        let q2 = JobQueue::new(cap, 1);
        let priorities: Vec<u8> = (0..jobs).map(|i| ((i * 7 + 3) % 10) as u8).collect();
        for (id, pri) in priorities.iter().enumerate() {
            q2.push(id as u64, *pri).map_err(|e| anyhow::anyhow!("admission failed: {e:?}"))?;
        }
        let mut drained_pri = Vec::with_capacity(jobs);
        while let Some(id) = {
            if q2.is_empty() {
                None
            } else {
                q2.pop()
            }
        } {
            drained_pri.push(priorities[id as usize]);
        }
        let ordered = drained_pri.windows(2).all(|w| w[0] >= w[1]);

        let mut out = Outcome::new();
        out.metric("jobs", jobs as f64);
        out.metric("workers", workers as f64);
        out.metric("p50_latency_s", pct(0.50));
        out.metric("p95_latency_s", pct(0.95));
        out.metric("makespan_s", makespan);
        out.metric("jobs_per_s", completed as f64 / makespan.max(1e-9));
        out.checks.push(Check::assert(
            "every burst job completed (none lost, none failed)",
            completed == jobs,
            format!("{completed} of {jobs} jobs finished in {}", crate::util::fmt::secs(makespan)),
        ));
        out.checks.push(Check::assert(
            "a single worker drains strictly in priority order",
            ordered && drained_pri.len() == jobs,
            format!("drain order {drained_pri:?}"),
        ));
        let mut t = Table::new(
            format!("burst of {jobs} '{inner}' jobs over {workers} workers"),
            &["percentile", "latency"],
        );
        for (label, q) in [("p50", 0.50), ("p90", 0.90), ("p95", 0.95), ("max", 1.0)] {
            t.row(vec![label.to_string(), crate::util::fmt::secs(pct(q))]);
        }
        out.tables.push(t);
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kv(pairs: &[(&str, &str)]) -> Vec<(String, String)> {
        pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
    }

    #[test]
    fn contention_model_passes_with_defaults() {
        let out =
            ScenarioRegistry::builtin().get("multi_tenant_contention").unwrap().run(&[]).unwrap();
        assert!(out.passed(), "checks failed: {:?}", out.checks);
        // With weights 4,1,1 the hi tenant holds 2/3 of the NIC: visibly
        // degraded vs solo but far under an even 3-way split.
        let d = out.metric_value("hi_pri_degradation").unwrap();
        assert!(d > 1.0 && d < 1.6, "degradation {d}");
        // Contention must *raise* aggregate utilization over one job.
        assert!(
            out.metric_value("aggregate_utilization").unwrap()
                > out.metric_value("solo_utilization").unwrap()
        );
    }

    #[test]
    fn contention_model_flags_a_starved_hi_tenant() {
        // Equal weights across 8 tenants: the "hi" tenant gets 1/8 of the
        // NIC and blows any reasonable degradation bound.
        let out = ScenarioRegistry::builtin()
            .get("multi_tenant_contention")
            .unwrap()
            .run(&kv(&[
                ("tenants", "8"),
                ("weights", "1,1,1,1,1,1,1,1"),
                ("max-degradation", "1.5"),
            ]))
            .unwrap();
        assert!(!out.passed(), "equal 8-way sharing must violate the hi-pri bound");
    }

    #[test]
    fn contention_emulate_measures_the_same_claims() {
        // Small real run: 2 tenants, 3:1 weights, ~1 MB payloads.
        let out = ScenarioRegistry::builtin()
            .get("multi_tenant_contention")
            .unwrap()
            .run(&kv(&[
                ("harness", "emulate"),
                ("tenants", "2"),
                ("steps", "4"),
                ("weights", "3,1"),
                ("payload-mb", "1"),
                ("compute-ms", "5"),
                ("max-degradation", "1.7"),
                ("min-utilization-frac", "0.8"),
            ]))
            .unwrap();
        assert!(out.passed(), "checks failed: {:?}", out.checks);
        assert!(out.metric_value("makespan_s").unwrap() > 0.0);
    }

    #[test]
    fn contention_rejects_mismatched_weights() {
        let err = ScenarioRegistry::builtin()
            .get("multi_tenant_contention")
            .unwrap()
            .run(&kv(&[("tenants", "3"), ("weights", "1,2")]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("weights"), "{err}");
    }

    #[test]
    fn throughput_burst_completes_and_orders() {
        let out = ScenarioRegistry::builtin()
            .get("serve_throughput")
            .unwrap()
            .run(&kv(&[("jobs", "6"), ("workers", "2")]))
            .unwrap();
        assert!(out.passed(), "checks failed: {:?}", out.checks);
        assert_eq!(out.metric_value("jobs").unwrap(), 6.0);
        assert!(out.metric_value("p95_latency_s").unwrap() >= out.metric_value("p50_latency_s").unwrap());
    }

    #[test]
    fn throughput_rejects_recursion_and_tiny_queues() {
        let r = ScenarioRegistry::builtin();
        let s = r.get("serve_throughput").unwrap();
        assert!(s.run(&kv(&[("scenario", "serve_throughput")])).is_err());
        assert!(s.run(&kv(&[("jobs", "8"), ("queue-cap", "4")])).is_err());
    }
}
