//! Transport scenarios: the constructive side of the paper's thesis.
//!
//! The paper diagnoses the bottleneck (a single-stream kernel-TCP
//! transport that strands ~2/3 of a 100 Gbps NIC); these scenarios show
//! the *repair* — multi-stream striping ([`crate::net::striped`]) — at
//! the model level, sweepable like every other experiment:
//!
//! * `transport_ablation` — effective throughput and simulated scaling
//!   factor as the stream count sweeps 1..N at one provisioned rate;
//! * `chunk_size_sweep` — one-shot message throughput vs chunk size
//!   (pipelining granularity: tiny chunks pay per-chunk software cost,
//!   huge chunks lose store-and-forward overlap);
//! * `fig4_recovered` — the paper's Fig 4 axes with the striped
//!   transport next to the broken one: utilization climbing back toward
//!   the provisioned line;
//! * `utilization_frontier` — scaling factor across transport ×
//!   bandwidth × model, and the cheapest provisioned rate at which each
//!   transport reaches a target scaling factor.

use super::outcome::Outcome;
use super::params::{ParamKind, ParamSchema, ParamSpec, ParamValues};
use super::registry::{Scenario, ScenarioRegistry};
use crate::config::TransportKind;
use crate::models::timing::backward_trace;
use crate::models::ModelId;
use crate::net::kernel_tcp::KernelTcpModel;
use crate::net::striped::StripedModel;
use crate::report::{Check, Figure, Series, Table};
use crate::sim::whatif::{fig4_recovered_utilization, GPUS_PER_SERVER};
use crate::sim::{simulate, SimParams};
use crate::Result;
use anyhow::ensure;

/// Register the four transport scenarios (called from
/// [`ScenarioRegistry::builtin`]).
pub(crate) fn register(r: &mut ScenarioRegistry) -> Result<()> {
    r.register(Scenario::from_fn(
        "transport_ablation",
        "effective throughput and simulated scaling vs stream count (single vs striped:N)",
        ParamSchema::new(vec![
            ParamSpec::new("model", "resnet50|resnet101|vgg16", ParamKind::Model, "vgg16"),
            ParamSpec::new("bandwidth", "provisioned Gbps", ParamKind::PositiveFloat, "100"),
            ParamSpec::new("max-streams", "largest stream count swept", ParamKind::Int, "16"),
        ]),
        "analytic",
        run_transport_ablation,
    ))?;
    r.register(Scenario::from_fn(
        "chunk_size_sweep",
        "one-shot striped message throughput vs pipelining chunk size",
        ParamSchema::new(vec![
            ParamSpec::new("streams", "parallel connections", ParamKind::Int, "8"),
            ParamSpec::new("bandwidth", "provisioned Gbps", ParamKind::PositiveFloat, "100"),
            ParamSpec::new("message-mb", "message size in MB", ParamKind::PositiveFloat, "64"),
        ]),
        "analytic",
        run_chunk_size_sweep,
    ))?;
    r.register(Scenario::from_fn(
        "fig4_recovered",
        "paper Fig 4 with the striped transport: network utilization recovered",
        ParamSchema::new(vec![ParamSpec::new(
            "streams",
            "parallel connections",
            ParamKind::Int,
            "8",
        )]),
        "analytic",
        run_fig4_recovered,
    ))?;
    r.register(Scenario::from_fn(
        "utilization_frontier",
        "scaling factor across transport x bandwidth x model, with the bandwidth frontier per transport",
        ParamSchema::new(vec![
            ParamSpec::new("streams", "striped stream count", ParamKind::Int, "8"),
            ParamSpec::new("target", "scaling-factor frontier target", ParamKind::PositiveFloat, "0.8"),
            ParamSpec::new("bandwidths", "comma list of Gbps", ParamKind::FloatList, "1,5,10,25,50,100"),
        ]),
        "analytic",
        run_utilization_frontier,
    ))?;
    Ok(())
}

fn run_transport_ablation(p: &ParamValues) -> Result<Outcome> {
    let model = p.get_model("model")?;
    let bw = p.get_f64("bandwidth")?;
    let max_streams = p.get_usize("max-streams")?;
    ensure!(
        (1..=64).contains(&max_streams),
        "parameter max-streams: must be in 1..=64, got {max_streams}"
    );
    let single = KernelTcpModel::default();
    let single_eff = single.effective_gbps(bw);
    let trace = backward_trace(&model.profile());

    let mut fig = Figure::new(
        "transport_ablation",
        format!("Transport ablation at {bw} Gbps ({model}, 8 servers)"),
        "streams",
        "effective Gbps / scaling factor",
    );
    let mut s_eff = Series::new("effective Gbps (striped:N)");
    let mut s_sf = Series::new("scaling factor (simulated)");
    let mut s_single = Series::new("effective Gbps (single-stream)");
    let mut last_eff = 0.0;
    for n in 1..=max_streams {
        let eff = StripedModel::with_streams(n).effective_gbps(bw);
        let sf =
            simulate(&SimParams::striped_like(trace.clone(), 8, GPUS_PER_SERVER, bw, n))
                .scaling_factor;
        s_eff.push(n as f64, eff);
        s_sf.push(n as f64, sf);
        s_single.push(n as f64, single_eff);
        last_eff = eff;
    }
    fig.series.push(s_eff);
    fig.series.push(s_sf);
    fig.series.push(s_single);

    let mut t = Table::new(
        format!("transport ablation: {model}, {bw} Gbps provisioned"),
        &["streams", "effective Gbps", "utilization", "speedup vs single", "scaling factor"],
    );
    for (i, (x, eff)) in fig.series[0].points.iter().enumerate() {
        t.row(vec![
            format!("{x}"),
            format!("{eff:.1}"),
            crate::util::fmt::pct(eff / bw),
            format!("{:.2}x", eff / single_eff),
            format!("{:.3}", fig.series[1].points[i].1),
        ]);
    }

    let mut out = Outcome::new();
    out.metric("single_effective_gbps", single_eff);
    out.metric(format!("effective_gbps@{max_streams}"), last_eff);
    out.metric("speedup_at_max_streams", last_eff / single_eff);
    if max_streams >= 8 {
        let eff8 = StripedModel::with_streams(8).effective_gbps(bw);
        out.metric("effective_gbps@8", eff8);
        out.metric("speedup@8", eff8 / single_eff);
        if bw >= 50.0 {
            // The PR's acceptance criterion: in the software-limited
            // regime, 8 streams at least double the effective throughput.
            out.checks.push(Check::assert(
                "striped:8 >= 2x single-stream effective throughput",
                eff8 / single_eff >= 2.0,
                format!("{eff8:.1} vs {single_eff:.1} Gbps at {bw} Gbps provisioned"),
            ));
        }
    }
    out.checks.push(Check::assert(
        "effective throughput monotone in stream count",
        fig.series[0].points.windows(2).all(|w| w[1].1 >= w[0].1 - 1e-9),
        format!("1..={max_streams} streams at {bw} Gbps"),
    ));
    out.tables.push(t);
    out.figures.push(fig);
    Ok(out)
}

fn run_chunk_size_sweep(p: &ParamValues) -> Result<Outcome> {
    let streams = p.get_usize("streams")?;
    ensure!((1..=256).contains(&streams), "parameter streams: must be in 1..=256, got {streams}");
    let bw = p.get_f64("bandwidth")?;
    let message_bytes = p.get_f64("message-mb")? * 1e6;
    let model = StripedModel::with_streams(streams);

    let mut fig = Figure::new(
        "chunk_size_sweep",
        format!(
            "One-shot throughput vs chunk size ({:.0} MB message, striped:{streams}, {bw} Gbps)",
            message_bytes / 1e6
        ),
        "chunk KiB",
        "effective Gbps",
    );
    let mut s = Series::new("effective Gbps");
    let mut chunk = 16.0 * 1024.0;
    let mut best = (chunk, 0.0f64);
    while chunk <= 16.0 * 1024.0 * 1024.0 {
        let gbps = model.effective_throughput_gbps(message_bytes, bw, chunk);
        s.push(chunk / 1024.0, gbps);
        if gbps > best.1 {
            best = (chunk, gbps);
        }
        chunk *= 2.0;
    }
    let first = s.points.first().expect("non-empty sweep").1;
    let last = s.points.last().expect("non-empty sweep").1;
    fig.series.push(s);

    let mut out = Outcome::new();
    out.metric("best_chunk_kib", best.0 / 1024.0);
    out.metric("best_gbps", best.1);
    out.metric("smallest_chunk_gbps", first);
    out.metric("largest_chunk_gbps", last);
    out.checks.push(Check::assert(
        "chunk size has an interior optimum",
        best.1 > first && best.1 > last,
        format!(
            "best {:.1} Gbps at {:.0} KiB vs {first:.1} (16 KiB) and {last:.1} (16 MiB)",
            best.1,
            best.0 / 1024.0
        ),
    ));
    out.figures.push(fig);
    Ok(out)
}

fn run_fig4_recovered(p: &ParamValues) -> Result<Outcome> {
    let streams = p.get_usize("streams")?;
    ensure!((1..=256).contains(&streams), "parameter streams: must be in 1..=256, got {streams}");
    let fig = fig4_recovered_utilization(streams);
    let single = fig.series("single-stream achievable").expect("series").clone();
    let striped =
        fig.series(&format!("striped:{streams} achievable")).expect("series").clone();
    let mut checks = vec![Check::assert(
        "striped utilization dominates single-stream at every rate",
        single
            .points
            .iter()
            .zip(&striped.points)
            .all(|((_, a), (_, b))| *b + 1e-12 >= *a),
        format!("striped:{streams} vs single across the Fig 4 sweep"),
    )];
    let single_100 = single.y_at(100.0).expect("100 Gbps point");
    let striped_100 = striped.y_at(100.0).expect("100 Gbps point");
    checks.push(Check::assert(
        "single-stream strands the 100 Gbps NIC (paper Fig 4)",
        single_100 < 0.35,
        format!("utilization {single_100:.2}"),
    ));
    if streams >= 8 {
        checks.push(Check::assert(
            "striped transport recovers >= 85% utilization at 100 Gbps",
            striped_100 > 0.85,
            format!("utilization {striped_100:.2} with {streams} streams"),
        ));
    }
    let mut out = Outcome::from_figures(vec![fig], checks);
    out.metric("single_utilization@100g", single_100);
    out.metric("striped_utilization@100g", striped_100);
    out.metric("recovery_factor@100g", striped_100 / single_100);
    Ok(out)
}

fn run_utilization_frontier(p: &ParamValues) -> Result<Outcome> {
    let streams = p.get_usize("streams")?;
    ensure!((1..=256).contains(&streams), "parameter streams: must be in 1..=256, got {streams}");
    let target = p.get_f64("target")?;
    ensure!(
        (0.0..1.0).contains(&target),
        "parameter target: must be in (0, 1), got {target}"
    );
    let mut bws = p.get_f64_list("bandwidths")?;
    ensure!(!bws.is_empty(), "parameter bandwidths: list is empty");
    // The frontier is "the cheapest rate reaching the target" and the
    // peak-bandwidth column is the largest rate: both need ascending
    // order regardless of how the user wrote the list.
    bws.sort_by(f64::total_cmp);

    let transports = [
        TransportKind::KernelTcp,
        TransportKind::Striped { streams },
        TransportKind::FullUtilization,
    ];
    let mut fig = Figure::new(
        "utilization_frontier",
        format!("Scaling factor across transport x bandwidth x model (target {target})"),
        "bandwidth Gbps",
        "scaling factor",
    );
    let mut t = Table::new(
        format!("bandwidth frontier: cheapest provisioned rate reaching scaling factor {target}"),
        &["model", "transport", "frontier Gbps", "sf at max Gbps"],
    );
    let mut out = Outcome::new();
    // (model, transport) -> frontier (None = never reaches target).
    let mut frontiers: Vec<(ModelId, TransportKind, Option<f64>, f64)> = Vec::new();
    for id in ModelId::paper_models() {
        let trace = backward_trace(&id.profile());
        for tk in transports {
            let mut s = Series::new(format!("{} {tk}", id.name()));
            let mut frontier = None;
            let mut sf_at_max = 0.0;
            for &bw in &bws {
                let sp = match tk {
                    TransportKind::KernelTcp => {
                        SimParams::horovod_like(trace.clone(), 8, GPUS_PER_SERVER, bw)
                    }
                    TransportKind::Striped { streams } => {
                        SimParams::striped_like(trace.clone(), 8, GPUS_PER_SERVER, bw, streams)
                    }
                    _ => SimParams::whatif(trace.clone(), 8, GPUS_PER_SERVER, bw),
                };
                let sf = simulate(&sp).scaling_factor;
                s.push(bw, sf);
                if frontier.is_none() && sf >= target {
                    frontier = Some(bw);
                }
                sf_at_max = sf;
            }
            t.row(vec![
                id.name().into(),
                tk.to_string(),
                frontier.map(|b| format!("{b}")).unwrap_or_else(|| "not reached".into()),
                format!("{sf_at_max:.3}"),
            ]);
            if let Some(b) = frontier {
                out.metric(format!("frontier_gbps@{}@{tk}", id.name()), b);
            }
            out.metric(format!("sf_at_max@{}@{tk}", id.name()), sf_at_max);
            frontiers.push((id, tk, frontier, sf_at_max));
            fig.series.push(s);
        }
    }
    // Shape checks: the striped frontier is never worse than the
    // single-stream one, and (for a reachable target) it exists.
    for id in ModelId::paper_models() {
        let get = |want: TransportKind| {
            frontiers
                .iter()
                .find(|(m, tk, _, _)| *m == id && *tk == want)
                .map(|(_, _, f, sf)| (*f, *sf))
                .expect("computed above")
        };
        let (kernel_frontier, kernel_sf_max) = get(TransportKind::KernelTcp);
        let (striped_frontier, striped_sf_max) = get(TransportKind::Striped { streams });
        let dominated = match (striped_frontier, kernel_frontier) {
            (Some(s), Some(k)) => s <= k,
            (Some(_), None) => true,
            (None, None) => true,
            (None, Some(_)) => false,
        };
        out.checks.push(Check::assert(
            format!("{}: striped frontier <= single-stream frontier", id.name()),
            dominated,
            format!("striped {striped_frontier:?} vs single {kernel_frontier:?} Gbps"),
        ));
        // In the software-limited regime the repaired transport must beat
        // the broken one outright (the wire-limited regime is checked for
        // parity by the simulator's own tests).
        if streams >= 8 && bws.last().is_some_and(|b| *b >= 50.0) {
            out.checks.push(Check::assert(
                format!("{}: striped scaling beats single-stream at peak bandwidth", id.name()),
                striped_sf_max >= kernel_sf_max + 0.02,
                format!("striped {striped_sf_max:.3} vs single {kernel_sf_max:.3}"),
            ));
        }
    }
    out.figures.push(fig);
    out.tables.push(t);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> ScenarioRegistry {
        ScenarioRegistry::builtin()
    }

    #[test]
    fn transport_ablation_meets_acceptance() {
        let out = registry().get("transport_ablation").unwrap().run(&[]).unwrap();
        assert!(out.passed(), "checks failed: {out:?}");
        let speedup = out.metric_value("speedup@8").unwrap();
        assert!(speedup >= 2.0, "striped:8 speedup {speedup}");
    }

    #[test]
    fn transport_ablation_wire_limited_regime_has_no_speedup() {
        // At 1 Gbps the wire binds; striping cannot help and the 2x check
        // is (correctly) not emitted.
        let out = registry()
            .get("transport_ablation")
            .unwrap()
            .run(&[("bandwidth".to_string(), "1".to_string())])
            .unwrap();
        assert!(out.passed());
        let speedup = out.metric_value("speedup@8").unwrap();
        assert!(speedup < 1.1, "{speedup}");
    }

    #[test]
    fn chunk_size_sweep_finds_interior_optimum() {
        let out = registry().get("chunk_size_sweep").unwrap().run(&[]).unwrap();
        assert!(out.passed());
        let best = out.metric_value("best_chunk_kib").unwrap();
        assert!(best > 16.0 && best < 16.0 * 1024.0, "{best}");
    }

    #[test]
    fn fig4_recovered_shows_recovery() {
        let out = registry().get("fig4_recovered").unwrap().run(&[]).unwrap();
        assert!(out.passed());
        assert!(out.metric_value("recovery_factor@100g").unwrap() >= 2.5);
    }

    #[test]
    fn utilization_frontier_striped_dominates() {
        let out = registry().get("utilization_frontier").unwrap().run(&[]).unwrap();
        assert!(out.passed(), "{:?}", out.checks);
        // 3 models x 3 transports.
        assert_eq!(out.figures[0].series.len(), 9);
    }

    #[test]
    fn scenarios_are_sweepable() {
        let reg = registry();
        let scenario = reg.get("transport_ablation").unwrap();
        let points = crate::engine::SweepBuilder::new(scenario)
            .fix("max-streams", "4")
            .axis_csv("bandwidth", "10,100")
            .run(1);
        assert_eq!(points.len(), 2);
        for p in &points {
            assert!(p.outcome.is_ok());
        }
    }
}
