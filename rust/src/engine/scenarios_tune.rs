//! Autotuning scenarios: the control plane's measurable claims.
//!
//! * `autotune_convergence` — drive the [`AutoTuner`] against the
//!   analytic oracle at one rate (default 10 Gbps, seeded measurement
//!   noise) and check the chosen operating point lands within tolerance
//!   (default 10%) of the **exhaustive sweep** over the same knob space —
//!   the same objective on both sides, so the gap is pure controller
//!   suboptimality. A ride-along thread-spawn `netbn launch` pair
//!   (autotuned vs static, same seeds) checks the e2e safety property:
//!   FNV-bit-identical final tensors;
//! * `autotune_vs_static` — at every swept rate, the tuned operating
//!   point must beat the repo's default-static configuration
//!   (single-stream kernel-TCP, 25 MB buckets, no compression): the
//!   "configuration, not capacity" thesis as one check;
//! * `autotune_adapt` — the environment moves mid-run. `harness=model`:
//!   the oracle's rate drops after convergence; the tuner must detect the
//!   sustained regression, re-probe, and land within tolerance of the
//!   *post-drop* optimum. `harness=launch`: a real two-worker launch over
//!   loopback TCP with a per-stream rate gate that drops 10× mid-run —
//!   rank 0's re-probe shows up as knob broadcasts after the drop step,
//!   and the run stays bit-identical to the equivalent static launch.

use super::outcome::Outcome;
use super::params::{ParamKind, ParamSchema, ParamSpec, ParamValues};
use super::registry::{Scenario, ScenarioRegistry};
use crate::config::{CollectiveKind, OverlapMode, TransportKind};
use crate::report::{Check, Figure, Series, Table};
use crate::trainer::launch::{launch, LaunchConfig, SpawnMode, WorkerParams};
use crate::tune::{
    drive_until_exploit, noisy_oracle_step, AutoTuner, KnobPoint, KnobSpace, OracleEnv,
    TunerConfig, TunerState,
};
use crate::util::Rng;
use crate::Result;
use anyhow::ensure;

/// Register the three autotune scenarios (called from
/// [`ScenarioRegistry::builtin`]).
pub(crate) fn register(r: &mut ScenarioRegistry) -> Result<()> {
    r.register(Scenario::new(
        "autotune_convergence",
        "tuner lands within tolerance of the exhaustive-sweep optimum; autotuned launch stays FNV-identical",
        ParamSchema::new(vec![
            ParamSpec::new("model", "resnet50|resnet101|vgg16", ParamKind::Model, "resnet50"),
            ParamSpec::new("servers", "server count", ParamKind::Int, "8"),
            ParamSpec::new("gpus", "GPUs per server", ParamKind::Int, "8"),
            ParamSpec::new("bandwidth", "provisioned Gbps", ParamKind::PositiveFloat, "10"),
            ParamSpec::new("tolerance", "allowed fraction above the sweep optimum", ParamKind::PositiveFloat, "0.1"),
            ParamSpec::new("noise", "relative measurement noise fed to the tuner", ParamKind::Float, "0.01"),
            ParamSpec::new("knobs", "knob-space overrides (name=v1,v2;... — empty = default grid)", ParamKind::Str, ""),
            ParamSpec::new("max-steps", "tuning step budget", ParamKind::Int, "400"),
            ParamSpec::new("fnv-check", "also run the autotuned-vs-static launch FNV check", ParamKind::Choice(&["on", "off"]), "on"),
            ParamSpec::new("seed", "controller + noise seed", ParamKind::Int, "271828"),
        ]),
        Box::new(ConvergenceRunner),
    ))?;
    r.register(Scenario::from_fn(
        "autotune_vs_static",
        "tuned operating point beats the default-static config at every swept rate",
        ParamSchema::new(vec![
            ParamSpec::new("model", "resnet50|resnet101|vgg16", ParamKind::Model, "resnet50"),
            ParamSpec::new("servers", "server count", ParamKind::Int, "8"),
            ParamSpec::new("gpus", "GPUs per server", ParamKind::Int, "8"),
            ParamSpec::new("bandwidths", "comma list of provisioned Gbps", ParamKind::FloatList, "1,10,25,100"),
            ParamSpec::new("noise", "relative measurement noise fed to the tuner", ParamKind::Float, "0.01"),
            ParamSpec::new("knobs", "knob-space overrides (empty = default grid)", ParamKind::Str, ""),
            ParamSpec::new("max-steps", "tuning step budget per rate", ParamKind::Int, "400"),
            ParamSpec::new("seed", "controller + noise seed", ParamKind::Int, "271828"),
        ]),
        "tune",
        run_vs_static,
    ))?;
    r.register(Scenario::new(
        "autotune_adapt",
        "rate drops mid-run: the tuner re-probes and recovers (model oracle or real launch)",
        ParamSchema::new(vec![
            ParamSpec::new("harness", "model (analytic oracle) or launch (real sockets)", ParamKind::Choice(&["model", "launch"]), "model"),
            ParamSpec::new("model", "resnet50|resnet101|vgg16 (model harness)", ParamKind::Model, "resnet50"),
            ParamSpec::new("servers", "server count (model harness)", ParamKind::Int, "8"),
            ParamSpec::new("gpus", "GPUs per server (model harness)", ParamKind::Int, "8"),
            ParamSpec::new("rate0", "pre-drop Gbps (model harness)", ParamKind::PositiveFloat, "25"),
            ParamSpec::new("rate1", "post-drop Gbps (model harness)", ParamKind::PositiveFloat, "1"),
            ParamSpec::new("tolerance", "allowed fraction above the post-drop optimum", ParamKind::PositiveFloat, "0.15"),
            ParamSpec::new("noise", "relative measurement noise", ParamKind::Float, "0.01"),
            ParamSpec::new("knobs", "knob-space overrides (model harness)", ParamKind::Str, ""),
            ParamSpec::new("max-steps", "tuning step budget per phase", ParamKind::Int, "600"),
            ParamSpec::new("steady-steps", "exploit steps before the drop (model harness)", ParamKind::Int, "6"),
            ParamSpec::new("workers", "worker count (launch harness)", ParamKind::Int, "2"),
            ParamSpec::new("steps", "synchronous steps (launch harness)", ParamKind::Int, "40"),
            ParamSpec::new("elems", "gradient tensor length, f32 (launch harness)", ParamKind::Int, "262144"),
            ParamSpec::new("gate-gbps", "per-stream ceiling Gbps (launch harness)", ParamKind::PositiveFloat, "0.4"),
            ParamSpec::new("drop-at-step", "step at which the gate drops (launch harness)", ParamKind::Int, "18"),
            ParamSpec::new("drop-gbps", "post-drop per-stream Gbps (launch harness)", ParamKind::PositiveFloat, "0.04"),
            ParamSpec::new("chunk-kbs", "tuner chunk axis, KB (launch harness)", ParamKind::Str, "4,32,256"),
            ParamSpec::new("seed", "controller + gradient seed", ParamKind::Int, "271828"),
        ]),
        Box::new(AdaptRunner),
    ))?;
    Ok(())
}

/// Parse the shared oracle-harness parameters.
fn oracle_from(p: &ParamValues) -> Result<(OracleEnv, KnobSpace)> {
    let model = p.get_model("model")?;
    let servers = p.get_usize("servers")?;
    ensure!((2..=1024).contains(&servers), "parameter servers: must be in 2..=1024, got {servers}");
    let gpus = p.get_usize("gpus")?;
    ensure!((1..=64).contains(&gpus), "parameter gpus: must be in 1..=64, got {gpus}");
    let space = KnobSpace::parse_spec(p.get_str("knobs")?)
        .map_err(|e| anyhow::anyhow!("parameter knobs: {e:#}"))?;
    Ok((OracleEnv::new(model, servers, gpus), space))
}

fn noise_from(p: &ParamValues) -> Result<f64> {
    let noise = p.get_f64("noise")?;
    ensure!((0.0..0.5).contains(&noise), "parameter noise: must be in [0, 0.5), got {noise}");
    Ok(noise)
}

/// Stamp the chosen point's coordinates as metrics.
fn knob_metrics(out: &mut Outcome, prefix: &str, k: &KnobPoint) {
    out.metric(format!("{prefix}_bucket_mb"), k.bucket_mb);
    out.metric(format!("{prefix}_stripes"), k.stripes as f64);
    out.metric(format!("{prefix}_chunk_kb"), k.chunk_kb as f64);
    out.metric(format!("{prefix}_compression_ratio"), k.compression.ratio());
}

/// The trajectory as a table (step, knobs, modeled step time).
fn trajectory_table(env: &OracleEnv, bw: f64, tuner: &AutoTuner) -> Table {
    let mut t = Table::new(
        format!("knob trajectory ({} applied points)", tuner.trajectory().len()),
        &["from step", "knobs", "modeled step"],
    );
    for (step, p) in tuner.trajectory() {
        t.row(vec![
            step.to_string(),
            p.spec(),
            crate::util::fmt::secs(env.step_time_s(bw, p)),
        ]);
    }
    t
}

// ---------------------------------------------------------------------------
// autotune_convergence
// ---------------------------------------------------------------------------

struct ConvergenceRunner;

impl super::runner::Runner for ConvergenceRunner {
    fn mode(&self) -> &'static str {
        "tune"
    }

    fn realtime(&self) -> bool {
        // The FNV leg runs real thread-spawned launches.
        true
    }

    fn run(&self, p: &ParamValues) -> Result<Outcome> {
        let (env, space) = oracle_from(p)?;
        let bw = p.get_f64("bandwidth")?;
        let tolerance = p.get_f64("tolerance")?;
        ensure!(tolerance < 1.0, "parameter tolerance: must be < 1, got {tolerance}");
        let noise = noise_from(p)?;
        let max_steps = p.get_usize("max-steps")?;
        ensure!(max_steps >= 10, "parameter max-steps: must be >= 10, got {max_steps}");
        let seed = p.get_usize("seed")? as u64;

        let cfg = TunerConfig { seed, ..TunerConfig::default() };
        let mut tuner = AutoTuner::new(space.clone(), cfg, &KnobPoint::default_static())?;
        let mut rng = Rng::new(seed ^ 0x0c1e);
        let converged = drive_until_exploit(&mut tuner, &env, bw, noise, &mut rng, max_steps);

        let tuned = tuner.chosen();
        let tuned_t = env.step_time_s(bw, &tuned);
        let (best_p, best_t) = env.best(bw, &space);
        let ratio = tuned_t / best_t;
        let static_t = env.step_time_s(bw, &KnobPoint::default_static());

        let mut out = Outcome::new();
        out.metric("tuned_step_s", tuned_t);
        out.metric("sweep_best_step_s", best_t);
        out.metric("ratio_to_optimum", ratio);
        out.metric("static_step_s", static_t);
        out.metric("steps_to_converge", converged.unwrap_or(max_steps) as f64);
        out.metric("knob_changes", tuner.trajectory().len().saturating_sub(1) as f64);
        out.metric("space_points", space.len() as f64);
        out.tuned_knobs = Some(tuned.spec());
        knob_metrics(&mut out, "final", &tuned);
        out.checks.push(Check::assert(
            "tuner reached the exploit phase within the step budget",
            converged.is_some(),
            format!("{} steps of {max_steps}", converged.unwrap_or(max_steps)),
        ));
        out.checks.push(Check::assert(
            "tuner-selected config within tolerance of the exhaustive-sweep optimum",
            ratio <= 1.0 + tolerance,
            format!(
                "tuned {} vs sweep best {} over {} points ({:.1}% above; tolerance {:.0}%; best: {})",
                crate::util::fmt::secs(tuned_t),
                crate::util::fmt::secs(best_t),
                space.len(),
                (ratio - 1.0) * 100.0,
                tolerance * 100.0,
                best_p.spec()
            ),
        ));
        out.tables.push(trajectory_table(&env, bw, &tuner));

        let mut fig = Figure::new(
            "autotune_convergence",
            format!("Tuner trajectory at {bw} Gbps ({})", env.model),
            "step",
            "modeled step seconds",
        );
        let mut s = Series::new("applied operating point");
        for (step, point) in tuner.trajectory() {
            s.push(*step as f64, env.step_time_s(bw, point));
        }
        fig.series.push(s);
        let mut bound = Series::new("exhaustive-sweep optimum");
        bound.push(0.0, best_t);
        bound.push(tuner.steps_seen() as f64, best_t);
        fig.series.push(bound);
        out.figures.push(fig);

        if p.get_str("fnv-check")? == "on" {
            run_fnv_leg(&mut out, seed)?;
        }
        Ok(out)
    }
}

/// The e2e safety leg: an autotuned thread-spawn launch must end
/// bit-identical to the static run with the same seeds.
fn run_fnv_leg(out: &mut Outcome, seed: u64) -> Result<()> {
    let params = WorkerParams {
        world: 2,
        steps: 8,
        elems: 65_536,
        transport: TransportKind::Striped { streams: 2 },
        collective: CollectiveKind::Ring,
        overlap: OverlapMode::Off,
        bucket_mb: 0.0,
        layers: 1,
        compute_us: 0,
        autotune: false,
        chunk_kbs: Vec::new(),
        gate_gbps: 0.0,
        drop_at_step: 0,
        drop_gbps: 0.0,
        seed,
        obs: false,
        trace_out: None,
    };
    let static_run = launch(&LaunchConfig {
        params: params.clone(),
        spawn: SpawnMode::Thread,
        feedback_out: None,
        rendezvous_timeout: std::time::Duration::from_secs(60),
        bind: "127.0.0.1:0".parse().unwrap(),
    })?;
    let tuned_run = launch(&LaunchConfig {
        params: WorkerParams {
            autotune: true,
            chunk_kbs: vec![4, 16, 64],
            ..params
        },
        spawn: SpawnMode::Thread,
        feedback_out: None,
        rendezvous_timeout: std::time::Duration::from_secs(60),
        bind: "127.0.0.1:0".parse().unwrap(),
    })?;
    out.metric("fnv_knob_changes", tuned_run.knob_trajectory.len().saturating_sub(1) as f64);
    out.checks.push(Check::assert(
        "autotuned launch FNV-bit-identical to the static-config run",
        static_run.identical
            && tuned_run.identical
            && static_run.checksums == tuned_run.checksums,
        format!("static {:x?} vs tuned {:x?}", static_run.checksums, tuned_run.checksums),
    ));
    out.checks.push(Check::assert(
        "the autotuned launch actually retuned (knob broadcasts happened)",
        tuned_run.knob_trajectory.len() >= 2,
        format!("trajectory {:?}", tuned_run.knob_trajectory),
    ));
    Ok(())
}

// ---------------------------------------------------------------------------
// autotune_vs_static
// ---------------------------------------------------------------------------

fn run_vs_static(p: &ParamValues) -> Result<Outcome> {
    let (env, space) = oracle_from(p)?;
    let noise = noise_from(p)?;
    let max_steps = p.get_usize("max-steps")?;
    ensure!(max_steps >= 10, "parameter max-steps: must be >= 10, got {max_steps}");
    let seed = p.get_usize("seed")? as u64;
    let mut bws = p.get_f64_list("bandwidths")?;
    ensure!(!bws.is_empty(), "parameter bandwidths: list is empty");
    bws.sort_by(f64::total_cmp);

    let static_point = KnobPoint::default_static();
    let mut fig = Figure::new(
        "autotune_vs_static",
        format!("Tuned vs default-static step time ({})", env.model),
        "Gbps",
        "step seconds",
    );
    let mut s_tuned = Series::new("autotuned");
    let mut s_static = Series::new("default static");
    let mut t = Table::new(
        format!("autotune vs static: {}", env.model),
        &["Gbps", "static step", "tuned step", "speedup", "tuned knobs"],
    );
    let mut all_beat = true;
    let mut all_converged = true;
    let mut min_speedup = f64::INFINITY;
    let mut out = Outcome::new();
    for (i, &bw) in bws.iter().enumerate() {
        let cfg = TunerConfig { seed: seed ^ (i as u64) << 8, ..TunerConfig::default() };
        let mut tuner = AutoTuner::new(space.clone(), cfg, &static_point)?;
        let mut rng = Rng::new(seed ^ 0x57a7 ^ (i as u64));
        let converged =
            drive_until_exploit(&mut tuner, &env, bw, noise, &mut rng, max_steps).is_some();
        all_converged &= converged;
        let tuned = tuner.chosen();
        let tuned_t = env.step_time_s(bw, &tuned);
        let static_t = env.step_time_s(bw, &static_point);
        let speedup = static_t / tuned_t;
        all_beat &= tuned_t < static_t;
        min_speedup = min_speedup.min(speedup);
        s_tuned.push(bw, tuned_t);
        s_static.push(bw, static_t);
        t.row(vec![
            format!("{bw}"),
            crate::util::fmt::secs(static_t),
            crate::util::fmt::secs(tuned_t),
            format!("{speedup:.2}x"),
            tuned.spec(),
        ]);
        out.metric(format!("tuned_step_s@{bw}g"), tuned_t);
        out.metric(format!("static_step_s@{bw}g"), static_t);
        out.metric(format!("speedup@{bw}g"), speedup);
    }
    fig.series.push(s_static);
    fig.series.push(s_tuned);
    out.metric("min_speedup", min_speedup);
    out.checks.push(Check::assert(
        "tuner reached exploit at every swept rate",
        all_converged,
        format!("{} rates", bws.len()),
    ));
    out.checks.push(Check::assert(
        "tuned operating point beats default-static at every swept rate",
        all_beat,
        format!("min speedup {min_speedup:.2}x across {} rates", bws.len()),
    ));
    out.figures.push(fig);
    out.tables.push(t);
    Ok(out)
}

// ---------------------------------------------------------------------------
// autotune_adapt
// ---------------------------------------------------------------------------

struct AdaptRunner;

impl super::runner::Runner for AdaptRunner {
    fn mode(&self) -> &'static str {
        "tune"
    }

    fn realtime(&self) -> bool {
        true
    }

    fn run(&self, p: &ParamValues) -> Result<Outcome> {
        match p.get_str("harness")? {
            "launch" => run_adapt_launch(p),
            _ => run_adapt_model(p),
        }
    }
}

fn run_adapt_model(p: &ParamValues) -> Result<Outcome> {
    let (env, space) = oracle_from(p)?;
    let rate0 = p.get_f64("rate0")?;
    let rate1 = p.get_f64("rate1")?;
    let tolerance = p.get_f64("tolerance")?;
    ensure!(tolerance < 1.0, "parameter tolerance: must be < 1, got {tolerance}");
    let noise = noise_from(p)?;
    let max_steps = p.get_usize("max-steps")?;
    ensure!(max_steps >= 20, "parameter max-steps: must be >= 20, got {max_steps}");
    let steady = p.get_usize("steady-steps")?;
    let seed = p.get_usize("seed")? as u64;

    let cfg = TunerConfig { seed, ..TunerConfig::default() };
    let mut tuner = AutoTuner::new(space.clone(), cfg, &KnobPoint::default_static())?;
    let mut rng = Rng::new(seed ^ 0xada7);

    // Phase 1: converge at rate0, then exploit for a steady window.
    let converged0 =
        drive_until_exploit(&mut tuner, &env, rate0, noise, &mut rng, max_steps).is_some();
    for _ in 0..steady {
        noisy_oracle_step(&mut tuner, &env, rate0, noise, &mut rng);
    }
    let pre_chosen = tuner.chosen();
    let pre_t = env.step_time_s(rate0, &pre_chosen);
    let drop_step = tuner.steps_seen();

    // Phase 2: the rate drops. The tuner must notice and re-probe.
    let mut reprobe_used = 0usize;
    while tuner.state() != TunerState::Probe && reprobe_used < max_steps {
        noisy_oracle_step(&mut tuner, &env, rate1, noise, &mut rng);
        reprobe_used += 1;
    }
    let reprobed = tuner.state() == TunerState::Probe;

    // Phase 3: recover at the new rate.
    let recovered =
        drive_until_exploit(&mut tuner, &env, rate1, noise, &mut rng, max_steps).is_some();
    let final_chosen = tuner.chosen();
    let final_t = env.step_time_s(rate1, &final_chosen);
    let (best1, best1_t) = env.best(rate1, &space);
    let ratio = final_t / best1_t;
    let pre_at_rate1 = env.step_time_s(rate1, &pre_chosen);

    let mut out = Outcome::new();
    out.metric("pre_drop_step_s", pre_t);
    out.metric("pre_config_at_new_rate_s", pre_at_rate1);
    out.metric("recovered_step_s", final_t);
    out.metric("post_drop_best_s", best1_t);
    out.metric("recovery_ratio", ratio);
    out.metric("reprobe_detect_steps", reprobe_used as f64);
    out.metric("probe_phases", tuner.probe_phases() as f64);
    out.metric("drop_at_step", drop_step as f64);
    out.tuned_knobs = Some(final_chosen.spec());
    knob_metrics(&mut out, "final", &final_chosen);
    out.checks.push(Check::assert(
        "tuner converged before the drop",
        converged0,
        format!("rate0 {rate0} Gbps"),
    ));
    out.checks.push(Check::assert(
        "sustained regression triggered a re-probe",
        reprobed && tuner.probe_phases() >= 2,
        format!(
            "detected in {reprobe_used} steps after the {rate0}→{rate1} Gbps drop; \
             {} probe phases",
            tuner.probe_phases()
        ),
    ));
    out.checks.push(Check::assert(
        "recovered within tolerance of the post-drop optimum",
        recovered && ratio <= 1.0 + tolerance,
        format!(
            "recovered {} vs post-drop best {} ({:.1}% above; tolerance {:.0}%; best: {})",
            crate::util::fmt::secs(final_t),
            crate::util::fmt::secs(best1_t),
            (ratio - 1.0) * 100.0,
            tolerance * 100.0,
            best1.spec()
        ),
    ));
    // Price each applied point at the rate it actually ran under —
    // pre-drop entries at rate0, post-drop at rate1.
    let mut tt = Table::new(
        format!(
            "knob trajectory ({} applied points; rate drops {rate0} -> {rate1} Gbps at step {drop_step})",
            tuner.trajectory().len()
        ),
        &["from step", "knobs", "Gbps", "modeled step"],
    );
    for (step, point) in tuner.trajectory() {
        let rate = if *step < drop_step { rate0 } else { rate1 };
        tt.row(vec![
            step.to_string(),
            point.spec(),
            format!("{rate}"),
            crate::util::fmt::secs(env.step_time_s(rate, point)),
        ]);
    }
    out.tables.push(tt);
    Ok(out)
}

fn run_adapt_launch(p: &ParamValues) -> Result<Outcome> {
    let workers = p.get_usize("workers")?;
    ensure!((2..=16).contains(&workers), "parameter workers: must be in 2..=16, got {workers}");
    let steps = p.get_usize("steps")?;
    let elems = p.get_usize("elems")?;
    ensure!(elems >= 1024, "parameter elems: must be >= 1024, got {elems}");
    let drop_at = p.get_usize("drop-at-step")?;
    ensure!(
        (6..steps.saturating_sub(6)).contains(&drop_at),
        "parameter drop-at-step: must leave >= 6 steps on each side of the drop, got {drop_at} of {steps}"
    );
    let chunk_kbs: Vec<usize> = p
        .get_str("chunk-kbs")?
        .split(',')
        .map(|s| {
            s.trim()
                .parse::<usize>()
                .map_err(|_| anyhow::anyhow!("parameter chunk-kbs: bad value {s:?}"))
        })
        .collect::<Result<_>>()?;
    let seed = p.get_usize("seed")? as u64;

    let params = WorkerParams {
        world: workers,
        steps,
        elems,
        transport: TransportKind::Striped { streams: 2 },
        collective: CollectiveKind::Ring,
        overlap: OverlapMode::Off,
        bucket_mb: 0.0,
        layers: 1,
        compute_us: 0,
        autotune: true,
        chunk_kbs,
        gate_gbps: p.get_f64("gate-gbps")?,
        drop_at_step: drop_at,
        drop_gbps: p.get_f64("drop-gbps")?,
        seed,
        obs: false,
        trace_out: None,
    };
    let tuned = launch(&LaunchConfig {
        params: params.clone(),
        spawn: SpawnMode::Thread,
        feedback_out: None,
        rendezvous_timeout: std::time::Duration::from_secs(60),
        bind: "127.0.0.1:0".parse().unwrap(),
    })?;
    let static_run = launch(&LaunchConfig {
        params: WorkerParams { autotune: false, chunk_kbs: Vec::new(), ..params },
        spawn: SpawnMode::Thread,
        feedback_out: None,
        rendezvous_timeout: std::time::Duration::from_secs(60),
        bind: "127.0.0.1:0".parse().unwrap(),
    })?;

    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len().max(1) as f64;
    let pre = mean(&tuned.step_wall_s[drop_at - 3..drop_at]);
    let post = mean(&tuned.step_wall_s[drop_at + 1..drop_at + 4]);
    let reprobed_after_drop =
        tuned.knob_trajectory.iter().any(|(step, _)| *step > drop_at as u64);

    let mut out = Outcome::new();
    out.metric("pre_drop_mean_wall_s", pre);
    out.metric("post_drop_mean_wall_s", post);
    out.metric("effective_bus_gbps", tuned.effective_bus_gbps);
    out.metric("knob_changes", tuned.knob_trajectory.len().saturating_sub(1) as f64);
    out.checks.push(Check::assert(
        "the gate drop is visible in step walls",
        post > pre * 2.0,
        format!("pre {} vs post {}", crate::util::fmt::secs(pre), crate::util::fmt::secs(post)),
    ));
    out.checks.push(Check::assert(
        "rank 0 re-probed after the drop (knob broadcasts past the drop step)",
        reprobed_after_drop,
        format!("trajectory {:?} (drop at {drop_at})", tuned.knob_trajectory),
    ));
    out.checks.push(Check::assert(
        "autotuned launch FNV-bit-identical to the static run under the same drop",
        tuned.identical && static_run.identical && tuned.checksums == static_run.checksums,
        format!("tuned {:x?} vs static {:x?}", tuned.checksums, static_run.checksums),
    ));
    let mut t = tuned.step_table();
    t.row(vec!["(gate drop)".into(), format!("step {drop_at}"), "-".into()]);
    out.tables.push(t);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> ScenarioRegistry {
        ScenarioRegistry::builtin()
    }

    fn kv(pairs: &[(&str, &str)]) -> Vec<(String, String)> {
        pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
    }

    #[test]
    fn convergence_meets_acceptance_at_10g() {
        // The ISSUE's criterion verbatim: within 10% of the exhaustive
        // sweep at 10 Gbps, FNV leg included.
        let out = registry().get("autotune_convergence").unwrap().run(&[]).unwrap();
        assert!(out.passed(), "checks failed: {:?}", out.checks);
        assert!(out.metric_value("ratio_to_optimum").unwrap() <= 1.1);
        assert!(out.metric_value("knob_changes").unwrap() >= 1.0);
        assert!(out.metric_value("fnv_knob_changes").unwrap() >= 1.0);
    }

    #[test]
    fn convergence_without_fnv_leg_is_pure_analytic() {
        let out = registry()
            .get("autotune_convergence")
            .unwrap()
            .run(&kv(&[("fnv-check", "off"), ("bandwidth", "25")]))
            .unwrap();
        assert!(out.passed(), "checks failed: {:?}", out.checks);
        assert!(out.metric_value("fnv_knob_changes").is_none());
    }

    #[test]
    fn vs_static_dominates_across_rates() {
        let out = registry().get("autotune_vs_static").unwrap().run(&[]).unwrap();
        assert!(out.passed(), "checks failed: {:?}", out.checks);
        assert!(out.metric_value("min_speedup").unwrap() > 1.0);
    }

    #[test]
    fn adapt_model_recovers_after_the_drop() {
        let out = registry().get("autotune_adapt").unwrap().run(&[]).unwrap();
        assert!(out.passed(), "checks failed: {:?}", out.checks);
        assert!(out.metric_value("probe_phases").unwrap() >= 2.0);
        assert!(
            out.metric_value("recovery_ratio").unwrap() <= 1.15,
            "{:?}",
            out.metric_value("recovery_ratio")
        );
    }

    #[test]
    fn adapt_launch_reprobes_and_stays_bit_identical() {
        // Shrunk launch variant: short gate windows keep it in test time.
        let out = registry()
            .get("autotune_adapt")
            .unwrap()
            .run(&kv(&[
                ("harness", "launch"),
                ("steps", "30"),
                ("elems", "131072"),
                ("drop-at-step", "16"),
                ("gate-gbps", "0.8"),
                ("drop-gbps", "0.08"),
                ("chunk-kbs", "8,64"),
            ]))
            .unwrap();
        assert!(out.passed(), "checks failed: {:?}", out.checks);
    }

    #[test]
    fn unknown_knob_override_is_actionable() {
        let err = registry()
            .get("autotune_convergence")
            .unwrap()
            .run(&kv(&[("knobs", "chunk_bytes=1,2"), ("fnv-check", "off")]))
            .unwrap_err()
            .to_string();
        assert!(err.contains("chunk_bytes"), "{err}");
        assert!(err.contains("chunk_kb"), "{err}");
        assert!(err.contains("bucket_mb"), "{err}");
    }

    #[test]
    fn tune_scenarios_are_sweepable_with_injected_seeds() {
        // The determinism satellite's engine face: the scenarios declare
        // `seed`, so sweeps inject per-point seeds and serial == parallel.
        let reg = registry();
        let scenario = reg.get("autotune_vs_static").unwrap();
        let build = || {
            crate::engine::SweepBuilder::new(scenario)
                .fix("bandwidths", "5,50")
                .fix("max-steps", "200")
                .axis_csv("model", "resnet50,vgg16")
        };
        let serial = build().run(1);
        let parallel = build().run(2);
        assert_eq!(serial.len(), 2);
        for (s, q) in serial.iter().zip(&parallel) {
            assert_eq!(s.params, q.params);
            let (so, qo) = (s.outcome.as_ref().unwrap(), q.outcome.as_ref().unwrap());
            assert_eq!(so.metric_value("min_speedup"), qo.metric_value("min_speedup"));
            assert_eq!(
                so.metric_value("tuned_step_s@5g"),
                qo.metric_value("tuned_step_s@5g")
            );
        }
    }
}
