//! Cartesian parameter sweeps over a scenario, with optional thread-pool
//! execution.
//!
//! A [`SweepBuilder`] takes a base parameter set ([`SweepBuilder::fix`])
//! plus any number of axes ([`SweepBuilder::axis`]); [`SweepBuilder::points`]
//! expands the cartesian grid in a deterministic order (later axes vary
//! fastest, like an odometer), and [`SweepBuilder::run`] executes every
//! point — independently, so `parallel > 1` fans points out across worker
//! threads. Each point yields its own [`Outcome`] (or error); one failing
//! point never aborts the sweep.

use super::outcome::Outcome;
use super::registry::Scenario;
use crate::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// One executed grid point.
pub struct SweepPoint {
    /// Position in [`SweepBuilder::points`] order.
    pub index: usize,
    /// The overrides this point ran with (base + axis values).
    pub params: Vec<(String, String)>,
    /// The point's result; errors are contained per-point.
    pub outcome: Result<Outcome>,
}

/// Builder for a cartesian sweep over one scenario.
pub struct SweepBuilder<'a> {
    scenario: &'a Scenario,
    base: Vec<(String, String)>,
    axes: Vec<(String, Vec<String>)>,
}

impl<'a> SweepBuilder<'a> {
    pub fn new(scenario: &'a Scenario) -> SweepBuilder<'a> {
        SweepBuilder { scenario, base: Vec::new(), axes: Vec::new() }
    }

    /// Fix one parameter for every point.
    pub fn fix(mut self, key: impl Into<String>, value: impl Into<String>) -> Self {
        self.base.push((key.into(), value.into()));
        self
    }

    /// Add a swept axis with explicit values.
    ///
    /// Panics on a duplicate axis key: the later axis would silently win
    /// every point (parameter resolution is last-write-wins) while the
    /// point labels claimed both values. CLI callers pre-validate and
    /// report this as a clean error instead.
    pub fn axis(mut self, key: impl Into<String>, values: Vec<String>) -> Self {
        let key = key.into();
        assert!(
            self.axes.iter().all(|(k, _)| *k != key),
            "duplicate sweep axis {key:?}"
        );
        self.axes.push((key, values));
        self
    }

    /// Add a swept axis from a comma-separated value list (the CLI's
    /// `--grid key=v1,v2,...` form).
    pub fn axis_csv(self, key: impl Into<String>, csv: &str) -> Self {
        self.axis(key, csv.split(',').map(|v| v.trim().to_string()).collect())
    }

    /// Number of grid points (product of axis lengths; 1 with no axes).
    pub fn len(&self) -> usize {
        self.axes.iter().map(|(_, vs)| vs.len()).product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand the cartesian grid. Deterministic: the first axis varies
    /// slowest, the last fastest.
    pub fn points(&self) -> Vec<Vec<(String, String)>> {
        let mut pts = vec![self.base.clone()];
        for (key, values) in &self.axes {
            let mut next = Vec::with_capacity(pts.len() * values.len());
            for p in &pts {
                for v in values {
                    let mut q = p.clone();
                    q.push((key.clone(), v.clone()));
                    next.push(q);
                }
            }
            pts = next;
        }
        pts
    }

    /// Execute every point on up to `parallel` worker threads (clamped to
    /// the point count; `0` behaves as `1`). Results come back in
    /// [`SweepBuilder::points`] order regardless of completion order.
    pub fn run(&self, parallel: usize) -> Vec<SweepPoint> {
        let pts = self.points();
        if pts.is_empty() {
            return Vec::new();
        }
        let workers = parallel.max(1).min(pts.len());
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<SweepPoint>>> = pts.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= pts.len() {
                        break;
                    }
                    let outcome = self.scenario.run(&pts[i]);
                    *slots[i].lock().unwrap() =
                        Some(SweepPoint { index: i, params: pts[i].clone(), outcome });
                });
            }
        });
        slots
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("every sweep point was executed"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::params::{ParamKind, ParamSchema, ParamSpec};
    use crate::engine::registry::Scenario;

    fn echo_scenario() -> Scenario {
        Scenario::from_fn(
            "echo",
            "returns its parameters as metrics",
            ParamSchema::new(vec![
                ParamSpec::new("a", "", ParamKind::Float, "0"),
                ParamSpec::new("b", "", ParamKind::Float, "0"),
                ParamSpec::new("c", "", ParamKind::Float, "0"),
            ]),
            "test",
            |p| {
                let mut out = Outcome::new();
                out.metric("a", p.get_f64("a")?);
                out.metric("b", p.get_f64("b")?);
                out.metric("c", p.get_f64("c")?);
                Ok(out)
            },
        )
    }

    fn vals(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn cartesian_counts_multiply() {
        let sc = echo_scenario();
        let sweep = SweepBuilder::new(&sc)
            .axis("a", vals(&["1", "2", "3"]))
            .axis("b", vals(&["10", "20"]));
        assert_eq!(sweep.len(), 6);
        assert_eq!(sweep.points().len(), 6);
        let one_axis = SweepBuilder::new(&sc).axis("a", vals(&["1", "2"]));
        assert_eq!(one_axis.len(), 2);
        let no_axis = SweepBuilder::new(&sc);
        assert_eq!(no_axis.points().len(), 1);
    }

    #[test]
    fn expansion_order_is_odometer() {
        let sc = echo_scenario();
        let pts = SweepBuilder::new(&sc)
            .axis("a", vals(&["1", "2"]))
            .axis("b", vals(&["10", "20"]))
            .points();
        let flat: Vec<(f64, f64)> = pts
            .iter()
            .map(|p| {
                let get = |k: &str| {
                    p.iter().find(|(n, _)| n == k).unwrap().1.parse::<f64>().unwrap()
                };
                (get("a"), get("b"))
            })
            .collect();
        assert_eq!(flat, vec![(1.0, 10.0), (1.0, 20.0), (2.0, 10.0), (2.0, 20.0)]);
    }

    #[test]
    fn fixed_params_reach_every_point() {
        let sc = echo_scenario();
        let results =
            SweepBuilder::new(&sc).fix("c", "7").axis("a", vals(&["1", "2"])).run(1);
        assert_eq!(results.len(), 2);
        for r in &results {
            let out = r.outcome.as_ref().unwrap();
            assert_eq!(out.metric_value("c"), Some(7.0));
        }
    }

    #[test]
    fn parallel_results_keep_point_order() {
        let sc = echo_scenario();
        let results = SweepBuilder::new(&sc)
            .axis("a", vals(&["1", "2", "3", "4", "5", "6", "7", "8"]))
            .run(4);
        assert_eq!(results.len(), 8);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.index, i);
            let out = r.outcome.as_ref().unwrap();
            assert_eq!(out.metric_value("a"), Some((i + 1) as f64));
        }
    }

    #[test]
    #[should_panic(expected = "duplicate sweep axis")]
    fn duplicate_axis_key_rejected() {
        let sc = echo_scenario();
        let _ = SweepBuilder::new(&sc)
            .axis("a", vals(&["1", "2"]))
            .axis("a", vals(&["3", "4"]));
    }

    #[test]
    fn point_errors_are_contained() {
        let sc = echo_scenario();
        // "x" fails Float validation at resolve time: the point errors,
        // the sweep completes.
        let results = SweepBuilder::new(&sc).axis("a", vals(&["1", "x", "3"])).run(2);
        assert_eq!(results.len(), 3);
        assert!(results[0].outcome.is_ok());
        assert!(results[1].outcome.is_err());
        assert!(results[2].outcome.is_ok());
    }
}
