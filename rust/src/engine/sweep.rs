//! Cartesian parameter sweeps over a scenario, with optional thread-pool
//! execution.
//!
//! A [`SweepBuilder`] takes a base parameter set ([`SweepBuilder::fix`])
//! plus any number of axes ([`SweepBuilder::axis`]); [`SweepBuilder::points`]
//! expands the cartesian grid in a deterministic order (later axes vary
//! fastest, like an odometer), and [`SweepBuilder::run`] executes every
//! point — independently, so `parallel > 1` fans points out across worker
//! threads. Each point yields its own [`Outcome`] (or error); one failing
//! point never aborts the sweep.
//!
//! **Per-point seeds.** A scenario that declares a `seed` parameter is
//! stochastic; if every grid point ran its schema default, each point
//! would reuse one process-global seed path while its labels claimed an
//! independent run. [`SweepBuilder::points`] therefore derives a
//! deterministic per-point seed — a pure function of the scenario name
//! and the point's grid index, never of thread scheduling — so `--parallel
//! N` and serial sweeps emit identical CSVs/JSON, point for point, while
//! distinct points get independent streams. Fixing or sweeping `seed`
//! explicitly disables the injection.

use super::outcome::Outcome;
use super::registry::Scenario;
use crate::Result;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// One executed grid point.
pub struct SweepPoint {
    /// Position in [`SweepBuilder::points`] order.
    pub index: usize,
    /// The overrides this point ran with (base + axis values).
    pub params: Vec<(String, String)>,
    /// The point's result; errors are contained per-point.
    pub outcome: Result<Outcome>,
}

/// Builder for a cartesian sweep over one scenario.
pub struct SweepBuilder<'a> {
    scenario: &'a Scenario,
    base: Vec<(String, String)>,
    axes: Vec<(String, Vec<String>)>,
}

impl<'a> SweepBuilder<'a> {
    pub fn new(scenario: &'a Scenario) -> SweepBuilder<'a> {
        SweepBuilder { scenario, base: Vec::new(), axes: Vec::new() }
    }

    /// Fix one parameter for every point.
    pub fn fix(mut self, key: impl Into<String>, value: impl Into<String>) -> Self {
        self.base.push((key.into(), value.into()));
        self
    }

    /// Add a swept axis with explicit values.
    ///
    /// Panics on a duplicate axis key: the later axis would silently win
    /// every point (parameter resolution is last-write-wins) while the
    /// point labels claimed both values. CLI callers pre-validate and
    /// report this as a clean error instead.
    pub fn axis(mut self, key: impl Into<String>, values: Vec<String>) -> Self {
        let key = key.into();
        assert!(
            self.axes.iter().all(|(k, _)| *k != key),
            "duplicate sweep axis {key:?}"
        );
        self.axes.push((key, values));
        self
    }

    /// Add a swept axis from a comma-separated value list (the CLI's
    /// `--grid key=v1,v2,...` form).
    pub fn axis_csv(self, key: impl Into<String>, csv: &str) -> Self {
        self.axis(key, csv.split(',').map(|v| v.trim().to_string()).collect())
    }

    /// Number of grid points (product of axis lengths; 1 with no axes).
    pub fn len(&self) -> usize {
        self.axes.iter().map(|(_, vs)| vs.len()).product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Expand the cartesian grid. Deterministic: the first axis varies
    /// slowest, the last fastest. Scenarios declaring a `seed` parameter
    /// get a derived per-point seed appended (see the module docs) unless
    /// the caller fixed or swept `seed` themselves.
    pub fn points(&self) -> Vec<Vec<(String, String)>> {
        let mut pts = vec![self.base.clone()];
        for (key, values) in &self.axes {
            let mut next = Vec::with_capacity(pts.len() * values.len());
            for p in &pts {
                for v in values {
                    let mut q = p.clone();
                    q.push((key.clone(), v.clone()));
                    next.push(q);
                }
            }
            pts = next;
        }
        let seed_declared =
            self.scenario.schema().specs().iter().any(|s| s.name == "seed");
        let seed_pinned = self.base.iter().any(|(k, _)| k == "seed")
            || self.axes.iter().any(|(k, _)| k == "seed");
        if seed_declared && !seed_pinned {
            // Index-derived, not execution-order-derived: point i gets the
            // same seed whether the sweep runs on 1 thread or N.
            let name_seed = crate::util::prop::fnv1a(self.scenario.name().as_bytes());
            for (i, p) in pts.iter_mut().enumerate() {
                let mut rng = crate::util::Rng::new(
                    name_seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                );
                // Masked to 32 bits: `ParamKind::Int` parses `usize`, and
                // u32 fits usize on every target.
                p.push(("seed".to_string(), (rng.next_u64() & 0xFFFF_FFFF).to_string()));
            }
        }
        pts
    }

    /// Execute every point on up to `parallel` worker threads (clamped to
    /// the point count; `0` behaves as `1`). Results come back in
    /// [`SweepBuilder::points`] order regardless of completion order.
    pub fn run(&self, parallel: usize) -> Vec<SweepPoint> {
        let pts = self.points();
        if pts.is_empty() {
            return Vec::new();
        }
        let workers = parallel.max(1).min(pts.len());
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<SweepPoint>>> = pts.iter().map(|_| Mutex::new(None)).collect();
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= pts.len() {
                        break;
                    }
                    let outcome = self.scenario.run(&pts[i]);
                    *slots[i].lock().unwrap() =
                        Some(SweepPoint { index: i, params: pts[i].clone(), outcome });
                });
            }
        });
        slots
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("every sweep point was executed"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::params::{ParamKind, ParamSchema, ParamSpec};
    use crate::engine::registry::Scenario;

    fn echo_scenario() -> Scenario {
        Scenario::from_fn(
            "echo",
            "returns its parameters as metrics",
            ParamSchema::new(vec![
                ParamSpec::new("a", "", ParamKind::Float, "0"),
                ParamSpec::new("b", "", ParamKind::Float, "0"),
                ParamSpec::new("c", "", ParamKind::Float, "0"),
            ]),
            "test",
            |p| {
                let mut out = Outcome::new();
                out.metric("a", p.get_f64("a")?);
                out.metric("b", p.get_f64("b")?);
                out.metric("c", p.get_f64("c")?);
                Ok(out)
            },
        )
    }

    fn vals(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn cartesian_counts_multiply() {
        let sc = echo_scenario();
        let sweep = SweepBuilder::new(&sc)
            .axis("a", vals(&["1", "2", "3"]))
            .axis("b", vals(&["10", "20"]));
        assert_eq!(sweep.len(), 6);
        assert_eq!(sweep.points().len(), 6);
        let one_axis = SweepBuilder::new(&sc).axis("a", vals(&["1", "2"]));
        assert_eq!(one_axis.len(), 2);
        let no_axis = SweepBuilder::new(&sc);
        assert_eq!(no_axis.points().len(), 1);
    }

    #[test]
    fn expansion_order_is_odometer() {
        let sc = echo_scenario();
        let pts = SweepBuilder::new(&sc)
            .axis("a", vals(&["1", "2"]))
            .axis("b", vals(&["10", "20"]))
            .points();
        let flat: Vec<(f64, f64)> = pts
            .iter()
            .map(|p| {
                let get = |k: &str| {
                    p.iter().find(|(n, _)| n == k).unwrap().1.parse::<f64>().unwrap()
                };
                (get("a"), get("b"))
            })
            .collect();
        assert_eq!(flat, vec![(1.0, 10.0), (1.0, 20.0), (2.0, 10.0), (2.0, 20.0)]);
    }

    #[test]
    fn fixed_params_reach_every_point() {
        let sc = echo_scenario();
        let results =
            SweepBuilder::new(&sc).fix("c", "7").axis("a", vals(&["1", "2"])).run(1);
        assert_eq!(results.len(), 2);
        for r in &results {
            let out = r.outcome.as_ref().unwrap();
            assert_eq!(out.metric_value("c"), Some(7.0));
        }
    }

    #[test]
    fn parallel_results_keep_point_order() {
        let sc = echo_scenario();
        let results = SweepBuilder::new(&sc)
            .axis("a", vals(&["1", "2", "3", "4", "5", "6", "7", "8"]))
            .run(4);
        assert_eq!(results.len(), 8);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.index, i);
            let out = r.outcome.as_ref().unwrap();
            assert_eq!(out.metric_value("a"), Some((i + 1) as f64));
        }
    }

    fn seeded_scenario() -> Scenario {
        Scenario::from_fn(
            "seeded-echo",
            "stochastic scenario: echoes its seed",
            ParamSchema::new(vec![
                ParamSpec::new("a", "", ParamKind::Float, "0"),
                ParamSpec::new("seed", "RNG seed", ParamKind::Int, "1234"),
            ]),
            "test",
            |p| {
                let mut out = Outcome::new();
                out.metric("a", p.get_f64("a")?);
                out.metric("seed", p.get_usize("seed")? as f64);
                Ok(out)
            },
        )
    }

    #[test]
    fn per_point_seeds_are_derived_and_distinct() {
        let sc = seeded_scenario();
        let pts = SweepBuilder::new(&sc).axis("a", vals(&["1", "2", "3"])).points();
        let seeds: Vec<&String> = pts
            .iter()
            .map(|p| &p.iter().find(|(k, _)| k == "seed").expect("seed injected").1)
            .collect();
        assert_eq!(seeds.len(), 3);
        assert!(seeds[0] != seeds[1] && seeds[1] != seeds[2], "{seeds:?}");
        // And never the schema default: every point is an independent run.
        assert!(seeds.iter().all(|s| *s != "1234"), "{seeds:?}");
    }

    #[test]
    fn parallel_and_serial_sweeps_emit_identical_results() {
        // The satellite's contract: same points, same seeds, same
        // outcomes regardless of --parallel (seeds derive from the point
        // index, not from thread scheduling).
        let sc = seeded_scenario();
        let build = || SweepBuilder::new(&sc).axis("a", vals(&["1", "2", "3", "4"]));
        let serial = build().run(1);
        let parallel = build().run(4);
        assert_eq!(serial.len(), parallel.len());
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.params, p.params);
            let (so, po) = (s.outcome.as_ref().unwrap(), p.outcome.as_ref().unwrap());
            assert_eq!(so.metric_value("seed"), po.metric_value("seed"));
            assert_eq!(so.metric_value("a"), po.metric_value("a"));
        }
    }

    #[test]
    fn explicit_seed_suppresses_injection() {
        let sc = seeded_scenario();
        let fixed = SweepBuilder::new(&sc).fix("seed", "42").axis("a", vals(&["1", "2"]));
        for p in fixed.points() {
            let seeds: Vec<&str> =
                p.iter().filter(|(k, _)| k == "seed").map(|(_, v)| v.as_str()).collect();
            assert_eq!(seeds, vec!["42"]);
        }
        let swept = SweepBuilder::new(&sc).axis("seed", vals(&["7", "8"]));
        let seeds: Vec<String> = swept
            .points()
            .iter()
            .map(|p| p.iter().find(|(k, _)| k == "seed").unwrap().1.clone())
            .collect();
        assert_eq!(seeds, vec!["7".to_string(), "8".to_string()]);
    }

    #[test]
    fn unseeded_scenarios_get_no_injection() {
        let sc = echo_scenario();
        for p in SweepBuilder::new(&sc).axis("a", vals(&["1", "2"])).points() {
            assert!(p.iter().all(|(k, _)| k != "seed"));
        }
    }

    #[test]
    #[should_panic(expected = "duplicate sweep axis")]
    fn duplicate_axis_key_rejected() {
        let sc = echo_scenario();
        let _ = SweepBuilder::new(&sc)
            .axis("a", vals(&["1", "2"]))
            .axis("a", vals(&["3", "4"]));
    }

    #[test]
    fn point_errors_are_contained() {
        let sc = echo_scenario();
        // "x" fails Float validation at resolve time: the point errors,
        // the sweep completes.
        let results = SweepBuilder::new(&sc).axis("a", vals(&["1", "x", "3"])).run(2);
        assert_eq!(results.len(), 3);
        assert!(results[0].outcome.is_ok());
        assert!(results[1].outcome.is_err());
        assert!(results[2].outcome.is_ok());
    }
}
