//! Per-figure experiment drivers: each paper figure gets a runner that
//! regenerates its data (via the what-if simulator and/or the emulator),
//! renders it, writes CSV, and evaluates the paper-shape checks (who
//! wins, by what factor, where the knees fall).

use crate::models::ModelId;
use crate::report::{Check, Figure};
use crate::sim::whatif;
use crate::Result;

/// Output of one figure run. Emission lives on the engine's uniform
/// [`crate::engine::Outcome`] record (convert via `From`) so the figure
/// path and `netbn run fig<n>` share one code path — and byte-identical
/// CSVs.
pub struct FigureRun {
    pub figures: Vec<Figure>,
    pub checks: Vec<Check>,
}

impl From<FigureRun> for crate::engine::Outcome {
    fn from(run: FigureRun) -> crate::engine::Outcome {
        crate::engine::Outcome::from_figures(run.figures, run.checks)
    }
}

/// All known figure ids.
pub const FIGURE_IDS: [&str; 8] = ["1", "2", "3", "4", "5", "6", "7", "8"];

/// Run one figure by id ("1".."8").
pub fn run_figure(id: &str) -> Result<FigureRun> {
    match id {
        "1" => Ok(fig1()),
        "2" => Ok(fig2()),
        "3" => Ok(fig3()),
        "4" => Ok(fig4()),
        "5" => Ok(fig5()),
        "6" => Ok(fig6()),
        "7" => Ok(fig7()),
        "8" => Ok(fig8()),
        other => anyhow::bail!("unknown figure {other:?}; known: {FIGURE_IDS:?}"),
    }
}

fn fig1() -> FigureRun {
    let f = whatif::fig1_scaling_vs_servers();
    let mut checks = Vec::new();
    // Paper: 56%–76% overall; ResNet50 best, VGG16 worst at every point.
    for servers in whatif::SERVER_COUNTS {
        let x = servers as f64;
        let rn50 = f.series("ResNet50").unwrap().y_at(x).unwrap();
        let rn101 = f.series("ResNet101").unwrap().y_at(x).unwrap();
        let vgg = f.series("VGG16").unwrap().y_at(x).unwrap();
        checks.push(Check::assert(
            format!("fig1@{servers}srv ordering rn50>rn101>vgg16"),
            rn50 > rn101 && rn101 > vgg,
            format!("{rn50:.3} / {rn101:.3} / {vgg:.3}"),
        ));
        // Band: paper measured 0.56–0.76; our hierarchical NIC accounting
        // (per-NIC traffic 2S(M−1)/M over M servers, vs the paper's
        // flat-ring-over-GPUs approximation) runs the 2-server points
        // ~10 pts higher — see EXPERIMENTS.md §Deviations.
        checks.push(Check::assert(
            format!("fig1@{servers}srv all within band 0.45–0.90"),
            [rn50, rn101, vgg].iter().all(|v| (0.45..=0.90).contains(v)),
            "paper: 0.56–0.76".to_string(),
        ));
    }
    FigureRun { figures: vec![f], checks }
}

fn fig2() -> FigureRun {
    let f = whatif::fig2_computation_time();
    let mut checks = Vec::new();
    for s in &f.series {
        let single = s.y_at(1.0).unwrap();
        let at2 = s.y_at(2.0).unwrap();
        let at8 = s.y_at(8.0).unwrap();
        checks.push(Check::assert(
            format!("fig2 {} flat across 2–8 servers", s.name),
            (at2 - at8).abs() / at2 < 0.02,
            format!("{at2:.1} ms vs {at8:.1} ms"),
        ));
        checks.push(Check::assert(
            format!("fig2 {} distributed ≤ 15% above single GPU", s.name),
            at8 / single <= 1.15 + 1e-9 && at8 / single >= 1.0,
            format!("ratio {:.3}", at8 / single),
        ));
    }
    FigureRun { figures: vec![f], checks }
}

fn fig3() -> FigureRun {
    let f = whatif::fig3_scaling_vs_bandwidth(ModelId::ResNet50);
    let mut checks = Vec::new();
    for s in &f.series {
        let low_gain = s.y_at(10.0).unwrap() - s.y_at(1.0).unwrap();
        let high_gain = s.y_at(100.0).unwrap() - s.y_at(25.0).unwrap();
        checks.push(Check::assert(
            format!("fig3 {} plateaus after 25 Gbps", s.name),
            high_gain < low_gain * 0.4,
            format!("Δ(1→10)={low_gain:.3}, Δ(25→100)={high_gain:.3}"),
        ));
    }
    // Paper: 2 servers grow 13% → ~68% from 1 to 10 Gbps.
    let s2 = f.series("2 servers").unwrap();
    checks.push(Check::assert(
        "fig3 2srv @1Gbps deeply degraded (paper: 13%)",
        s2.y_at(1.0).unwrap() < 0.30,
        format!("{:.3}", s2.y_at(1.0).unwrap()),
    ));
    FigureRun { figures: vec![f], checks }
}

fn fig4() -> FigureRun {
    let f = whatif::fig4_network_utilization();
    let cap = f.series("transport achievable").unwrap();
    let checks = vec![
        Check::assert(
            "fig4 ≈ full utilization at 1 Gbps",
            cap.y_at(1.0).unwrap() > 0.99,
            format!("{:.3}", cap.y_at(1.0).unwrap()),
        ),
        Check::assert(
            "fig4 ≤ 32/100 at 100 Gbps (paper: 'no more than 32 Gbps')",
            cap.y_at(100.0).unwrap() <= 0.32,
            format!("{:.3}", cap.y_at(100.0).unwrap()),
        ),
        Check::assert(
            "fig4 utilization monotonically falls with provisioned bw",
            whatif::BANDWIDTHS
                .windows(2)
                .all(|w| cap.y_at(w[0]).unwrap() >= cap.y_at(w[1]).unwrap()),
            String::new(),
        ),
    ];
    FigureRun { figures: vec![f], checks }
}

fn fig5() -> FigureRun {
    let f = whatif::fig5_cpu_utilization();
    let mut checks = Vec::new();
    for s in &f.series {
        let max = s.points.iter().map(|p| p.1).fold(0.0, f64::max);
        checks.push(Check::assert(
            format!("fig5 {} CPU stays ≤ 30% (paper: 14–25%)", s.name),
            max <= 0.30,
            format!("max {max:.3}"),
        ));
    }
    FigureRun { figures: vec![f], checks }
}

fn fig6() -> FigureRun {
    let mut figures = Vec::new();
    let mut checks = Vec::new();
    for id in ModelId::paper_models() {
        let f = whatif::fig6_sim_vs_measured(id, 8);
        let sim = f.series("simulated (full util)").unwrap();
        let meas = f.series("measured-mode (Horovod-like)").unwrap();
        checks.push(Check::assert(
            format!("fig6 {id} lines close at 1–10 Gbps"),
            (sim.y_at(1.0).unwrap() - meas.y_at(1.0).unwrap()).abs() < 0.12
                && (sim.y_at(10.0).unwrap() - meas.y_at(10.0).unwrap()).abs() < 0.15,
            format!(
                "Δ@1G={:.3}, Δ@10G={:.3}",
                sim.y_at(1.0).unwrap() - meas.y_at(1.0).unwrap(),
                sim.y_at(10.0).unwrap() - meas.y_at(10.0).unwrap()
            ),
        ));
        checks.push(Check::assert(
            format!("fig6 {id} diverges past 25 Gbps"),
            sim.y_at(100.0).unwrap() - meas.y_at(100.0).unwrap() > 0.10,
            format!("Δ@100G={:.3}", sim.y_at(100.0).unwrap() - meas.y_at(100.0).unwrap()),
        ));
        checks.push(Check::assert(
            format!("fig6 {id} simulated ≈ 100% at 100 Gbps"),
            sim.y_at(100.0).unwrap() > 0.95,
            format!("{:.3}", sim.y_at(100.0).unwrap()),
        ));
        figures.push(f);
    }
    FigureRun { figures, checks }
}

fn fig7() -> FigureRun {
    let f = whatif::fig7_simulated_at_100g();
    let mut checks = Vec::new();
    for id in ModelId::paper_models() {
        let sim = f.series(&format!("{} simulated", id.name())).unwrap();
        let meas = f.series(&format!("{} measured", id.name())).unwrap();
        checks.push(Check::assert(
            format!("fig7 {id} simulated >95% even at 64 GPUs"),
            whatif::SERVER_COUNTS.iter().all(|s| sim.y_at((s * 8) as f64).unwrap() > 0.95),
            format!("@64: {:.3}", sim.y_at(64.0).unwrap()),
        ));
        checks.push(Check::assert(
            format!("fig7 {id} visible gap to measured"),
            meas.y_at(64.0).unwrap() < sim.y_at(64.0).unwrap() - 0.1,
            format!("measured@64 {:.3}", meas.y_at(64.0).unwrap()),
        ));
    }
    FigureRun { figures: vec![f], checks }
}

fn fig8() -> FigureRun {
    let f10 = whatif::fig8_compression(10.0);
    let f100 = whatif::fig8_compression(100.0);
    let mut checks = Vec::new();
    let vgg10 = f10.series("VGG16").unwrap();
    checks.push(Check::assert(
        "fig8 VGG16 @10G: 10× compression reaches ≈ linear",
        vgg10.y_at(10.0).unwrap() > 0.90,
        format!("{:.3}", vgg10.y_at(10.0).unwrap()),
    ));
    checks.push(Check::assert(
        "fig8 @10G: 100× adds almost nothing over 10×",
        vgg10.y_at(100.0).unwrap() - vgg10.y_at(10.0).unwrap() < 0.08,
        format!("Δ={:.3}", vgg10.y_at(100.0).unwrap() - vgg10.y_at(10.0).unwrap()),
    ));
    let rn50_10 = f10.series("ResNet50").unwrap();
    checks.push(Check::assert(
        "fig8 ResNet50 @10G: 2–5× already ≈ linear (paper §1: 2×–5×)",
        rn50_10.y_at(5.0).unwrap() > 0.90,
        format!("@5x: {:.3}", rn50_10.y_at(5.0).unwrap()),
    ));
    for s in &f100.series {
        checks.push(Check::assert(
            format!("fig8 {} @100G: compression unnecessary", s.name),
            s.y_at(1.0).unwrap() > 0.90,
            format!("@1x: {:.3}", s.y_at(1.0).unwrap()),
        ));
    }
    FigureRun { figures: vec![f10, f100], checks }
}

/// Cross-validation: emulator (real clocks, shaped fabric, real bytes) vs
/// simulator (virtual clock, analytic costs) on identical laptop-scale
/// configs — our analogue of the paper's low-bandwidth validation of the
/// what-if simulator.
pub fn validate_emulator_against_sim(
    model: ModelId,
    workers: usize,
    bandwidth_gbps: f64,
    payload_scale: f64,
) -> Result<(f64, f64, Check)> {
    use crate::config::{ExperimentConfig, TransportKind};
    use crate::models::timing::backward_trace;
    use crate::sim::{simulate, SimParams};
    use crate::trainer::{run_emulated, EmulatedRunConfig};

    let exp = ExperimentConfig {
        model,
        servers: workers,
        gpus_per_server: 1,
        bandwidth_gbps,
        transport: TransportKind::FullUtilization,
        steps: 5,
        warmup_steps: 1,
        ..Default::default()
    };
    let emu = run_emulated(&EmulatedRunConfig { exp, payload_scale })?;
    let mut p = SimParams::whatif(backward_trace(&model.profile()), workers, 1, bandwidth_gbps);
    // The emulator reduces *payload-scaled* buffers, so its add cost is
    // negligible by construction; zero the sim's AddEst so both sides
    // model the same thing (the validation isolates transit + fusion +
    // overlap, which is the paper's argument).
    p.add_est = crate::models::timing::AddEst::from_points(vec![(0.0, 0.0), (1e9, 0.0)]);
    let sim = simulate(&p);
    let (e, s) = (emu.scaling_factor, sim.scaling_factor);
    let rel = (e - s).abs() / s.max(1e-9);
    let check = Check::assert(
        format!("emulator ≈ simulator ({model}, {workers}w, {bandwidth_gbps} Gbps)"),
        rel < 0.25,
        format!("emulated {e:.3} vs simulated {s:.3} (rel Δ {:.1}%)", rel * 100.0),
    );
    Ok((e, s, check))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_figure_runs_and_passes_shape_checks() {
        for id in FIGURE_IDS {
            let run = run_figure(id).unwrap();
            assert!(!run.figures.is_empty(), "fig{id} produced no figures");
            for c in &run.checks {
                assert!(c.pass, "fig{id} check failed: {} — {}", c.desc, c.detail);
            }
        }
    }

    #[test]
    fn unknown_figure_rejected() {
        assert!(run_figure("9").is_err());
    }
}
