//! # netbn — "Is Network the Bottleneck of Distributed Training?"
//!
//! A reproduction of Zhang et al., NetAI'20, as a three-layer
//! rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — a data-parallel training *emulator* (real worker
//!   threads, real TCP, token-bucket bandwidth shaping, Horovod-style fusion
//!   buffer + ring all-reduce), the paper's **what-if simulator** (virtual
//!   clock, full-utilization transport, `AddEst` interpolation), gradient
//!   compression codecs, and the measurement harness that regenerates every
//!   figure in the paper's evaluation.
//! * **L2 (python/compile/model.py)** — a JAX transformer train step, AOT
//!   lowered to HLO text at build time (`make artifacts`).
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the hot spots
//!   (gradient vector-add, tiled matmul, int8 quantization, top-k masking),
//!   lowered inside the same HLO artifacts.
//!
//! Python never runs on the measurement/request path: the rust binary loads
//! `artifacts/*.hlo.txt` through PJRT (`runtime`) and is self-contained.
//!
//! ## Map of the crate
//!
//! Layered bottom-up: substrates, domain models, execution modes, then
//! the engine that unifies them behind one API.
//!
//! | layer | module | role |
//! |---|---|---|
//! | substrate | [`util`] | PRNG, statistics, microbench + property-test mini-frameworks, logging |
//! | substrate | [`cli`] | subcommand/flag parser with repeatable options (no clap in the offline env) |
//! | substrate | [`report`] | ASCII tables, figure series, CSV/JSON writers, paper-shape checks |
//! | substrate | [`obs`] | unified observability plane: lock-free metrics registry (counters/gauges/log-bucketed histograms), scoped `span!` tracing with Chrome-trace export, cross-rank per-step time breakdowns + link-utilization timelines |
//! | substrate | [`config`] | typed experiment configs, `Compression::parse` (ratio-or-codec), TOML-subset parser, paper presets |
//! | domain | [`topology`] | servers × GPUs, ring construction, two-tier `Cluster` grouping |
//! | domain | [`net`] | fabrics (in-proc, real TCP, multi-process mesh), the `Transport` strategy layer (single-stream vs striped:N), size-classed buffer pool + vectored I/O, token-bucket shaper, kernel-TCP + striped cost models |
//! | domain | [`collectives`] | ring / tree / PS / hierarchical leader-ring all-reduce + Horovod fusion buffer |
//! | domain | [`models`] | ResNet50/101/VGG16 layer generators + V100 timing model |
//! | domain | [`compress`] | real gradient codecs: fp16, int8, top-k, random-k, 1-bit |
//! | domain | [`measure`] | CPU / link utilization sampling, white-box timing traces |
//! | domain | [`sched`] | overlap scheduling: async collective engine (non-blocking handles), DDP-style bucketizer, compute/comm overlap scheduler (`--overlap off\|buckets`, `--bucket-mb`) |
//! | domain | [`tune`] | online autotuning control plane: per-step feedback, the typed knob space (bucket × stripes × chunk × collective × compression), the warmup→probe→exploit `AutoTuner`, and the analytic oracle (`--autotune`, `netbn tune`) |
//! | mode | [`sim`] | the paper's §3 what-if simulator + ablation sweeps + hierarchical and overlap cost models |
//! | mode | [`trainer`] | data-parallel worker loop with backward/all-reduce overlap; `launch` runs real worker processes over host-addressable TCP rendezvous (loopback default); `elastic` adds membership churn, checkpoint/replay crash recovery and straggler detection |
//! | mode | [`runtime`] | PJRT wrapper: load + execute AOT artifacts (vendored stub offline) |
//! | mode | [`figures`] | per-figure experiment drivers (Fig 1–8) |
//! | engine | [`engine`] | `Scenario` / `Runner` / `Outcome` / `ScenarioRegistry` / `SweepBuilder` — every experiment as a named, parameterized, sweepable scenario (see ENGINE.md) |
//! | service | [`serve`] | `netbn serve`: persistent multi-tenant experiment daemon — std-only HTTP/1.1, bounded priority queue with admission control, worker pool over the engine, live telemetry, store-backed restart + tuner warm starts |
//!
//! New workloads register as [`engine`] scenarios rather than growing
//! `main.rs`; the CLI (`netbn list` / `run` / `sweep`) is registry-driven.

pub mod cli;
pub mod collectives;
pub mod compress;
pub mod config;
pub mod engine;
pub mod figures;
pub mod measure;
pub mod models;
pub mod net;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod sim;
pub mod topology;
pub mod trainer;
pub mod tune;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Bits per byte — kept explicit because the paper mixes Gbps (bits) and
/// MB (bytes) constantly and silent factor-of-8 bugs are the #1 hazard here.
pub const BITS_PER_BYTE: f64 = 8.0;

/// Convert a link speed in Gbps to bytes/second.
pub fn gbps_to_bytes_per_sec(gbps: f64) -> f64 {
    gbps * 1e9 / BITS_PER_BYTE
}

/// Convert bytes/second to Gbps.
pub fn bytes_per_sec_to_gbps(bps: f64) -> f64 {
    bps * BITS_PER_BYTE / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gbps_round_trip() {
        for g in [1.0, 10.0, 25.0, 50.0, 100.0] {
            let b = gbps_to_bytes_per_sec(g);
            assert!((bytes_per_sec_to_gbps(b) - g).abs() < 1e-9);
        }
    }

    #[test]
    fn gbps_magnitude() {
        // 100 Gbps = 12.5 GB/s
        assert_eq!(gbps_to_bytes_per_sec(100.0), 12.5e9);
    }
}
