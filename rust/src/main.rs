//! `netbn` — leader binary over the scenario engine: discover and run any
//! registered experiment (`list` / `run` / `sweep`), regenerate paper
//! figures, run emulated or real training, calibrate cost tables, validate
//! emulator vs simulator. The pre-engine subcommands (`fig`, `simulate`,
//! `emulate`, `validate`, `ablate`) remain as thin aliases over the
//! [`netbn::engine::ScenarioRegistry`], with unchanged CSV output. The
//! service face (`serve` / `submit` / `jobs` / `watch`) runs the same
//! registry behind a persistent HTTP daemon ([`netbn::serve`]).

use netbn::cli::{App, Args, CmdSpec, OptSpec, Parsed};
use netbn::engine::{ScenarioRegistry, SweepBuilder, SweepPoint};
use netbn::models::ModelId;
use netbn::report::{json_str, Table};
use netbn::Result;
use std::path::PathBuf;

fn app() -> App {
    App {
        name: "netbn",
        about: "reproduction of 'Is Network the Bottleneck of Distributed Training?' (NetAI'20)",
        commands: vec![
            CmdSpec {
                name: "list",
                about: "enumerate every registered scenario",
                opts: vec![OptSpec::flag(
                    "markdown",
                    "render the catalog as Markdown (the docs/SCENARIOS.md generator)",
                )],
                positional: vec![],
            },
            CmdSpec {
                name: "run",
                about: "run one scenario by name",
                opts: vec![
                    OptSpec::repeated("param", "override one parameter (k=v)"),
                    OptSpec::value("out", "CSV output directory", "out"),
                    OptSpec::optional("json", "write the Outcome as JSON to a file, or '-' for stdout"),
                ],
                positional: vec![("scenario", "scenario name (see `netbn list`)")],
            },
            CmdSpec {
                name: "sweep",
                about: "run a cartesian parameter sweep over one scenario",
                opts: vec![
                    OptSpec::repeated("grid", "swept axis (k=v1,v2,...)"),
                    OptSpec::repeated("param", "fixed parameter for every point (k=v)"),
                    OptSpec::value("parallel", "worker threads", "1"),
                    OptSpec::optional("json", "write all Outcomes as JSON to a file, or '-' for stdout"),
                ],
                positional: vec![("scenario", "scenario name (see `netbn list`)")],
            },
            CmdSpec {
                name: "fig",
                about: "regenerate a paper figure (1-8, or 'all') [alias for `run fig<n>`]",
                opts: vec![OptSpec::value("out", "CSV output directory", "out")],
                positional: vec![("n", "figure number 1-8 or 'all'")],
            },
            CmdSpec {
                name: "simulate",
                about: "run the what-if simulator at one experiment point [alias for `run simulate`]",
                opts: vec![
                    OptSpec::optional("model", "resnet50|resnet101|vgg16|transformer (default resnet50)"),
                    OptSpec::optional("workers", "GPUs in the all-reduce (default 64)"),
                    OptSpec::optional("bandwidth", "provisioned Gbps (default 100)"),
                    OptSpec::optional("transport", "full|kernel-tcp (default full)"),
                    OptSpec::optional("compression", "wire ratio or codec, e.g. 4 | fp16 | topk:0.01 (default 1)"),
                ],
                positional: vec![],
            },
            CmdSpec {
                name: "emulate",
                about: "run the real-time emulator (modeled compute, shaped fabric) [alias for `run emulate`]",
                opts: vec![
                    OptSpec::optional("model", "resnet50|resnet101|vgg16 (default resnet50)"),
                    OptSpec::optional("servers", "server count, 1 worker each (default 4)"),
                    OptSpec::optional("bandwidth", "provisioned Gbps (default 25)"),
                    OptSpec::optional("transport", "full|kernel-tcp (default full)"),
                    OptSpec::optional("collective", "ring|tree|ps|hier:<g> (default ring)"),
                    OptSpec::optional("overlap", "off|buckets (default buckets)"),
                    OptSpec::optional("bucket-mb", "DDP bucket threshold MB, 0 = fusion buffer (default 0)"),
                    OptSpec::optional("steps", "measured steps (default 5)"),
                    OptSpec::optional("payload-scale", "byte/rate shrink factor (default 256)"),
                    OptSpec::optional("compression", "wire ratio or codec (default 1)"),
                ],
                positional: vec![],
            },
            CmdSpec {
                name: "validate",
                about: "cross-validate emulator vs simulator [alias for `run validate`]",
                opts: vec![
                    OptSpec::optional("workers", "worker count (default 4)"),
                    OptSpec::optional("bandwidths", "comma list of Gbps (default 5,25,100)"),
                    OptSpec::optional("payload-scale", "byte/rate shrink factor (default 1024)"),
                ],
                positional: vec![],
            },
            CmdSpec {
                name: "ablate",
                about: "run the ablation sweeps [alias for the four ablate-* scenarios]",
                opts: vec![
                    OptSpec::optional("model", "resnet50|resnet101|vgg16 (default vgg16)"),
                    OptSpec::value("out", "CSV output directory", "out"),
                ],
                positional: vec![],
            },
            CmdSpec {
                name: "calibrate-add",
                about: "measure AddEst(x) locally and print the table (§3.1)",
                opts: vec![OptSpec::value("max-elems", "largest vector size", "4194304")],
                positional: vec![],
            },
            CmdSpec {
                name: "train",
                about: "e2e: train the AOT transformer over N emulated workers",
                opts: vec![
                    OptSpec::value("workers", "worker count", "2"),
                    OptSpec::value("steps", "training steps", "20"),
                    OptSpec::value("batch", "batch per worker", "4"),
                    OptSpec::value("lr", "learning rate", "0.05"),
                    OptSpec::value("artifacts", "artifacts directory", "artifacts"),
                ],
                positional: vec![],
            },
            CmdSpec {
                name: "launch",
                about: "e2e: spawn N real worker processes on loopback TCP and train synchronously",
                opts: vec![
                    OptSpec::value("workers", "worker process count", "4"),
                    OptSpec::value("steps", "synchronous steps", "2"),
                    OptSpec::value("elems", "gradient tensor length (f32 elements)", "262144"),
                    OptSpec::value("transport", "single|tcp|striped:N", "striped:4"),
                    OptSpec::value("collective", "ring|tree|ps|hier:<group_size>", "hier:2"),
                    OptSpec::value("overlap", "off|buckets (submit buckets during backward?)", "off"),
                    OptSpec::value("bucket-mb", "bucketizer threshold MB (0 = one bucket)", "0"),
                    OptSpec::value("layers", "synthetic backward layers", "1"),
                    OptSpec::value("compute-us", "modeled backward compute per step (us)", "0"),
                    OptSpec::value("autotune", "true|false: rank 0 tunes the stripe chunk online and broadcasts knob changes", "false"),
                    OptSpec::value("chunk-kbs", "autotune chunk-size candidates, KB (comma list)", "4,32,256"),
                    OptSpec::value("gate-gbps", "modeled per-stream ceiling Gbps (0 = unshaped)", "0"),
                    OptSpec::value("drop-at-step", "drop the gate at this step (0 = never)", "0"),
                    OptSpec::value("drop-gbps", "post-drop per-stream Gbps", "0"),
                    OptSpec::value("obs", "true|false: span tracing + per-step time breakdown and link-utilization report", "false"),
                    OptSpec::optional("trace-out", "write the merged Chrome trace-event JSON here (implies --obs; open in Perfetto)"),
                    OptSpec::optional("feedback-out", "write per-step step_feedback JSONL here"),
                    OptSpec::value(
                        "spawn",
                        "process|thread|external (external = serve the rendezvous only; start \
                         workers yourself with `netbn _worker --coordinator host:port`)",
                        "process",
                    ),
                    OptSpec::value("rendezvous-timeout", "seconds to wait for all workers to register", "60"),
                    OptSpec::value(
                        "bind",
                        "coordinator bind address (a routable IP for multi-host cohorts)",
                        "127.0.0.1:0",
                    ),
                    OptSpec::value("seed", "gradient RNG seed", "3735928559"),
                ],
                positional: vec![],
            },
            CmdSpec {
                name: "_worker",
                about: "(internal) one rank of a `netbn launch` run",
                opts: vec![
                    OptSpec::optional("rank", "this worker's rank"),
                    OptSpec::optional("world", "total worker count"),
                    OptSpec::optional("coordinator", "coordinator host:port"),
                    OptSpec::value("steps", "synchronous steps", "2"),
                    OptSpec::value("elems", "gradient tensor length", "262144"),
                    OptSpec::value("transport", "single|tcp|striped:N", "striped:4"),
                    OptSpec::value("collective", "ring|tree|ps|hier:<g>", "hier:2"),
                    OptSpec::value("overlap", "off|buckets", "off"),
                    OptSpec::value("bucket-mb", "bucketizer threshold MB (0 = one bucket)", "0"),
                    OptSpec::value("layers", "synthetic backward layers", "1"),
                    OptSpec::value("compute-us", "modeled backward compute per step (us)", "0"),
                    OptSpec::value("autotune", "true|false", "false"),
                    OptSpec::value("chunk-kbs", "autotune chunk-size candidates, KB", "4,32,256"),
                    OptSpec::value("gate-gbps", "modeled per-stream ceiling Gbps", "0"),
                    OptSpec::value("drop-at-step", "drop the gate at this step (0 = never)", "0"),
                    OptSpec::value("drop-gbps", "post-drop per-stream Gbps", "0"),
                    OptSpec::value("obs", "true|false: span tracing + breakdown shipping", "false"),
                    OptSpec::optional("trace-out", "rank 0 writes the merged Chrome trace here"),
                    OptSpec::value("seed", "gradient RNG seed", "3735928559"),
                ],
                positional: vec![],
            },
            CmdSpec {
                name: "_eworker",
                about: "(internal) one elastic worker of an elastic/chaos launch",
                opts: vec![
                    OptSpec::optional("uid", "this worker's unique id"),
                    OptSpec::optional("coordinator", "coordinator host:port"),
                    OptSpec::optional("die-at", "(fault injection) drop dead at this step"),
                ],
                positional: vec![],
            },
            CmdSpec {
                name: "tune",
                about: "the autotuning control plane, offline: replay recorded feedback and/or query the analytic oracle",
                opts: vec![
                    OptSpec::optional("from-trace", "JSONL trace with step_feedback records to replay"),
                    OptSpec::flag("oracle", "print the oracle's best knob point per rate"),
                    OptSpec::value("model", "resnet50|resnet101|vgg16|transformer", "resnet50"),
                    OptSpec::value("servers", "server count", "8"),
                    OptSpec::value("gpus", "GPUs per server", "8"),
                    OptSpec::value("bandwidths", "comma list of Gbps for --oracle", "1,10,25,100"),
                    OptSpec::repeated("knobs", "knob-space override (name=v1,v2,...)"),
                    OptSpec::optional("json", "write the result as JSON, or '-' for stdout"),
                ],
                positional: vec![],
            },
            CmdSpec {
                name: "bench",
                about: "run the benchmark scenarios and optionally gate against a baseline",
                opts: vec![
                    OptSpec::optional("json", "write the collected metrics as flat JSON"),
                    OptSpec::optional("compare", "baseline JSON to gate against (bench/baseline.json)"),
                    OptSpec::value("tolerance", "allowed fractional regression", "0.2"),
                    OptSpec::value("e2e-runs", "launch-probe repetitions for e2e.busbw mean/stddev", "3"),
                    OptSpec::optional("store", "append this run to <store>/bench_history.jsonl"),
                    OptSpec::flag("trend", "evaluate <store>/bench_history.jsonl for sustained regressions and exit"),
                    OptSpec::value("trend-window", "history entries the trend gate looks at", "12"),
                ],
                positional: vec![],
            },
            CmdSpec {
                name: "serve",
                about: "run the persistent experiment daemon (HTTP job queue over the engine)",
                opts: vec![
                    OptSpec::value("port", "TCP port to listen on (0 = pick a free port)", "7070"),
                    OptSpec::value("workers", "worker threads draining the job queue", "2"),
                    OptSpec::value("queue-cap", "max queued jobs before submissions get 429", "32"),
                    OptSpec::value("store", "job-record + tuner-checkpoint store directory", ".netbn-store"),
                ],
                positional: vec![],
            },
            CmdSpec {
                name: "submit",
                about: "submit one scenario to a running `netbn serve` daemon",
                opts: vec![
                    OptSpec::repeated("param", "override one parameter (k=v)"),
                    OptSpec::value("priority", "scheduling priority 0-9 (higher drains first)", "5"),
                    OptSpec::value("host", "daemon address", "127.0.0.1:7070"),
                ],
                positional: vec![("scenario", "scenario name (see `netbn list`)")],
            },
            CmdSpec {
                name: "jobs",
                about: "list the jobs a running `netbn serve` daemon knows about",
                opts: vec![OptSpec::value("host", "daemon address", "127.0.0.1:7070")],
                positional: vec![],
            },
            CmdSpec {
                name: "watch",
                about: "stream one job's live telemetry until it finishes",
                opts: vec![OptSpec::value("host", "daemon address", "127.0.0.1:7070")],
                positional: vec![("id", "job id (from `netbn submit`)")],
            },
            CmdSpec {
                name: "info",
                about: "print model profiles and environment",
                opts: vec![],
                positional: vec![],
            },
        ],
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(true) => 0,
        Ok(false) => 1,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<bool> {
    let registry = ScenarioRegistry::builtin();
    match app().parse(argv)? {
        Parsed::Help(text) => {
            println!("{text}");
            Ok(true)
        }
        Parsed::Command(name, args) => match name.as_str() {
            "list" => cmd_list(&registry, &args),
            "run" => cmd_run(&registry, &args),
            "sweep" => cmd_sweep(&registry, &args),
            "fig" => cmd_fig(&registry, &args),
            "simulate" => cmd_alias(&registry, "simulate", &args),
            "emulate" => cmd_alias(&registry, "emulate", &args),
            "validate" => cmd_alias(&registry, "validate", &args),
            "ablate" => cmd_ablate(&registry, &args),
            "calibrate-add" => cmd_calibrate(&args),
            "train" => cmd_train(&args),
            "launch" => cmd_launch(&args),
            "_worker" => cmd_worker(&args),
            "_eworker" => cmd_eworker(&args),
            "tune" => cmd_tune(&args),
            "bench" => cmd_bench(&registry, &args),
            "serve" => cmd_serve(&args),
            "submit" => cmd_submit(&args),
            "jobs" => cmd_jobs(&args),
            "watch" => cmd_watch(&args),
            "info" => cmd_info(),
            other => anyhow::bail!("unhandled command {other}"),
        },
    }
}

/// User-provided options as scenario parameter overrides (alias path:
/// option names match parameter names one-to-one).
fn overrides_from_options(args: &Args) -> Vec<(String, String)> {
    args.options.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
}

/// Reject a repeated key in a `--param`/`--grid` list: parameter
/// resolution is last-write-wins, so the earlier value would silently
/// lose with no diagnostic.
fn ensure_unique_keys(flag: &str, pairs: &[(String, String)]) -> Result<()> {
    for (i, (k, _)) in pairs.iter().enumerate() {
        anyhow::ensure!(
            !pairs[..i].iter().any(|(prev, _)| prev == k),
            "--{flag} {k} given twice; the later value would silently win"
        );
    }
    Ok(())
}

fn cmd_list(registry: &ScenarioRegistry, args: &Args) -> Result<bool> {
    if args.has_flag("markdown") {
        // Pure generated output: `netbn list --markdown > docs/SCENARIOS.md`.
        // CI regenerates the file and fails on drift.
        print!("{}", registry.markdown());
        return Ok(true);
    }
    let mut t = Table::new(
        format!("registered scenarios ({})", registry.len()),
        &["name", "mode", "parameters (defaults)", "description"],
    );
    for s in registry.iter() {
        let params = s
            .schema()
            .specs()
            .iter()
            .map(|p| format!("{}={}", p.name, p.default))
            .collect::<Vec<_>>()
            .join(" ");
        t.row(vec![
            s.name().into(),
            s.mode().into(),
            if params.is_empty() { "-".into() } else { params },
            s.about().into(),
        ]);
    }
    println!("{}", t.render());
    println!("run one with: netbn run <scenario> [--param k=v ...] [--json -]");
    println!("sweep one with: netbn sweep <scenario> --grid k=v1,v2,... [--parallel N]");
    Ok(true)
}

fn cmd_run(registry: &ScenarioRegistry, args: &Args) -> Result<bool> {
    let name = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: netbn run <scenario> [--param k=v ...]"))?;
    let scenario = registry.get(name)?;
    let params = args.get_kv_multi("param")?;
    ensure_unique_keys("param", &params)?;
    let outcome = scenario.run(&params)?;
    let out_dir = PathBuf::from(args.get_or("out", "out"));
    let json_dest = args.get("json");
    // `--json -` streams pure JSON to stdout: suppress human rendering but
    // still persist CSVs.
    let ok = if json_dest == Some("-") {
        outcome.write_csvs(&out_dir)?;
        outcome.passed()
    } else {
        outcome.emit(Some(out_dir.as_path()))?
    };
    match json_dest {
        None => {}
        Some("-") => println!("{}", outcome.to_json()),
        Some(path) => {
            std::fs::write(path, outcome.to_json())?;
            println!("  -> {path}");
        }
    }
    Ok(ok)
}

fn cmd_sweep(registry: &ScenarioRegistry, args: &Args) -> Result<bool> {
    let name = args.positional.first().ok_or_else(|| {
        anyhow::anyhow!("usage: netbn sweep <scenario> --grid k=v1,v2,... [--parallel N]")
    })?;
    let scenario = registry.get(name)?;
    let mut sweep = SweepBuilder::new(scenario);
    let params = args.get_kv_multi("param")?;
    let grids = args.get_kv_multi("grid")?;
    anyhow::ensure!(!grids.is_empty(), "sweep needs at least one --grid key=v1,v2,...");
    // Reject key collisions up front: resolution is last-write-wins, so a
    // silently overridden key would leave point labels contradicting what
    // actually ran.
    ensure_unique_keys("param", &params)?;
    for (i, (k, _)) in grids.iter().enumerate() {
        anyhow::ensure!(
            !grids[..i].iter().any(|(prev, _)| prev == k),
            "--grid {k} given twice; merge the values into one axis (--grid {k}=v1,v2,...)"
        );
        anyhow::ensure!(
            !params.iter().any(|(p, _)| p == k),
            "{k} is both --param and --grid; a parameter is either fixed or swept, not both"
        );
    }
    for (k, v) in params {
        sweep = sweep.fix(k, v);
    }
    for (k, csv) in grids {
        sweep = sweep.axis_csv(k, &csv);
    }
    let parallel = args.get_usize("parallel", 1)?;
    if parallel > 1 && scenario.realtime() {
        eprintln!(
            "warning: {} measures real wall-clock behavior; --parallel {parallel} \
             oversubscribes the host and distorts per-point measurements — \
             use --parallel 1 for numbers you intend to compare",
            scenario.name()
        );
    }
    let t0 = std::time::Instant::now();
    let points = sweep.run(parallel);
    let wall_s = t0.elapsed().as_secs_f64();

    let json_dest = args.get("json");
    if json_dest != Some("-") {
        let mut t = Table::new(
            format!(
                "sweep: {} — {} points, --parallel {}, {}",
                scenario.name(),
                points.len(),
                parallel.max(1),
                netbn::util::fmt::secs(wall_s)
            ),
            &["#", "point", "status", "scaling factor", "wall"],
        );
        for p in &points {
            let param_str =
                p.params.iter().map(|(k, v)| format!("{k}={v}")).collect::<Vec<_>>().join(" ");
            match &p.outcome {
                Ok(o) => t.row(vec![
                    p.index.to_string(),
                    param_str,
                    if o.passed() { "ok".into() } else { "CHECKS FAILED".into() },
                    o.metric_value("scaling_factor")
                        .map(|v| format!("{v:.3}"))
                        .unwrap_or_else(|| "-".into()),
                    netbn::util::fmt::secs(o.wall_s),
                ]),
                Err(e) => t.row(vec![
                    p.index.to_string(),
                    param_str,
                    format!("error: {e}"),
                    "-".into(),
                    "-".into(),
                ]),
            }
        }
        println!("{}", t.render());
    }
    if let Some(dest) = json_dest {
        let json = sweep_json(scenario.name(), parallel, wall_s, &points);
        if dest == "-" {
            println!("{json}");
        } else {
            std::fs::write(dest, json)?;
            println!("  -> {dest}");
        }
    }
    Ok(points.iter().all(|p| p.outcome.as_ref().map(|o| o.passed()).unwrap_or(false)))
}

fn sweep_json(scenario: &str, parallel: usize, wall_s: f64, points: &[SweepPoint]) -> String {
    use std::fmt::Write as _;
    let mut s = String::new();
    let _ = write!(
        s,
        "{{\"scenario\":{},\"parallel\":{},\"wall_s\":{},\"points\":[",
        json_str(scenario),
        parallel.max(1),
        wall_s
    );
    for (i, p) in points.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        match &p.outcome {
            Ok(o) => {
                let _ = write!(s, "{{\"index\":{},\"ok\":true,\"outcome\":{}}}", p.index, o.to_json());
            }
            Err(e) => {
                let _ = write!(
                    s,
                    "{{\"index\":{},\"ok\":false,\"error\":{}}}",
                    p.index,
                    json_str(&format!("{e:#}"))
                );
            }
        }
    }
    s.push_str("]}");
    s
}

/// `fig <n|all>` alias: route through the `fig<n>` scenarios; emission and
/// CSV bytes are identical to the pre-engine path.
fn cmd_fig(registry: &ScenarioRegistry, args: &Args) -> Result<bool> {
    let out = PathBuf::from(args.get_or("out", "out"));
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let ids: Vec<&str> = if which == "all" {
        netbn::figures::FIGURE_IDS.to_vec()
    } else {
        vec![which]
    };
    let mut all_ok = true;
    for id in ids {
        let scenario_name = format!("fig{id}");
        let outcome = registry.get(&scenario_name)?.run(&[])?;
        all_ok &= outcome.emit(Some(out.as_path()))?;
    }
    Ok(all_ok)
}

/// `simulate` / `emulate` / `validate` aliases: the option names map
/// one-to-one onto scenario parameters.
fn cmd_alias(registry: &ScenarioRegistry, scenario: &str, args: &Args) -> Result<bool> {
    let outcome = registry.get(scenario)?.run(&overrides_from_options(args))?;
    outcome.emit(None)
}

/// `ablate` alias: run all four ablation scenarios for one model.
fn cmd_ablate(registry: &ScenarioRegistry, args: &Args) -> Result<bool> {
    let out = PathBuf::from(args.get_or("out", "out"));
    let mut overrides = Vec::new();
    if let Some(model) = args.get("model") {
        overrides.push(("model".to_string(), model.to_string()));
    }
    let mut all_ok = true;
    for name in
        ["ablate-fusion-size", "ablate-fusion-timeout", "ablate-collectives", "ablate-bw-compression"]
    {
        let scenario = registry.get(name)?;
        // `ablate-collectives` takes an extra bandwidth parameter the
        // legacy command never exposed; defaults cover it.
        let outcome = scenario.run(&overrides)?;
        all_ok &= outcome.emit(Some(out.as_path()))?;
    }
    Ok(all_ok)
}

fn cmd_calibrate(args: &Args) -> Result<bool> {
    let max = args.get_usize("max-elems", 1 << 22)?;
    let add = netbn::models::timing::AddEst::measure_local(max);
    let mut t = Table::new("AddEst(x) measured on this host", &["elements", "seconds"]);
    let mut elems = 1024usize;
    while elems <= max {
        t.row(vec![elems.to_string(), format!("{:.3e}", add.seconds(elems as f64))]);
        elems *= 4;
    }
    println!("{}", t.render());
    let v100 = netbn::models::timing::AddEst::v100();
    println!(
        "reference V100 AddEst(131.75M elems / VGG16) = {:.3} ms",
        v100.seconds(527e6 / 4.0) * 1e3
    );
    Ok(true)
}

fn cmd_train(args: &Args) -> Result<bool> {
    use netbn::net::tcp::TcpFabric;
    use netbn::runtime::DeviceService;
    use netbn::trainer::xla::{load_init_params, ModelMeta, XlaTrainer};
    let workers = args.get_usize("workers", 2)?;
    let steps = args.get_usize("steps", 20)?;
    let batch = args.get_usize("batch", 4)?;
    let lr = args.get_f64("lr", 0.05)? as f32;
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let meta = ModelMeta::load(&dir)?;
    let init = load_init_params(&dir, meta.param_count)?;
    println!(
        "model: {} params over {} tensors, vocab {}, seq {}",
        meta.param_count,
        meta.layers.len(),
        meta.vocab,
        meta.seq
    );
    let svc = DeviceService::start(dir);
    let trainer = XlaTrainer::new(svc.handle(), meta);
    let fabric = TcpFabric::new(workers, None)?;
    let result = trainer.train_distributed(
        &fabric,
        init,
        steps,
        batch,
        lr,
        0xe2e,
        netbn::config::FusionConfig::default(),
    )?;
    println!("loss curve (mean across {} workers):", result.workers);
    for (i, l) in result.loss_curve.iter().enumerate() {
        println!("  step {i:>4}  loss {l:.4}");
    }
    let first = result.loss_curve.first().copied().unwrap_or(0.0);
    let last = result.loss_curve.last().copied().unwrap_or(0.0);
    println!("loss: {first:.4} -> {last:.4}");
    Ok(last < first)
}

/// Shared parsing of the launch/_worker knobs.
fn worker_params(args: &Args, world: usize) -> Result<netbn::trainer::launch::WorkerParams> {
    use netbn::config::{CollectiveKind, OverlapMode, TransportKind};
    let transport_s = args.get_or("transport", "striped:4");
    let transport = TransportKind::parse(transport_s)
        .ok_or_else(|| anyhow::anyhow!("--transport: unknown transport {transport_s:?}"))?;
    let collective_s = args.get_or("collective", "hier:2");
    let collective = CollectiveKind::parse(collective_s)
        .ok_or_else(|| anyhow::anyhow!("--collective: unknown collective {collective_s:?}"))?;
    let overlap_s = args.get_or("overlap", "off");
    let overlap = OverlapMode::parse(overlap_s)
        .ok_or_else(|| anyhow::anyhow!("--overlap: expected off|buckets, got {overlap_s:?}"))?;
    let parse_bool = |flag: &str, s: &str| -> Result<bool> {
        match s {
            "true" | "on" | "1" => Ok(true),
            "false" | "off" | "0" => Ok(false),
            other => anyhow::bail!("--{flag}: expected true|false, got {other:?}"),
        }
    };
    let autotune = parse_bool("autotune", args.get_or("autotune", "false"))?;
    let obs = parse_bool("obs", args.get_or("obs", "false"))?;
    let trace_out = args.get("trace-out").map(PathBuf::from);
    let chunk_kbs = args
        .get_or("chunk-kbs", "4,32,256")
        .split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| {
            s.parse::<usize>()
                .map_err(|_| anyhow::anyhow!("--chunk-kbs: bad value {s:?}"))
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(netbn::trainer::launch::WorkerParams {
        world,
        steps: args.get_usize("steps", 2)?,
        elems: args.get_usize("elems", 1 << 18)?,
        transport,
        collective,
        overlap,
        bucket_mb: args.get_f64("bucket-mb", 0.0)?,
        layers: args.get_usize("layers", 1)?,
        compute_us: args.get_usize("compute-us", 0)? as u64,
        autotune,
        chunk_kbs,
        gate_gbps: args.get_f64("gate-gbps", 0.0)?,
        drop_at_step: args.get_usize("drop-at-step", 0)?,
        drop_gbps: args.get_f64("drop-gbps", 0.0)?,
        seed: args.get_usize("seed", 0xdeadbeef)? as u64,
        // --trace-out without --obs still traces: the export needs spans.
        obs: obs || trace_out.is_some(),
        trace_out,
    })
}

fn cmd_launch(args: &Args) -> Result<bool> {
    use netbn::trainer::launch::{launch, LaunchConfig, SpawnMode};
    let workers = args.get_usize("workers", 4)?;
    let spawn_s = args.get_or("spawn", "process");
    let spawn = SpawnMode::parse(spawn_s).ok_or_else(|| {
        anyhow::anyhow!("--spawn: expected process|thread|external, got {spawn_s:?}")
    })?;
    let timeout_s = args.get_f64("rendezvous-timeout", 60.0)?;
    anyhow::ensure!(
        timeout_s.is_finite() && timeout_s > 0.0,
        "--rendezvous-timeout must be a positive number of seconds, got {timeout_s}"
    );
    let bind_s = args.get_or("bind", "127.0.0.1:0");
    let bind: std::net::SocketAddr = bind_s
        .parse()
        .map_err(|_| anyhow::anyhow!("--bind: expected ip:port, got {bind_s:?}"))?;
    let params = worker_params(args, workers)?;
    println!(
        "launch: {workers} workers ({}), {} steps, {} elems, transport {}, collective {}, \
         overlap {} (bucket-mb {}, {} layers, {} us compute{})",
        match spawn {
            SpawnMode::Process => "processes",
            SpawnMode::Thread => "threads",
            SpawnMode::External => "externally started",
        },
        params.steps,
        params.elems,
        params.transport,
        params.collective,
        params.overlap,
        params.bucket_mb,
        params.layers,
        params.compute_us,
        if params.autotune { ", autotune on" } else { "" },
    );
    let feedback_out = args.get("feedback-out").map(PathBuf::from);
    let r = launch(&LaunchConfig {
        params,
        spawn,
        feedback_out: feedback_out.clone(),
        rendezvous_timeout: std::time::Duration::from_secs_f64(timeout_s),
        bind,
    })?;
    println!("{}", r.step_table().render());
    println!("effective bus bandwidth: {:.3} Gbps", r.effective_bus_gbps);
    if !r.breakdown.is_empty() {
        let mut t = Table::new(
            "per-step time breakdown (rank-averaged, from spans)".to_string(),
            &["step", "barrier", "compute", "serialize", "wire", "reduce", "total", "sum/total"],
        );
        for b in &r.breakdown {
            let fmt = netbn::util::fmt::secs;
            t.row(vec![
                b.step.to_string(),
                fmt(b.barrier_s),
                fmt(b.compute_s),
                fmt(b.serialize_s),
                fmt(b.wire_s),
                fmt(b.reduce_s),
                fmt(b.total_s),
                format!("{:.1}%", 100.0 * b.components_sum() / b.total_s.max(1e-12)),
            ]);
        }
        println!("{}", t.render());
        println!(
            "mean delivered wire rate: {:.3} Gbps per rank",
            netbn::bytes_per_sec_to_gbps(r.wire_mean_bps)
        );
    }
    if let Some(path) = args.get("trace-out") {
        println!("  -> {path} (Chrome trace; open in Perfetto / chrome://tracing)");
    }
    if !r.knob_trajectory.is_empty() {
        println!(
            "knob trajectory (step:chunk KB): {}",
            r.knob_trajectory
                .iter()
                .map(|(s, kb)| format!("{s}:{kb}"))
                .collect::<Vec<_>>()
                .join(" -> ")
        );
    }
    if let Some(path) = feedback_out {
        println!("  -> {} (step_feedback JSONL)", path.display());
    }
    println!(
        "final tensors: {} (checksums {})",
        if r.identical { "bit-identical across all workers" } else { "MISMATCH" },
        r.checksums.iter().map(|c| format!("{c:x}")).collect::<Vec<_>>().join(" ")
    );
    Ok(r.passed())
}

/// `netbn tune` — the control plane's offline face: summarize a recorded
/// feedback trace and/or print the oracle's best operating point per
/// rate.
fn cmd_tune(args: &Args) -> Result<bool> {
    use netbn::tune::knobs;
    use netbn::tune::OracleEnv;
    let overrides = args
        .get_multi("knobs")
        .iter()
        .map(|pair| knobs::parse_knob_override(pair))
        .collect::<Result<Vec<_>>>()?;
    let space = knobs::space_from_overrides(&overrides)?;

    let from_trace = args.get("from-trace");
    let oracle = args.has_flag("oracle");
    anyhow::ensure!(
        from_trace.is_some() || oracle,
        "netbn tune needs --from-trace <file> and/or --oracle (see `netbn tune --help`)"
    );

    let mut json = String::from("{");
    if let Some(path) = from_trace {
        let records = netbn::measure::trace::load_step_feedback(std::path::Path::new(path))?;
        anyhow::ensure!(
            !records.is_empty(),
            "{path}: no step_feedback records (capture one with `netbn launch --feedback-out`)"
        );
        let walls: Vec<f64> = records.iter().map(|r| r.wall_s).collect();
        let busy: Vec<f64> = records.iter().map(|r| r.comm_busy_s).collect();
        let busbw: Vec<f64> = records.iter().map(|r| r.busbw_gbps).collect();
        let (w, b, bw) = (
            netbn::util::stats::Summary::of(&walls),
            netbn::util::stats::Summary::of(&busy),
            netbn::util::stats::Summary::of(&busbw),
        );
        let mut t = Table::new(
            format!("recorded feedback: {} steps from {path}", records.len()),
            &["signal", "mean", "std", "min", "max"],
        );
        let fmt_s = netbn::util::fmt::secs;
        t.row(vec!["step wall".into(), fmt_s(w.mean), fmt_s(w.std), fmt_s(w.min), fmt_s(w.max)]);
        t.row(vec!["comm busy".into(), fmt_s(b.mean), fmt_s(b.std), fmt_s(b.min), fmt_s(b.max)]);
        t.row(vec![
            "bus bandwidth".into(),
            format!("{:.3} Gbps", bw.mean),
            format!("{:.3}", bw.std),
            format!("{:.3}", bw.min),
            format!("{:.3}", bw.max),
        ]);
        println!("{}", t.render());
        println!(
            "comm-busy fraction of the step: {:.1}% — {}",
            100.0 * b.mean / w.mean.max(1e-12),
            if b.mean > 0.5 * w.mean {
                "communication-bound; the oracle below is worth consulting"
            } else {
                "mostly hidden under compute"
            }
        );
        json.push_str(&format!(
            "\"trace\":{{\"steps\":{},\"wall_mean_s\":{},\"wall_std_s\":{},\
             \"comm_busy_mean_s\":{},\"busbw_mean_gbps\":{}}}",
            records.len(),
            w.mean,
            w.std,
            b.mean,
            bw.mean
        ));
    }

    if oracle {
        let model_s = args.get_or("model", "resnet50");
        let model = netbn::models::ModelId::parse(model_s)
            .ok_or_else(|| anyhow::anyhow!("--model: unknown model {model_s:?}"))?;
        let servers = args.get_usize("servers", 8)?;
        let gpus = args.get_usize("gpus", 8)?;
        anyhow::ensure!(servers >= 1 && gpus >= 1, "--servers and --gpus must be >= 1");
        let bws = args.get_f64_list("bandwidths", &[1.0, 10.0, 25.0, 100.0])?;
        let env = OracleEnv::new(model, servers, gpus);
        let mut t = Table::new(
            format!(
                "oracle: best of {} knob points ({model}, {servers}x{gpus})",
                space.len()
            ),
            &["Gbps", "best step", "static step", "speedup", "best knobs"],
        );
        let static_point = netbn::tune::KnobPoint::default_static();
        if !json.ends_with('{') {
            json.push(',');
        }
        json.push_str("\"oracle\":[");
        for (i, &bw) in bws.iter().enumerate() {
            anyhow::ensure!(bw > 0.0, "--bandwidths entries must be > 0");
            let (best, best_t) = env.best(bw, &space);
            let static_t = env.step_time_s(bw, &static_point);
            t.row(vec![
                format!("{bw}"),
                netbn::util::fmt::secs(best_t),
                netbn::util::fmt::secs(static_t),
                format!("{:.2}x", static_t / best_t),
                best.spec(),
            ]);
            if i > 0 {
                json.push(',');
            }
            json.push_str(&format!(
                "{{\"gbps\":{bw},\"best_step_s\":{best_t},\"static_step_s\":{static_t},\
                 \"knobs\":{}}}",
                json_str(&best.spec())
            ));
        }
        json.push(']');
        println!("{}", t.render());
    }
    json.push('}');

    match args.get("json") {
        None => {}
        Some("-") => println!("{json}"),
        Some(path) => {
            if let Some(dir) = std::path::Path::new(path).parent() {
                if !dir.as_os_str().is_empty() {
                    std::fs::create_dir_all(dir)?;
                }
            }
            std::fs::write(path, json)?;
            println!("  -> {path}");
        }
    }
    Ok(true)
}

fn cmd_worker(args: &Args) -> Result<bool> {
    let rank = args
        .get("rank")
        .and_then(|s| s.parse::<usize>().ok())
        .ok_or_else(|| anyhow::anyhow!("_worker needs --rank"))?;
    let world = args
        .get("world")
        .and_then(|s| s.parse::<usize>().ok())
        .ok_or_else(|| anyhow::anyhow!("_worker needs --world"))?;
    let coordinator = args
        .get("coordinator")
        .and_then(|s| s.parse::<std::net::SocketAddr>().ok())
        .ok_or_else(|| anyhow::anyhow!("_worker needs --coordinator host:port"))?;
    // Tag this process's log lines with its rank: N interleaved worker
    // stderr streams stay attributable.
    netbn::util::logging::set_identity(format!("rank{rank}"));
    let params = worker_params(args, world)?;
    netbn::trainer::launch::worker_entry(rank, coordinator, &params)?;
    Ok(true)
}

fn cmd_eworker(args: &Args) -> Result<bool> {
    let uid = args
        .get("uid")
        .and_then(|s| s.parse::<u64>().ok())
        .ok_or_else(|| anyhow::anyhow!("_eworker needs --uid"))?;
    let coordinator = args
        .get("coordinator")
        .and_then(|s| s.parse::<std::net::SocketAddr>().ok())
        .ok_or_else(|| anyhow::anyhow!("_eworker needs --coordinator host:port"))?;
    let die_at = args
        .get("die-at")
        .map(|s| {
            s.parse::<usize>()
                .map_err(|_| anyhow::anyhow!("--die-at: expected a step number, got {s:?}"))
        })
        .transpose()?;
    netbn::util::logging::set_identity(format!("uid{uid}"));
    netbn::trainer::elastic::elastic_worker_entry(uid, coordinator, die_at)?;
    Ok(true)
}

fn cmd_bench(registry: &ScenarioRegistry, args: &Args) -> Result<bool> {
    use netbn::engine::bench;
    // The launch probe runs N times so e2e.busbw_gbps carries a measured
    // mean + stddev; the gate for that pair is variance-aware (3σ slack
    // on top of the fractional tolerance).
    // --trend is a pure history gate: it reads what earlier runs appended
    // and never re-measures, so CI can point it at an uploaded artifact.
    if args.has_flag("trend") {
        let store = args.get("store").ok_or_else(|| {
            anyhow::anyhow!("--trend reads <store>/bench_history.jsonl; pass --store <dir>")
        })?;
        let window = args.get_usize("trend-window", bench::TREND_WINDOW)?;
        anyhow::ensure!(window >= 2, "--trend-window must be >= 2, got {window}");
        let trend = bench::evaluate_trend(std::path::Path::new(store), window)?;
        println!("{}", trend.render(window));
        return Ok(trend.ok());
    }
    let e2e_runs = args.get_usize("e2e-runs", 3)?;
    let report = bench::collect_with_e2e(registry, e2e_runs)?;
    println!("{}", report.render());
    if let Some(path) = args.get("json") {
        std::fs::write(path, report.to_json())?;
        println!("  -> {path}");
    }
    // Record the run before gating: a regressed run is exactly the one
    // worth having in the trend line.
    if let Some(store) = args.get("store") {
        let path = bench::append_history(&report, std::path::Path::new(store))?;
        println!("  -> {} (history appended)", path.display());
    }
    let Some(baseline_path) = args.get("compare") else {
        return Ok(true);
    };
    let tolerance = args.get_f64("tolerance", 0.2)?;
    anyhow::ensure!(
        (0.0..1.0).contains(&tolerance),
        "--tolerance must be in [0, 1), got {tolerance}"
    );
    let baseline_raw = std::fs::read_to_string(baseline_path)
        .map_err(|e| anyhow::anyhow!("read baseline {baseline_path}: {e}"))?;
    let baseline = bench::parse_flat_json(&baseline_raw)
        .map_err(|e| anyhow::anyhow!("parse baseline {baseline_path}: {e:#}"))?;
    let cmp = bench::compare(&report.metrics, &baseline, tolerance);
    println!("{}", cmp.render(baseline_path, tolerance));
    Ok(cmp.ok())
}

fn cmd_serve(args: &Args) -> Result<bool> {
    let port = args.get_usize("port", 7070)?;
    anyhow::ensure!(port <= u16::MAX as usize, "--port must fit in 16 bits, got {port}");
    let workers = args.get_usize("workers", 2)?;
    anyhow::ensure!(workers >= 1, "--workers must be >= 1");
    let queue_capacity = args.get_usize("queue-cap", 32)?;
    anyhow::ensure!(queue_capacity >= 1, "--queue-cap must be >= 1");
    let cfg = netbn::serve::ServeConfig {
        port: port as u16,
        workers,
        queue_capacity,
        store_dir: PathBuf::from(args.get_or("store", ".netbn-store")),
    };
    netbn::serve::run_serve(&cfg)?;
    Ok(true)
}

fn cmd_submit(args: &Args) -> Result<bool> {
    use netbn::engine::jobqueue::JobRequest;
    let scenario = args.positional.first().ok_or_else(|| {
        anyhow::anyhow!("usage: netbn submit <scenario> [--param k=v ...] [--host h:p]")
    })?;
    let params = args.get_kv_multi("param")?;
    ensure_unique_keys("param", &params)?;
    let priority = args.get_usize("priority", 5)?;
    anyhow::ensure!(priority <= 9, "--priority must be 0..=9, got {priority}");
    let req = JobRequest { scenario: scenario.clone(), params, priority: priority as u8 };
    let host = args.get_or("host", "127.0.0.1:7070");
    let (status, body) = netbn::serve::http::request(host, "POST", "/jobs", Some(&req.to_json()))?;
    println!("{body}");
    if status != 202 {
        eprintln!("submit rejected: HTTP {status}");
    }
    Ok(status == 202)
}

fn cmd_jobs(args: &Args) -> Result<bool> {
    let host = args.get_or("host", "127.0.0.1:7070");
    let (status, body) = netbn::serve::http::request(host, "GET", "/jobs", None)?;
    anyhow::ensure!(status == 200, "GET /jobs: HTTP {status}: {body}");
    println!("{body}");
    Ok(true)
}

/// Long-poll `/jobs/<id>/feedback`, printing each telemetry sample as it
/// lands, then print the final record. Passes when the job reached
/// `done` (not cancelled/failed).
fn cmd_watch(args: &Args) -> Result<bool> {
    use netbn::util::json;
    let id_s = args
        .positional
        .first()
        .ok_or_else(|| anyhow::anyhow!("usage: netbn watch <job-id> [--host h:p]"))?;
    let id: u64 = id_s
        .parse()
        .map_err(|_| anyhow::anyhow!("job id must be an integer, got {id_s:?}"))?;
    let host = args.get_or("host", "127.0.0.1:7070");
    let mut since = 0u64;
    loop {
        let path = format!("/jobs/{id}/feedback?since={since}&timeout=10");
        let (status, body) = netbn::serve::http::request(host, "GET", &path, None)?;
        anyhow::ensure!(status == 200, "GET {path}: HTTP {status}: {body}");
        // Samples arrive one per line (the daemon formats them that way
        // for exactly this consumer and `curl -N`).
        for line in body.lines() {
            let line = line.trim().trim_end_matches(|c| c == ',' || c == ']');
            if line.starts_with('{') && line.contains("\"step\"") {
                println!("{line}");
            }
        }
        let fields = json::object_fields(&body)?;
        since = json::parse_u64(json::require(&fields, "next")?)?;
        if json::parse_bool(json::require(&fields, "done")?)? {
            break;
        }
    }
    let (status, body) = netbn::serve::http::request(host, "GET", &format!("/jobs/{id}"), None)?;
    anyhow::ensure!(status == 200, "GET /jobs/{id}: HTTP {status}: {body}");
    println!("{body}");
    let state = json::parse_string(json::require(&json::object_fields(&body)?, "state")?)?;
    Ok(state == "done")
}

fn cmd_info() -> Result<bool> {
    let mut t = Table::new(
        "model profiles",
        &["model", "layers", "params", "size", "fwd GFLOPs", "t_batch"],
    );
    for id in [ModelId::ResNet50, ModelId::ResNet101, ModelId::Vgg16, ModelId::Transformer] {
        let p = id.profile();
        t.row(vec![
            id.name().into(),
            p.layers.len().to_string(),
            format!("{:.2}M", p.total_params() as f64 / 1e6),
            netbn::util::fmt::bytes(p.total_bytes() as f64),
            format!("{:.1}", p.total_fwd_flops_per_sample() / 1e9),
            netbn::util::fmt::secs(p.t_batch()),
        ]);
    }
    println!("{}", t.render());
    let m = netbn::net::kernel_tcp::KernelTcpModel::default();
    let mut t2 =
        Table::new("kernel-TCP transport model", &["provisioned Gbps", "effective Gbps", "utilization"]);
    for bw in [1.0, 10.0, 25.0, 50.0, 100.0] {
        t2.row(vec![
            format!("{bw}"),
            format!("{:.1}", m.effective_gbps(bw)),
            netbn::util::fmt::pct(m.utilization(bw)),
        ]);
    }
    println!("{}", t2.render());
    Ok(true)
}
