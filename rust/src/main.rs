//! `netbn` — leader binary: regenerate paper figures, run emulated or real
//! training, calibrate cost tables, validate emulator vs simulator.

use netbn::cli::{App, Args, CmdSpec, OptSpec, Parsed};
use netbn::config::{Compression, ExperimentConfig, TransportKind};
use netbn::models::ModelId;
use netbn::report::Table;
use netbn::Result;
use std::path::PathBuf;

fn app() -> App {
    App {
        name: "netbn",
        about: "reproduction of 'Is Network the Bottleneck of Distributed Training?' (NetAI'20)",
        commands: vec![
            CmdSpec {
                name: "fig",
                about: "regenerate a paper figure (1-8, or 'all')",
                opts: vec![OptSpec {
                    name: "out",
                    help: "CSV output directory",
                    takes_value: true,
                    default: Some("out"),
                }],
                positional: vec![("n", "figure number 1-8 or 'all'")],
            },
            CmdSpec {
                name: "simulate",
                about: "run the what-if simulator at one experiment point",
                opts: vec![
                    OptSpec { name: "model", help: "resnet50|resnet101|vgg16|transformer", takes_value: true, default: Some("resnet50") },
                    OptSpec { name: "workers", help: "GPUs in the all-reduce", takes_value: true, default: Some("64") },
                    OptSpec { name: "bandwidth", help: "provisioned Gbps", takes_value: true, default: Some("100") },
                    OptSpec { name: "transport", help: "full|kernel-tcp", takes_value: true, default: Some("full") },
                    OptSpec { name: "compression", help: "wire-size ratio", takes_value: true, default: Some("1") },
                ],
                positional: vec![],
            },
            CmdSpec {
                name: "emulate",
                about: "run the real-time emulator (modeled compute, shaped fabric)",
                opts: vec![
                    OptSpec { name: "model", help: "resnet50|resnet101|vgg16", takes_value: true, default: Some("resnet50") },
                    OptSpec { name: "servers", help: "server count (1 worker each)", takes_value: true, default: Some("4") },
                    OptSpec { name: "bandwidth", help: "provisioned Gbps", takes_value: true, default: Some("25") },
                    OptSpec { name: "transport", help: "full|kernel-tcp", takes_value: true, default: Some("full") },
                    OptSpec { name: "steps", help: "measured steps", takes_value: true, default: Some("5") },
                    OptSpec { name: "payload-scale", help: "byte/rate shrink factor", takes_value: true, default: Some("256") },
                ],
                positional: vec![],
            },
            CmdSpec {
                name: "validate",
                about: "cross-validate emulator vs simulator (the paper's Fig 6 logic)",
                opts: vec![
                    OptSpec { name: "workers", help: "worker count", takes_value: true, default: Some("4") },
                    OptSpec { name: "bandwidths", help: "comma list of Gbps", takes_value: true, default: Some("5,25,100") },
                ],
                positional: vec![],
            },
            CmdSpec {
                name: "calibrate-add",
                about: "measure AddEst(x) locally and print the table (§3.1)",
                opts: vec![OptSpec {
                    name: "max-elems",
                    help: "largest vector size",
                    takes_value: true,
                    default: Some("4194304"),
                }],
                positional: vec![],
            },
            CmdSpec {
                name: "train",
                about: "e2e: train the AOT transformer over N emulated workers",
                opts: vec![
                    OptSpec { name: "workers", help: "worker count", takes_value: true, default: Some("2") },
                    OptSpec { name: "steps", help: "training steps", takes_value: true, default: Some("20") },
                    OptSpec { name: "batch", help: "batch per worker", takes_value: true, default: Some("4") },
                    OptSpec { name: "lr", help: "learning rate", takes_value: true, default: Some("0.05") },
                    OptSpec { name: "artifacts", help: "artifacts directory", takes_value: true, default: Some("artifacts") },
                ],
                positional: vec![],
            },
            CmdSpec {
                name: "ablate",
                about: "run the ablation sweeps (fusion size/timeout, collectives, bw×compression)",
                opts: vec![
                    OptSpec { name: "model", help: "resnet50|resnet101|vgg16", takes_value: true, default: Some("vgg16") },
                    OptSpec { name: "out", help: "CSV output directory", takes_value: true, default: Some("out") },
                ],
                positional: vec![],
            },
            CmdSpec {
                name: "info",
                about: "print model profiles and environment",
                opts: vec![],
                positional: vec![],
            },
        ],
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&argv) {
        Ok(true) => 0,
        Ok(false) => 1,
        Err(e) => {
            eprintln!("error: {e:#}");
            2
        }
    };
    std::process::exit(code);
}

fn run(argv: &[String]) -> Result<bool> {
    match app().parse(argv)? {
        Parsed::Help(text) => {
            println!("{text}");
            Ok(true)
        }
        Parsed::Command(name, args) => match name.as_str() {
            "fig" => cmd_fig(&args),
            "simulate" => cmd_simulate(&args),
            "emulate" => cmd_emulate(&args),
            "validate" => cmd_validate(&args),
            "calibrate-add" => cmd_calibrate(&args),
            "train" => cmd_train(&args),
            "ablate" => cmd_ablate(&args),
            "info" => cmd_info(),
            other => anyhow::bail!("unhandled command {other}"),
        },
    }
}

fn parse_model(args: &Args) -> Result<ModelId> {
    let s = args.get_or("model", "resnet50");
    ModelId::parse(s).ok_or_else(|| anyhow::anyhow!("unknown model {s:?}"))
}

fn cmd_fig(args: &Args) -> Result<bool> {
    let out = PathBuf::from(args.get_or("out", "out"));
    let which = args.positional.first().map(|s| s.as_str()).unwrap_or("all");
    let ids: Vec<&str> = if which == "all" {
        netbn::figures::FIGURE_IDS.to_vec()
    } else {
        vec![which]
    };
    let mut all_ok = true;
    for id in ids {
        let run = netbn::figures::run_figure(id)?;
        all_ok &= run.emit(&out)?;
    }
    Ok(all_ok)
}

fn cmd_simulate(args: &Args) -> Result<bool> {
    use netbn::models::timing::backward_trace;
    use netbn::sim::{simulate, SimParams};
    let model = parse_model(args)?;
    let workers = args.get_usize("workers", 64)?;
    let bw = args.get_f64("bandwidth", 100.0)?;
    let transport = TransportKind::parse(args.get_or("transport", "full"))
        .ok_or_else(|| anyhow::anyhow!("bad transport"))?;
    let ratio = args.get_f64("compression", 1.0)?;
    let trace = backward_trace(&model.profile());
    let gpus = 8.min(workers.max(1));
    let servers = (workers / gpus).max(1);
    let mut p = match transport {
        TransportKind::KernelTcp => SimParams::horovod_like(trace, servers, gpus, bw),
        _ => SimParams::whatif(trace, servers, gpus, bw),
    };
    p.compression_ratio = ratio;
    let r = simulate(&p);
    let mut t = Table::new(
        format!("what-if: {model}, {workers} workers, {bw} Gbps, {transport}, {ratio}x"),
        &["metric", "value"],
    );
    t.row(vec!["t_batch".into(), netbn::util::fmt::secs(r.t_batch)]);
    t.row(vec!["t_back".into(), netbn::util::fmt::secs(r.t_back)]);
    t.row(vec!["t_sync".into(), netbn::util::fmt::secs(r.t_sync)]);
    t.row(vec!["t_overhead".into(), netbn::util::fmt::secs(r.t_overhead)]);
    t.row(vec!["scaling factor".into(), netbn::util::fmt::pct(r.scaling_factor)]);
    t.row(vec!["buckets".into(), r.buckets.to_string()]);
    t.row(vec!["wire bytes/worker".into(), netbn::util::fmt::bytes(r.wire_bytes_per_worker)]);
    t.row(vec!["achieved rate".into(), format!("{:.2} Gbps", r.achieved_gbps)]);
    println!("{}", t.render());
    Ok(true)
}

fn cmd_emulate(args: &Args) -> Result<bool> {
    use netbn::trainer::{run_emulated, EmulatedRunConfig};
    let model = parse_model(args)?;
    let servers = args.get_usize("servers", 4)?;
    let bw = args.get_f64("bandwidth", 25.0)?;
    let steps = args.get_usize("steps", 5)?;
    let payload_scale = args.get_f64("payload-scale", 256.0)?;
    let transport = TransportKind::parse(args.get_or("transport", "full"))
        .ok_or_else(|| anyhow::anyhow!("bad transport"))?;
    let exp = ExperimentConfig {
        model,
        servers,
        gpus_per_server: 1,
        bandwidth_gbps: bw,
        transport,
        compression: Compression::None,
        steps,
        warmup_steps: 1,
        ..Default::default()
    };
    let r = run_emulated(&EmulatedRunConfig { exp, payload_scale })?;
    let mut t = Table::new(
        format!("emulated: {model}, {servers} servers, {bw} Gbps, {transport}"),
        &["metric", "value"],
    );
    t.row(vec!["step time".into(), netbn::util::fmt::secs(r.step_time_s)]);
    t.row(vec!["throughput".into(), format!("{:.1} samples/s", r.throughput)]);
    t.row(vec!["scaling factor".into(), netbn::util::fmt::pct(r.scaling_factor)]);
    t.row(vec!["mean compute".into(), netbn::util::fmt::secs(r.mean_compute_s)]);
    t.row(vec!["mean comm wait".into(), netbn::util::fmt::secs(r.mean_comm_wait_s)]);
    t.row(vec!["network utilization".into(), netbn::util::fmt::pct(r.network_utilization)]);
    t.row(vec!["buckets/step".into(), format!("{:.1}", r.buckets_per_step)]);
    println!("{}", t.render());
    Ok(true)
}

fn cmd_validate(args: &Args) -> Result<bool> {
    let workers = args.get_usize("workers", 4)?;
    let bws = args.get_f64_list("bandwidths", &[5.0, 25.0, 100.0])?;
    let mut checks = Vec::new();
    let mut t = Table::new(
        "emulator vs simulator (full-utilization transport)",
        &["model", "Gbps", "emulated sf", "simulated sf"],
    );
    for bw in bws {
        let (e, s, check) = netbn::figures::validate_emulator_against_sim(
            ModelId::ResNet50,
            workers,
            bw,
            1024.0,
        )?;
        t.row(vec!["ResNet50".into(), format!("{bw}"), format!("{e:.3}"), format!("{s:.3}")]);
        checks.push(check);
    }
    println!("{}", t.render());
    let (text, ok) = netbn::report::render_checks(&checks);
    println!("{text}");
    Ok(ok)
}

fn cmd_calibrate(args: &Args) -> Result<bool> {
    let max = args.get_usize("max-elems", 1 << 22)?;
    let add = netbn::models::timing::AddEst::measure_local(max);
    let mut t = Table::new("AddEst(x) measured on this host", &["elements", "seconds"]);
    let mut elems = 1024usize;
    while elems <= max {
        t.row(vec![elems.to_string(), format!("{:.3e}", add.seconds(elems as f64))]);
        elems *= 4;
    }
    println!("{}", t.render());
    let v100 = netbn::models::timing::AddEst::v100();
    println!(
        "reference V100 AddEst(131.75M elems / VGG16) = {:.3} ms",
        v100.seconds(527e6 / 4.0) * 1e3
    );
    Ok(true)
}

fn cmd_train(args: &Args) -> Result<bool> {
    use netbn::net::tcp::TcpFabric;
    use netbn::runtime::DeviceService;
    use netbn::trainer::xla::{load_init_params, ModelMeta, XlaTrainer};
    let workers = args.get_usize("workers", 2)?;
    let steps = args.get_usize("steps", 20)?;
    let batch = args.get_usize("batch", 4)?;
    let lr = args.get_f64("lr", 0.05)? as f32;
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let meta = ModelMeta::load(&dir)?;
    let init = load_init_params(&dir, meta.param_count)?;
    println!(
        "model: {} params over {} tensors, vocab {}, seq {}",
        meta.param_count,
        meta.layers.len(),
        meta.vocab,
        meta.seq
    );
    let svc = DeviceService::start(dir);
    let trainer = XlaTrainer::new(svc.handle(), meta);
    let fabric = TcpFabric::new(workers, None)?;
    let result = trainer.train_distributed(
        &fabric,
        init,
        steps,
        batch,
        lr,
        0xe2e,
        netbn::config::FusionConfig::default(),
    )?;
    println!("loss curve (mean across {} workers):", result.workers);
    for (i, l) in result.loss_curve.iter().enumerate() {
        println!("  step {i:>4}  loss {l:.4}");
    }
    let first = result.loss_curve.first().copied().unwrap_or(0.0);
    let last = result.loss_curve.last().copied().unwrap_or(0.0);
    println!("loss: {first:.4} -> {last:.4}");
    Ok(last < first)
}

fn cmd_ablate(args: &Args) -> Result<bool> {
    let model = parse_model(args)?;
    let out = PathBuf::from(args.get_or("out", "out"));
    for fig in netbn::sim::ablation::all(model) {
        println!("{}", fig.render());
        let path = fig.write_csv(&out)?;
        println!("  -> {}", path.display());
    }
    Ok(true)
}

fn cmd_info() -> Result<bool> {
    let mut t = Table::new(
        "model profiles",
        &["model", "layers", "params", "size", "fwd GFLOPs", "t_batch"],
    );
    for id in [ModelId::ResNet50, ModelId::ResNet101, ModelId::Vgg16, ModelId::Transformer] {
        let p = id.profile();
        t.row(vec![
            id.name().into(),
            p.layers.len().to_string(),
            format!("{:.2}M", p.total_params() as f64 / 1e6),
            netbn::util::fmt::bytes(p.total_bytes() as f64),
            format!("{:.1}", p.total_fwd_flops_per_sample() / 1e9),
            netbn::util::fmt::secs(p.t_batch()),
        ]);
    }
    println!("{}", t.render());
    let m = netbn::net::kernel_tcp::KernelTcpModel::default();
    let mut t2 =
        Table::new("kernel-TCP transport model", &["provisioned Gbps", "effective Gbps", "utilization"]);
    for bw in [1.0, 10.0, 25.0, 50.0, 100.0] {
        t2.row(vec![
            format!("{bw}"),
            format!("{:.1}", m.effective_gbps(bw)),
            netbn::util::fmt::pct(m.utilization(bw)),
        ]);
    }
    println!("{}", t2.render());
    Ok(true)
}
