//! CPU utilization sampling via `/proc/stat` — the instrument behind the
//! paper's Fig 5 ("CPU utilizations while training ... under five
//! different network speeds").

use crate::Result;

/// Aggregate jiffies from the `cpu ` line of `/proc/stat`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CpuTimes {
    pub busy: u64,
    pub idle: u64,
}

/// Parse the aggregate `cpu ` line.
pub fn parse_proc_stat(text: &str) -> Result<CpuTimes> {
    let line = text
        .lines()
        .find(|l| l.starts_with("cpu "))
        .ok_or_else(|| anyhow::anyhow!("no aggregate cpu line in /proc/stat"))?;
    let fields: Vec<u64> =
        line.split_whitespace().skip(1).map(|f| f.parse().unwrap_or(0)).collect();
    anyhow::ensure!(fields.len() >= 4, "short cpu line: {line:?}");
    // user nice system idle iowait irq softirq steal ...
    let idle = fields[3] + fields.get(4).copied().unwrap_or(0);
    let busy: u64 = fields.iter().sum::<u64>() - idle;
    Ok(CpuTimes { busy, idle })
}

/// Samples `/proc/stat` and reports utilization between samples.
pub struct CpuSampler {
    last: CpuTimes,
}

impl CpuSampler {
    pub fn new() -> Result<CpuSampler> {
        Ok(CpuSampler { last: read_now()? })
    }

    /// Utilization (0..=1) since the previous call.
    pub fn sample(&mut self) -> Result<f64> {
        let cur = read_now()?;
        let busy = cur.busy.saturating_sub(self.last.busy);
        let idle = cur.idle.saturating_sub(self.last.idle);
        self.last = cur;
        let total = busy + idle;
        Ok(if total == 0 { 0.0 } else { busy as f64 / total as f64 })
    }
}

fn read_now() -> Result<CpuTimes> {
    let text = std::fs::read_to_string("/proc/stat")?;
    parse_proc_stat(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_line() {
        let t = parse_proc_stat("cpu  100 0 50 800 25 0 5 0 0 0\ncpu0 1 2 3 4\n").unwrap();
        assert_eq!(t.idle, 825);
        assert_eq!(t.busy, 155);
    }

    #[test]
    fn rejects_missing_line() {
        assert!(parse_proc_stat("intr 0 0 0").is_err());
    }

    #[test]
    fn live_sampling_in_unit_interval() {
        let mut s = CpuSampler::new().unwrap();
        // Burn a little CPU so the sample is meaningful.
        let mut x = 0u64;
        for i in 0..2_000_000u64 {
            x = x.wrapping_add(i * i);
        }
        std::hint::black_box(x);
        let u = s.sample().unwrap();
        assert!((0.0..=1.0).contains(&u), "{u}");
    }
}
