//! System measurement: CPU utilization sampling from `/proc/stat` (the
//! paper's Fig 5 instrument), wall-clock phase timers, and the white-box
//! timing trace logger (§3.1's "add logging code to training scripts to
//! retrieve detailed timing information").

pub mod cpu;
pub mod trace;

pub use cpu::CpuSampler;
pub use trace::{TraceLogger, TraceRecord};

use std::time::Instant;

/// A simple two-phase (compute / communicate) stopwatch used by the
/// emulated trainer to report the paper's Fig 2 computation times.
#[derive(Debug, Default, Clone, Copy)]
pub struct PhaseTimes {
    pub compute_s: f64,
    pub comm_s: f64,
    pub steps: u32,
}

impl PhaseTimes {
    pub fn add_compute(&mut self, t: f64) {
        self.compute_s += t;
    }

    pub fn add_comm(&mut self, t: f64) {
        self.comm_s += t;
    }

    pub fn end_step(&mut self) {
        self.steps += 1;
    }

    pub fn mean_compute(&self) -> f64 {
        if self.steps == 0 { 0.0 } else { self.compute_s / self.steps as f64 }
    }

    pub fn mean_comm(&self) -> f64 {
        if self.steps == 0 { 0.0 } else { self.comm_s / self.steps as f64 }
    }
}

/// Measure the wall time of `f`.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_times_average() {
        let mut p = PhaseTimes::default();
        p.add_compute(1.0);
        p.add_comm(0.5);
        p.end_step();
        p.add_compute(3.0);
        p.add_comm(1.5);
        p.end_step();
        assert!((p.mean_compute() - 2.0).abs() < 1e-12);
        assert!((p.mean_comm() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn timed_returns_value_and_duration() {
        let (v, t) = timed(|| {
            std::thread::sleep(std::time::Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(t >= 0.004);
    }
}
