//! White-box timing trace: JSON-lines records of gradient-ready /
//! bucket-emitted / all-reduce-done events, written by the emulated
//! trainer and replayable into the what-if simulator — the closed loop
//! the paper builds between measurement and simulation.

use crate::report::json_str;
use crate::Result;
use std::io::{BufRead, Write};
use std::path::Path;

/// One trace record.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRecord {
    /// Event kind: `grad_ready`, `bucket_emit`, `allreduce_done`, `step`.
    pub kind: String,
    pub step: u32,
    pub worker: usize,
    /// Layer index or bucket seq (kind-dependent).
    pub id: usize,
    pub bytes: usize,
    /// Seconds since trace start.
    pub t: f64,
}

/// Extract the raw text of one `"key":value` field from a JSONL record
/// line (shared by [`TraceRecord`] and [`StepFeedbackRecord`]).
fn json_field<'a>(line: &'a str, key: &str) -> Result<&'a str> {
    let pat = format!("\"{key}\":");
    let start = line
        .find(&pat)
        .ok_or_else(|| anyhow::anyhow!("missing key {key} in {line:?}"))?
        + pat.len();
    let rest = line[start..].trim_start();
    // String values may contain `,` / `}` (and escaped quotes), so scan
    // them escape-aware to the closing quote instead of stopping at the
    // first delimiter.
    if rest.as_bytes().first() == Some(&b'"') {
        let b = rest.as_bytes();
        let mut j = 1;
        while j < b.len() {
            match b[j] {
                b'\\' => j += 2,
                b'"' => return Ok(&rest[..=j]),
                _ => j += 1,
            }
        }
        anyhow::bail!("unterminated string value for {key}");
    }
    let end = rest
        .find([',', '}'])
        .ok_or_else(|| anyhow::anyhow!("unterminated value for {key}"))?;
    Ok(rest[..end].trim())
}

impl TraceRecord {
    pub fn to_json_line(&self) -> String {
        format!(
            "{{\"kind\":{},\"step\":{},\"worker\":{},\"id\":{},\"bytes\":{},\"t\":{}}}",
            json_str(&self.kind),
            self.step,
            self.worker,
            self.id,
            self.bytes,
            self.t
        )
    }

    /// Parse a record from the exact format `to_json_line` writes. Extra
    /// keys (e.g. a `step_feedback` record's timing fields) are ignored,
    /// so one reader loop handles mixed traces.
    pub fn from_json_line(line: &str) -> Result<TraceRecord> {
        let get = |key: &str| json_field(line, key);
        let kind_raw = get("kind")?;
        let kind = kind_raw.trim_matches('"').to_string();
        Ok(TraceRecord {
            kind,
            step: get("step")?.parse()?,
            worker: get("worker")?.parse()?,
            id: get("id")?.parse()?,
            bytes: get("bytes")?.parse()?,
            t: get("t")?.parse()?,
        })
    }
}

/// The `kind` string of a per-step feedback record.
pub const STEP_FEEDBACK_KIND: &str = "step_feedback";

/// One step's timing summary in a trace — the record kind that lets
/// traces captured today drive `netbn tune --from-trace` later. The JSON
/// line carries the standard `id`/`bytes`/`t` fields too (`t` = wall
/// seconds), so a generic [`TraceRecord`] reader parses it unchanged.
#[derive(Clone, Debug, PartialEq)]
pub struct StepFeedbackRecord {
    pub step: u32,
    pub worker: usize,
    /// Wall-clock seconds of the whole step.
    pub wall_s: f64,
    /// Seconds of the compute/emission phase.
    pub compute_s: f64,
    /// Seconds the collective engine was busy.
    pub comm_busy_s: f64,
    /// Effective bus bandwidth, Gbps (0 when unknown).
    pub busbw_gbps: f64,
}

impl StepFeedbackRecord {
    pub fn to_json_line(&self) -> String {
        format!(
            "{{\"kind\":{},\"step\":{},\"worker\":{},\"id\":0,\"bytes\":0,\"t\":{},\
             \"compute_s\":{},\"comm_busy_s\":{},\"busbw_gbps\":{}}}",
            json_str(STEP_FEEDBACK_KIND),
            self.step,
            self.worker,
            self.wall_s,
            self.compute_s,
            self.comm_busy_s,
            self.busbw_gbps
        )
    }

    /// Parse the exact format `to_json_line` writes; rejects lines of any
    /// other kind.
    pub fn from_json_line(line: &str) -> Result<StepFeedbackRecord> {
        let kind = json_field(line, "kind")?.trim_matches('"');
        anyhow::ensure!(
            kind == STEP_FEEDBACK_KIND,
            "expected a {STEP_FEEDBACK_KIND} record, got kind {kind:?}"
        );
        Ok(StepFeedbackRecord {
            step: json_field(line, "step")?.parse()?,
            worker: json_field(line, "worker")?.parse()?,
            wall_s: json_field(line, "t")?.parse()?,
            compute_s: json_field(line, "compute_s")?.parse()?,
            comm_busy_s: json_field(line, "comm_busy_s")?.parse()?,
            busbw_gbps: json_field(line, "busbw_gbps")?.parse()?,
        })
    }
}

/// Load every `step_feedback` record from a (possibly mixed) trace file,
/// in file order.
pub fn load_step_feedback(path: &Path) -> Result<Vec<StepFeedbackRecord>> {
    let f = std::fs::File::open(path)?;
    let mut out = Vec::new();
    for line in std::io::BufReader::new(f).lines() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        if json_field(trimmed, "kind")?.trim_matches('"') == STEP_FEEDBACK_KIND {
            out.push(StepFeedbackRecord::from_json_line(trimmed)?);
        }
    }
    Ok(out)
}

/// Appending JSONL writer.
pub struct TraceLogger {
    out: std::io::BufWriter<std::fs::File>,
    start: std::time::Instant,
}

impl TraceLogger {
    pub fn create(path: &Path) -> Result<TraceLogger> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        Ok(TraceLogger {
            out: std::io::BufWriter::new(std::fs::File::create(path)?),
            start: std::time::Instant::now(),
        })
    }

    /// Seconds since logger creation — the `t` to put into records.
    pub fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn log(&mut self, rec: &TraceRecord) -> Result<()> {
        writeln!(self.out, "{}", rec.to_json_line())?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// Load a trace file.
pub fn load_trace(path: &Path) -> Result<Vec<TraceRecord>> {
    let f = std::fs::File::open(path)?;
    let mut out = Vec::new();
    for line in std::io::BufReader::new(f).lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        out.push(TraceRecord::from_json_line(&line)?);
    }
    Ok(out)
}

/// Convert recorded `grad_ready` events of one worker+step into a
/// [`crate::models::timing::StepTrace`] the simulator can consume —
/// closing the measure→simulate loop on *real* traces.
pub fn step_trace_from_records(
    records: &[TraceRecord],
    worker: usize,
    step: u32,
    t_forward: f64,
) -> Option<crate::models::timing::StepTrace> {
    let mut events: Vec<crate::models::timing::TraceEvent> = records
        .iter()
        .filter(|r| r.kind == "grad_ready" && r.worker == worker && r.step == step)
        .map(|r| crate::models::timing::TraceEvent { layer: r.id, bytes: r.bytes, t_ready: r.t })
        .collect();
    if events.is_empty() {
        return None;
    }
    // Normalize to backward start.
    let t0 = events.iter().map(|e| e.t_ready).fold(f64::INFINITY, f64::min);
    for e in &mut events {
        e.t_ready -= t0;
    }
    events.sort_by(|a, b| a.t_ready.partial_cmp(&b.t_ready).unwrap());
    let t_backward = events.last().map(|e| e.t_ready).unwrap_or(0.0);
    Some(crate::models::timing::StepTrace {
        t_forward,
        t_backward,
        t_batch: t_forward + t_backward,
        events,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec() -> TraceRecord {
        TraceRecord { kind: "grad_ready".into(), step: 3, worker: 1, id: 17, bytes: 4096, t: 0.125 }
    }

    #[test]
    fn json_line_round_trip() {
        let r = rec();
        let parsed = TraceRecord::from_json_line(&r.to_json_line()).unwrap();
        assert_eq!(parsed, r);
    }

    #[test]
    fn file_round_trip() {
        let path = std::env::temp_dir().join("netbn_trace_test.jsonl");
        {
            let mut l = TraceLogger::create(&path).unwrap();
            l.log(&rec()).unwrap();
            let mut r2 = rec();
            r2.step = 4;
            l.log(&r2).unwrap();
            l.flush().unwrap();
        }
        let back = load_trace(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0], rec());
        assert_eq!(back[1].step, 4);
    }

    #[test]
    fn records_to_step_trace() {
        let records = vec![
            TraceRecord { kind: "grad_ready".into(), step: 0, worker: 0, id: 2, bytes: 100, t: 1.10 },
            TraceRecord { kind: "grad_ready".into(), step: 0, worker: 0, id: 1, bytes: 200, t: 1.20 },
            TraceRecord { kind: "grad_ready".into(), step: 0, worker: 1, id: 2, bytes: 100, t: 9.0 },
            TraceRecord { kind: "bucket_emit".into(), step: 0, worker: 0, id: 0, bytes: 300, t: 1.25 },
        ];
        let st = step_trace_from_records(&records, 0, 0, 0.5).unwrap();
        assert_eq!(st.events.len(), 2);
        assert_eq!(st.events[0].t_ready, 0.0);
        assert!((st.events[1].t_ready - 0.1).abs() < 1e-9);
        assert!((st.t_batch - 0.6).abs() < 1e-9);
        assert!(step_trace_from_records(&records, 5, 0, 0.5).is_none());
    }

    #[test]
    fn malformed_line_is_error() {
        assert!(TraceRecord::from_json_line("{\"nope\":1}").is_err());
        assert!(TraceRecord::from_json_line("{\"kind\":\"unterminated").is_err());
    }

    #[test]
    fn string_values_containing_delimiters_round_trip() {
        // Regression: the value scan used to stop at the first `,` or `}`
        // even inside a quoted string, so a kind like this truncated to
        // `"a` and every later field shifted.
        let mut r = rec();
        r.kind = "a,}b".into();
        let line = r.to_json_line();
        let parsed = TraceRecord::from_json_line(&line).unwrap();
        assert_eq!(parsed, r);
        assert_eq!(parsed.bytes, 4096);
        // Escaped quotes inside the string survive too.
        let line = "{\"kind\":\"x\\\",}y\",\"step\":1,\"worker\":0,\"id\":0,\"bytes\":9,\"t\":0.5}";
        let parsed = TraceRecord::from_json_line(line).unwrap();
        assert_eq!(parsed.bytes, 9);
    }

    fn feedback_rec() -> StepFeedbackRecord {
        StepFeedbackRecord {
            step: 7,
            worker: 1,
            wall_s: 0.125,
            compute_s: 0.08,
            comm_busy_s: 0.03,
            busbw_gbps: 12.5,
        }
    }

    #[test]
    fn step_feedback_round_trip() {
        let r = feedback_rec();
        let line = r.to_json_line();
        assert_eq!(StepFeedbackRecord::from_json_line(&line).unwrap(), r);
        // Wrong kind is rejected.
        assert!(StepFeedbackRecord::from_json_line(&rec().to_json_line()).is_err());
        // A generic TraceRecord reader consumes the same line (t = wall).
        let generic = TraceRecord::from_json_line(&line).unwrap();
        assert_eq!(generic.kind, STEP_FEEDBACK_KIND);
        assert_eq!(generic.step, 7);
        assert!((generic.t - 0.125).abs() < 1e-12);
    }

    #[test]
    fn step_feedback_file_round_trip_in_a_mixed_trace() {
        let path = std::env::temp_dir().join("netbn_step_feedback_test.jsonl");
        {
            let mut l = TraceLogger::create(&path).unwrap();
            l.log(&rec()).unwrap(); // a grad_ready record interleaves
            l.flush().unwrap();
        }
        {
            use std::io::Write as _;
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            writeln!(f, "{}", feedback_rec().to_json_line()).unwrap();
            let mut second = feedback_rec();
            second.step = 8;
            writeln!(f, "{}", second.to_json_line()).unwrap();
        }
        let fb = load_step_feedback(&path).unwrap();
        assert_eq!(fb.len(), 2);
        assert_eq!(fb[0], feedback_rec());
        assert_eq!(fb[1].step, 8);
        // The generic loader still reads the whole mixed file.
        assert_eq!(load_trace(&path).unwrap().len(), 3);
    }
}
