//! White-box timing trace: JSON-lines records of gradient-ready /
//! bucket-emitted / all-reduce-done events, written by the emulated
//! trainer and replayable into the what-if simulator — the closed loop
//! the paper builds between measurement and simulation.

use crate::report::json_str;
use crate::Result;
use std::io::{BufRead, Write};
use std::path::Path;

/// One trace record.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceRecord {
    /// Event kind: `grad_ready`, `bucket_emit`, `allreduce_done`, `step`.
    pub kind: String,
    pub step: u32,
    pub worker: usize,
    /// Layer index or bucket seq (kind-dependent).
    pub id: usize,
    pub bytes: usize,
    /// Seconds since trace start.
    pub t: f64,
}

impl TraceRecord {
    pub fn to_json_line(&self) -> String {
        format!(
            "{{\"kind\":{},\"step\":{},\"worker\":{},\"id\":{},\"bytes\":{},\"t\":{}}}",
            json_str(&self.kind),
            self.step,
            self.worker,
            self.id,
            self.bytes,
            self.t
        )
    }

    /// Parse a record from the exact format `to_json_line` writes.
    pub fn from_json_line(line: &str) -> Result<TraceRecord> {
        let get = |key: &str| -> Result<&str> {
            let pat = format!("\"{key}\":");
            let start = line
                .find(&pat)
                .ok_or_else(|| anyhow::anyhow!("missing key {key} in {line:?}"))?
                + pat.len();
            let rest = &line[start..];
            let end = rest
                .find([',', '}'])
                .ok_or_else(|| anyhow::anyhow!("unterminated value for {key}"))?;
            Ok(rest[..end].trim())
        };
        let kind_raw = get("kind")?;
        let kind = kind_raw.trim_matches('"').to_string();
        Ok(TraceRecord {
            kind,
            step: get("step")?.parse()?,
            worker: get("worker")?.parse()?,
            id: get("id")?.parse()?,
            bytes: get("bytes")?.parse()?,
            t: get("t")?.parse()?,
        })
    }
}

/// Appending JSONL writer.
pub struct TraceLogger {
    out: std::io::BufWriter<std::fs::File>,
    start: std::time::Instant,
}

impl TraceLogger {
    pub fn create(path: &Path) -> Result<TraceLogger> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        Ok(TraceLogger {
            out: std::io::BufWriter::new(std::fs::File::create(path)?),
            start: std::time::Instant::now(),
        })
    }

    /// Seconds since logger creation — the `t` to put into records.
    pub fn now(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn log(&mut self, rec: &TraceRecord) -> Result<()> {
        writeln!(self.out, "{}", rec.to_json_line())?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

/// Load a trace file.
pub fn load_trace(path: &Path) -> Result<Vec<TraceRecord>> {
    let f = std::fs::File::open(path)?;
    let mut out = Vec::new();
    for line in std::io::BufReader::new(f).lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        out.push(TraceRecord::from_json_line(&line)?);
    }
    Ok(out)
}

/// Convert recorded `grad_ready` events of one worker+step into a
/// [`crate::models::timing::StepTrace`] the simulator can consume —
/// closing the measure→simulate loop on *real* traces.
pub fn step_trace_from_records(
    records: &[TraceRecord],
    worker: usize,
    step: u32,
    t_forward: f64,
) -> Option<crate::models::timing::StepTrace> {
    let mut events: Vec<crate::models::timing::TraceEvent> = records
        .iter()
        .filter(|r| r.kind == "grad_ready" && r.worker == worker && r.step == step)
        .map(|r| crate::models::timing::TraceEvent { layer: r.id, bytes: r.bytes, t_ready: r.t })
        .collect();
    if events.is_empty() {
        return None;
    }
    // Normalize to backward start.
    let t0 = events.iter().map(|e| e.t_ready).fold(f64::INFINITY, f64::min);
    for e in &mut events {
        e.t_ready -= t0;
    }
    events.sort_by(|a, b| a.t_ready.partial_cmp(&b.t_ready).unwrap());
    let t_backward = events.last().map(|e| e.t_ready).unwrap_or(0.0);
    Some(crate::models::timing::StepTrace {
        t_forward,
        t_backward,
        t_batch: t_forward + t_backward,
        events,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec() -> TraceRecord {
        TraceRecord { kind: "grad_ready".into(), step: 3, worker: 1, id: 17, bytes: 4096, t: 0.125 }
    }

    #[test]
    fn json_line_round_trip() {
        let r = rec();
        let parsed = TraceRecord::from_json_line(&r.to_json_line()).unwrap();
        assert_eq!(parsed, r);
    }

    #[test]
    fn file_round_trip() {
        let path = std::env::temp_dir().join("netbn_trace_test.jsonl");
        {
            let mut l = TraceLogger::create(&path).unwrap();
            l.log(&rec()).unwrap();
            let mut r2 = rec();
            r2.step = 4;
            l.log(&r2).unwrap();
            l.flush().unwrap();
        }
        let back = load_trace(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0], rec());
        assert_eq!(back[1].step, 4);
    }

    #[test]
    fn records_to_step_trace() {
        let records = vec![
            TraceRecord { kind: "grad_ready".into(), step: 0, worker: 0, id: 2, bytes: 100, t: 1.10 },
            TraceRecord { kind: "grad_ready".into(), step: 0, worker: 0, id: 1, bytes: 200, t: 1.20 },
            TraceRecord { kind: "grad_ready".into(), step: 0, worker: 1, id: 2, bytes: 100, t: 9.0 },
            TraceRecord { kind: "bucket_emit".into(), step: 0, worker: 0, id: 0, bytes: 300, t: 1.25 },
        ];
        let st = step_trace_from_records(&records, 0, 0, 0.5).unwrap();
        assert_eq!(st.events.len(), 2);
        assert_eq!(st.events[0].t_ready, 0.0);
        assert!((st.events[1].t_ready - 0.1).abs() < 1e-9);
        assert!((st.t_batch - 0.6).abs() < 1e-9);
        assert!(step_trace_from_records(&records, 5, 0, 0.5).is_none());
    }

    #[test]
    fn malformed_line_is_error() {
        assert!(TraceRecord::from_json_line("{\"nope\":1}").is_err());
    }
}
