//! Model profiles: per-layer parameter counts and FLOPs for the paper's
//! three workloads — ResNet50, ResNet101, VGG16 — generated from the real
//! architectures (not hard-coded totals), plus a transformer profile for
//! the e2e example. The what-if simulator consumes these through
//! [`timing`], which turns FLOPs into V100-calibrated per-layer
//! *gradient-computation-done* traces (the paper's white-box logs).

pub mod resnet;
pub mod timing;
pub mod transformer;
pub mod vgg;

/// The workloads.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelId {
    ResNet50,
    ResNet101,
    Vgg16,
    /// The e2e transformer (trained for real through the XLA runtime).
    Transformer,
}

impl ModelId {
    pub fn parse(s: &str) -> Option<ModelId> {
        match s.to_ascii_lowercase().as_str() {
            "resnet50" | "rn50" => Some(ModelId::ResNet50),
            "resnet101" | "rn101" => Some(ModelId::ResNet101),
            "vgg16" | "vgg" => Some(ModelId::Vgg16),
            "transformer" | "tfm" => Some(ModelId::Transformer),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ModelId::ResNet50 => "ResNet50",
            ModelId::ResNet101 => "ResNet101",
            ModelId::Vgg16 => "VGG16",
            ModelId::Transformer => "Transformer",
        }
    }

    /// The three models of the paper's evaluation.
    pub fn paper_models() -> [ModelId; 3] {
        [ModelId::ResNet50, ModelId::ResNet101, ModelId::Vgg16]
    }

    /// Build the layer profile.
    pub fn profile(&self) -> ModelProfile {
        match self {
            ModelId::ResNet50 => resnet::resnet_profile(50),
            ModelId::ResNet101 => resnet::resnet_profile(101),
            ModelId::Vgg16 => vgg::vgg16_profile(),
            ModelId::Transformer => transformer::transformer_profile(),
        }
    }
}

impl std::fmt::Display for ModelId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One learnable layer (as the training framework's gradient hooks see it:
/// a parameter tensor that becomes ready during backward).
#[derive(Clone, Debug, PartialEq)]
pub struct LayerProfile {
    pub name: String,
    /// Learnable parameter count.
    pub params: usize,
    /// Forward FLOPs for one sample (batch multiplies this).
    pub fwd_flops_per_sample: f64,
}

impl LayerProfile {
    /// Gradient bytes (f32).
    pub fn grad_bytes(&self) -> usize {
        self.params * 4
    }
}

/// A whole model: layers in forward order + single-device calibration.
#[derive(Clone, Debug)]
pub struct ModelProfile {
    pub id: ModelId,
    pub layers: Vec<LayerProfile>,
    /// Calibrated single-V100 training throughput at the paper's batch
    /// size (32), images (or sequences) per second. Sets the absolute time
    /// scale; the per-layer split is by FLOPs.
    pub base_throughput_per_sec: f64,
    pub batch_size: usize,
}

impl ModelProfile {
    pub fn total_params(&self) -> usize {
        self.layers.iter().map(|l| l.params).sum()
    }

    /// Model size in bytes (f32 params) — the paper's `S`.
    pub fn total_bytes(&self) -> usize {
        self.total_params() * 4
    }

    pub fn total_fwd_flops_per_sample(&self) -> f64 {
        self.layers.iter().map(|l| l.fwd_flops_per_sample).sum()
    }

    /// Single-device time for one batch (forward + backward), seconds —
    /// the paper's `t_batch`.
    pub fn t_batch(&self) -> f64 {
        self.batch_size as f64 / self.base_throughput_per_sec
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_sizes_match_paper() {
        // Paper §2.1: "The model sizes are 97 MB for ResNet50, 170 MB for
        // ResNet101, and 527 MB for VGG16."
        let mb = |id: ModelId| id.profile().total_bytes() as f64 / 1e6;
        let rn50 = mb(ModelId::ResNet50);
        let rn101 = mb(ModelId::ResNet101);
        let vgg = mb(ModelId::Vgg16);
        assert!((rn50 - 97.0).abs() < 7.0, "ResNet50 {rn50} MB");
        assert!((rn101 - 170.0).abs() < 10.0, "ResNet101 {rn101} MB");
        assert!((vgg - 527.0).abs() < 30.0, "VGG16 {vgg} MB");
    }

    #[test]
    fn vgg_has_the_400mb_layer() {
        // Paper: "VGG16 has a layer with 400MB parameters".
        let p = ModelId::Vgg16.profile();
        let max_layer = p.layers.iter().map(|l| l.grad_bytes()).max().unwrap();
        assert!(
            (380e6..=430e6).contains(&(max_layer as f64)),
            "largest VGG16 layer = {} bytes",
            max_layer
        );
    }

    #[test]
    fn resnet_params_spread_more_evenly() {
        // Paper: "parameters in ResNet series are distributed more evenly".
        let frac_max = |id: ModelId| {
            let p = id.profile();
            let mx = p.layers.iter().map(|l| l.params).max().unwrap() as f64;
            mx / p.total_params() as f64
        };
        assert!(frac_max(ModelId::ResNet50) < 0.12);
        assert!(frac_max(ModelId::Vgg16) > 0.7);
    }

    #[test]
    fn parse_and_display() {
        assert_eq!(ModelId::parse("vgg16"), Some(ModelId::Vgg16));
        assert_eq!(ModelId::parse("RESNET101"), Some(ModelId::ResNet101));
        assert_eq!(ModelId::parse("x"), None);
        assert_eq!(ModelId::Vgg16.to_string(), "VGG16");
    }

    #[test]
    fn t_batch_reasonable() {
        // Single V100 step times in the tens-to-hundreds of ms.
        for id in ModelId::paper_models() {
            let t = id.profile().t_batch();
            assert!((0.02..0.5).contains(&t), "{id}: {t}");
        }
    }
}
