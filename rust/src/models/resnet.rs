//! ResNet-50/101 layer generation (He et al., CVPR'16), bottleneck
//! variant, ImageNet configuration (224×224 input, 1000 classes).
//!
//! Layers are produced in forward order with real parameter counts and
//! per-sample FLOPs, so the profile totals must land on the paper's
//! quoted sizes (97 MB / 170 MB) without any hand-tuned constants.

use super::{LayerProfile, ModelId, ModelProfile};

/// Conv2d parameter count (no bias, as in torchvision ResNet).
fn conv_params(k: usize, c_in: usize, c_out: usize) -> usize {
    k * k * c_in * c_out
}

/// Conv2d forward FLOPs per sample: 2 · K² · C_in · C_out · H_out · W_out.
fn conv_flops(k: usize, c_in: usize, c_out: usize, h_out: usize, w_out: usize) -> f64 {
    2.0 * (k * k * c_in * c_out * h_out * w_out) as f64
}

/// BatchNorm: weight + bias per channel.
fn bn_params(c: usize) -> usize {
    2 * c
}

struct Builder {
    layers: Vec<LayerProfile>,
}

impl Builder {
    fn conv_bn(&mut self, name: &str, k: usize, c_in: usize, c_out: usize, h: usize, w: usize) {
        self.layers.push(LayerProfile {
            name: format!("{name}.conv"),
            params: conv_params(k, c_in, c_out),
            fwd_flops_per_sample: conv_flops(k, c_in, c_out, h, w),
        });
        self.layers.push(LayerProfile {
            name: format!("{name}.bn"),
            params: bn_params(c_out),
            // 4 ops per output element (normalize, scale, shift, running stats).
            fwd_flops_per_sample: 4.0 * (c_out * h * w) as f64,
        });
    }

    /// One bottleneck block: 1×1 reduce → 3×3 → 1×1 expand (+ optional
    /// projection shortcut). `h`/`w` are the block's *output* spatial dims.
    fn bottleneck(
        &mut self,
        name: &str,
        c_in: usize,
        mid: usize,
        stride: usize,
        h: usize,
        w: usize,
    ) {
        let c_out = mid * 4;
        // conv1 (1×1) runs at input resolution / stride applied at conv2
        // (torchvision v1.5+ puts stride on the 3×3).
        let (h_in, w_in) = (h * stride, w * stride);
        self.conv_bn(&format!("{name}.conv1"), 1, c_in, mid, h_in, w_in);
        self.conv_bn(&format!("{name}.conv2"), 3, mid, mid, h, w);
        self.conv_bn(&format!("{name}.conv3"), 1, mid, c_out, h, w);
        if stride != 1 || c_in != c_out {
            self.conv_bn(&format!("{name}.downsample"), 1, c_in, c_out, h, w);
        }
    }
}

/// Build the profile for ResNet-`depth` (50 or 101).
pub fn resnet_profile(depth: usize) -> ModelProfile {
    let (blocks, id, throughput) = match depth {
        // Stage block counts and calibrated single-V100 throughput
        // (images/s, batch 32, fp32, paper-era cuDNN).
        50 => ([3usize, 4, 6, 3], ModelId::ResNet50, 360.0),
        101 => ([3, 4, 23, 3], ModelId::ResNet101, 235.0),
        other => panic!("unsupported ResNet depth {other}"),
    };
    let mut b = Builder { layers: Vec::new() };

    // Stem: 7×7/2 conv, 64 channels, output 112×112 (then 3×3/2 maxpool → 56).
    b.conv_bn("stem", 7, 3, 64, 112, 112);

    // Stages: (mid channels, output spatial size).
    let stage_cfg = [(64usize, 56usize), (128, 28), (256, 14), (512, 7)];
    let mut c_in = 64;
    for (s, ((mid, hw), n_blocks)) in stage_cfg.iter().zip(blocks.iter()).enumerate() {
        for blk in 0..*n_blocks {
            // First block of stages 2–4 downsamples (stride 2); stage 1's
            // first block only projects channels.
            let stride = if blk == 0 && s > 0 { 2 } else { 1 };
            b.bottleneck(&format!("layer{}.{}", s + 1, blk), c_in, *mid, stride, *hw, *hw);
            c_in = mid * 4;
        }
    }

    // Classifier head.
    b.layers.push(LayerProfile {
        name: "fc".into(),
        params: 2048 * 1000 + 1000,
        fwd_flops_per_sample: 2.0 * 2048.0 * 1000.0,
    });

    ModelProfile { id, layers: b.layers, base_throughput_per_sec: throughput, batch_size: 32 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet50_parameter_count() {
        // torchvision resnet50: 25,557,032 params.
        let p = resnet_profile(50);
        let total = p.total_params();
        assert!(
            (25_000_000..=26_100_000).contains(&total),
            "ResNet50 params = {total}"
        );
    }

    #[test]
    fn resnet101_parameter_count() {
        // torchvision resnet101: 44,549,160 params.
        let p = resnet_profile(101);
        let total = p.total_params();
        assert!(
            (43_900_000..=45_200_000).contains(&total),
            "ResNet101 params = {total}"
        );
    }

    #[test]
    fn resnet50_flops_about_8_gflops() {
        // Published "4.1 GFLOPs" counts multiply-adds (MACs); at 2 FLOPs
        // per MAC the forward pass is ≈ 8.2 GFLOPs.
        let p = resnet_profile(50);
        let gf = p.total_fwd_flops_per_sample() / 1e9;
        assert!((7.0..=9.5).contains(&gf), "ResNet50 fwd = {gf} GFLOPs");
    }

    #[test]
    fn layer_count_reasonable() {
        // 50-layer net → >100 learnable tensors with BN tracked separately.
        let p = resnet_profile(50);
        assert!(p.layers.len() > 100 && p.layers.len() < 250, "{}", p.layers.len());
        let p = resnet_profile(101);
        assert!(p.layers.len() > 200 && p.layers.len() < 500, "{}", p.layers.len());
    }

    #[test]
    #[should_panic(expected = "unsupported ResNet depth")]
    fn rejects_unknown_depth() {
        resnet_profile(34);
    }
}
