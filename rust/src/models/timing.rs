//! Device timing model: turns a [`ModelProfile`] into the per-layer
//! *gradient-computation-done* trace that the paper's what-if simulator
//! consumes (§3.1 "white-box approach ... hooks for parameters in the
//! model to get the gradient-computation-done time"), plus the `AddEst`
//! vector-add cost tables.

use super::ModelProfile;
use crate::util::stats::Interp;

/// One gradient-ready event in a backward pass, relative to backward start.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEvent {
    /// Forward-order layer index.
    pub layer: usize,
    /// Gradient bytes.
    pub bytes: usize,
    /// Seconds after backward start at which this gradient is ready.
    pub t_ready: f64,
}

/// A full white-box timing log for one training step on one device.
#[derive(Clone, Debug)]
pub struct StepTrace {
    /// Forward-pass duration (no gradients produced).
    pub t_forward: f64,
    /// Gradient-ready events in emission order (last layer first).
    pub events: Vec<TraceEvent>,
    /// Total backward duration (= last event's `t_ready`).
    pub t_backward: f64,
    /// Single-device whole-batch time (`t_forward + t_backward`) — the
    /// paper's `t_batch`.
    pub t_batch: f64,
}

/// Split of `t_batch` between forward and backward. Backward ≈ 2× forward
/// for conv nets (two GEMMs per layer in backward vs one in forward).
pub const BWD_FRACTION: f64 = 2.0 / 3.0;

/// Generate the backward trace for a model: per-layer backward time is
/// proportional to the layer's FLOPs; layers finish in reverse forward
/// order (the output layer's gradient is ready first — which is what makes
/// communication/computation *overlap* possible, §4).
pub fn backward_trace(profile: &ModelProfile) -> StepTrace {
    let t_batch = profile.t_batch();
    let t_backward = t_batch * BWD_FRACTION;
    let t_forward = t_batch - t_backward;
    let total_flops: f64 = profile.total_fwd_flops_per_sample().max(1.0);
    let mut events = Vec::with_capacity(profile.layers.len());
    let mut t = 0.0;
    for (layer_idx, layer) in profile.layers.iter().enumerate().rev() {
        let frac = layer.fwd_flops_per_sample / total_flops;
        t += t_backward * frac;
        events.push(TraceEvent { layer: layer_idx, bytes: layer.grad_bytes(), t_ready: t });
    }
    StepTrace { t_forward, events, t_backward, t_batch }
}

/// `AddEst(x)`: time to element-wise add two f32 vectors of `x` elements.
/// Paper §3.1 prescribes an empirical table + linear interpolation; the
/// table is in *elements*.
#[derive(Clone, Debug)]
pub struct AddEst {
    interp: Interp,
}

impl AddEst {
    pub fn from_points(points: Vec<(f64, f64)>) -> AddEst {
        AddEst { interp: Interp::new(points) }
    }

    /// Estimated seconds to add two vectors of `elems` f32 elements.
    pub fn seconds(&self, elems: f64) -> f64 {
        self.interp.eval(elems.max(0.0)).max(0.0)
    }

    /// V100 preset: vector add is HBM-bound — 12 bytes/element moved
    /// (2 reads + 1 write) at ~810 GB/s effective (90% of 900 GB/s peak),
    /// plus ~4 µs launch latency. Table knots at powers of 4 up to 256 M
    /// elements (a 1 GB tensor).
    pub fn v100() -> AddEst {
        const LAUNCH_S: f64 = 4e-6;
        const BYTES_PER_ELEM: f64 = 12.0;
        const EFF_BW: f64 = 810e9;
        let pts = (0..15)
            .map(|i| {
                let elems = 4f64.powi(i); // 1 .. 256M
                (elems, LAUNCH_S + elems * BYTES_PER_ELEM / EFF_BW)
            })
            .collect();
        AddEst::from_points(pts)
    }

    /// Empirical table measured on *this* machine through the same
    /// `add_assign` the emulator's hot path uses — the paper's method,
    /// executed locally. `max_elems` bounds measurement time.
    pub fn measure_local(max_elems: usize) -> AddEst {
        let mut pts = Vec::new();
        let mut elems = 1usize << 10;
        // Always include a near-zero knot so interpolation starts sanely.
        pts.push((0.0, 1e-7));
        while elems <= max_elems {
            let reps = (1 << 22) / elems.max(1) + 1;
            let t = crate::collectives::reduce::measure_add_seconds(elems, reps.min(64));
            pts.push((elems as f64, t));
            elems *= 4;
        }
        AddEst::from_points(pts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelId;

    #[test]
    fn trace_covers_all_layers_reverse_order() {
        let p = ModelId::ResNet50.profile();
        let tr = backward_trace(&p);
        assert_eq!(tr.events.len(), p.layers.len());
        // Emission order: strictly decreasing layer index, increasing time.
        for w in tr.events.windows(2) {
            assert!(w[0].layer > w[1].layer);
            assert!(w[0].t_ready <= w[1].t_ready);
        }
        assert_eq!(tr.events.first().unwrap().layer, p.layers.len() - 1);
        assert_eq!(tr.events.last().unwrap().layer, 0);
    }

    #[test]
    fn trace_times_sum_to_backward() {
        let p = ModelId::Vgg16.profile();
        let tr = backward_trace(&p);
        let last = tr.events.last().unwrap().t_ready;
        assert!((last - tr.t_backward).abs() < 1e-9);
        assert!((tr.t_forward + tr.t_backward - tr.t_batch).abs() < 1e-12);
    }

    #[test]
    fn trace_bytes_sum_to_model_size() {
        for id in ModelId::paper_models() {
            let p = id.profile();
            let tr = backward_trace(&p);
            let total: usize = tr.events.iter().map(|e| e.bytes).sum();
            assert_eq!(total, p.total_bytes());
        }
    }

    #[test]
    fn addest_v100_matches_paper_transmit_scale() {
        // Sanity: adding a 527 MB (131.75 M elem) vector on V100 ≈ 2 ms —
        // far below its 42.2 ms transmit at 100 Gbps, which is why the
        // paper can treat the add cost as secondary.
        let a = AddEst::v100();
        let t = a.seconds(527e6 / 4.0);
        assert!((1e-3..4e-3).contains(&t), "{t}");
    }

    #[test]
    fn addest_monotone() {
        let a = AddEst::v100();
        let mut last = 0.0;
        for e in [1e3, 1e5, 1e7, 2.5e8] {
            let t = a.seconds(e);
            assert!(t >= last);
            last = t;
        }
    }

    #[test]
    fn addest_local_measurement_works() {
        let a = AddEst::measure_local(1 << 14);
        let small = a.seconds(1024.0);
        let big = a.seconds(16384.0);
        assert!(small > 0.0 && big > small);
    }
}
