//! Transformer profile — the model the e2e example actually trains through
//! the XLA runtime. Two variants:
//!
//! * [`transformer_profile`] — a GPT-2-small-class decoder (~117 M params)
//!   used by the simulator when `--model transformer` is selected, showing
//!   the paper's analysis generalizes beyond CNNs (its §4 future work).
//! * [`tiny_transformer_dims`] — the scaled-down configuration the e2e
//!   example trains for real on this box (matching
//!   `python/compile/model.py`; the AOT artifact is built from the same
//!   numbers, keep them in sync).

use super::{LayerProfile, ModelId, ModelProfile};

/// Decoder-block parameter count for width `d`, FFN multiplier 4.
fn block_params(d: usize) -> usize {
    // qkv + output projection: 4·d² (+4d bias) ; MLP: 8·d² (+5d bias);
    // 2 layer norms: 4d.
    4 * d * d + 4 * d + 8 * d * d + 5 * d + 4 * d
}

fn block_flops(d: usize, seq: usize) -> f64 {
    // Per sample (sequence): matmuls 2·seq·(12·d²) + attention 4·seq²·d.
    (2 * seq * 12 * d * d) as f64 + (4 * seq * seq * d) as f64
}

/// GPT-2-small-class profile: 12 layers, d=768, vocab 50257, seq 1024.
pub fn transformer_profile() -> ModelProfile {
    let (d, n_layers, vocab, seq) = (768usize, 12usize, 50257usize, 1024usize);
    let mut layers = Vec::new();
    layers.push(LayerProfile {
        name: "embed".into(),
        params: vocab * d + seq * d,
        fwd_flops_per_sample: (seq * d) as f64, // lookup + add
    });
    for i in 0..n_layers {
        layers.push(LayerProfile {
            name: format!("block{i}"),
            params: block_params(d),
            fwd_flops_per_sample: block_flops(d, seq),
        });
    }
    layers.push(LayerProfile {
        name: "lm_head".into(),
        // Tied embeddings contribute no extra params; final LN only.
        params: 2 * d,
        fwd_flops_per_sample: (2 * seq * vocab * d) as f64,
    });
    ModelProfile {
        id: ModelId::Transformer,
        layers,
        // V100 fp32, batch 32 sequences: ~4 seq/s (GPT-2-small scale).
        base_throughput_per_sec: 4.0,
        batch_size: 32,
    }
}

/// Dimensions of the e2e training config (must mirror
/// `python/compile/model.py::TINY`): returns
/// `(vocab, d_model, n_layers, n_heads, seq_len)`.
pub fn tiny_transformer_dims() -> (usize, usize, usize, usize, usize) {
    (512, 256, 4, 8, 64)
}

/// Parameter count of the tiny e2e transformer (python side must agree;
/// checked by an integration test against the artifact metadata).
///
/// The python model (`python/compile/model.py`) uses bias-free linear
/// layers: per block qkv `d·3d` + proj `d·d` + mlp `d·4d + 4d·d` + four
/// layer-norm vectors `4d` = `12d² + 4d`.
pub fn tiny_transformer_params() -> usize {
    let (vocab, d, n_layers, _heads, seq) = tiny_transformer_dims();
    let embed = vocab * d + seq * d;
    let per_block = 12 * d * d + 4 * d;
    let final_ln = 2 * d;
    embed + n_layers * per_block + final_ln // lm head tied to embedding
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpt2_small_scale() {
        let p = transformer_profile();
        let m = p.total_params() as f64 / 1e6;
        // GPT-2 small is 117M; block-math approximation should land close.
        assert!((100.0..140.0).contains(&m), "{m}M params");
    }

    #[test]
    fn tiny_params_are_laptop_scale() {
        let n = tiny_transformer_params();
        // A few million params: real to train on 1 CPU core, big enough to
        // produce MB-scale gradients for the fusion buffer to chew on.
        assert!((1_000_000..20_000_000).contains(&n), "{n}");
    }

    #[test]
    fn block_params_formula() {
        // d=4: qkv+proj 64+16, mlp 128+20, ln 16 → 244.
        assert_eq!(block_params(4), 244);
    }
}
