//! VGG16 layer generation (Simonyan & Zisserman, configuration D) with
//! bias terms, ImageNet shape. The interesting property for this paper:
//! the first fully-connected layer holds 25088×4096 ≈ 103 M parameters
//! (~411 MB of f32 gradients) — the "layer with 400MB parameters" that
//! stresses the fusion buffer and makes VGG16 the worst scaler.

use super::{LayerProfile, ModelId, ModelProfile};

fn conv(name: &str, c_in: usize, c_out: usize, hw: usize) -> LayerProfile {
    LayerProfile {
        name: name.into(),
        params: 3 * 3 * c_in * c_out + c_out, // 3×3 kernel + bias
        fwd_flops_per_sample: 2.0 * (3 * 3 * c_in * c_out * hw * hw) as f64,
    }
}

fn fc(name: &str, d_in: usize, d_out: usize) -> LayerProfile {
    LayerProfile {
        name: name.into(),
        params: d_in * d_out + d_out,
        fwd_flops_per_sample: 2.0 * (d_in * d_out) as f64,
    }
}

/// Build the VGG16 profile.
pub fn vgg16_profile() -> ModelProfile {
    // (channels, spatial size while at that stage)
    let mut layers = Vec::new();
    let cfg: &[(usize, usize, usize)] = &[
        // (c_in, c_out, hw)
        (3, 64, 224),
        (64, 64, 224),
        (64, 128, 112),
        (128, 128, 112),
        (128, 256, 56),
        (256, 256, 56),
        (256, 256, 56),
        (256, 512, 28),
        (512, 512, 28),
        (512, 512, 28),
        (512, 512, 14),
        (512, 512, 14),
        (512, 512, 14),
    ];
    for (i, (ci, co, hw)) in cfg.iter().enumerate() {
        layers.push(conv(&format!("conv{}", i + 1), *ci, *co, *hw));
    }
    // Classifier: 7×7×512 = 25088 → 4096 → 4096 → 1000.
    layers.push(fc("fc1", 25088, 4096));
    layers.push(fc("fc2", 4096, 4096));
    layers.push(fc("fc3", 4096, 1000));

    ModelProfile {
        id: ModelId::Vgg16,
        layers,
        // Calibrated single-V100 throughput (images/s, batch 32, fp32).
        base_throughput_per_sec: 170.0,
        batch_size: 32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_parameter_count() {
        // Published: 138,357,544 parameters.
        let p = vgg16_profile();
        let total = p.total_params();
        assert!(
            (137_000_000..=139_500_000).contains(&total),
            "VGG16 params = {total}"
        );
    }

    #[test]
    fn fc1_dominates() {
        let p = vgg16_profile();
        let fc1 = p.layers.iter().find(|l| l.name == "fc1").unwrap();
        assert_eq!(fc1.params, 25088 * 4096 + 4096);
        assert!(fc1.params as f64 / p.total_params() as f64 > 0.7);
    }

    #[test]
    fn vgg16_flops_about_31_gflops() {
        // Published "15.5 GFLOPs" counts multiply-adds (MACs); at 2 FLOPs
        // per MAC the forward pass is ≈ 31 GFLOPs.
        let gf = vgg16_profile().total_fwd_flops_per_sample() / 1e9;
        assert!((28.0..=33.0).contains(&gf), "VGG16 fwd = {gf} GFLOPs");
    }

    #[test]
    fn sixteen_weight_layers() {
        assert_eq!(vgg16_profile().layers.len(), 16);
    }
}
