//! Size-classed buffer pool for the zero-copy data plane.
//!
//! The paper's diagnosis is that software overhead — not the wire —
//! strands provisioned bandwidth, and per-chunk allocation is exactly
//! that kind of overhead: before this module the striped transport
//! `to_vec()`-copied every chunk into a fresh allocation and every
//! `recv` returned a fresh `Vec<u8>`, so the steady-state hot path
//! allocated per chunk per lane per step. [`BufPool`] closes the loop:
//!
//! * Buffers are grouped into power-of-two **size classes** (64 B up to
//!   128 MiB). `get(len)` pops a free buffer of the smallest class that
//!   fits, or allocates one fresh at the full class size so it is
//!   reusable for any request in the class.
//! * [`PooledBuf`] is an owned, `Send` handle that derefs to exactly the
//!   logical `len` requested. Dropping it returns the storage to the
//!   pool; [`PooledBuf::into_vec`] detaches it for legacy callers that
//!   need a bare `Vec<u8>` (the allocation then stays with the caller).
//! * The pool is **leak-checked by counting**: [`BufPool::stats`]
//!   exposes fresh allocations, reuses, detaches and the number of
//!   buffers currently outstanding. The transport-conformance suite
//!   asserts `outstanding == 0` after a drain and that the striped hot
//!   path performs **zero fresh allocations** at steady state.
//!
//! Free lists are bounded per class so a burst cannot pin unbounded
//! memory: returns beyond the bound free the buffer (counted in
//! `dropped`) rather than caching it.

use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Smallest pooled class. Requests below this still get this class so
/// tiny control messages recycle too.
const MIN_CLASS_BYTES: usize = 64;
/// Largest pooled class (one full uncompressed stripe of a VGG16-scale
/// gradient fits). Larger requests fall back to exact, unpooled allocs.
const MAX_CLASS_BYTES: usize = 1 << 27; // 128 MiB
/// Default bound on cached free buffers per class.
const DEFAULT_DEPTH: usize = 32;

fn n_classes() -> usize {
    (MAX_CLASS_BYTES / MIN_CLASS_BYTES).trailing_zeros() as usize + 1
}

/// The size class serving a request of `len` bytes, or `None` when the
/// request is empty (no storage needed) or beyond the largest class.
fn class_of(len: usize) -> Option<usize> {
    if len == 0 || len > MAX_CLASS_BYTES {
        return None;
    }
    let size = len.next_power_of_two().max(MIN_CLASS_BYTES);
    Some((size / MIN_CLASS_BYTES).trailing_zeros() as usize)
}

fn class_bytes(class: usize) -> usize {
    MIN_CLASS_BYTES << class
}

struct PoolInner {
    classes: Vec<Mutex<Vec<Vec<u8>>>>,
    max_per_class: usize,
    fresh_allocs: AtomicU64,
    reuses: AtomicU64,
    outstanding: AtomicU64,
    detached: AtomicU64,
    recycled: AtomicU64,
    dropped: AtomicU64,
}

/// Counters snapshot — the observable side of the leak check.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Buffers allocated fresh from the system allocator.
    pub fresh_allocs: u64,
    /// Requests served from a class free list (no allocation).
    pub reuses: u64,
    /// Pooled buffers currently held by callers. Zero after a drain.
    pub outstanding: u64,
    /// Buffers handed away via [`PooledBuf::into_vec`] (legacy `Vec`
    /// paths); their storage no longer recycles.
    pub detached: u64,
    /// Buffers returned to a free list on drop.
    pub recycled: u64,
    /// Buffers freed on drop because their class list was full.
    pub dropped: u64,
}

/// A shared, thread-safe, size-classed buffer pool. `Clone` shares the
/// same underlying pool (and counters), so one pool can back every lane
/// of a fabric.
#[derive(Clone)]
pub struct BufPool {
    inner: Arc<PoolInner>,
}

impl Default for BufPool {
    fn default() -> Self {
        Self::with_depth(DEFAULT_DEPTH)
    }
}

impl BufPool {
    pub fn new() -> BufPool {
        Self::default()
    }

    /// A pool caching at most `depth` free buffers per size class.
    pub fn with_depth(depth: usize) -> BufPool {
        BufPool {
            inner: Arc::new(PoolInner {
                classes: (0..n_classes()).map(|_| Mutex::new(Vec::new())).collect(),
                max_per_class: depth,
                fresh_allocs: AtomicU64::new(0),
                reuses: AtomicU64::new(0),
                outstanding: AtomicU64::new(0),
                detached: AtomicU64::new(0),
                recycled: AtomicU64::new(0),
                dropped: AtomicU64::new(0),
            }),
        }
    }

    /// A zeroed buffer of logical length `len`. Storage comes from the
    /// matching size class when one is cached; otherwise a fresh buffer
    /// is allocated at the full class size (so it can serve any later
    /// request in the class). Empty and over-`MAX_CLASS_BYTES` requests
    /// are served unpooled.
    pub fn get(&self, len: usize) -> PooledBuf {
        let Some(class) = class_of(len) else {
            return PooledBuf { buf: vec![0u8; len], class: 0, pool: None };
        };
        let cached = self.inner.classes[class].lock().unwrap().pop();
        let buf = match cached {
            Some(mut v) => {
                // Capacity is at least the class size; resize only
                // zero-fills the grown region (the caller overwrites).
                v.clear();
                v.resize(len, 0);
                self.inner.reuses.fetch_add(1, Ordering::Relaxed);
                v
            }
            None => {
                let mut v = Vec::with_capacity(class_bytes(class));
                v.resize(len, 0);
                self.inner.fresh_allocs.fetch_add(1, Ordering::Relaxed);
                v
            }
        };
        self.inner.outstanding.fetch_add(1, Ordering::Relaxed);
        PooledBuf { buf, class, pool: Some(Arc::clone(&self.inner)) }
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            fresh_allocs: self.inner.fresh_allocs.load(Ordering::Relaxed),
            reuses: self.inner.reuses.load(Ordering::Relaxed),
            outstanding: self.inner.outstanding.load(Ordering::Relaxed),
            detached: self.inner.detached.load(Ordering::Relaxed),
            recycled: self.inner.recycled.load(Ordering::Relaxed),
            dropped: self.inner.dropped.load(Ordering::Relaxed),
        }
    }
}

/// An owned buffer borrowed from a [`BufPool`] (or wrapping a plain
/// `Vec<u8>` via [`PooledBuf::from_vec`]). Derefs to exactly the logical
/// length it was requested (or received) at; dropping it returns pooled
/// storage to its class free list.
pub struct PooledBuf {
    buf: Vec<u8>,
    class: usize,
    pool: Option<Arc<PoolInner>>,
}

impl PooledBuf {
    /// Wrap an existing `Vec` as an unpooled buffer — the adapter the
    /// default [`crate::net::Endpoint`] methods use so fabrics can
    /// migrate to the pooled API incrementally.
    pub fn from_vec(v: Vec<u8>) -> PooledBuf {
        PooledBuf { buf: v, class: 0, pool: None }
    }

    /// Detach the storage as a bare `Vec<u8>`. The buffer does not
    /// return to the pool (counted in [`PoolStats::detached`]); legacy
    /// `recv() -> Vec<u8>` paths pay this, pooled paths never call it.
    pub fn into_vec(mut self) -> Vec<u8> {
        if let Some(pool) = self.pool.take() {
            pool.detached.fetch_add(1, Ordering::Relaxed);
            pool.outstanding.fetch_sub(1, Ordering::Relaxed);
        }
        std::mem::take(&mut self.buf)
    }
}

impl Drop for PooledBuf {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.take() {
            pool.outstanding.fetch_sub(1, Ordering::Relaxed);
            let mut free = pool.classes[self.class].lock().unwrap();
            if free.len() < pool.max_per_class {
                free.push(std::mem::take(&mut self.buf));
                drop(free);
                pool.recycled.fetch_add(1, Ordering::Relaxed);
            } else {
                drop(free);
                pool.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

impl Deref for PooledBuf {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        &self.buf
    }
}

impl DerefMut for PooledBuf {
    fn deref_mut(&mut self) -> &mut [u8] {
        &mut self.buf
    }
}

impl AsRef<[u8]> for PooledBuf {
    fn as_ref(&self) -> &[u8] {
        &self.buf
    }
}

impl std::fmt::Debug for PooledBuf {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PooledBuf")
            .field("len", &self.buf.len())
            .field("pooled", &self.pool.is_some())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_sizing_rounds_up_to_power_of_two() {
        assert_eq!(class_of(0), None);
        assert_eq!(class_of(1), Some(0));
        assert_eq!(class_of(64), Some(0));
        assert_eq!(class_of(65), Some(1));
        assert_eq!(class_of(100), Some(1));
        assert_eq!(class_of(MAX_CLASS_BYTES), class_of(MAX_CLASS_BYTES / 2 + 1));
        assert_eq!(class_of(MAX_CLASS_BYTES + 1), None);
        assert_eq!(class_bytes(class_of(100).unwrap()), 128);
    }

    #[test]
    fn get_returns_zeroed_logical_len() {
        let pool = BufPool::new();
        let b = pool.get(100);
        assert_eq!(b.len(), 100);
        assert!(b.iter().all(|&x| x == 0));
    }

    #[test]
    fn drop_recycles_and_reuse_counts() {
        let pool = BufPool::new();
        {
            let mut b = pool.get(1000);
            b[0] = 7;
        } // returns to the pool
        let s = pool.stats();
        assert_eq!((s.fresh_allocs, s.reuses, s.recycled, s.outstanding), (1, 0, 1, 0));
        // Same class, different length: served without a fresh alloc,
        // and re-zeroed.
        let b = pool.get(900);
        assert_eq!(b.len(), 900);
        assert!(b.iter().all(|&x| x == 0));
        let s = pool.stats();
        assert_eq!((s.fresh_allocs, s.reuses, s.outstanding), (1, 1, 1));
        drop(b);
        assert_eq!(pool.stats().outstanding, 0);
    }

    #[test]
    fn steady_state_allocates_zero_after_warmup() {
        let pool = BufPool::new();
        drop(pool.get(4096)); // warmup
        let baseline = pool.stats().fresh_allocs;
        for _ in 0..100 {
            drop(pool.get(4096));
        }
        assert_eq!(pool.stats().fresh_allocs, baseline, "steady state must not allocate");
        assert_eq!(pool.stats().outstanding, 0);
    }

    #[test]
    fn into_vec_detaches_without_recycling() {
        let pool = BufPool::new();
        let v = pool.get(10).into_vec();
        assert_eq!(v.len(), 10);
        let s = pool.stats();
        assert_eq!((s.detached, s.recycled, s.outstanding), (1, 0, 0));
        // The next get of the class allocates fresh — the storage left.
        pool.get(10);
        assert_eq!(pool.stats().fresh_allocs, 2);
    }

    #[test]
    fn empty_and_oversize_requests_are_unpooled() {
        let pool = BufPool::new();
        let e = pool.get(0);
        assert_eq!(e.len(), 0);
        drop(e);
        assert_eq!(pool.stats(), PoolStats::default());
        let big = pool.get(MAX_CLASS_BYTES + 1);
        assert_eq!(big.len(), MAX_CLASS_BYTES + 1);
        drop(big);
        assert_eq!(pool.stats(), PoolStats::default());
    }

    #[test]
    fn depth_bound_drops_excess_returns() {
        let pool = BufPool::with_depth(2);
        let bufs: Vec<_> = (0..4).map(|_| pool.get(64)).collect();
        drop(bufs);
        let s = pool.stats();
        assert_eq!((s.recycled, s.dropped, s.outstanding), (2, 2, 0));
    }

    #[test]
    fn clones_share_one_pool() {
        let pool = BufPool::new();
        let clone = pool.clone();
        drop(clone.get(64));
        assert_eq!(pool.stats().fresh_allocs, 1);
        drop(pool.get(64));
        assert_eq!(pool.stats().reuses, 1);
    }

    #[test]
    fn from_vec_round_trips_unpooled() {
        let b = PooledBuf::from_vec(b"abc".to_vec());
        assert_eq!(&*b, b"abc");
        assert_eq!(b.into_vec(), b"abc");
    }
}
