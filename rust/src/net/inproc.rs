//! In-process fabric: per-worker mailboxes guarded by `Mutex` + `Condvar`,
//! with tag matching. The fast path for emulation and the reference
//! implementation the TCP fabric is tested against.

use super::buf::{BufPool, PooledBuf};
use super::{Endpoint, Fabric, Mailbox};
use crate::net::shaper::Shaper;
use crate::topology::WorkerId;
use crate::Result;
use std::io::IoSlice;
use std::sync::Arc;

struct Shared {
    mailboxes: Vec<Mailbox>,
    /// Optional egress shaping (None = infinitely fast fabric).
    shaper: Option<Arc<Shaper>>,
    /// Frame storage: sends copy into pooled buffers, receivers either
    /// borrow them (`recv_buf`/`recv_into` — recycled on drop) or detach
    /// them (`recv` — legacy `Vec` path).
    pool: BufPool,
}

/// In-process fabric over `n` workers.
pub struct InProcFabric {
    shared: Arc<Shared>,
}

impl InProcFabric {
    /// Unshaped fabric (tests, intra-node-only experiments).
    pub fn new(n: usize) -> InProcFabric {
        Self::with_shaper(n, None)
    }

    /// Fabric whose sends pass through `shaper` (the NIC model). The
    /// shaper is shared — multiple fabric lanes of one striped transport
    /// drain the same per-server token buckets.
    pub fn with_shaper(n: usize, shaper: Option<Arc<Shaper>>) -> InProcFabric {
        Self::with_shaper_and_pool(n, shaper, BufPool::new())
    }

    /// Like [`InProcFabric::with_shaper`] with an explicit (possibly
    /// shared) buffer pool — the counting-pool conformance tests inject
    /// one to prove the hot path allocates nothing at steady state.
    pub fn with_shaper_and_pool(
        n: usize,
        shaper: Option<Arc<Shaper>>,
        pool: BufPool,
    ) -> InProcFabric {
        assert!(n >= 1);
        let mailboxes = (0..n).map(|_| Mailbox::default()).collect();
        InProcFabric { shared: Arc::new(Shared { mailboxes, shaper, pool }) }
    }

    /// The pool backing this fabric's frames.
    pub fn pool(&self) -> &BufPool {
        &self.shared.pool
    }
}

impl Fabric for InProcFabric {
    fn endpoints(&self) -> Vec<Arc<dyn Endpoint>> {
        (0..self.shared.mailboxes.len())
            .map(|i| {
                Arc::new(InProcEndpoint { me: WorkerId(i), shared: Arc::clone(&self.shared) })
                    as Arc<dyn Endpoint>
            })
            .collect()
    }
}

struct InProcEndpoint {
    me: WorkerId,
    shared: Arc<Shared>,
}

impl Endpoint for InProcEndpoint {
    fn me(&self) -> WorkerId {
        self.me
    }

    fn world(&self) -> usize {
        self.shared.mailboxes.len()
    }

    fn send(&self, to: WorkerId, tag: u64, payload: &[u8]) -> Result<()> {
        anyhow::ensure!(to.0 < self.world(), "send to out-of-range worker {to}");
        if let Some(shaper) = &self.shared.shaper {
            shaper.admit(self.me, to, payload.len() as u64);
        }
        let mut frame = self.shared.pool.get(payload.len());
        frame.copy_from_slice(payload);
        self.shared.mailboxes[to.0].put(self.me.0, tag, frame);
        Ok(())
    }

    fn send_vectored(&self, to: WorkerId, tag: u64, iov: &[IoSlice<'_>]) -> Result<()> {
        anyhow::ensure!(to.0 < self.world(), "send to out-of-range worker {to}");
        let total: usize = iov.iter().map(|s| s.len()).sum();
        if let Some(shaper) = &self.shared.shaper {
            shaper.admit(self.me, to, total as u64);
        }
        // One pooled frame gathers the slices; no intermediate Vec.
        let mut frame = self.shared.pool.get(total);
        let mut off = 0usize;
        for s in iov {
            frame[off..off + s.len()].copy_from_slice(s);
            off += s.len();
        }
        self.shared.mailboxes[to.0].put(self.me.0, tag, frame);
        Ok(())
    }

    fn recv(&self, from: WorkerId, tag: u64) -> Result<Vec<u8>> {
        Ok(self.recv_buf(from, tag)?.into_vec())
    }

    fn recv_buf(&self, from: WorkerId, tag: u64) -> Result<PooledBuf> {
        anyhow::ensure!(from.0 < self.world(), "recv from out-of-range worker {from}");
        self.shared.mailboxes[self.me.0].take(from.0, tag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn ping_pong() {
        let fab = InProcFabric::new(2);
        let eps = fab.endpoints();
        let (a, b) = (Arc::clone(&eps[0]), Arc::clone(&eps[1]));
        let t = thread::spawn(move || {
            let m = b.recv(WorkerId(0), 7).unwrap();
            b.send(WorkerId(0), 8, &m).unwrap();
        });
        a.send(WorkerId(1), 7, b"hello").unwrap();
        let echo = a.recv(WorkerId(1), 8).unwrap();
        t.join().unwrap();
        assert_eq!(echo, b"hello");
    }

    #[test]
    fn tag_isolation_and_fifo_order() {
        let fab = InProcFabric::new(2);
        let eps = fab.endpoints();
        eps[0].send(WorkerId(1), 1, b"t1-first").unwrap();
        eps[0].send(WorkerId(1), 2, b"t2").unwrap();
        eps[0].send(WorkerId(1), 1, b"t1-second").unwrap();
        assert_eq!(eps[1].recv(WorkerId(0), 2).unwrap(), b"t2");
        assert_eq!(eps[1].recv(WorkerId(0), 1).unwrap(), b"t1-first");
        assert_eq!(eps[1].recv(WorkerId(0), 1).unwrap(), b"t1-second");
    }

    #[test]
    fn sender_isolation() {
        let fab = InProcFabric::new(3);
        let eps = fab.endpoints();
        eps[0].send(WorkerId(2), 5, b"from0").unwrap();
        eps[1].send(WorkerId(2), 5, b"from1").unwrap();
        assert_eq!(eps[2].recv(WorkerId(1), 5).unwrap(), b"from1");
        assert_eq!(eps[2].recv(WorkerId(0), 5).unwrap(), b"from0");
    }

    #[test]
    fn many_threads_all_to_all() {
        let n = 4;
        let fab = InProcFabric::new(n);
        let eps = fab.endpoints();
        let mut handles = Vec::new();
        for (i, ep) in eps.into_iter().enumerate() {
            handles.push(thread::spawn(move || {
                for j in 0..n {
                    if j != i {
                        ep.send(WorkerId(j), 9, &[i as u8]).unwrap();
                    }
                }
                let mut got = Vec::new();
                for j in 0..n {
                    if j != i {
                        got.push(ep.recv(WorkerId(j), 9).unwrap()[0]);
                    }
                }
                got.sort();
                let want: Vec<u8> =
                    (0..n as u8).filter(|x| *x != i as u8).collect();
                assert_eq!(got, want);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn out_of_range_errors() {
        let fab = InProcFabric::new(1);
        let eps = fab.endpoints();
        assert!(eps[0].send(WorkerId(5), 0, b"x").is_err());
    }
}
