//! Mechanistic model of a kernel-TCP / Horovod-class transport.
//!
//! The paper's root-cause finding (§2.4): the provisioned network is *not*
//! saturated — the communication software tops out around **32 Gbps of a
//! 100 Gbps NIC** while CPU sits at 14–25%. We model that transport with
//! three parameters:
//!
//! * `ceiling_gbps` — the software processing ceiling (single effective
//!   processing pipeline: syscalls + copies + protocol work). Fig 4: the
//!   servers "use no more than 32 Gbps" ⇒ 32.
//! * `knee` — sharpness of the transition between the wire-limited and
//!   software-limited regimes. Effective throughput composes as a
//!   power-mean: `eff = (bw^-p + ceiling^-p)^(-1/p)`. `p = 2` reproduces
//!   the paper's observations: ≈100% utilization at 1 Gbps, ≈95% at
//!   10 Gbps (Fig 6: measured ≈ simulated up to 10 Gbps), divergence
//!   beyond 25 Gbps and a plateau approaching the ceiling (Fig 3/4).
//! * `per_msg_overhead_s` — fixed per-message software cost (syscall +
//!   wakeup); only visible for small messages.
//!
//! The same model provides the CPU-utilization estimate behind Fig 5: the
//! communication phase burns CPU proportional to bytes actually processed,
//! far from the 96-vCPU capacity — confirming CPU is not the bottleneck.

/// Parameters of the kernel-TCP transport model.
#[derive(Clone, Copy, Debug)]
pub struct KernelTcpModel {
    pub ceiling_gbps: f64,
    pub knee: f64,
    pub per_msg_overhead_s: f64,
    /// CPU model: fraction of the server's CPU consumed per achieved Gbps.
    pub cpu_frac_per_gbps: f64,
    /// CPU model: fixed communication-phase overhead fraction (event loops,
    /// framework hooks) independent of rate.
    pub cpu_frac_base: f64,
}

impl Default for KernelTcpModel {
    /// Calibration against the paper's measurements (see module docs).
    fn default() -> Self {
        KernelTcpModel {
            ceiling_gbps: 32.0,
            knee: 2.0,
            per_msg_overhead_s: 50e-6,
            // Fig 5: 14%–25% of 96 vCPUs across 1–100 Gbps. Achieved rate
            // spans ~1–30 Gbps, so base ≈ 0.13, slope ≈ 0.004/Gbps.
            cpu_frac_per_gbps: 0.004,
            cpu_frac_base: 0.13,
        }
    }
}

impl KernelTcpModel {
    /// Effective achievable throughput (Gbps) given a provisioned rate.
    pub fn effective_gbps(&self, provisioned_gbps: f64) -> f64 {
        assert!(provisioned_gbps > 0.0);
        let p = self.knee;
        (provisioned_gbps.powf(-p) + self.ceiling_gbps.powf(-p)).powf(-1.0 / p)
    }

    /// Utilization of the provisioned bandwidth (Fig 4's y-axis as a
    /// fraction).
    pub fn utilization(&self, provisioned_gbps: f64) -> f64 {
        self.effective_gbps(provisioned_gbps) / provisioned_gbps
    }

    /// Time to move `bytes` through this transport at `provisioned_gbps`,
    /// including the per-message overhead.
    pub fn transfer_time_s(&self, bytes: f64, provisioned_gbps: f64) -> f64 {
        let eff_bytes_per_s = crate::gbps_to_bytes_per_sec(self.effective_gbps(provisioned_gbps));
        self.per_msg_overhead_s + bytes / eff_bytes_per_s
    }

    /// Estimated CPU utilization (fraction of the whole server) while the
    /// communication phase runs at `provisioned_gbps` (Fig 5 model).
    pub fn cpu_utilization(&self, provisioned_gbps: f64) -> f64 {
        (self.cpu_frac_base + self.cpu_frac_per_gbps * self.effective_gbps(provisioned_gbps))
            .min(1.0)
    }

    /// An idealized transport (the what-if §3.1 assumption): no software
    /// ceiling, no per-message overhead.
    pub fn ideal() -> KernelTcpModel {
        KernelTcpModel {
            ceiling_gbps: f64::INFINITY,
            knee: 2.0,
            per_msg_overhead_s: 0.0,
            cpu_frac_per_gbps: 0.0,
            cpu_frac_base: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn near_full_utilization_at_low_speed() {
        let m = KernelTcpModel::default();
        assert!(m.utilization(1.0) > 0.99, "{}", m.utilization(1.0));
        assert!(m.utilization(10.0) > 0.90, "{}", m.utilization(10.0));
    }

    #[test]
    fn capped_near_paper_ceiling_at_100g() {
        let m = KernelTcpModel::default();
        let eff = m.effective_gbps(100.0);
        // Paper: "uses no more than 32 Gbps" of the 100 Gbps NIC.
        assert!(eff <= 32.0, "{eff}");
        assert!(eff >= 25.0, "{eff}");
        assert!(m.utilization(100.0) < 0.35);
    }

    #[test]
    fn plateau_after_25g() {
        // Fig 3: marginal gain from extra bandwidth shrinks past 25 Gbps.
        let m = KernelTcpModel::default();
        let gain_10_25 = m.effective_gbps(25.0) - m.effective_gbps(10.0);
        let gain_50_100 = m.effective_gbps(100.0) - m.effective_gbps(50.0);
        assert!(gain_50_100 < gain_10_25 / 2.0);
    }

    #[test]
    fn monotone_in_provisioned_bw() {
        let m = KernelTcpModel::default();
        let mut last = 0.0;
        for g in [1.0, 5.0, 10.0, 25.0, 50.0, 100.0, 400.0] {
            let e = m.effective_gbps(g);
            assert!(e > last);
            last = e;
        }
    }

    #[test]
    fn ideal_transport_is_transparent() {
        let m = KernelTcpModel::ideal();
        for g in [1.0, 10.0, 100.0] {
            assert!((m.effective_gbps(g) - g).abs() < 1e-9);
            assert!((m.utilization(g) - 1.0).abs() < 1e-12);
        }
        assert_eq!(m.transfer_time_s(1e9, 100.0), 1e9 / 12.5e9);
    }

    #[test]
    fn cpu_utilization_in_paper_band() {
        // Fig 5: 14–25% across network speeds.
        let m = KernelTcpModel::default();
        for g in [1.0, 10.0, 25.0, 50.0, 100.0] {
            let u = m.cpu_utilization(g);
            assert!((0.10..=0.30).contains(&u), "{g} Gbps -> {u}");
        }
    }

    #[test]
    fn transfer_time_includes_overhead() {
        let m = KernelTcpModel::default();
        let tiny = m.transfer_time_s(1.0, 100.0);
        assert!(tiny >= m.per_msg_overhead_s);
    }
}
