//! Multi-**process** loopback TCP fabric.
//!
//! [`crate::net::tcp::TcpFabric`] owns every worker's listener and
//! mailbox in one process — right for the threaded emulator, useless for
//! real worker processes. A [`MeshNode`] is the per-process half of the
//! same fabric: it owns *one* worker's listener and mailbox, learns the
//! peers' addresses out of band (the `netbn launch` coordinator's
//! rendezvous — see [`crate::trainer::launch`]), and dials peers lazily
//! with the bounded-retry connect ([`crate::net::tcp::connect_retry`]) so
//! a racing worker whose peer has not bound yet waits instead of failing
//! the collective.
//!
//! The wire format is byte-identical to `TcpFabric`'s
//! (`[from u64][tag u64][len u64][payload]`, same reader loop, same
//! poison-on-garbage semantics), so everything layered on [`Endpoint`] —
//! collectives, the striped transport — runs unchanged across process
//! boundaries. Striped transports bind one `MeshNode` per lane: each
//! lane is its own listener and its own set of peer connections, exactly
//! like a `TransportFabric` lane in process.

use super::buf::{BufPool, PooledBuf};
use super::tcp::{
    connect_retry, reader_loop_into, write_frame, write_frame_vectored, CONNECT_TIMEOUT,
};
use super::{Endpoint, Mailbox};
use crate::topology::WorkerId;
use crate::Result;
use anyhow::Context;
use std::collections::HashMap;
use std::io::IoSlice;
use std::net::{IpAddr, Ipv4Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Duration;

/// One worker's bound-but-not-yet-connected half of a mesh fabric: a
/// listener plus the mailbox its reader threads dispatch into. Create
/// with [`MeshNode::bind`], exchange [`MeshNode::addr`] with the peers,
/// then [`MeshNode::connect`] into an [`Endpoint`].
///
/// A node dropped *without* reaching `connect` (a failed rendezvous)
/// stops its accept thread and releases the port; after a successful
/// `connect`, that cleanup transfers to the endpoint's own `Drop`.
pub struct MeshNode {
    me: WorkerId,
    world: usize,
    addr: SocketAddr,
    mailbox: Arc<Mailbox>,
    closed: Arc<AtomicBool>,
    /// Set by `connect`: cleanup responsibility has moved to the endpoint.
    defused: std::cell::Cell<bool>,
}

impl Drop for MeshNode {
    fn drop(&mut self) {
        if !self.defused.get() && !self.closed.swap(true, Ordering::SeqCst) {
            // Wake the accept loop so its thread exits and the port frees.
            let _ = TcpStream::connect(self.addr);
        }
    }
}

impl MeshNode {
    /// Bind a loopback listener for rank `me` of `world` and start its
    /// accept loop.
    pub fn bind(me: WorkerId, world: usize) -> Result<MeshNode> {
        MeshNode::bind_on(IpAddr::V4(Ipv4Addr::LOCALHOST), me, world)
    }

    /// Bind a listener on a specific interface — the one routing to the
    /// coordinator in a multi-host launch — for rank `me` of `world`, and
    /// start its accept loop.
    pub fn bind_on(ip: IpAddr, me: WorkerId, world: usize) -> Result<MeshNode> {
        anyhow::ensure!(world >= 1 && me.0 < world, "rank {me} out of a world of {world}");
        let listener = TcpListener::bind((ip, 0)).context("bind mesh listener")?;
        let addr = listener.local_addr()?;
        let mailbox = Arc::new(Mailbox::default());
        let closed = Arc::new(AtomicBool::new(false));
        let pool = BufPool::new();
        {
            let mailbox = Arc::clone(&mailbox);
            let closed = Arc::clone(&closed);
            let pool = pool.clone();
            thread::spawn(move || loop {
                let (stream, _) = match listener.accept() {
                    Ok(s) => s,
                    Err(_) => return,
                };
                if closed.load(Ordering::SeqCst) {
                    return;
                }
                let mailbox = Arc::clone(&mailbox);
                let pool = pool.clone();
                let owner = me.0;
                thread::spawn(move || reader_loop_into(owner, stream, world, &mailbox, &pool));
            });
        }
        Ok(MeshNode { me, world, addr, mailbox, closed, defused: std::cell::Cell::new(false) })
    }

    /// The address peers must dial to reach this node.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Bind the node to the full peer address table (rank-ordered; entry
    /// `me` must be this node's own address) and return the endpoint the
    /// collectives use. Outgoing connections are dialed lazily on first
    /// send, with retry while a peer is still binding.
    pub fn connect(self, addrs: Vec<SocketAddr>) -> Result<Arc<MeshEndpoint>> {
        anyhow::ensure!(
            addrs.len() == self.world,
            "peer table has {} entries for a world of {}",
            addrs.len(),
            self.world
        );
        anyhow::ensure!(
            addrs[self.me.0] == self.addr,
            "peer table entry {} is {}, but this node bound {}",
            self.me.0,
            addrs[self.me.0],
            self.addr
        );
        // Cleanup responsibility moves to the endpoint's Drop.
        self.defused.set(true);
        Ok(Arc::new(MeshEndpoint {
            me: self.me,
            world: self.world,
            addrs,
            self_addr: self.addr,
            mailbox: Arc::clone(&self.mailbox),
            closed: Arc::clone(&self.closed),
            senders: Mutex::new(HashMap::new()),
            recv_timeout_ms: AtomicU64::new(0),
        }))
    }
}

/// The connected endpoint of one mesh worker. Dropping it stops the
/// accept loop; reader threads exit when peer streams close.
pub struct MeshEndpoint {
    me: WorkerId,
    world: usize,
    addrs: Vec<SocketAddr>,
    self_addr: SocketAddr,
    mailbox: Arc<Mailbox>,
    closed: Arc<AtomicBool>,
    /// Lazily-opened outgoing streams, one per destination.
    senders: Mutex<HashMap<usize, Arc<Mutex<TcpStream>>>>,
    /// Deadline applied to every `recv`, in milliseconds; 0 = block forever.
    recv_timeout_ms: AtomicU64,
}

impl MeshEndpoint {
    /// Bound every subsequent `recv`: a receive still blocked after
    /// `timeout` fails naming the absent rank instead of wedging the
    /// collective behind a dead peer. `None` restores unbounded blocking.
    pub fn set_recv_timeout(&self, timeout: Option<Duration>) {
        let ms = timeout.map_or(0, |t| t.as_millis().max(1) as u64);
        self.recv_timeout_ms.store(ms, Ordering::SeqCst);
    }

    /// Mark this endpoint's mailbox broken: every blocked and future
    /// `recv` fails with `why`. Used when a co-dependent lane or shard has
    /// observed a dead peer, so sibling threads unwind instead of hanging.
    pub fn poison(&self, why: impl Into<String>) {
        self.mailbox.poison(why);
    }

    /// Tear down outgoing connections (epoch change): shut down every
    /// cached sender stream so peers observe EOF promptly, and forget
    /// them so any later send would re-dial.
    pub fn reap(&self) {
        let mut senders = self.senders.lock().unwrap();
        for (_, s) in senders.drain() {
            let _ = s.lock().unwrap().shutdown(std::net::Shutdown::Both);
        }
    }

    fn sender_to(&self, to: usize) -> Result<Arc<Mutex<TcpStream>>> {
        if let Some(s) = self.senders.lock().unwrap().get(&to) {
            return Ok(Arc::clone(s));
        }
        // Dial OUTSIDE the lock: a slow or dead peer must not stall sends
        // to healthy peers for the whole retry window.
        let stream = connect_retry(self.addrs[to], CONNECT_TIMEOUT)
            .context("connect to mesh peer")?;
        let arc = Arc::new(Mutex::new(stream));
        let mut senders = self.senders.lock().unwrap();
        // A concurrent dial may have won the race; keep the first stream
        // (ours closes cleanly, which the peer reads as EOF, not poison).
        Ok(Arc::clone(senders.entry(to).or_insert(arc)))
    }
}

impl Drop for MeshEndpoint {
    fn drop(&mut self) {
        if !self.closed.swap(true, Ordering::SeqCst) {
            // Wake the accept loop so its thread exits.
            let _ = TcpStream::connect(self.self_addr);
        }
    }
}

impl Endpoint for MeshEndpoint {
    fn me(&self) -> WorkerId {
        self.me
    }

    fn world(&self) -> usize {
        self.world
    }

    fn send(&self, to: WorkerId, tag: u64, payload: &[u8]) -> Result<()> {
        anyhow::ensure!(to.0 < self.world, "send to out-of-range worker {to}");
        let sender = self.sender_to(to.0)?;
        let mut stream = sender.lock().unwrap();
        write_frame(&mut stream, self.me.0, tag, payload)
    }

    fn send_vectored(&self, to: WorkerId, tag: u64, iov: &[IoSlice<'_>]) -> Result<()> {
        anyhow::ensure!(to.0 < self.world, "send to out-of-range worker {to}");
        let sender = self.sender_to(to.0)?;
        let mut stream = sender.lock().unwrap();
        write_frame_vectored(&mut stream, self.me.0, tag, iov)
    }

    fn recv(&self, from: WorkerId, tag: u64) -> Result<Vec<u8>> {
        Ok(self.recv_buf(from, tag)?.into_vec())
    }

    fn recv_buf(&self, from: WorkerId, tag: u64) -> Result<PooledBuf> {
        anyhow::ensure!(from.0 < self.world, "recv from out-of-range worker {from}");
        let ms = self.recv_timeout_ms.load(Ordering::SeqCst);
        let timeout = (ms > 0).then(|| Duration::from_millis(ms));
        self.mailbox.take_deadline(from.0, tag, timeout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collectives::ring::ring_allreduce;
    use crate::net::striped::{StripeConfig, StripedTransport};
    use crate::net::transport::Transport;
    use crate::topology::Topology;

    /// Bind `world` nodes, exchange addresses, connect all endpoints —
    /// the same dance the launch rendezvous performs across processes.
    fn mesh(world: usize) -> Vec<Arc<MeshEndpoint>> {
        let nodes: Vec<MeshNode> =
            (0..world).map(|i| MeshNode::bind(WorkerId(i), world).unwrap()).collect();
        let addrs: Vec<SocketAddr> = nodes.iter().map(|n| n.addr()).collect();
        nodes.into_iter().map(|n| n.connect(addrs.clone()).unwrap()).collect()
    }

    #[test]
    fn ping_pong_across_nodes() {
        let eps = mesh(2);
        let (a, b) = (Arc::clone(&eps[0]), Arc::clone(&eps[1]));
        let t = thread::spawn(move || {
            let m = b.recv(WorkerId(0), 1).unwrap();
            b.send(WorkerId(0), 2, &m).unwrap();
        });
        a.send(WorkerId(1), 1, b"mesh").unwrap();
        assert_eq!(a.recv(WorkerId(1), 2).unwrap(), b"mesh");
        t.join().unwrap();
    }

    #[test]
    fn ring_allreduce_over_mesh() {
        let world = 3;
        let eps = mesh(world);
        let ring = Topology::new(world, 1).flat_ring();
        let mut handles = Vec::new();
        for (i, ep) in eps.into_iter().enumerate() {
            let ring = ring.clone();
            handles.push(thread::spawn(move || {
                let mut data = vec![i as f32; 101];
                ring_allreduce(ep.as_ref(), &ring, 0, 0, &mut data).unwrap();
                data
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![3.0; 101]); // 0+1+2
        }
    }

    #[test]
    fn striped_transport_binds_mesh_lanes() {
        // Two lanes per worker, each its own listener — the launch path's
        // shape, in miniature.
        let world = 2;
        let lanes = 2;
        let cfg = StripeConfig { streams: lanes, chunk_bytes: 4 << 10, credit_window: 2 };
        let transport = StripedTransport::new(cfg);
        // nodes[w][l]
        let nodes: Vec<Vec<MeshNode>> = (0..world)
            .map(|w| (0..lanes).map(|_| MeshNode::bind(WorkerId(w), world).unwrap()).collect())
            .collect();
        let lane_addrs: Vec<Vec<SocketAddr>> = (0..lanes)
            .map(|l| nodes.iter().map(|ws| ws[l].addr()).collect())
            .collect();
        let mut eps = Vec::new();
        for ws in nodes {
            let mut lane_eps: Vec<Arc<dyn Endpoint>> = Vec::new();
            for (l, node) in ws.into_iter().enumerate() {
                lane_eps.push(node.connect(lane_addrs[l].clone()).unwrap() as Arc<dyn Endpoint>);
            }
            eps.push(transport.bind(lane_eps).unwrap());
        }
        let payload: Vec<u8> = (0..50_000u32).map(|i| (i % 251) as u8).collect();
        let want = payload.clone();
        let (a, b) = (Arc::clone(&eps[0]), Arc::clone(&eps[1]));
        let t = thread::spawn(move || b.recv(WorkerId(0), 5).unwrap());
        a.send(WorkerId(1), 5, &payload).unwrap();
        assert_eq!(t.join().unwrap(), want);
        drop(eps);
    }

    #[test]
    fn recv_timeout_names_the_dead_rank() {
        let eps = mesh(2);
        eps[0].set_recv_timeout(Some(Duration::from_millis(40)));
        let err = eps[0].recv(WorkerId(1), 9).unwrap_err().to_string();
        assert!(err.contains("rank 1"), "{err}");
        // Clearing the deadline restores unbounded blocking semantics for
        // messages that do arrive.
        eps[0].set_recv_timeout(None);
        eps[1].send(WorkerId(0), 9, b"alive").unwrap();
        assert_eq!(eps[0].recv(WorkerId(1), 9).unwrap(), b"alive");
    }

    #[test]
    fn poison_fails_pending_recvs() {
        let eps = mesh(2);
        let a = Arc::clone(&eps[0]);
        let t = thread::spawn(move || a.recv(WorkerId(1), 3));
        thread::sleep(Duration::from_millis(20));
        eps[0].poison("peer 1 declared dead");
        let err = t.join().unwrap().unwrap_err().to_string();
        assert!(err.contains("peer 1 declared dead"), "{err}");
    }

    #[test]
    fn reap_closes_outgoing_streams() {
        let eps = mesh(2);
        eps[0].send(WorkerId(1), 4, b"pre-reap").unwrap();
        assert_eq!(eps[1].recv(WorkerId(0), 4).unwrap(), b"pre-reap");
        eps[0].reap();
        // A later send re-dials transparently.
        eps[0].send(WorkerId(1), 5, b"post-reap").unwrap();
        assert_eq!(eps[1].recv(WorkerId(0), 5).unwrap(), b"post-reap");
    }

    #[test]
    fn bind_on_explicit_loopback_interface() {
        let node =
            MeshNode::bind_on(IpAddr::V4(Ipv4Addr::LOCALHOST), WorkerId(0), 1).unwrap();
        assert!(node.addr().ip().is_loopback());
    }

    #[test]
    fn bad_peer_table_rejected() {
        let node = MeshNode::bind(WorkerId(0), 2).unwrap();
        let wrong_len = vec![node.addr()];
        // Too few entries.
        let node2 = MeshNode::bind(WorkerId(0), 2).unwrap();
        assert!(node2.connect(wrong_len).is_err());
        // Own entry mismatched.
        let other: SocketAddr = "127.0.0.1:1".parse().unwrap();
        assert!(node.connect(vec![other, other]).is_err());
    }
}
