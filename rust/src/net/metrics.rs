//! Link byte counters and utilization sampling — the instrumentation behind
//! the paper's Fig 4 ("recording real time network throughput").
//!
//! The counters are built on [`crate::obs::metrics::Counter`], the
//! lock-free primitive of the unified observability plane; this module
//! keeps its *per-instance* semantics (each fabric gets fresh counters)
//! rather than going through the global registry, because utilization
//! sampling needs a clean zero per experiment.

use crate::obs::metrics::Counter;
use std::time::Instant;

/// Cumulative byte counters: one egress counter per server plus an
/// aggregate intra-node counter. Lock-free; safe to read while workers run.
pub struct NetCounters {
    egress: Vec<Counter>,
    intra: Counter,
}

impl NetCounters {
    pub fn new(servers: usize) -> NetCounters {
        NetCounters {
            egress: (0..servers).map(|_| Counter::default()).collect(),
            intra: Counter::default(),
        }
    }

    pub fn record_egress(&self, server: usize, bytes: u64) {
        self.egress[server].add(bytes);
    }

    pub fn record_intra(&self, bytes: u64) {
        self.intra.add(bytes);
    }

    pub fn egress_bytes(&self, server: usize) -> u64 {
        self.egress[server].get()
    }

    pub fn total_egress(&self) -> u64 {
        self.egress.iter().map(|c| c.get()).sum()
    }

    pub fn intra_bytes(&self) -> u64 {
        self.intra.get()
    }

    pub fn servers(&self) -> usize {
        self.egress.len()
    }
}

/// Windowed utilization sampler: snapshot cumulative counters over a wall
/// interval and convert to achieved bytes/sec per server.
pub struct UtilizationSampler {
    last_snapshot: Vec<u64>,
    last_time: Instant,
}

/// One utilization sample.
#[derive(Clone, Debug)]
pub struct UtilizationSample {
    /// Seconds since the previous sample.
    pub window_s: f64,
    /// Achieved egress bytes/sec per server during the window.
    pub egress_bps: Vec<f64>,
}

impl UtilizationSample {
    /// Mean utilization across servers against a provisioned rate.
    pub fn mean_utilization(&self, provisioned_bytes_per_sec: f64) -> f64 {
        if self.egress_bps.is_empty() {
            return 0.0;
        }
        let mean = self.egress_bps.iter().sum::<f64>() / self.egress_bps.len() as f64;
        mean / provisioned_bytes_per_sec
    }

    /// Peak per-server rate in the window.
    pub fn peak_bps(&self) -> f64 {
        self.egress_bps.iter().cloned().fold(0.0, f64::max)
    }
}

impl UtilizationSampler {
    pub fn new(counters: &NetCounters) -> UtilizationSampler {
        UtilizationSampler {
            last_snapshot: (0..counters.servers()).map(|s| counters.egress_bytes(s)).collect(),
            last_time: Instant::now(),
        }
    }

    /// Take a sample since the last call.
    pub fn sample(&mut self, counters: &NetCounters) -> UtilizationSample {
        let now = Instant::now();
        let window = (now - self.last_time).as_secs_f64().max(1e-9);
        let mut egress_bps = Vec::with_capacity(self.last_snapshot.len());
        for (s, last) in self.last_snapshot.iter_mut().enumerate() {
            let cur = counters.egress_bytes(s);
            egress_bps.push((cur - *last) as f64 / window);
            *last = cur;
        }
        self.last_time = now;
        UtilizationSample { window_s: window, egress_bps }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_are_per_server() {
        let c = NetCounters::new(3);
        c.record_egress(0, 10);
        c.record_egress(2, 30);
        assert_eq!(c.egress_bytes(0), 10);
        assert_eq!(c.egress_bytes(1), 0);
        assert_eq!(c.egress_bytes(2), 30);
        assert_eq!(c.total_egress(), 40);
    }

    #[test]
    fn sampler_reports_window_rate() {
        let c = NetCounters::new(1);
        let mut s = UtilizationSampler::new(&c);
        c.record_egress(0, 1_000_000);
        std::thread::sleep(std::time::Duration::from_millis(20));
        let sample = s.sample(&c);
        assert!(sample.egress_bps[0] > 0.0);
        // Second window with no traffic → zero rate.
        std::thread::sleep(std::time::Duration::from_millis(5));
        let sample2 = s.sample(&c);
        assert_eq!(sample2.egress_bps[0], 0.0);
    }

    #[test]
    fn utilization_against_provisioned() {
        let s = UtilizationSample { window_s: 1.0, egress_bps: vec![5e8, 5e8] };
        assert!((s.mean_utilization(1e9) - 0.5).abs() < 1e-12);
        assert_eq!(s.peak_bps(), 5e8);
    }
}
