//! Networking substrate.
//!
//! The paper's entire argument is about the gap between *provisioned*
//! bandwidth and what the transport software actually delivers. This module
//! provides the pieces to express both sides:
//!
//! * [`Endpoint`]/[`Fabric`] — point-to-point message transport with tag
//!   matching, in three implementations: [`inproc`] (lock+condvar
//!   mailboxes, for tests and fast emulation), [`tcp`] (real loopback
//!   sockets owned by one process) and [`mesh`] (the per-*process* half
//!   of the TCP fabric, for `netbn launch`'s real worker processes).
//! * [`buf`] — the size-classed, leak-checked buffer pool behind the
//!   zero-copy receive path ([`Endpoint::recv_into`] /
//!   [`Endpoint::recv_buf`]) and the scatter-gather send path
//!   ([`Endpoint::send_vectored`]).
//! * [`transport`] — the [`transport::Transport`] strategy layer: how a
//!   logical message traverses the fabric — legacy single-stream or
//!   striped across N parallel connections.
//! * [`striped`] — the multi-stream striped transport (chunk pipelining +
//!   credit flow control) and its analytic effective-bandwidth model: the
//!   repair for the software bottleneck the paper diagnoses.
//! * [`shaper`] — a token-bucket NIC model that throttles each server's
//!   egress to the provisioned rate (1–100 Gbps, optionally time-scaled).
//! * [`kernel_tcp`] — the mechanistic model of a kernel-TCP/Horovod-class
//!   transport whose *effective* throughput saturates well below the
//!   provisioned rate; calibrated against the paper's Fig 4.
//! * [`metrics`] — byte counters from which network utilization
//!   (Fig 4) is computed.

pub mod buf;
pub mod inproc;
pub mod kernel_tcp;
pub mod mesh;
pub mod metrics;
pub mod shaper;
pub mod striped;
pub mod tcp;
pub mod transport;

use crate::topology::WorkerId;
use crate::Result;
use buf::PooledBuf;
use std::io::IoSlice;
use std::sync::Arc;

/// Message tags name (collective, step, chunk) coordinates so concurrent
/// collectives never cross wires. Layout: `[kind:8][step:24][sub:32]`.
pub fn tag(kind: u8, step: u32, sub: u32) -> u64 {
    ((kind as u64) << 56) | (((step as u64) & 0xFF_FFFF) << 32) | sub as u64
}

/// Tag kinds used by the collectives.
pub mod tags {
    pub const REDUCE_SCATTER: u8 = 1;
    pub const ALL_GATHER: u8 = 2;
    pub const TREE_UP: u8 = 3;
    pub const TREE_DOWN: u8 = 4;
    pub const PS_PUSH: u8 = 5;
    pub const PS_PULL: u8 = 6;
    pub const CONTROL: u8 = 7;
    pub const BARRIER: u8 = 8;
    /// Leader-to-member broadcast in the hierarchical all-reduce.
    pub const HIER_BCAST: u8 = 9;
    /// Per-shard gradient blob all-gather in the elastic trainer
    /// ([`crate::trainer::elastic`]).
    pub const SHARD_GATHER: u8 = 10;
}

/// A worker's handle onto the fabric. Clone-able and thread-safe so the
/// compute thread and the communication thread of one worker can share it
/// (that sharing is what makes backward/all-reduce *overlap* possible,
/// which the paper identifies as critical).
pub trait Endpoint: Send + Sync {
    fn me(&self) -> WorkerId;
    /// Number of workers on the fabric.
    fn world(&self) -> usize;
    /// Send `payload` to `to` under `tag`. Blocks until the transport has
    /// accepted the bytes (after any shaping delay).
    fn send(&self, to: WorkerId, tag: u64, payload: &[u8]) -> Result<()>;
    /// Receive the next message from `from` under `tag`, blocking.
    ///
    /// **Allocates a fresh `Vec<u8>` per message** — on a pooled fabric
    /// the storage is detached from the pool and never recycles. Hot
    /// paths should prefer [`Endpoint::recv_into`] (receive straight
    /// into caller storage) or [`Endpoint::recv_buf`] (borrow the pooled
    /// frame); this method remains for control-plane and cold paths.
    fn recv(&self, from: WorkerId, tag: u64) -> Result<Vec<u8>>;

    /// Receive the next message from `from` under `tag` as a pooled
    /// buffer: on pool-aware fabrics this hands over the very frame the
    /// reader filled (no copy), and dropping it returns the storage to
    /// the fabric's [`buf::BufPool`].
    ///
    /// The default falls back to [`Endpoint::recv`] and wraps the
    /// allocation unpooled, so implementations migrate incrementally.
    fn recv_buf(&self, from: WorkerId, tag: u64) -> Result<PooledBuf> {
        Ok(PooledBuf::from_vec(self.recv(from, tag)?))
    }

    /// Receive the next message from `from` under `tag` directly into
    /// `dst`, returning the message length. Fails if the message does
    /// not fit. On pooled fabrics the only copy is frame → `dst`; the
    /// frame storage recycles. Striped endpoints reassemble straight
    /// into `dst` with no intermediate message-sized buffer at all.
    fn recv_into(&self, from: WorkerId, tag: u64, dst: &mut [u8]) -> Result<usize> {
        let buf = self.recv_buf(from, tag)?;
        anyhow::ensure!(
            buf.len() <= dst.len(),
            "recv_into: message of {} bytes exceeds dst of {}",
            buf.len(),
            dst.len()
        );
        dst[..buf.len()].copy_from_slice(&buf);
        Ok(buf.len())
    }

    /// Send a message whose payload is the concatenation of `iov`,
    /// without requiring the caller to materialize it. Socket fabrics
    /// turn this into one gathered `write_vectored`; mailbox fabrics
    /// copy the slices once into a pooled frame. The default falls back
    /// to concatenate-then-[`Endpoint::send`].
    fn send_vectored(&self, to: WorkerId, tag: u64, iov: &[IoSlice<'_>]) -> Result<()> {
        let total: usize = iov.iter().map(|s| s.len()).sum();
        let mut flat = Vec::with_capacity(total);
        for s in iov {
            flat.extend_from_slice(s);
        }
        self.send(to, tag, &flat)
    }
}

/// A constructed fabric: one endpoint per worker.
pub trait Fabric {
    fn endpoints(&self) -> Vec<Arc<dyn Endpoint>>;
}

/// Tag-matched mailbox shared by the fabric implementations:
/// `(from, tag) -> FIFO of payloads`, blocking `take`. A mailbox can be
/// **poisoned** (e.g. by a TCP reader that hit a truncated frame):
/// already-delivered messages still drain, but a `take` that would block
/// fails instead of hanging the collective forever.
pub(crate) struct Mailbox {
    state: std::sync::Mutex<MailboxState>,
    cv: std::sync::Condvar,
}

struct MailboxState {
    queues: std::collections::HashMap<(usize, u64), std::collections::VecDeque<PooledBuf>>,
    poison: Option<String>,
}

impl Default for Mailbox {
    fn default() -> Self {
        Mailbox {
            state: std::sync::Mutex::new(MailboxState {
                queues: std::collections::HashMap::new(),
                poison: None,
            }),
            cv: std::sync::Condvar::new(),
        }
    }
}

impl Mailbox {
    /// Queue a message. Frames arrive as [`PooledBuf`]s so pool-aware
    /// fabrics hand storage through the mailbox without copying; plain
    /// `Vec` producers wrap with [`PooledBuf::from_vec`].
    pub(crate) fn put(&self, from: usize, tag: u64, payload: PooledBuf) {
        let mut st = self.state.lock().unwrap();
        st.queues.entry((from, tag)).or_default().push_back(payload);
        self.cv.notify_all();
    }

    pub(crate) fn take(&self, from: usize, tag: u64) -> Result<PooledBuf> {
        self.take_deadline(from, tag, None)
    }

    /// Like `take`, but with an optional deadline: a take that would still
    /// be blocked after `timeout` fails with an error naming the absent
    /// peer, instead of hanging the collective forever behind a dead rank.
    pub(crate) fn take_deadline(
        &self,
        from: usize,
        tag: u64,
        timeout: Option<std::time::Duration>,
    ) -> Result<PooledBuf> {
        let deadline = timeout.map(|t| std::time::Instant::now() + t);
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(dq) = st.queues.get_mut(&(from, tag)) {
                if let Some(p) = dq.pop_front() {
                    return Ok(p);
                }
            }
            if let Some(why) = &st.poison {
                anyhow::bail!("mailbox poisoned: {why}");
            }
            match deadline {
                None => st = self.cv.wait(st).unwrap(),
                Some(d) => {
                    let now = std::time::Instant::now();
                    if now >= d {
                        anyhow::bail!(
                            "recv deadline expired waiting on rank {from} (tag {tag:#x}): \
                             peer is dead or stalled"
                        );
                    }
                    let (guard, _timed_out) = self.cv.wait_timeout(st, d - now).unwrap();
                    st = guard;
                }
            }
        }
    }

    /// Mark the mailbox broken and wake every blocked `take`. The first
    /// cause wins; queued messages remain consumable.
    pub(crate) fn poison(&self, why: impl Into<String>) {
        let mut st = self.state.lock().unwrap();
        if st.poison.is_none() {
            st.poison = Some(why.into());
        }
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tag_fields_do_not_collide() {
        let a = tag(tags::REDUCE_SCATTER, 1, 2);
        let b = tag(tags::ALL_GATHER, 1, 2);
        let c = tag(tags::REDUCE_SCATTER, 2, 2);
        let d = tag(tags::REDUCE_SCATTER, 1, 3);
        let all = [a, b, c, d];
        for i in 0..all.len() {
            for j in i + 1..all.len() {
                assert_ne!(all[i], all[j]);
            }
        }
    }

    #[test]
    fn tag_step_wraps_at_24_bits() {
        // steps beyond 2^24 reuse tag space — documented behavior; just
        // check masking is what we think it is.
        assert_eq!(tag(1, 0x0100_0000, 0), tag(1, 0, 0));
    }

    #[test]
    fn poisoned_mailbox_drains_then_fails() {
        let mb = Mailbox::default();
        mb.put(0, 1, PooledBuf::from_vec(b"ok".to_vec()));
        mb.poison("truncated frame");
        // Messages delivered before the poison still drain...
        assert_eq!(&*mb.take(0, 1).unwrap(), b"ok");
        // ...but a take that would block fails instead of hanging.
        let err = mb.take(0, 1).unwrap_err().to_string();
        assert!(err.contains("truncated frame"), "{err}");
    }

    #[test]
    fn take_deadline_expires_naming_the_absent_rank() {
        let mb = Mailbox::default();
        let err = mb
            .take_deadline(4, 7, Some(std::time::Duration::from_millis(30)))
            .unwrap_err()
            .to_string();
        assert!(err.contains("rank 4"), "{err}");
        assert!(err.contains("deadline"), "{err}");
    }

    #[test]
    fn take_deadline_delivers_when_message_arrives_in_time() {
        let mb = std::sync::Arc::new(Mailbox::default());
        let mb2 = std::sync::Arc::clone(&mb);
        let t = std::thread::spawn(move || {
            mb2.take_deadline(1, 2, Some(std::time::Duration::from_secs(5)))
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        mb.put(1, 2, PooledBuf::from_vec(b"late but in time".to_vec()));
        assert_eq!(&*t.join().unwrap().unwrap(), b"late but in time");
    }

    #[test]
    fn poison_wakes_blocked_takers() {
        let mb = std::sync::Arc::new(Mailbox::default());
        let mb2 = std::sync::Arc::clone(&mb);
        let t = std::thread::spawn(move || mb2.take(3, 9));
        std::thread::sleep(std::time::Duration::from_millis(20));
        mb.poison("reader died");
        assert!(t.join().unwrap().is_err());
    }
}
