//! Token-bucket NIC model.
//!
//! Each *server* has one egress shaper at the provisioned rate (p3dn: one
//! 100 Gbps NIC shared by its 8 GPUs). Intra-node traffic (NVLink) is not
//! charged. A `time_scale > 1` slows the emulated network down uniformly so
//! 100 Gbps-class experiments fit on a loopback interface; as long as the
//! compute phase is scaled by the same factor, scaling factors are
//! invariant (both phases stretch equally).

use crate::net::metrics::NetCounters;
use crate::topology::{LinkClass, Topology, WorkerId};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Per-server egress token bucket state.
struct Bucket {
    /// Time at which the NIC is next free (virtual serialization point).
    next_free: Instant,
}

/// The NIC model shared by all endpoints of a fabric.
pub struct Shaper {
    topo: Topology,
    /// Bytes/second actually granted on the wire (after time scaling and
    /// any effective-bandwidth model applied by the caller).
    rate_bytes_per_sec: f64,
    /// Fixed per-message latency (propagation + stack traversal), seconds.
    latency_s: f64,
    buckets: Vec<Mutex<Bucket>>,
    counters: Arc<NetCounters>,
}

impl Shaper {
    /// `rate_bytes_per_sec` is the *emulated wall-clock* rate, i.e.
    /// `provisioned / time_scale`.
    pub fn new(topo: Topology, rate_bytes_per_sec: f64, latency_s: f64) -> Shaper {
        assert!(rate_bytes_per_sec > 0.0);
        let now = Instant::now();
        Shaper {
            topo,
            rate_bytes_per_sec,
            latency_s,
            buckets: (0..topo.servers).map(|_| Mutex::new(Bucket { next_free: now })).collect(),
            counters: Arc::new(NetCounters::new(topo.servers)),
        }
    }

    /// Counters for utilization measurement (Fig 4).
    pub fn counters(&self) -> Arc<NetCounters> {
        Arc::clone(&self.counters)
    }

    /// The configured rate in bytes/sec.
    pub fn rate(&self) -> f64 {
        self.rate_bytes_per_sec
    }

    /// Admit `bytes` from `from` to `to`: blocks the sender for the
    /// serialization delay if the message crosses the network. Returns the
    /// time actually spent blocked.
    pub fn admit(&self, from: WorkerId, to: WorkerId, bytes: u64) -> Duration {
        if self.topo.link_class(from, to) == LinkClass::IntraNode {
            // NVLink-class: counted but never throttled.
            self.counters.record_intra(bytes);
            return Duration::ZERO;
        }
        let server = self.topo.server_of(from).0;
        let serialization = Duration::from_secs_f64(bytes as f64 / self.rate_bytes_per_sec);
        let start = Instant::now();
        let wake = {
            let mut b = self.buckets[server].lock().unwrap();
            let begin = if b.next_free > start { b.next_free } else { start };
            b.next_free = begin + serialization;
            b.next_free
        };
        let wake = wake + Duration::from_secs_f64(self.latency_s);
        let now = Instant::now();
        if wake > now {
            std::thread::sleep(wake - now);
        }
        self.counters.record_egress(server, bytes);
        start.elapsed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo22() -> Topology {
        Topology::new(2, 2)
    }

    #[test]
    fn intra_node_is_free() {
        let s = Shaper::new(topo22(), 1e6, 0.0);
        let d = s.admit(WorkerId(0), WorkerId(1), 10_000_000);
        assert_eq!(d, Duration::ZERO);
    }

    #[test]
    fn inter_node_is_paced_at_rate() {
        // 1 MB/s; send 200 KB across servers → ~200 ms.
        let s = Shaper::new(topo22(), 1e6, 0.0);
        let t0 = Instant::now();
        s.admit(WorkerId(0), WorkerId(2), 200_000);
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt > 0.15 && dt < 0.4, "dt={dt}");
    }

    #[test]
    fn egress_is_serialized_per_server() {
        // Two workers on server 0 both send across: the second waits for
        // the first's serialization slot.
        let s = Arc::new(Shaper::new(topo22(), 1e6, 0.0));
        let t0 = Instant::now();
        let s2 = Arc::clone(&s);
        let h = std::thread::spawn(move || {
            s2.admit(WorkerId(1), WorkerId(3), 100_000);
        });
        s.admit(WorkerId(0), WorkerId(2), 100_000);
        h.join().unwrap();
        let dt = t0.elapsed().as_secs_f64();
        // 200 KB total through one 1 MB/s NIC → ≥ ~200 ms.
        assert!(dt > 0.17, "dt={dt}");
    }

    #[test]
    fn latency_added_once_per_message() {
        let s = Shaper::new(topo22(), 1e9, 0.05);
        let t0 = Instant::now();
        s.admit(WorkerId(0), WorkerId(2), 10);
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt >= 0.05 && dt < 0.2, "dt={dt}");
    }

    #[test]
    fn counters_accumulate() {
        let s = Shaper::new(topo22(), 1e9, 0.0);
        s.admit(WorkerId(0), WorkerId(2), 1000);
        s.admit(WorkerId(0), WorkerId(3), 500);
        s.admit(WorkerId(0), WorkerId(1), 123); // intra
        let c = s.counters();
        assert_eq!(c.egress_bytes(0), 1500);
        assert_eq!(c.intra_bytes(), 123);
    }
}
