//! Token-bucket NIC model.
//!
//! Each *server* has one egress shaper at the provisioned rate (p3dn: one
//! 100 Gbps NIC shared by its 8 GPUs). Intra-node traffic (NVLink) is not
//! charged. A `time_scale > 1` slows the emulated network down uniformly so
//! 100 Gbps-class experiments fit on a loopback interface; as long as the
//! compute phase is scaled by the same factor, scaling factors are
//! invariant (both phases stretch equally).
//!
//! Multi-tenant mode: [`Shaper::register_flow`] + [`Shaper::admit_weighted`]
//! add weighted fair sharing on top of the same fabric — N tenants (jobs)
//! contending for one NIC each get `weight / Σ(active weights)` of the
//! rate, the fluid-model approximation of WFQ at per-message granularity.
//! Capacity is conserved: a lone flow gets the full rate, concurrent flows
//! split it, and the sum of grants never exceeds the provisioned rate.
//! This is what the `multi_tenant_contention` scenario (and the `netbn
//! serve` job service it exists for) measures.

use crate::net::metrics::NetCounters;
use crate::topology::{LinkClass, Topology, WorkerId};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Per-server egress token bucket state.
struct Bucket {
    /// Time at which the NIC is next free (virtual serialization point).
    next_free: Instant,
}

/// One tenant's share of the NIC in weighted mode.
struct Flow {
    /// Relative priority weight (> 0); shares are weight-proportional.
    weight: f64,
    /// Time at which this flow's last admitted message finishes.
    next_free: Instant,
}

/// Handle to a registered tenant flow (see [`Shaper::register_flow`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FlowId(usize);

/// The NIC model shared by all endpoints of a fabric.
pub struct Shaper {
    topo: Topology,
    /// Bytes/second actually granted on the wire (after time scaling and
    /// any effective-bandwidth model applied by the caller).
    rate_bytes_per_sec: f64,
    /// Fixed per-message latency (propagation + stack traversal), seconds.
    latency_s: f64,
    buckets: Vec<Mutex<Bucket>>,
    /// Tenant flows for weighted mode; one lock — contention between
    /// tenants is the phenomenon being modeled, not an artifact.
    flows: Mutex<Vec<Flow>>,
    counters: Arc<NetCounters>,
}

impl Shaper {
    /// `rate_bytes_per_sec` is the *emulated wall-clock* rate, i.e.
    /// `provisioned / time_scale`.
    pub fn new(topo: Topology, rate_bytes_per_sec: f64, latency_s: f64) -> Shaper {
        assert!(rate_bytes_per_sec > 0.0);
        let now = Instant::now();
        Shaper {
            topo,
            rate_bytes_per_sec,
            latency_s,
            buckets: (0..topo.servers).map(|_| Mutex::new(Bucket { next_free: now })).collect(),
            flows: Mutex::new(Vec::new()),
            counters: Arc::new(NetCounters::new(topo.servers)),
        }
    }

    /// Counters for utilization measurement (Fig 4).
    pub fn counters(&self) -> Arc<NetCounters> {
        Arc::clone(&self.counters)
    }

    /// The configured rate in bytes/sec.
    pub fn rate(&self) -> f64 {
        self.rate_bytes_per_sec
    }

    /// Admit `bytes` from `from` to `to`: blocks the sender for the
    /// serialization delay if the message crosses the network. Returns the
    /// time actually spent blocked.
    pub fn admit(&self, from: WorkerId, to: WorkerId, bytes: u64) -> Duration {
        if self.topo.link_class(from, to) == LinkClass::IntraNode {
            // NVLink-class: counted but never throttled.
            self.counters.record_intra(bytes);
            return Duration::ZERO;
        }
        let server = self.topo.server_of(from).0;
        let serialization = Duration::from_secs_f64(bytes as f64 / self.rate_bytes_per_sec);
        let start = Instant::now();
        let wake = {
            let mut b = self.buckets[server].lock().unwrap();
            let begin = if b.next_free > start { b.next_free } else { start };
            b.next_free = begin + serialization;
            b.next_free
        };
        let wake = wake + Duration::from_secs_f64(self.latency_s);
        let now = Instant::now();
        if wake > now {
            std::thread::sleep(wake - now);
        }
        self.counters.record_egress(server, bytes);
        start.elapsed()
    }

    /// Register a tenant flow with a relative priority `weight` (> 0)
    /// for use with [`Shaper::admit_weighted`].
    pub fn register_flow(&self, weight: f64) -> FlowId {
        assert!(weight > 0.0 && weight.is_finite(), "flow weight must be finite and > 0");
        let mut flows = self.flows.lock().unwrap();
        flows.push(Flow { weight, next_free: Instant::now() });
        FlowId(flows.len() - 1)
    }

    /// Admit `bytes` on behalf of tenant `flow`: like [`Shaper::admit`],
    /// but the serialization rate is this flow's weighted fair share of
    /// the NIC, `rate x weight / Σ(weights of active flows)`. A flow is
    /// *active* while it still has an admitted message in flight, so a
    /// lone sender gets the full rate and concurrent senders split it in
    /// proportion to their weights — the fluid WFQ approximation at
    /// message granularity (shares rebalance per admitted message, not
    /// mid-message). Returns the time spent blocked.
    pub fn admit_weighted(&self, flow: FlowId, from: WorkerId, to: WorkerId, bytes: u64) -> Duration {
        if self.topo.link_class(from, to) == LinkClass::IntraNode {
            self.counters.record_intra(bytes);
            return Duration::ZERO;
        }
        let server = self.topo.server_of(from).0;
        let start = Instant::now();
        let wake = {
            let mut flows = self.flows.lock().unwrap();
            let mut active_weight = 0.0;
            for (i, f) in flows.iter().enumerate() {
                if i == flow.0 || f.next_free > start {
                    active_weight += f.weight;
                }
            }
            let f = &mut flows[flow.0];
            let share = f.weight / active_weight;
            let serialization =
                Duration::from_secs_f64(bytes as f64 / (self.rate_bytes_per_sec * share));
            let begin = if f.next_free > start { f.next_free } else { start };
            f.next_free = begin + serialization;
            f.next_free
        };
        let wake = wake + Duration::from_secs_f64(self.latency_s);
        let now = Instant::now();
        if wake > now {
            std::thread::sleep(wake - now);
        }
        self.counters.record_egress(server, bytes);
        start.elapsed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo22() -> Topology {
        Topology::new(2, 2)
    }

    #[test]
    fn intra_node_is_free() {
        let s = Shaper::new(topo22(), 1e6, 0.0);
        let d = s.admit(WorkerId(0), WorkerId(1), 10_000_000);
        assert_eq!(d, Duration::ZERO);
    }

    #[test]
    fn inter_node_is_paced_at_rate() {
        // 1 MB/s; send 200 KB across servers → ~200 ms.
        let s = Shaper::new(topo22(), 1e6, 0.0);
        let t0 = Instant::now();
        s.admit(WorkerId(0), WorkerId(2), 200_000);
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt > 0.15 && dt < 0.4, "dt={dt}");
    }

    #[test]
    fn egress_is_serialized_per_server() {
        // Two workers on server 0 both send across: the second waits for
        // the first's serialization slot.
        let s = Arc::new(Shaper::new(topo22(), 1e6, 0.0));
        let t0 = Instant::now();
        let s2 = Arc::clone(&s);
        let h = std::thread::spawn(move || {
            s2.admit(WorkerId(1), WorkerId(3), 100_000);
        });
        s.admit(WorkerId(0), WorkerId(2), 100_000);
        h.join().unwrap();
        let dt = t0.elapsed().as_secs_f64();
        // 200 KB total through one 1 MB/s NIC → ≥ ~200 ms.
        assert!(dt > 0.17, "dt={dt}");
    }

    #[test]
    fn latency_added_once_per_message() {
        let s = Shaper::new(topo22(), 1e9, 0.05);
        let t0 = Instant::now();
        s.admit(WorkerId(0), WorkerId(2), 10);
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt >= 0.05 && dt < 0.2, "dt={dt}");
    }

    /// Stream `msgs` back-to-back messages of `bytes` each through
    /// `flow`; returns the wall seconds from `t0` to completion.
    fn stream(s: &Arc<Shaper>, flow: FlowId, msgs: usize, bytes: u64, t0: Instant) -> f64 {
        for _ in 0..msgs {
            s.admit_weighted(flow, WorkerId(0), WorkerId(2), bytes);
        }
        t0.elapsed().as_secs_f64()
    }

    #[test]
    fn solo_weighted_flow_gets_the_full_rate() {
        // 1 MB/s, one registered flow, 200 KB: ~200 ms, same as admit().
        let s = Shaper::new(topo22(), 1e6, 0.0);
        let f = s.register_flow(1.0);
        let t0 = Instant::now();
        s.admit_weighted(f, WorkerId(0), WorkerId(2), 200_000);
        let dt = t0.elapsed().as_secs_f64();
        assert!(dt > 0.15 && dt < 0.45, "dt={dt}");
        // Intra-node stays free in weighted mode too.
        assert_eq!(s.admit_weighted(f, WorkerId(0), WorkerId(1), 10_000_000), Duration::ZERO);
    }

    #[test]
    fn equal_weights_split_capacity_without_losing_it() {
        // Two always-backlogged equal flows, 200 KB each through 1 MB/s:
        // capacity conservation means ~400 ms total (not ~800 ms as a
        // naive half-rate-each-always model would give, not < 400 ms as
        // an over-granting model would).
        let s = Arc::new(Shaper::new(topo22(), 1e6, 0.0));
        let fa = s.register_flow(1.0);
        let fb = s.register_flow(1.0);
        let t0 = Instant::now();
        let s2 = Arc::clone(&s);
        let h = std::thread::spawn(move || stream(&s2, fb, 10, 20_000, t0));
        let da = stream(&s, fa, 10, 20_000, t0);
        let db = h.join().unwrap();
        let total = da.max(db);
        assert!(total > 0.32 && total < 0.8, "da={da} db={db}");
    }

    #[test]
    fn higher_weight_finishes_first() {
        // 3:1 weights, same demand: the heavy flow must complete well
        // before the light one (shares ~0.75 vs ~0.25 while both are
        // backlogged, then the survivor gets the full rate).
        let s = Arc::new(Shaper::new(topo22(), 1e6, 0.0));
        let heavy = s.register_flow(3.0);
        let light = s.register_flow(1.0);
        let t0 = Instant::now();
        let s2 = Arc::clone(&s);
        let h = std::thread::spawn(move || stream(&s2, light, 10, 20_000, t0));
        let d_heavy = stream(&s, heavy, 10, 20_000, t0);
        let d_light = h.join().unwrap();
        assert!(
            d_heavy < d_light * 0.85,
            "heavy flow not prioritized: heavy={d_heavy} light={d_light}"
        );
    }

    #[test]
    fn counters_accumulate() {
        let s = Shaper::new(topo22(), 1e9, 0.0);
        s.admit(WorkerId(0), WorkerId(2), 1000);
        s.admit(WorkerId(0), WorkerId(3), 500);
        s.admit(WorkerId(0), WorkerId(1), 123); // intra
        let c = s.counters();
        assert_eq!(c.egress_bytes(0), 1500);
        assert_eq!(c.intra_bytes(), 123);
    }
}
