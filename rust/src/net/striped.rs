//! Multi-stream **striped** transport — the paper's §2.4 bottleneck,
//! repaired.
//!
//! The paper's root cause: a kernel-TCP/Horovod-class transport is a
//! *single* effective software pipeline that tops out near 32 Gbps of a
//! 100 Gbps NIC. The known network-level fix (Sun et al., "ImageNet/
//! AlexNet in 1.5 Minutes") is to stripe every large tensor across N
//! parallel connections so N pipelines drain the same NIC. This module
//! implements both sides of that argument:
//!
//! * **Mechanistic** — [`StripedTransport`] /
//!   [`StripedEndpoint`](struct@StripedEndpoint): a real striping layer
//!   over any [`Endpoint`] fabric (in-proc or TCP). Each logical message
//!   is split into `streams` contiguous stripes, each stripe is pipelined
//!   as fixed-size chunks on its own lane (= its own connection and
//!   mailbox), and a credit window bounds the bytes in flight per lane.
//!   Collectives run on it unchanged: it *is* an `Endpoint`.
//! * **Analytic** — [`StripedModel`]: the [`KernelTcpModel`]-style
//!   effective-bandwidth model of the same design, so the §3 simulator
//!   and the emulator stay apples-to-apples (`fig4_recovered`,
//!   `transport_ablation`, `chunk_size_sweep` scenarios).
//!
//! Wire protocol (per logical message, both ends derive the identical
//! layout from the total length and the shared [`StripeConfig`]):
//!
//! ```text
//! fused (total <= chunk):
//!   lane 0, one frame: [total_len u64 LE][payload]
//! striped (total > chunk, any stream count including 1):
//!   lane 0, frame 0:   [total_len u64 LE]          (header only)
//!   lane 0, rest:      raw chunks of stripe 0
//!   lane l >= 1:       raw chunks of stripe l
//! credits:             empty frames receiver -> sender, same tag with
//!                      the high kind bit set (collective kinds < 0x80)
//! ```
//!
//! The header-only first frame makes every payload chunk identical on
//! the wire — the receiver copies each exactly once, straight into the
//! caller's buffer on the [`Endpoint::recv_into`] path (the old format
//! piggybacked chunk 0 on the length prefix, which forced an extra
//! buffered copy of the first chunk and broke down for single-stream
//! multi-chunk messages).
//!
//! Senders never block the caller: `send` validates, copies the stripes
//! into buffers from the endpoint's [`BufPool`] (steady state: zero
//! allocations — the pool recycles) and enqueues them to per-lane
//! sender threads (this is what keeps a symmetric ring — everyone
//! sending before anyone receives — free of credit deadlock). Fused
//! frames go out as one gathered write (`[prefix][payload]` via
//! [`Endpoint::send_vectored`], no concatenation). A lane sender that
//! fails records the fault; later `send`/`recv` calls on the endpoint
//! report it.
//!
//! **Known limitation**: lane failures are reported per lane. If lanes
//! fail *asymmetrically* mid-message (one lane's mailbox poisons while
//! siblings saw clean closes), `recv` surfaces the failed lane's error
//! only after its scoped sibling receivers return — siblings blocked on
//! chunks that will never arrive keep the call pending. Single-fabric
//! failure domains (loopback TCP, in-proc) poison whole-process-wise, so
//! this arises only with genuinely independent per-lane links.
//!
//! **Ordering contract** (narrower than the raw fabrics): once a message
//! is large enough to stripe past the credit window, the receiver must
//! consume a peer's messages in send order — a stalled striped message
//! holds its lane's FIFO queue, so receiving a *later* tag first would
//! deadlock on credits. Fused (single-chunk) messages never wait for
//! credits and stay fully order-free across tags. Every collective in
//! [`crate::collectives`] consumes per-peer traffic in send order, so
//! they all run unchanged on either transport.

use super::buf::{BufPool, PooledBuf};
use super::Endpoint;
use crate::collectives::split_points;
use crate::net::kernel_tcp::KernelTcpModel;
use crate::topology::WorkerId;
use crate::Result;
use std::io::IoSlice;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Credits reuse the data tag with this kind bit set; collective tag kinds
/// ([`crate::net::tags`]) stay below 0x80, so the spaces never collide.
const CREDIT_KIND_BIT: u64 = 0x80 << 56;

fn credit_tag(tag: u64) -> u64 {
    tag | CREDIT_KIND_BIT
}

/// Striping knobs shared (and independently derived) by both endpoints.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StripeConfig {
    /// Parallel connections per peer pair.
    pub streams: usize,
    /// Fixed pipelining unit within one stripe.
    pub chunk_bytes: usize,
    /// Chunks in flight per lane before the sender waits for a credit.
    pub credit_window: usize,
}

impl Default for StripeConfig {
    fn default() -> Self {
        StripeConfig { streams: 8, chunk_bytes: 256 << 10, credit_window: 4 }
    }
}

impl StripeConfig {
    /// Default chunking/credits with an explicit stream count.
    pub fn with_streams(streams: usize) -> StripeConfig {
        StripeConfig { streams, ..Default::default() }
    }

    /// Reject configurations the wire protocol cannot carry.
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.streams >= 1, "stripe streams must be >= 1");
        anyhow::ensure!(self.streams <= 256, "stripe streams capped at 256, got {}", self.streams);
        anyhow::ensure!(
            self.chunk_bytes >= self.streams,
            "chunk_bytes ({}) must be >= streams ({}) so no stripe is ever empty",
            self.chunk_bytes,
            self.streams
        );
        anyhow::ensure!(self.credit_window >= 1, "credit window must be >= 1");
        Ok(())
    }

    /// Rescale the chunk size for a payload-scaled emulation (see
    /// [`crate::trainer`]) so the pipelining *shape* survives the byte
    /// shrink. Any positive scale is accepted (schemas only guarantee
    /// `payload-scale > 0`); floors keep chunks meaningful and stripes
    /// non-empty.
    pub fn scaled(&self, payload_scale: f64) -> StripeConfig {
        assert!(payload_scale > 0.0 && payload_scale.is_finite());
        let chunk = ((self.chunk_bytes as f64 / payload_scale) as usize)
            .max(4096)
            .max(self.streams);
        StripeConfig { chunk_bytes: chunk, ..*self }
    }
}

/// Per-lane egress pacing: the mechanistic stand-in for the kernel-TCP
/// *per-pipeline* software ceiling (each stream is one pipeline; N
/// streams escape it N-fold until the NIC shaper binds). The rate lives
/// in a shared atomic (f64 bits) so the endpoint can retune it mid-run —
/// the autotuning scenarios use this to model a NIC rate change.
struct RateGate {
    rate_bits: Arc<AtomicU64>,
    next_free: Mutex<Instant>,
}

impl RateGate {
    fn new(rate_bits: Arc<AtomicU64>) -> RateGate {
        RateGate { rate_bits, next_free: Mutex::new(Instant::now()) }
    }

    fn admit(&self, bytes: usize) {
        let rate = f64::from_bits(self.rate_bits.load(Ordering::SeqCst));
        let serialization = Duration::from_secs_f64(bytes as f64 / rate);
        let wake = {
            let mut nf = self.next_free.lock().unwrap();
            let now = Instant::now();
            let begin = if *nf > now { *nf } else { now };
            *nf = begin + serialization;
            *nf
        };
        let now = Instant::now();
        if wake > now {
            std::thread::sleep(wake - now);
        }
    }
}

/// How a stripe frames on its lane.
enum JobKind {
    /// The whole message in one `[total][payload]` frame on lane 0.
    Fused,
    /// Lane 0's stripe: a header-only `[total]` frame, then raw chunks.
    Lead { total: u64 },
    /// Lane >= 1 stripe: raw chunks only.
    Tail,
}

/// One enqueued stripe, its payload held in a pooled buffer that
/// returns to the endpoint's [`BufPool`] once the lane sender drains it.
struct SendJob {
    to: WorkerId,
    tag: u64,
    kind: JobKind,
    data: PooledBuf,
}

/// The striped transport strategy (see module docs). Implements
/// [`crate::net::transport::Transport`]; bind it over `streams` fabric
/// lanes with [`crate::net::transport::TransportFabric`]. All endpoints
/// bound from one transport share its stripe buffer pool.
pub struct StripedTransport {
    cfg: StripeConfig,
    per_stream_rate_bytes_per_sec: Option<f64>,
    pool: BufPool,
}

impl StripedTransport {
    pub fn new(cfg: StripeConfig) -> StripedTransport {
        StripedTransport { cfg, per_stream_rate_bytes_per_sec: None, pool: BufPool::new() }
    }

    /// Cap each stream's egress at `rate_bytes_per_sec` — the mechanistic
    /// counterpart of the kernel-TCP software ceiling, *per pipeline*.
    /// With 1 stream this reproduces the broken single-stream transport;
    /// with N it recovers up to N× until the NIC shaper binds.
    pub fn with_stream_ceiling(cfg: StripeConfig, rate_bytes_per_sec: f64) -> StripedTransport {
        assert!(
            rate_bytes_per_sec > 0.0 && rate_bytes_per_sec.is_finite(),
            "stream ceiling must be a positive rate"
        );
        StripedTransport {
            cfg,
            per_stream_rate_bytes_per_sec: Some(rate_bytes_per_sec),
            pool: BufPool::new(),
        }
    }

    /// Like [`StripedTransport::new`] with an explicit (possibly shared)
    /// stripe buffer pool — the counting-pool tests inject one to prove
    /// the send path stops allocating after warmup.
    pub fn with_pool(cfg: StripeConfig, pool: BufPool) -> StripedTransport {
        StripedTransport { cfg, per_stream_rate_bytes_per_sec: None, pool }
    }

    pub fn config(&self) -> StripeConfig {
        self.cfg
    }

    /// The stripe buffer pool shared by every endpoint bound from this
    /// transport.
    pub fn pool(&self) -> &BufPool {
        &self.pool
    }
}

impl StripedTransport {
    /// [`crate::net::transport::Transport::bind`] with the concrete
    /// endpoint type — callers that need the runtime tuning surface
    /// ([`StripedEndpoint::set_chunk_bytes`],
    /// [`StripedEndpoint::set_stream_rate_bytes_per_sec`]) bind through
    /// here; the trait object path delegates to it.
    pub fn bind_striped(&self, lanes: Vec<Arc<dyn Endpoint>>) -> Result<Arc<StripedEndpoint>> {
        self.cfg.validate()?;
        anyhow::ensure!(
            lanes.len() == self.cfg.streams,
            "striped transport binds {} lanes, got {}",
            self.cfg.streams,
            lanes.len()
        );
        let me = lanes[0].me();
        let world = lanes[0].world();
        for (i, l) in lanes.iter().enumerate() {
            anyhow::ensure!(
                l.me() == me && l.world() == world,
                "stripe lane {i} disagrees on identity ({} of {} vs {me} of {world})",
                l.me(),
                l.world()
            );
        }
        let fault: Arc<Mutex<Option<String>>> = Arc::new(Mutex::new(None));
        let chunk_bytes = Arc::new(AtomicUsize::new(self.cfg.chunk_bytes));
        let stream_rate = self
            .per_stream_rate_bytes_per_sec
            .map(|r| Arc::new(AtomicU64::new(r.to_bits())));
        let mut tx = Vec::with_capacity(lanes.len());
        for (i, lane) in lanes.iter().enumerate() {
            let (job_tx, job_rx) = mpsc::channel::<SendJob>();
            let ep = Arc::clone(lane);
            let gate = stream_rate.as_ref().map(|r| RateGate::new(Arc::clone(r)));
            let cfg = self.cfg;
            let fault = Arc::clone(&fault);
            let chunk = Arc::clone(&chunk_bytes);
            std::thread::spawn(move || lane_sender(i, job_rx, ep, gate, cfg, chunk, fault));
            tx.push(Mutex::new(job_tx));
        }
        Ok(Arc::new(StripedEndpoint {
            me,
            world,
            lanes,
            cfg: self.cfg,
            chunk_bytes,
            stream_rate,
            tx,
            fault,
            pool: self.pool.clone(),
        }))
    }
}

impl crate::net::transport::Transport for StripedTransport {
    fn name(&self) -> String {
        format!("striped:{}", self.cfg.streams)
    }

    fn lanes(&self) -> usize {
        self.cfg.streams
    }

    fn bind(&self, lanes: Vec<Arc<dyn Endpoint>>) -> Result<Arc<dyn Endpoint>> {
        let ep = self.bind_striped(lanes)?;
        Ok(ep as Arc<dyn Endpoint>)
    }
}

/// Per-lane sender thread: drains jobs FIFO, paces through the optional
/// stream gate, honors the credit window. Exits when the endpoint drops.
/// The chunk size is re-read per job from the endpoint's shared atomic —
/// see [`StripedEndpoint::set_chunk_bytes`].
fn lane_sender(
    lane: usize,
    rx: mpsc::Receiver<SendJob>,
    ep: Arc<dyn Endpoint>,
    gate: Option<RateGate>,
    cfg: StripeConfig,
    chunk_bytes: Arc<AtomicUsize>,
    fault: Arc<Mutex<Option<String>>>,
) {
    // Per-lane wire-time histogram (one registry resolution per lane
    // lifetime, atomics per job) — `netbn bench --json` and the serve
    // `/metrics` exposition both surface it, so lane skew is visible
    // without turning span tracing on.
    let send_us =
        crate::obs::metrics::global().histo("wire.lane.send_us", &[("lane", &lane.to_string())]);
    while let Ok(job) = rx.recv() {
        let chunk = chunk_bytes.load(Ordering::SeqCst);
        let t0 = std::time::Instant::now();
        let sent = send_job(ep.as_ref(), gate.as_ref(), &cfg, chunk, &job);
        send_us.record(t0.elapsed().as_micros() as u64);
        if let Err(e) = sent {
            let why = format!("lane {lane} sender to {}: {e:#}", job.to);
            crate::log_error!("net::striped", "{why}");
            let mut f = fault.lock().unwrap();
            if f.is_none() {
                *f = Some(why);
            }
            return;
        }
    }
}

fn send_job(
    ep: &dyn Endpoint,
    gate: Option<&RateGate>,
    cfg: &StripeConfig,
    chunk: usize,
    job: &SendJob,
) -> Result<()> {
    // Wire-busy span for the whole stripe: gate pacing, credit waits and
    // the fabric writes are all time the lane is occupied by this job.
    // The tag's step field ([kind:8][step:24][sub:32]) attributes it.
    let _span = crate::obs::span::enter_bytes(
        "wire.send",
        ep.me().0 as u32,
        ((job.tag >> 32) & 0xFF_FFFF) as u32,
        job.data.len() as u64,
    );
    let ct = credit_tag(job.tag);
    match job.kind {
        JobKind::Fused => {
            // One gathered frame: length prefix + payload slice, no
            // concatenation. Fused messages never wait for credits.
            let prefix = (job.data.len() as u64).to_le_bytes();
            if let Some(g) = gate {
                g.admit(8 + job.data.len());
            }
            return ep.send_vectored(
                job.to,
                job.tag,
                &[IoSlice::new(&prefix), IoSlice::new(&job.data)],
            );
        }
        JobKind::Lead { total } => {
            // Header-only first frame announces the logical length; the
            // stripe itself follows as raw chunks like every other lane.
            let prefix = total.to_le_bytes();
            if let Some(g) = gate {
                g.admit(prefix.len());
            }
            ep.send(job.to, job.tag, &prefix)?;
        }
        JobKind::Tail => {}
    }
    let mut sent = 0usize;
    let mut off = 0usize;
    while off < job.data.len() {
        let end = (off + chunk).min(job.data.len());
        if sent >= cfg.credit_window {
            // Wait for the receiver to free a slot in the window.
            ep.recv(job.to, ct)?;
        }
        if let Some(g) = gate {
            g.admit(end - off);
        }
        ep.send(job.to, job.tag, &job.data[off..end])?;
        sent += 1;
        off = end;
    }
    Ok(())
}

/// The endpoint collectives see: `send` stripes and enqueues, `recv`
/// reassembles (spawning one scoped thread per extra lane).
pub struct StripedEndpoint {
    me: WorkerId,
    world: usize,
    lanes: Vec<Arc<dyn Endpoint>>,
    cfg: StripeConfig,
    /// Live chunk size — all send/recv paths read this instead of
    /// `cfg.chunk_bytes`, so the autotuner can retune it at quiesced step
    /// boundaries (see [`StripedEndpoint::set_chunk_bytes`]).
    chunk_bytes: Arc<AtomicUsize>,
    /// Live per-stream gate rate (f64 bits), when a ceiling is modeled.
    stream_rate: Option<Arc<AtomicU64>>,
    tx: Vec<Mutex<mpsc::Sender<SendJob>>>,
    fault: Arc<Mutex<Option<String>>>,
    /// Stripe staging buffers; shared with the transport (and through it,
    /// with every sibling endpoint) so steady-state traffic recycles
    /// instead of allocating per chunk.
    pool: BufPool,
}

impl StripedEndpoint {
    fn check_fault(&self) -> Result<()> {
        if let Some(why) = self.fault.lock().unwrap().clone() {
            anyhow::bail!("striped transport fault: {why}");
        }
        Ok(())
    }

    /// The chunk size currently in force.
    pub fn chunk_bytes(&self) -> usize {
        self.chunk_bytes.load(Ordering::SeqCst)
    }

    /// Retune the pipelining chunk size. **Safety contract**: both ends
    /// of every peer pair must apply the same value while no striped
    /// message is in flight — sender and receiver derive the chunk layout
    /// independently, so a mid-message change would surface as a loud
    /// frame-size mismatch. The launch loop guarantees this by applying
    /// knob changes only at barrier-synchronized step boundaries after
    /// all collectives have drained.
    pub fn set_chunk_bytes(&self, bytes: usize) -> Result<()> {
        // streams >= 1 is validated at bind, so this also implies >= 1.
        anyhow::ensure!(
            bytes >= self.cfg.streams,
            "chunk_bytes ({bytes}) must be >= streams ({})",
            self.cfg.streams
        );
        self.chunk_bytes.store(bytes, Ordering::SeqCst);
        Ok(())
    }

    /// Retune the modeled per-stream software ceiling (no-op when the
    /// endpoint was built without a gate). Takes effect on the next
    /// admitted chunk — the `autotune_adapt` launch scenario drops this
    /// mid-run to model a NIC rate change.
    pub fn set_stream_rate_bytes_per_sec(&self, rate: f64) -> Result<()> {
        anyhow::ensure!(rate > 0.0 && rate.is_finite(), "stream rate must be positive");
        if let Some(bits) = &self.stream_rate {
            bits.store(rate.to_bits(), Ordering::SeqCst);
        }
        Ok(())
    }

    /// Whether a per-stream rate gate is active.
    pub fn has_stream_gate(&self) -> bool {
        self.stream_rate.is_some()
    }

    /// The pool staging this endpoint's stripes — exposed so tests (and
    /// telemetry) can check reuse/leak counters.
    pub fn pool(&self) -> &BufPool {
        &self.pool
    }

    fn enqueue(&self, lane: usize, job: SendJob) -> Result<()> {
        self.tx[lane]
            .lock()
            .unwrap()
            .send(job)
            .map_err(|_| anyhow::anyhow!("stripe lane {lane} sender thread is gone"))
    }

    /// Receive one lane's stripe: every chunk lands straight in `out`
    /// via [`Endpoint::recv_into`] — the frame buffer recycles through
    /// the lane fabric's pool, and nothing is copied twice. All lanes
    /// (including lane 0, whose header frame [`Self::recv_first`]
    /// already consumed) are symmetric.
    fn recv_stripe(
        &self,
        lane: usize,
        from: WorkerId,
        tag: u64,
        out: &mut [u8],
        chunk: usize,
    ) -> Result<()> {
        let ep = self.lanes[lane].as_ref();
        let ct = credit_tag(tag);
        let window = self.cfg.credit_window;
        let n_chunks = out.len().div_ceil(chunk).max(1);
        let mut off = 0usize;
        let mut k = 0usize;
        while off < out.len() {
            let want = chunk.min(out.len() - off);
            let got = ep.recv_into(from, tag, &mut out[off..off + want])?;
            anyhow::ensure!(
                got == want,
                "striped chunk {k}/{n_chunks} on lane {lane}: {got} bytes, want {want}"
            );
            off += want;
            if k + window < n_chunks {
                ep.send(from, ct, &[])?;
            }
            k += 1;
        }
        Ok(())
    }

    /// Common validation for the receive paths.
    fn check_recv(&self, from: WorkerId, tag: u64) -> Result<()> {
        anyhow::ensure!(from.0 < self.world, "recv from out-of-range worker {from}");
        anyhow::ensure!(
            tag & CREDIT_KIND_BIT == 0,
            "tag kind bit 0x80 is reserved for stripe credits"
        );
        self.check_fault()
    }

    /// Consume lane 0's first frame. Fused messages (`total <= chunk`)
    /// arrive whole — the frame is returned. Striped messages announce
    /// themselves with a header-only frame — `None` is returned and the
    /// payload follows as raw chunks on every lane.
    fn recv_first(
        &self,
        from: WorkerId,
        tag: u64,
        chunk: usize,
    ) -> Result<(usize, Option<PooledBuf>)> {
        let first = self.lanes[0].recv_buf(from, tag)?;
        anyhow::ensure!(
            first.len() >= 8,
            "striped frame missing length prefix ({} bytes)",
            first.len()
        );
        let total = u64::from_le_bytes(first[..8].try_into().unwrap()) as usize;
        if total <= chunk {
            anyhow::ensure!(
                first.len() == 8 + total,
                "fused striped frame: {} bytes, want {}",
                first.len(),
                8 + total
            );
            Ok((total, Some(first)))
        } else {
            anyhow::ensure!(
                first.len() == 8,
                "striped header frame: {} bytes, want 8",
                first.len()
            );
            Ok((total, None))
        }
    }

    /// Reassemble a striped body straight into `out` (`out.len()` is the
    /// announced total), one scoped receiver thread per extra lane.
    fn recv_body(&self, from: WorkerId, tag: u64, chunk: usize, out: &mut [u8]) -> Result<()> {
        let stripes = split_points(out.len(), self.cfg.streams);
        let mut slices = Vec::with_capacity(stripes.len());
        let mut rest = out;
        for r in &stripes {
            let (head, tail) = rest.split_at_mut(r.len());
            slices.push(head);
            rest = tail;
        }
        let mut iter = slices.into_iter();
        let lead = iter.next().expect("streams >= 1");
        std::thread::scope(|sc| -> Result<()> {
            let mut handles = Vec::new();
            for (i, slice) in iter.enumerate() {
                let lane = i + 1;
                handles.push(sc.spawn(move || self.recv_stripe(lane, from, tag, slice, chunk)));
            }
            let lead_res = self.recv_stripe(0, from, tag, lead, chunk);
            for h in handles {
                h.join().map_err(|_| anyhow::anyhow!("stripe receiver panicked"))??;
            }
            lead_res
        })
    }
}

impl Endpoint for StripedEndpoint {
    fn me(&self) -> WorkerId {
        self.me
    }

    fn world(&self) -> usize {
        self.world
    }

    fn send(&self, to: WorkerId, tag: u64, payload: &[u8]) -> Result<()> {
        anyhow::ensure!(to.0 < self.world, "send to out-of-range worker {to}");
        anyhow::ensure!(
            tag & CREDIT_KIND_BIT == 0,
            "tag kind bit 0x80 is reserved for stripe credits"
        );
        self.check_fault()?;
        let total = payload.len();
        if total <= self.chunk_bytes() {
            let mut buf = self.pool.get(total);
            buf.copy_from_slice(payload);
            return self.enqueue(0, SendJob { to, tag, kind: JobKind::Fused, data: buf });
        }
        // `split_points` is shared with the receive path (and the ring
        // collective): both ends MUST derive the identical stripe layout.
        for (lane, r) in split_points(total, self.cfg.streams).iter().enumerate() {
            let mut buf = self.pool.get(r.len());
            buf.copy_from_slice(&payload[r.clone()]);
            let kind =
                if lane == 0 { JobKind::Lead { total: total as u64 } } else { JobKind::Tail };
            self.enqueue(lane, SendJob { to, tag, kind, data: buf })?;
        }
        Ok(())
    }

    fn send_vectored(&self, to: WorkerId, tag: u64, iov: &[IoSlice<'_>]) -> Result<()> {
        anyhow::ensure!(to.0 < self.world, "send to out-of-range worker {to}");
        anyhow::ensure!(
            tag & CREDIT_KIND_BIT == 0,
            "tag kind bit 0x80 is reserved for stripe credits"
        );
        self.check_fault()?;
        let total: usize = iov.iter().map(|s| s.len()).sum();
        if total <= self.chunk_bytes() {
            let mut buf = self.pool.get(total);
            let mut off = 0usize;
            for s in iov {
                buf[off..off + s.len()].copy_from_slice(s);
                off += s.len();
            }
            return self.enqueue(0, SendJob { to, tag, kind: JobKind::Fused, data: buf });
        }
        // Scatter the iovec straight into per-lane stripe buffers — the
        // concatenated message never materializes.
        let stripes = split_points(total, self.cfg.streams);
        let mut bufs: Vec<PooledBuf> = stripes.iter().map(|r| self.pool.get(r.len())).collect();
        let mut lane = 0usize;
        let mut gpos = 0usize;
        for s in iov {
            let mut sp = 0usize;
            while sp < s.len() {
                while gpos >= stripes[lane].end {
                    lane += 1;
                }
                let r = &stripes[lane];
                let n = (r.end - gpos).min(s.len() - sp);
                bufs[lane][gpos - r.start..gpos - r.start + n].copy_from_slice(&s[sp..sp + n]);
                sp += n;
                gpos += n;
            }
        }
        for (lane, buf) in bufs.into_iter().enumerate() {
            let kind =
                if lane == 0 { JobKind::Lead { total: total as u64 } } else { JobKind::Tail };
            self.enqueue(lane, SendJob { to, tag, kind, data: buf })?;
        }
        Ok(())
    }

    fn recv(&self, from: WorkerId, tag: u64) -> Result<Vec<u8>> {
        self.check_recv(from, tag)?;
        // One consistent chunk size for the whole message (the set_chunk
        // contract guarantees sender and receiver agree on it).
        let chunk = self.chunk_bytes();
        let (total, fused) = self.recv_first(from, tag, chunk)?;
        if let Some(first) = fused {
            return Ok(first[8..].to_vec());
        }
        let mut buf = vec![0u8; total];
        self.recv_body(from, tag, chunk, &mut buf)?;
        Ok(buf)
    }

    fn recv_into(&self, from: WorkerId, tag: u64, dst: &mut [u8]) -> Result<usize> {
        self.check_recv(from, tag)?;
        let chunk = self.chunk_bytes();
        let (total, fused) = self.recv_first(from, tag, chunk)?;
        anyhow::ensure!(
            total <= dst.len(),
            "recv_into: striped message of {total} bytes exceeds dst of {}",
            dst.len()
        );
        if let Some(first) = fused {
            dst[..total].copy_from_slice(&first[8..]);
            return Ok(total);
        }
        // Chunks land straight in `dst`: no message-sized staging buffer.
        self.recv_body(from, tag, chunk, &mut dst[..total])?;
        Ok(total)
    }
}

// ---------------------------------------------------------------------------
// Analytic model
// ---------------------------------------------------------------------------

/// Effective-bandwidth model of the striped transport, mirroring
/// [`KernelTcpModel`] so the §3 simulator can swap transports and stay
/// comparable with the emulator.
///
/// Each stream is one kernel-TCP software pipeline; `n` streams raise the
/// aggregate software ceiling to `n·C·(1 − loss·(n−1))` (stripe
/// coordination and scheduler interference eat a little of each extra
/// stream), composed with the provisioned rate by the same power-mean as
/// the single-stream model. Chunk granularity enters through
/// [`StripedModel::transfer_time_chunked`]: tiny chunks pay per-chunk
/// software cost, huge chunks lose the store-and-forward overlap.
#[derive(Clone, Copy, Debug)]
pub struct StripedModel {
    /// The single-pipeline software model each stream runs on.
    pub per_stream: KernelTcpModel,
    pub streams: usize,
    /// Fractional aggregate-ceiling loss per extra stream.
    pub coord_loss_per_stream: f64,
    /// Fixed per-message stripe setup (scatter/gather bookkeeping).
    pub setup_overhead_s: f64,
    /// Per-chunk software cost on each stream's pipeline.
    pub per_chunk_overhead_s: f64,
    /// Fraction of the final chunk's serialization that cannot overlap
    /// with delivery (store-and-forward tail at the receiver).
    pub delivery_tail_frac: f64,
    /// Default chunk size for [`StripedModel::transfer_time_s`].
    pub chunk_bytes: f64,
}

impl StripedModel {
    /// Calibrated default with `n` streams; `with_streams(1)` coincides
    /// with the single-stream [`KernelTcpModel::default`] ceiling.
    pub fn with_streams(n: usize) -> StripedModel {
        StripedModel {
            per_stream: KernelTcpModel::default(),
            streams: n.max(1),
            coord_loss_per_stream: 0.004,
            setup_overhead_s: 20e-6,
            per_chunk_overhead_s: 10e-6,
            delivery_tail_frac: 0.5,
            chunk_bytes: (256 << 10) as f64,
        }
    }

    /// Aggregate software ceiling across all streams, Gbps.
    pub fn aggregate_ceiling_gbps(&self) -> f64 {
        let n = self.streams as f64;
        let efficiency = (1.0 - self.coord_loss_per_stream * (n - 1.0)).max(0.25);
        self.per_stream.ceiling_gbps * n * efficiency
    }

    /// Effective achievable throughput (Gbps) at a provisioned rate —
    /// same power-mean composition as [`KernelTcpModel::effective_gbps`].
    pub fn effective_gbps(&self, provisioned_gbps: f64) -> f64 {
        assert!(provisioned_gbps > 0.0);
        let p = self.per_stream.knee;
        let c = self.aggregate_ceiling_gbps();
        (provisioned_gbps.powf(-p) + c.powf(-p)).powf(-1.0 / p)
    }

    /// Utilization of the provisioned bandwidth (Fig 4's y-axis).
    pub fn utilization(&self, provisioned_gbps: f64) -> f64 {
        self.effective_gbps(provisioned_gbps) / provisioned_gbps
    }

    /// Time to move `bytes` once at the default chunk size.
    pub fn transfer_time_s(&self, bytes: f64, provisioned_gbps: f64) -> f64 {
        self.transfer_time_chunked(bytes, provisioned_gbps, self.chunk_bytes)
    }

    /// Time to move `bytes` once with an explicit chunk size (the
    /// `chunk_size_sweep` scenario's x-axis).
    pub fn transfer_time_chunked(&self, bytes: f64, provisioned_gbps: f64, chunk_bytes: f64) -> f64 {
        assert!(chunk_bytes > 0.0 && bytes >= 0.0);
        let n = self.streams as f64;
        let rate = crate::gbps_to_bytes_per_sec(self.effective_gbps(provisioned_gbps));
        let stripe = bytes / n;
        let n_chunks = (stripe / chunk_bytes).ceil().max(1.0);
        let stream_rate = rate / n;
        let tail = self.delivery_tail_frac * stripe.min(chunk_bytes) / stream_rate;
        self.setup_overhead_s
            + self.per_stream.per_msg_overhead_s
            + bytes / rate
            + n_chunks * self.per_chunk_overhead_s
            + tail
    }

    /// Effective one-shot throughput (Gbps) for a message of `bytes` at a
    /// given chunk size.
    pub fn effective_throughput_gbps(&self, bytes: f64, provisioned_gbps: f64, chunk_bytes: f64) -> f64 {
        crate::bytes_per_sec_to_gbps(bytes / self.transfer_time_chunked(bytes, provisioned_gbps, chunk_bytes))
    }

    /// Collapse to the [`KernelTcpModel`] interface the simulator
    /// consumes — this is what keeps simulator and emulator
    /// apples-to-apples on the striped path.
    pub fn to_kernel_model(&self) -> KernelTcpModel {
        KernelTcpModel {
            ceiling_gbps: self.aggregate_ceiling_gbps(),
            knee: self.per_stream.knee,
            per_msg_overhead_s: self.per_stream.per_msg_overhead_s + self.setup_overhead_s,
            cpu_frac_per_gbps: self.per_stream.cpu_frac_per_gbps,
            cpu_frac_base: self.per_stream.cpu_frac_base
                * (1.0 + 0.05 * (self.streams as f64 - 1.0)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::transport::{Transport, TransportFabric};
    use std::sync::Arc;

    fn striped_pair(cfg: StripeConfig) -> Vec<Arc<dyn Endpoint>> {
        let t = StripedTransport::new(cfg);
        TransportFabric::inproc(2, &t, None).unwrap().endpoints()
    }

    #[test]
    fn small_message_fused_round_trip() {
        let eps = striped_pair(StripeConfig::with_streams(4));
        let (a, b) = (Arc::clone(&eps[0]), Arc::clone(&eps[1]));
        let t = std::thread::spawn(move || b.recv(WorkerId(0), 7).unwrap());
        a.send(WorkerId(1), 7, b"small").unwrap();
        assert_eq!(t.join().unwrap(), b"small");
    }

    #[test]
    fn empty_message_round_trip() {
        let eps = striped_pair(StripeConfig::with_streams(3));
        let (a, b) = (Arc::clone(&eps[0]), Arc::clone(&eps[1]));
        let t = std::thread::spawn(move || b.recv(WorkerId(0), 1).unwrap());
        a.send(WorkerId(1), 1, &[]).unwrap();
        assert_eq!(t.join().unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn large_message_striped_round_trip() {
        // 1 MB across 4 streams with 32 KB chunks: 8 chunks per stripe,
        // more than the credit window — exercises the credit path.
        let cfg = StripeConfig { streams: 4, chunk_bytes: 32 << 10, credit_window: 2 };
        let eps = striped_pair(cfg);
        let payload: Vec<u8> = (0..1_000_003u32).map(|i| (i % 251) as u8).collect();
        let want = payload.clone();
        let (a, b) = (Arc::clone(&eps[0]), Arc::clone(&eps[1]));
        let t = std::thread::spawn(move || b.recv(WorkerId(0), 9).unwrap());
        a.send(WorkerId(1), 9, &payload).unwrap();
        assert_eq!(t.join().unwrap(), want);
        // Every lane's sender recorded its wire time in the global
        // registry (labels survive into the exposition format).
        let text = crate::obs::metrics::global().render_text();
        for lane in 0..4 {
            assert!(
                text.contains(&format!("wire.lane.send_us{{lane=\"{lane}\"")),
                "missing lane {lane} histogram:\n{text}"
            );
        }
    }

    #[test]
    fn mixed_sizes_in_send_order() {
        // A multi-chunk message followed by a fused one on the same peer
        // pair, consumed in send order (the contract collectives follow).
        let cfg = StripeConfig { streams: 2, chunk_bytes: 1 << 10, credit_window: 2 };
        let eps = striped_pair(cfg);
        let big: Vec<u8> = vec![0xAB; 10_000];
        let (a, b) = (Arc::clone(&eps[0]), Arc::clone(&eps[1]));
        let want = big.clone();
        let t = std::thread::spawn(move || {
            let big = b.recv(WorkerId(0), 1).unwrap();
            let small = b.recv(WorkerId(0), 2).unwrap();
            (big, small)
        });
        a.send(WorkerId(1), 1, &big).unwrap();
        a.send(WorkerId(1), 2, b"tiny").unwrap();
        let (got_big, small) = t.join().unwrap();
        assert_eq!(small, b"tiny");
        assert_eq!(got_big, want);
    }

    #[test]
    fn fused_messages_allow_out_of_order_tags() {
        // Single-chunk (fused) messages never wait for credits, so tag
        // matching stays fully order-free for them — the inproc/tcp
        // contract small control traffic (barriers) relies on.
        let eps = striped_pair(StripeConfig::with_streams(4));
        let (a, b) = (Arc::clone(&eps[0]), Arc::clone(&eps[1]));
        a.send(WorkerId(1), 1, b"first").unwrap();
        a.send(WorkerId(1), 2, b"second").unwrap();
        assert_eq!(b.recv(WorkerId(0), 2).unwrap(), b"second");
        assert_eq!(b.recv(WorkerId(0), 1).unwrap(), b"first");
    }

    #[test]
    fn symmetric_exchange_does_not_deadlock() {
        // Both sides send a multi-window message before either receives —
        // the ring pattern. Async lane senders make this safe.
        let cfg = StripeConfig { streams: 2, chunk_bytes: 1 << 10, credit_window: 1 };
        let eps = striped_pair(cfg);
        let payload = vec![7u8; 50_000];
        let mut handles = Vec::new();
        for (i, ep) in eps.into_iter().enumerate() {
            let p = payload.clone();
            handles.push(std::thread::spawn(move || {
                let peer = WorkerId(1 - i);
                ep.send(peer, 5, &p).unwrap();
                ep.recv(peer, 5).unwrap()
            }));
        }
        for h in handles {
            assert_eq!(h.join().unwrap(), payload);
        }
    }

    #[test]
    fn chunk_size_retunes_between_messages() {
        // The autotune surface: both ends retune at a quiesced boundary,
        // traffic keeps flowing and reassembling bit-exactly. One
        // InProcFabric per lane keeps the lanes independent, exactly like
        // TransportFabric::inproc builds them.
        let cfg = StripeConfig { streams: 2, chunk_bytes: 8 << 10, credit_window: 2 };
        let t = StripedTransport::new(cfg);
        let lane_fabs =
            [crate::net::inproc::InProcFabric::new(2), crate::net::inproc::InProcFabric::new(2)];
        let mut pairs: Vec<Vec<Arc<dyn Endpoint>>> = vec![Vec::new(), Vec::new()];
        for fab in &lane_fabs {
            for (w, ep) in inner_lane(fab).into_iter().enumerate() {
                pairs[w].push(ep);
            }
        }
        let b = t.bind_striped(pairs.pop().unwrap()).unwrap();
        let a = t.bind_striped(pairs.pop().unwrap()).unwrap();
        assert_eq!(a.chunk_bytes(), 8 << 10);
        let payload: Vec<u8> = (0..60_000u32).map(|i| (i % 253) as u8).collect();
        let want = payload.clone();
        let b2 = Arc::clone(&b);
        let h = std::thread::spawn(move || b2.recv(WorkerId(0), 1).unwrap());
        a.send(WorkerId(1), 1, &payload).unwrap();
        assert_eq!(h.join().unwrap(), want);
        // Retune both ends, then move the same payload again.
        a.set_chunk_bytes(2 << 10).unwrap();
        b.set_chunk_bytes(2 << 10).unwrap();
        assert_eq!(a.chunk_bytes(), 2 << 10);
        let want2 = payload.clone();
        let b3 = Arc::clone(&b);
        let h = std::thread::spawn(move || b3.recv(WorkerId(0), 2).unwrap());
        a.send(WorkerId(1), 2, &payload).unwrap();
        assert_eq!(h.join().unwrap(), want2);
        // Degenerate chunk sizes are rejected.
        assert!(a.set_chunk_bytes(1).is_err());
    }

    #[test]
    fn stream_rate_retunes_live() {
        // Gate starts punitive (1 KB/s would take ~100 s for 1 KB×100);
        // raising it before any traffic means the send completes fast.
        let cfg = StripeConfig { streams: 1, chunk_bytes: 16 << 10, credit_window: 4 };
        let t = StripedTransport::with_stream_ceiling(cfg, 1e3);
        let inner = crate::net::inproc::InProcFabric::new(2);
        let mut eps = inner_lane(&inner);
        let b_lane = eps.pop().unwrap();
        let a_lane = eps.pop().unwrap();
        let a = t.bind_striped(vec![a_lane]).unwrap();
        let b = t.bind_striped(vec![b_lane]).unwrap();
        assert!(a.has_stream_gate());
        a.set_stream_rate_bytes_per_sec(1e9).unwrap();
        b.set_stream_rate_bytes_per_sec(1e9).unwrap();
        let b2 = Arc::clone(&b);
        let h = std::thread::spawn(move || b2.recv(WorkerId(0), 3).unwrap());
        let t0 = Instant::now();
        a.send(WorkerId(1), 3, &vec![9u8; 100_000]).unwrap();
        assert_eq!(h.join().unwrap(), vec![9u8; 100_000]);
        assert!(t0.elapsed().as_secs_f64() < 5.0, "gate retune did not take effect");
        assert!(a.set_stream_rate_bytes_per_sec(-1.0).is_err());
        // Without a gate, setting the rate is a tolerated no-op.
        let ungated = StripedTransport::new(StripeConfig::with_streams(1));
        let inner2 = crate::net::inproc::InProcFabric::new(2);
        let ep = inner_lane(&inner2).remove(0);
        let u = ungated.bind_striped(vec![ep]).unwrap();
        assert!(!u.has_stream_gate());
        u.set_stream_rate_bytes_per_sec(1e6).unwrap();
    }

    /// Endpoints of an in-proc fabric as trait objects (test helper).
    fn inner_lane(fab: &crate::net::inproc::InProcFabric) -> Vec<Arc<dyn Endpoint>> {
        use crate::net::Fabric as _;
        fab.endpoints()
    }

    #[test]
    fn reserved_credit_bit_rejected() {
        let eps = striped_pair(StripeConfig::with_streams(2));
        assert!(eps[0].send(WorkerId(1), CREDIT_KIND_BIT | 3, b"x").is_err());
    }

    #[test]
    fn stream_ceiling_paces_single_stream() {
        // 1 stream gated at 1 MB/s: 100 KB takes >= ~80 ms.
        let cfg = StripeConfig { streams: 1, chunk_bytes: 16 << 10, credit_window: 4 };
        let t = StripedTransport::with_stream_ceiling(cfg, 1e6);
        let eps = TransportFabric::inproc(2, &t, None).unwrap().endpoints();
        let (a, b) = (Arc::clone(&eps[0]), Arc::clone(&eps[1]));
        let h = std::thread::spawn(move || b.recv(WorkerId(0), 1).unwrap());
        let t0 = Instant::now();
        a.send(WorkerId(1), 1, &vec![0u8; 100_000]).unwrap();
        h.join().unwrap();
        assert!(t0.elapsed().as_secs_f64() > 0.08);
    }

    #[test]
    fn config_validation() {
        assert!(StripeConfig::with_streams(0).validate().is_err());
        assert!(StripeConfig { streams: 8, chunk_bytes: 4, credit_window: 1 }.validate().is_err());
        assert!(StripeConfig { streams: 8, chunk_bytes: 1 << 20, credit_window: 0 }
            .validate()
            .is_err());
        assert!(StripeConfig::default().validate().is_ok());
    }

    #[test]
    fn scaled_config_keeps_floors() {
        let c = StripeConfig::default().scaled(1024.0);
        assert!(c.chunk_bytes >= 4096);
        assert_eq!(c.streams, 8);
    }

    // ---- analytic model ----

    #[test]
    fn one_stream_matches_kernel_tcp_ceiling() {
        let striped = StripedModel::with_streams(1);
        let single = KernelTcpModel::default();
        for bw in [1.0, 10.0, 100.0] {
            let d = (striped.effective_gbps(bw) - single.effective_gbps(bw)).abs();
            assert!(d < 1e-9, "bw={bw}: {d}");
        }
    }

    #[test]
    fn striped8_recovers_2x_at_100g() {
        // The PR's acceptance criterion, at the model level.
        let striped = StripedModel::with_streams(8);
        let single = KernelTcpModel::default();
        let speedup = striped.effective_gbps(100.0) / single.effective_gbps(100.0);
        assert!(speedup >= 2.0, "speedup {speedup}");
        assert!(striped.utilization(100.0) > 0.85, "{}", striped.utilization(100.0));
    }

    #[test]
    fn effective_monotone_in_streams() {
        let mut last = 0.0;
        for n in [1usize, 2, 4, 8, 16] {
            let e = StripedModel::with_streams(n).effective_gbps(100.0);
            assert!(e >= last, "n={n}: {e} < {last}");
            last = e;
        }
    }

    #[test]
    fn full_utilization_at_low_speed() {
        let m = StripedModel::with_streams(8);
        assert!(m.utilization(1.0) > 0.99);
        assert!(m.utilization(10.0) > 0.99);
    }

    #[test]
    fn chunk_sweep_has_interior_optimum() {
        // Tiny chunks pay per-chunk overhead; huge chunks lose overlap.
        let m = StripedModel::with_streams(8);
        let bytes = 64e6;
        let tp = |chunk: f64| m.effective_throughput_gbps(bytes, 100.0, chunk);
        let tiny = tp(16.0 * 1024.0);
        let best = tp(512.0 * 1024.0);
        let huge = tp(16.0 * 1024.0 * 1024.0);
        assert!(best > tiny, "best {best} vs tiny {tiny}");
        assert!(best > huge, "best {best} vs huge {huge}");
    }

    #[test]
    fn to_kernel_model_preserves_ceiling() {
        let m = StripedModel::with_streams(8);
        let k = m.to_kernel_model();
        assert_eq!(k.ceiling_gbps, m.aggregate_ceiling_gbps());
        assert!(k.per_msg_overhead_s > m.per_stream.per_msg_overhead_s);
    }
}
