//! Real-socket fabric: every worker owns a loopback `TcpListener`; peers
//! connect lazily on first send. Frames are `[from u64][tag u64][len u64]
//! [payload]`. One reader thread per accepted connection dispatches into
//! the shared tag-matched [`Mailbox`]. A reader that hits a truncated or
//! garbage frame logs the cause and **poisons** its mailbox, so a broken
//! connection fails the collective with an error instead of hanging it.
//!
//! This is the emulation path where actual kernel TCP sits on the
//! communication phase — the same stack the paper measured (Horovod/NCCL
//! "use Linux kernel TCP").

use super::buf::{BufPool, PooledBuf};
use super::{Endpoint, Fabric, Mailbox};
use crate::net::shaper::Shaper;
use crate::topology::WorkerId;
use crate::Result;
use anyhow::Context;
use std::collections::HashMap;
use std::io::{IoSlice, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

/// Connect to `addr`, retrying with exponential backoff until `timeout`
/// elapses. At startup a racing worker can dial a peer that has not
/// finished binding; without the retry that one refused connection used
/// to fail the whole collective. Every dial error is retried (loopback
/// cannot distinguish "not bound yet" from "never will be" at dial
/// time); the last underlying error is returned once the deadline
/// passes.
pub fn connect_retry(addr: SocketAddr, timeout: Duration) -> Result<TcpStream> {
    let deadline = Instant::now() + timeout;
    let mut backoff = Duration::from_millis(1);
    loop {
        match TcpStream::connect(addr) {
            Ok(stream) => {
                stream.set_nodelay(true).ok();
                return Ok(stream);
            }
            Err(e) => {
                let now = Instant::now();
                if now >= deadline {
                    return Err(anyhow::anyhow!(
                        "connect to {addr} failed after retrying for {:.1}s: {e}",
                        timeout.as_secs_f64()
                    ));
                }
                // Never sleep past the deadline: a listener that binds
                // inside the caller's budget gets one final attempt.
                thread::sleep(backoff.min(deadline - now));
                backoff = (backoff * 2).min(Duration::from_millis(250));
            }
        }
    }
}

/// Default patience for [`connect_retry`] on the lazy send path: long
/// enough for a slow peer process to bind, short enough that a genuinely
/// dead peer fails the collective promptly.
pub(crate) const CONNECT_TIMEOUT: Duration = Duration::from_secs(10);

struct Shared {
    addrs: Vec<SocketAddr>,
    mailboxes: Vec<Mailbox>,
    shaper: Option<Arc<Shaper>>,
    closed: AtomicBool,
    /// Frame storage for the reader threads: payloads land in pooled
    /// buffers and recycle when receivers consume them via
    /// `recv_buf`/`recv_into`.
    pool: BufPool,
}

/// A fabric of `n` workers connected over loopback TCP.
pub struct TcpFabric {
    shared: Arc<Shared>,
    accept_handles: Vec<thread::JoinHandle<()>>,
}

impl TcpFabric {
    /// Bind listeners and start accept loops. `shaper` throttles egress to
    /// the modeled NIC rate (None = unshaped loopback).
    pub fn new(n: usize, shaper: Option<Arc<Shaper>>) -> Result<TcpFabric> {
        assert!(n >= 1);
        let mut listeners = Vec::with_capacity(n);
        let mut addrs = Vec::with_capacity(n);
        for _ in 0..n {
            let l = TcpListener::bind("127.0.0.1:0").context("bind loopback listener")?;
            addrs.push(l.local_addr()?);
            listeners.push(l);
        }
        let shared = Arc::new(Shared {
            addrs,
            mailboxes: (0..n).map(|_| Mailbox::default()).collect(),
            shaper,
            closed: AtomicBool::new(false),
            pool: BufPool::new(),
        });
        let mut accept_handles = Vec::with_capacity(n);
        for (owner, listener) in listeners.into_iter().enumerate() {
            let shared = Arc::clone(&shared);
            accept_handles.push(thread::spawn(move || accept_loop(owner, listener, shared)));
        }
        Ok(TcpFabric { shared, accept_handles })
    }

    /// Close listeners and join accept threads. Reader threads exit when
    /// their peer streams close (endpoints dropped).
    pub fn shutdown(&mut self) {
        if self.shared.closed.swap(true, Ordering::SeqCst) {
            return;
        }
        // Wake each accept loop with a dummy connection.
        for addr in &self.shared.addrs {
            let _ = TcpStream::connect(addr);
        }
        for h in self.accept_handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for TcpFabric {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(owner: usize, listener: TcpListener, shared: Arc<Shared>) {
    loop {
        let (stream, _) = match listener.accept() {
            Ok(s) => s,
            Err(_) => return,
        };
        if shared.closed.load(Ordering::SeqCst) {
            return;
        }
        let shared2 = Arc::clone(&shared);
        thread::spawn(move || reader_loop(owner, stream, shared2));
    }
}

/// Largest frame a reader will accept — a generous multiple of the
/// largest legitimate message (a full uncompressed VGG16 gradient is
/// ~527 MB). A corrupt or hostile header beyond this poisons the mailbox
/// instead of attempting a multi-GiB allocation that would abort the
/// process.
const MAX_FRAME_BYTES: usize = 1 << 30; // 1 GiB

pub(crate) fn reader_loop_into(
    owner: usize,
    mut stream: TcpStream,
    world: usize,
    mailbox: &Mailbox,
    pool: &BufPool,
) {
    let _ = stream.set_nodelay(true);
    loop {
        match read_frame(&mut stream, world, pool) {
            Ok(Some((from, tag, payload))) => mailbox.put(from, tag, payload),
            Ok(None) => return, // clean close at a frame boundary
            Err(e) => {
                crate::log_error!(
                    "net::tcp",
                    "worker {owner}: frame decode failed: {e:#}; poisoning mailbox"
                );
                mailbox.poison(format!("worker {owner} reader: {e:#}"));
                return;
            }
        }
    }
}

/// A truncated or garbage frame means bytes are gone for good:
/// [`reader_loop_into`] poisons the mailbox so blocked recvs fail loudly
/// instead of hanging the collective. The multi-process mesh fabric
/// ([`crate::net::mesh`]) shares the same loop over its own mailbox.
fn reader_loop(owner: usize, stream: TcpStream, shared: Arc<Shared>) {
    reader_loop_into(owner, stream, shared.addrs.len(), &shared.mailboxes[owner], &shared.pool);
}

/// Write one `[from u64][tag u64][len u64][payload]` frame — the wire
/// format shared by [`TcpFabric`] and the multi-process mesh fabric.
/// Header and payload go out in one gathered `write_vectored` (no
/// copy-then-write, and usually one syscall instead of two).
pub(crate) fn write_frame(
    stream: &mut TcpStream,
    from: usize,
    tag: u64,
    payload: &[u8],
) -> Result<()> {
    write_frame_vectored(stream, from, tag, &[IoSlice::new(payload)])
}

/// How many slices one gathered write submits (header + payload parts);
/// anything beyond is flushed sequentially. Callers today pass at most
/// 2 payload parts (stripe length prefix + chunk).
const FRAME_IOV: usize = 8;

/// Write one frame whose payload is the concatenation of `parts`,
/// without materializing it: the 24-byte header and the payload slices
/// are submitted as a single gathered write, and whatever the socket
/// did not accept is finished with per-slice `write_all`.
pub(crate) fn write_frame_vectored(
    stream: &mut TcpStream,
    from: usize,
    tag: u64,
    parts: &[IoSlice<'_>],
) -> Result<()> {
    let len: usize = parts.iter().map(|p| p.len()).sum();
    let mut header = [0u8; 24];
    header[0..8].copy_from_slice(&(from as u64).to_le_bytes());
    header[8..16].copy_from_slice(&tag.to_le_bytes());
    header[16..24].copy_from_slice(&(len as u64).to_le_bytes());
    let mut iov = [IoSlice::new(&[]); FRAME_IOV];
    iov[0] = IoSlice::new(&header);
    let n_parts = parts.len().min(FRAME_IOV - 1);
    for (i, p) in parts.iter().take(n_parts).enumerate() {
        iov[i + 1] = IoSlice::new(p);
    }
    let mut written = match stream.write_vectored(&iov[..1 + n_parts]) {
        Ok(n) => n,
        Err(e) if e.kind() == std::io::ErrorKind::Interrupted => 0,
        Err(e) => return Err(e.into()),
    };
    // Skip what the gathered write covered; write_all the remainder
    // (including any parts beyond the iov cap).
    for piece in std::iter::once(&header[..]).chain(parts.iter().map(|p| &p[..])) {
        if written >= piece.len() {
            written -= piece.len();
            continue;
        }
        stream.write_all(&piece[written..])?;
        written = 0;
    }
    Ok(())
}

/// Read one `[from][tag][len][payload]` frame. `Ok(None)` means the peer
/// closed cleanly *between* frames; a mid-frame EOF, an oversized length,
/// or an out-of-range sender is a decode error. The payload lands in a
/// buffer from `pool`, so a drained frame's storage recycles instead of
/// costing an allocation per frame.
pub(crate) fn read_frame(
    stream: &mut TcpStream,
    world: usize,
    pool: &BufPool,
) -> Result<Option<(usize, u64, PooledBuf)>> {
    let mut header = [0u8; 24];
    let mut got = 0usize;
    while got < header.len() {
        match stream.read(&mut header[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => anyhow::bail!("connection closed mid-header after {got}/24 bytes"),
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            // Even at a frame boundary, an I/O error (vs a clean FIN) can
            // mean a reset that discarded frames the kernel had already
            // buffered — poison rather than risk a silent gap. Streams
            // here are unidirectional, so healthy teardown always FINs.
            Err(e) => anyhow::bail!("read failed after {got}/24 header bytes: {e}"),
        }
    }
    let from = u64::from_le_bytes(header[0..8].try_into().unwrap()) as usize;
    let tag = u64::from_le_bytes(header[8..16].try_into().unwrap());
    let len = u64::from_le_bytes(header[16..24].try_into().unwrap()) as usize;
    anyhow::ensure!(from < world, "frame claims sender {from} in a world of {world}");
    anyhow::ensure!(len <= MAX_FRAME_BYTES, "frame length {len} exceeds {MAX_FRAME_BYTES}");
    let mut payload = pool.get(len);
    stream
        .read_exact(&mut payload)
        .map_err(|e| anyhow::anyhow!("connection closed mid-payload ({len} bytes expected): {e}"))?;
    Ok(Some((from, tag, payload)))
}

impl Fabric for TcpFabric {
    fn endpoints(&self) -> Vec<Arc<dyn Endpoint>> {
        (0..self.shared.addrs.len())
            .map(|i| {
                Arc::new(TcpEndpoint {
                    me: WorkerId(i),
                    shared: Arc::clone(&self.shared),
                    senders: Mutex::new(HashMap::new()),
                }) as Arc<dyn Endpoint>
            })
            .collect()
    }
}

struct TcpEndpoint {
    me: WorkerId,
    shared: Arc<Shared>,
    /// Lazily-opened outgoing streams, one per destination.
    senders: Mutex<HashMap<usize, Arc<Mutex<TcpStream>>>>,
}

impl TcpEndpoint {
    fn sender_to(&self, to: usize) -> Result<Arc<Mutex<TcpStream>>> {
        if let Some(s) = self.senders.lock().unwrap().get(&to) {
            return Ok(Arc::clone(s));
        }
        // Bounded retry: a racing peer may not have finished binding yet
        // (multi-process startup); a refused dial must not fail the whole
        // collective. Dialed OUTSIDE the lock so one slow peer cannot
        // stall sends to healthy ones.
        let stream = connect_retry(self.shared.addrs[to], CONNECT_TIMEOUT)
            .context("connect to peer listener")?;
        let arc = Arc::new(Mutex::new(stream));
        let mut senders = self.senders.lock().unwrap();
        // First dial wins a concurrent race; the loser closes cleanly.
        Ok(Arc::clone(senders.entry(to).or_insert(arc)))
    }
}

impl Endpoint for TcpEndpoint {
    fn me(&self) -> WorkerId {
        self.me
    }

    fn world(&self) -> usize {
        self.shared.addrs.len()
    }

    fn send(&self, to: WorkerId, tag: u64, payload: &[u8]) -> Result<()> {
        anyhow::ensure!(to.0 < self.world(), "send to out-of-range worker {to}");
        if let Some(shaper) = &self.shared.shaper {
            shaper.admit(self.me, to, payload.len() as u64);
        }
        let sender = self.sender_to(to.0)?;
        let mut stream = sender.lock().unwrap();
        write_frame(&mut stream, self.me.0, tag, payload)
    }

    fn send_vectored(&self, to: WorkerId, tag: u64, iov: &[IoSlice<'_>]) -> Result<()> {
        anyhow::ensure!(to.0 < self.world(), "send to out-of-range worker {to}");
        if let Some(shaper) = &self.shared.shaper {
            let total: usize = iov.iter().map(|s| s.len()).sum();
            shaper.admit(self.me, to, total as u64);
        }
        let sender = self.sender_to(to.0)?;
        let mut stream = sender.lock().unwrap();
        write_frame_vectored(&mut stream, self.me.0, tag, iov)
    }

    fn recv(&self, from: WorkerId, tag: u64) -> Result<Vec<u8>> {
        Ok(self.recv_buf(from, tag)?.into_vec())
    }

    fn recv_buf(&self, from: WorkerId, tag: u64) -> Result<PooledBuf> {
        anyhow::ensure!(from.0 < self.world(), "recv from out-of-range worker {from}");
        self.shared.mailboxes[self.me.0].take(from.0, tag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    #[test]
    fn ping_pong_over_sockets() {
        let fab = TcpFabric::new(2, None).unwrap();
        let eps = fab.endpoints();
        let (a, b) = (Arc::clone(&eps[0]), Arc::clone(&eps[1]));
        let t = thread::spawn(move || {
            let m = b.recv(WorkerId(0), 1).unwrap();
            b.send(WorkerId(0), 2, &m).unwrap();
        });
        a.send(WorkerId(1), 1, b"over-tcp").unwrap();
        assert_eq!(a.recv(WorkerId(1), 2).unwrap(), b"over-tcp");
        t.join().unwrap();
    }

    #[test]
    fn large_payload_round_trip() {
        let fab = TcpFabric::new(2, None).unwrap();
        let eps = fab.endpoints();
        let payload: Vec<u8> = (0..3_000_000u32).map(|i| (i % 251) as u8).collect();
        let (a, b) = (Arc::clone(&eps[0]), Arc::clone(&eps[1]));
        let want = payload.clone();
        let t = thread::spawn(move || {
            let m = b.recv(WorkerId(0), 1).unwrap();
            assert_eq!(m, want);
        });
        a.send(WorkerId(1), 1, &payload).unwrap();
        t.join().unwrap();
    }

    #[test]
    fn concurrent_ring_neighbors() {
        // 4 workers, each sends to (i+1)%4 and receives from (i-1)%4.
        let n = 4;
        let fab = TcpFabric::new(n, None).unwrap();
        let eps = fab.endpoints();
        let mut handles = Vec::new();
        for (i, ep) in eps.into_iter().enumerate() {
            handles.push(thread::spawn(move || {
                let next = WorkerId((i + 1) % n);
                let prev = WorkerId((i + n - 1) % n);
                ep.send(next, 3, &[i as u8; 1000]).unwrap();
                let got = ep.recv(prev, 3).unwrap();
                assert_eq!(got, vec![prev.0 as u8; 1000]);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn shaped_tcp_is_paced() {
        // 2 servers × 1 GPU; 1 MB/s → 100 KB takes ≥ ~80 ms.
        let topo = Topology::new(2, 1);
        let shaper = Arc::new(Shaper::new(topo, 1e6, 0.0));
        let fab = TcpFabric::new(2, Some(shaper)).unwrap();
        let eps = fab.endpoints();
        let (a, b) = (Arc::clone(&eps[0]), Arc::clone(&eps[1]));
        let t = thread::spawn(move || {
            b.recv(WorkerId(0), 1).unwrap();
        });
        let t0 = std::time::Instant::now();
        a.send(WorkerId(1), 1, &vec![0u8; 100_000]).unwrap();
        t.join().unwrap();
        assert!(t0.elapsed().as_secs_f64() > 0.08);
    }

    #[test]
    fn shutdown_joins_accept_threads() {
        let mut fab = TcpFabric::new(3, None).unwrap();
        fab.shutdown();
        fab.shutdown(); // idempotent
    }

    #[test]
    fn truncated_frame_poisons_recv_instead_of_hanging() {
        let fab = TcpFabric::new(2, None).unwrap();
        let eps = fab.endpoints();
        // Raw connection to worker 0's listener: write a header promising
        // 1000 bytes, deliver only 10, then close mid-payload.
        let mut raw = TcpStream::connect(fab.shared.addrs[0]).unwrap();
        let mut header = [0u8; 24];
        header[0..8].copy_from_slice(&1u64.to_le_bytes()); // from worker 1
        header[8..16].copy_from_slice(&42u64.to_le_bytes()); // tag
        header[16..24].copy_from_slice(&1000u64.to_le_bytes()); // len
        raw.write_all(&header).unwrap();
        raw.write_all(&[0u8; 10]).unwrap();
        drop(raw);
        let err = eps[0].recv(WorkerId(1), 42).unwrap_err().to_string();
        assert!(err.contains("poisoned"), "{err}");
    }

    #[test]
    fn garbage_length_poisons_recv() {
        let fab = TcpFabric::new(2, None).unwrap();
        let eps = fab.endpoints();
        let mut raw = TcpStream::connect(fab.shared.addrs[0]).unwrap();
        let mut header = [0u8; 24];
        header[0..8].copy_from_slice(&1u64.to_le_bytes());
        header[8..16].copy_from_slice(&7u64.to_le_bytes());
        header[16..24].copy_from_slice(&u64::MAX.to_le_bytes()); // absurd len
        raw.write_all(&header).unwrap();
        let err = eps[0].recv(WorkerId(1), 7).unwrap_err().to_string();
        assert!(err.contains("poisoned"), "{err}");
    }

    #[test]
    fn connector_before_listener_retries_until_bound() {
        // The startup race: the connector dials BEFORE the listener
        // exists. Reserve a port by binding and dropping, start the
        // connector, then bind the real listener after a delay — the
        // bounded retry must bridge the gap.
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap();
        drop(probe);
        let connector = thread::spawn(move || {
            connect_retry(addr, Duration::from_secs(5)).map(|mut s| {
                write_frame(&mut s, 0, 7, b"late-bind").unwrap();
            })
        });
        thread::sleep(Duration::from_millis(150));
        let listener = TcpListener::bind(addr).unwrap();
        let (mut conn, _) = listener.accept().unwrap();
        let pool = BufPool::new();
        let (from, tag, payload) = read_frame(&mut conn, 1, &pool).unwrap().unwrap();
        assert_eq!((from, tag, &*payload), (0, 7, &b"late-bind"[..]));
        connector.join().unwrap().unwrap();
    }

    #[test]
    fn connect_retry_gives_up_after_timeout() {
        // Nothing ever listens: the retry must return the underlying
        // error once the deadline passes, not spin forever.
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap();
        drop(probe);
        let t0 = Instant::now();
        let err = connect_retry(addr, Duration::from_millis(200)).unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(3));
        assert!(err.to_string().contains("after retrying"), "{err}");
    }

    #[test]
    fn clean_close_between_frames_does_not_poison() {
        let fab = TcpFabric::new(2, None).unwrap();
        let eps = fab.endpoints();
        // A full frame followed by a clean close: the frame is delivered
        // and nothing is poisoned.
        let mut raw = TcpStream::connect(fab.shared.addrs[0]).unwrap();
        let mut frame = Vec::new();
        frame.extend_from_slice(&1u64.to_le_bytes());
        frame.extend_from_slice(&5u64.to_le_bytes());
        frame.extend_from_slice(&3u64.to_le_bytes());
        frame.extend_from_slice(b"abc");
        raw.write_all(&frame).unwrap();
        drop(raw);
        assert_eq!(eps[0].recv(WorkerId(1), 5).unwrap(), b"abc");
    }
}
