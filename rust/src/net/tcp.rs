//! Real-socket fabric: every worker owns a loopback `TcpListener`; peers
//! connect lazily on first send. Frames are `[from u64][tag u64][len u64]
//! [payload]`. One reader thread per accepted connection dispatches into
//! the shared tag-matched [`Mailbox`].
//!
//! This is the emulation path where actual kernel TCP sits on the
//! communication phase — the same stack the paper measured (Horovod/NCCL
//! "use Linux kernel TCP").

use super::{Endpoint, Fabric, Mailbox};
use crate::net::shaper::Shaper;
use crate::topology::WorkerId;
use crate::Result;
use anyhow::Context;
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;

struct Shared {
    addrs: Vec<SocketAddr>,
    mailboxes: Vec<Mailbox>,
    shaper: Option<Arc<Shaper>>,
    closed: AtomicBool,
}

/// A fabric of `n` workers connected over loopback TCP.
pub struct TcpFabric {
    shared: Arc<Shared>,
    accept_handles: Vec<thread::JoinHandle<()>>,
}

impl TcpFabric {
    /// Bind listeners and start accept loops. `shaper` throttles egress to
    /// the modeled NIC rate (None = unshaped loopback).
    pub fn new(n: usize, shaper: Option<Arc<Shaper>>) -> Result<TcpFabric> {
        assert!(n >= 1);
        let mut listeners = Vec::with_capacity(n);
        let mut addrs = Vec::with_capacity(n);
        for _ in 0..n {
            let l = TcpListener::bind("127.0.0.1:0").context("bind loopback listener")?;
            addrs.push(l.local_addr()?);
            listeners.push(l);
        }
        let shared = Arc::new(Shared {
            addrs,
            mailboxes: (0..n).map(|_| Mailbox::default()).collect(),
            shaper,
            closed: AtomicBool::new(false),
        });
        let mut accept_handles = Vec::with_capacity(n);
        for (owner, listener) in listeners.into_iter().enumerate() {
            let shared = Arc::clone(&shared);
            accept_handles.push(thread::spawn(move || accept_loop(owner, listener, shared)));
        }
        Ok(TcpFabric { shared, accept_handles })
    }

    /// Close listeners and join accept threads. Reader threads exit when
    /// their peer streams close (endpoints dropped).
    pub fn shutdown(&mut self) {
        if self.shared.closed.swap(true, Ordering::SeqCst) {
            return;
        }
        // Wake each accept loop with a dummy connection.
        for addr in &self.shared.addrs {
            let _ = TcpStream::connect(addr);
        }
        for h in self.accept_handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for TcpFabric {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(owner: usize, listener: TcpListener, shared: Arc<Shared>) {
    loop {
        let (stream, _) = match listener.accept() {
            Ok(s) => s,
            Err(_) => return,
        };
        if shared.closed.load(Ordering::SeqCst) {
            return;
        }
        let shared2 = Arc::clone(&shared);
        thread::spawn(move || reader_loop(owner, stream, shared2));
    }
}

fn reader_loop(owner: usize, mut stream: TcpStream, shared: Arc<Shared>) {
    let _ = stream.set_nodelay(true);
    let mut header = [0u8; 24];
    loop {
        if stream.read_exact(&mut header).is_err() {
            return; // peer closed
        }
        let from = u64::from_le_bytes(header[0..8].try_into().unwrap()) as usize;
        let tag = u64::from_le_bytes(header[8..16].try_into().unwrap());
        let len = u64::from_le_bytes(header[16..24].try_into().unwrap()) as usize;
        let mut payload = vec![0u8; len];
        if stream.read_exact(&mut payload).is_err() {
            return;
        }
        shared.mailboxes[owner].put(from, tag, payload);
    }
}

impl Fabric for TcpFabric {
    fn endpoints(&self) -> Vec<Arc<dyn Endpoint>> {
        (0..self.shared.addrs.len())
            .map(|i| {
                Arc::new(TcpEndpoint {
                    me: WorkerId(i),
                    shared: Arc::clone(&self.shared),
                    senders: Mutex::new(HashMap::new()),
                }) as Arc<dyn Endpoint>
            })
            .collect()
    }
}

struct TcpEndpoint {
    me: WorkerId,
    shared: Arc<Shared>,
    /// Lazily-opened outgoing streams, one per destination.
    senders: Mutex<HashMap<usize, Arc<Mutex<TcpStream>>>>,
}

impl TcpEndpoint {
    fn sender_to(&self, to: usize) -> Result<Arc<Mutex<TcpStream>>> {
        let mut senders = self.senders.lock().unwrap();
        if let Some(s) = senders.get(&to) {
            return Ok(Arc::clone(s));
        }
        let stream =
            TcpStream::connect(self.shared.addrs[to]).context("connect to peer listener")?;
        stream.set_nodelay(true).ok();
        let arc = Arc::new(Mutex::new(stream));
        senders.insert(to, Arc::clone(&arc));
        Ok(arc)
    }
}

impl Endpoint for TcpEndpoint {
    fn me(&self) -> WorkerId {
        self.me
    }

    fn world(&self) -> usize {
        self.shared.addrs.len()
    }

    fn send(&self, to: WorkerId, tag: u64, payload: &[u8]) -> Result<()> {
        anyhow::ensure!(to.0 < self.world(), "send to out-of-range worker {to}");
        if let Some(shaper) = &self.shared.shaper {
            shaper.admit(self.me, to, payload.len() as u64);
        }
        let sender = self.sender_to(to.0)?;
        let mut stream = sender.lock().unwrap();
        let mut header = [0u8; 24];
        header[0..8].copy_from_slice(&(self.me.0 as u64).to_le_bytes());
        header[8..16].copy_from_slice(&tag.to_le_bytes());
        header[16..24].copy_from_slice(&(payload.len() as u64).to_le_bytes());
        stream.write_all(&header)?;
        stream.write_all(payload)?;
        Ok(())
    }

    fn recv(&self, from: WorkerId, tag: u64) -> Result<Vec<u8>> {
        anyhow::ensure!(from.0 < self.world(), "recv from out-of-range worker {from}");
        Ok(self.shared.mailboxes[self.me.0].take(from.0, tag))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Topology;

    #[test]
    fn ping_pong_over_sockets() {
        let fab = TcpFabric::new(2, None).unwrap();
        let eps = fab.endpoints();
        let (a, b) = (Arc::clone(&eps[0]), Arc::clone(&eps[1]));
        let t = thread::spawn(move || {
            let m = b.recv(WorkerId(0), 1).unwrap();
            b.send(WorkerId(0), 2, &m).unwrap();
        });
        a.send(WorkerId(1), 1, b"over-tcp").unwrap();
        assert_eq!(a.recv(WorkerId(1), 2).unwrap(), b"over-tcp");
        t.join().unwrap();
    }

    #[test]
    fn large_payload_round_trip() {
        let fab = TcpFabric::new(2, None).unwrap();
        let eps = fab.endpoints();
        let payload: Vec<u8> = (0..3_000_000u32).map(|i| (i % 251) as u8).collect();
        let (a, b) = (Arc::clone(&eps[0]), Arc::clone(&eps[1]));
        let want = payload.clone();
        let t = thread::spawn(move || {
            let m = b.recv(WorkerId(0), 1).unwrap();
            assert_eq!(m, want);
        });
        a.send(WorkerId(1), 1, &payload).unwrap();
        t.join().unwrap();
    }

    #[test]
    fn concurrent_ring_neighbors() {
        // 4 workers, each sends to (i+1)%4 and receives from (i-1)%4.
        let n = 4;
        let fab = TcpFabric::new(n, None).unwrap();
        let eps = fab.endpoints();
        let mut handles = Vec::new();
        for (i, ep) in eps.into_iter().enumerate() {
            handles.push(thread::spawn(move || {
                let next = WorkerId((i + 1) % n);
                let prev = WorkerId((i + n - 1) % n);
                ep.send(next, 3, &[i as u8; 1000]).unwrap();
                let got = ep.recv(prev, 3).unwrap();
                assert_eq!(got, vec![prev.0 as u8; 1000]);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn shaped_tcp_is_paced() {
        // 2 servers × 1 GPU; 1 MB/s → 100 KB takes ≥ ~80 ms.
        let topo = Topology::new(2, 1);
        let shaper = Arc::new(Shaper::new(topo, 1e6, 0.0));
        let fab = TcpFabric::new(2, Some(shaper)).unwrap();
        let eps = fab.endpoints();
        let (a, b) = (Arc::clone(&eps[0]), Arc::clone(&eps[1]));
        let t = thread::spawn(move || {
            b.recv(WorkerId(0), 1).unwrap();
        });
        let t0 = std::time::Instant::now();
        a.send(WorkerId(1), 1, &vec![0u8; 100_000]).unwrap();
        t.join().unwrap();
        assert!(t0.elapsed().as_secs_f64() > 0.08);
    }

    #[test]
    fn shutdown_joins_accept_threads() {
        let mut fab = TcpFabric::new(3, None).unwrap();
        fab.shutdown();
        fab.shutdown(); // idempotent
    }
}
