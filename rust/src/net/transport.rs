//! The transport layer: *how* a logical message traverses a fabric.
//!
//! A [`Transport`] is a strategy that binds one worker's per-lane
//! [`Endpoint`]s (one lane = one independent fabric instance, i.e. one
//! connection per peer pair) into the single endpoint the collectives
//! use. Two strategies exist:
//!
//! * [`SingleStream`] — the legacy path: one lane, passed through
//!   untouched. This is the kernel-TCP-class transport the paper measures.
//! * [`crate::net::striped::StripedTransport`] — stripes each large
//!   message across N lanes with chunk pipelining and credit flow
//!   control: the repair that recovers the provisioned bandwidth.
//!
//! [`TransportFabric`] assembles the lanes: it builds `lanes()` inner
//! fabrics (in-proc or TCP — anything implementing [`Fabric`]) and binds
//! them per worker, so every collective runs on either path via the
//! `--transport single|striped:N` config knob ([`for_kind`]).

use super::{Endpoint, Fabric};
use crate::config::TransportKind;
use crate::net::inproc::InProcFabric;
use crate::net::shaper::Shaper;
use crate::net::striped::{StripeConfig, StripedTransport};
use crate::net::tcp::TcpFabric;
use crate::Result;
use std::sync::Arc;

/// A message-transport strategy over one or more fabric lanes.
pub trait Transport: Send + Sync {
    /// Human-readable name (`single`, `striped:8`).
    fn name(&self) -> String;

    /// Independent fabric lanes (connections per peer pair) required.
    fn lanes(&self) -> usize;

    /// Bind one worker's per-lane endpoints into the endpoint the
    /// collectives use. `lanes.len() == self.lanes()`, all for the same
    /// worker.
    fn bind(&self, lanes: Vec<Arc<dyn Endpoint>>) -> Result<Arc<dyn Endpoint>>;
}

/// The legacy single-stream path: one lane, passed through untouched.
pub struct SingleStream;

impl Transport for SingleStream {
    fn name(&self) -> String {
        "single".into()
    }

    fn lanes(&self) -> usize {
        1
    }

    fn bind(&self, mut lanes: Vec<Arc<dyn Endpoint>>) -> Result<Arc<dyn Endpoint>> {
        anyhow::ensure!(
            lanes.len() == 1,
            "single-stream transport binds exactly one lane, got {}",
            lanes.len()
        );
        Ok(lanes.pop().expect("one lane"))
    }
}

/// A fabric whose messages traverse a [`Transport`]: `lanes()` inner
/// fabrics, one bound endpoint per worker. The inner fabrics are kept
/// alive (and shut down) with the `TransportFabric`.
pub struct TransportFabric {
    _inner: Vec<Box<dyn Fabric>>,
    endpoints: Vec<Arc<dyn Endpoint>>,
}

impl TransportFabric {
    /// Build `transport.lanes()` lanes with `make_lane` and bind each
    /// worker's lane endpoints through the transport.
    pub fn new(
        transport: &dyn Transport,
        mut make_lane: impl FnMut() -> Result<Box<dyn Fabric>>,
    ) -> Result<TransportFabric> {
        let lanes = transport.lanes();
        anyhow::ensure!(lanes >= 1, "transport {:?} needs >= 1 lane", transport.name());
        let inner: Vec<Box<dyn Fabric>> =
            (0..lanes).map(|_| make_lane()).collect::<Result<_>>()?;
        let per_lane: Vec<Vec<Arc<dyn Endpoint>>> = inner.iter().map(|f| f.endpoints()).collect();
        let world = per_lane[0].len();
        for (l, eps) in per_lane.iter().enumerate() {
            anyhow::ensure!(
                eps.len() == world,
                "lane {l} has {} endpoints, lane 0 has {world}",
                eps.len()
            );
        }
        let mut endpoints = Vec::with_capacity(world);
        for w in 0..world {
            let worker_lanes: Vec<Arc<dyn Endpoint>> =
                per_lane.iter().map(|eps| Arc::clone(&eps[w])).collect();
            endpoints.push(transport.bind(worker_lanes)?);
        }
        Ok(TransportFabric { _inner: inner, endpoints })
    }

    /// In-process lanes over `n` workers, all sharing one NIC shaper (the
    /// per-server token bucket stays aggregate across lanes).
    pub fn inproc(
        n: usize,
        transport: &dyn Transport,
        shaper: Option<Arc<Shaper>>,
    ) -> Result<TransportFabric> {
        TransportFabric::new(transport, || {
            Ok(Box::new(InProcFabric::with_shaper(n, shaper.clone())) as Box<dyn Fabric>)
        })
    }

    /// Loopback-TCP lanes over `n` workers — each lane is a real set of
    /// kernel-TCP connections — sharing one NIC shaper.
    pub fn tcp(
        n: usize,
        transport: &dyn Transport,
        shaper: Option<Arc<Shaper>>,
    ) -> Result<TransportFabric> {
        TransportFabric::new(transport, || {
            Ok(Box::new(TcpFabric::new(n, shaper.clone())?) as Box<dyn Fabric>)
        })
    }
}

impl Fabric for TransportFabric {
    fn endpoints(&self) -> Vec<Arc<dyn Endpoint>> {
        self.endpoints.clone()
    }
}

/// The transport strategy for a config [`TransportKind`]: `striped:N`
/// stripes, every other kind is the legacy single-stream path (their
/// differences are bandwidth *models*, not wire strategies).
pub fn for_kind(kind: TransportKind) -> Box<dyn Transport> {
    match kind {
        TransportKind::Striped { streams } => {
            Box::new(StripedTransport::new(StripeConfig::with_streams(streams)))
        }
        _ => Box::new(SingleStream),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::WorkerId;

    #[test]
    fn single_stream_passes_through() {
        let fab = TransportFabric::inproc(2, &SingleStream, None).unwrap();
        let eps = fab.endpoints();
        assert_eq!(eps.len(), 2);
        eps[0].send(WorkerId(1), 3, b"hello").unwrap();
        assert_eq!(eps[1].recv(WorkerId(0), 3).unwrap(), b"hello");
    }

    #[test]
    fn striped_fabric_builds_n_lanes() {
        let t = StripedTransport::new(StripeConfig::with_streams(3));
        assert_eq!(t.lanes(), 3);
        assert_eq!(t.name(), "striped:3");
        let fab = TransportFabric::inproc(4, &t, None).unwrap();
        assert_eq!(fab.endpoints().len(), 4);
    }

    #[test]
    fn for_kind_maps_config() {
        assert_eq!(for_kind(TransportKind::KernelTcp).name(), "single");
        assert_eq!(for_kind(TransportKind::FullUtilization).name(), "single");
        assert_eq!(for_kind(TransportKind::Striped { streams: 4 }).name(), "striped:4");
    }

    #[test]
    fn tcp_lanes_round_trip() {
        let t = StripedTransport::new(StripeConfig {
            streams: 2,
            chunk_bytes: 64 << 10,
            credit_window: 4,
        });
        let fab = TransportFabric::tcp(2, &t, None).unwrap();
        let eps = fab.endpoints();
        let payload: Vec<u8> = (0..300_000u32).map(|i| (i % 253) as u8).collect();
        let want = payload.clone();
        let (a, b) = (Arc::clone(&eps[0]), Arc::clone(&eps[1]));
        let h = std::thread::spawn(move || b.recv(WorkerId(0), 1).unwrap());
        a.send(WorkerId(1), 1, &payload).unwrap();
        assert_eq!(h.join().unwrap(), want);
    }
}
