//! Cross-rank span aggregation: per-step time breakdowns and the
//! link-utilization timeline — the paper's Fig-4 finding recovered from
//! *instrumentation of a real launch* instead of the analytic model.
//!
//! Inputs are merged [`SpanRecord`] streams (the coordinator's own spans
//! plus the batches every worker ships at step boundaries). All
//! per-duration math is offset-invariant; only the timeline and Chrome
//! export need [`align`], which shifts each rank's clock so the step-0
//! barrier — a genuine synchronization point — ends simultaneously
//! everywhere.
//!
//! Span-name contract (what the trainer/transport layers emit):
//! `step.barrier`, `step.grad`, `step.compute`, `step.serialize`,
//! `step.wait`, `step.update`, `step.total` on the worker thread;
//! `comm.allreduce` on the engine thread; `wire.send` (with bytes) on
//! the lane senders; `reduce.add` inside the collectives.

use super::span::SpanRecord;
use std::collections::BTreeMap;

/// One step's wall time attributed to five disjoint phases. The worker
/// thread's wait on the collective engine is split between `wire_s` and
/// `reduce_s` proportionally to the engine side's measured send vs.
/// reduce busy time for the same (rank, step).
#[derive(Clone, Debug, Default)]
pub struct StepBreakdown {
    pub step: u32,
    /// Rendezvous barrier at the step boundary.
    pub barrier_s: f64,
    /// Gradient generation + modeled compute + parameter update.
    pub compute_s: f64,
    /// Gathering layer gradients into the flat wire payload.
    pub serialize_s: f64,
    /// Share of the collective wait attributed to moving bytes.
    pub wire_s: f64,
    /// Share of the collective wait attributed to decode+add.
    pub reduce_s: f64,
    /// The measured step wall (`step.total` span).
    pub total_s: f64,
}

impl StepBreakdown {
    /// Sum of the five attributed components — the acceptance check
    /// compares this against `total_s` (within 5%).
    pub fn components_sum(&self) -> f64 {
        self.barrier_s + self.compute_s + self.serialize_s + self.wire_s + self.reduce_s
    }
}

fn us(v: u64) -> f64 {
    v as f64 / 1e6
}

/// Per-(rank, step) duration sums by span name.
#[derive(Default, Clone)]
struct RankStep {
    barrier: u64,
    grad: u64,
    compute: u64,
    update: u64,
    serialize: u64,
    wait: u64,
    total: u64,
    wire_busy: u64,
    reduce_busy: u64,
}

/// Per-step breakdowns, averaged across every rank that reported a
/// `step.total` for the step. Steps come back sorted.
pub fn per_step(spans: &[SpanRecord]) -> Vec<StepBreakdown> {
    let mut acc: BTreeMap<(u32, u32), RankStep> = BTreeMap::new();
    for s in spans {
        let e = acc.entry((s.step, s.rank)).or_default();
        let d = s.dur_us;
        match s.name.as_str() {
            "step.barrier" => e.barrier += d,
            "step.grad" => e.grad += d,
            "step.compute" => e.compute += d,
            "step.update" => e.update += d,
            "step.serialize" => e.serialize += d,
            "step.wait" => e.wait += d,
            "step.total" => e.total += d,
            "wire.send" => e.wire_busy += d,
            "reduce.add" => e.reduce_busy += d,
            _ => {}
        }
    }
    let mut by_step: BTreeMap<u32, (StepBreakdown, usize)> = BTreeMap::new();
    for ((step, _rank), rs) in &acc {
        if rs.total == 0 {
            // A rank that never closed its step.total (e.g. spans from a
            // different instrumented site) contributes nothing.
            continue;
        }
        let busy = (rs.wire_busy + rs.reduce_busy) as f64;
        let wire_frac = if busy > 0.0 { rs.wire_busy as f64 / busy } else { 1.0 };
        let (b, n) = by_step.entry(*step).or_insert_with(|| {
            (StepBreakdown { step: *step, ..StepBreakdown::default() }, 0)
        });
        b.barrier_s += us(rs.barrier);
        b.compute_s += us(rs.grad + rs.compute + rs.update);
        b.serialize_s += us(rs.serialize);
        b.wire_s += us(rs.wait) * wire_frac;
        b.reduce_s += us(rs.wait) * (1.0 - wire_frac);
        b.total_s += us(rs.total);
        *n += 1;
    }
    by_step
        .into_values()
        .map(|(mut b, n)| {
            let n = n as f64;
            b.barrier_s /= n;
            b.compute_s /= n;
            b.serialize_s /= n;
            b.wire_s /= n;
            b.reduce_s /= n;
            b.total_s /= n;
            b
        })
        .collect()
}

/// Length of the union of `[start, end)` intervals, in µs.
fn union_us(mut iv: Vec<(u64, u64)>) -> u64 {
    iv.sort_unstable();
    let mut total = 0u64;
    let mut cur: Option<(u64, u64)> = None;
    for (s, e) in iv {
        match &mut cur {
            Some((_, ce)) if s <= *ce => *ce = (*ce).max(e),
            _ => {
                if let Some((cs, ce)) = cur {
                    total += ce - cs;
                }
                cur = Some((s, e));
            }
        }
    }
    if let Some((cs, ce)) = cur {
        total += ce - cs;
    }
    total
}

/// Mean delivered wire rate per rank, bytes/second: each rank's total
/// `wire.send` bytes divided by the *union* of its send spans' wall
/// intervals, averaged over ranks.
///
/// The union window is the load-bearing choice: striped lanes overlap in
/// wall time, so dividing by summed per-span busy time would just give
/// back the per-lane gate rate for any stream count — the union measures
/// what the link as a whole delivered while it was active, which is the
/// quantity the paper's utilization figure is about.
pub fn wire_mean_bps(spans: &[SpanRecord]) -> f64 {
    let mut per_rank: BTreeMap<u32, (u64, Vec<(u64, u64)>)> = BTreeMap::new();
    for s in spans {
        if s.name == "wire.send" {
            let e = per_rank.entry(s.rank).or_default();
            e.0 += s.bytes;
            e.1.push((s.start_us, s.end_us()));
        }
    }
    if per_rank.is_empty() {
        return 0.0;
    }
    let mut sum = 0.0;
    let n = per_rank.len() as f64;
    for (_rank, (bytes, iv)) in per_rank {
        let window = us(union_us(iv));
        if window > 0.0 {
            sum += bytes as f64 / window;
        }
    }
    sum / n
}

/// Time-bucketed link-utilization timeline: `bins` buckets spanning the
/// whole run, each reporting `(bucket midpoint seconds, mean bytes/sec
/// per rank)`. A span's bytes spread across the buckets it overlaps,
/// proportional to overlap.
pub fn util_timeline(spans: &[SpanRecord], bins: usize) -> Vec<(f64, f64)> {
    let wire: Vec<&SpanRecord> = spans.iter().filter(|s| s.name == "wire.send").collect();
    if wire.is_empty() || bins == 0 {
        return Vec::new();
    }
    let t0 = wire.iter().map(|s| s.start_us).min().unwrap_or(0);
    let t1 = wire.iter().map(|s| s.end_us()).max().unwrap_or(t0).max(t0 + 1);
    let width = (t1 - t0) as f64 / bins as f64;
    let mut bytes_in = vec![0.0f64; bins];
    let mut ranks = std::collections::BTreeSet::new();
    for s in &wire {
        ranks.insert(s.rank);
        let (ss, se) = (s.start_us as f64, s.end_us() as f64);
        let dur = (se - ss).max(1.0);
        for (i, b) in bytes_in.iter_mut().enumerate() {
            let (bs, be) = (t0 as f64 + i as f64 * width, t0 as f64 + (i + 1) as f64 * width);
            let overlap = (se.min(be) - ss.max(bs)).max(0.0);
            *b += s.bytes as f64 * overlap / dur;
        }
    }
    let nranks = ranks.len().max(1) as f64;
    bytes_in
        .iter()
        .enumerate()
        .map(|(i, b)| {
            let mid_s = ((i as f64 + 0.5) * width) / 1e6;
            (mid_s, b / (width / 1e6) / nranks)
        })
        .collect()
}

/// Shift each rank's timestamps so its earliest `anchor` span *ends* at
/// the same instant as the reference rank's (lowest rank present). The
/// anchor should be a true synchronization point — the step-0 barrier —
/// so cross-process epochs line up. Finally re-bases everything to start
/// at 0. Ranks with no anchor span are left on their own clock (shifted
/// only by the re-base).
pub fn align(spans: &mut [SpanRecord], anchor: &str) {
    let mut anchors: BTreeMap<u32, u64> = BTreeMap::new();
    for s in spans.iter() {
        if s.name == anchor {
            let e = anchors.entry(s.rank).or_insert(u64::MAX);
            // Earliest anchor by (step, start) — step 0's barrier.
            let key = ((s.step as u64) << 40) | s.end_us().min((1 << 40) - 1);
            *e = (*e).min(key);
        }
    }
    let Some((&ref_rank, &ref_key)) = anchors.iter().next() else { return };
    let end_of = |key: u64| (key & ((1 << 40) - 1)) as i64;
    let ref_end = end_of(ref_key);
    let offsets: BTreeMap<u32, i64> = anchors
        .iter()
        .map(|(&r, &k)| (r, if r == ref_rank { 0 } else { ref_end - end_of(k) }))
        .collect();
    let mut min_start = i64::MAX;
    let shifted: Vec<i64> = spans
        .iter()
        .map(|s| {
            let off = offsets.get(&s.rank).copied().unwrap_or(0);
            let v = s.start_us as i64 + off;
            min_start = min_start.min(v);
            v
        })
        .collect();
    for (s, v) in spans.iter_mut().zip(shifted) {
        s.start_us = (v - min_start).max(0) as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(name: &str, rank: u32, step: u32, start_us: u64, dur_us: u64, bytes: u64) -> SpanRecord {
        SpanRecord { seq: 0, name: name.to_string(), rank, step, start_us, dur_us, bytes }
    }

    #[test]
    fn breakdown_splits_wait_by_engine_busy_ratio() {
        // One rank, one step: 10ms barrier, 20ms compute phases, 5ms
        // serialize, 40ms wait. Engine-side: 30ms of wire.send and 10ms
        // of reduce.add → wait splits 3:1.
        let spans = vec![
            span("step.barrier", 0, 0, 0, 10_000, 0),
            span("step.grad", 0, 0, 10_000, 8_000, 0),
            span("step.compute", 0, 0, 18_000, 10_000, 0),
            span("step.serialize", 0, 0, 28_000, 5_000, 0),
            span("step.wait", 0, 0, 33_000, 40_000, 0),
            span("step.update", 0, 0, 73_000, 2_000, 0),
            span("step.total", 0, 0, 0, 75_000, 0),
            span("wire.send", 0, 0, 34_000, 30_000, 1 << 20),
            span("reduce.add", 0, 0, 40_000, 10_000, 0),
        ];
        let b = per_step(&spans);
        assert_eq!(b.len(), 1);
        let b = &b[0];
        assert_eq!(b.step, 0);
        assert!((b.barrier_s - 0.010).abs() < 1e-9);
        assert!((b.compute_s - 0.020).abs() < 1e-9);
        assert!((b.serialize_s - 0.005).abs() < 1e-9);
        assert!((b.wire_s - 0.030).abs() < 1e-9, "{b:?}");
        assert!((b.reduce_s - 0.010).abs() < 1e-9, "{b:?}");
        assert!((b.total_s - 0.075).abs() < 1e-9);
        assert!((b.components_sum() - b.total_s).abs() / b.total_s < 0.05);
    }

    #[test]
    fn breakdown_averages_across_ranks_and_sorts_steps() {
        let mut spans = Vec::new();
        for rank in 0..2u32 {
            for step in [1u32, 0] {
                let wait = if rank == 0 { 20_000 } else { 40_000 };
                spans.push(span("step.wait", rank, step, 0, wait, 0));
                spans.push(span("step.total", rank, step, 0, 50_000, 0));
                spans.push(span("wire.send", rank, step, 0, 10_000, 1024));
            }
        }
        let b = per_step(&spans);
        assert_eq!(b.len(), 2);
        assert_eq!((b[0].step, b[1].step), (0, 1));
        // No reduce.add busy → the whole wait is wire; mean of 20/40ms.
        assert!((b[0].wire_s - 0.030).abs() < 1e-9, "{:?}", b[0]);
        assert_eq!(b[0].reduce_s, 0.0);
    }

    #[test]
    fn union_window_discriminates_overlapping_lanes() {
        // 8 lanes each sending 1 MB for the same 100ms window: the summed
        // busy time is 800ms but the union is 100ms — the delivered rate
        // is 8 MB / 0.1 s, not 1 MB / 0.1 s.
        let spans: Vec<SpanRecord> =
            (0..8).map(|_| span("wire.send", 0, 0, 0, 100_000, 1 << 20)).collect();
        let bps = wire_mean_bps(&spans);
        assert!((bps - 8.0 * (1 << 20) as f64 / 0.1).abs() / bps < 1e-9, "{bps}");
        // Disjoint spans: 2 MB over 0.2 s of union.
        let spans = vec![
            span("wire.send", 0, 0, 0, 100_000, 1 << 20),
            span("wire.send", 0, 0, 200_000, 100_000, 1 << 20),
        ];
        let bps = wire_mean_bps(&spans);
        assert!((bps - 2.0 * (1 << 20) as f64 / 0.2).abs() / bps < 1e-9, "{bps}");
        // Mean across ranks, and non-wire spans are ignored.
        let spans = vec![
            span("wire.send", 0, 0, 0, 100_000, 1000),
            span("wire.send", 1, 0, 0, 100_000, 3000),
            span("step.total", 0, 0, 0, 500_000, 0),
        ];
        let bps = wire_mean_bps(&spans);
        assert!((bps - (10_000.0 + 30_000.0) / 2.0).abs() < 1e-6, "{bps}");
        assert_eq!(wire_mean_bps(&[]), 0.0);
    }

    #[test]
    fn timeline_bins_spread_bytes_proportionally() {
        // One 1 MB span covering exactly the first half of the window.
        let spans = vec![
            span("wire.send", 0, 0, 0, 100_000, 1 << 20),
            span("wire.send", 0, 0, 100_000, 100_000, 0),
        ];
        let tl = util_timeline(&spans, 4);
        assert_eq!(tl.len(), 4);
        let rate = (1 << 20) as f64 / 0.1; // bytes/sec while active
        assert!((tl[0].1 - rate).abs() / rate < 1e-9, "{tl:?}");
        assert!((tl[1].1 - rate).abs() / rate < 1e-9, "{tl:?}");
        assert_eq!(tl[2].1, 0.0);
        assert_eq!(tl[3].1, 0.0);
        // Midpoints are increasing and within the window.
        assert!(tl.windows(2).all(|w| w[0].0 < w[1].0));
        assert!(util_timeline(&[], 4).is_empty());
    }

    #[test]
    fn align_shifts_ranks_onto_the_reference_barrier() {
        // Rank 1's process epoch is 1 s behind: its barrier ends at
        // 1_050_000 while rank 0's ends at 50_000.
        let mut spans = vec![
            span("step.barrier", 0, 0, 0, 50_000, 0),
            span("wire.send", 0, 0, 60_000, 10_000, 64),
            span("step.barrier", 1, 0, 1_000_000, 50_000, 0),
            span("wire.send", 1, 0, 1_060_000, 10_000, 64),
        ];
        align(&mut spans, "step.barrier");
        let get = |rank: u32, name: &str| {
            spans.iter().find(|s| s.rank == rank && s.name == name).unwrap().start_us
        };
        assert_eq!(get(0, "step.barrier"), get(1, "step.barrier"));
        assert_eq!(get(0, "wire.send"), get(1, "wire.send"));
        assert_eq!(spans.iter().map(|s| s.start_us).min().unwrap(), 0);
    }
}
