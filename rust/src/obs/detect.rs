//! Online anomaly detection over telemetry series — the watching half of
//! the observability plane.
//!
//! The paper's finding is that the network runs far below its
//! provisioned rate *and nobody notices*; [`super::timeseries`] makes
//! utilization continuously visible, and this module makes it
//! continuously *judged*. A [`SeriesDetector`] keeps an EWMA baseline
//! per series and scores each new sample with a robust z-score (median
//! absolute deviation over a sliding window of past deviations, scaled
//! by the usual 1.4826 normal-consistency constant). A detection fires
//! only after `sustain` consecutive anomalous samples — a single noisy
//! step never trips it — and anomalous samples are excluded from the
//! baseline so a genuine regression cannot normalize itself away.
//!
//! Three detection kinds, one mechanism:
//! * **throughput regression** — a sustained drop in a rate series
//!   (`busbw_gbps`, bench history entries; direction = low);
//! * **utilization collapse** — the same low-side rule on utilization /
//!   wire-rate series sampled by the serve daemon;
//! * **straggler onset** — cohort scoring reused verbatim from
//!   [`crate::tune::straggler_scores`], surfaced as [`Detection`]s.
//!
//! Consumers: `netbn launch` stamps detections into the
//! [`crate::trainer::launch::LaunchReport`], the serve sampler streams
//! them over `GET /metrics/stream`, job feedback rings stamp them into
//! job telemetry, and `netbn bench --trend` fails CI on a sustained
//! regression across `bench_history.jsonl`.

use crate::tune::FeedbackRing;
use crate::Result;
use std::collections::VecDeque;

/// What a detection claims went wrong.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DetectionKind {
    /// A rate/bandwidth series dropped significantly below its baseline.
    ThroughputRegression,
    /// A utilization series collapsed below its baseline.
    UtilizationCollapse,
    /// One cohort member's compute time left the cohort median.
    StragglerOnset,
}

impl DetectionKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            DetectionKind::ThroughputRegression => "throughput_regression",
            DetectionKind::UtilizationCollapse => "utilization_collapse",
            DetectionKind::StragglerOnset => "straggler_onset",
        }
    }

    pub fn parse(s: &str) -> Option<DetectionKind> {
        match s {
            "throughput_regression" => Some(DetectionKind::ThroughputRegression),
            "utilization_collapse" => Some(DetectionKind::UtilizationCollapse),
            "straggler_onset" => Some(DetectionKind::StragglerOnset),
            _ => None,
        }
    }
}

/// One fired detection.
#[derive(Clone, Debug, PartialEq)]
pub struct Detection {
    pub kind: DetectionKind,
    /// The series the detector was watching (a metrics series key, a
    /// feedback field name, or a cohort member id).
    pub series: String,
    /// Sample index the detection fired at (step, seq, or history row).
    pub at: u64,
    /// Signed robust z-score of the firing sample vs the baseline.
    pub z: f64,
    /// EWMA baseline at firing time.
    pub baseline: f64,
    /// The sample that fired.
    pub value: f64,
}

impl Detection {
    /// One JSON object (hand-rolled like every other emitter here).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"kind\":{},\"series\":{},\"at\":{},\"z\":{:.3},\"baseline\":{:.6},\"value\":{:.6}}}",
            crate::report::json_str(self.kind.as_str()),
            crate::report::json_str(&self.series),
            self.at,
            self.z,
            self.baseline,
            self.value
        )
    }

    /// A one-line human summary (`netbn bench --trend`, serve logs).
    pub fn summary(&self) -> String {
        format!(
            "{} on {} at sample {}: value {:.4} vs baseline {:.4} (z = {:.1})",
            self.kind.as_str(),
            self.series,
            self.at,
            self.value,
            self.baseline,
            self.z
        )
    }
}

/// Which side of the baseline is anomalous.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    /// Drops are anomalous (throughput, utilization).
    Low,
    /// Rises are anomalous (wall times, latencies).
    High,
}

/// Detector tuning. The defaults are deliberately conservative: the
/// acceptance bar is *zero* false positives on a steady prefix, so the
/// scale estimate is floored at `min_rel_dev` of the baseline — on a
/// near-noiseless series (MAD ≈ 0) a sample must still deviate by
/// `z_threshold × min_rel_dev` (40% with the defaults) to count.
#[derive(Clone, Copy, Debug)]
pub struct DetectorConfig {
    /// EWMA smoothing factor for the baseline.
    pub alpha: f64,
    /// Robust z-score a sample must cross to count as anomalous.
    pub z_threshold: f64,
    /// Samples consumed (baseline priming) before detection arms.
    pub warmup: usize,
    /// Consecutive anomalous samples required to fire — the
    /// single-sample-blip filter.
    pub sustain: usize,
    /// Sliding window of past absolute deviations the MAD is taken over.
    pub mad_window: usize,
    /// Scale floor as a fraction of the baseline magnitude.
    pub min_rel_dev: f64,
    pub direction: Direction,
}

impl DetectorConfig {
    /// Rate/bandwidth series: a sustained drop is a regression.
    pub fn throughput() -> DetectorConfig {
        DetectorConfig {
            alpha: 0.3,
            z_threshold: 5.0,
            warmup: 3,
            sustain: 2,
            mad_window: 16,
            min_rel_dev: 0.08,
            direction: Direction::Low,
        }
    }

    /// Utilization series: same low-side rule as throughput.
    pub fn utilization() -> DetectorConfig {
        DetectorConfig::throughput()
    }

    /// Duration series (step walls, latencies): a sustained rise fires.
    pub fn wall() -> DetectorConfig {
        DetectorConfig { direction: Direction::High, ..DetectorConfig::throughput() }
    }
}

/// Online per-series detector: EWMA baseline + MAD z-score, sustained
/// firing, baseline frozen while anomalous.
#[derive(Clone, Debug)]
pub struct SeriesDetector {
    cfg: DetectorConfig,
    ewma: f64,
    devs: VecDeque<f64>,
    seen: usize,
    streak: usize,
    /// Latched after a fire so one sustained episode reports once;
    /// re-arms when a normal sample arrives.
    fired: bool,
}

impl SeriesDetector {
    pub fn new(cfg: DetectorConfig) -> SeriesDetector {
        SeriesDetector { cfg, ewma: 0.0, devs: VecDeque::new(), seen: 0, streak: 0, fired: false }
    }

    fn mad(&self) -> f64 {
        if self.devs.is_empty() {
            return 0.0;
        }
        let mut d: Vec<f64> = self.devs.iter().copied().collect();
        d.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if d.len() % 2 == 1 {
            d[d.len() / 2]
        } else {
            (d[d.len() / 2 - 1] + d[d.len() / 2]) / 2.0
        }
    }

    fn absorb(&mut self, value: f64) {
        let dev = (value - self.ewma).abs();
        self.ewma = if self.seen == 0 {
            value
        } else {
            self.cfg.alpha * value + (1.0 - self.cfg.alpha) * self.ewma
        };
        if self.seen > 0 {
            if self.devs.len() >= self.cfg.mad_window {
                self.devs.pop_front();
            }
            self.devs.push_back(dev);
        }
        self.seen += 1;
    }

    /// Feed one sample; `Some((z, baseline))` when this sample completes a
    /// sustained anomalous run. Non-finite samples are ignored.
    pub fn observe(&mut self, value: f64) -> Option<(f64, f64)> {
        if !value.is_finite() {
            return None;
        }
        if self.seen < self.cfg.warmup {
            self.absorb(value);
            return None;
        }
        let scale = (1.4826 * self.mad())
            .max(self.cfg.min_rel_dev * self.ewma.abs())
            .max(1e-12);
        let z = (value - self.ewma) / scale;
        let anomalous = match self.cfg.direction {
            Direction::Low => z <= -self.cfg.z_threshold,
            Direction::High => z >= self.cfg.z_threshold,
        };
        if !anomalous {
            self.streak = 0;
            self.fired = false;
            self.absorb(value);
            return None;
        }
        // Anomalous samples never update the baseline: a persistent
        // regression stays visible instead of becoming the new normal.
        self.streak += 1;
        if self.streak >= self.cfg.sustain && !self.fired {
            self.fired = true;
            return Some((z, self.ewma));
        }
        None
    }
}

/// Run a detector over a whole `(at, value)` series — identical firing
/// points to the online form, packaged as [`Detection`]s. This is what
/// post-hoc consumers (`netbn bench --trend`, the launch coordinator's
/// step series) call.
pub fn scan(
    cfg: DetectorConfig,
    kind: DetectionKind,
    series: &str,
    values: &[(u64, f64)],
) -> Vec<Detection> {
    let mut det = SeriesDetector::new(cfg);
    let mut out = Vec::new();
    for &(at, v) in values {
        if let Some((z, baseline)) = det.observe(v) {
            out.push(Detection { kind, series: series.to_string(), at, z, baseline, value: v });
        }
    }
    out
}

/// Cohort straggler onset: score every member's feedback ring against
/// the cohort median (the exact [`crate::tune::straggler_scores`]
/// logic) and surface each flagged member as a [`Detection`] whose `z`
/// is its score multiple and whose `series` names the member.
pub fn straggler_onset(
    rings: &[(u64, &FeedbackRing)],
    window: usize,
    threshold: f64,
    at: u64,
) -> Vec<Detection> {
    crate::tune::straggler_scores(rings, window, threshold)
        .into_iter()
        .filter(|s| s.straggler)
        .map(|s| Detection {
            kind: DetectionKind::StragglerOnset,
            series: format!("member.{}.compute_s", s.id),
            at,
            z: s.score,
            baseline: if s.score > 0.0 { s.compute_s / s.score } else { 0.0 },
            value: s.compute_s,
        })
        .collect()
}

/// Whitespace-free wire form for the launch done line:
/// `kind:at:z:baseline:value` tuples joined with `;` (the series is
/// carried separately — done-line fields cannot hold arbitrary text).
pub fn format_detections(dets: &[Detection]) -> String {
    if dets.is_empty() {
        return "-".to_string();
    }
    dets.iter()
        .map(|d| {
            format!("{}:{}:{:.3}:{:.6}:{:.6}", d.kind.as_str(), d.at, d.z, d.baseline, d.value)
        })
        .collect::<Vec<_>>()
        .join(";")
}

/// Inverse of [`format_detections`]; `series` is stamped onto every
/// entry.
pub fn parse_detections(s: &str, series: &str) -> Result<Vec<Detection>> {
    if s == "-" {
        return Ok(Vec::new());
    }
    s.split(';')
        .filter(|part| !part.is_empty())
        .map(|part| {
            let f: Vec<&str> = part.split(':').collect();
            anyhow::ensure!(f.len() == 5, "bad detection entry {part:?}");
            let num = |i: usize| -> Result<f64> {
                f[i].parse().map_err(|_| anyhow::anyhow!("bad detection field {:?}", f[i]))
            };
            Ok(Detection {
                kind: DetectionKind::parse(f[0])
                    .ok_or_else(|| anyhow::anyhow!("bad detection kind {:?}", f[0]))?,
                series: series.to_string(),
                at: f[1].parse().map_err(|_| anyhow::anyhow!("bad detection step {:?}", f[1]))?,
                z: num(2)?,
                baseline: num(3)?,
                value: num(4)?,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tune::StepFeedback;

    fn steady_then_drop(steady: usize, drop_at: usize, total: usize) -> Vec<(u64, f64)> {
        (0..total)
            .map(|i| {
                // Deterministic ±2% jitter around the steady level.
                let jitter = 1.0 + 0.02 * (((i * 7 + 3) % 5) as f64 - 2.0) / 2.0;
                let base = if i >= drop_at { 0.1 } else { 1.0 };
                (i as u64, base * jitter)
            })
            .take(total.max(steady))
            .collect()
    }

    #[test]
    fn sustained_drop_fires_within_three_samples_no_false_positives() {
        let series = steady_then_drop(8, 8, 14);
        let dets = scan(
            DetectorConfig::throughput(),
            DetectionKind::ThroughputRegression,
            "busbw_gbps",
            &series,
        );
        assert_eq!(dets.len(), 1, "{dets:?}");
        let d = &dets[0];
        assert!(d.at >= 8 && d.at < 8 + 3, "fired at {}", d.at);
        assert!(d.z < -5.0, "{d:?}");
        assert!(d.baseline > 0.9 && d.value < 0.15, "{d:?}");
    }

    #[test]
    fn single_sample_blip_never_fires() {
        let mut series: Vec<(u64, f64)> = (0..12).map(|i| (i as u64, 1.0)).collect();
        series[6].1 = 0.05; // one bad step, recovered next sample
        let dets = scan(
            DetectorConfig::throughput(),
            DetectionKind::ThroughputRegression,
            "busbw_gbps",
            &series,
        );
        assert!(dets.is_empty(), "{dets:?}");
    }

    #[test]
    fn steady_series_with_noise_stays_silent() {
        let series: Vec<(u64, f64)> = (0..64)
            .map(|i| (i as u64, 10.0 * (1.0 + 0.05 * ((i % 7) as f64 - 3.0) / 3.0)))
            .collect();
        let dets =
            scan(DetectorConfig::throughput(), DetectionKind::UtilizationCollapse, "u", &series);
        assert!(dets.is_empty(), "{dets:?}");
    }

    #[test]
    fn high_direction_fires_on_wall_time_rise_only() {
        let mut series: Vec<(u64, f64)> = (0..12).map(|i| (i as u64, 0.010)).collect();
        for p in series.iter_mut().skip(7) {
            p.1 = 0.120; // 12x slower from sample 7 on
        }
        let dets = scan(DetectorConfig::wall(), DetectionKind::ThroughputRegression, "w", &series);
        assert_eq!(dets.len(), 1, "{dets:?}");
        assert!(dets[0].at >= 7 && dets[0].at <= 9, "{dets:?}");
        // The same series through a low-side detector is silent.
        let low =
            scan(DetectorConfig::throughput(), DetectionKind::ThroughputRegression, "w", &series);
        assert!(low.is_empty(), "{low:?}");
    }

    #[test]
    fn one_episode_reports_once_and_rearms_after_recovery() {
        let mut series: Vec<(u64, f64)> = (0..24).map(|i| (i as u64, 1.0)).collect();
        for p in series.iter_mut().take(10).skip(6) {
            p.1 = 0.1; // first episode: samples 6..10
        }
        for p in series.iter_mut().take(22).skip(16) {
            p.1 = 0.1; // second episode after recovery
        }
        let dets =
            scan(DetectorConfig::throughput(), DetectionKind::ThroughputRegression, "b", &series);
        assert_eq!(dets.len(), 2, "{dets:?}");
        assert!(dets[0].at < 10 && dets[1].at >= 16, "{dets:?}");
    }

    #[test]
    fn anomalous_samples_do_not_poison_the_baseline() {
        // After a long regression, the baseline still reflects the
        // healthy level — so the detection's reported baseline is honest.
        let mut series: Vec<(u64, f64)> = (0..8).map(|i| (i as u64, 2.0)).collect();
        series.extend((8..32).map(|i| (i as u64, 0.2)));
        let dets =
            scan(DetectorConfig::throughput(), DetectionKind::ThroughputRegression, "b", &series);
        assert_eq!(dets.len(), 1);
        assert!((dets[0].baseline - 2.0).abs() < 0.2, "{:?}", dets[0]);
    }

    #[test]
    fn straggler_onset_reuses_cohort_scoring() {
        let mk = |compute_s: f64| {
            let mut r = FeedbackRing::new(8);
            for step in 0..5u64 {
                r.push(StepFeedback {
                    step,
                    wall_s: 1.0,
                    compute_s,
                    comm_busy_s: 0.1,
                    busbw_gbps: 1.0,
                });
            }
            r
        };
        let (a, b, slow) = (mk(0.1), mk(0.11), mk(0.45));
        let dets = straggler_onset(&[(1, &a), (2, &b), (3, &slow)], 8, 3.0, 42);
        assert_eq!(dets.len(), 1, "{dets:?}");
        assert_eq!(dets[0].kind, DetectionKind::StragglerOnset);
        assert_eq!(dets[0].series, "member.3.compute_s");
        assert_eq!(dets[0].at, 42);
        assert!(dets[0].z > 3.0);
    }

    #[test]
    fn wire_format_round_trips() {
        let dets = vec![
            Detection {
                kind: DetectionKind::ThroughputRegression,
                series: "busbw_gbps".to_string(),
                at: 5,
                z: -7.25,
                baseline: 1.5,
                value: 0.15,
            },
            Detection {
                kind: DetectionKind::UtilizationCollapse,
                series: "busbw_gbps".to_string(),
                at: 9,
                z: -12.0,
                baseline: 0.9,
                value: 0.01,
            },
        ];
        let s = format_detections(&dets);
        assert!(!s.contains(' '), "done-line fields are whitespace-delimited: {s}");
        let back = parse_detections(&s, "busbw_gbps").unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].kind, dets[0].kind);
        assert_eq!(back[0].at, 5);
        assert!((back[0].z - dets[0].z).abs() < 1e-3);
        assert!((back[1].value - dets[1].value).abs() < 1e-6);
        assert_eq!(format_detections(&[]), "-");
        assert!(parse_detections("nope:1:2:3:4", "s").is_err());
        assert!(parse_detections("throughput_regression:1:2", "s").is_err());
    }

    #[test]
    fn detection_json_shape() {
        let d = Detection {
            kind: DetectionKind::StragglerOnset,
            series: "member.3.compute_s".to_string(),
            at: 7,
            z: 4.5,
            baseline: 0.1,
            value: 0.45,
        };
        let j = d.to_json();
        let fields = crate::util::json::object_fields(&j).unwrap();
        assert_eq!(
            crate::util::json::parse_string(
                crate::util::json::require(&fields, "kind").unwrap()
            )
            .unwrap(),
            "straggler_onset"
        );
        assert_eq!(
            crate::util::json::parse_u64(crate::util::json::require(&fields, "at").unwrap())
                .unwrap(),
            7
        );
        assert!(d.summary().contains("straggler_onset"), "{}", d.summary());
    }
}
