//! Lock-free metrics: counters, gauges, log-bucketed histograms, and the
//! named+labeled [`Registry`] that `GET /metrics` renders.
//!
//! Handles ([`Counter`], [`Gauge`], [`Histo`]) are cheap `Arc` clones:
//! callers resolve a metric once (by name + labels, get-or-create) and
//! record through plain atomics afterwards — no lock anywhere on the
//! record path, so worker threads never contend and the registry can be
//! snapshot mid-run without pausing anyone.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// A monotonically increasing counter.
#[derive(Clone, Default)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn new() -> Counter {
        Counter::default()
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-write-wins float value (stored as f64 bits).
#[derive(Clone, Default)]
pub struct Gauge(Arc<AtomicU64>);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge::default()
    }

    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Bucket count: bucket 0 holds the value 0 exactly; bucket `k` (k >= 1)
/// holds values whose bit length is `k`, i.e. the range
/// `[2^(k-1), 2^k - 1]`. 64 doublings cover the full `u64` domain.
pub const HISTO_BUCKETS: usize = 65;

/// Bucket index for a recorded value.
fn bucket_of(v: u64) -> usize {
    (u64::BITS - v.leading_zeros()) as usize
}

/// Inclusive `[lo, hi]` value range of bucket `i`.
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    if i == 0 {
        (0, 0)
    } else if i >= 64 {
        (1u64 << 63, u64::MAX)
    } else {
        (1u64 << (i - 1), (1u64 << i) - 1)
    }
}

struct HistoInner {
    buckets: [AtomicU64; HISTO_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

/// A log-bucketed histogram of `u64` samples (durations in µs, sizes in
/// bytes, ...). Recording is three relaxed atomic adds; quantiles come
/// from a [`HistoSnapshot`] with linear interpolation inside the bucket.
#[derive(Clone)]
pub struct Histo(Arc<HistoInner>);

impl Default for Histo {
    fn default() -> Self {
        Histo(Arc::new(HistoInner {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }))
    }
}

impl Histo {
    pub fn new() -> Histo {
        Histo::default()
    }

    pub fn record(&self, v: u64) {
        self.0.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
        self.0.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Point-in-time copy; safe while other threads keep recording (the
    /// copy is not a single atomic cut, but every counted sample is in
    /// exactly one bucket and counts only grow).
    pub fn snapshot(&self) -> HistoSnapshot {
        let mut buckets = [0u64; HISTO_BUCKETS];
        for (dst, src) in buckets.iter_mut().zip(self.0.buckets.iter()) {
            *dst = src.load(Ordering::Relaxed);
        }
        HistoSnapshot {
            buckets,
            count: self.0.count.load(Ordering::Relaxed),
            sum: self.0.sum.load(Ordering::Relaxed),
        }
    }
}

/// Frozen histogram state.
#[derive(Clone, Debug)]
pub struct HistoSnapshot {
    pub buckets: [u64; HISTO_BUCKETS],
    pub count: u64,
    pub sum: u64,
}

impl HistoSnapshot {
    /// Quantile `q` in `[0, 1]`, linearly interpolated inside the bucket
    /// the cumulative count crosses. Empty histograms report 0.
    pub fn quantile(&self, q: f64) -> f64 {
        let total: u64 = self.buckets.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = q.clamp(0.0, 1.0) * total as f64;
        let mut cum = 0.0;
        let mut last = 0usize;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            last = i;
            let c = c as f64;
            if cum + c >= target {
                let frac = ((target - cum) / c).clamp(0.0, 1.0);
                let (lo, hi) = bucket_bounds(i);
                return lo as f64 + frac * (hi - lo) as f64;
            }
            cum += c;
        }
        bucket_bounds(last).1 as f64
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> f64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histo(Histo),
}

/// One registry entry's point-in-time value, as structured data rather
/// than exposition text — what [`crate::obs::timeseries`]'s sampler
/// diffs between rounds.
#[derive(Clone, Debug, PartialEq)]
pub enum SampleValue {
    Counter(u64),
    Gauge(f64),
    /// Cumulative sample count and value sum of a histogram.
    Histo { count: u64, sum: u64 },
}

/// A named + labeled entry paired with its [`SampleValue`].
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: SampleValue,
}

impl Sample {
    /// Canonical series key, `name{k=v,...}` (registration label order,
    /// no quotes — the key doubles as a JSONL field and must stay
    /// whitespace/escape-free).
    pub fn series_key(&self) -> String {
        if self.labels.is_empty() {
            return self.name.clone();
        }
        let labels: Vec<String> =
            self.labels.iter().map(|(k, v)| format!("{k}={v}")).collect();
        format!("{}{{{}}}", self.name, labels.join(","))
    }
}

struct Entry {
    name: String,
    labels: Vec<(String, String)>,
    metric: Metric,
}

/// Named + labeled metrics, get-or-create. The registry lock is taken
/// only at resolution and snapshot time — never on the record path.
#[derive(Default)]
pub struct Registry {
    entries: Mutex<Vec<Entry>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    fn resolve<T: Clone>(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        get: impl Fn(&Metric) -> Option<T>,
        make: impl Fn() -> (Metric, T),
    ) -> T {
        let mut entries = self.entries.lock().unwrap();
        let owned: Vec<(String, String)> =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        if let Some(e) = entries.iter().find(|e| e.name == name && e.labels == owned) {
            if let Some(t) = get(&e.metric) {
                return t;
            }
            panic!("metric {name:?} re-registered with a different type");
        }
        let (metric, handle) = make();
        entries.push(Entry { name: name.to_string(), labels: owned, metric });
        handle
    }

    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Counter {
        self.resolve(
            name,
            labels,
            |m| match m {
                Metric::Counter(c) => Some(c.clone()),
                _ => None,
            },
            || {
                let c = Counter::new();
                (Metric::Counter(c.clone()), c)
            },
        )
    }

    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Gauge {
        self.resolve(
            name,
            labels,
            |m| match m {
                Metric::Gauge(g) => Some(g.clone()),
                _ => None,
            },
            || {
                let g = Gauge::new();
                (Metric::Gauge(g.clone()), g)
            },
        )
    }

    pub fn histo(&self, name: &str, labels: &[(&str, &str)]) -> Histo {
        self.resolve(
            name,
            labels,
            |m| match m {
                Metric::Histo(h) => Some(h.clone()),
                _ => None,
            },
            || {
                let h = Histo::new();
                (Metric::Histo(h.clone()), h)
            },
        )
    }

    /// Structured point-in-time snapshot of every entry, in registration
    /// order. Like [`Registry::render_text`] this never pauses writers:
    /// each value is a relaxed atomic read.
    pub fn sample(&self) -> Vec<Sample> {
        let entries = self.entries.lock().unwrap();
        entries
            .iter()
            .map(|e| Sample {
                name: e.name.clone(),
                labels: e.labels.clone(),
                value: match &e.metric {
                    Metric::Counter(c) => SampleValue::Counter(c.get()),
                    Metric::Gauge(g) => SampleValue::Gauge(g.get()),
                    Metric::Histo(h) => {
                        let s = h.snapshot();
                        SampleValue::Histo { count: s.count, sum: s.sum }
                    }
                },
            })
            .collect()
    }

    /// Render every metric as `name{labels} value` text lines
    /// (Prometheus-style exposition; histograms expand to quantile,
    /// `_count` and `_sum` lines). This is what `GET /metrics` serves.
    pub fn render_text(&self) -> String {
        use std::fmt::Write as _;
        let fmt_labels = |labels: &[(String, String)], extra: Option<(&str, &str)>| {
            let mut parts: Vec<String> =
                labels.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect();
            if let Some((k, v)) = extra {
                parts.push(format!("{k}=\"{v}\""));
            }
            if parts.is_empty() {
                String::new()
            } else {
                format!("{{{}}}", parts.join(","))
            }
        };
        let entries = self.entries.lock().unwrap();
        let mut s = String::new();
        for e in entries.iter() {
            match &e.metric {
                Metric::Counter(c) => {
                    let _ = writeln!(s, "{}{} {}", e.name, fmt_labels(&e.labels, None), c.get());
                }
                Metric::Gauge(g) => {
                    let _ = writeln!(s, "{}{} {}", e.name, fmt_labels(&e.labels, None), g.get());
                }
                Metric::Histo(h) => {
                    let snap = h.snapshot();
                    for (q, v) in
                        [("0.5", snap.p50()), ("0.95", snap.p95()), ("0.99", snap.p99())]
                    {
                        let _ = writeln!(
                            s,
                            "{}{} {v}",
                            e.name,
                            fmt_labels(&e.labels, Some(("quantile", q)))
                        );
                    }
                    let _ = writeln!(
                        s,
                        "{}_count{} {}",
                        e.name,
                        fmt_labels(&e.labels, None),
                        snap.count
                    );
                    let _ =
                        writeln!(s, "{}_sum{} {}", e.name, fmt_labels(&e.labels, None), snap.sum);
                }
            }
        }
        s
    }
}

/// The process-global registry (what `netbn serve` exposes).
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_round_trip() {
        let c = Counter::new();
        c.inc();
        c.add(41);
        assert_eq!(c.get(), 42);
        let c2 = c.clone();
        c2.inc();
        assert_eq!(c.get(), 43, "clones share state");
        let g = Gauge::new();
        g.set(-2.5);
        assert_eq!(g.get(), -2.5);
    }

    #[test]
    fn histo_bucket_boundaries() {
        // Bucket k holds exactly the values of bit length k: the
        // boundaries are powers of two, closed below and open above.
        assert_eq!(bucket_bounds(0), (0, 0));
        assert_eq!(bucket_bounds(1), (1, 1));
        assert_eq!(bucket_bounds(2), (2, 3));
        assert_eq!(bucket_bounds(7), (64, 127));
        assert_eq!(bucket_bounds(64), (1 << 63, u64::MAX));
        let h = Histo::new();
        for v in [0u64, 1, 2, 3, 4, 7, 8, 127, 128, u64::MAX] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.buckets[0], 1); // 0
        assert_eq!(s.buckets[1], 1); // 1
        assert_eq!(s.buckets[2], 2); // 2, 3
        assert_eq!(s.buckets[3], 2); // 4, 7
        assert_eq!(s.buckets[4], 1); // 8
        assert_eq!(s.buckets[7], 1); // 127
        assert_eq!(s.buckets[8], 1); // 128
        assert_eq!(s.buckets[64], 1); // u64::MAX
        assert_eq!(s.count, 10);
        // Every sample lands in exactly one bucket.
        assert_eq!(s.buckets.iter().sum::<u64>(), s.count);
    }

    #[test]
    fn histo_quantile_interpolation() {
        // All samples inside one bucket: quantiles interpolate linearly
        // across the bucket's [lo, hi] span.
        let h = Histo::new();
        for _ in 0..1000 {
            h.record(100); // bucket 7 = [64, 127]
        }
        let s = h.snapshot();
        assert_eq!(s.quantile(0.0), 64.0);
        assert!((s.quantile(0.5) - 95.5).abs() < 1e-9, "{}", s.quantile(0.5));
        assert_eq!(s.quantile(1.0), 127.0);
        // Two widely separated buckets: the median sits in the lower one,
        // the tail quantiles in the upper.
        let h = Histo::new();
        for _ in 0..90 {
            h.record(1);
        }
        for _ in 0..10 {
            h.record(1000); // bucket 10 = [512, 1023]
        }
        let s = h.snapshot();
        assert!(s.p50() <= 1.0, "{}", s.p50());
        assert!(s.p95() >= 512.0 && s.p95() <= 1023.0, "{}", s.p95());
        assert!(s.p99() >= s.p95());
        assert!((s.mean() - (90.0 + 10_000.0) / 100.0).abs() < 1e-9);
        // Empty histogram is all zeros, not NaN.
        let empty = Histo::new().snapshot();
        assert_eq!(empty.quantile(0.5), 0.0);
        assert_eq!(empty.mean(), 0.0);
    }

    #[test]
    fn histo_concurrent_record_then_snapshot_is_consistent() {
        let h = Histo::new();
        let threads: u64 = 4;
        let per = 10_000u64;
        let mut handles = Vec::new();
        for t in 0..threads {
            let h = h.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..per {
                    h.record(t * 1000 + i % 257);
                }
            }));
        }
        // Mid-run snapshots must always be internally consistent: counts
        // only grow and each counted sample is in exactly one bucket.
        let mut last_count = 0u64;
        for _ in 0..50 {
            let s = h.snapshot();
            assert!(s.count >= last_count);
            last_count = s.count;
        }
        for th in handles {
            th.join().unwrap();
        }
        let s = h.snapshot();
        assert_eq!(s.count, threads * per);
        assert_eq!(s.buckets.iter().sum::<u64>(), s.count);
        let want_sum: u64 =
            (0..threads).map(|t| (0..per).map(|i| t * 1000 + i % 257).sum::<u64>()).sum();
        assert_eq!(s.sum, want_sum);
    }

    #[test]
    fn registry_get_or_create_by_name_and_labels() {
        let r = Registry::new();
        let a = r.counter("tx_bytes", &[("rank", "0")]);
        let b = r.counter("tx_bytes", &[("rank", "0")]);
        let other = r.counter("tx_bytes", &[("rank", "1")]);
        a.add(5);
        b.add(7);
        other.add(100);
        assert_eq!(a.get(), 12, "same name+labels resolves the same counter");
        assert_eq!(other.get(), 100, "different labels are a different series");
        r.gauge("depth", &[]).set(3.0);
        let h = r.histo("lat_us", &[]);
        h.record(100);
        let text = r.render_text();
        assert!(text.contains("tx_bytes{rank=\"0\"} 12"), "{text}");
        assert!(text.contains("tx_bytes{rank=\"1\"} 100"), "{text}");
        assert!(text.contains("depth 3"), "{text}");
        assert!(text.contains("lat_us{quantile=\"0.5\"}"), "{text}");
        assert!(text.contains("lat_us_count 1"), "{text}");
        assert!(text.contains("lat_us_sum 100"), "{text}");
    }

    #[test]
    fn sample_returns_structured_values_and_series_keys() {
        let r = Registry::new();
        r.counter("tx_bytes", &[("rank", "0")]).add(9);
        r.gauge("depth", &[]).set(2.5);
        let h = r.histo("lat_us", &[("lane", "3")]);
        h.record(10);
        h.record(30);
        let samples = r.sample();
        assert_eq!(samples.len(), 3);
        assert_eq!(samples[0].series_key(), "tx_bytes{rank=0}");
        assert_eq!(samples[0].value, SampleValue::Counter(9));
        assert_eq!(samples[1].series_key(), "depth");
        assert_eq!(samples[1].value, SampleValue::Gauge(2.5));
        assert_eq!(samples[2].series_key(), "lat_us{lane=3}");
        assert_eq!(samples[2].value, SampleValue::Histo { count: 2, sum: 40 });
        // Keys stay whitespace-free (they ride inside JSONL fields).
        assert!(samples.iter().all(|s| !s.series_key().contains(' ')));
    }

    #[test]
    fn global_registry_is_shared() {
        let c = global().counter("obs_test_global_counter", &[("t", "metrics")]);
        c.add(2);
        assert!(global().render_text().contains("obs_test_global_counter{t=\"metrics\"}"));
        assert!(global().counter("obs_test_global_counter", &[("t", "metrics")]).get() >= 2);
    }
}
