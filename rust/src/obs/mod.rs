//! Unified observability plane.
//!
//! The paper's core contribution is *measurement* — attributing step time
//! to compute vs. communication and showing the network runs far below
//! its provisioned rate (Fig 4). This module is the instrumentation that
//! recovers those findings from a *live* run instead of an analytic
//! model:
//!
//! * [`metrics`] — a lock-free metrics registry: [`Counter`] / [`Gauge`]
//!   / [`Histo`] (log-bucketed histograms with interpolated p50/p95/p99),
//!   named + labeled, snapshot-able while workers run. Supersedes the
//!   ad-hoc `net/metrics.rs` counters (which are now built on these
//!   primitives) and backs `netbn serve`'s `GET /metrics` endpoint.
//! * [`span`] — scoped span tracing: `span!("wire.send", rank, step)`
//!   returns an RAII timer that records into a bounded process-global
//!   ring on drop. Disabled (the default) a span is one relaxed atomic
//!   load — cheap enough to leave in every hot path. Spans export as
//!   Chrome trace-event JSON (`netbn launch --trace-out trace.json`
//!   loads directly into Perfetto).
//! * [`breakdown`] — cross-rank aggregation: per-step time breakdowns
//!   (barrier / compute / serialize / wire / reduce vs. the measured
//!   step wall) and a time-bucketed link-utilization timeline, computed
//!   by the launch coordinator from span snapshots the workers ship over
//!   the mesh `tags::CONTROL` channel at step boundaries.
//! * [`timeseries`] — continuous sampling: a background [`Sampler`]
//!   snapshots the registry into rate/level [`TsPoint`] rings with
//!   durable seq cursors, persisted by `netbn serve` as JSONL and
//!   streamed live over `GET /metrics/stream`.
//! * [`detect`] — online anomaly detection (EWMA baseline + MAD
//!   z-score): utilization collapse, throughput regression, straggler
//!   onset. Watches the sampled series, the per-job feedback stream,
//!   launch reports, and `bench_history.jsonl` (`netbn bench --trend`).
//!
//! One tracer per process: `netbn launch` / `netbn _worker` run exactly
//! one traced cohort per process, so the ring needs no scoping. In-crate
//! tests that enable tracing serialize on [`span::test_lock`] so
//! parallel `cargo test` threads cannot interleave span streams.

pub mod breakdown;
pub mod detect;
pub mod metrics;
pub mod span;
pub mod timeseries;

pub use breakdown::StepBreakdown;
pub use detect::Detection;
pub use metrics::{Counter, Gauge, Histo, Registry};
pub use span::SpanRecord;
pub use timeseries::{Sampler, TimeSeries, TsPoint};
