//! Scoped span tracing with a bounded process-global ring.
//!
//! `span!("wire.send", rank, step)` (optionally `, bytes`) returns an
//! RAII guard; when it drops, a [`SpanRecord`] lands in the ring. With
//! tracing **disabled — the default — a span is a single relaxed atomic
//! load and no clock read**, so the instrumentation stays in every hot
//! path permanently (the bench gate holds the enabled path to within 3%
//! of uninstrumented throughput; the disabled path is free).
//!
//! The ring is process-global and sequence-numbered: readers snapshot
//! non-destructively with [`since`], so in thread-spawned launches every
//! rank ships its own spans (filtered by rank, advancing its own cursor)
//! out of the one shared ring without racing the others. Records encode
//! to a compact binary frame ([`encode`]/[`decode`]) for shipping over
//! the mesh control channel, and any collection of records exports as
//! Chrome trace-event JSON ([`chrome_trace_json`]) for Perfetto.

use crate::Result;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

/// Spans retained in the ring; older records are dropped (bounded
/// memory — a run that outgrows the ring loses the oldest spans, never
/// blocks a worker).
pub const RING_CAP: usize = 65_536;

/// One completed span.
#[derive(Clone, Debug, PartialEq)]
pub struct SpanRecord {
    /// Ring sequence number (monotonic per process).
    pub seq: u64,
    pub name: String,
    pub rank: u32,
    pub step: u32,
    /// Start, µs since the process trace epoch.
    pub start_us: u64,
    pub dur_us: u64,
    /// Payload bytes the span moved (0 when not applicable).
    pub bytes: u64,
}

impl SpanRecord {
    pub fn end_us(&self) -> u64 {
        self.start_us + self.dur_us
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);

struct Ring {
    next_seq: u64,
    buf: VecDeque<SpanRecord>,
}

fn ring() -> &'static Mutex<Ring> {
    static RING: OnceLock<Mutex<Ring>> = OnceLock::new();
    RING.get_or_init(|| Mutex::new(Ring { next_seq: 0, buf: VecDeque::new() }))
}

/// The process trace epoch: fixed at first use so `start_us` values are
/// comparable within a process.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Turn recording on (idempotent). Never turned off implicitly: a launch
/// that doesn't trace simply never enables it.
pub fn enable() {
    epoch();
    ENABLED.store(true, Ordering::Relaxed);
}

/// Turn recording off (tests).
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

pub fn is_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// RAII span timer; records on drop when armed.
pub struct SpanGuard {
    name: &'static str,
    rank: u32,
    step: u32,
    bytes: u64,
    start: Option<Instant>,
}

impl SpanGuard {
    /// Attach the byte count after entry (e.g. once the payload size is
    /// known mid-span).
    pub fn set_bytes(&mut self, bytes: u64) {
        self.bytes = bytes;
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        let start_us = start.duration_since(epoch()).as_micros() as u64;
        let dur_us = start.elapsed().as_micros() as u64;
        let mut r = ring().lock().unwrap();
        let seq = r.next_seq;
        r.next_seq += 1;
        if r.buf.len() >= RING_CAP {
            r.buf.pop_front();
        }
        r.buf.push_back(SpanRecord {
            seq,
            name: self.name.to_string(),
            rank: self.rank,
            step: self.step,
            start_us,
            dur_us,
            bytes: self.bytes,
        });
    }
}

/// Open a span. Prefer the [`crate::span!`] macro at call sites.
pub fn enter(name: &'static str, rank: u32, step: u32) -> SpanGuard {
    enter_bytes(name, rank, step, 0)
}

/// Open a span carrying a payload byte count.
pub fn enter_bytes(name: &'static str, rank: u32, step: u32, bytes: u64) -> SpanGuard {
    let start = if is_enabled() { Some(Instant::now()) } else { None };
    SpanGuard { name, rank, step, bytes, start }
}

/// Scoped span timer: `span!("wire.send", rank, step)` or
/// `span!("wire.send", rank, step, bytes)`. Bind the result
/// (`let _sp = span!(...)`) so the guard lives to the end of the scope.
#[macro_export]
macro_rules! span {
    ($name:expr, $rank:expr, $step:expr) => {
        $crate::obs::span::enter($name, $rank as u32, $step as u32)
    };
    ($name:expr, $rank:expr, $step:expr, $bytes:expr) => {
        $crate::obs::span::enter_bytes($name, $rank as u32, $step as u32, $bytes as u64)
    };
}

/// Non-destructive snapshot: records with `seq >= after` (optionally
/// only one rank's), plus the cursor to pass next time. The cursor
/// covers everything in the ring at snapshot time, including records the
/// rank filter skipped — those belong to other ranks and are never this
/// caller's to ship.
pub fn since(after: u64, rank: Option<u32>) -> (Vec<SpanRecord>, u64) {
    let r = ring().lock().unwrap();
    let cursor = r.next_seq;
    let out = r
        .buf
        .iter()
        .filter(|s| s.seq >= after && rank.map_or(true, |rk| s.rank == rk))
        .cloned()
        .collect();
    (out, cursor)
}

/// The next sequence number the ring will assign — snapshot this before
/// a run starts so [`since`] skips anything recorded earlier.
pub fn cursor() -> u64 {
    ring().lock().unwrap().next_seq
}

/// Drop every buffered record (tests / between runs).
pub fn clear() {
    ring().lock().unwrap().buf.clear();
}

/// Serializes in-crate tests that enable the global tracer, so parallel
/// `cargo test` threads don't interleave span streams. Recovers from a
/// poisoned lock (a failed test must not cascade).
#[doc(hidden)]
pub fn test_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Binary frame for shipping spans over the control channel:
/// `u32 count`, then per record `seq u64 | rank u32 | step u32 |
/// start_us u64 | dur_us u64 | bytes u64 | name_len u16 | name utf8`,
/// all little-endian.
pub fn encode(spans: &[SpanRecord]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + spans.len() * 48);
    out.extend_from_slice(&(spans.len() as u32).to_le_bytes());
    for s in spans {
        out.extend_from_slice(&s.seq.to_le_bytes());
        out.extend_from_slice(&s.rank.to_le_bytes());
        out.extend_from_slice(&s.step.to_le_bytes());
        out.extend_from_slice(&s.start_us.to_le_bytes());
        out.extend_from_slice(&s.dur_us.to_le_bytes());
        out.extend_from_slice(&s.bytes.to_le_bytes());
        let name = s.name.as_bytes();
        out.extend_from_slice(&(name.len() as u16).to_le_bytes());
        out.extend_from_slice(name);
    }
    out
}

/// Inverse of [`encode`].
pub fn decode(buf: &[u8]) -> Result<Vec<SpanRecord>> {
    let mut at = 0usize;
    let take = |at: &mut usize, n: usize| -> Result<&[u8]> {
        anyhow::ensure!(*at + n <= buf.len(), "span frame truncated at byte {at}");
        let s = &buf[*at..*at + n];
        *at += n;
        Ok(s)
    };
    let count = u32::from_le_bytes(take(&mut at, 4)?.try_into().unwrap()) as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let seq = u64::from_le_bytes(take(&mut at, 8)?.try_into().unwrap());
        let rank = u32::from_le_bytes(take(&mut at, 4)?.try_into().unwrap());
        let step = u32::from_le_bytes(take(&mut at, 4)?.try_into().unwrap());
        let start_us = u64::from_le_bytes(take(&mut at, 8)?.try_into().unwrap());
        let dur_us = u64::from_le_bytes(take(&mut at, 8)?.try_into().unwrap());
        let bytes = u64::from_le_bytes(take(&mut at, 8)?.try_into().unwrap());
        let name_len = u16::from_le_bytes(take(&mut at, 2)?.try_into().unwrap()) as usize;
        let name = std::str::from_utf8(take(&mut at, name_len)?)
            .map_err(|e| anyhow::anyhow!("span name not utf8: {e}"))?
            .to_string();
        out.push(SpanRecord { seq, name, rank, step, start_us, dur_us, bytes });
    }
    anyhow::ensure!(at == buf.len(), "{} trailing bytes after span frame", buf.len() - at);
    Ok(out)
}

/// Render records as Chrome trace-event JSON (the `traceEvents` array
/// format — load the file straight into Perfetto or
/// `chrome://tracing`). Every span emits a matched `B`/`E` pair; events
/// are sorted by timestamp (`B` before `E` on ties so zero-length spans
/// stay well-formed). `pid` is the rank, `tid` groups spans of the same
/// name onto one track.
pub fn chrome_trace_json(spans: &[SpanRecord]) -> String {
    use std::fmt::Write as _;
    let mut tids: Vec<&str> = Vec::new();
    let mut events: Vec<(u64, u8, String)> = Vec::with_capacity(spans.len() * 2);
    for s in spans {
        let tid = match tids.iter().position(|n| *n == s.name) {
            Some(i) => i,
            None => {
                tids.push(&s.name);
                tids.len() - 1
            }
        };
        let name = crate::report::json_str(&s.name);
        events.push((
            s.start_us,
            0,
            format!(
                "{{\"name\":{name},\"ph\":\"B\",\"ts\":{},\"pid\":{},\"tid\":{tid},\
                 \"args\":{{\"step\":{},\"bytes\":{}}}}}",
                s.start_us, s.rank, s.step, s.bytes
            ),
        ));
        events.push((
            s.end_us(),
            1,
            format!(
                "{{\"name\":{name},\"ph\":\"E\",\"ts\":{},\"pid\":{},\"tid\":{tid}}}",
                s.end_us(),
                s.rank
            ),
        ));
    }
    events.sort_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
    let mut out = String::from("{\"traceEvents\":[\n");
    for (i, (_, _, e)) in events.iter().enumerate() {
        out.push_str(e);
        if i + 1 < events.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push_str("]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(seq: u64, name: &str, rank: u32, start_us: u64, dur_us: u64) -> SpanRecord {
        SpanRecord {
            seq,
            name: name.to_string(),
            rank,
            step: 1,
            start_us,
            dur_us,
            bytes: 4096,
        }
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _serial = test_lock();
        disable();
        let before = cursor();
        {
            let _sp = crate::span!("obs.test.disabled", 900, 0);
        }
        let (got, _) = since(before, Some(900));
        assert!(got.is_empty(), "{got:?}");
    }

    #[test]
    fn enabled_spans_land_in_the_ring_with_rank_filtering() {
        let _serial = test_lock();
        enable();
        let before = cursor();
        {
            let _a = crate::span!("obs.test.a", 901, 3, 128);
            let _b = crate::span!("obs.test.b", 902, 3);
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        disable();
        let (mine, cur) = since(before, Some(901));
        assert_eq!(mine.len(), 1);
        assert_eq!(mine[0].name, "obs.test.a");
        assert_eq!(mine[0].step, 3);
        assert_eq!(mine[0].bytes, 128);
        assert!(mine[0].dur_us >= 1000, "{:?}", mine[0]);
        assert!(cur > before);
        // The cursor advanced past BOTH records: re-snapshotting from it
        // re-ships nothing, for either rank.
        assert!(since(cur, Some(901)).0.is_empty());
        assert!(since(cur, Some(902)).0.is_empty());
        let (other, _) = since(before, Some(902));
        assert_eq!(other.len(), 1);
        assert_eq!(other[0].name, "obs.test.b");
    }

    #[test]
    fn encode_decode_round_trip() {
        let spans = vec![
            sample(7, "wire.send", 0, 100, 50),
            sample(8, "reduce.add", 3, 160, 0),
            sample(9, "step.total", 1, 0, 100_000),
        ];
        let wire = encode(&spans);
        assert_eq!(decode(&wire).unwrap(), spans);
        assert_eq!(decode(&encode(&[])).unwrap(), vec![]);
        assert!(decode(&wire[..wire.len() - 1]).is_err(), "truncated frame must fail");
        let mut extra = wire.clone();
        extra.push(0);
        assert!(decode(&extra).is_err(), "trailing bytes must fail");
    }

    #[test]
    fn chrome_trace_has_matched_pairs_and_monotonic_ts() {
        let spans = vec![
            sample(0, "step.total", 0, 0, 300),
            sample(1, "wire.send", 0, 50, 100),
            sample(2, "wire.send", 1, 60, 120),
            sample(3, "zero.len", 0, 70, 0),
        ];
        let json = chrome_trace_json(&spans);
        // Well-formed JSON with a single traceEvents array.
        let fields = crate::util::json::object_fields(&json).unwrap();
        let events_raw = crate::util::json::get(&fields, "traceEvents").unwrap();
        assert!(events_raw.starts_with('['));
        // One B and one E per span, B's ts never after its E.
        let count = |needle: &str| json.matches(needle).count();
        assert_eq!(count("\"ph\":\"B\""), spans.len());
        assert_eq!(count("\"ph\":\"E\""), spans.len());
        // Timestamps are monotone non-decreasing in emission order, and a
        // zero-length span's B precedes its E.
        let mut last_ts = 0u64;
        let mut b_seen = 0i64;
        for line in json.lines().filter(|l| l.contains("\"ph\"")) {
            let ts_at = line.find("\"ts\":").unwrap() + 5;
            let ts: u64 = line[ts_at..]
                .chars()
                .take_while(|c| c.is_ascii_digit())
                .collect::<String>()
                .parse()
                .unwrap();
            assert!(ts >= last_ts, "ts went backwards in:\n{json}");
            last_ts = ts;
            b_seen += if line.contains("\"ph\":\"B\"") { 1 } else { -1 };
            assert!(b_seen >= 0, "an E appeared before any matching B:\n{json}");
        }
        assert_eq!(b_seen, 0, "unmatched B/E pairs:\n{json}");
        // Args ride on the B event.
        assert!(json.contains("\"args\":{\"step\":1,\"bytes\":4096}"), "{json}");
    }

    #[test]
    fn ring_is_bounded() {
        let _serial = test_lock();
        enable();
        let before = cursor();
        for _ in 0..(RING_CAP + 10) {
            let _sp = crate::span!("obs.test.flood", 903, 0);
        }
        disable();
        let (got, _) = since(before, Some(903));
        assert!(got.len() <= RING_CAP);
        clear();
        assert!(since(0, None).0.is_empty());
    }
}
