//! Continuous time-series sampling of the metrics registry.
//!
//! A [`TimeSeries`] periodically snapshots a [`Registry`]
//! ([`Registry::sample`]) and turns the cumulative values into
//! [`TsPoint`]s: counters and histogram counts become **rates**
//! (delta / elapsed seconds), gauges stay **levels**, and histograms
//! additionally emit the mean of the samples recorded since the last
//! round. Points land in a bounded, sequence-numbered ring with the
//! same non-destructive cursor contract as the span ring and the
//! feedback rings: `GET /metrics/stream?since=N` resumes exactly where
//! it left off, and a reader that falls behind loses the overwritten
//! prefix, never sees duplicates.
//!
//! Every sampled rate/utilization series also flows through an online
//! [`SeriesDetector`] ([`super::detect`]), so the daemon notices a
//! utilization collapse while the run is still going — the paper's
//! "nobody watched the network" failure, automated away.
//!
//! Sequence numbers are durable: the serve store persists points as
//! JSONL and a restarted daemon resumes from the last persisted seq
//! ([`TimeSeries::resume_from`]) without duplicating or losing cursors.

use super::detect::{Detection, DetectionKind, DetectorConfig, SeriesDetector};
use super::metrics::{Registry, SampleValue};
use crate::Result;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Points retained in the ring (across all series).
pub const TS_RING_CAP: usize = 16_384;
/// Detections retained alongside the ring.
pub const DETECTIONS_CAP: usize = 256;
/// The serve daemon's default sampling cadence.
pub const DEFAULT_SAMPLE_INTERVAL: Duration = Duration::from_secs(1);

/// One sampled value of one series.
#[derive(Clone, Debug, PartialEq)]
pub struct TsPoint {
    /// Global monotonic sequence number (durable across daemon restarts).
    pub seq: u64,
    /// Seconds since this daemon life's sampler started.
    pub t_s: f64,
    /// Series key (`name{k=v,...}`, with `.rate` / `.mean` suffixes for
    /// the derived histogram series).
    pub series: String,
    /// Rate (per second) or level, per `kind`.
    pub value: f64,
    /// `"rate"` or `"level"`.
    pub kind: TsKind,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TsKind {
    Rate,
    Level,
}

impl TsKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            TsKind::Rate => "rate",
            TsKind::Level => "level",
        }
    }
}

impl TsPoint {
    /// One JSONL line (the store format and the stream format).
    pub fn to_json_line(&self) -> String {
        format!(
            "{{\"seq\":{},\"t_s\":{:.3},\"series\":{},\"kind\":\"{}\",\"value\":{}}}",
            self.seq,
            self.t_s,
            crate::report::json_str(&self.series),
            self.kind.as_str(),
            if self.value.is_finite() { format!("{:.6}", self.value) } else { "0".to_string() }
        )
    }

    /// Inverse of [`TsPoint::to_json_line`].
    pub fn from_json_line(line: &str) -> Result<TsPoint> {
        use crate::util::json;
        let fields = json::object_fields(line)?;
        let kind = match json::parse_string(json::require(&fields, "kind")?)?.as_str() {
            "rate" => TsKind::Rate,
            "level" => TsKind::Level,
            other => anyhow::bail!("bad timeseries kind {other:?}"),
        };
        Ok(TsPoint {
            seq: json::parse_u64(json::require(&fields, "seq")?)?,
            t_s: json::parse_f64(json::require(&fields, "t_s")?)?,
            series: json::parse_string(json::require(&fields, "series")?)?,
            value: json::parse_f64(json::require(&fields, "value")?)?,
            kind,
        })
    }
}

/// Per-series cumulative state from the previous sampling round.
#[derive(Clone, Copy, Default)]
struct LastRaw {
    count: u64,
    sum: u64,
}

struct TsInner {
    next_seq: u64,
    buf: VecDeque<TsPoint>,
    /// Last cumulative counter/histogram values, for the deltas.
    last: BTreeMap<String, LastRaw>,
    /// Wall clock of the previous round (None before the first).
    last_t: Option<f64>,
    detectors: BTreeMap<String, SeriesDetector>,
    detections: VecDeque<Detection>,
    rounds: u64,
}

/// The sampled store: ring + seq cursors + online detectors.
pub struct TimeSeries {
    t0: Instant,
    inner: Mutex<TsInner>,
}

impl Default for TimeSeries {
    fn default() -> Self {
        TimeSeries::new()
    }
}

impl TimeSeries {
    pub fn new() -> TimeSeries {
        TimeSeries::resume_from(0)
    }

    /// Resume sequence numbering after `next_seq` — pass `last persisted
    /// seq + 1` so a restarted daemon's stream and store stay gap- and
    /// duplicate-free.
    pub fn resume_from(next_seq: u64) -> TimeSeries {
        TimeSeries {
            t0: Instant::now(),
            inner: Mutex::new(TsInner {
                next_seq,
                buf: VecDeque::new(),
                last: BTreeMap::new(),
                last_t: None,
                detectors: BTreeMap::new(),
                detections: VecDeque::new(),
                rounds: 0,
            }),
        }
    }

    /// The next sequence number the ring will assign.
    pub fn cursor(&self) -> u64 {
        self.inner.lock().unwrap().next_seq
    }

    /// Sampling rounds taken so far.
    pub fn rounds(&self) -> u64 {
        self.inner.lock().unwrap().rounds
    }

    /// Snapshot `registry` once: append the derived points to the ring
    /// and return them (the persistence sink writes exactly this batch).
    /// The first round only primes the cumulative state — rates need a
    /// baseline — so it returns gauge levels but no counter rates.
    pub fn sample(&self, registry: &Registry) -> Vec<TsPoint> {
        let now = self.t0.elapsed().as_secs_f64();
        let samples = registry.sample();
        let mut inner = self.inner.lock().unwrap();
        let dt = inner.last_t.map(|t| now - t);
        let mut fresh: Vec<(String, f64, TsKind)> = Vec::new();
        for s in samples {
            let key = s.series_key();
            match s.value {
                SampleValue::Gauge(v) => fresh.push((key, v, TsKind::Level)),
                SampleValue::Counter(v) => {
                    let prev = inner.last.get(&key).copied();
                    inner.last.insert(key.clone(), LastRaw { count: v, sum: 0 });
                    if let (Some(prev), Some(dt)) = (prev, dt) {
                        if dt > 0.0 {
                            let rate = v.saturating_sub(prev.count) as f64 / dt;
                            fresh.push((format!("{key}.rate"), rate, TsKind::Rate));
                        }
                    }
                }
                SampleValue::Histo { count, sum } => {
                    let prev = inner.last.get(&key).copied();
                    inner.last.insert(key.clone(), LastRaw { count, sum });
                    if let (Some(prev), Some(dt)) = (prev, dt) {
                        if dt > 0.0 {
                            let dc = count.saturating_sub(prev.count);
                            fresh.push((format!("{key}.rate"), dc as f64 / dt, TsKind::Rate));
                            if dc > 0 {
                                let mean = sum.saturating_sub(prev.sum) as f64 / dc as f64;
                                fresh.push((format!("{key}.mean"), mean, TsKind::Level));
                            }
                        }
                    }
                }
            }
        }
        inner.last_t = Some(now);
        inner.rounds += 1;
        let mut out = Vec::with_capacity(fresh.len());
        for (series, value, kind) in fresh {
            let seq = inner.next_seq;
            inner.next_seq += 1;
            let p = TsPoint { seq, t_s: now, series, value, kind };
            // The daemon watches every bandwidth/utilization series it
            // samples — detection is a property of the stream, not of
            // any one consumer.
            if let Some(dkind) = watch_kind(&p.series) {
                let det = inner
                    .detectors
                    .entry(p.series.clone())
                    .or_insert_with(|| SeriesDetector::new(DetectorConfig::utilization()));
                if let Some((z, baseline)) = det.observe(p.value) {
                    if inner.detections.len() >= DETECTIONS_CAP {
                        inner.detections.pop_front();
                    }
                    inner.detections.push_back(Detection {
                        kind: dkind,
                        series: p.series.clone(),
                        at: seq,
                        z,
                        baseline,
                        value: p.value,
                    });
                }
            }
            if inner.buf.len() >= TS_RING_CAP {
                inner.buf.pop_front();
            }
            inner.buf.push_back(p.clone());
            out.push(p);
        }
        out
    }

    /// Non-destructive snapshot: points with `seq >= after`, plus the
    /// cursor to pass next time (same contract as
    /// [`crate::obs::span::since`]).
    pub fn since(&self, after: u64) -> (Vec<TsPoint>, u64) {
        let inner = self.inner.lock().unwrap();
        let cursor = inner.next_seq;
        (inner.buf.iter().filter(|p| p.seq >= after).cloned().collect(), cursor)
    }

    /// Every retained detection (bounded at [`DETECTIONS_CAP`]).
    pub fn detections(&self) -> Vec<Detection> {
        self.inner.lock().unwrap().detections.iter().cloned().collect()
    }
}

/// Which series the daemon's standing detectors watch, and as what kind:
/// utilization/wire-rate series collapse, other bandwidth series regress.
fn watch_kind(series: &str) -> Option<DetectionKind> {
    if series.contains("util") || series.contains("wire.") {
        Some(DetectionKind::UtilizationCollapse)
    } else if series.contains("gbps") || series.contains("bps") {
        Some(DetectionKind::ThroughputRegression)
    } else {
        None
    }
}

/// A background sampling thread: snapshots `registry` into `ts` every
/// `interval` and hands each fresh batch to the persistence sink.
pub struct Sampler {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl Sampler {
    pub fn start(
        ts: Arc<TimeSeries>,
        registry: &'static Registry,
        interval: Duration,
        mut persist: Option<Box<dyn FnMut(&[TsPoint]) + Send>>,
    ) -> Sampler {
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::Builder::new()
            .name("obs-sampler".to_string())
            .spawn(move || {
                let mut last = Instant::now();
                // Prime the cumulative state immediately so the first
                // interval already yields rates.
                let first = ts.sample(registry);
                if let Some(p) = persist.as_mut() {
                    p(&first);
                }
                while !stop2.load(Ordering::Relaxed) {
                    // Short dozes so stop() returns promptly even with a
                    // long sampling interval.
                    std::thread::sleep(Duration::from_millis(25).min(interval));
                    if last.elapsed() < interval {
                        continue;
                    }
                    last = Instant::now();
                    let batch = ts.sample(registry);
                    if let Some(p) = persist.as_mut() {
                        p(&batch);
                    }
                }
            })
            .expect("spawn obs sampler");
        Sampler { stop, handle: Some(handle) }
    }

    /// Stop and join (idempotent; also runs on drop).
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Sampler {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_become_rates_and_gauges_stay_levels() {
        let reg = Registry::new();
        let ts = TimeSeries::new();
        let c = reg.counter("bytes_tx", &[("rank", "0")]);
        reg.gauge("depth", &[]).set(3.0);
        c.add(100);
        let first = ts.sample(&reg);
        // Round 1: only the gauge level (rates need a baseline).
        assert_eq!(first.len(), 1, "{first:?}");
        assert_eq!(first[0].series, "depth");
        assert_eq!(first[0].kind, TsKind::Level);
        c.add(300);
        std::thread::sleep(Duration::from_millis(20));
        let second = ts.sample(&reg);
        let rate = second.iter().find(|p| p.series == "bytes_tx{rank=0}.rate").unwrap();
        assert_eq!(rate.kind, TsKind::Rate);
        // 300 new bytes over >= 20ms: the rate is positive and bounded.
        assert!(rate.value > 0.0 && rate.value <= 300.0 / 0.02, "{rate:?}");
        // Seqs are dense and monotonic across rounds.
        let seqs: Vec<u64> = first.iter().chain(&second).map(|p| p.seq).collect();
        assert!(seqs.windows(2).all(|w| w[1] == w[0] + 1), "{seqs:?}");
    }

    #[test]
    fn histos_emit_count_rate_and_interval_mean() {
        let reg = Registry::new();
        let ts = TimeSeries::new();
        let h = reg.histo("send_us", &[("lane", "1")]);
        h.record(10);
        ts.sample(&reg);
        h.record(30);
        h.record(50);
        std::thread::sleep(Duration::from_millis(10));
        let batch = ts.sample(&reg);
        let mean = batch.iter().find(|p| p.series == "send_us{lane=1}.mean").unwrap();
        // Only the NEW samples (30, 50) are in the interval mean.
        assert!((mean.value - 40.0).abs() < 1e-9, "{mean:?}");
        assert!(batch.iter().any(|p| p.series == "send_us{lane=1}.rate"));
    }

    #[test]
    fn since_cursor_resumes_without_duplicates() {
        let reg = Registry::new();
        let ts = TimeSeries::new();
        reg.gauge("g", &[]).set(1.0);
        ts.sample(&reg);
        let (all, cur) = ts.since(0);
        assert_eq!(all.len(), 1);
        assert_eq!(cur, 1);
        assert!(ts.since(cur).0.is_empty(), "cursor resume must yield only the delta");
        ts.sample(&reg);
        let (delta, cur2) = ts.since(cur);
        assert_eq!(delta.len(), 1);
        assert_eq!(delta[0].seq, 1);
        assert_eq!(cur2, 2);
    }

    #[test]
    fn resume_from_continues_the_durable_seq_space() {
        let reg = Registry::new();
        reg.gauge("g", &[]).set(1.0);
        let ts = TimeSeries::resume_from(41);
        let batch = ts.sample(&reg);
        assert_eq!(batch[0].seq, 41);
        assert_eq!(ts.cursor(), 42);
    }

    #[test]
    fn json_lines_round_trip() {
        let p = TsPoint {
            seq: 7,
            t_s: 1.25,
            series: "wire.lane.send_us{lane=3}.rate".to_string(),
            value: 125.5,
            kind: TsKind::Rate,
        };
        let back = TsPoint::from_json_line(&p.to_json_line()).unwrap();
        assert_eq!(back.seq, 7);
        assert_eq!(back.series, p.series);
        assert_eq!(back.kind, TsKind::Rate);
        assert!((back.value - 125.5).abs() < 1e-6);
        assert!(TsPoint::from_json_line("{\"seq\":1}").is_err());
        assert!(TsPoint::from_json_line("not json").is_err());
    }

    #[test]
    fn sampled_gbps_series_flow_through_the_collapse_detector() {
        let reg = Registry::new();
        let ts = TimeSeries::new();
        let g = reg.gauge("e2e.busbw_gbps", &[]);
        for _ in 0..8 {
            g.set(10.0);
            ts.sample(&reg);
        }
        assert!(ts.detections().is_empty(), "steady series must stay silent");
        for _ in 0..3 {
            g.set(0.5);
            ts.sample(&reg);
        }
        let dets = ts.detections();
        assert_eq!(dets.len(), 1, "{dets:?}");
        assert_eq!(dets[0].kind, DetectionKind::ThroughputRegression);
        assert_eq!(dets[0].series, "e2e.busbw_gbps");
    }

    #[test]
    fn ring_is_bounded() {
        let reg = Registry::new();
        let ts = TimeSeries::new();
        let g = reg.gauge("g", &[]);
        for i in 0..(TS_RING_CAP + 50) {
            g.set(i as f64);
            ts.sample(&reg);
        }
        let (got, cur) = ts.since(0);
        assert!(got.len() <= TS_RING_CAP);
        assert_eq!(cur, (TS_RING_CAP + 50) as u64);
        // The oldest retained point reflects the drop.
        assert_eq!(got[0].seq, 50);
    }

    #[test]
    fn sampler_thread_samples_and_persists() {
        let reg: &'static Registry = Box::leak(Box::new(Registry::new()));
        reg.gauge("sampler_test_g", &[]).set(1.0);
        let ts = Arc::new(TimeSeries::new());
        let persisted: Arc<Mutex<Vec<TsPoint>>> = Arc::new(Mutex::new(Vec::new()));
        let sink = Arc::clone(&persisted);
        let mut s = Sampler::start(
            Arc::clone(&ts),
            reg,
            Duration::from_millis(30),
            Some(Box::new(move |batch: &[TsPoint]| {
                sink.lock().unwrap().extend_from_slice(batch);
            })),
        );
        let deadline = Instant::now() + Duration::from_secs(5);
        while ts.rounds() < 3 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(10));
        }
        s.stop();
        assert!(ts.rounds() >= 3, "sampler only took {} rounds", ts.rounds());
        let persisted = persisted.lock().unwrap();
        assert!(!persisted.is_empty());
        // Persisted exactly the ring's points: same seqs, no duplicates.
        let mut seqs: Vec<u64> = persisted.iter().map(|p| p.seq).collect();
        let n = seqs.len();
        seqs.dedup();
        assert_eq!(seqs.len(), n, "duplicate seqs persisted");
    }
}
