//! Result presentation: ASCII tables, figure series, CSV/JSON writers and
//! paper-shape checks. (serde stands replaced by purpose-built writers —
//! the offline build has no serde.)

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// A named data series: `(x, y)` points.
#[derive(Clone, Debug, PartialEq)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Series {
        Series { name: name.into(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }

    pub fn y_at(&self, x: f64) -> Option<f64> {
        self.points.iter().find(|(px, _)| (*px - x).abs() < 1e-9).map(|(_, y)| *y)
    }
}

/// One figure: what the paper plots, as regenerable data.
#[derive(Clone, Debug)]
pub struct Figure {
    /// e.g. "fig3".
    pub id: String,
    pub title: String,
    pub x_label: String,
    pub y_label: String,
    pub series: Vec<Series>,
}

impl Figure {
    pub fn new(
        id: impl Into<String>,
        title: impl Into<String>,
        x_label: impl Into<String>,
        y_label: impl Into<String>,
    ) -> Figure {
        Figure {
            id: id.into(),
            title: title.into(),
            x_label: x_label.into(),
            y_label: y_label.into(),
            series: Vec::new(),
        }
    }

    pub fn series(&self, name: &str) -> Option<&Series> {
        self.series.iter().find(|s| s.name == name)
    }

    /// Render as an aligned text table: one row per x, one column per series.
    pub fn render(&self) -> String {
        let mut xs: Vec<f64> = self.series.iter().flat_map(|s| s.points.iter().map(|p| p.0)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        xs.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
        let mut out = String::new();
        let _ = writeln!(out, "[{}] {}", self.id, self.title);
        let _ = write!(out, "{:>14}", self.x_label);
        for s in &self.series {
            let _ = write!(out, " {:>18}", truncate(&s.name, 18));
        }
        let _ = writeln!(out, "    ({})", self.y_label);
        for x in &xs {
            let _ = write!(out, "{x:>14.3}");
            for s in &self.series {
                match s.y_at(*x) {
                    Some(y) => {
                        let _ = write!(out, " {y:>18.4}");
                    }
                    None => {
                        let _ = write!(out, " {:>18}", "-");
                    }
                }
            }
            let _ = writeln!(out);
        }
        out
    }

    /// Write `out_dir/<id>.csv`: `x,<series...>` header then one row per x.
    pub fn write_csv(&self, out_dir: &Path) -> crate::Result<std::path::PathBuf> {
        std::fs::create_dir_all(out_dir)?;
        let path = out_dir.join(format!("{}.csv", self.id));
        let mut f = std::fs::File::create(&path)?;
        let mut header = vec![self.x_label.clone()];
        header.extend(self.series.iter().map(|s| csv_escape(&s.name)));
        writeln!(f, "{}", header.join(","))?;
        let mut xs: Vec<f64> = self.series.iter().flat_map(|s| s.points.iter().map(|p| p.0)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        xs.dedup_by(|a, b| (*a - *b).abs() < 1e-9);
        for x in xs {
            let mut row = vec![format!("{x}")];
            for s in &self.series {
                row.push(s.y_at(x).map(|y| format!("{y}")).unwrap_or_default());
            }
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(path)
    }

    /// Minimal JSON encoding (hand-rolled; numbers + strings only).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        let _ = write!(
            s,
            "{{\"id\":{},\"title\":{},\"x_label\":{},\"y_label\":{},\"series\":[",
            json_str(&self.id),
            json_str(&self.title),
            json_str(&self.x_label),
            json_str(&self.y_label)
        );
        for (i, ser) in self.series.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            let _ = write!(s, "{{\"name\":{},\"points\":[", json_str(&ser.name));
            for (j, (x, y)) in ser.points.iter().enumerate() {
                if j > 0 {
                    s.push(',');
                }
                let _ = write!(s, "[{x},{y}]");
            }
            s.push_str("]}");
        }
        s.push_str("]}");
        s
    }
}

fn truncate(s: &str, n: usize) -> String {
    if s.len() <= n {
        s.to_string()
    } else {
        format!("{}…", &s[..n.saturating_sub(1)])
    }
}

fn csv_escape(s: &str) -> String {
    if s.contains(',') || s.contains('"') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// JSON string literal with escaping.
pub fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// A generic ASCII table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Minimal JSON encoding, mirroring [`Figure::to_json`]:
    /// `{"title":..,"headers":[..],"rows":[[..]]}`.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        let _ = write!(s, "{{\"title\":{},\"headers\":[", json_str(&self.title));
        for (i, h) in self.headers.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&json_str(h));
        }
        s.push_str("],\"rows\":[");
        for (i, row) in self.rows.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push('[');
            for (j, cell) in row.iter().enumerate() {
                if j > 0 {
                    s.push(',');
                }
                s.push_str(&json_str(cell));
            }
            s.push(']');
        }
        s.push_str("]}");
        s
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers, &widths));
        let _ = writeln!(out, "{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }
}

/// A paper-shape assertion: "who wins / by what factor / where's the knee"
/// checks recorded alongside each regenerated figure.
#[derive(Clone, Debug)]
pub struct Check {
    pub desc: String,
    pub pass: bool,
    pub detail: String,
}

impl Check {
    pub fn assert(desc: impl Into<String>, pass: bool, detail: impl Into<String>) -> Check {
        Check { desc: desc.into(), pass, detail: detail.into() }
    }
}

/// Render a check list; returns `(rendered, all_passed)`.
pub fn render_checks(checks: &[Check]) -> (String, bool) {
    let mut out = String::new();
    let mut all = true;
    for c in checks {
        all &= c.pass;
        let _ = writeln!(out, "  [{}] {} — {}", if c.pass { "PASS" } else { "FAIL" }, c.desc, c.detail);
    }
    (out, all)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn series_lookup() {
        let mut s = Series::new("a");
        s.push(1.0, 10.0);
        s.push(2.0, 20.0);
        assert_eq!(s.y_at(2.0), Some(20.0));
        assert_eq!(s.y_at(3.0), None);
    }

    #[test]
    fn figure_render_aligns_series() {
        let mut f = Figure::new("figX", "test", "x", "y");
        let mut a = Series::new("a");
        a.push(1.0, 0.5);
        let mut b = Series::new("b");
        b.push(1.0, 0.6);
        b.push(2.0, 0.7);
        f.series = vec![a, b];
        let r = f.render();
        assert!(r.contains("figX"));
        assert!(r.contains('-')); // missing point placeholder
    }

    #[test]
    fn csv_written_with_header() {
        let dir = std::env::temp_dir().join("netbn_test_csv");
        let mut f = Figure::new("figY", "t", "bw", "sf");
        let mut s = Series::new("m,1");
        s.push(1.0, 0.1);
        f.series = vec![s];
        let p = f.write_csv(&dir).unwrap();
        let text = std::fs::read_to_string(p).unwrap();
        assert!(text.starts_with("bw,\"m,1\""));
        assert!(text.contains("1,0.1"));
    }

    #[test]
    fn json_escaping() {
        assert_eq!(json_str("a\"b\\c\n"), "\"a\\\"b\\\\c\\n\"");
        let mut f = Figure::new("f", "t", "x", "y");
        f.series.push(Series { name: "s".into(), points: vec![(1.0, 2.0)] });
        let j = f.to_json();
        assert!(j.contains("\"points\":[[1,2]]"));
    }

    #[test]
    fn table_render() {
        let mut t = Table::new("T", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("T"));
        assert!(r.contains("bb"));
    }

    #[test]
    fn table_json() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into(), "x\"y".into()]);
        assert_eq!(
            t.to_json(),
            "{\"title\":\"T\",\"headers\":[\"a\",\"b\"],\"rows\":[[\"1\",\"x\\\"y\"]]}"
        );
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }

    #[test]
    fn checks_aggregate() {
        let (_, ok) = render_checks(&[
            Check::assert("x", true, ""),
            Check::assert("y", false, "boom"),
        ]);
        assert!(!ok);
    }
}
