//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `make artifacts` from the JAX/Pallas side) and executes them on
//! the CPU PJRT client. Python never runs here.
//!
//! The `xla` crate's handles wrap raw PJRT pointers without `Send`, so all
//! device work is owned by a dedicated **device-service thread**; worker
//! threads talk to it through a cloneable [`DeviceHandle`] with plain
//! `Vec<f32>`/`Vec<i32>` tensors. On this 1-core testbed that thread also
//! models the reality that compute serializes — the emulator's scaling
//! experiments use modeled compute instead (see `trainer`).

pub mod service;
pub mod tensor;

pub use service::{DeviceHandle, DeviceService, ExecStats};
pub use tensor::{HostTensor, TensorData};

use crate::Result;
use std::path::{Path, PathBuf};

/// Resolve the artifacts directory: `$NETBN_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var_os("NETBN_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Path of a named artifact.
pub fn artifact_path(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("{name}.hlo.txt"))
}

/// List artifact names available in a directory.
pub fn list_artifacts(dir: &Path) -> Result<Vec<String>> {
    let mut names = Vec::new();
    if !dir.exists() {
        return Ok(names);
    }
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if let Some(fname) = path.file_name().and_then(|s| s.to_str()) {
            if let Some(stem) = fname.strip_suffix(".hlo.txt") {
                names.push(stem.to_string());
            }
        }
    }
    names.sort();
    Ok(names)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_path_shape() {
        let p = artifact_path(Path::new("/a"), "train_step");
        assert_eq!(p, PathBuf::from("/a/train_step.hlo.txt"));
    }

    #[test]
    fn list_artifacts_empty_dir_ok() {
        let names = list_artifacts(Path::new("/definitely/not/here")).unwrap();
        assert!(names.is_empty());
    }

    #[test]
    fn list_artifacts_filters_suffix() {
        let dir = std::env::temp_dir().join("netbn_artifacts_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("a.hlo.txt"), "x").unwrap();
        std::fs::write(dir.join("b.txt"), "x").unwrap();
        let names = list_artifacts(&dir).unwrap();
        assert_eq!(names, vec!["a"]);
    }
}
