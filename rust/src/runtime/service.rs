//! The device-service thread: owns the PJRT client and compiled
//! executables; serves execution requests from any number of worker
//! threads over an mpsc channel.

use super::tensor::{HostTensor, TensorData};
use crate::Result;
use anyhow::{anyhow, Context};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Execution statistics (for §Perf).
#[derive(Clone, Copy, Debug, Default)]
pub struct ExecStats {
    pub calls: u64,
    pub compiles: u64,
    pub exec_seconds: f64,
    pub compile_seconds: f64,
}

enum Request {
    Exec { name: String, inputs: Vec<HostTensor>, reply: mpsc::Sender<Result<Vec<HostTensor>>> },
    Stats { reply: mpsc::Sender<ExecStats> },
    /// Preload (compile) an artifact without running it.
    Warm { name: String, reply: mpsc::Sender<Result<()>> },
    Shutdown,
}

/// Cloneable, `Send` handle to the device service.
#[derive(Clone)]
pub struct DeviceHandle {
    tx: mpsc::Sender<Request>,
    // Serializes shutdown.
    _shared: Arc<Mutex<()>>,
}

impl DeviceHandle {
    /// Execute artifact `name` with `inputs`; returns the flattened tuple
    /// outputs.
    pub fn exec(&self, name: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Exec { name: name.to_string(), inputs, reply })
            .map_err(|_| anyhow!("device service is down"))?;
        rx.recv().map_err(|_| anyhow!("device service dropped the request"))?
    }

    /// Compile `name` ahead of first use.
    pub fn warm(&self, name: &str) -> Result<()> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Request::Warm { name: name.to_string(), reply })
            .map_err(|_| anyhow!("device service is down"))?;
        rx.recv().map_err(|_| anyhow!("device service dropped the request"))?
    }

    pub fn stats(&self) -> Result<ExecStats> {
        let (reply, rx) = mpsc::channel();
        self.tx.send(Request::Stats { reply }).map_err(|_| anyhow!("device service is down"))?;
        Ok(rx.recv()?)
    }
}

/// The service: spawn once, hand out handles.
pub struct DeviceService {
    handle: DeviceHandle,
    join: Option<std::thread::JoinHandle<()>>,
    tx: mpsc::Sender<Request>,
}

impl DeviceService {
    /// Start the service over an artifacts directory.
    pub fn start(artifact_dir: PathBuf) -> DeviceService {
        let (tx, rx) = mpsc::channel::<Request>();
        let join = std::thread::Builder::new()
            .name("device-service".into())
            .spawn(move || service_main(artifact_dir, rx))
            .expect("spawn device service");
        let handle = DeviceHandle { tx: tx.clone(), _shared: Arc::new(Mutex::new(())) };
        DeviceService { handle, join: Some(join), tx }
    }

    pub fn handle(&self) -> DeviceHandle {
        self.handle.clone()
    }
}

impl Drop for DeviceService {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

fn service_main(artifact_dir: PathBuf, rx: mpsc::Receiver<Request>) {
    let mut state = match ServiceState::new(artifact_dir) {
        Ok(s) => s,
        Err(e) => {
            // Fail every request with the construction error.
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Exec { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("PJRT client failed to start: {e}")));
                    }
                    Request::Warm { reply, .. } => {
                        let _ = reply.send(Err(anyhow!("PJRT client failed to start: {e}")));
                    }
                    Request::Stats { reply } => {
                        let _ = reply.send(ExecStats::default());
                    }
                    Request::Shutdown => return,
                }
            }
            return;
        }
    };
    while let Ok(req) = rx.recv() {
        match req {
            Request::Exec { name, inputs, reply } => {
                let _ = reply.send(state.exec(&name, inputs));
            }
            Request::Warm { name, reply } => {
                let _ = reply.send(state.ensure_compiled(&name).map(|_| ()));
            }
            Request::Stats { reply } => {
                let _ = reply.send(state.stats);
            }
            Request::Shutdown => return,
        }
    }
}

struct ServiceState {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    stats: ExecStats,
}

impl ServiceState {
    fn new(artifact_dir: PathBuf) -> Result<ServiceState> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        Ok(ServiceState { client, artifact_dir, executables: HashMap::new(), stats: ExecStats::default() })
    }

    fn ensure_compiled(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(name) {
            let path = super::artifact_path(&self.artifact_dir, name);
            anyhow::ensure!(
                path.exists(),
                "artifact {name:?} not found at {path:?}; run `make artifacts`"
            );
            let t0 = std::time::Instant::now();
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp).map_err(|e| anyhow!("compile {name}: {e:?}"))?;
            self.stats.compiles += 1;
            self.stats.compile_seconds += t0.elapsed().as_secs_f64();
            self.executables.insert(name.to_string(), exe);
        }
        Ok(&self.executables[name])
    }

    fn exec(&mut self, name: &str, inputs: Vec<HostTensor>) -> Result<Vec<HostTensor>> {
        self.ensure_compiled(name)?;
        let exe = &self.executables[name];
        let literals: Vec<xla::Literal> = inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let t0 = std::time::Instant::now();
        let out = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        anyhow::ensure!(!out.is_empty() && !out[0].is_empty(), "no outputs from {name}");
        let lit = out[0][0].to_literal_sync().map_err(|e| anyhow!("fetch outputs: {e:?}"))?;
        self.stats.calls += 1;
        self.stats.exec_seconds += t0.elapsed().as_secs_f64();
        // jax lowers with return_tuple=True → always a tuple at top level.
        let parts = lit.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        parts.into_iter().map(|l| from_literal(&l)).collect()
    }
}

fn to_literal(t: &HostTensor) -> Result<xla::Literal> {
    let lit = match &t.data {
        TensorData::F32(v) => xla::Literal::vec1(v),
        TensorData::I32(v) => xla::Literal::vec1(v),
        TensorData::U32(v) => xla::Literal::vec1(v),
    };
    lit.reshape(&t.dims).map_err(|e| anyhow!("reshape to {:?}: {e:?}", t.dims))
}

fn from_literal(l: &xla::Literal) -> Result<HostTensor> {
    let shape = l.array_shape().map_err(|e| anyhow!("output shape: {e:?}"))?;
    let dims = shape.dims().to_vec();
    let data = match shape.ty() {
        xla::ElementType::F32 => {
            TensorData::F32(l.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))?)
        }
        xla::ElementType::S32 => {
            TensorData::I32(l.to_vec::<i32>().map_err(|e| anyhow!("to_vec i32: {e:?}"))?)
        }
        xla::ElementType::U32 => {
            TensorData::U32(l.to_vec::<u32>().map_err(|e| anyhow!("to_vec u32: {e:?}"))?)
        }
        other => anyhow::bail!("unsupported output element type {other:?}"),
    };
    Ok(HostTensor { dims, data })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifact_is_clean_error() {
        let svc = DeviceService::start(PathBuf::from("/nonexistent"));
        let h = svc.handle();
        let err = h.exec("nope", vec![]).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn handle_survives_many_clones() {
        let svc = DeviceService::start(PathBuf::from("/nonexistent"));
        let h1 = svc.handle();
        let h2 = h1.clone();
        assert!(h2.exec("x", vec![]).is_err());
        assert_eq!(h1.stats().unwrap().calls, 0);
    }
}
