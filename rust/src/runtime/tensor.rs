//! Host-side tensors: the `Send`-able currency between worker threads and
//! the device-service thread.

use crate::Result;

/// Element storage.
#[derive(Clone, Debug, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

impl TensorData {
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::U32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A shaped host tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub dims: Vec<i64>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(dims: &[i64], data: Vec<f32>) -> HostTensor {
        let t = HostTensor { dims: dims.to_vec(), data: TensorData::F32(data) };
        t.check();
        t
    }

    pub fn i32(dims: &[i64], data: Vec<i32>) -> HostTensor {
        let t = HostTensor { dims: dims.to_vec(), data: TensorData::I32(data) };
        t.check();
        t
    }

    pub fn scalar_f32(x: f32) -> HostTensor {
        HostTensor { dims: vec![], data: TensorData::F32(vec![x]) }
    }

    pub fn elem_count(&self) -> usize {
        self.dims.iter().map(|d| *d as usize).product()
    }

    fn check(&self) {
        assert_eq!(self.elem_count(), self.data.len(), "dims {:?} vs len {}", self.dims, self.data.len());
    }

    /// Borrow as f32 slice.
    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            other => anyhow::bail!("expected f32 tensor, got {other:?}"),
        }
    }

    /// Consume into an f32 vector.
    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self.data {
            TensorData::F32(v) => Ok(v),
            other => anyhow::bail!("expected f32 tensor, got {other:?}"),
        }
    }

    /// Mean of an f32 tensor (loss reporting).
    pub fn mean_f32(&self) -> Result<f64> {
        let v = self.as_f32()?;
        anyhow::ensure!(!v.is_empty(), "mean of empty tensor");
        Ok(v.iter().map(|x| *x as f64).sum::<f64>() / v.len() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_has_empty_dims() {
        let t = HostTensor::scalar_f32(3.0);
        assert_eq!(t.elem_count(), 1);
        assert_eq!(t.as_f32().unwrap(), &[3.0]);
    }

    #[test]
    #[should_panic(expected = "dims")]
    fn mismatched_dims_panic() {
        HostTensor::f32(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn type_mismatch_errors() {
        let t = HostTensor::i32(&[1], vec![1]);
        assert!(t.as_f32().is_err());
    }

    #[test]
    fn mean() {
        let t = HostTensor::f32(&[3], vec![1.0, 2.0, 3.0]);
        assert!((t.mean_f32().unwrap() - 2.0).abs() < 1e-12);
    }
}
