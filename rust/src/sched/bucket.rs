//! PyTorch-DDP-style gradient bucketizer.
//!
//! Layers are assigned to buckets in **gradient-ready order** — reverse
//! forward order, because the output layer's gradient materializes first —
//! accumulating until a size threshold (`--bucket-mb`) is crossed, then
//! cutting. The plan is a pure function of the layer sizes and the
//! threshold, so every rank derives the identical plan and the per-bucket
//! collectives stay matched without a negotiation round (the same trick
//! [`crate::trainer::bucket_timeline`] plays with the fusion buffer).
//!
//! Unlike the Horovod fusion buffer (64 MB + 5 ms timeout, a *runtime*
//! state machine), this bucketizer is *static*: the threshold trades
//! per-bucket overhead (too many small buckets) against lost overlap (one
//! huge bucket ships only when backward ends) — the trade
//! `bucket_size_sweep` measures and [`crate::sim::overlap_model`] mirrors.

/// One layer's contribution, in gradient-ready order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayerGrad {
    /// Forward-order layer index.
    pub layer: usize,
    /// Gradient bytes.
    pub bytes: usize,
}

/// One planned bucket: a contiguous run of gradient-ready-order layers.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BucketSpec {
    /// Submission sequence number (0 = flushed first).
    pub seq: u32,
    /// Member layers, in gradient-ready order.
    pub layers: Vec<LayerGrad>,
    /// Total gradient bytes in the bucket.
    pub bytes: usize,
}

/// A deterministic bucket assignment for one backward pass.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BucketPlan {
    pub buckets: Vec<BucketSpec>,
    /// The size threshold the plan was cut with.
    pub threshold_bytes: usize,
}

impl BucketPlan {
    /// Total bytes across all buckets (conservation checks).
    pub fn total_bytes(&self) -> usize {
        self.buckets.iter().map(|b| b.bytes).sum()
    }
}

/// Assign layers (given in gradient-ready order) to buckets: accumulate
/// until the running total reaches `threshold_bytes`, then cut. An
/// oversized layer closes the current bucket immediately — including any
/// smaller layers already accumulated in front of it; the final bucket
/// may be smaller (the head of the model rarely fills a whole bucket).
/// `threshold_bytes == 0` is treated as unbounded: one bucket holding
/// everything — the blocking baseline's decomposition.
pub fn plan_buckets(layers_ready_order: &[LayerGrad], threshold_bytes: usize) -> BucketPlan {
    let threshold = if threshold_bytes == 0 { usize::MAX } else { threshold_bytes };
    let mut buckets = Vec::new();
    let mut cur: Vec<LayerGrad> = Vec::new();
    let mut cur_bytes = 0usize;
    for &lg in layers_ready_order {
        cur.push(lg);
        cur_bytes += lg.bytes;
        if cur_bytes >= threshold {
            buckets.push(BucketSpec {
                seq: buckets.len() as u32,
                layers: std::mem::take(&mut cur),
                bytes: cur_bytes,
            });
            cur_bytes = 0;
        }
    }
    if !cur.is_empty() {
        buckets.push(BucketSpec { seq: buckets.len() as u32, layers: cur, bytes: cur_bytes });
    }
    BucketPlan { buckets, threshold_bytes }
}

/// Gradient-ready-order layer list for contiguous forward-order f32
/// gradient `ranges` (reverse order, 4 bytes per element) — the map every
/// caller of [`plan_buckets`] over a sliced tensor needs; keeping it here
/// keeps the ready-order convention in one place.
pub fn ready_order_from_ranges(ranges: &[std::ops::Range<usize>]) -> Vec<LayerGrad> {
    (0..ranges.len())
        .rev()
        .map(|l| LayerGrad { layer: l, bytes: ranges[l].len() * 4 })
        .collect()
}

/// Convenience: megabytes → the byte threshold `plan_buckets` takes
/// (`<= 0` MB ⇒ 0 ⇒ unbounded single bucket).
pub fn mb_to_threshold(bucket_mb: f64) -> usize {
    if bucket_mb <= 0.0 {
        0
    } else {
        (bucket_mb * (1 << 20) as f64) as usize
    }
}

/// The emulator's `(emit time rel. backward start, bucket bytes)` timeline
/// derived from a white-box trace with this bucketizer instead of the
/// fusion buffer: a bucket's emit time is its *last* member layer's
/// gradient-ready instant (the bucket cannot ship earlier). Drop-in
/// replacement for [`crate::trainer::bucket_timeline`] when `--bucket-mb`
/// is set.
pub fn bucket_timeline_from_trace(
    trace: &crate::models::timing::StepTrace,
    threshold_bytes: usize,
) -> Vec<(f64, usize)> {
    let layers: Vec<LayerGrad> =
        trace.events.iter().map(|e| LayerGrad { layer: e.layer, bytes: e.bytes }).collect();
    let plan = plan_buckets(&layers, threshold_bytes);
    // Buckets partition the ready-order event sequence contiguously, so
    // bucket i ships at its last member's t_ready. Walking by position
    // (not by layer id) keeps this correct for any trace — recorded
    // whitebox traces carry arbitrary, non-dense layer ids.
    let mut out = Vec::with_capacity(plan.buckets.len());
    let mut end = 0usize;
    for b in &plan.buckets {
        end += b.layers.len();
        out.push((trace.events[end - 1].t_ready, b.bytes));
    }
    out
}

/// Memoized `threshold_bytes → bucket timeline` over one backward trace —
/// the lookup the autotuned emulator does at every step boundary (the
/// tuner may revisit a bucket size many times per probe phase; replanning
/// each step would put a plan computation on the step path).
///
/// Thread-safe and shared (`Arc<TimelineCache>`) across all worker
/// threads of a run, which also guarantees every rank draws the *same*
/// timeline object for the same knob — the determinism the matched
/// collectives rely on.
pub struct TimelineCache {
    trace: crate::models::timing::StepTrace,
    map: std::sync::Mutex<
        std::collections::HashMap<usize, std::sync::Arc<Vec<(f64, usize)>>>,
    >,
}

impl TimelineCache {
    pub fn new(trace: crate::models::timing::StepTrace) -> TimelineCache {
        TimelineCache { trace, map: std::sync::Mutex::new(std::collections::HashMap::new()) }
    }

    /// The timeline for one threshold, computed at most once.
    pub fn get(&self, threshold_bytes: usize) -> std::sync::Arc<Vec<(f64, usize)>> {
        let mut map = self.map.lock().unwrap();
        std::sync::Arc::clone(map.entry(threshold_bytes).or_insert_with(|| {
            std::sync::Arc::new(bucket_timeline_from_trace(&self.trace, threshold_bytes))
        }))
    }

    /// Distinct thresholds planned so far.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::timing::backward_trace;
    use crate::models::ModelId;
    use crate::util::prop;

    fn layers(sizes: &[usize]) -> Vec<LayerGrad> {
        // Ready order = reverse forward order, like a real backward pass.
        sizes
            .iter()
            .enumerate()
            .rev()
            .map(|(layer, &bytes)| LayerGrad { layer, bytes })
            .collect()
    }

    #[test]
    fn threshold_cuts_and_conserves() {
        let ls = layers(&[10, 20, 30, 40, 50]);
        let plan = plan_buckets(&ls, 60);
        assert_eq!(plan.total_bytes(), 150);
        // Ready order: 50,40,30,20,10 → [50+40], [30+20+10].
        assert_eq!(plan.buckets.len(), 2);
        assert_eq!(plan.buckets[0].bytes, 90);
        assert_eq!(plan.buckets[1].bytes, 60);
        assert_eq!(plan.buckets[0].seq, 0);
        assert_eq!(plan.buckets[1].seq, 1);
    }

    #[test]
    fn zero_threshold_means_one_bucket() {
        let ls = layers(&[10, 20, 30]);
        let plan = plan_buckets(&ls, 0);
        assert_eq!(plan.buckets.len(), 1);
        assert_eq!(plan.buckets[0].bytes, 60);
        assert_eq!(mb_to_threshold(0.0), 0);
        assert_eq!(mb_to_threshold(-1.0), 0);
        assert_eq!(mb_to_threshold(1.0), 1 << 20);
    }

    #[test]
    fn ready_order_reverses_ranges() {
        let ranges = vec![0..10, 10..25, 25..30];
        let ready = ready_order_from_ranges(&ranges);
        assert_eq!(
            ready,
            vec![
                LayerGrad { layer: 2, bytes: 20 },
                LayerGrad { layer: 1, bytes: 60 },
                LayerGrad { layer: 0, bytes: 40 },
            ]
        );
    }

    #[test]
    fn oversized_layer_closes_current_bucket() {
        let ls = layers(&[5, 1000, 5]);
        let plan = plan_buckets(&ls, 100);
        // Ready order: 5, 1000, 5 → [5+1000] (cut over threshold), [5].
        assert_eq!(plan.buckets.len(), 2);
        assert!(plan.buckets[0].bytes >= 100);
        assert_eq!(plan.total_bytes(), 1010);
    }

    #[test]
    fn property_conservation_order_and_bounds() {
        prop::forall("bucket plan conserves bytes and ready order", 200, |rng| {
            let n = prop::usize_in(rng, 1..=40);
            let sizes: Vec<usize> = (0..n).map(|_| prop::usize_in(rng, 1..=5000)).collect();
            let ls = layers(&sizes);
            let threshold = prop::usize_in(rng, 1..=8000);
            let plan = plan_buckets(&ls, threshold);
            let total: usize = sizes.iter().sum();
            if plan.total_bytes() != total {
                return Err(format!("bytes {} != {total}", plan.total_bytes()));
            }
            // Flattened layer order must equal the input ready order.
            let flat: Vec<usize> =
                plan.buckets.iter().flat_map(|b| b.layers.iter().map(|l| l.layer)).collect();
            let want: Vec<usize> = ls.iter().map(|l| l.layer).collect();
            if flat != want {
                return Err(format!("order {flat:?} != {want:?}"));
            }
            // Every bucket except the last reaches the threshold; every
            // multi-layer bucket stayed under threshold before its final
            // member arrived.
            for (i, b) in plan.buckets.iter().enumerate() {
                if i + 1 < plan.buckets.len() && b.bytes < threshold {
                    return Err(format!("bucket {i} under threshold: {}", b.bytes));
                }
                let before_last: usize =
                    b.layers[..b.layers.len() - 1].iter().map(|l| l.bytes).sum();
                if before_last >= threshold {
                    return Err(format!("bucket {i} should have been cut earlier"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn trace_timeline_conserves_and_is_sorted() {
        for id in [ModelId::ResNet50, ModelId::Vgg16] {
            let trace = backward_trace(&id.profile());
            let tl = bucket_timeline_from_trace(&trace, 25 << 20);
            let total: usize = tl.iter().map(|(_, b)| *b).sum();
            assert_eq!(total, id.profile().total_bytes(), "{id}");
            for w in tl.windows(2) {
                assert!(w[0].0 <= w[1].0, "{id}: timeline not sorted");
            }
            assert!(tl.last().unwrap().0 <= trace.t_backward + 1e-12);
            assert!(tl.len() > 1, "{id}: 25 MB threshold must cut a {id} model");
        }
    }

    #[test]
    fn trace_timeline_tolerates_sparse_layer_ids() {
        // Recorded whitebox traces carry arbitrary layer ids; the
        // timeline must key on ready-order position, not on the id.
        use crate::models::timing::{StepTrace, TraceEvent};
        let trace = StepTrace {
            t_forward: 0.01,
            events: vec![
                TraceEvent { layer: 30, bytes: 100, t_ready: 0.001 },
                TraceEvent { layer: 10, bytes: 100, t_ready: 0.002 },
                TraceEvent { layer: 20, bytes: 100, t_ready: 0.003 },
            ],
            t_backward: 0.003,
            t_batch: 0.013,
        };
        let tl = bucket_timeline_from_trace(&trace, 150);
        assert_eq!(tl, vec![(0.002, 200), (0.003, 100)]);
    }

    #[test]
    fn timeline_cache_memoizes_and_matches_direct_planning() {
        let trace = backward_trace(&ModelId::ResNet50.profile());
        let cache = TimelineCache::new(trace.clone());
        assert!(cache.is_empty());
        let a = cache.get(mb_to_threshold(16.0));
        let b = cache.get(mb_to_threshold(16.0));
        assert!(std::sync::Arc::ptr_eq(&a, &b), "same threshold must hit the cache");
        assert_eq!(cache.len(), 1);
        assert_eq!(*a, bucket_timeline_from_trace(&trace, mb_to_threshold(16.0)));
        let c = cache.get(mb_to_threshold(4.0));
        assert_eq!(cache.len(), 2);
        assert!(c.len() > a.len(), "smaller buckets, more of them");
    }

    #[test]
    fn smaller_threshold_never_fewer_buckets() {
        let trace = backward_trace(&ModelId::ResNet101.profile());
        let mut last = usize::MAX;
        for mb in [1.0, 4.0, 16.0, 64.0, 256.0] {
            let n = bucket_timeline_from_trace(&trace, mb_to_threshold(mb)).len();
            assert!(n <= last, "{mb} MB: {n} buckets > {last}");
            last = n;
        }
    }
}
