//! Non-blocking collective handles.
//!
//! An [`AsyncCollectiveEngine`] owns one background worker thread bound to
//! one worker's [`Endpoint`]; [`AsyncCollectiveEngine::submit`] enqueues an
//! all-reduce and immediately returns an [`AllReduceHandle`] the compute
//! path can [`test`](AllReduceHandle::test) (non-blocking) or
//! [`wait`](AllReduceHandle::wait) (blocking) — the NCCL-stream shape that
//! makes compute/communication overlap possible.
//!
//! Jobs execute strictly FIFO on the worker thread. That is a correctness
//! property, not a convenience: every rank submits the same deterministic
//! bucket sequence, so FIFO execution keeps the collectives matched across
//! ranks (and makes `--overlap off` vs `--overlap buckets` bit-identical —
//! the same per-bucket collectives run in the same order; only *when* they
//! start differs).

use crate::config::CollectiveKind;
use crate::net::Endpoint;
use crate::topology::{Cluster, Topology};
use crate::Result;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Completion slot shared between a handle and the worker thread.
struct HandleShared {
    /// `Some` once the job ran: the reduced tensor or the error.
    slot: Mutex<Option<Result<Vec<f32>>>>,
    cv: Condvar,
    done: AtomicBool,
    /// Seconds the worker thread spent inside the collective (excludes
    /// queue wait and any pre-delay) — the comm-busy time reporters use.
    busy_s: Mutex<f64>,
}

/// A pending all-reduce: the async counterpart of one
/// [`crate::collectives::allreduce`] call.
pub struct AllReduceHandle {
    shared: Arc<HandleShared>,
    /// Bucket sequence number the job was submitted under.
    pub seq: u32,
    /// Payload length in f32 elements.
    pub elems: usize,
}

impl AllReduceHandle {
    /// `true` once the collective has finished (successfully or not);
    /// never blocks.
    pub fn test(&self) -> bool {
        self.shared.done.load(Ordering::Acquire)
    }

    /// Block until the collective finishes; returns the reduced tensor.
    pub fn wait(self) -> Result<Vec<f32>> {
        self.wait_with_busy().map(|(data, _)| data)
    }

    /// [`wait`](Self::wait), also returning the seconds the worker thread
    /// spent inside this collective (the comm-busy time — it includes any
    /// span overlapped under compute, which pure wait-time measurement
    /// would miss).
    pub fn wait_with_busy(self) -> Result<(Vec<f32>, f64)> {
        let mut slot = self.shared.slot.lock().unwrap();
        while slot.is_none() {
            slot = self.shared.cv.wait(slot).unwrap();
        }
        let result = slot.take().expect("completed job leaves a result");
        drop(slot);
        let busy = *self.shared.busy_s.lock().unwrap();
        result.map(|data| (data, busy))
    }

    /// Seconds the worker thread spent executing this collective. Only
    /// meaningful after completion (`test()` returned true or `wait`
    /// would not block); 0 before.
    pub fn busy_seconds(&self) -> f64 {
        *self.shared.busy_s.lock().unwrap()
    }
}

struct Job {
    step: u32,
    seq: u32,
    data: Vec<f32>,
    /// Modeled coordination latency charged on the worker thread before
    /// the collective starts (the emulator's negotiation round).
    pre_delay_s: f64,
    shared: Arc<HandleShared>,
}

/// One worker's background collective engine: a FIFO job queue drained by
/// a dedicated thread that runs the configured [`CollectiveKind`] over the
/// worker's endpoint (any fabric: inproc, tcp, mesh; any transport:
/// single-stream or striped).
pub struct AsyncCollectiveEngine {
    tx: Option<mpsc::Sender<Job>>,
    worker: Option<std::thread::JoinHandle<()>>,
    /// The bound endpoint's rank — kept here (the endpoint itself moves
    /// into the worker thread) so callers can attribute spans/metrics.
    me: u32,
}

impl AsyncCollectiveEngine {
    /// Spawn the worker thread for `ep`, running `kind` for every job.
    pub fn new(ep: Arc<dyn Endpoint>, kind: CollectiveKind) -> AsyncCollectiveEngine {
        let me = ep.me().0 as u32;
        let (tx, rx) = mpsc::channel::<Job>();
        let worker = std::thread::spawn(move || {
            // Topology is prebuilt once so the per-bucket comm path
            // allocates nothing — small DDP buckets mean hundreds of
            // collectives per step on this critical path.
            let flat = Topology::new(ep.world(), 1).flat_ring();
            let cluster = match kind {
                CollectiveKind::Hierarchical { group_size } => {
                    Some(Cluster::new(ep.world(), group_size))
                }
                _ => None,
            };
            while let Ok(job) = rx.recv() {
                if job.pre_delay_s > 0.0 {
                    std::thread::sleep(Duration::from_secs_f64(job.pre_delay_s));
                }
                let mut data = job.data;
                let _sp = crate::span!(
                    "comm.allreduce",
                    ep.me().0,
                    job.step,
                    (data.len() * std::mem::size_of::<f32>()) as u64
                );
                let t0 = Instant::now();
                let result = crate::collectives::allreduce_prepared(
                    kind,
                    ep.as_ref(),
                    &flat,
                    cluster.as_ref(),
                    job.step,
                    job.seq,
                    &mut data,
                )
                .map(|()| data);
                *job.shared.busy_s.lock().unwrap() = t0.elapsed().as_secs_f64();
                *job.shared.slot.lock().unwrap() = Some(result);
                job.shared.done.store(true, Ordering::Release);
                job.shared.cv.notify_all();
            }
        });
        AsyncCollectiveEngine { tx: Some(tx), worker: Some(worker), me }
    }

    /// Rank of the endpoint this engine is bound to.
    pub fn rank(&self) -> u32 {
        self.me
    }

    /// Enqueue one all-reduce; returns immediately. `(step, seq)` must
    /// match the peers' submission (they form the wire tag).
    pub fn submit(&self, step: u32, seq: u32, data: Vec<f32>) -> AllReduceHandle {
        self.submit_after(step, seq, data, 0.0)
    }

    /// [`submit`](Self::submit) with a modeled pre-collective delay
    /// (charged on the worker thread, so it serializes with earlier jobs
    /// exactly like Horovod's per-bucket negotiation round).
    pub fn submit_after(
        &self,
        step: u32,
        seq: u32,
        data: Vec<f32>,
        pre_delay_s: f64,
    ) -> AllReduceHandle {
        let shared = Arc::new(HandleShared {
            slot: Mutex::new(None),
            cv: Condvar::new(),
            done: AtomicBool::new(false),
            busy_s: Mutex::new(0.0),
        });
        let elems = data.len();
        let job = Job { step, seq, data, pre_delay_s, shared: Arc::clone(&shared) };
        // The worker loop only exits after draining the channel, so a send
        // can fail only if the worker thread panicked; surface that at
        // wait() rather than here (submit stays infallible for callers).
        if let Some(tx) = &self.tx {
            if tx.send(job).is_err() {
                let mut slot = shared.slot.lock().unwrap();
                *slot = Some(Err(anyhow::anyhow!("collective engine worker died")));
                shared.done.store(true, Ordering::Release);
                shared.cv.notify_all();
            }
        }
        AllReduceHandle { shared, seq, elems }
    }
}

impl Drop for AsyncCollectiveEngine {
    fn drop(&mut self) {
        // Close the queue, then join: pending jobs still drain (their
        // handles may be waited on after the engine is gone).
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{inproc::InProcFabric, Fabric};

    fn engines(world: usize, kind: CollectiveKind) -> Vec<AsyncCollectiveEngine> {
        let fab = InProcFabric::new(world);
        fab.endpoints().into_iter().map(|ep| AsyncCollectiveEngine::new(ep, kind)).collect()
    }

    #[test]
    fn async_allreduce_sums_across_ranks() {
        let engines = engines(3, CollectiveKind::Ring);
        let handles: Vec<AllReduceHandle> = engines
            .iter()
            .enumerate()
            .map(|(i, e)| e.submit(0, 0, vec![i as f32; 17]))
            .collect();
        for h in handles {
            assert_eq!(h.elems, 17);
            assert_eq!(h.wait().unwrap(), vec![3.0; 17]);
        }
    }

    #[test]
    fn fifo_order_matches_across_ranks() {
        // Three buckets submitted back-to-back on every rank: FIFO
        // execution keeps the tags matched and all sums correct.
        let engines = engines(4, CollectiveKind::Hierarchical { group_size: 2 });
        let mut per_rank: Vec<Vec<AllReduceHandle>> = Vec::new();
        for (i, e) in engines.iter().enumerate() {
            per_rank.push(
                (0..3u32).map(|seq| e.submit(0, seq, vec![(i + 1) as f32; 11])).collect(),
            );
        }
        for handles in per_rank {
            for h in handles {
                assert_eq!(h.wait().unwrap(), vec![10.0; 11]);
            }
        }
    }

    #[test]
    fn test_is_nonblocking_and_turns_true() {
        let engines = engines(2, CollectiveKind::Ring);
        // A 30 ms pre-delay guarantees the job is still pending right
        // after submit.
        let h0 = engines[0].submit_after(0, 0, vec![1.0; 8], 0.03);
        let h1 = engines[1].submit_after(0, 0, vec![2.0; 8], 0.0);
        assert!(!h0.test(), "job with a 30ms pre-delay cannot be done instantly");
        let r1 = h1.wait().unwrap();
        let r0 = h0.wait().unwrap();
        assert_eq!(r0, vec![3.0; 8]);
        assert_eq!(r0, r1);
    }

    #[test]
    fn busy_seconds_reported_after_completion() {
        let engines = engines(2, CollectiveKind::Ring);
        let h0 = engines[0].submit(0, 0, vec![1.0; 1024]);
        let h1 = engines[1].submit(0, 0, vec![1.0; 1024]);
        h1.wait().unwrap();
        while !h0.test() {
            std::thread::yield_now();
        }
        assert!(h0.busy_seconds() > 0.0);
        h0.wait().unwrap();
    }

    #[test]
    fn drop_drains_pending_jobs() {
        let engines = engines(2, CollectiveKind::Ring);
        let handles: Vec<AllReduceHandle> =
            engines.iter().map(|e| e.submit(0, 0, vec![2.0; 5])).collect();
        drop(engines);
        for h in handles {
            assert_eq!(h.wait().unwrap(), vec![4.0; 5]);
        }
    }
}
