//! Compute/communication **overlap scheduling** — the subsystem between
//! the collectives and the trainers.
//!
//! The paper's diagnosis is that scale-out stalls because the NIC idles
//! while the GPU computes and the GPU idles while gradients aggregate.
//! This module supplies the standard systems remedy (Horovod/DDP-style
//! tensor fusion + pipelined all-reduce, cf. Sun et al.'s "ImageNet/
//! AlexNet Training in 1.5 Minutes"):
//!
//! * [`handle`] — [`handle::AsyncCollectiveEngine`]: a per-worker
//!   background thread running any [`crate::config::CollectiveKind`] over
//!   any fabric/transport, returning non-blocking
//!   [`handle::AllReduceHandle`]s (`wait()`/`test()`);
//! * [`bucket`] — the PyTorch-DDP-style size-threshold bucketizer
//!   (`--bucket-mb`, reverse-order assignment): a deterministic
//!   [`bucket::BucketPlan`] every rank derives identically;
//! * [`scheduler`] — [`scheduler::run_step`]: walk the plan in
//!   gradient-ready order, interleave per-layer compute with bucket
//!   flushes (`--overlap buckets`), or submit the identical buckets after
//!   backward (`--overlap off`) — bit-identical by construction, only the
//!   timing differs.
//!
//! The analytic mirror lives in [`crate::sim::overlap_model`]; the
//! measurable claims are the `overlap_ablation`, `bucket_size_sweep` and
//! `scaling_factor_recovered` scenarios.

pub mod bucket;
pub mod handle;
pub mod scheduler;

pub use bucket::{plan_buckets, BucketPlan, BucketSpec, LayerGrad, TimelineCache};
pub use handle::{AllReduceHandle, AsyncCollectiveEngine};
pub use scheduler::{layer_ranges, run_step, StepStats};
