//! The overlap scheduler: interleave a per-layer backward compute
//! timeline with bucket flushes into the [`AsyncCollectiveEngine`].
//!
//! [`run_step`] is the one step loop both real-gradient paths share (the
//! `netbn launch` worker and the conformance tests): walk the bucket plan
//! in gradient-ready order, run each member layer's compute, and — under
//! `--overlap buckets` — submit the bucket the instant its last layer is
//! done, while later layers are still computing. Under `--overlap off`
//! the identical buckets are submitted only after backward finishes: the
//! serialized compute-then-all-reduce baseline the paper measures, with
//! the same arithmetic bit for bit.
//!
//! The emulated trainer ([`crate::trainer::run_emulated`]) drives the
//! same engine from its virtual-time bucket timeline rather than through
//! [`run_step`] — its payloads are modeled, not sliced from a parameter
//! tensor — but the off/buckets submission policy is the same.

use super::bucket::BucketPlan;
use super::handle::{AllReduceHandle, AsyncCollectiveEngine};
use crate::config::OverlapMode;
use crate::Result;
use std::ops::Range;
use std::time::Instant;

/// What one scheduled step measured.
#[derive(Clone, Copy, Debug, Default)]
pub struct StepStats {
    /// Wall seconds of the backward/emission phase (includes bucket
    /// gathering and, under `buckets`, submission).
    pub compute_s: f64,
    /// Wall seconds blocked waiting on outstanding collectives after
    /// compute finished — the serialization the overlap hides.
    pub comm_wait_s: f64,
    /// Seconds the engine's worker thread spent inside collectives
    /// (include time overlapped under compute; basis for bus bandwidth).
    pub comm_busy_s: f64,
    /// Buckets all-reduced.
    pub buckets: usize,
}

/// Split `elems` gradient elements into `layers` near-equal contiguous
/// per-layer ranges (forward order) — the synthetic layer map the launch
/// worker buckets over.
pub fn layer_ranges(elems: usize, layers: usize) -> Vec<Range<usize>> {
    crate::collectives::split_points(elems, layers.max(1))
}

/// Run one data-parallel step over `grad`: per-layer compute (the
/// `compute_layer` callback, invoked in gradient-ready order), bucket
/// flushes per `plan`, reduction through `engine`, results scattered back
/// into `grad` in place. Every rank must call this with the same plan and
/// layer map — the plan is deterministic, so that holds by construction.
pub fn run_step(
    engine: &AsyncCollectiveEngine,
    mode: OverlapMode,
    step: u32,
    grad: &mut [f32],
    ranges: &[Range<usize>],
    plan: &BucketPlan,
    mut compute_layer: impl FnMut(usize),
) -> Result<StepStats> {
    for b in &plan.buckets {
        for l in &b.layers {
            anyhow::ensure!(
                l.layer < ranges.len() && ranges[l.layer].end <= grad.len(),
                "bucket plan references layer {} outside the gradient's {} ranges",
                l.layer,
                ranges.len()
            );
        }
    }
    let rank = engine.rank();
    let t0 = Instant::now();
    let mut handles: Vec<AllReduceHandle> = Vec::with_capacity(plan.buckets.len());
    let mut deferred: Vec<(u32, Vec<f32>)> = Vec::new();
    for b in &plan.buckets {
        let mut payload = Vec::with_capacity(ranges_len(ranges, b));
        for l in &b.layers {
            {
                let _sp = crate::span!("step.compute", rank, step);
                compute_layer(l.layer);
            }
            let _sp = crate::span!(
                "step.serialize",
                rank,
                step,
                ranges[l.layer].len() * std::mem::size_of::<f32>()
            );
            payload.extend_from_slice(&grad[ranges[l.layer].clone()]);
        }
        match mode {
            OverlapMode::Buckets => handles.push(engine.submit(step, b.seq, payload)),
            OverlapMode::Off => deferred.push((b.seq, payload)),
        }
    }
    let compute_s = t0.elapsed().as_secs_f64();

    // Blocking mode: the identical buckets, submitted only now.
    let t_wait = Instant::now();
    let wait_sp = crate::span!("step.wait", rank, step);
    for (seq, payload) in deferred {
        handles.push(engine.submit(step, seq, payload));
    }
    let mut comm_busy = 0.0;
    let buckets = handles.len();
    for (h, b) in handles.into_iter().zip(&plan.buckets) {
        let (reduced, busy) = h.wait_with_busy()?;
        comm_busy += busy;
        let mut offset = 0;
        for l in &b.layers {
            let r = ranges[l.layer].clone();
            grad[r.clone()].copy_from_slice(&reduced[offset..offset + r.len()]);
            offset += r.len();
        }
    }
    drop(wait_sp);
    let comm_wait_s = t_wait.elapsed().as_secs_f64();
    Ok(StepStats { compute_s, comm_wait_s, comm_busy_s: comm_busy, buckets })
}

fn ranges_len(ranges: &[Range<usize>], b: &super::bucket::BucketSpec) -> usize {
    b.layers.iter().map(|l| ranges[l.layer].len()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CollectiveKind;
    use crate::net::{inproc::InProcFabric, Fabric};
    use crate::sched::bucket::{plan_buckets, ready_order_from_ranges, LayerGrad};
    use crate::util::Rng;

    const ELEMS: usize = 1003;
    const LAYERS: usize = 5;

    /// Run one step on every rank of a fresh inproc fabric; returns each
    /// rank's final gradient and stats.
    fn run_world(
        world: usize,
        mode: OverlapMode,
        threshold: usize,
        kind: CollectiveKind,
    ) -> Vec<(Vec<f32>, StepStats)> {
        let fab = InProcFabric::new(world);
        let ranges = layer_ranges(ELEMS, LAYERS);
        let plan = plan_buckets(&ready_order_from_ranges(&ranges), threshold);
        let mut handles = Vec::new();
        for (i, ep) in fab.endpoints().into_iter().enumerate() {
            let ranges = ranges.clone();
            let plan = plan.clone();
            handles.push(std::thread::spawn(move || {
                let engine = AsyncCollectiveEngine::new(ep, kind);
                let mut grad = vec![0.0f32; ELEMS];
                Rng::new(0xabc ^ i as u64).fill_f32(&mut grad, 1.0);
                let stats =
                    run_step(&engine, mode, 0, &mut grad, &ranges, &plan, |_layer| {}).unwrap();
                (grad, stats)
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn overlap_and_blocking_are_bit_identical() {
        for kind in [CollectiveKind::Ring, CollectiveKind::Hierarchical { group_size: 2 }] {
            let off = run_world(4, OverlapMode::Off, 800, kind);
            let on = run_world(4, OverlapMode::Buckets, 800, kind);
            let reference = bits(&off[0].0);
            for (g, _) in off.iter().chain(on.iter()) {
                assert_eq!(bits(g), reference, "{kind:?}");
            }
        }
    }

    #[test]
    fn results_are_correct_sums() {
        // Against a directly computed elementwise sum of the seeded inputs.
        let world = 3;
        let mut want = vec![0.0f32; ELEMS];
        for i in 0..world {
            let mut g = vec![0.0f32; ELEMS];
            Rng::new(0xabc ^ i as u64).fill_f32(&mut g, 1.0);
            for (w, x) in want.iter_mut().zip(&g) {
                *w += *x;
            }
        }
        let got = run_world(world, OverlapMode::Buckets, 512, CollectiveKind::Ring);
        for (g, stats) in &got {
            assert!(stats.buckets >= 2, "threshold must actually cut: {}", stats.buckets);
            for (a, b) in g.iter().zip(&want) {
                assert!((a - b).abs() <= 1e-5 * b.abs().max(1.0), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn zero_threshold_is_single_bucket() {
        let got = run_world(2, OverlapMode::Off, 0, CollectiveKind::Ring);
        for (_, stats) in &got {
            assert_eq!(stats.buckets, 1);
            assert!(stats.comm_busy_s > 0.0);
        }
    }

    #[test]
    fn bad_plan_is_rejected() {
        let fab = InProcFabric::new(1);
        let ep = fab.endpoints().pop().unwrap();
        let engine = AsyncCollectiveEngine::new(ep, CollectiveKind::Ring);
        let mut grad = vec![0.0f32; 8];
        let ranges = layer_ranges(8, 2);
        let plan = plan_buckets(&[LayerGrad { layer: 7, bytes: 4 }], 0);
        let err = run_step(&engine, OverlapMode::Off, 0, &mut grad, &ranges, &plan, |_| {});
        assert!(err.is_err());
    }
}
