//! Minimal HTTP/1.1 over `std::net` — just enough protocol for the job
//! service, since the offline build has no axum/hyper/tokio.
//!
//! This is a deliberate sibling of the length-prefixed frame codec in
//! [`crate::net::tcp`], not a layer over it: the service speaks plain
//! HTTP so `curl` works against it. Scope: one request per connection
//! (`Connection: close`), `Content-Length` bodies in both directions,
//! chunked transfer encoding on responses (used by the long-poll
//! feedback route), no pipelining, no TLS. The client half
//! ([`request`]) is what `netbn submit|jobs|watch` and the test suite
//! use; it decodes both body framings.

use crate::Result;
use anyhow::{bail, ensure, Context};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

/// Caps keep a misbehaving peer from ballooning memory.
const MAX_HEADER_BYTES: usize = 64 * 1024;
const MAX_BODY_BYTES: usize = 4 * 1024 * 1024;

/// One parsed request.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    /// Path without the query string, e.g. `/jobs/3/feedback`.
    pub path: String,
    /// Decoded `k=v` query pairs (no percent-decoding — the API's query
    /// values are plain numbers).
    pub query: Vec<(String, String)>,
    pub headers: Vec<(String, String)>,
    pub body: String,
}

impl Request {
    pub fn query_u64(&self, key: &str) -> Option<u64> {
        self.query.iter().find(|(k, _)| k == key).and_then(|(_, v)| v.parse().ok())
    }

    pub fn query_f64(&self, key: &str) -> Option<f64> {
        self.query.iter().find(|(k, _)| k == key).and_then(|(_, v)| v.parse().ok())
    }

    fn header(&self, key: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(key))
            .map(|(_, v)| v.as_str())
    }

    /// Path segments, e.g. `/jobs/3/feedback` → `["jobs", "3", "feedback"]`.
    pub fn segments(&self) -> Vec<&str> {
        self.path.split('/').filter(|s| !s.is_empty()).collect()
    }
}

/// Read and parse one request from `stream` (which should carry a read
/// timeout so a stalled peer cannot pin a handler thread forever).
pub fn read_request(stream: &mut TcpStream) -> Result<Request> {
    let mut reader = BufReader::new(stream);
    let mut head = String::new();
    loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line).context("reading request head")?;
        ensure!(n > 0, "connection closed before a full request head");
        head.push_str(&line);
        ensure!(head.len() <= MAX_HEADER_BYTES, "request head exceeds {MAX_HEADER_BYTES} bytes");
        if line == "\r\n" || line == "\n" {
            break;
        }
    }
    let mut lines = head.lines();
    let request_line = lines.next().context("empty request")?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().context("missing method")?.to_string();
    let target = parts.next().context("missing request target")?;
    let version = parts.next().context("missing HTTP version")?;
    ensure!(version.starts_with("HTTP/1."), "unsupported protocol {version:?}");

    let (path, query_raw) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q),
        None => (target.to_string(), ""),
    };
    let query = query_raw
        .split('&')
        .filter(|s| !s.is_empty())
        .map(|pair| match pair.split_once('=') {
            Some((k, v)) => (k.to_string(), v.to_string()),
            None => (pair.to_string(), String::new()),
        })
        .collect();

    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            break;
        }
        if let Some((k, v)) = line.split_once(':') {
            headers.push((k.trim().to_string(), v.trim().to_string()));
        }
    }

    let mut req = Request { method, path, query, headers, body: String::new() };
    let content_length = match req.header("content-length") {
        Some(v) => v.parse::<usize>().context("bad Content-Length")?,
        None => 0,
    };
    ensure!(content_length <= MAX_BODY_BYTES, "request body exceeds {MAX_BODY_BYTES} bytes");
    if content_length > 0 {
        let mut buf = vec![0u8; content_length];
        reader.read_exact(&mut buf).context("reading request body")?;
        req.body = String::from_utf8(buf).context("request body is not UTF-8")?;
    }
    Ok(req)
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        202 => "Accepted",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        _ => "",
    }
}

/// One response, always `Connection: close`.
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: String,
    pub content_type: &'static str,
    /// Send the body with chunked transfer encoding (one chunk per line)
    /// instead of `Content-Length`.
    pub chunked: bool,
}

impl Response {
    pub fn json(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            headers: Vec::new(),
            body: body.into(),
            content_type: "application/json",
            chunked: false,
        }
    }

    /// A plaintext payload (the `/metrics` exposition format).
    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response { content_type: "text/plain; charset=utf-8", ..Response::json(status, body) }
    }

    /// An HTML payload (the `/dash` page).
    pub fn html(status: u16, body: impl Into<String>) -> Response {
        Response { content_type: "text/html; charset=utf-8", ..Response::json(status, body) }
    }

    /// A JSON error payload `{"error": …}`.
    pub fn error(status: u16, message: &str) -> Response {
        Response::json(status, format!("{{\"error\":{}}}", crate::report::json_str(message)))
    }

    pub fn header(mut self, key: &str, value: impl Into<String>) -> Response {
        self.headers.push((key.to_string(), value.into()));
        self
    }

    pub fn chunked(mut self) -> Response {
        self.chunked = true;
        self
    }

    pub fn write_to(&self, stream: &mut TcpStream) -> Result<()> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nConnection: close\r\n",
            self.status,
            reason(self.status),
            self.content_type
        );
        for (k, v) in &self.headers {
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        if self.chunked {
            head.push_str("Transfer-Encoding: chunked\r\n\r\n");
            stream.write_all(head.as_bytes())?;
            // One chunk per body line keeps the framing observable in
            // tests without fragmenting tiny payloads byte-by-byte.
            for line in self.body.split_inclusive('\n') {
                stream
                    .write_all(format!("{:x}\r\n{line}\r\n", line.len()).as_bytes())?;
            }
            stream.write_all(b"0\r\n\r\n")?;
        } else {
            head.push_str(&format!("Content-Length: {}\r\n\r\n", self.body.len()));
            stream.write_all(head.as_bytes())?;
            stream.write_all(self.body.as_bytes())?;
        }
        stream.flush()?;
        Ok(())
    }
}

/// Blocking HTTP client for the service API: send `method path` with an
/// optional JSON body to `addr` (`host:port`), return `(status, body)`.
/// Decodes both `Content-Length` and chunked response bodies.
pub fn request(addr: &str, method: &str, path: &str, body: Option<&str>) -> Result<(u16, String)> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    let body = body.unwrap_or("");
    let head = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()?;

    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line).context("reading status line")?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .with_context(|| format!("bad status line {status_line:?}"))?;

    let mut content_length: Option<usize> = None;
    let mut chunked = false;
    loop {
        let mut line = String::new();
        let n = reader.read_line(&mut line)?;
        if n == 0 || line == "\r\n" || line == "\n" {
            break;
        }
        if let Some((k, v)) = line.split_once(':') {
            let (k, v) = (k.trim(), v.trim());
            if k.eq_ignore_ascii_case("content-length") {
                content_length = Some(v.parse().context("bad Content-Length")?);
            } else if k.eq_ignore_ascii_case("transfer-encoding")
                && v.eq_ignore_ascii_case("chunked")
            {
                chunked = true;
            }
        }
    }

    let body = if chunked {
        let mut out = Vec::new();
        loop {
            let mut size_line = String::new();
            ensure!(reader.read_line(&mut size_line)? > 0, "truncated chunked body");
            let size = usize::from_str_radix(size_line.trim(), 16)
                .with_context(|| format!("bad chunk size {size_line:?}"))?;
            if size == 0 {
                break;
            }
            ensure!(out.len() + size <= MAX_BODY_BYTES, "chunked body too large");
            let mut chunk = vec![0u8; size + 2]; // data + trailing CRLF
            reader.read_exact(&mut chunk)?;
            out.extend_from_slice(&chunk[..size]);
        }
        out
    } else if let Some(len) = content_length {
        ensure!(len <= MAX_BODY_BYTES, "response body too large");
        let mut buf = vec![0u8; len];
        reader.read_exact(&mut buf)?;
        buf
    } else {
        let mut buf = Vec::new();
        reader.read_to_end(&mut buf)?;
        ensure!(buf.len() <= MAX_BODY_BYTES, "response body too large");
        buf
    };
    match String::from_utf8(body) {
        Ok(s) => Ok((status, s)),
        Err(_) => bail!("response body is not UTF-8"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Run `server` against one accepted connection while the client
    /// half of the test drives `request` against it.
    fn with_server<F>(server: F) -> String
    where
        F: FnOnce(&mut TcpStream) + Send + 'static,
    {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            server(&mut s);
        });
        format!("{addr}")
    }

    #[test]
    fn parses_request_line_query_headers_and_body() {
        let addr = with_server(|s| {
            let req = read_request(s).unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.path, "/jobs/7/feedback");
            assert_eq!(req.segments(), vec!["jobs", "7", "feedback"]);
            assert_eq!(req.query_u64("since"), Some(42));
            assert_eq!(req.query_f64("timeout"), Some(1.5));
            assert_eq!(req.body, "{\"k\":\"v\"}");
            Response::json(200, "{}").write_to(s).unwrap();
        });
        let (status, body) =
            request(&addr, "POST", "/jobs/7/feedback?since=42&timeout=1.5", Some("{\"k\":\"v\"}"))
                .unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "{}");
    }

    #[test]
    fn chunked_responses_reassemble() {
        let payload = "{\"a\":1,\n\"b\":[2,3],\n\"c\":\"end\"}";
        let addr = with_server(move |s| {
            read_request(s).unwrap();
            Response::json(200, payload).chunked().write_to(s).unwrap();
        });
        let (status, body) = request(&addr, "GET", "/stream", None).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, payload);
    }

    #[test]
    fn error_responses_carry_status_and_header() {
        let addr = with_server(|s| {
            read_request(s).unwrap();
            Response::error(429, "queue full").header("Retry-After", "2").write_to(s).unwrap();
        });
        let (status, body) = request(&addr, "POST", "/jobs", Some("{}")).unwrap();
        assert_eq!(status, 429);
        assert!(body.contains("queue full"), "{body}");
    }

    #[test]
    fn rejects_malformed_requests() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            read_request(&mut s).is_err()
        });
        let mut c = TcpStream::connect(addr).unwrap();
        c.write_all(b"NOT-HTTP\r\n\r\n").unwrap();
        drop(c);
        assert!(h.join().unwrap(), "garbage must not parse as a request");
    }
}
