//! The job model: what one submission is, through its whole lifecycle.
//!
//! State machine: `Queued → Running → Done | Failed`, with `Cancelled`
//! reachable only from `Queued` (a running scenario has no preemption
//! point — `DELETE /jobs/<id>` on a running job is a 409). Records
//! serialize to the same JSON the HTTP API serves and the store
//! persists, so a daemon restart reloads exactly what a client saw.

use crate::engine::jobqueue::JobRequest;
use crate::report::json_str;
use crate::util::json;
use crate::Result;
use anyhow::{bail, Context};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    Done,
    Failed,
    Cancelled,
}

impl JobState {
    pub fn as_str(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    pub fn parse(s: &str) -> Result<JobState> {
        Ok(match s {
            "queued" => JobState::Queued,
            "running" => JobState::Running,
            "done" => JobState::Done,
            "failed" => JobState::Failed,
            "cancelled" => JobState::Cancelled,
            other => bail!("unknown job state {other:?}"),
        })
    }

    /// Terminal states never change again (what a poller waits for).
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled)
    }
}

/// One job, from submission to terminal state.
#[derive(Clone, Debug, PartialEq)]
pub struct JobRecord {
    pub id: u64,
    pub request: JobRequest,
    pub state: JobState,
    /// Did the service inject warm-start overrides from a persisted
    /// tuner checkpoint?
    pub warm_started: bool,
    /// Failure message (state == Failed) or cancellation note.
    pub error: Option<String>,
    /// The finished run's `Outcome::to_json` output, verbatim.
    pub outcome_json: Option<String>,
}

impl JobRecord {
    pub fn new(id: u64, request: JobRequest) -> JobRecord {
        JobRecord {
            id,
            request,
            state: JobState::Queued,
            warm_started: false,
            error: None,
            outcome_json: None,
        }
    }

    /// Full record JSON — the `GET /jobs/<id>` body and the store's
    /// on-disk format. The outcome is embedded raw (it is already JSON).
    pub fn to_json(&self) -> String {
        let mut s = format!(
            "{{\"id\":{},\"scenario\":{},\"state\":{},\"priority\":{},\"warm_started\":{}",
            self.id,
            json_str(&self.request.scenario),
            json_str(self.state.as_str()),
            self.request.priority,
            self.warm_started
        );
        s.push_str(",\"params\":{");
        for (i, (k, v)) in self.request.params.iter().enumerate() {
            if i > 0 {
                s.push(',');
            }
            s.push_str(&format!("{}:{}", json_str(k), json_str(v)));
        }
        s.push('}');
        if let Some(e) = &self.error {
            s.push_str(&format!(",\"error\":{}", json_str(e)));
        }
        if let Some(o) = &self.outcome_json {
            s.push_str(&format!(",\"outcome\":{o}"));
        }
        s.push('}');
        s
    }

    /// One-line summary for `GET /jobs` listings.
    pub fn to_json_brief(&self) -> String {
        format!(
            "{{\"id\":{},\"scenario\":{},\"state\":{},\"priority\":{}}}",
            self.id,
            json_str(&self.request.scenario),
            json_str(self.state.as_str()),
            self.request.priority
        )
    }

    pub fn from_json(text: &str) -> Result<JobRecord> {
        let fields = json::object_fields(text).context("malformed job record")?;
        let params = match json::get(&fields, "params") {
            Some(raw) => json::parse_str_map(raw)?,
            None => Vec::new(),
        };
        Ok(JobRecord {
            id: json::parse_u64(json::require(&fields, "id")?)?,
            request: JobRequest {
                scenario: json::parse_string(json::require(&fields, "scenario")?)?,
                params,
                priority: json::parse_u64(json::require(&fields, "priority")?)? as u8,
            },
            state: JobState::parse(&json::parse_string(json::require(&fields, "state")?)?)?,
            warm_started: json::parse_bool(json::require(&fields, "warm_started")?)?,
            error: match json::get(&fields, "error") {
                Some(raw) => Some(json::parse_string(raw)?),
                None => None,
            },
            outcome_json: json::get(&fields, "outcome").map(|s| s.to_string()),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_json_round_trips_with_embedded_outcome() {
        let mut r = JobRecord::new(
            12,
            JobRequest {
                scenario: "emulate".into(),
                params: vec![("servers".into(), "2".into())],
                priority: 9,
            },
        );
        r.state = JobState::Done;
        r.warm_started = true;
        r.outcome_json =
            Some("{\"scenario\":\"emulate\",\"passed\":true,\"metrics\":{\"x\":1}}".into());
        let back = JobRecord::from_json(&r.to_json()).unwrap();
        assert_eq!(back, r);
        // The embedded outcome comes back byte-for-byte.
        assert_eq!(back.outcome_json, r.outcome_json);
    }

    #[test]
    fn states_round_trip_and_classify() {
        for s in
            [JobState::Queued, JobState::Running, JobState::Done, JobState::Failed, JobState::Cancelled]
        {
            assert_eq!(JobState::parse(s.as_str()).unwrap(), s);
        }
        assert!(JobState::parse("zombie").is_err());
        assert!(!JobState::Queued.is_terminal());
        assert!(!JobState::Running.is_terminal());
        assert!(JobState::Done.is_terminal());
        assert!(JobState::Cancelled.is_terminal());
    }

    #[test]
    fn error_strings_survive_escaping() {
        let mut r = JobRecord::new(1, JobRequest { scenario: "x".into(), params: vec![], priority: 0 });
        r.state = JobState::Failed;
        r.error = Some("line1\nline2 \"quoted\" \\ backslash".into());
        let back = JobRecord::from_json(&r.to_json()).unwrap();
        assert_eq!(back.error, r.error);
    }
}
