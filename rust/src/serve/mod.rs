//! `netbn serve` — a persistent, multi-tenant experiment service.
//!
//! The daemon accepts scenario submissions over HTTP, runs them on a
//! bounded worker pool with admission control and per-job priorities,
//! streams live telemetry, and persists results + tuner state so a
//! restarted daemon picks up where it left off (resubmitted jobs
//! warm-start from the stored [`crate::tune::TunerCheckpoint`]).
//!
//! ```text
//! POST   /jobs               submit {"scenario","params","priority"} → 202 | 429
//! GET    /jobs               list all job records (brief)
//! GET    /jobs/<id>          one full record (embedded outcome when done)
//! GET    /jobs/<id>/outcome  the raw Outcome JSON alone → 200 | 409 | 404
//! GET    /jobs/<id>/feedback?since=N&timeout=S   long-poll telemetry (chunked)
//! GET    /jobs/<id>/trace    merged Chrome trace of the job's spans
//! DELETE /jobs/<id>          cancel a still-queued job → 200 | 409 | 404
//! GET    /healthz            liveness + load
//! GET    /metrics            process-wide observability registry (plaintext)
//! GET    /metrics/stream?since=N&timeout=S   long-poll sampled timeseries (chunked)
//! GET    /dash               dependency-free live dashboard (HTML)
//! ```
//!
//! Module map: [`http`] is the std-only HTTP/1.1 layer, [`queue`] the
//! bounded priority queue, [`state`] the job table + lifecycle,
//! [`workers`] the pool draining into [`crate::engine::jobqueue`],
//! [`telemetry`] the per-job feedback rings, [`store`] the on-disk
//! results + tuner persistence, [`job`] the record model.

pub mod http;
pub mod job;
pub mod queue;
pub mod state;
pub mod store;
pub mod telemetry;
pub mod workers;

pub use job::{JobRecord, JobState};
pub use queue::{JobQueue, QueueFull};
pub use state::{CancelError, ServeState};
pub use store::Store;
pub use telemetry::TelemetryHub;
pub use workers::WorkerPool;

use crate::engine::jobqueue::{self, JobRequest};
use crate::engine::ScenarioRegistry;
use crate::tune::StepFeedback;
use crate::util::signal;
use crate::Result;
use anyhow::Context;
use http::{Request, Response};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// How the daemon is wired up (`netbn serve` flags).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// TCP port to listen on (0 picks a free port — used by tests).
    pub port: u16,
    /// Worker threads draining the queue.
    pub workers: usize,
    /// Max jobs waiting in the queue before admissions get a 429.
    pub queue_capacity: usize,
    /// Store directory for job records + tuner checkpoints.
    pub store_dir: PathBuf,
}

/// Poll cadence of the (non-blocking) accept loop and the signal loop.
const ACCEPT_POLL: Duration = Duration::from_millis(25);
/// Per-connection read timeout so a stalled peer cannot pin a handler.
const READ_TIMEOUT: Duration = Duration::from_secs(10);
/// Long-poll ceiling for the feedback route.
const MAX_POLL_S: f64 = 30.0;

/// A running daemon: accept loop + worker pool + metrics sampler over
/// one [`ServeState`].
pub struct Daemon {
    state: Arc<ServeState>,
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<JoinHandle<()>>,
    pool: Option<WorkerPool>,
    sampler: Option<crate::obs::Sampler>,
}

impl Daemon {
    /// Bind, reload the store, spawn workers, start accepting and
    /// sampling. The sampler persists each batch to the store's
    /// `timeseries.jsonl`; the state's [`crate::obs::TimeSeries`] has
    /// already resumed the durable seq space, so restarts neither
    /// duplicate nor lose cursors.
    pub fn start(cfg: &ServeConfig) -> Result<Daemon> {
        let store = Store::open(&cfg.store_dir)?;
        let state = Arc::new(ServeState::new(store, cfg.queue_capacity, cfg.workers)?);
        let listener = TcpListener::bind(("127.0.0.1", cfg.port))
            .with_context(|| format!("binding port {}", cfg.port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let pool = WorkerPool::start(cfg.workers, Arc::clone(&state));
        // The sink gets its own store handle (stores are just a rooted
        // path) so persistence never contends with request handlers.
        let sink_store = Store::open(&cfg.store_dir)?;
        let sampler = crate::obs::Sampler::start(
            Arc::clone(&state.timeseries),
            crate::obs::metrics::global(),
            crate::obs::timeseries::DEFAULT_SAMPLE_INTERVAL,
            Some(Box::new(move |batch: &[crate::obs::TsPoint]| {
                if let Err(e) = sink_store.append_timeseries(batch) {
                    eprintln!("serve: failed to persist timeseries batch: {e:#}");
                }
            })),
        );
        let accept_thread = {
            let state = Arc::clone(&state);
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("serve-accept".to_string())
                .spawn(move || accept_loop(&listener, &state, &stop))
                .context("spawning accept loop")?
        };
        Ok(Daemon {
            state,
            addr,
            stop,
            accept_thread: Some(accept_thread),
            pool: Some(pool),
            sampler: Some(sampler),
        })
    }

    /// Where the daemon is listening (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn state(&self) -> &Arc<ServeState> {
        &self.state
    }

    /// Graceful shutdown: stop accepting, cancel everything still
    /// queued, drain running jobs, flush the store. Idempotent.
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        self.state.begin_shutdown();
        if let Some(mut sampler) = self.sampler.take() {
            sampler.stop();
        }
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
        if let Some(pool) = self.pool.take() {
            pool.join();
        }
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        self.stop();
    }
}

fn accept_loop(listener: &TcpListener, state: &Arc<ServeState>, stop: &AtomicBool) {
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                let state = Arc::clone(state);
                // Handlers are short-lived (bounded by READ_TIMEOUT and
                // the long-poll ceiling); detach rather than track.
                let _ = std::thread::Builder::new()
                    .name("serve-conn".to_string())
                    .spawn(move || handle_connection(stream, &state));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(e) => {
                eprintln!("serve: accept error: {e}");
                std::thread::sleep(ACCEPT_POLL);
            }
        }
    }
}

fn handle_connection(mut stream: TcpStream, state: &ServeState) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let response = match http::read_request(&mut stream) {
        Ok(req) => route(&req, state),
        Err(e) => Response::error(400, &format!("{e:#}")),
    };
    let _ = response.write_to(&mut stream);
}

/// Dispatch one request. Pure request → response; all state transitions
/// go through [`ServeState`].
fn route(req: &Request, state: &ServeState) -> Response {
    let m = crate::obs::metrics::global();
    m.counter("serve.http_requests", &[("method", req.method.as_str())]).inc();
    let t0 = std::time::Instant::now();
    let segments = req.segments();
    let resp = match (req.method.as_str(), segments.as_slice()) {
        ("GET", ["healthz"]) => healthz(state),
        ("GET", ["metrics"]) => metrics(),
        ("GET", ["metrics", "stream"]) => metrics_stream(req, state),
        ("GET", ["dash"]) => dash(),
        ("POST", ["jobs"]) => submit(req, state),
        ("GET", ["jobs"]) => list(state),
        ("GET", ["jobs", id]) => with_id(id, |id| get_job(state, id)),
        ("DELETE", ["jobs", id]) => with_id(id, |id| cancel(state, id)),
        ("GET", ["jobs", id, "outcome"]) => with_id(id, |id| outcome(state, id)),
        ("GET", ["jobs", id, "feedback"]) => with_id(id, |id| feedback(req, state, id)),
        ("GET", ["jobs", id, "trace"]) => with_id(id, |id| job_trace(state, id)),
        (_, ["healthz" | "metrics" | "dash" | "jobs", ..]) => {
            Response::error(405, "method not allowed")
        }
        _ => Response::error(404, "no such route"),
    };
    m.histo("serve.route_us", &[]).record(t0.elapsed().as_micros() as u64);
    resp
}

/// The process-wide observability registry, rendered in the plaintext
/// exposition format of [`crate::obs::metrics::Registry::render_text`].
fn metrics() -> Response {
    Response::text(200, crate::obs::metrics::global().render_text())
}

/// Poll cadence while a stream request waits for fresh samples. The
/// sampler ticks at [`crate::obs::timeseries::DEFAULT_SAMPLE_INTERVAL`],
/// so a short sleep keeps first-chunk latency low without a condvar.
const STREAM_POLL: Duration = Duration::from_millis(50);

/// Long-poll the sampled timeseries ring. Cursor contract mirrors
/// `/jobs/<id>/feedback?since=N`: pass back `next` to resume without
/// duplicates; chunked so `curl -N` sees points line by line.
fn metrics_stream(req: &Request, state: &ServeState) -> Response {
    let since = req.query_u64("since").unwrap_or(0);
    let timeout = req.query_f64("timeout").unwrap_or(10.0).clamp(0.0, MAX_POLL_S);
    let deadline = std::time::Instant::now() + Duration::from_secs_f64(timeout);
    let (points, next) = loop {
        let (points, next) = state.timeseries.since(since);
        if !points.is_empty() || std::time::Instant::now() >= deadline {
            break (points, next);
        }
        std::thread::sleep(STREAM_POLL);
    };
    let mut body = String::from("{\"points\":[");
    for (i, p) in points.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        body.push('\n');
        body.push_str(&p.to_json_line());
    }
    body.push_str("],\n\"detections\":[");
    for (i, d) in state.timeseries.detections().iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        body.push('\n');
        body.push_str(&d.to_json());
    }
    body.push_str(&format!("],\n\"next\":{next}}}"));
    Response::json(200, body).chunked()
}

/// The merged Chrome trace of everything the job's run left in the span
/// ring — loads straight into Perfetto / `chrome://tracing`. Untraced
/// jobs (and history reloaded from a previous daemon life) answer with
/// a valid empty trace rather than an error.
fn job_trace(state: &ServeState, id: u64) -> Response {
    if state.get(id).is_none() {
        return Response::error(404, &format!("no job {id}"));
    }
    let spans = state.telemetry.get(id).map(|f| f.spans()).unwrap_or_default();
    Response::json(200, crate::obs::span::chrome_trace_json(&spans))
}

/// The live dashboard: one self-contained HTML page (no external
/// scripts, fonts, or styles — it must render inside an airgapped
/// cluster) that tails `/metrics/stream` with a resume cursor and plots
/// the utilization timeline plus the latest job's per-step breakdown.
fn dash() -> Response {
    Response::html(200, DASH_HTML)
}

const DASH_HTML: &str = r#"<!DOCTYPE html>
<html lang="en">
<head>
<meta charset="utf-8">
<title>netbn dash</title>
<style>
body { font: 13px/1.4 monospace; margin: 1.5em; background: #111; color: #ddd; }
h1 { font-size: 16px; } h2 { font-size: 13px; color: #9cf; margin: 1.2em 0 0.3em; }
svg { background: #181818; border: 1px solid #333; }
table { border-collapse: collapse; } td, th { padding: 2px 8px; border: 1px solid #333; }
#detections li { color: #f66; }
.muted { color: #777; }
</style>
</head>
<body>
<h1>netbn · live telemetry</h1>
<div class="muted" id="status">connecting…</div>
<h2>utilization timeline (sampled bandwidth/utilization series)</h2>
<svg id="timeline" width="720" height="160"></svg>
<h2>detections</h2>
<ul id="detections"><li class="muted">none</li></ul>
<h2>latest job · per-step breakdown</h2>
<table id="steps"><tr><th>step</th><th>wall_s</th><th>compute</th><th>comm</th><th>busbw_gbps</th></tr></table>
<script>
"use strict";
const hist = new Map(); // series -> [{t,v}]
let cursor = 0;
function plot() {
  const svg = document.getElementById("timeline");
  const W = 720, H = 160;
  let out = "";
  let all = [];
  for (const pts of hist.values()) all = all.concat(pts);
  if (all.length > 1) {
    const t0 = Math.min(...all.map(p => p.t)), t1 = Math.max(...all.map(p => p.t));
    const vmax = Math.max(1e-9, ...all.map(p => p.v));
    const colors = ["#6cf", "#fc6", "#6f9", "#f9f", "#ff6", "#c9f"];
    let ci = 0, legendY = 14;
    for (const [name, pts] of hist) {
      const c = colors[ci++ % colors.length];
      const d = pts.map((p, i) => (i ? "L" : "M") +
        ((p.t - t0) / Math.max(1e-9, t1 - t0) * (W - 20) + 10).toFixed(1) + "," +
        (H - 10 - p.v / vmax * (H - 30)).toFixed(1)).join(" ");
      out += `<path d="${d}" fill="none" stroke="${c}" stroke-width="1.5"/>`;
      out += `<text x="14" y="${legendY}" fill="${c}" font-size="10">${name}</text>`;
      legendY += 12;
    }
  }
  svg.innerHTML = out;
}
function onBatch(msg) {
  for (const p of msg.points || []) {
    if (!(p.series.includes("bps") || p.series.includes("util"))) continue;
    if (!hist.has(p.series)) hist.set(p.series, []);
    const pts = hist.get(p.series);
    pts.push({ t: p.t_s, v: p.value });
    if (pts.length > 600) pts.shift();
  }
  const ul = document.getElementById("detections");
  if ((msg.detections || []).length) {
    ul.innerHTML = msg.detections.map(d =>
      `<li>${d.kind} on ${d.series} at seq ${d.at}: ${d.value.toFixed(3)} vs baseline ${d.baseline.toFixed(3)} (z=${d.z.toFixed(1)})</li>`
    ).join("");
  }
  plot();
}
async function tail() {
  for (;;) {
    try {
      const r = await fetch(`/metrics/stream?since=${cursor}&timeout=15`);
      const msg = await r.json();
      cursor = msg.next;
      document.getElementById("status").textContent =
        `streaming · cursor ${cursor} · ${hist.size} series`;
      onBatch(msg);
    } catch (e) {
      document.getElementById("status").textContent = "stream error: " + e;
      await new Promise(res => setTimeout(res, 2000));
    }
  }
}
async function steps() {
  for (;;) {
    try {
      const jobs = (await (await fetch("/jobs")).json()).jobs || [];
      if (jobs.length) {
        const id = jobs[jobs.length - 1].id;
        const fb = await (await fetch(`/jobs/${id}/feedback?since=0&timeout=0`)).json();
        const rows = (fb.samples || []).slice(-20).map(s =>
          `<tr><td>${s.step}</td><td>${s.wall_s.toFixed(4)}</td>` +
          `<td>${(100 * s.compute_frac).toFixed(1)}%</td>` +
          `<td>${(100 * s.comm_frac).toFixed(1)}%</td><td>${s.busbw_gbps.toFixed(3)}</td></tr>`
        ).join("");
        document.getElementById("steps").innerHTML =
          "<tr><th>step</th><th>wall_s</th><th>compute</th><th>comm</th><th>busbw_gbps</th></tr>" + rows;
      }
    } catch (e) { /* daemon may not have jobs yet */ }
    await new Promise(res => setTimeout(res, 2000));
  }
}
tail();
steps();
</script>
</body>
</html>
"#;

fn with_id(raw: &str, f: impl FnOnce(u64) -> Response) -> Response {
    match raw.parse::<u64>() {
        Ok(id) => f(id),
        Err(_) => Response::error(400, &format!("job id must be an integer, got {raw:?}")),
    }
}

fn healthz(state: &ServeState) -> Response {
    let (queued, running) = state.counts();
    Response::json(
        200,
        format!(
            "{{\"status\":\"ok\",\"queued\":{queued},\"running\":{running},\
             \"workers\":{},\"capacity\":{}}}",
            state.workers,
            state.queue.capacity()
        ),
    )
}

fn submit(req: &Request, state: &ServeState) -> Response {
    let job = match JobRequest::from_json(&req.body) {
        Ok(j) => j,
        Err(e) => return Response::error(400, &format!("{e:#}")),
    };
    // Validate at admission so the queue never holds doomed work.
    let registry = ScenarioRegistry::builtin();
    let scenario = match registry.get(&job.scenario) {
        Ok(s) => s,
        Err(e) => return Response::error(404, &format!("{e:#}")),
    };
    if let Err(e) = scenario.schema().resolve(&job.params) {
        return Response::error(400, &format!("{e:#}"));
    }
    // Advisory warm-start hint (the worker injects the real overrides
    // at claim time, against the then-current checkpoint).
    let warm_hint = state
        .store
        .load_tuner(&job.scenario)
        .map(|ck| !jobqueue::warm_start_overrides(scenario.schema(), &job, &ck).is_empty())
        .unwrap_or(false);
    match state.submit(job) {
        Ok(record) => Response::json(
            202,
            format!("{{\"id\":{},\"state\":\"queued\",\"warm_start\":{warm_hint}}}", record.id),
        ),
        Err(full) => Response::error(
            429,
            &format!("queue full ({} waiting); retry after {:.0}s", full.queued, full.retry_after_s),
        )
        .header("Retry-After", format!("{:.0}", full.retry_after_s.ceil())),
    }
}

fn list(state: &ServeState) -> Response {
    let briefs: Vec<String> = state.list().iter().map(JobRecord::to_json_brief).collect();
    Response::json(200, format!("{{\"jobs\":[{}]}}", briefs.join(",")))
}

fn get_job(state: &ServeState, id: u64) -> Response {
    match state.get(id) {
        Some(record) => Response::json(200, record.to_json()),
        None => Response::error(404, &format!("no job {id}")),
    }
}

/// The finished run's `Outcome` JSON, verbatim — exactly what a direct
/// `ScenarioRegistry` run would have produced.
fn outcome(state: &ServeState, id: u64) -> Response {
    match state.get(id) {
        Some(record) => match record.outcome_json {
            Some(json) => Response::json(200, json),
            None => Response::error(
                409,
                &format!("job {id} is {} — no outcome", record.state.as_str()),
            ),
        },
        None => Response::error(404, &format!("no job {id}")),
    }
}

fn cancel(state: &ServeState, id: u64) -> Response {
    match state.cancel(id) {
        Ok(record) => Response::json(200, record.to_json_brief()),
        Err(CancelError::NotFound) => Response::error(404, &format!("no job {id}")),
        Err(CancelError::NotCancellable(s)) => Response::error(
            409,
            &format!("job {id} is {} — only queued jobs can be cancelled", s.as_str()),
        ),
    }
}

/// Long-poll the job's telemetry feed. Chunked so `netbn watch` (and
/// `curl -N`) see samples line by line.
fn feedback(req: &Request, state: &ServeState, id: u64) -> Response {
    let Some(feed) = state.telemetry.get(id) else {
        return match state.get(id) {
            // Reloaded history from a previous daemon life has no feed.
            Some(_) => Response::json(200, feedback_json(&[], 0, true)).chunked(),
            None => Response::error(404, &format!("no job {id}")),
        };
    };
    let since = req.query_u64("since").unwrap_or(0);
    let timeout = req.query_f64("timeout").unwrap_or(10.0).clamp(0.0, MAX_POLL_S);
    let (samples, next, done) = feed.poll_since(since, Duration::from_secs_f64(timeout));
    Response::json(200, feedback_json(&samples, next, done)).chunked()
}

/// `{"samples":[…],"next":N,"done":b}`, one sample per line. Each sample
/// carries the derived per-step breakdown fractions (`compute_frac`,
/// `comm_frac` of the step wall) so a watcher reads the compute/comm
/// split without re-deriving it.
fn feedback_json(samples: &[StepFeedback], next: u64, done: bool) -> String {
    let mut s = String::from("{\"samples\":[");
    for (i, fb) in samples.iter().enumerate() {
        if i > 0 {
            s.push(',');
        }
        s.push('\n');
        let frac = |part: f64| if fb.wall_s > 0.0 { part / fb.wall_s } else { 0.0 };
        s.push_str(&format!(
            "{{\"step\":{},\"wall_s\":{},\"compute_s\":{},\"comm_busy_s\":{},\"busbw_gbps\":{},\
             \"compute_frac\":{:.6},\"comm_frac\":{:.6}}}",
            fb.step,
            fb.wall_s,
            fb.compute_s,
            fb.comm_busy_s,
            fb.busbw_gbps,
            frac(fb.compute_s),
            frac(fb.comm_busy_s)
        ));
    }
    s.push_str(&format!("],\n\"next\":{next},\"done\":{done}}}"));
    s
}

/// `netbn serve` entry point: run the daemon until SIGINT/SIGTERM, then
/// drain gracefully (cancel queued, finish running, flush the store).
pub fn run_serve(cfg: &ServeConfig) -> Result<()> {
    signal::install();
    let mut daemon = Daemon::start(cfg)?;
    println!(
        "netbn serve: listening on http://{} ({} workers, queue capacity {}, store {})",
        daemon.addr(),
        cfg.workers,
        cfg.queue_capacity,
        cfg.store_dir.display()
    );
    while !signal::triggered() {
        std::thread::sleep(ACCEPT_POLL);
    }
    let (queued, running) = daemon.state().counts();
    eprintln!(
        "netbn serve: shutdown requested — cancelling {queued} queued, draining {running} running"
    );
    daemon.stop();
    eprintln!("netbn serve: store flushed to {}", cfg.store_dir.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    fn test_daemon(workers: usize, queue_capacity: usize) -> Daemon {
        static N: AtomicU64 = AtomicU64::new(0);
        let dir = std::env::temp_dir().join(format!(
            "netbn_daemon_{}_{}",
            std::process::id(),
            N.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&dir);
        Daemon::start(&ServeConfig { port: 0, workers, queue_capacity, store_dir: dir }).unwrap()
    }

    #[test]
    fn healthz_reports_shape() {
        let daemon = test_daemon(2, 8);
        let (status, body) =
            http::request(&daemon.addr().to_string(), "GET", "/healthz", None).unwrap();
        assert_eq!(status, 200);
        assert!(body.contains("\"workers\":2"), "{body}");
        assert!(body.contains("\"capacity\":8"), "{body}");
    }

    #[test]
    fn metrics_route_serves_the_registry_as_plaintext() {
        let daemon = test_daemon(1, 4);
        let addr = daemon.addr().to_string();
        // The healthz hit increments the request counter the /metrics
        // response must then contain.
        assert_eq!(http::request(&addr, "GET", "/healthz", None).unwrap().0, 200);
        let (status, body) = http::request(&addr, "GET", "/metrics", None).unwrap();
        assert_eq!(status, 200);
        assert!(body.contains("serve.http_requests"), "{body}");
        assert!(body.contains("serve.route_us"), "{body}");
        assert_eq!(http::request(&addr, "POST", "/metrics", None).unwrap().0, 405);
    }

    #[test]
    fn feedback_json_carries_breakdown_fractions() {
        let fb = StepFeedback {
            step: 3,
            wall_s: 2.0,
            compute_s: 1.0,
            comm_busy_s: 0.5,
            busbw_gbps: 7.0,
        };
        let s = feedback_json(&[fb], 4, false);
        assert!(s.contains("\"compute_frac\":0.500000"), "{s}");
        assert!(s.contains("\"comm_frac\":0.250000"), "{s}");
        // A zero wall must not divide by zero.
        let z = StepFeedback { step: 0, wall_s: 0.0, compute_s: 0.0, comm_busy_s: 0.0, busbw_gbps: 0.0 };
        assert!(feedback_json(&[z], 1, true).contains("\"compute_frac\":0.000000"));
    }

    #[test]
    fn bad_routes_and_methods_are_refused() {
        let daemon = test_daemon(1, 4);
        let addr = daemon.addr().to_string();
        assert_eq!(http::request(&addr, "GET", "/nope", None).unwrap().0, 404);
        assert_eq!(http::request(&addr, "DELETE", "/healthz", None).unwrap().0, 405);
        assert_eq!(http::request(&addr, "GET", "/jobs/abc", None).unwrap().0, 400);
        assert_eq!(http::request(&addr, "POST", "/jobs", Some("not json")).unwrap().0, 400);
        assert_eq!(
            http::request(&addr, "POST", "/jobs", Some("{\"scenario\":\"nope\"}")).unwrap().0,
            404
        );
        assert_eq!(
            http::request(
                &addr,
                "POST",
                "/jobs",
                Some("{\"scenario\":\"simulate\",\"params\":{\"bandwidth\":\"-1\"}}")
            )
            .unwrap()
            .0,
            400,
            "schema violations must be rejected at admission"
        );
    }

    #[test]
    fn feedback_for_reloaded_history_is_done_and_empty() {
        let daemon = test_daemon(1, 4);
        let addr = daemon.addr().to_string();
        let (status, _) = http::request(&addr, "GET", "/jobs/42/feedback", None).unwrap();
        assert_eq!(status, 404, "unknown job has no feedback");
    }

    #[test]
    fn dash_serves_a_self_contained_html_page() {
        let daemon = test_daemon(1, 4);
        let addr = daemon.addr().to_string();
        let (status, body) = http::request(&addr, "GET", "/dash", None).unwrap();
        assert_eq!(status, 200);
        assert!(body.contains("<!DOCTYPE html>"), "{body:.60}");
        assert!(body.contains("/metrics/stream"), "the page must tail the live stream");
        assert!(!body.contains("http://") && !body.contains("https://"),
            "dash must not reference external resources");
        assert_eq!(http::request(&addr, "POST", "/dash", None).unwrap().0, 405);
    }

    #[test]
    fn metrics_stream_answers_with_points_and_a_cursor() {
        let daemon = test_daemon(1, 4);
        let addr = daemon.addr().to_string();
        // Force at least one sampled gauge, then take a deterministic
        // sample (the background sampler's cadence is too slow for a
        // unit test).
        crate::obs::metrics::global().gauge("serve_stream_test", &[]).set(4.0);
        daemon.state().sample_now();
        let since = 0;
        let (status, body) = http::request(
            &addr,
            "GET",
            &format!("/metrics/stream?since={since}&timeout=0"),
            None,
        )
        .unwrap();
        assert_eq!(status, 200);
        assert!(body.contains("\"points\":["), "{body}");
        assert!(body.contains("\"next\":"), "{body}");
        assert!(body.contains("serve_stream_test"), "{body}");
        // Cursor resume: asking from the returned cursor yields only
        // points at or past it (the background sampler may have ticked
        // in between — new points are fine, re-sent ones are not).
        let next = body
            .rsplit("\"next\":")
            .next()
            .and_then(|s| s.trim_end_matches('}').trim().parse::<u64>().ok())
            .unwrap();
        let (_, body2) = http::request(
            &addr,
            "GET",
            &format!("/metrics/stream?since={next}&timeout=0"),
            None,
        )
        .unwrap();
        for line in body2.lines().filter(|l| l.contains("\"seq\"")) {
            // Point lines may carry the array's trailing `],` or `,`.
            let clean = line.trim_end_matches(',').trim_end_matches(']').trim_end_matches(',');
            let p = crate::obs::TsPoint::from_json_line(clean)
                .unwrap_or_else(|e| panic!("bad stream line {line:?}: {e:#}"));
            assert!(p.seq >= next, "duplicate point {p:?} (cursor {next})");
        }
    }

    #[test]
    fn trace_route_answers_per_job() {
        let daemon = test_daemon(1, 4);
        let addr = daemon.addr().to_string();
        assert_eq!(http::request(&addr, "GET", "/jobs/9/trace", None).unwrap().0, 404);
        let (status, body) = http::request(
            &addr,
            "POST",
            "/jobs",
            Some("{\"scenario\":\"simulate\",\"params\":{}}"),
        )
        .unwrap();
        assert_eq!(status, 202, "{body}");
        // Wait for the job to finish so the feed holds its span window.
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        loop {
            let (_, job) = http::request(&addr, "GET", "/jobs/1", None).unwrap();
            if job.contains("\"state\":\"done\"") || job.contains("\"state\":\"failed\"") {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "job never finished: {job}");
            std::thread::sleep(Duration::from_millis(50));
        }
        let (status, trace) = http::request(&addr, "GET", "/jobs/1/trace", None).unwrap();
        assert_eq!(status, 200);
        assert!(trace.contains("\"traceEvents\""), "{trace}");
    }
}
