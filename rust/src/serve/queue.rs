//! Bounded priority queue with admission control — the backpressure
//! half of the job service.
//!
//! Submissions carry a priority (higher drains first; FIFO within a
//! priority level). The queue is bounded: when full, [`JobQueue::push`]
//! *rejects* instead of blocking, and the HTTP layer turns that into
//! `429 Too Many Requests` + `Retry-After` — a loaded service should
//! shed work at the door, not accumulate unbounded latency. The hint is
//! the queue's own estimate: pending work / workers × a recent
//! mean job duration.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Why a submission was refused.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QueueFull {
    /// Entries currently queued (== capacity).
    pub queued: usize,
    /// Suggested client back-off, seconds (the `Retry-After` header).
    pub retry_after_s: f64,
}

#[derive(Debug, Clone, Copy)]
struct Entry {
    job_id: u64,
    priority: u8,
    /// Admission order, for FIFO within a priority level.
    seq: u64,
}

struct Inner {
    entries: VecDeque<Entry>,
    next_seq: u64,
    closed: bool,
    /// Rolling mean job duration (seconds), fed by the worker pool; the
    /// retry-after estimate's clock.
    mean_job_s: f64,
}

/// The shared queue between the HTTP handlers and the worker pool.
pub struct JobQueue {
    capacity: usize,
    workers: usize,
    inner: Mutex<Inner>,
    ready: Condvar,
}

impl JobQueue {
    /// A queue admitting at most `capacity` waiting jobs, drained by
    /// `workers` workers (the worker count only shapes the retry-after
    /// estimate; zero is allowed and means "nothing drains").
    pub fn new(capacity: usize, workers: usize) -> JobQueue {
        assert!(capacity >= 1, "queue capacity must be >= 1");
        JobQueue {
            capacity,
            workers,
            inner: Mutex::new(Inner {
                entries: VecDeque::new(),
                next_seq: 0,
                closed: false,
                mean_job_s: 1.0,
            }),
            ready: Condvar::new(),
        }
    }

    /// Admit `job_id` at `priority` (higher drains first), or reject
    /// with a back-off hint when at capacity or shut down.
    pub fn push(&self, job_id: u64, priority: u8) -> Result<(), QueueFull> {
        let mut inner = self.inner.lock().unwrap();
        if inner.closed || inner.entries.len() >= self.capacity {
            let queued = inner.entries.len();
            let per_worker = queued as f64 / self.workers.max(1) as f64;
            let retry = (per_worker * inner.mean_job_s).clamp(1.0, 60.0);
            return Err(QueueFull { queued, retry_after_s: retry });
        }
        let seq = inner.next_seq;
        inner.next_seq += 1;
        inner.entries.push_back(Entry { job_id, priority, seq });
        drop(inner);
        self.ready.notify_one();
        Ok(())
    }

    /// Block until a job is available (highest priority first, FIFO
    /// within a priority) or the queue is closed. `None` means shutdown:
    /// once closed, remaining entries are *not* handed out — the daemon
    /// cancels them so a SIGTERM drains running work only.
    pub fn pop(&self) -> Option<u64> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if inner.closed {
                return None;
            }
            // Highest priority, then lowest seq: a stable selection that
            // starves nothing *within* a priority level.
            let best = inner
                .entries
                .iter()
                .enumerate()
                .max_by_key(|(_, e)| (e.priority, u64::MAX - e.seq))
                .map(|(i, _)| i);
            if let Some(i) = best {
                let e = inner.entries.remove(i).expect("index from enumerate");
                return Some(e.job_id);
            }
            inner = self.ready.wait(inner).unwrap();
        }
    }

    /// Remove a still-queued job; `false` when it already left the queue
    /// (running, finished, or never admitted).
    pub fn cancel(&self, job_id: u64) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let before = inner.entries.len();
        inner.entries.retain(|e| e.job_id != job_id);
        inner.entries.len() != before
    }

    /// Stop admissions and wake all poppers; queued entries stay for the
    /// daemon to cancel. Returns the job ids that were still queued.
    pub fn close(&self) -> Vec<u64> {
        let mut inner = self.inner.lock().unwrap();
        inner.closed = true;
        let leftover = inner.entries.drain(..).map(|e| e.job_id).collect();
        drop(inner);
        self.ready.notify_all();
        leftover
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Fold one finished job's duration into the retry-after estimate
    /// (exponential moving average, α = 0.3).
    pub fn observe_job_duration(&self, d: Duration) {
        let mut inner = self.inner.lock().unwrap();
        inner.mean_job_s = 0.7 * inner.mean_job_s + 0.3 * d.as_secs_f64();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn drains_by_priority_then_fifo() {
        let q = JobQueue::new(8, 1);
        q.push(1, 5).unwrap();
        q.push(2, 9).unwrap();
        q.push(3, 5).unwrap();
        q.push(4, 9).unwrap();
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(4));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn rejects_at_capacity_with_a_backoff_hint() {
        let q = JobQueue::new(2, 1);
        q.push(1, 5).unwrap();
        q.push(2, 5).unwrap();
        let err = q.push(3, 5).unwrap_err();
        assert_eq!(err.queued, 2);
        assert!(err.retry_after_s >= 1.0 && err.retry_after_s <= 60.0);
        // Draining one slot re-opens admission.
        assert_eq!(q.pop(), Some(1));
        q.push(3, 5).unwrap();
        assert_eq!(q.len(), 2);
    }

    #[test]
    fn cancel_removes_only_queued_entries() {
        let q = JobQueue::new(4, 1);
        q.push(1, 5).unwrap();
        q.push(2, 5).unwrap();
        assert!(q.cancel(1));
        assert!(!q.cancel(1), "already cancelled");
        assert!(!q.cancel(99), "never queued");
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn close_wakes_blocked_poppers_and_returns_leftovers() {
        let q = Arc::new(JobQueue::new(4, 1));
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop());
        // Give the popper time to block, then close.
        std::thread::sleep(Duration::from_millis(50));
        q.push(7, 5).unwrap();
        q.push(8, 5).unwrap();
        // The popper may have grabbed 7 already; close returns the rest.
        std::thread::sleep(Duration::from_millis(50));
        let leftover = q.close();
        assert!(leftover.contains(&8) || h.join().unwrap() == Some(8));
        assert!(q.pop().is_none(), "closed queue must not hand out jobs");
        assert!(q.push(9, 5).is_err(), "closed queue must reject admissions");
    }

    #[test]
    fn retry_hint_tracks_observed_durations() {
        let q = JobQueue::new(1, 2);
        for _ in 0..20 {
            q.observe_job_duration(Duration::from_secs(10));
        }
        q.push(1, 5).unwrap();
        let err = q.push(2, 5).unwrap_err();
        // 1 queued / 2 workers × ~10 s ≈ 5 s.
        assert!(err.retry_after_s > 2.0, "hint {} ignores durations", err.retry_after_s);
    }
}
