//! Shared daemon state: the job table and its lifecycle transitions.
//!
//! One `Arc<ServeState>` is held by the accept loop, every connection
//! handler, and every worker. All transitions (submit, claim, finish,
//! cancel, shutdown) live here so the locking story stays in one file:
//! the job table is one mutex, the queue and telemetry hub have their
//! own, and no code path holds two of them across a blocking call.

use super::job::{JobRecord, JobState};
use super::queue::{JobQueue, QueueFull};
use super::store::Store;
use super::telemetry::TelemetryHub;
use crate::engine::jobqueue::JobRequest;
use crate::obs::TimeSeries;
use crate::Result;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Why a cancellation was refused.
#[derive(Debug, PartialEq, Eq)]
pub enum CancelError {
    /// No such job id.
    NotFound,
    /// The job already left the queue (running or terminal) — there is
    /// no preemption point inside a scenario run.
    NotCancellable(JobState),
}

pub struct ServeState {
    pub queue: JobQueue,
    pub telemetry: TelemetryHub,
    pub store: Store,
    /// The continuous metrics sampler's ring. Its seq space resumes
    /// from the store's persisted high-water mark, so a restarted
    /// daemon's stream and JSONL log never duplicate or skip a cursor.
    pub timeseries: Arc<TimeSeries>,
    /// Worker pool size (surfaced by `/healthz`).
    pub workers: usize,
    jobs: Mutex<BTreeMap<u64, JobRecord>>,
    next_id: AtomicU64,
    running: AtomicUsize,
}

impl ServeState {
    /// Build the state over an opened store, reloading persisted
    /// history. Jobs that were queued or running when the previous
    /// daemon died are marked cancelled (their work is gone; the record
    /// says so) — everything terminal is queryable history again.
    pub fn new(store: Store, queue_capacity: usize, workers: usize) -> Result<ServeState> {
        let mut jobs = BTreeMap::new();
        let mut max_id = 0u64;
        for mut record in store.load_jobs()? {
            max_id = max_id.max(record.id);
            if !record.state.is_terminal() {
                record.state = JobState::Cancelled;
                record.error = Some("daemon restarted while the job was pending".to_string());
                store.save_job(&record)?;
            }
            jobs.insert(record.id, record);
        }
        let ts_resume = store.last_timeseries_seq().map(|s| s + 1).unwrap_or(0);
        Ok(ServeState {
            queue: JobQueue::new(queue_capacity, workers),
            telemetry: TelemetryHub::new(),
            store,
            timeseries: Arc::new(TimeSeries::resume_from(ts_resume)),
            workers,
            jobs: Mutex::new(jobs),
            next_id: AtomicU64::new(max_id + 1),
            running: AtomicUsize::new(0),
        })
    }

    /// Take one timeseries sample immediately and persist it — exactly
    /// what the background [`crate::obs::Sampler`] does every interval.
    /// Tests and shutdown paths use this for a deterministic sample.
    pub fn sample_now(&self) -> usize {
        let batch = self.timeseries.sample(crate::obs::metrics::global());
        if let Err(e) = self.store.append_timeseries(&batch) {
            eprintln!("serve: failed to persist timeseries batch: {e:#}");
        }
        batch.len()
    }

    /// Admit a validated request: allocate an id, persist the queued
    /// record, enqueue. On a full queue nothing survives (record and
    /// file are rolled back) and the caller turns the hint into a 429.
    pub fn submit(&self, request: JobRequest) -> std::result::Result<JobRecord, QueueFull> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let record = JobRecord::new(id, request);
        let priority = record.request.priority;
        self.jobs.lock().unwrap().insert(id, record.clone());
        // Persist before enqueueing: once a worker can see the job, the
        // on-disk record must already exist (a fast worker's Done write
        // must never race a later Queued write).
        if let Err(e) = self.store.save_job(&record) {
            eprintln!("serve: failed to persist job {id}: {e:#}");
        }
        self.telemetry.feed(id); // pollers can attach while queued
        if let Err(full) = self.queue.push(id, priority) {
            self.jobs.lock().unwrap().remove(&id);
            self.store.delete_job(id);
            self.telemetry.remove(id);
            return Err(full);
        }
        Ok(record)
    }

    /// Worker claim: `Queued → Running`; `None` when the job vanished
    /// (cancelled in the pop window).
    pub fn claim_running(&self, id: u64) -> Option<JobRequest> {
        let mut jobs = self.jobs.lock().unwrap();
        let record = jobs.get_mut(&id)?;
        if record.state != JobState::Queued {
            return None;
        }
        record.state = JobState::Running;
        self.running.fetch_add(1, Ordering::Relaxed);
        Some(record.request.clone())
    }

    /// Record that warm-start overrides were injected (the persisted
    /// record carries the resolved view).
    pub fn mark_warm_started(&self, id: u64) {
        if let Some(r) = self.jobs.lock().unwrap().get_mut(&id) {
            r.warm_started = true;
        }
    }

    /// Worker completion: move to a terminal state and persist.
    pub fn finish(
        &self,
        id: u64,
        state: JobState,
        error: Option<String>,
        outcome_json: Option<String>,
    ) {
        debug_assert!(state.is_terminal());
        let mut jobs = self.jobs.lock().unwrap();
        let Some(record) = jobs.get_mut(&id) else {
            return;
        };
        if record.state == JobState::Running {
            self.running.fetch_sub(1, Ordering::Relaxed);
        }
        record.state = state;
        record.error = error;
        record.outcome_json = outcome_json;
        let snapshot = record.clone();
        drop(jobs);
        if let Err(e) = self.store.save_job(&snapshot) {
            eprintln!("serve: failed to persist job {id}: {e:#}");
        }
    }

    /// Cancel a still-queued job.
    pub fn cancel(&self, id: u64) -> std::result::Result<JobRecord, CancelError> {
        let mut jobs = self.jobs.lock().unwrap();
        let record = jobs.get_mut(&id).ok_or(CancelError::NotFound)?;
        if record.state != JobState::Queued || !self.queue.cancel(id) {
            return Err(CancelError::NotCancellable(record.state));
        }
        record.state = JobState::Cancelled;
        record.error = Some("cancelled by client".to_string());
        let snapshot = record.clone();
        drop(jobs);
        if let Err(e) = self.store.save_job(&snapshot) {
            eprintln!("serve: failed to persist job {id}: {e:#}");
        }
        if let Some(feed) = self.telemetry.get(id) {
            feed.close();
        }
        Ok(snapshot)
    }

    pub fn get(&self, id: u64) -> Option<JobRecord> {
        self.jobs.lock().unwrap().get(&id).cloned()
    }

    /// All records, id order.
    pub fn list(&self) -> Vec<JobRecord> {
        self.jobs.lock().unwrap().values().cloned().collect()
    }

    /// `(queued, running)` — the `/healthz` load numbers.
    pub fn counts(&self) -> (usize, usize) {
        (self.queue.len(), self.running.load(Ordering::Relaxed))
    }

    /// Graceful shutdown step one: stop admissions, cancel everything
    /// still queued (persisting each), and wake blocked workers. Running
    /// jobs keep going — the caller joins the pool to drain them.
    pub fn begin_shutdown(&self) {
        for id in self.queue.close() {
            let mut jobs = self.jobs.lock().unwrap();
            if let Some(record) = jobs.get_mut(&id) {
                if record.state == JobState::Queued {
                    record.state = JobState::Cancelled;
                    record.error = Some("daemon shutting down".to_string());
                    let snapshot = record.clone();
                    drop(jobs);
                    if let Err(e) = self.store.save_job(&snapshot) {
                        eprintln!("serve: failed to persist job {id}: {e:#}");
                    }
                    if let Some(feed) = self.telemetry.get(id) {
                        feed.close();
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64 as TestCounter;

    fn tmp_state(tag: &str, capacity: usize, workers: usize) -> ServeState {
        static N: TestCounter = TestCounter::new(0);
        let dir = std::env::temp_dir().join(format!(
            "netbn_state_{tag}_{}_{}",
            std::process::id(),
            N.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&dir);
        ServeState::new(Store::open(&dir).unwrap(), capacity, workers).unwrap()
    }

    fn req(scenario: &str) -> JobRequest {
        JobRequest { scenario: scenario.into(), params: vec![], priority: 5 }
    }

    #[test]
    fn lifecycle_queued_running_done() {
        let s = tmp_state("life", 4, 1);
        let r = s.submit(req("simulate")).unwrap();
        assert_eq!(r.state, JobState::Queued);
        assert_eq!(s.counts(), (1, 0));
        let popped = s.queue.pop().unwrap();
        assert_eq!(popped, r.id);
        assert!(s.claim_running(popped).is_some());
        assert_eq!(s.counts(), (0, 1));
        assert!(s.claim_running(popped).is_none(), "double claim must fail");
        s.finish(popped, JobState::Done, None, Some("{}".into()));
        assert_eq!(s.counts(), (0, 0));
        let done = s.get(popped).unwrap();
        assert_eq!(done.state, JobState::Done);
        // Persisted too.
        assert_eq!(s.store.load_jobs().unwrap()[0].state, JobState::Done);
    }

    #[test]
    fn submit_rolls_back_cleanly_on_a_full_queue() {
        let s = tmp_state("full", 1, 1);
        let a = s.submit(req("simulate")).unwrap();
        let full = s.submit(req("simulate")).unwrap_err();
        assert_eq!(full.queued, 1);
        assert_eq!(s.list().len(), 1, "rejected submission must leave no record");
        assert_eq!(s.store.load_jobs().unwrap().len(), 1);
        // Ids keep increasing; the rolled-back id is simply skipped.
        s.queue.cancel(a.id);
        let b = s.submit(req("simulate")).unwrap();
        assert!(b.id > a.id + 1);
    }

    #[test]
    fn cancel_only_touches_queued_jobs() {
        let s = tmp_state("cancel", 4, 1);
        let a = s.submit(req("simulate")).unwrap();
        let cancelled = s.cancel(a.id).unwrap();
        assert_eq!(cancelled.state, JobState::Cancelled);
        assert_eq!(s.cancel(a.id), Err(CancelError::NotCancellable(JobState::Cancelled)));
        assert_eq!(s.cancel(999), Err(CancelError::NotFound));
        // A claimed (running) job is not cancellable.
        let b = s.submit(req("simulate")).unwrap();
        assert_eq!(s.queue.pop(), Some(b.id));
        s.claim_running(b.id);
        assert_eq!(s.cancel(b.id), Err(CancelError::NotCancellable(JobState::Running)));
    }

    #[test]
    fn restart_reload_cancels_interrupted_jobs_and_resumes_ids() {
        let dir = std::env::temp_dir().join(format!(
            "netbn_state_reload_{}_{}",
            std::process::id(),
            line!()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let s = ServeState::new(Store::open(&dir).unwrap(), 4, 1).unwrap();
        let a = s.submit(req("simulate")).unwrap();
        let b = s.submit(req("fig1")).unwrap();
        s.queue.pop();
        s.claim_running(a.id);
        s.finish(a.id, JobState::Done, None, Some("{\"passed\":true}".into()));
        drop(s); // "crash" with b still queued

        let s2 = ServeState::new(Store::open(&dir).unwrap(), 4, 1).unwrap();
        let a2 = s2.get(a.id).unwrap();
        assert_eq!(a2.state, JobState::Done);
        assert_eq!(a2.outcome_json.as_deref(), Some("{\"passed\":true}"));
        let b2 = s2.get(b.id).unwrap();
        assert_eq!(b2.state, JobState::Cancelled, "interrupted job must be cancelled on reload");
        let c = s2.submit(req("simulate")).unwrap();
        assert!(c.id > b.id, "ids must not be reused across restarts");
    }

    #[test]
    fn begin_shutdown_cancels_queued_and_persists() {
        let s = tmp_state("shutdown", 4, 1);
        let a = s.submit(req("simulate")).unwrap();
        let b = s.submit(req("fig1")).unwrap();
        s.begin_shutdown();
        assert_eq!(s.get(a.id).unwrap().state, JobState::Cancelled);
        assert_eq!(s.get(b.id).unwrap().state, JobState::Cancelled);
        assert!(s.queue.pop().is_none(), "closed queue releases workers");
        assert!(s.submit(req("simulate")).is_err(), "no admissions after shutdown");
        let on_disk = s.store.load_jobs().unwrap();
        assert!(on_disk.iter().all(|r| r.state == JobState::Cancelled));
    }
}
