//! The on-disk results + tuner-state store behind `netbn serve --store`.
//!
//! Layout under the store root:
//!
//! ```text
//! <store>/jobs/<id>.json      one JobRecord per submitted job
//! <store>/tuner/<key>.json    one TunerCheckpoint per scenario
//! <store>/timeseries.jsonl    sampled TsPoints, append-only + compaction
//! ```
//!
//! Every record write goes through a temp-file + rename so a daemon
//! killed mid-write never leaves a torn record. On restart the daemon
//! reloads both trees: finished jobs become queryable history, and
//! checkpoints warm-start resubmitted jobs
//! ([`crate::engine::jobqueue::warm_start_overrides`]) — the first
//! slice of the ROADMAP's "persist and reuse tuner state".
//!
//! The timeseries log is the durable half of [`crate::obs::timeseries`]:
//! batches append as JSONL; when the file outgrows
//! [`TS_COMPACT_LINES`] it is compacted (newest half kept, via
//! temp + rename so compaction is crash-safe); and on restart
//! [`Store::last_timeseries_seq`] recovers the high-water sequence so
//! the resumed sampler continues the seq space with no gap and no
//! duplicate.

use super::job::JobRecord;
use crate::obs::timeseries::TsPoint;
use crate::tune::TunerCheckpoint;
use crate::Result;
use anyhow::Context;
use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

/// Compact `timeseries.jsonl` once it exceeds this many lines.
pub const TS_COMPACT_LINES: usize = 100_000;

pub struct Store {
    root: PathBuf,
}

impl Store {
    /// Open (creating if needed) a store rooted at `root`.
    pub fn open(root: &Path) -> Result<Store> {
        fs::create_dir_all(root.join("jobs"))
            .with_context(|| format!("creating store at {}", root.display()))?;
        fs::create_dir_all(root.join("tuner"))?;
        Ok(Store { root: root.to_path_buf() })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn write_atomic(&self, path: &Path, contents: &str) -> Result<()> {
        let tmp = path.with_extension("json.tmp");
        fs::write(&tmp, contents).with_context(|| format!("writing {}", tmp.display()))?;
        fs::rename(&tmp, path).with_context(|| format!("renaming into {}", path.display()))?;
        Ok(())
    }

    /// Persist (or overwrite) one job record.
    pub fn save_job(&self, record: &JobRecord) -> Result<()> {
        let path = self.root.join("jobs").join(format!("{}.json", record.id));
        self.write_atomic(&path, &record.to_json())
    }

    /// Remove one job record (submission rollback after an admission
    /// failure); missing files are fine.
    pub fn delete_job(&self, id: u64) {
        let _ = fs::remove_file(self.root.join("jobs").join(format!("{id}.json")));
    }

    /// All persisted job records, ordered by id.
    pub fn load_jobs(&self) -> Result<Vec<JobRecord>> {
        let mut records = Vec::new();
        for entry in fs::read_dir(self.root.join("jobs"))? {
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) != Some("json") {
                continue;
            }
            let text = fs::read_to_string(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            records.push(
                JobRecord::from_json(&text)
                    .with_context(|| format!("parsing {}", path.display()))?,
            );
        }
        records.sort_by_key(|r| r.id);
        Ok(records)
    }

    /// Persist the tuner checkpoint for `scenario`.
    pub fn save_tuner(&self, scenario: &str, ck: &TunerCheckpoint) -> Result<()> {
        let path = self.root.join("tuner").join(format!("{}.json", file_key(scenario)));
        self.write_atomic(&path, &ck.to_json())
    }

    /// The persisted checkpoint for `scenario`, if any. A corrupt file
    /// reads as `None` — a warm start is an optimization, never a
    /// reason to refuse a job.
    pub fn load_tuner(&self, scenario: &str) -> Option<TunerCheckpoint> {
        let path = self.root.join("tuner").join(format!("{}.json", file_key(scenario)));
        let text = fs::read_to_string(path).ok()?;
        TunerCheckpoint::from_json(&text).ok()
    }

    fn timeseries_path(&self) -> PathBuf {
        self.root.join("timeseries.jsonl")
    }

    /// Append one sampled batch to `timeseries.jsonl`, compacting first
    /// if the log has outgrown [`TS_COMPACT_LINES`]. Compaction keeps
    /// the newest half and rewrites through temp + rename, so a crash
    /// mid-compaction leaves either the old log or the new one — seq
    /// numbers survive intact either way.
    pub fn append_timeseries(&self, points: &[TsPoint]) -> Result<()> {
        if points.is_empty() {
            return Ok(());
        }
        let path = self.timeseries_path();
        if let Ok(text) = fs::read_to_string(&path) {
            let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
            if lines.len() > TS_COMPACT_LINES {
                let keep = &lines[lines.len() / 2..];
                let tmp = self.root.join("timeseries.jsonl.tmp");
                let mut body = keep.join("\n");
                body.push('\n');
                fs::write(&tmp, body).with_context(|| format!("writing {}", tmp.display()))?;
                fs::rename(&tmp, &path)
                    .with_context(|| format!("compacting {}", path.display()))?;
            }
        }
        let mut file = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut batch = String::new();
        for p in points {
            batch.push_str(&p.to_json_line());
            batch.push('\n');
        }
        file.write_all(batch.as_bytes())
            .with_context(|| format!("appending {}", path.display()))?;
        Ok(())
    }

    /// The highest persisted timeseries seq, if any — the restart
    /// resume point (`resume_from(seq + 1)`). Scans from the tail;
    /// torn or corrupt trailing lines are skipped, not fatal.
    pub fn last_timeseries_seq(&self) -> Option<u64> {
        let text = fs::read_to_string(self.timeseries_path()).ok()?;
        text.lines()
            .rev()
            .filter_map(|l| TsPoint::from_json_line(l).ok())
            .map(|p| p.seq)
            .next()
    }

    /// Persisted timeseries points with `seq >= after`, in log order.
    pub fn load_timeseries_since(&self, after: u64) -> Vec<TsPoint> {
        let Ok(text) = fs::read_to_string(self.timeseries_path()) else {
            return Vec::new();
        };
        text.lines()
            .filter_map(|l| TsPoint::from_json_line(l).ok())
            .filter(|p| p.seq >= after)
            .collect()
    }
}

/// Scenario name → safe file stem.
fn file_key(scenario: &str) -> String {
    scenario
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::jobqueue::JobRequest;
    use crate::serve::job::JobState;
    use crate::tune::KnobPoint;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn tmp_store(tag: &str) -> Store {
        static N: AtomicU64 = AtomicU64::new(0);
        let dir = std::env::temp_dir().join(format!(
            "netbn_store_{tag}_{}_{}",
            std::process::id(),
            N.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = fs::remove_dir_all(&dir);
        Store::open(&dir).unwrap()
    }

    fn record(id: u64, state: JobState) -> JobRecord {
        JobRecord {
            id,
            request: JobRequest {
                scenario: "simulate".into(),
                params: vec![("workers".into(), "8".into())],
                priority: 7,
            },
            state,
            warm_started: id % 2 == 0,
            error: None,
            outcome_json: None,
        }
    }

    #[test]
    fn jobs_round_trip_across_reopen() {
        let store = tmp_store("jobs");
        let mut done = record(3, JobState::Done);
        done.outcome_json = Some("{\"scenario\":\"simulate\",\"passed\":true}".into());
        let mut failed = record(1, JobState::Failed);
        failed.error = Some("boom: \"quoted\"\nsecond line".into());
        store.save_job(&done).unwrap();
        store.save_job(&failed).unwrap();

        let reopened = Store::open(store.root()).unwrap();
        let loaded = reopened.load_jobs().unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded[0], failed, "sorted by id, exact round trip");
        assert_eq!(loaded[1], done);
    }

    #[test]
    fn save_overwrites_in_place() {
        let store = tmp_store("overwrite");
        let mut r = record(5, JobState::Queued);
        store.save_job(&r).unwrap();
        r.state = JobState::Done;
        store.save_job(&r).unwrap();
        let loaded = store.load_jobs().unwrap();
        assert_eq!(loaded.len(), 1);
        assert_eq!(loaded[0].state, JobState::Done);
    }

    #[test]
    fn timeseries_appends_resume_and_survive_torn_tails() {
        use crate::obs::timeseries::{TsKind, TsPoint};
        let store = tmp_store("ts");
        assert_eq!(store.last_timeseries_seq(), None);
        let point = |seq: u64| TsPoint {
            seq,
            t_s: seq as f64,
            series: "e2e.busbw_gbps".to_string(),
            value: 10.0,
            kind: TsKind::Level,
        };
        store.append_timeseries(&[point(0), point(1)]).unwrap();
        store.append_timeseries(&[point(2)]).unwrap();
        assert_eq!(store.last_timeseries_seq(), Some(2));
        let loaded = store.load_timeseries_since(1);
        assert_eq!(loaded.iter().map(|p| p.seq).collect::<Vec<_>>(), vec![1, 2]);
        // A torn trailing line (daemon killed mid-append) is skipped.
        let mut f = fs::OpenOptions::new()
            .append(true)
            .open(store.root().join("timeseries.jsonl"))
            .unwrap();
        f.write_all(b"{\"seq\":99,\"t_s").unwrap();
        drop(f);
        assert_eq!(store.last_timeseries_seq(), Some(2));
        // Resume and append with the continued seq space: no dup, no gap.
        let reopened = Store::open(store.root()).unwrap();
        let next = reopened.last_timeseries_seq().unwrap() + 1;
        reopened.append_timeseries(&[point(next)]).unwrap();
        let seqs: Vec<u64> =
            reopened.load_timeseries_since(0).iter().map(|p| p.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2, 3]);
    }

    #[test]
    fn timeseries_log_compacts_keeping_the_newest_half() {
        use crate::obs::timeseries::{TsKind, TsPoint};
        let store = tmp_store("ts_compact");
        let path = store.root().join("timeseries.jsonl");
        // Seed an oversized log directly (unit-speed stand-in for a
        // long-lived daemon), then trigger compaction with one append.
        let mut body = String::new();
        for seq in 0..(TS_COMPACT_LINES as u64 + 10) {
            body.push_str(&format!(
                "{{\"seq\":{seq},\"t_s\":0.0,\"series\":\"g\",\"kind\":\"level\",\"value\":1}}\n"
            ));
        }
        fs::write(&path, body).unwrap();
        store
            .append_timeseries(&[TsPoint {
                seq: TS_COMPACT_LINES as u64 + 10,
                t_s: 1.0,
                series: "g".to_string(),
                value: 1.0,
                kind: TsKind::Level,
            }])
            .unwrap();
        let points = store.load_timeseries_since(0);
        assert!(points.len() <= TS_COMPACT_LINES / 2 + 20, "compaction kept {}", points.len());
        // Newest points survive; seqs stay strictly increasing.
        assert_eq!(points.last().unwrap().seq, TS_COMPACT_LINES as u64 + 10);
        assert!(points.windows(2).all(|w| w[1].seq > w[0].seq));
        assert_eq!(store.last_timeseries_seq(), Some(TS_COMPACT_LINES as u64 + 10));
    }

    #[test]
    fn tuner_checkpoints_round_trip_and_tolerate_corruption() {
        let store = tmp_store("tuner");
        assert!(store.load_tuner("emulate").is_none());
        let ck = TunerCheckpoint {
            chosen: KnobPoint { bucket_mb: 4.0, ..KnobPoint::default_static() },
            baseline_s: 0.25,
            steps_seen: 40,
            probe_phases: 1,
        };
        store.save_tuner("emulate", &ck).unwrap();
        assert_eq!(Store::open(store.root()).unwrap().load_tuner("emulate"), Some(ck));
        // Corruption degrades to a cold start, not an error.
        fs::write(store.root().join("tuner").join("emulate.json"), "garbage").unwrap();
        assert!(store.load_tuner("emulate").is_none());
    }
}
