//! The on-disk results + tuner-state store behind `netbn serve --store`.
//!
//! Layout under the store root:
//!
//! ```text
//! <store>/jobs/<id>.json    one JobRecord per submitted job
//! <store>/tuner/<key>.json  one TunerCheckpoint per scenario
//! ```
//!
//! Every write goes through a temp-file + rename so a daemon killed
//! mid-write never leaves a torn record. On restart the daemon reloads
//! both trees: finished jobs become queryable history, and checkpoints
//! warm-start resubmitted jobs ([`crate::engine::jobqueue::warm_start_overrides`])
//! — the first slice of the ROADMAP's "persist and reuse tuner state".

use super::job::JobRecord;
use crate::tune::TunerCheckpoint;
use crate::Result;
use anyhow::Context;
use std::fs;
use std::path::{Path, PathBuf};

pub struct Store {
    root: PathBuf,
}

impl Store {
    /// Open (creating if needed) a store rooted at `root`.
    pub fn open(root: &Path) -> Result<Store> {
        fs::create_dir_all(root.join("jobs"))
            .with_context(|| format!("creating store at {}", root.display()))?;
        fs::create_dir_all(root.join("tuner"))?;
        Ok(Store { root: root.to_path_buf() })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn write_atomic(&self, path: &Path, contents: &str) -> Result<()> {
        let tmp = path.with_extension("json.tmp");
        fs::write(&tmp, contents).with_context(|| format!("writing {}", tmp.display()))?;
        fs::rename(&tmp, path).with_context(|| format!("renaming into {}", path.display()))?;
        Ok(())
    }

    /// Persist (or overwrite) one job record.
    pub fn save_job(&self, record: &JobRecord) -> Result<()> {
        let path = self.root.join("jobs").join(format!("{}.json", record.id));
        self.write_atomic(&path, &record.to_json())
    }

    /// Remove one job record (submission rollback after an admission
    /// failure); missing files are fine.
    pub fn delete_job(&self, id: u64) {
        let _ = fs::remove_file(self.root.join("jobs").join(format!("{id}.json")));
    }

    /// All persisted job records, ordered by id.
    pub fn load_jobs(&self) -> Result<Vec<JobRecord>> {
        let mut records = Vec::new();
        for entry in fs::read_dir(self.root.join("jobs"))? {
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) != Some("json") {
                continue;
            }
            let text = fs::read_to_string(&path)
                .with_context(|| format!("reading {}", path.display()))?;
            records.push(
                JobRecord::from_json(&text)
                    .with_context(|| format!("parsing {}", path.display()))?,
            );
        }
        records.sort_by_key(|r| r.id);
        Ok(records)
    }

    /// Persist the tuner checkpoint for `scenario`.
    pub fn save_tuner(&self, scenario: &str, ck: &TunerCheckpoint) -> Result<()> {
        let path = self.root.join("tuner").join(format!("{}.json", file_key(scenario)));
        self.write_atomic(&path, &ck.to_json())
    }

    /// The persisted checkpoint for `scenario`, if any. A corrupt file
    /// reads as `None` — a warm start is an optimization, never a
    /// reason to refuse a job.
    pub fn load_tuner(&self, scenario: &str) -> Option<TunerCheckpoint> {
        let path = self.root.join("tuner").join(format!("{}.json", file_key(scenario)));
        let text = fs::read_to_string(path).ok()?;
        TunerCheckpoint::from_json(&text).ok()
    }
}

/// Scenario name → safe file stem.
fn file_key(scenario: &str) -> String {
    scenario
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '_' { c } else { '_' })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::jobqueue::JobRequest;
    use crate::serve::job::JobState;
    use crate::tune::KnobPoint;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn tmp_store(tag: &str) -> Store {
        static N: AtomicU64 = AtomicU64::new(0);
        let dir = std::env::temp_dir().join(format!(
            "netbn_store_{tag}_{}_{}",
            std::process::id(),
            N.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = fs::remove_dir_all(&dir);
        Store::open(&dir).unwrap()
    }

    fn record(id: u64, state: JobState) -> JobRecord {
        JobRecord {
            id,
            request: JobRequest {
                scenario: "simulate".into(),
                params: vec![("workers".into(), "8".into())],
                priority: 7,
            },
            state,
            warm_started: id % 2 == 0,
            error: None,
            outcome_json: None,
        }
    }

    #[test]
    fn jobs_round_trip_across_reopen() {
        let store = tmp_store("jobs");
        let mut done = record(3, JobState::Done);
        done.outcome_json = Some("{\"scenario\":\"simulate\",\"passed\":true}".into());
        let mut failed = record(1, JobState::Failed);
        failed.error = Some("boom: \"quoted\"\nsecond line".into());
        store.save_job(&done).unwrap();
        store.save_job(&failed).unwrap();

        let reopened = Store::open(store.root()).unwrap();
        let loaded = reopened.load_jobs().unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded[0], failed, "sorted by id, exact round trip");
        assert_eq!(loaded[1], done);
    }

    #[test]
    fn save_overwrites_in_place() {
        let store = tmp_store("overwrite");
        let mut r = record(5, JobState::Queued);
        store.save_job(&r).unwrap();
        r.state = JobState::Done;
        store.save_job(&r).unwrap();
        let loaded = store.load_jobs().unwrap();
        assert_eq!(loaded.len(), 1);
        assert_eq!(loaded[0].state, JobState::Done);
    }

    #[test]
    fn tuner_checkpoints_round_trip_and_tolerate_corruption() {
        let store = tmp_store("tuner");
        assert!(store.load_tuner("emulate").is_none());
        let ck = TunerCheckpoint {
            chosen: KnobPoint { bucket_mb: 4.0, ..KnobPoint::default_static() },
            baseline_s: 0.25,
            steps_seen: 40,
            probe_phases: 1,
        };
        store.save_tuner("emulate", &ck).unwrap();
        assert_eq!(Store::open(store.root()).unwrap().load_tuner("emulate"), Some(ck));
        // Corruption degrades to a cold start, not an error.
        fs::write(store.root().join("tuner").join("emulate.json"), "garbage").unwrap();
        assert!(store.load_tuner("emulate").is_none());
    }
}
